from .ckpt import (CheckpointManager, restore_pytree,  # noqa: F401
                   save_pytree)
