"""Sharded checkpointing: atomic writes, async writer, step retention,
elastic (mesh-shape-agnostic) restore.

Format: one ``.npz`` per checkpoint step holding every leaf under its
flattened pytree path, plus a JSON manifest. Leaves are fetched to host
(fully replicated view) before writing, and restored with any target
sharding — so a run checkpointed on a 2×16×16 mesh restores onto 16×16 or a
single host (elastic scaling / failover re-provisioning). At real pod scale
the write path would be per-host shard files (e.g. tensorstore/ocdbt);
the manager interface is the production contract, the storage codec is not.

Durability: writes go to ``<step>.tmp.npz`` and are atomically renamed, so a
mid-write failure never corrupts the latest checkpoint (restart-safe).
"""
from __future__ import annotations

import json
import os
import re
import threading
import time
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(p).strip("[].'") for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V" or not arr.dtype.isnative or \
                str(arr.dtype) == "bfloat16":
            arr = arr.astype(np.float32)  # bf16 et al: lossless upcast
        out[key] = arr
    return out


def save_pytree(tree, path: str) -> None:
    if not path.endswith(".npz"):
        path = path + ".npz"
    arrs = _flatten(tree)
    tmp = path[:-4] + ".tmp"      # np.savez appends ".npz"
    np.savez(tmp, **arrs)
    os.replace(tmp + ".npz", path)


def restore_pytree(template, path: str, shardings=None):
    """Restore into ``template``'s structure; optional target shardings
    (a pytree of jax.sharding.Sharding) for elastic re-layout."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    data = np.load(path)
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in flat:
        key = "/".join(str(x).strip("[].'") for x in p)
        arr = data[key]
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        leaves.append(arr.astype(leaf.dtype))
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree


class CheckpointManager:
    """Async, retention-managed checkpointing for the train loop."""

    def __init__(self, directory: str, *, keep: int = 3,
                 async_write: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_write = async_write
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:09d}.npz")

    def latest_step(self) -> Optional[int]:
        steps = []
        for f in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)\.npz", f)
            if m:
                steps.append(int(m.group(1)))
        return max(steps) if steps else None

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save(self, step: int, tree: Any) -> None:
        self.wait()  # one in-flight write at a time
        # snapshot to host *before* returning control (consistent view even
        # if training mutates buffers next step)
        arrs = _flatten(tree)
        path = self._path(step)

        def _write():
            try:
                tmp = path + ".tmp"
                np.savez(tmp, **arrs)
                os.replace(tmp + ".npz", path)
                self._gc()
            except BaseException as e:  # surfaced on next wait()/save()
                self._error = e

        if self.async_write:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            # Synchronous writes fail loudly at the call site — a swallowed
            # error here would be a silent hole in the retention chain.
            _write()
            if self._error is not None:
                err, self._error = self._error, None
                raise err

    def restore_latest(self, template, shardings=None):
        self.wait()
        step = self.latest_step()
        if step is None:
            return None, None
        return step, restore_pytree(template, self._path(step), shardings)

    def _gc(self) -> None:
        steps = sorted(
            int(re.fullmatch(r"step_(\d+)\.npz", f).group(1))
            for f in os.listdir(self.dir)
            if re.fullmatch(r"step_(\d+)\.npz", f))
        for s in steps[:-self.keep]:
            try:
                os.remove(self._path(s))
            except OSError:
                pass
