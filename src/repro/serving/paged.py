"""Paged KV cache with a DiLi page table (DESIGN.md §3.1).

The page table is a DiLi instance: key = (seq_id << PAGE_BITS) | page_idx,
value = physical page slot. This buys the serving layer exactly what the
paper promises a database: the (seq,page) -> slot index is *dynamically
re-partitionable* (Split hot key ranges) and *live-migratable* (Move a
sublist of pages to another server while decode steps keep running —
temporary replication covers the in-flight page allocations).

The decode hot path is jitted and consumes an array *snapshot* of the table
(page_table[b, p]) refreshed from DiLi state between steps; lookups inside
the step are O(1) gathers (or the hybrid_search kernel when the table is
consulted by key).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import DiLiClient, LocalBackend
from repro.core.types import DiLiConfig
from repro.kernels import ops as K
from repro.models import transformer as T
from repro.models.attention import decode_attention
from repro.models.config import ArchConfig
from repro.models.layers import apply_rope, rms_norm, swiglu

PAGE_BITS = 12                      # up to 4096 pages per sequence
MAX_SEQS = 1 << 17


def page_key(seq_id: int, page: int) -> int:
    return (seq_id << PAGE_BITS) | page


class PagedKVManager:
    """Host-side page allocation backed by a DiLi cluster."""

    def __init__(self, cfg: ArchConfig, *, num_pages: int, page_size: int,
                 dili_shards: int = 1, dtype=jnp.float32):
        self.cfg = cfg
        self.page_size = page_size
        self.num_pages = num_pages
        self.dtype = dtype
        kh, hd, nl = cfg.n_kv_heads, cfg.hd, cfg.n_layers
        self.k_pages = jnp.zeros((nl, num_pages, page_size, kh, hd), dtype)
        self.v_pages = jnp.zeros((nl, num_pages, page_size, kh, hd), dtype)
        self.free_slots: List[int] = list(range(num_pages - 1, -1, -1))
        dcfg = DiLiConfig(num_shards=dili_shards,
                          pool_capacity=max(4 * num_pages, 1024),
                          max_sublists=64, max_ctrs=64,
                          max_scan=max(4 * num_pages, 1024),
                          batch_size=32, mailbox_cap=256, move_batch=16)
        self.backend = LocalBackend(dcfg)
        self.client = DiLiClient(self.backend)
        # the raw cluster stays reachable for tests/tools that inject
        # background commands or inspect chains directly
        self.dili = self.backend.cluster
        self._table: Dict[int, int] = {}   # key -> slot (snapshot cache)

    # ------------------------------------------------------------ alloc/free
    def alloc_page(self, seq_id: int, page: int) -> int:
        assert self.free_slots, "page pool exhausted"
        slot = self.free_slots.pop()
        key = page_key(seq_id, page)
        self.client.insert(key, value=slot)
        self.client.drain()
        self._table[key] = slot
        return slot

    def free_seq(self, seq_id: int, num_pages: int) -> None:
        keys = [page_key(seq_id, p) for p in range(num_pages)]
        self.client.remove_batch(keys)
        self.client.drain()
        for k in keys:
            slot = self._table.pop(k, None)
            if slot is not None:
                self.free_slots.append(slot)

    # -------------------------------------------------------------- lookups
    def refresh_table(self) -> None:
        """Re-snapshot key->slot from the DiLi chains (after Split/Move)."""
        table: Dict[int, int] = {}
        for s in range(self.backend.n):
            for e in self.backend.sublists(s):
                if e["owner"] != s:
                    continue
                for k, _idx, val in self.backend.shard_chain(
                        s, e["head_idx"], include_meta=True):
                    table[k] = val
        self._table = table

    def page_table(self, seq_ids: List[int], pages_per_seq: int
                   ) -> jnp.ndarray:
        rows = []
        for sid in seq_ids:
            row = [self._table.get(page_key(sid, p), 0)
                   for p in range(pages_per_seq)]
            rows.append(row)
        return jnp.asarray(np.asarray(rows, np.int32))

    # ------------------------------------------------------------ KV writes
    def write_prefill(self, layer_caches, seq_ids: List[int],
                      seq_lens: List[int]) -> None:
        """Scatter contiguous prefill caches [L,B,S,KH,D] into pages."""
        ps = self.page_size
        k_pages, v_pages = self.k_pages, self.v_pages
        kc, vc = layer_caches["k"], layer_caches["v"]
        for b, sid in enumerate(seq_ids):
            n_pages = (seq_lens[b] + ps - 1) // ps
            for p in range(n_pages):
                slot = self._table[page_key(sid, p)]
                k_blk = kc[:, b, p * ps:(p + 1) * ps]
                v_blk = vc[:, b, p * ps:(p + 1) * ps]
                k_pages = k_pages.at[:, slot, :k_blk.shape[1]].set(
                    k_blk.astype(self.dtype))
                v_pages = v_pages.at[:, slot, :v_blk.shape[1]].set(
                    v_blk.astype(self.dtype))
        self.k_pages, self.v_pages = k_pages, v_pages


def paged_decode_step(params, cfg: ArchConfig, tokens, k_pages, v_pages,
                      page_table, seq_lens, *, page_size: int,
                      use_kernel: bool = True):
    """One decode step for dense-family models over paged KV.

    tokens: [B, 1]; page_table: [B, PP]; seq_lens: [B] (tokens already in
    cache). Returns (logits [B, V], k_pages, v_pages) with the new token's
    KV scattered into its page.
    """
    h = params["embed"][tokens]
    b = tokens.shape[0]
    positions = seq_lens[:, None]
    blocks = params["blocks"]

    def body(carry, xs):
        h, = carry
        blk, kp, vp = xs
        x = rms_norm(h, blk["ln1"], cfg.norm_eps)
        hd, nh, kh = cfg.hd, cfg.n_heads, cfg.n_kv_heads
        q = x @ blk["attn"]["wq"]
        k = x @ blk["attn"]["wk"]
        v = x @ blk["attn"]["wv"]
        if cfg.qkv_bias:
            q = q + blk["attn"]["bq"]
            k = k + blk["attn"]["bk"]
            v = v + blk["attn"]["bv"]
        q = apply_rope(q.reshape(b, 1, nh, hd), positions, cfg.rope_theta)
        k = apply_rope(k.reshape(b, 1, kh, hd), positions, cfg.rope_theta)
        v = v.reshape(b, 1, kh, hd)

        # scatter the new token's K/V into its page slot
        slot = page_table[jnp.arange(b), seq_lens // page_size]
        off = seq_lens % page_size
        kp = kp.at[slot, off].set(k[:, 0].astype(kp.dtype))
        vp = vp.at[slot, off].set(v[:, 0].astype(vp.dtype))

        if use_kernel:
            attn = K.paged_attention(q[:, 0], kp, vp, page_table,
                                     seq_lens + 1, page_size=page_size)
            attn = attn[:, None]
        else:
            kc = kp[page_table].reshape(b, -1, kh, hd)
            vc = vp[page_table].reshape(b, -1, kh, hd)
            attn = decode_attention(q, kc, vc, seq_lens + 1)
        x = attn.reshape(b, 1, nh * hd) @ blk["attn"]["wo"]
        h = h + x
        hn = rms_norm(h, blk["ln2"], cfg.norm_eps)
        x = swiglu(hn, blk["mlp"]["w_gate"], blk["mlp"]["w_up"],
                   blk["mlp"]["w_down"])
        return (h + x,), (kp, vp)

    (h,), (k_pages, v_pages) = jax.lax.scan(
        body, (h,), (blocks, k_pages, v_pages))
    h = rms_norm(h[:, -1:], params["final_norm"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = (h @ head)[:, 0]
    return logits, k_pages, v_pages
