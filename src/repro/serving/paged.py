"""Paged KV cache with a DiLi page table (DESIGN.md §3.1, §16).

The page table is a DiLi instance: key = (seq_id << PAGE_BITS) | page_idx,
value = physical page slot. This buys the serving layer exactly what the
paper promises a database: the (seq,page) -> slot index is *dynamically
re-partitionable* (Split hot key ranges) and *live-migratable* (Move a
sublist of pages to another server while decode steps keep running —
temporary replication covers the in-flight page allocations).

The decode hot path is jitted and consumes an array *snapshot* of the table
(page_table[b, p]) refreshed from DiLi state between steps; lookups inside
the step are O(1) gathers (or the hybrid_search kernel when the table is
consulted by key). Because page keys pack (seq_id, page) into one sorted
key space, a sequence's pages occupy one contiguous key interval — so the
snapshot refresh after a migration is a single ``RANGE`` scan over
``[seq_id << PAGE_BITS, (seq_id+1) << PAGE_BITS)`` per live sequence
(``refresh_seq``), not a cluster-wide chain rescan (``refresh_table``,
kept as the slow fallback and the benchmark baseline). Snapshot misses are
surfaced as a ``-1`` sentinel and masked out of the decode gather/scatter;
they must never alias onto physical slot 0.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import DiLiClient, LocalBackend
from repro.core.types import DiLiConfig
from repro.kernels import ops as K
from repro.models import transformer as T
from repro.models.attention import decode_attention
from repro.models.config import ArchConfig
from repro.models.layers import apply_rope, rms_norm, swiglu

PAGE_BITS = 12                      # up to 4096 pages per sequence
MAX_SEQS = 1 << 17


class PagePoolExhausted(RuntimeError):
    """No free physical page slots (explicit — must survive ``python -O``,
    unlike the bare assert it replaced; same class of fix as
    ``OutboxOverflow``)."""


def page_key(seq_id: int, page: int) -> int:
    return (seq_id << PAGE_BITS) | page


class PagedKVManager:
    """Host-side page allocation backed by a DiLi cluster."""

    def __init__(self, cfg: ArchConfig, *, num_pages: int, page_size: int,
                 dili_shards: int = 1, dtype=jnp.float32):
        self.cfg = cfg
        self.page_size = page_size
        self.num_pages = num_pages
        self.dtype = dtype
        kh, hd, nl = cfg.n_kv_heads, cfg.hd, cfg.n_layers
        self.k_pages = jnp.zeros((nl, num_pages, page_size, kh, hd), dtype)
        self.v_pages = jnp.zeros((nl, num_pages, page_size, kh, hd), dtype)
        self.free_slots: List[int] = list(range(num_pages - 1, -1, -1))
        dcfg = DiLiConfig(num_shards=dili_shards,
                          pool_capacity=max(4 * num_pages, 1024),
                          max_sublists=64, max_ctrs=64,
                          max_scan=max(4 * num_pages, 1024),
                          batch_size=32, mailbox_cap=256, move_batch=16,
                          range_scan=True)
        self.backend = LocalBackend(dcfg)
        self.client = DiLiClient(self.backend)
        # the raw cluster stays reachable for tests/tools that inject
        # background commands or inspect chains directly
        self.dili = self.backend.cluster
        self._table: Dict[int, int] = {}   # key -> slot (snapshot cache)
        # authoritative host-side allocation record (key -> slot): the
        # ground truth for "was this page ever allocated", independent of
        # the snapshot cache's staleness during migrations
        self._allocated: Dict[int, int] = {}

    # ------------------------------------------------------------ alloc/free
    def alloc_page(self, seq_id: int, page: int) -> int:
        if not self.free_slots:
            raise PagePoolExhausted(
                f"page pool exhausted: all {self.num_pages} physical "
                f"slots are live (alloc seq={seq_id} page={page})")
        slot = self.free_slots.pop()
        key = page_key(seq_id, page)
        fut = self.client.insert(key, value=slot)
        self.client.drain()
        if not fut.result(wait=False):
            self.free_slots.append(slot)
            raise RuntimeError(
                f"alloc_page: key {key} (seq={seq_id} page={page}) is "
                f"already present in the page table — double allocation")
        self._table[key] = slot
        self._allocated[key] = slot
        return slot

    def alloc_pages(self, seq_id: int, n_pages: int) -> List[int]:
        """Allocate ``n_pages`` consecutive pages for one sequence in a
        single batched insert (one drain instead of one per page)."""
        if len(self.free_slots) < n_pages:
            raise PagePoolExhausted(
                f"page pool exhausted: {len(self.free_slots)} free slots "
                f"< {n_pages} requested (alloc seq={seq_id})")
        keys = [page_key(seq_id, p) for p in range(n_pages)]
        slots = [self.free_slots.pop() for _ in keys]
        res = self.client.insert_batch(keys, slots)
        self.client.drain()
        oks = res.results(wait=False)
        bad = []
        for k, slot, ok in zip(keys, slots, oks):
            if ok:
                # live in DiLi now — must be tracked even on a partial
                # failure, or its slot could be recycled into an alias
                self._table[k] = slot
                self._allocated[k] = slot
            else:
                self.free_slots.append(slot)
                bad.append(k)
        if bad:
            raise RuntimeError(
                f"alloc_pages: keys {bad[:4]} (seq={seq_id}) already "
                f"present in the page table — double allocation")
        return slots

    def free_seq(self, seq_id: int, num_pages: int) -> None:
        """Remove a sequence's page mappings and recycle their slots.

        A slot is recycled only once its remove is *confirmed*: a bounced
        or failed remove would leave the key live in DiLi while the slot
        is reissued to another sequence — serving-level key resurrection.
        ``drain()`` raises if the backend never reaches quiescence, so a
        stuck remove cannot silently fall through to recycling either.
        """
        keys = [page_key(seq_id, p) for p in range(num_pages)]
        res = self.client.remove_batch(keys)
        self.client.drain()
        for k, ok in zip(keys, res.results(wait=False)):
            if k not in self._allocated:
                continue        # never allocated — nothing to recycle
            if not ok:
                raise RuntimeError(
                    f"free_seq: remove of page key {k} (seq={seq_id}) "
                    f"failed — the key is still live in the page table; "
                    f"recycling its slot would alias another sequence's "
                    f"KV")
            slot = self._allocated.pop(k)
            self._table.pop(k, None)
            self.free_slots.append(slot)

    # -------------------------------------------------------------- lookups
    def refresh_table(self) -> None:
        """Re-snapshot key->slot from the DiLi chains (after Split/Move).

        The cluster-wide full rescan — kept as the slow fallback and the
        benchmark baseline; ``refresh_seq`` is the RANGE-based fast path.
        """
        table: Dict[int, int] = {}
        for s in range(self.backend.n):
            for e in self.backend.sublists(s):
                if e["owner"] != s:
                    continue
                for k, _idx, val in self.backend.shard_chain(
                        s, e["head_idx"], include_meta=True):
                    table[k] = val
        self._table = table

    def refresh_seq(self, seq_id: int) -> int:
        """Refresh one sequence's snapshot rows with a single RANGE scan
        over its key interval (DESIGN.md §16) — the ordered-structure
        payoff: no other sequence's chains are touched. Returns the
        number of live mappings found."""
        return self.refresh_seqs([seq_id])

    def refresh_seqs(self, seq_ids: List[int]) -> int:
        """Refresh several sequences' snapshot rows concurrently: the
        spans are disjoint, so every scan is admitted in the same batch
        and one drain resolves them all (the decode loop refreshes the
        whole live batch this way after a migration). Returns the total
        number of live mappings found."""
        futs = []
        for sid in seq_ids:
            lo = page_key(sid, 0)
            hi = page_key(sid + 1, 0)
            futs.append((lo, hi, self.client.range(lo, hi,
                                                   limit=1 << PAGE_BITS)))
        self.client.drain()
        n = 0
        for lo, hi, fut in futs:
            items = fut.items(wait=False)
            for k in [k for k in self._table if lo <= k < hi]:
                del self._table[k]
            for k, slot in items:
                self._table[k] = slot
            n += len(items)
        return n

    def page_table(self, seq_ids: List[int], pages_per_seq) -> jnp.ndarray:
        """Dense [B, PP] slot snapshot for the decode step.

        ``pages_per_seq`` is one int or a per-sequence list; rows are
        padded to the max with ``-1``. A page inside a sequence's declared
        count that is missing from the snapshot yields ``-1`` (stale
        snapshot during a live migration — the decode step masks it) when
        it was ever allocated, and raises when it never was: slot 0 is a
        real page, and defaulting to it serves another sequence's KV.
        """
        if isinstance(pages_per_seq, int):
            pages_per_seq = [pages_per_seq] * len(seq_ids)
        if len(pages_per_seq) != len(seq_ids):
            raise ValueError(f"{len(pages_per_seq)} page counts vs "
                             f"{len(seq_ids)} seq ids")
        pp = max(pages_per_seq, default=0)
        rows = []
        for sid, n in zip(seq_ids, pages_per_seq):
            row = []
            for p in range(n):
                key = page_key(sid, p)
                slot = self._table.get(key)
                if slot is None:
                    if key not in self._allocated:
                        raise KeyError(
                            f"page_table: seq {sid} page {p} was never "
                            f"allocated — refusing to alias slot 0")
                    slot = -1       # allocated, snapshot stale: masked
                row.append(slot)
            rows.append(row + [-1] * (pp - n))
        return jnp.asarray(np.asarray(rows, np.int32).reshape(
            len(seq_ids), pp))

    # ------------------------------------------------------------ KV writes
    def write_prefill(self, layer_caches, seq_ids: List[int],
                      seq_lens: List[int]) -> None:
        """Scatter contiguous prefill caches [L,B,S,KH,D] into pages."""
        ps = self.page_size
        k_pages, v_pages = self.k_pages, self.v_pages
        kc, vc = layer_caches["k"], layer_caches["v"]
        for b, sid in enumerate(seq_ids):
            n_pages = (seq_lens[b] + ps - 1) // ps
            for p in range(n_pages):
                slot = self._table[page_key(sid, p)]
                k_blk = kc[:, b, p * ps:(p + 1) * ps]
                v_blk = vc[:, b, p * ps:(p + 1) * ps]
                k_pages = k_pages.at[:, slot, :k_blk.shape[1]].set(
                    k_blk.astype(self.dtype))
                v_pages = v_pages.at[:, slot, :v_blk.shape[1]].set(
                    v_blk.astype(self.dtype))
        self.k_pages, self.v_pages = k_pages, v_pages


def paged_decode_step(params, cfg: ArchConfig, tokens, k_pages, v_pages,
                      page_table, seq_lens, *, page_size: int,
                      use_kernel: bool = True):
    """One decode step for dense-family models over paged KV.

    tokens: [B, 1]; page_table: [B, PP]; seq_lens: [B] (tokens already in
    cache). Returns (logits [B, V], k_pages, v_pages) with the new token's
    KV scattered into its page.
    """
    h = params["embed"][tokens]
    b = tokens.shape[0]
    positions = seq_lens[:, None]
    blocks = params["blocks"]

    def body(carry, xs):
        h, = carry
        blk, kp, vp = xs
        x = rms_norm(h, blk["ln1"], cfg.norm_eps)
        hd, nh, kh = cfg.hd, cfg.n_heads, cfg.n_kv_heads
        q = x @ blk["attn"]["wq"]
        k = x @ blk["attn"]["wk"]
        v = x @ blk["attn"]["wv"]
        if cfg.qkv_bias:
            q = q + blk["attn"]["bq"]
            k = k + blk["attn"]["bk"]
            v = v + blk["attn"]["bv"]
        q = apply_rope(q.reshape(b, 1, nh, hd), positions, cfg.rope_theta)
        k = apply_rope(k.reshape(b, 1, kh, hd), positions, cfg.rope_theta)
        v = v.reshape(b, 1, kh, hd)

        # scatter the new token's K/V into its page slot. A -1 sentinel
        # (stale snapshot during migration) must not clamp onto slot 0 —
        # aim the write past the end instead; JAX drops out-of-bounds
        # scatter indices.
        slot = page_table[jnp.arange(b), seq_lens // page_size]
        off = seq_lens % page_size
        safe = jnp.where(slot >= 0, slot, kp.shape[0])
        kp = kp.at[safe, off].set(k[:, 0].astype(kp.dtype))
        vp = vp.at[safe, off].set(v[:, 0].astype(vp.dtype))

        if use_kernel:
            # the kernel indexes pages by table entry; -1 would clamp to
            # page 0 inside its gather (aliasing another sequence's KV),
            # so clamp host-side — sentinel pages sit at/beyond each
            # sequence's length and are masked by the kernel's length
            # predicate, never attended.
            attn = K.paged_attention(q[:, 0], kp, vp,
                                     jnp.maximum(page_table, 0),
                                     seq_lens + 1, page_size=page_size)
            attn = attn[:, None]
        else:
            # gather clamps -1 -> 0: zero-mask sentinel pages instead of
            # serving page 0's (another sequence's) KV
            pt = jnp.maximum(page_table, 0)
            live = (page_table >= 0)[:, :, None, None, None]
            kc = jnp.where(live, kp[pt], 0).reshape(b, -1, kh, hd)
            vc = jnp.where(live, vp[pt], 0).reshape(b, -1, kh, hd)
            attn = decode_attention(q, kc, vc, seq_lens + 1)
        x = attn.reshape(b, 1, nh * hd) @ blk["attn"]["wo"]
        h = h + x
        hn = rms_norm(h, blk["ln2"], cfg.norm_eps)
        x = swiglu(hn, blk["mlp"]["w_gate"], blk["mlp"]["w_up"],
                   blk["mlp"]["w_down"])
        return (h + x,), (kp, vp)

    (h,), (k_pages, v_pages) = jax.lax.scan(
        body, (h,), (blocks, k_pages, v_pages))
    h = rms_norm(h[:, -1:], params["final_norm"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = (h @ head)[:, 0]
    return logits, k_pages, v_pages
