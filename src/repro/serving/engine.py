"""Batched serving engine: admission, prefill, paged decode, live rebalance.

The engine ties the pieces together: requests are admitted into a decode
batch; prefill fills contiguous caches which are scattered into DiLi-indexed
pages; decode steps run the paged path; the load balancer may Split/Move the
page-index between steps — decode keeps running on the refreshed snapshot
(the paper's asynchronous re-partitioning, at the serving layer).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.balancer import Balancer
from repro.models import transformer as T
from repro.models.config import ArchConfig
from .paged import PagedKVManager, paged_decode_step


class BatchOverflow(RuntimeError):
    """Admission past ``max_batch`` (explicit — must survive ``python -O``,
    unlike the bare assert it replaced)."""


@dataclasses.dataclass
class Request:
    seq_id: int
    prompt: np.ndarray           # [S] int32
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, cfg: ArchConfig, params, *, page_size: int = 16,
                 num_pages: int = 256, max_batch: int = 8,
                 dili_shards: int = 1, dtype=jnp.float32,
                 use_kernel: bool = False, refresh_mode: str = "range"):
        self.cfg, self.params = cfg, params
        self.kv = PagedKVManager(cfg, num_pages=num_pages,
                                 page_size=page_size,
                                 dili_shards=dili_shards, dtype=dtype)
        self.page_size = page_size
        self.max_batch = max_batch
        self.use_kernel = use_kernel
        if refresh_mode not in ("range", "rescan"):
            raise ValueError(f"refresh_mode={refresh_mode!r} not in "
                             f"('range', 'rescan')")
        # how the page-table snapshot heals after a live migration:
        # "range" = one RANGE scan per live sequence (DESIGN.md §16),
        # "rescan" = the legacy cluster-wide chain walk (benchmark
        # baseline)
        self.refresh_mode = refresh_mode
        self.active: List[Request] = []
        self.balancer = Balancer(self.kv.backend, split_threshold=64)
        self._decode = jax.jit(
            lambda p, t, kp, vp, pt, sl: paged_decode_step(
                p, cfg, t, kp, vp, pt, sl, page_size=page_size,
                use_kernel=use_kernel))

    # --------------------------------------------------------------- admit
    def admit(self, req: Request) -> None:
        if len(self.active) >= self.max_batch:
            raise BatchOverflow(
                f"admit: decode batch is full ({len(self.active)}/"
                f"{self.max_batch}) — finish or evict a sequence first")
        s = len(req.prompt)
        n_pages = (s + req.max_new + self.page_size - 1) // self.page_size
        self.kv.alloc_pages(req.seq_id, n_pages)
        # prefill with a contiguous cache, then scatter into pages
        cache = T.init_cache(self.cfg, 1,
                             n_pages * self.page_size, dtype=self.kv.dtype)
        toks = jnp.asarray(req.prompt[None, :])
        logits, cache = T.forward_serve(
            self.params, self.cfg, {"tokens": toks}, cache,
            jnp.zeros((1,), jnp.int32), decode=False)
        self.kv.write_prefill(
            {"k": cache["k"][:, :1], "v": cache["v"][:, :1]},
            [req.seq_id], [s])
        req.out.append(int(jnp.argmax(logits[0])))
        self.active.append(req)

    # --------------------------------------------------------------- decode
    def step(self, *, rebalance: bool = False) -> None:
        live = [r for r in self.active if not r.done]
        if not live:
            return
        if rebalance:
            self.balancer.step()
            self.kv.client.drain(600)
            if self.refresh_mode == "range":
                self.kv.refresh_seqs([r.seq_id for r in live])
            else:
                self.kv.refresh_table()
        b = len(live)
        counts = [(len(r.prompt) + r.max_new + self.page_size - 1)
                  // self.page_size for r in live]
        page_table = self.kv.page_table([r.seq_id for r in live], counts)
        seq_lens = jnp.asarray(
            [len(r.prompt) + len(r.out) - 1 for r in live], jnp.int32)
        tokens = jnp.asarray([[r.out[-1]] for r in live], jnp.int32)

        # flatten layer-stacked pages for the jitted step
        logits, kp, vp = self._decode(
            self.params, tokens, self.kv.k_pages, self.kv.v_pages,
            page_table, seq_lens)
        self.kv.k_pages, self.kv.v_pages = kp, vp
        nxt = np.asarray(jnp.argmax(logits, -1))
        for i, r in enumerate(live):
            r.out.append(int(nxt[i]))
            if len(r.out) >= r.max_new:
                r.done = True
                self.kv.free_seq(r.seq_id,
                                 (len(r.prompt) + r.max_new +
                                  self.page_size - 1) // self.page_size)
        self.active = [r for r in self.active if not r.done]
