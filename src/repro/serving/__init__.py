from .paged import PagedKVManager, paged_decode_step  # noqa: F401
from .engine import ServingEngine  # noqa: F401
