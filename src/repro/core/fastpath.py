"""Batched FIND fast-path: answer a round's pure reads in one vectorized
pass (DESIGN.md §4).

The serial round answers every op through a per-row ``lax.while_loop``
pointer chase, so read-heavy rounds pay O(sum of path lengths) *sequential*
steps. This module is the §4 hybrid search applied to the round itself:

  1. one vectorized registry binary search over all op keys
     (``registry.get_by_key`` — the same logarithmic index the Pallas
     kernel ``kernels/hybrid_search.py`` runs in VMEM),
  2. one bounded lock-step gather-walk over ``pool.key``/``pool.nxt``
     (``traverse.probe_batch`` — the kernel's bounded block sweep against
     the linked pool),

so the round's reads cost O(fast_scan_bound) vector steps total instead of
O(ops x path) serial ones. The load balancer's split threshold bounds the
sweep exactly as it bounds the kernel's block occupancy, which is what
makes the Pallas kernel a drop-in for stage 2 on TPU.

Correctness (the commute argument, DESIGN.md §4): within a round only
MSG_OP handlers run between rows, and an insert/remove changes the
membership of *its own key only* — so a FIND with no same-key mutation in
the round reads the same answer at round start as at its serial position.
Everything that could break that reasoning is bounced to the serial path
*by construction*:

  * rounds carrying any replicate/move/switch message (membership of a key
    can change physically without a same-round client op) — all finds bounce;
  * finds whose key collides with a same-round insert/remove;
  * finds for remote clients (the serial path would emit a MSG_RESULT whose
    outbox position must be preserved for per-channel FIFO determinism);
  * finds that delegate, route nowhere, or whose walk touches a marked,
    moving (newLoc != null) or switched (stCt < 0) node, crosses to another
    shard, or exceeds ``cfg.fast_scan_bound``.

A bounced find goes through the exact serial ``ops.apply_op`` — semantics
are unchanged by construction, which ``tests/test_fastpath.py`` checks
differentially (fastpath on vs. off, op-for-op).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import messages as M
from .ops import resolve_route
from .traverse import probe_batch
from .types import (DiLiConfig, OP_FIND, OP_INSERT, OP_REMOVE, RES_FALSE,
                    RES_TRUE, ShardState)

# message kinds that cannot invalidate a round-start read: padding, result
# routing (no state writes) and client ops (same-key collisions are checked
# per find).
_BENIGN_KINDS = (M.MSG_NONE, M.MSG_RESULT, M.MSG_OP)


class FastOut(NamedTuple):
    elig: jnp.ndarray   # bool[R] — row answered here; serial scan skips it
    res: jnp.ndarray    # int32[R] RES_TRUE/RES_FALSE (valid where elig)


def find_fastpath(state: ShardState, rows, me, cfg: DiLiConfig) -> FastOut:
    """Classify + answer the round's eligible FIND rows. ``rows`` is the
    round's full [R, FIELDS] inbox+client block; never mutates state."""
    me = jnp.asarray(me, jnp.int32)
    kind = rows[:, M.F_KIND]
    op = rows[:, M.F_A]
    key = rows[:, M.F_KEY]

    is_op = kind == M.MSG_OP
    benign = jnp.zeros(kind.shape, bool)
    for k in _BENIGN_KINDS:
        benign = benign | (kind == k)
    round_ok = jnp.all(benign)

    is_find = is_op & (op == OP_FIND)
    is_mut = is_op & ((op == OP_INSERT) | (op == OP_REMOVE))
    local_client = rows[:, M.F_SID] == me

    # the pre-pass sweeps every lane whether one find is eligible or all
    # are, so it only pays off with enough candidates; below the cut (and
    # on drain / write-only / bg-message rounds) skip it wholesale.
    precand = round_ok & is_find & local_client
    gate = jnp.sum(precand) >= max(1, cfg.fast_min_batch)
    bound = min(cfg.fast_scan_bound, cfg.max_scan)
    n = key.shape[0]

    def run(_):
        # a find commutes with every other row of the round unless a
        # mutation targets the same key (conservatively: at any row
        # position). Sort-based membership test — O(R log R), not R^2;
        # padding lanes hold INT32_MAX, which no valid key equals (a
        # false positive there only bounces, never corrupts).
        mut_keys = jnp.where(is_mut, key, jnp.iinfo(jnp.int32).max)
        smut = jnp.sort(mut_keys)
        pos = jnp.clip(jnp.searchsorted(smut, key), 0, n - 1)
        collides = smut[pos] == key

        rt = resolve_route(state, key, M.i2ref(rows[:, M.F_REF1]), me)
        routed = (~rt.no_route) & (rt.owner == me) & (~rt.head_moved)
        cand = precand & (~collides) & routed

        # compact candidates into k lanes before sweeping: inboxes are
        # sized for worst-case all-to-all fan-in (R can be 64x the client
        # batch) and the sweep costs per *lane*, not per candidate. k
        # covers a full client batch plus slack; overflow lanes just
        # bounce to the serial path (cand & ok stays False for them).
        k = min(n, max(2 * cfg.batch_size, 64))
        sel = jnp.argsort((~cand).astype(jnp.int32) * n
                          + jnp.arange(n, dtype=jnp.int32))[:k]
        ok_k, present_k = probe_batch(state, rt.head_idx[sel], key[sel],
                                      me, bound)
        z = jnp.zeros((n,), bool)
        ok = z.at[sel].set(ok_k)
        present = z.at[sel].set(present_k)
        return cand & ok, present

    def skip(_):
        z = jnp.zeros((n,), bool)
        return z, z

    elig, present = jax.lax.cond(gate, run, skip, None)
    res = jnp.where(present, RES_TRUE, RES_FALSE).astype(jnp.int32)
    return FastOut(elig=elig, res=res)
