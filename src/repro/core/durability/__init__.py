"""Durable recovery: per-shard WAL + snapshots + crash-restart replay.

This package makes the (seed, config) -> byte-identical-run contract
survive kill -9 (DESIGN.md §14). Rounds are the unit of both
linearization and durability: every round each live shard journals the
*inputs* that round consumed (backlog appends, client feed) plus the
post-routing image of its transport-lane halves, fsyncs, and only then
lets the next round's acks make the round's effects observable to peers.
A crash therefore always lands on a round boundary, and recovery is
snapshot + deterministic re-execution of `shard_round` over the logged
feeds — the same pure function the live run used, so the rebuilt state
is bit-identical (audited against the journaled completions).

  * ``wal``      — append-only framed record log (crc32, torn-tail safe)
  * ``snapshot`` — periodic full-state snapshots via CheckpointManager,
                   with incremental WAL truncation up to the snapshot
  * ``recovery`` — replay a shard's WAL suffix through ``shard_round``
  * ``engine``   — the per-backend orchestration facade (``Durability``)
"""
from .engine import Durability, DurabilityConfig            # noqa: F401
from .recovery import RecoveredShard, RecoveryError, recover_shard  # noqa: F401
from .snapshot import ShardSnapshots                        # noqa: F401
from .wal import KIND_ROUND, KIND_SUBMIT, WriteAheadLog     # noqa: F401
