"""Per-shard write-ahead log: append-only, framed, torn-tail safe.

One WAL file per shard holds a sequence of records, each a dict of
``str -> np.ndarray`` serialized as an in-memory ``.npz`` blob and framed

    MAGIC(4) | length u32 | crc32 u32 | payload

Appends are flush+fsync'd before returning — the fsync-before-ack
discipline (DESIGN.md §14): a round's record must be durable before the
*next* round's cumulative acks let peers forget the frames that fed it.
The reader validates magic + crc per frame and truncates at the first
torn/corrupt frame, so a crash mid-append costs exactly the record being
written (whose round, by the same discipline, nobody observed yet).

``truncate_upto`` drops the prefix a snapshot made redundant, rewriting
through a tmp file + ``os.replace`` — the same atomic-rename discipline
as ``checkpoint/ckpt.py`` (a crash mid-truncate leaves the old log).

Two record kinds, distinguished by the ``kind`` scalar:

  * ``KIND_ROUND``  — one executed round: the client feed it consumed,
    the rows appended to the host backlog by routing, the completions it
    produced (replay audit), post-round bg phases + epoch (audit), and
    the shard's transport-lane halves (``lane/...`` keys).
  * ``KIND_SUBMIT`` — client rows journaled at ``submit()`` time, before
    the round that will consume them (requests are durable on
    acceptance; a crash cannot lose an op whose id was handed out).
  * ``KIND_COMMAND`` — a balancer command (split/move/merge) queued
    host-side into the shard's BgTable between rounds. These bypass the
    inbox, so without a record of their own replay would never re-queue
    them and the bg phases would diverge from the journaled run. The
    record's round is the round the command will first be visible to
    (``round_no`` between steps is the next round), so stream order
    reproduces exactly when the live run queued it.
"""
from __future__ import annotations

import io
import os
import struct
import zlib
from typing import Dict, Iterator, List

import numpy as np

MAGIC = b"DWAL"
_HEADER = struct.Struct("<4sII")     # magic, payload length, crc32

KIND_ROUND = 0
KIND_SUBMIT = 1
KIND_COMMAND = 2

# KIND_COMMAND verbs (the ``cmd`` scalar)
CMD_SPLIT = 0
CMD_MOVE = 1
CMD_MERGE = 2
CMD_REPLICATE = 3       # host replicate(entry_keymax, target) — §15;
                        # replays against ShardState.rep, not the BgTable
CMD_DROP_REPLICA = 4    # host drop_replica(entry_keymax, target)


def _encode(record: Dict[str, np.ndarray]) -> bytes:
    buf = io.BytesIO()
    np.savez(buf, **record)
    payload = buf.getvalue()
    return _HEADER.pack(MAGIC, len(payload), zlib.crc32(payload)) + payload


def _decode(payload: bytes) -> Dict[str, np.ndarray]:
    data = np.load(io.BytesIO(payload))
    return {k: data[k] for k in data.files}


class WriteAheadLog:
    """Append-only record log for one shard (see module docstring)."""

    def __init__(self, path: str):
        self.path = path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._fh = open(path, "ab")
        self.fsyncs = 0

    # ---------------------------------------------------------------- write
    def append(self, record: Dict[str, np.ndarray],
               sync: bool = True) -> None:
        """Append a record; with ``sync`` (the default) it is flushed and
        fsync'd before returning. ``sync=False`` leaves the record in the
        OS buffer for a later ``sync()`` — the group-commit path
        (``DurabilityConfig.group_commit_rounds``): durability of the
        batched records is deferred to the batch boundary, where the
        fsync-before-ack discipline is re-established."""
        self._fh.write(_encode(record))
        if sync:
            self.sync()

    def sync(self) -> None:
        """Flush + fsync everything appended so far (a group-commit
        barrier)."""
        self._fh.flush()
        os.fsync(self._fh.fileno())
        self.fsyncs += 1

    # ----------------------------------------------------------------- read
    def records(self) -> Iterator[Dict[str, np.ndarray]]:
        """All intact records, oldest first; stops at the first torn or
        corrupt frame (the tail a mid-append crash may leave)."""
        self._fh.flush()
        if not os.path.exists(self.path):
            return
        with open(self.path, "rb") as fh:
            while True:
                head = fh.read(_HEADER.size)
                if len(head) < _HEADER.size:
                    return
                magic, length, crc = _HEADER.unpack(head)
                if magic != MAGIC:
                    return
                payload = fh.read(length)
                if len(payload) < length or zlib.crc32(payload) != crc:
                    return
                yield _decode(payload)

    # ------------------------------------------------------------- truncate
    def truncate_upto(self, round_no: int) -> int:
        """Drop every record with ``round <= round_no`` (covered by a
        snapshot). Atomic: rewrite to tmp, fsync, rename. Returns the
        number of records kept."""
        keep: List[bytes] = []
        for rec in self.records():
            if int(rec["round"]) > round_no:
                keep.append(_encode(rec))
        self._fh.close()
        tmp = self.path + ".tmp"
        with open(tmp, "wb") as fh:
            for blob in keep:
                fh.write(blob)
            fh.flush()
            os.fsync(fh.fileno())
        os.replace(tmp, self.path)
        self._fh = open(self.path, "ab")
        return len(keep)

    def close(self) -> None:
        self._fh.close()
