"""Periodic full-state shard snapshots through ``CheckpointManager``.

A snapshot is the durable base recovery replays from: the shard's full
``ShardState`` (pool arrays, registry replica, epoch/peers row), its
``BgTable``, the host backlog at the end of the snapshot round, and the
shard-owned halves of its transport lanes (sender rings + receiver
cursors, the ``Transport.export_shard_lanes`` image). Written through
``CheckpointManager`` so it inherits the atomic tmp+rename discipline
and step retention; ``async_write=False`` because the WAL may only be
truncated once the snapshot is durably on disk (a snapshot-then-truncate
window where neither survives a crash would lose the shard).

Steps are ``round + 1`` so the genesis snapshot (pre-round-0 state,
written at attach time) lands on step 0.
"""
from __future__ import annotations

import os
from typing import Dict, Optional

import numpy as np

from ...checkpoint.ckpt import CheckpointManager, restore_pytree
from .. import bg as B
from ..types import DiLiConfig, ShardState, init_shard

_LANES = "lanes/"


class ShardSnapshots:
    """Snapshot store for one shard slot."""

    def __init__(self, directory: str, shard: int, *, keep: int = 2):
        self.shard = int(shard)
        self.mgr = CheckpointManager(
            os.path.join(directory, f"shard_{self.shard:02d}"),
            keep=keep, async_write=False)

    def latest_round(self) -> Optional[int]:
        step = self.mgr.latest_step()
        return None if step is None else step - 1

    def save(self, round_no: int, state: ShardState, bg: B.BgTable,
             backlog: np.ndarray,
             lanes: Dict[str, np.ndarray]) -> None:
        tree = {
            "round": np.int64(round_no),
            "state": state,
            "bg": bg,
            "backlog": np.asarray(backlog, np.int32),
            "lanes": dict(lanes),
        }
        self.mgr.save(round_no + 1, tree)

    def load_latest(self, cfg: DiLiConfig) -> Optional[dict]:
        """Latest snapshot as ``{round, state, bg, backlog, lanes}``, or
        None when no snapshot exists (a slot that never attached)."""
        step = self.mgr.latest_step()
        if step is None:
            return None
        path = self.mgr._path(step)
        # state/bg restore through the shape-checked template path; the
        # variable-length members (backlog, lane image) read directly.
        template = {"state": init_shard(cfg, self.shard, peers_mask=0),
                    "bg": B.init_bg_table(cfg)}
        tree = restore_pytree(template, path)
        data = np.load(path)
        lanes = {k[len(_LANES):]: data[k]
                 for k in data.files if k.startswith(_LANES)}
        return dict(round=int(data["round"]),
                    state=tree["state"], bg=tree["bg"],
                    backlog=np.asarray(data["backlog"], np.int32),
                    lanes=lanes)
