"""``Durability``: the per-backend orchestration facade.

Both execution backends (``Cluster`` and ``ShardMapBackend``'s hostroute
path) drive durability through this one object so the journaling
discipline cannot drift between them:

  * ``ensure_genesis`` — written at attach time so recovery always has a
    durable base (the pre-round-0 state, snapshot step 0);
  * ``log_submit``    — client rows journaled before their op ids leak;
  * ``log_round``     — one record per live shard per round, fsync'd
    before the engine moves on (fsync-before-ack);
  * ``maybe_snapshot``/``snapshot_now`` — cadence snapshots + the
    post-recovery snapshot, each followed by incremental WAL truncation;
  * ``recover``       — snapshot + replay, returning what to reinstall.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

from ..types import DiLiConfig
from .recovery import RecoveredShard, recover_shard
from .snapshot import ShardSnapshots
from .wal import KIND_COMMAND, KIND_ROUND, KIND_SUBMIT, WriteAheadLog

_LANE = "lane/"


def validate_crash_plans(crashes, num_shards: int) -> None:
    """Shared CrashPlan sanity: shard in range, per-shard windows
    disjoint (a shard must restart before it can crash again). Both
    backends call this at construction so a bad schedule fails fast."""
    windows: Dict[int, list] = {}
    for c in crashes:
        if not 0 <= c.shard < num_shards:
            raise ValueError(
                f"CrashPlan shard {c.shard} out of range 0..{num_shards - 1}")
        windows.setdefault(c.shard, []).append(
            (c.crash_round, c.restart_round))
    for s, spans in windows.items():
        spans.sort()
        for (_, e0), (b1, _) in zip(spans, spans[1:]):
            if b1 <= e0:
                raise ValueError(
                    f"CrashPlans for shard {s} overlap: a shard must "
                    f"restart before it can crash again")


@dataclass(frozen=True)
class DurabilityConfig:
    """Knobs for the durability subsystem (host-side; not jit-static)."""
    snapshot_every: int = 64     # cadence in rounds; <=0 disables cadence
    keep: int = 2                # snapshot retention per shard
    group_commit_rounds: int = 1  # fsync KIND_ROUND records every N
                                 # rounds instead of per record: write
                                 # amplification drops ~N:1 while the
                                 # fsync-before-ack discipline holds at
                                 # every batch boundary. 1 = the legacy
                                 # sync-per-round behavior. Submits and
                                 # commands always sync (durable on
                                 # acceptance).


class Durability:
    """Per-shard WALs + snapshot stores rooted at one directory."""

    def __init__(self, directory: str, cfg: DiLiConfig,
                 config: Optional[DurabilityConfig] = None):
        self.dir = directory
        self.cfg = cfg
        self.config = config or DurabilityConfig()
        os.makedirs(directory, exist_ok=True)
        self._wals: Dict[int, WriteAheadLog] = {}
        self._snaps: Dict[int, ShardSnapshots] = {}
        self.stats = {"records": 0, "submits": 0, "commands": 0,
                      "snapshots": 0, "recoveries": 0,
                      "replayed_rounds": 0}

    def wal(self, s: int) -> WriteAheadLog:
        if s not in self._wals:
            self._wals[s] = WriteAheadLog(
                os.path.join(self.dir, f"shard_{s:02d}.wal"))
        return self._wals[s]

    def snaps(self, s: int) -> ShardSnapshots:
        if s not in self._snaps:
            self._snaps[s] = ShardSnapshots(self.dir, s,
                                            keep=self.config.keep)
        return self._snaps[s]

    # ------------------------------------------------------------- journal
    def ensure_genesis(self, s: int, state, bg, backlog,
                       lanes: Dict[str, np.ndarray]) -> None:
        if self.snaps(s).latest_round() is None:
            self.snaps(s).save(-1, state, bg, backlog, lanes)
            self.stats["snapshots"] += 1

    def log_submit(self, s: int, round_no: int, rows: np.ndarray) -> None:
        self.wal(s).append({
            "round": np.int64(round_no), "kind": np.int64(KIND_SUBMIT),
            "appends": np.asarray(rows, np.int32)})
        self.stats["submits"] += 1

    def log_command(self, s: int, round_no: int, cmd: int,
                    args, ok: bool) -> None:
        """A balancer split/move/merge queued host-side into shard
        ``s``'s BgTable — journaled because it bypasses the inbox (see
        wal.py). ``ok`` (whether a slot accepted it) is audited on
        replay."""
        self.wal(s).append({
            "round": np.int64(round_no), "kind": np.int64(KIND_COMMAND),
            "cmd": np.int64(cmd),
            "args": np.asarray(list(args), np.int64),
            "ok": np.int64(bool(ok))})
        self.stats["commands"] += 1

    def log_round(self, s: int, round_no: int, *, appends, client, comp,
                  bg_phases, epoch: int,
                  lanes: Dict[str, np.ndarray]) -> None:
        rec = {
            "round": np.int64(round_no), "kind": np.int64(KIND_ROUND),
            "appends": np.asarray(appends, np.int32),
            "client": np.asarray(client, np.int32),
            "comp": np.asarray(comp, np.int32).reshape(-1, 4),
            "bg_phases": np.asarray(bg_phases),
            "epoch": np.int64(epoch),
        }
        for k, v in lanes.items():
            rec[_LANE + k] = v
        every = max(1, int(self.config.group_commit_rounds))
        self.wal(s).append(rec, sync=(round_no + 1) % every == 0)
        self.stats["records"] += 1

    # ----------------------------------------------------------- snapshots
    def maybe_snapshot(self, s: int, round_no: int, state, bg, backlog,
                       lanes: Dict[str, np.ndarray]) -> bool:
        every = self.config.snapshot_every
        if every <= 0 or (round_no + 1) % every != 0:
            return False
        self.snapshot_now(s, round_no, state, bg, backlog, lanes)
        return True

    def snapshot_now(self, s: int, round_no: int, state, bg, backlog,
                     lanes: Dict[str, np.ndarray]) -> None:
        """Durable snapshot at ``round_no``, then drop the WAL prefix it
        covers. Ordering matters: truncate only after the snapshot's
        atomic rename — a crash between the two replays the (still
        intact) longer suffix onto the older snapshot instead."""
        self.snaps(s).save(round_no, state, bg, backlog, lanes)
        self.wal(s).truncate_upto(round_no)
        self.stats["snapshots"] += 1

    def fsync_count(self) -> int:
        """Total fsyncs issued across every shard's WAL — the write-
        amplification observable the group-commit test pins down."""
        return sum(w.fsyncs for w in self._wals.values())

    # ------------------------------------------------------------- recover
    def recover(self, s: int, *, in_cap: int) -> RecoveredShard:
        rec = recover_shard(self.cfg, s, self.wal(s), self.snaps(s),
                            in_cap=in_cap)
        self.stats["recoveries"] += 1
        self.stats["replayed_rounds"] += rec.replayed_rounds
        return rec
