"""Crash recovery: snapshot + WAL replay through ``shard_round``.

``shard_round`` is a pure function of ``(state, bg, inbox, client,
cfg)`` — so the WAL does not need to journal round *effects* at all; it
journals the round's *inputs* (the backlog rows appended by routing, the
client feed consumed) and replay is literal re-execution. The rebuilt
state, BgTable and backlog are bit-identical to what the dead process
held at its last durable round, which is what lets the restarted shard
re-enter the deterministic run without perturbing the replay witness.

Replayed outboxes are discarded: the journaled lane image already holds
every frame the shard had sent and not yet seen acked (the retransmit
ring), and everything acked was, by the cumulative-ack contract,
delivered at the peer. Re-shipping from the restored ring plus the
peers' receiver-side dedup is exactly the at-least-once -> exactly-once
collapse the transport already implements — replay composes with the
lanes instead of needing its own delivery reconciliation.

Every replayed round's completions (and post-round bg phases / epoch)
are audited against the journaled ones; a mismatch means the replay
diverged from the live run — nondeterminism or a torn log — and raises
``RecoveryError`` rather than resurrecting a shard with silently
different history.
"""
from __future__ import annotations

from typing import Dict, NamedTuple

import jax.numpy as jnp
import numpy as np

from .. import bg as B
from .. import messages as M
from .. import replica as R
from ..shard import shard_round
from ..types import DiLiConfig
from .snapshot import ShardSnapshots
from .wal import (CMD_DROP_REPLICA, CMD_MERGE, CMD_MOVE, CMD_REPLICATE,
                  CMD_SPLIT, KIND_COMMAND, KIND_SUBMIT, WriteAheadLog)

_LANE = "lane/"


class RecoveryError(RuntimeError):
    """WAL replay diverged from the journaled run (or no durable base)."""


class RecoveredShard(NamedTuple):
    state: object            # ShardState at the last durable round
    bg: object               # BgTable at the last durable round
    backlog: np.ndarray      # host backlog (delivered-but-unconsumed rows)
    lanes: Dict[str, np.ndarray]   # transport lane image to reinstall
    last_round: int          # the last durable round replay reached
    replayed_rounds: int     # WAL rounds re-executed on top of snapshot


def completions_array(out) -> np.ndarray:
    """The (op_id, result, src, key) rows one RoundOut completed, in row
    order — the same harvest the live engines journal, so replay can
    compare bit-for-bit. ``key`` is SH_KEY for scalar completions and
    the scanned key for RANGE item rows (DESIGN.md §16)."""
    cs = np.asarray(out.comp_slot)
    cv = np.asarray(out.comp_val)
    cr = np.asarray(out.comp_src)
    ck = np.asarray(out.comp_key)
    done = cs >= 0
    return np.stack([cs[done], cv[done], cr[done], ck[done]],
                    axis=1).astype(np.int32)


def lane_image_of(record: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
    return {k[len(_LANE):]: v for k, v in record.items()
            if k.startswith(_LANE)}


def recover_shard(cfg: DiLiConfig, shard: int, wal: WriteAheadLog,
                  snaps: ShardSnapshots, *, in_cap: int) -> RecoveredShard:
    """Rebuild ``shard`` from its latest snapshot + WAL suffix."""
    base = snaps.load_latest(cfg)
    if base is None:
        raise RecoveryError(
            f"shard {shard}: no snapshot on disk — the genesis snapshot "
            f"is written at attach time, so this slot never attached")
    state, bg = base["state"], base["bg"]
    backlog = base["backlog"]
    lanes = base["lanes"]
    last_round = base["round"]
    replayed = 0
    for rec in wal.records():
        rnd = int(rec["round"])
        if rnd <= base["round"]:
            continue           # pre-snapshot leftovers (truncation is lazy)
        if int(rec["kind"]) == KIND_SUBMIT:
            rows = np.asarray(rec["appends"], np.int32)
            if rows.size:
                backlog = np.concatenate([backlog, rows], axis=0)
            continue
        if int(rec["kind"]) == KIND_COMMAND:
            # re-queue the host-side balancer command exactly where the
            # live run did (stream order = queue order)
            args = [int(a) for a in np.asarray(rec["args"]).ravel()]
            cmd = int(rec["cmd"])
            if cmd in (CMD_REPLICATE, CMD_DROP_REPLICA):
                # replication commands edit ShardState.rep, not the
                # BgTable — same journal, different substrate (§15)
                fn = (R.queue_replicate_jit if cmd == CMD_REPLICATE
                      else R.queue_drop_replica_jit)
                state, ok = fn(state, cfg, *args)
            else:
                queue = {CMD_SPLIT: B.queue_split, CMD_MOVE: B.queue_move,
                         CMD_MERGE: B.queue_merge}[cmd]
                bg, ok = queue(bg, *args)
            if bool(np.asarray(ok)) != bool(int(rec["ok"])):
                raise RecoveryError(
                    f"shard {shard} round {rnd}: replayed command "
                    f"cmd={int(rec['cmd'])} args={args} accepted="
                    f"{bool(np.asarray(ok))} != journaled "
                    f"{bool(int(rec['ok']))}")
            continue
        # mirror the live feed discipline exactly: bounded FIFO pop,
        # zero-padded inbox, the journaled client feed, then the round's
        # routed appends land behind whatever was left over.
        feed = backlog[:in_cap]
        backlog = backlog[in_cap:]
        inbox = np.zeros((in_cap, M.FIELDS), np.int32)
        inbox[:feed.shape[0]] = feed
        client = np.asarray(rec["client"], np.int32)
        out = shard_round(state, bg, shard, jnp.asarray(inbox),
                          jnp.asarray(client), cfg)
        state, bg = out.state, out.bg
        comp = completions_array(out)
        want = np.asarray(rec["comp"], np.int32).reshape(-1, 4)
        if not np.array_equal(comp, want):
            raise RecoveryError(
                f"shard {shard} round {rnd}: replayed completions "
                f"{comp.tolist()} != journaled {want.tolist()} — replay "
                f"diverged from the live run")
        phases = np.asarray(B.slot_phases(bg))
        if not np.array_equal(phases, np.asarray(rec["bg_phases"])):
            raise RecoveryError(
                f"shard {shard} round {rnd}: replayed bg phases "
                f"{phases.tolist()} != journaled "
                f"{np.asarray(rec['bg_phases']).tolist()}")
        if int(np.asarray(state.epoch)) != int(rec["epoch"]):
            raise RecoveryError(
                f"shard {shard} round {rnd}: replayed epoch "
                f"{int(np.asarray(state.epoch))} != journaled "
                f"{int(rec['epoch'])}")
        appends = np.asarray(rec["appends"], np.int32)
        if appends.size:
            backlog = np.concatenate([backlog, appends], axis=0)
        lanes = lane_image_of(rec)
        last_round = rnd
        replayed += 1
    return RecoveredShard(state, bg, backlog, lanes, last_round, replayed)
