"""Background operations: Split (§5.3), Move + Replay (§5.4), Switch (Alg. 5).

Each shard runs at most one background operation at a time (the paper assigns
one background thread per machine); the operation advances one *phase* per
round, never blocking client operations — they observe either the pre- or
post-state of each phase plus delegation, exactly the paper's asynchrony.

Phase graph::

   IDLE -> SPLIT_EXEC -> SPLIT_WAIT -> IDLE
   IDLE -> MOVE_SH -> MOVE_SH_WAIT -> MOVE_COPY -> MOVE_STABLE
        -> SWITCH_ST [-> SWITCH_ST_WAIT] -> SWITCH_REG -> QUAR -> IDLE
   IDLE -> MERGE_EXEC -> MERGE_WAIT -> IDLE          (Appendix B)

Replay (Lines 249-262) is implemented faithfully: items are identified by
their <sId, ts> tuple; an insert replays before the first node whose ts is
smaller than the inserted item's comparison timestamp (Lemmas 8/9).
One adaptation (DESIGN.md §8): the receiving shard Lamport-bumps its logical
clock on every replayed/moved item (clock = max(clock, item_ts + 1)) so that
timestamps stay comparable across repeated moves of the same sublist —
x86 DiLi gets this for free only until a sublist changes clock domain twice.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import messages as M
from . import refs, registry as reg_ops
from .types import (DiLiConfig, NEG_INF_CT, SH_KEY, ST_KEY, ShardState)

# ------------------------------------------------------------------ phases
BG_IDLE = 0
BG_SPLIT_EXEC = 1
BG_SPLIT_WAIT = 2
BG_MOVE_SH = 3
BG_MOVE_SH_WAIT = 4
BG_MOVE_COPY = 5
BG_MOVE_STABLE = 6
BG_SWITCH_ST = 7
BG_SWITCH_ST_WAIT = 8
BG_SWITCH_REG = 9
BG_QUAR = 10
BG_MERGE_EXEC = 11
BG_MERGE_WAIT = 12

# MOVE_ITEM / MOVE_ACK flag bits (message field F_A)
FL_MARKED = 1
FL_ST = 2


class BgState(NamedTuple):
    phase: jnp.ndarray       # int32
    entry_key: jnp.ndarray   # int32 — keymax identifying the sublist entry
    target: jnp.ndarray      # int32 — destination shard of a Move
    sitem: jnp.ndarray       # int32 — split item pool idx
    cursor: jnp.ndarray      # int32 — last copied (acked) source pool idx
    sent: jnp.ndarray        # int32 — MoveItems sent in the current batch
    acked: jnp.ndarray       # int32
    st_sent: jnp.ndarray     # int32 bool — the SubTail has been sent
    st_acked: jnp.ndarray    # int32 bool
    sh_star: jnp.ndarray     # uint32 — target SubHead ref
    st_star: jnp.ndarray     # uint32 — target SubTail ref
    old_head: jnp.ndarray    # int32 — source SubHead pool idx
    quar_round: jnp.ndarray  # int32
    round: jnp.ndarray       # int32 — round counter
    new_slot: jnp.ndarray    # int32 — split: right-half counter slot
    old_slot: jnp.ndarray    # int32 — split: left-half counter slot
    split_key: jnp.ndarray   # int32
    sh_new: jnp.ndarray      # int32 — split: new SubHead pool idx
    st_new: jnp.ndarray      # int32 — split: new SubTail pool idx
    old_keymax: jnp.ndarray  # int32 — split: pre-split keymax (right keymax)
    merge_key: jnp.ndarray   # int32 — merge: right entry keymax


def init_bg() -> BgState:
    z = jnp.zeros((), jnp.int32)
    return BgState(phase=z, entry_key=z, target=z, sitem=z, cursor=z,
                   sent=z, acked=z, st_sent=z, st_acked=z,
                   sh_star=refs.null_ref(), st_star=refs.null_ref(),
                   old_head=z, quar_round=z, round=z, new_slot=z,
                   old_slot=z, split_key=z, sh_new=z, st_new=z,
                   old_keymax=z, merge_key=z)


# ===================================================================== util

def _cover(reg, key):
    return reg_ops.get_by_key(reg, key)


def _entry_by_keymax(reg, keymax):
    """Entry whose keymax equals ``keymax`` (the bg op's stable handle)."""
    e = _cover(reg, keymax)
    ok = (e >= 0) & (reg.keymax[jnp.clip(e, 0, None)] == keymax)
    return jnp.where(ok, e, -1)


def _alloc_node(state: ShardState):
    has_free = state.free_top > 0
    free_idx = state.free_list[jnp.clip(state.free_top - 1, 0, None)]
    bump_ok = state.alloc_top < state.pool.key.shape[0]
    idx = jnp.where(has_free, free_idx, state.alloc_top)
    ok = has_free | bump_ok
    state = state._replace(
        free_top=state.free_top - has_free.astype(jnp.int32),
        alloc_top=state.alloc_top + ((~has_free) & bump_ok).astype(jnp.int32))
    return state, jnp.where(ok, idx, 0), ok


def _set(col, idx, val, do):
    return jnp.where(do, col.at[idx].set(val), col)


def _lamport(state: ShardState, ts):
    return state._replace(ts_clock=jnp.maximum(state.ts_clock, ts + 1))


def _find_by_identity(state: ShardState, start_idx, sid, ts, bound):
    """Walk the chain from ``start_idx`` for the node with <sId, ts>.

    Returns (idx, found). Stops at SubTail / null / ``bound`` steps.
    Used by Replay (Lines 227-230) and RepDelete (Lines 232-234).
    """
    pool = state.pool
    n = pool.key.shape[0]

    def cond(c):
        idx, steps, done = c
        return (~done) & (steps < bound)

    def body(c):
        idx, steps, _ = c
        hit = (pool.sid[idx] == sid) & (pool.ts[idx] == ts)
        at_end = (pool.key[idx] == ST_KEY) | \
                 refs.is_null(pool.nxt[idx]) & ~hit
        nxt_idx = jnp.clip(refs.ref_idx(refs.unmarked(pool.nxt[idx])), 0, n - 1)
        idx2 = jnp.where(hit | at_end, idx, nxt_idx)
        return idx2, steps + 1, hit | at_end

    idx0 = jnp.clip(start_idx, 0, n - 1)
    hit0 = (pool.sid[idx0] == sid) & (pool.ts[idx0] == ts)
    idx, _, done = jax.lax.while_loop(
        cond, body, (idx0, jnp.zeros((), jnp.int32), hit0))
    found = (pool.sid[idx] == sid) & (pool.ts[idx] == ts)
    return idx, found


def _replay_insert(state: ShardState, me, prev_idx, comp_ts, key, item_sid,
                   item_ts, is_marked, cfg: DiLiConfig, value=0):
    """Replay algorithm Lines 249-262: insert after ``prev``, before the
    first node whose ts < comp_ts. Returns (state, new_idx, ok)."""
    pool = state.pool
    n = pool.key.shape[0]

    def cond(c):
        curr_prev, curr, steps = c
        go = (pool.ts[curr] >= comp_ts) & (pool.key[curr] != ST_KEY)
        return go & (steps < cfg.max_scan)

    def body(c):
        curr_prev, curr, steps = c
        nxt = jnp.clip(refs.ref_idx(refs.unmarked(pool.nxt[curr])), 0, n - 1)
        return curr, nxt, steps + 1

    first = jnp.clip(refs.ref_idx(refs.unmarked(pool.nxt[prev_idx])), 0, n - 1)
    curr_prev, curr, _ = jax.lax.while_loop(
        cond, body, (prev_idx, first, jnp.zeros((), jnp.int32)))

    state, new_idx, ok = _alloc_node(state)
    pool = state.pool
    prev_nxt = pool.nxt[curr_prev]
    prev_mark = prev_nxt & jnp.uint32(refs.MARK_BIT)
    item_next = refs.with_mark(refs.make_ref(me, curr), is_marked)

    pool = pool._replace(
        key=_set(pool.key, new_idx, key, ok),
        ts=_set(pool.ts, new_idx, item_ts, ok),
        sid=_set(pool.sid, new_idx, item_sid, ok),
        ctr=_set(pool.ctr, new_idx, pool.ctr[curr_prev], ok),
        newloc=_set(pool.newloc, new_idx, refs.null_ref(), ok),
        keymax=_set(pool.keymax, new_idx, value, ok),
    )
    pool = pool._replace(nxt=_set(pool.nxt, new_idx, item_next, ok))
    # Line 260: preserve currPrev's own deletion mark when relinking.
    pool = pool._replace(nxt=_set(
        pool.nxt, curr_prev, refs.make_ref(me, new_idx) | prev_mark, ok))
    state = state._replace(pool=pool)
    state = _lamport(state, item_ts)
    return state, new_idx, ok


# ============================================================== msg handlers
# All handlers: (state, bg, me, row, outbox, count, cfg) ->
#               (state, bg, outbox, count)

def h_rep_insert(state, bg, me, row, outbox, count, cfg):
    """RepInsertAfterRecv (Lines 226-231)."""
    anchor = refs.ref_idx(M.i2ref(row[M.F_REF1]))
    prev_sid, prev_ts = row[M.F_X2], row[M.F_X3]
    item_sid, item_ts = row[M.F_SID], row[M.F_TS]
    key, oldloc, slot = row[M.F_KEY], row[M.F_X1], row[M.F_X4]

    prev_idx, found = _find_by_identity(state, anchor, prev_sid, prev_ts,
                                        cfg.max_scan)
    st2, new_idx, ok = _replay_insert(
        state, me, prev_idx, item_ts, key, item_sid, item_ts,
        jnp.asarray(False), cfg, value=row[M.F_VAL])
    apply_it = found & ok
    state = jax.tree_util.tree_map(
        lambda a, b: jnp.where(apply_it, b, a), state, st2)

    ack = M.make_row(M.MSG_ACK_INSERT, row[M.F_SRC], me,
                     ref1=M.ref2i(refs.make_ref(me, new_idx)),
                     sid=item_sid, ts=item_ts, x1=oldloc, x4=slot)
    outbox, count = M.push(outbox, count, ack, apply_it)
    # prev's copy not here yet (out-of-order delivery): retry next round.
    retry_row = row.at[M.F_A].set(row[M.F_A] + 1)
    retry_row = retry_row.at[M.F_DST].set(me)
    outbox, count = M.push(outbox, count, retry_row,
                           (~apply_it) & (row[M.F_A] < cfg.max_retries))
    return state, bg, outbox, count


def h_rep_delete(state, bg, me, row, outbox, count, cfg):
    """RepDeleteRecv (Lines 232-239)."""
    anchor = refs.ref_idx(M.i2ref(row[M.F_REF1]))
    item_sid, item_ts = row[M.F_SID], row[M.F_TS]
    oldloc, slot = row[M.F_X1], row[M.F_X4]
    need_ack = row[M.F_X2] != 0

    idx, found = _find_by_identity(state, anchor, item_sid, item_ts,
                                   cfg.max_scan)
    state = state._replace(pool=state.pool._replace(
        nxt=_set(state.pool.nxt, idx, refs.with_mark(state.pool.nxt[idx]),
                 found)))
    ack = M.make_row(M.MSG_ACK_DELETE, row[M.F_SRC], me, x1=oldloc, x4=slot)
    outbox, count = M.push(outbox, count, ack, found & need_ack)
    retry_row = row.at[M.F_A].set(row[M.F_A] + 1)
    retry_row = retry_row.at[M.F_DST].set(me)
    outbox, count = M.push(outbox, count, retry_row,
                           (~found) & (row[M.F_A] < cfg.max_retries))
    return state, bg, outbox, count


def h_ack_insert(state, bg, me, row, outbox, count, cfg):
    """InsertReplayResponseRecv (Lines 263-265).

    No marked-while-in-flight race catch is needed here (unlike
    h_move_ack's Line 210): an item awaiting this ack was born with its
    left's non-null newLoc (ops.py Line 189), so a remove racing the
    replay sees node_moving and sends its own RepDelete — whose pair-FIFO
    channel guarantees it arrives after the replay it chases.
    """
    oldloc, slot = row[M.F_X1], row[M.F_X4]
    sid, ts = row[M.F_SID], row[M.F_TS]
    same = (state.pool.sid[oldloc] == sid) & (state.pool.ts[oldloc] == ts)
    state = state._replace(pool=state.pool._replace(
        newloc=_set(state.pool.newloc, oldloc, M.i2ref(row[M.F_REF1]), same)))
    # the deferred endCt increment always lands (balances the op's stCt++)
    state = state._replace(endct=state.endct.at[slot].add(1))
    return state, bg, outbox, count


def h_ack_delete(state, bg, me, row, outbox, count, cfg):
    """RemoveReplayResponseRecv (Lines 266-267)."""
    state = state._replace(endct=state.endct.at[row[M.F_X4]].add(1))
    return state, bg, outbox, count


def h_move_sh(state, bg, me, row, outbox, count, cfg):
    """MoveSHRecv (Lines 215-225): create SH*/ST* + fresh counters."""
    keymin, keymax = row[M.F_KEY], row[M.F_X1]
    sh_sid, sh_ts = row[M.F_SID], row[M.F_TS]

    slot = state.ctr_top
    slot_ok = slot < state.stct.shape[0]
    state = state._replace(ctr_top=slot + slot_ok.astype(jnp.int32))
    state, st_idx, ok1 = _alloc_node(state)
    state, sh_idx, ok2 = _alloc_node(state)
    ok = slot_ok & ok1 & ok2

    pool = state.pool
    pool = pool._replace(
        key=_set(_set(pool.key, st_idx, ST_KEY, ok), sh_idx, SH_KEY, ok),
        keymax=_set(pool.keymax, st_idx, keymax, ok),
        ctr=_set(_set(pool.ctr, st_idx, slot, ok), sh_idx, slot, ok),
        # the SubHead keeps the original's <sId, ts> identity (Line 219)
        sid=_set(_set(pool.sid, sh_idx, sh_sid, ok), st_idx, me, ok),
        ts=_set(_set(pool.ts, sh_idx, sh_ts, ok), st_idx, state.ts_clock, ok),
        newloc=_set(_set(pool.newloc, sh_idx, refs.null_ref(), ok),
                    st_idx, refs.null_ref(), ok),
    )
    pool = pool._replace(
        nxt=_set(_set(pool.nxt, sh_idx, refs.make_ref(me, st_idx), ok),
                 st_idx, refs.null_ref(), ok))
    state = state._replace(pool=pool, ts_clock=state.ts_clock + 1)
    state = _lamport(state, sh_ts)

    ack = M.make_row(M.MSG_MOVE_SH_ACK, row[M.F_SRC], me,
                     ref1=M.ref2i(refs.make_ref(me, sh_idx)),
                     x3=M.ref2i(refs.make_ref(me, st_idx)),
                     key=keymin, x1=keymax, a=ok.astype(jnp.int32))
    outbox, count = M.push(outbox, count, ack)
    return state, bg, outbox, count


def h_move_sh_ack(state, bg, me, row, outbox, count, cfg):
    """Line 200: head.newLoc = remoteSH; start copying."""
    good = (bg.phase == BG_MOVE_SH_WAIT) & (row[M.F_A] != 0)
    sh_star = M.i2ref(row[M.F_REF1])
    state = state._replace(pool=state.pool._replace(
        newloc=_set(state.pool.newloc, bg.old_head, sh_star, good)))
    bg = bg._replace(
        phase=jnp.where(good, BG_MOVE_COPY, bg.phase),
        sh_star=jnp.where(good, sh_star, bg.sh_star),
        st_star=jnp.where(good, M.i2ref(row[M.F_X3]), bg.st_star),
        cursor=jnp.where(good, bg.old_head, bg.cursor),
        sent=jnp.where(good, 0, bg.sent),
        acked=jnp.where(good, 0, bg.acked),
        st_sent=jnp.where(good, 0, bg.st_sent),
        st_acked=jnp.where(good, 0, bg.st_acked))
    return state, bg, outbox, count


def h_move_item(state, bg, me, row, outbox, count, cfg):
    """MoveItemRecv (Lines 240-248): replay-insert the copied item."""
    flags = row[M.F_A]
    is_st = (flags & FL_ST) != 0
    is_marked = (flags & FL_MARKED) != 0
    anchor = refs.ref_idx(M.i2ref(row[M.F_REF1]))
    prev_sid, prev_ts = row[M.F_X2], row[M.F_X3]
    item_sid, item_ts = row[M.F_SID], row[M.F_TS]
    key, oldloc = row[M.F_KEY], row[M.F_X1]

    prev_idx, found = _find_by_identity(state, anchor, prev_sid, prev_ts,
                                        cfg.max_scan)

    # ---- ST: link the target SubTail into the global chain (Lines 241-247)
    pool = state.pool
    n = pool.key.shape[0]

    def walk_to_st(c):
        idx, steps = c
        nxt = jnp.clip(refs.ref_idx(refs.unmarked(pool.nxt[idx])), 0, n - 1)
        return jnp.where(pool.key[idx] == ST_KEY, idx, nxt), steps + 1

    def not_st(c):
        idx, steps = c
        return (pool.key[idx] != ST_KEY) & (steps < cfg.max_scan)

    st_idx, _ = jax.lax.while_loop(not_st, walk_to_st,
                                   (prev_idx, jnp.zeros((), jnp.int32)))
    do_st = found & is_st
    st_next = M.i2ref(row[M.F_X4])     # source ST's next: the global chain
    pool = pool._replace(
        nxt=_set(pool.nxt, st_idx, st_next, do_st),
        keymax=_set(pool.keymax, st_idx, key, do_st))
    state = state._replace(pool=pool)
    ack_ref = refs.make_ref(me, st_idx)

    # ---- ordinary item: replay insert with compTs = prev.ts (Line 248)
    st2, new_idx, ok = _replay_insert(
        state, me, prev_idx, prev_ts, key, item_sid, item_ts, is_marked, cfg,
        value=row[M.F_VAL])
    do_item = found & (~is_st) & ok
    state = jax.tree_util.tree_map(
        lambda a, b: jnp.where(do_item, b, a), state, st2)
    ack_ref = jnp.where(is_st, ack_ref, refs.make_ref(me, new_idx))

    done = do_st | do_item
    ack = M.make_row(M.MSG_MOVE_ACK, row[M.F_SRC], me,
                     ref1=M.ref2i(ack_ref), sid=item_sid, ts=item_ts,
                     x1=oldloc, a=flags)
    outbox, count = M.push(outbox, count, ack, done)
    # bounded retry: the retry count rides in the flag word's high bits
    retries = flags >> 8
    retry = row.at[M.F_A].set(flags + 256)
    retry = retry.at[M.F_DST].set(me)
    outbox, count = M.push(outbox, count, retry,
                           (~done) & (retries < cfg.max_retries))
    return state, bg, outbox, count


def h_move_ack(state, bg, me, row, outbox, count, cfg):
    """Source side of MoveItem (Lines 208-211): record newLoc, detect races."""
    oldloc = row[M.F_X1]
    sid, ts = row[M.F_SID], row[M.F_TS]
    flags = row[M.F_A]
    is_st = (flags & FL_ST) != 0
    sent_marked = (flags & FL_MARKED) != 0
    new_ref = M.i2ref(row[M.F_REF1])

    same = (state.pool.sid[oldloc] == sid) & (state.pool.ts[oldloc] == ts)
    state = state._replace(pool=state.pool._replace(
        newloc=_set(state.pool.newloc, oldloc, new_ref, same)))

    # Line 210: item got marked while the copy was in flight -> RepDelete
    now_marked = refs.ref_mark(state.pool.nxt[oldloc])
    race = same & now_marked & (~sent_marked) & (~is_st)
    rep = M.make_row(M.MSG_REP_DELETE, refs.ref_sid(new_ref), me,
                     ref1=M.ref2i(refs.unmarked(new_ref)),
                     sid=sid, ts=ts, x1=oldloc, x2=0, x4=0)
    # x2=0: no ack needed — the remove already balanced its endCt.
    outbox, count = M.push(outbox, count, rep, race)

    in_copy = bg.phase == BG_MOVE_COPY
    # NB: the cursor is advanced only by _move_copy's contiguous-prefix walk;
    # advancing it here (to the last ack) would skip inserts that landed
    # between in-flight batch items.
    bg = bg._replace(
        acked=jnp.where(in_copy, bg.acked + 1, bg.acked),
        st_acked=jnp.where(in_copy & is_st, 1, bg.st_acked))
    return state, bg, outbox, count


def h_switch_st(state, bg, me, row, outbox, count, cfg):
    """SwitchSTRecv (Lines 272-277 + 297-302)."""
    keymin = row[M.F_KEY]
    new_sh = M.i2ref(row[M.F_REF1])
    ok = _switch_next_st(state, me, keymin, new_sh)
    state, success = ok
    ack = M.make_row(M.MSG_SWITCH_ST_ACK, row[M.F_SRC], me,
                     a=success.astype(jnp.int32))
    outbox, count = M.push(outbox, count, ack)
    return state, bg, outbox, count


def _switch_next_st(state, me, keymin, new_sh):
    """switchNextST (Lines 297-302) on the local shard. Returns (state, ok)."""
    reg = state.registry
    left = reg_ops.get_by_key(reg, keymin)
    lidx = jnp.clip(left, 0, None)
    owner_ok = (left >= 0) & (refs.ref_sid(reg.subhead[lidx]) == me)
    st_idx = refs.ref_idx(reg.subtail[lidx])
    st_idx = jnp.clip(st_idx, 0, state.pool.key.shape[0] - 1)
    slot = state.pool.ctr[st_idx]
    state = state._replace(
        stct=jnp.where(owner_ok, state.stct.at[slot].add(1), state.stct))
    live = owner_ok & (state.stct[slot] >= 0)
    state = state._replace(pool=state.pool._replace(
        nxt=_set(state.pool.nxt, st_idx, new_sh, live)))
    state = state._replace(
        endct=jnp.where(live, state.endct.at[slot].add(1), state.endct))
    return state, live


def h_switch_st_ack(state, bg, me, row, outbox, count, cfg):
    good = (bg.phase == BG_SWITCH_ST_WAIT)
    ok = row[M.F_A] != 0
    bg = bg._replace(phase=jnp.where(
        good, jnp.where(ok, BG_SWITCH_REG, BG_SWITCH_ST), bg.phase))
    return state, bg, outbox, count


def h_reg_split(state, bg, me, row, outbox, count, cfg):
    """RegisterSublistRecv (Lines 159-163) at a replica."""
    split_key, keymax = row[M.F_KEY], row[M.F_X1]
    sh_ref = M.i2ref(row[M.F_REF1])
    reg = state.registry
    e = reg_ops.get_by_key(reg, keymax)
    eidx = jnp.clip(e, 0, None)
    # exact right-half already present (duplicate) — drop
    dup = (e >= 0) & (reg.keymin[eidx] == split_key) & \
        (reg.keymax[eidx] == keymax)
    # parent entry present: split it
    can = (e >= 0) & (~dup) & (reg.keymin[eidx] < split_key) & \
        (reg.keymax[eidx] == keymax) & (reg.size < reg.keymin.shape[0])
    new_reg = reg_ops.add_entry(
        reg_ops.set_fields(reg, eidx, keymax=split_key),
        split_key, keymax, sh_ref, refs.null_ref(), 0, 0)
    state = state._replace(registry=jax.tree_util.tree_map(
        lambda a, b: jnp.where(can, b, a), reg, new_reg))
    retry = row.at[M.F_A].set(row[M.F_A] + 1)
    retry = retry.at[M.F_DST].set(me)
    outbox, count = M.push(outbox, count, retry,
                           (~can) & (~dup) & (row[M.F_A] < cfg.max_retries))
    return state, bg, outbox, count


def h_switch_server(state, bg, me, row, outbox, count, cfg):
    """SwitchServerRecv (Lines 285-287): repoint a registry entry."""
    keymin, keymax = row[M.F_KEY], row[M.F_X1]
    sh_ref, st_ref = M.i2ref(row[M.F_REF1]), M.i2ref(row[M.F_X3])
    reg = state.registry
    e = reg_ops.get_by_key(reg, keymax)
    eidx = jnp.clip(e, 0, None)
    exact = (e >= 0) & (reg.keymin[eidx] == keymin) & \
        (reg.keymax[eidx] == keymax)
    i_am_new_owner = refs.ref_sid(sh_ref) == me
    sh_idx = jnp.clip(refs.ref_idx(sh_ref), 0, state.pool.key.shape[0] - 1)
    new_ctr = jnp.where(i_am_new_owner, state.pool.ctr[sh_idx], 0)
    new_reg = reg_ops.set_fields(reg, eidx, subhead=sh_ref, subtail=st_ref,
                                 ctr=new_ctr, offset=0)
    state = state._replace(registry=jax.tree_util.tree_map(
        lambda a, b: jnp.where(exact, b, a), reg, new_reg))
    retry = row.at[M.F_A].set(row[M.F_A] + 1)
    retry = retry.at[M.F_DST].set(me)
    outbox, count = M.push(outbox, count, retry,
                           (~exact) & (row[M.F_A] < cfg.max_retries))
    return state, bg, outbox, count


def h_reg_merged(state, bg, me, row, outbox, count, cfg):
    """RegisterMergedSublistRecv (Lines 360-365) at a replica."""
    key_mid = row[M.F_KEY]
    reg = state.registry
    right = _entry_by_keymax(reg, row[M.F_X1])
    ridx = jnp.clip(right, 0, None)
    ok = (right >= 0) & (reg.keymin[ridx] == key_mid)
    left = _cover(reg, key_mid)
    lidx = jnp.clip(left, 0, None)
    ok = ok & (left >= 0) & (reg.keymax[lidx] == key_mid)
    new_reg = reg_ops.remove_entry(
        reg_ops.set_fields(reg, lidx, keymax=reg.keymax[ridx]), ridx)
    state = state._replace(registry=jax.tree_util.tree_map(
        lambda a, b: jnp.where(ok, b, a), reg, new_reg))
    # already merged here (idempotent) — drop; otherwise out-of-order with a
    # pending REG_SPLIT: retry next round
    merged = (right < 0) & (_cover(reg, key_mid) >= 0)
    retry = row.at[M.F_A].set(row[M.F_A] + 1)
    retry = retry.at[M.F_DST].set(me)
    outbox, count = M.push(outbox, count, retry,
                           (~ok) & (~merged) & (row[M.F_A] < cfg.max_retries))
    return state, bg, outbox, count


# ================================================================== bg step

def _split_exec(state, bg, me, outbox, count, cfg):
    """Split steps 1-3 (§5.3): insert the ST-SH block, repoint counters."""
    reg = state.registry
    e = _entry_by_keymax(reg, bg.entry_key)
    eidx = jnp.clip(e, 0, None)
    sitem = jnp.clip(bg.sitem, 0, state.pool.key.shape[0] - 1)
    sitem_key = state.pool.key[sitem]
    valid = (e >= 0) & (refs.ref_sid(reg.subhead[eidx]) == me) & \
        (~refs.ref_mark(state.pool.nxt[sitem])) & \
        (state.pool.ctr[sitem] == reg.ctr[eidx]) & \
        (sitem_key > reg.keymin[eidx]) & (sitem_key < reg.keymax[eidx]) & \
        (state.pool.key[sitem] != SH_KEY) & (state.pool.key[sitem] != ST_KEY)

    new_slot = state.ctr_top
    slot_ok = new_slot < state.stct.shape[0]
    old_slot = reg.ctr[eidx]

    state2 = state._replace(ctr_top=new_slot + 1)
    state2, st_idx, ok1 = _alloc_node(state2)
    state2, sh_idx, ok2 = _alloc_node(state2)
    ok = valid & slot_ok & ok1 & ok2

    pool = state2.pool
    old_next = pool.nxt[sitem]          # unmarked by ``valid``
    ts1 = state2.ts_clock
    pool = pool._replace(
        key=_set(_set(pool.key, st_idx, ST_KEY, ok), sh_idx, SH_KEY, ok),
        keymax=_set(pool.keymax, st_idx, sitem_key, ok),
        ctr=_set(_set(pool.ctr, st_idx, old_slot, ok), sh_idx, new_slot, ok),
        sid=_set(_set(pool.sid, st_idx, me, ok), sh_idx, me, ok),
        ts=_set(_set(pool.ts, st_idx, ts1, ok), sh_idx, ts1 + 1, ok),
        newloc=_set(_set(pool.newloc, st_idx, refs.null_ref(), ok),
                    sh_idx, refs.null_ref(), ok),
    )
    # ST -> SH -> old next; then CAS sItem.next := ST (Lines 131-139)
    pool = pool._replace(nxt=_set(pool.nxt, sh_idx, old_next, ok))
    pool = pool._replace(
        nxt=_set(pool.nxt, st_idx, refs.make_ref(me, sh_idx), ok))
    pool = pool._replace(
        nxt=_set(pool.nxt, sitem, refs.make_ref(me, st_idx), ok))
    state2 = state2._replace(pool=pool, ts_clock=ts1 + 2)

    # repoint counter pointers of the right half (Lines 140-146),
    # old-subtail included
    n = pool.key.shape[0]

    def cond2(c):
        ctr_col, idx, steps, done = c
        return (~done) & (steps < cfg.max_scan)

    def body2(c):
        ctr_col, idx, steps, _ = c
        ctr_col = ctr_col.at[idx].set(new_slot)
        at_st = pool.key[idx] == ST_KEY
        nxt = jnp.clip(refs.ref_idx(refs.unmarked(pool.nxt[idx])), 0, n - 1)
        return ctr_col, jnp.where(at_st, idx, nxt), steps + 1, at_st

    start = jnp.clip(refs.ref_idx(refs.unmarked(old_next)), 0, n - 1)
    ctr_col, _, _, _ = jax.lax.while_loop(
        cond2, body2,
        (state2.pool.ctr, start, jnp.zeros((), jnp.int32),
         jnp.asarray(False)))
    state2 = state2._replace(pool=state2.pool._replace(
        ctr=jnp.where(ok, ctr_col, state2.pool.ctr)))

    state = jax.tree_util.tree_map(
        lambda a, b: jnp.where(ok, b, a), state, state2)
    bg = bg._replace(
        phase=jnp.where(ok, BG_SPLIT_WAIT, BG_IDLE),
        new_slot=jnp.where(ok, new_slot, bg.new_slot),
        old_slot=jnp.where(ok, old_slot, bg.old_slot),
        split_key=jnp.where(ok, sitem_key, bg.split_key),
        sh_new=jnp.where(ok, sh_idx, bg.sh_new),
        st_new=jnp.where(ok, st_idx, bg.st_new),
        old_keymax=jnp.where(ok, reg.keymax[eidx], bg.old_keymax))
    return state, bg, outbox, count


def _split_wait(state, bg, me, outbox, count, cfg):
    """Split step 4 (Lines 147-157): offset stabilization + registry COW."""
    reg = state.registry
    e = _entry_by_keymax(reg, bg.entry_key)
    eidx = jnp.clip(e, 0, None)
    a1 = state.stct[bg.new_slot] - state.endct[bg.new_slot]
    a2 = state.stct[bg.old_slot] - state.endct[bg.old_slot]
    stable = (e >= 0) & (a1 + a2 == reg.offset[eidx]) & \
        (reg.size < reg.keymin.shape[0])

    old_subtail = reg.subtail[eidx]
    sh_ref = refs.make_ref(me, bg.sh_new)
    st_ref = refs.make_ref(me, bg.st_new)
    new_reg = reg_ops.add_entry(
        reg_ops.set_fields(reg, eidx, keymax=bg.split_key, subtail=st_ref,
                           offset=a2),
        bg.split_key, bg.old_keymax, sh_ref, old_subtail, bg.new_slot, a1)
    state = state._replace(registry=jax.tree_util.tree_map(
        lambda a, b: jnp.where(stable, b, a), reg, new_reg))

    row = M.make_row(M.MSG_REG_SPLIT, 0, me, key=bg.split_key,
                     x1=bg.old_keymax, ref1=M.ref2i(sh_ref))
    def send(i, oc):
        ob, ct = oc
        r = row.at[M.F_DST].set(i)
        return M.push(ob, ct, r, stable & (i != me))

    outbox, count = jax.lax.fori_loop(0, cfg.num_shards, send,
                                      (outbox, count))
    bg = bg._replace(phase=jnp.where(stable, BG_IDLE, bg.phase))
    return state, bg, outbox, count


def _move_sh(state, bg, me, outbox, count, cfg):
    reg = state.registry
    e = _entry_by_keymax(reg, bg.entry_key)
    eidx = jnp.clip(e, 0, None)
    ok = (e >= 0) & (refs.ref_sid(reg.subhead[eidx]) == me) & \
        (bg.target != me)
    head_idx = refs.ref_idx(reg.subhead[eidx])
    row = M.make_row(M.MSG_MOVE_SH, bg.target, me,
                     key=reg.keymin[eidx], x1=reg.keymax[eidx],
                     sid=state.pool.sid[head_idx],
                     ts=state.pool.ts[head_idx])
    outbox, count = M.push(outbox, count, row, ok)
    bg = bg._replace(
        phase=jnp.where(ok, BG_MOVE_SH_WAIT, BG_IDLE),
        old_head=jnp.where(ok, head_idx, bg.old_head))
    return state, bg, outbox, count


def _move_copy(state, bg, me, outbox, count, cfg):
    """Send the next batch of MoveItems once the previous batch is acked.

    Concurrency contract (mirrors the paper's synchronous per-item RPC,
    Lines 206-214, batched): inserts racing an in-flight MoveItem land with
    newLoc == null (their left's newLoc is not set until the ack), so the
    cursor advances only over the *contiguous prefix* of copied items and
    every batch re-walks from there — stragglers are picked up by the next
    walk. The SubTail is copied only when nothing before it remains, after
    which every concurrent update replicates (left.newLoc is set) and no
    item can be missed.
    """
    ready = (bg.sent == bg.acked) & (bg.st_sent == 0)
    pool = state.pool
    n = pool.key.shape[0]

    # advance cursor over items that already have a newLoc (copied/replicated)
    def adv_cond(c):
        cur, steps = c
        nxt = jnp.clip(refs.ref_idx(refs.unmarked(pool.nxt[cur])), 0, n - 1)
        ok = (~refs.is_null(pool.newloc[nxt])) & (pool.key[nxt] != ST_KEY)
        return ready & ok & (steps < cfg.max_scan)

    def adv_body(c):
        cur, steps = c
        nxt = jnp.clip(refs.ref_idx(refs.unmarked(pool.nxt[cur])), 0, n - 1)
        return nxt, steps + 1

    cursor, _ = jax.lax.while_loop(adv_cond, adv_body,
                                   (bg.cursor, jnp.zeros((), jnp.int32)))
    anchor = refs.unmarked(pool.newloc[cursor])

    def body(k, c):
        outbox, count, prev, sent, st_sent, stop = c
        curr_ref = pool.nxt[prev]
        curr = jnp.clip(refs.ref_idx(refs.unmarked(curr_ref)), 0, n - 1)
        # Line 207: skip items already moved / being replicated
        has_newloc = ~refs.is_null(pool.newloc[curr])
        is_st = pool.key[curr] == ST_KEY
        can = ready & (~stop)
        # ST only when every prior item is copied (nothing sent this walk,
        # nothing in flight) — then no un-replicated straggler can exist.
        send_st = can & is_st & (sent == 0)
        send = can & (~has_newloc) & ((~is_st) | send_st)
        flags = (refs.ref_mark(pool.nxt[curr]).astype(jnp.int32) * FL_MARKED
                 + is_st.astype(jnp.int32) * FL_ST)
        key_field = jnp.where(is_st, pool.keymax[curr], pool.key[curr])
        row = M.make_row(
            M.MSG_MOVE_ITEM, bg.target, me, a=flags, key=key_field,
            ref1=M.ref2i(anchor), sid=pool.sid[curr], ts=pool.ts[curr],
            x1=curr, x2=pool.sid[prev], x3=pool.ts[prev],
            x4=M.ref2i(refs.unmarked(pool.nxt[curr])),
            val=pool.keymax[curr])
        outbox, count = M.push(outbox, count, row, send)
        sent = sent + send.astype(jnp.int32)
        st_sent = st_sent | (send & is_st).astype(jnp.int32)
        stop = stop | is_st
        prev = jnp.where(can, curr, prev)
        return outbox, count, prev, sent, st_sent, stop

    outbox, count, _, nsent, st_sent, _ = jax.lax.fori_loop(
        0, cfg.move_batch, body,
        (outbox, count, cursor, jnp.zeros((), jnp.int32),
         jnp.zeros((), jnp.int32), jnp.asarray(False)))
    bg = bg._replace(
        cursor=jnp.where(ready, cursor, bg.cursor),
        sent=jnp.where(ready, bg.sent + nsent, bg.sent),
        st_sent=jnp.where(ready, st_sent, bg.st_sent),
        phase=jnp.where((bg.st_acked != 0) & (bg.sent == bg.acked),
                        BG_MOVE_STABLE, bg.phase))
    return state, bg, outbox, count


def _move_stable(state, bg, me, outbox, count, cfg):
    """Line 202-204: CAS stCt := -inf once both copies are provably equal."""
    reg = state.registry
    e = _entry_by_keymax(reg, bg.entry_key)
    eidx = jnp.clip(e, 0, None)
    slot = reg.ctr[eidx]
    quiet = (e >= 0) & \
        (state.stct[slot] == state.endct[slot] + reg.offset[eidx])
    state = state._replace(
        stct=jnp.where(quiet, state.stct.at[slot].set(NEG_INF_CT),
                       state.stct))
    bg = bg._replace(phase=jnp.where(quiet, BG_SWITCH_ST, bg.phase))
    return state, bg, outbox, count


def _switch_st_phase(state, bg, me, outbox, count, cfg):
    """Alg. 5 Lines 269-280: repoint the previous sublist's SubTail."""
    reg = state.registry
    e = _entry_by_keymax(reg, bg.entry_key)
    eidx = jnp.clip(e, 0, None)
    keymin = reg.keymin[eidx]
    no_left = keymin <= SH_KEY
    left = _cover(reg, keymin)
    lidx = jnp.clip(left, 0, None)
    left_owner = refs.ref_sid(reg.subhead[lidx])
    local = (~no_left) & (left >= 0) & (left_owner == me)
    remote = (~no_left) & (left >= 0) & (left_owner != me)

    st2, ok = _switch_next_st(state, me, keymin, bg.sh_star)
    state = jax.tree_util.tree_map(
        lambda a, b: jnp.where(local, b, a), state, st2)

    row = M.make_row(M.MSG_SWITCH_ST, left_owner, me, key=keymin,
                     ref1=M.ref2i(bg.sh_star))
    outbox, count = M.push(outbox, count, row, remote)

    next_phase = jnp.where(
        no_left | (local & ok), BG_SWITCH_REG,
        jnp.where(remote, BG_SWITCH_ST_WAIT, bg.phase))
    bg = bg._replace(phase=next_phase)
    return state, bg, outbox, count


def _switch_reg(state, bg, me, outbox, count, cfg):
    """Alg. 5 Lines 281-284: update own registry, broadcast SwitchServer."""
    reg = state.registry
    e = _entry_by_keymax(reg, bg.entry_key)
    eidx = jnp.clip(e, 0, None)
    keymin = reg.keymin[eidx]
    new_reg = reg_ops.set_fields(reg, eidx, subhead=bg.sh_star,
                                 subtail=bg.st_star, ctr=0, offset=0)
    state = state._replace(registry=jax.tree_util.tree_map(
        lambda a, b: jnp.where(e >= 0, b, a), reg, new_reg))

    row = M.make_row(M.MSG_SWITCH_SERVER, 0, me, key=keymin,
                     x1=bg.entry_key, ref1=M.ref2i(bg.sh_star),
                     x3=M.ref2i(bg.st_star))

    def send(i, oc):
        ob, ct = oc
        return M.push(ob, ct, row.at[M.F_DST].set(i), (e >= 0) & (i != me))

    outbox, count = jax.lax.fori_loop(0, cfg.num_shards, send,
                                      (outbox, count))
    bg = bg._replace(phase=BG_QUAR, quar_round=bg.round)
    return state, bg, outbox, count


def _quarantine(state, bg, me, outbox, count, cfg):
    """Free the stale source chain (interior only — the old SubHead keeps
    forwarding via newLoc; the epoch-based analogue of hazard pointers)."""
    due = bg.round - bg.quar_round >= cfg.quarantine_rounds
    pool = state.pool
    n = pool.key.shape[0]

    def cond(c):
        flist, ftop, idx, steps, done = c
        return due & (~done) & (steps < cfg.max_scan)

    def body(c):
        flist, ftop, idx, steps, _ = c
        at_st = pool.key[idx] == ST_KEY
        pos = jnp.clip(ftop, 0, flist.shape[0] - 1)
        flist = flist.at[pos].set(idx)
        ftop = ftop + 1
        nxt = jnp.clip(refs.ref_idx(refs.unmarked(pool.nxt[idx])), 0, n - 1)
        return flist, ftop, nxt, steps + 1, at_st

    start = jnp.clip(refs.ref_idx(refs.unmarked(pool.nxt[bg.old_head])),
                     0, n - 1)
    flist, ftop, _, _, _ = jax.lax.while_loop(
        cond, body,
        (state.free_list, state.free_top, start,
         jnp.zeros((), jnp.int32), jnp.asarray(False)))
    state = state._replace(
        free_list=jnp.where(due, flist, state.free_list),
        free_top=jnp.where(due, ftop, state.free_top))
    bg = bg._replace(phase=jnp.where(due, BG_IDLE, bg.phase))
    return state, bg, outbox, count


def _merge_exec(state, bg, me, outbox, count, cfg):
    """Merge (Appendix B, Alg. 7): fold the right sublist into the left."""
    reg = state.registry
    le = _entry_by_keymax(reg, bg.entry_key)      # left entry
    re_ = _entry_by_keymax(reg, bg.merge_key)     # right entry
    lidx, ridx = jnp.clip(le, 0, None), jnp.clip(re_, 0, None)
    pool = state.pool
    n = pool.key.shape[0]
    lslot, rslot = reg.ctr[lidx], reg.ctr[ridx]
    valid = (le >= 0) & (re_ >= 0) & \
        (reg.keymax[lidx] == reg.keymin[ridx]) & \
        (refs.ref_sid(reg.subhead[lidx]) == me) & \
        (refs.ref_sid(reg.subhead[ridx]) == me) & \
        (state.stct[lslot] >= 0) & (state.stct[rslot] >= 0)

    key_mid = reg.keymax[lidx]
    mid_st = refs.ref_idx(reg.subtail[lidx])      # the block to neutralize
    right_sh = refs.ref_idx(reg.subhead[ridx])
    right_st_ref = reg.subtail[ridx]
    old_off_sum = reg.offset[lidx] + reg.offset[ridx]

    # Line 335: neutralize the mid SubTail so traversals cross it
    pool = pool._replace(
        keymax=_set(pool.keymax, mid_st, reg.keymin[lidx], valid))

    # Lines 341-344: repoint the right half's counter slots to the left's
    def cond(c):
        ctr_col, idx, steps, done = c
        return (~done) & (steps < cfg.max_scan)

    def body(c):
        ctr_col, idx, steps, _ = c
        ctr_col = ctr_col.at[idx].set(lslot)
        at_st = pool.key[idx] == ST_KEY
        nxt = jnp.clip(refs.ref_idx(refs.unmarked(pool.nxt[idx])), 0, n - 1)
        return ctr_col, jnp.where(at_st, idx, nxt), steps + 1, at_st

    ctr_col, _, _, _ = jax.lax.while_loop(
        cond, body, (pool.ctr, jnp.clip(right_sh, 0, n - 1),
                     jnp.zeros((), jnp.int32), jnp.asarray(False)))
    pool = pool._replace(ctr=jnp.where(valid, ctr_col, pool.ctr))

    # Lines 346-352 (RDCSS): link leftLast directly to rightFirst. The mid
    # ST-SH block stays quarantined as a forwarder for stale delegations
    # (its nxt chain still reaches the merged items).
    def find_last(c):
        idx, steps = c
        nxt_ref = refs.unmarked(pool.nxt[idx])
        nxt = jnp.clip(refs.ref_idx(nxt_ref), 0, n - 1)
        at_last = nxt == mid_st
        return jnp.where(at_last, idx, nxt), steps + 1

    def not_last(c):
        idx, steps = c
        nxt = refs.ref_idx(refs.unmarked(pool.nxt[idx]))
        return (nxt != mid_st) & (steps < cfg.max_scan)

    left_sh = jnp.clip(refs.ref_idx(reg.subhead[lidx]), 0, n - 1)
    left_last, _ = jax.lax.while_loop(
        not_last, find_last, (left_sh, jnp.zeros((), jnp.int32)))
    right_first = refs.unmarked(pool.nxt[jnp.clip(right_sh, 0, n - 1)])
    ll_mark = pool.nxt[left_last] & jnp.uint32(refs.MARK_BIT)
    pool = pool._replace(
        nxt=_set(pool.nxt, left_last, right_first | ll_mark, valid))
    state = state._replace(pool=pool)

    # Lines 336-338: extend the left entry, drop the right entry (local COW)
    new_reg = reg_ops.remove_entry(
        reg_ops.set_fields(reg, lidx, keymax=reg.keymax[ridx],
                           subtail=right_st_ref),
        ridx)
    state = state._replace(registry=jax.tree_util.tree_map(
        lambda a, b: jnp.where(valid, b, a), reg, new_reg))

    bg = bg._replace(
        phase=jnp.where(valid, BG_MERGE_WAIT, BG_IDLE),
        entry_key=jnp.where(valid, bg.merge_key, bg.entry_key),
        split_key=jnp.where(valid, key_mid, bg.split_key),
        old_slot=jnp.where(valid, lslot, bg.old_slot),
        new_slot=jnp.where(valid, rslot, bg.new_slot),
        old_keymax=jnp.where(valid, old_off_sum, bg.old_keymax))
    return state, bg, outbox, count


def _merge_wait(state, bg, me, outbox, count, cfg):
    """Alg. 7 Lines 353-358: offset stabilization + broadcast."""
    a1 = state.stct[bg.old_slot] - state.endct[bg.old_slot]
    a2 = state.stct[bg.new_slot] - state.endct[bg.new_slot]
    stable = (a1 + a2) == bg.old_keymax
    reg = state.registry
    e = _entry_by_keymax(reg, bg.entry_key)
    eidx = jnp.clip(e, 0, None)
    new_reg = reg_ops.set_fields(reg, eidx, offset=a1)
    state = state._replace(registry=jax.tree_util.tree_map(
        lambda a, b: jnp.where(stable & (e >= 0), b, a), reg, new_reg))

    row = M.make_row(M.MSG_REG_MERGED, 0, me, key=bg.split_key,
                     x1=bg.entry_key)

    def send(i, oc):
        ob, ct = oc
        return M.push(ob, ct, row.at[M.F_DST].set(i), stable & (i != me))

    outbox, count = jax.lax.fori_loop(0, cfg.num_shards, send,
                                      (outbox, count))
    bg = bg._replace(phase=jnp.where(stable, BG_IDLE, bg.phase))
    return state, bg, outbox, count


_PHASES = {
    BG_SPLIT_EXEC: _split_exec,
    BG_SPLIT_WAIT: _split_wait,
    BG_MOVE_SH: _move_sh,
    BG_MOVE_COPY: _move_copy,
    BG_MOVE_STABLE: _move_stable,
    BG_SWITCH_ST: _switch_st_phase,
    BG_SWITCH_REG: _switch_reg,
    BG_QUAR: _quarantine,
    BG_MERGE_EXEC: _merge_exec,
    BG_MERGE_WAIT: _merge_wait,
}


def bg_step(state: ShardState, bg: BgState, me, outbox, count,
            cfg: DiLiConfig):
    """Advance the background op by one phase this round."""
    def mk(fn):
        def br(args):
            st, b, ob, ct = args
            return fn(st, b, me, ob, ct, cfg)
        return br

    def noop(args):
        return args

    branches = []
    for ph in range(13):
        branches.append(mk(_PHASES[ph]) if ph in _PHASES else noop)
    state, bg, outbox, count = jax.lax.switch(
        jnp.clip(bg.phase, 0, 12), branches, (state, bg, outbox, count))
    bg = bg._replace(round=bg.round + 1)
    return state, bg, outbox, count


# ============================================================ host commands

def queue_split(bg: BgState, entry_key, sitem_idx) -> BgState:
    """Host command: split ``entry`` (identified by keymax) at pool idx."""
    idle = bg.phase == BG_IDLE
    return bg._replace(
        phase=jnp.where(idle, BG_SPLIT_EXEC, bg.phase),
        entry_key=jnp.where(idle, jnp.asarray(entry_key, jnp.int32),
                            bg.entry_key),
        sitem=jnp.where(idle, jnp.asarray(sitem_idx, jnp.int32), bg.sitem))


def queue_move(bg: BgState, entry_key, target) -> BgState:
    """Host command: move ``entry`` (identified by keymax) to ``target``."""
    idle = bg.phase == BG_IDLE
    return bg._replace(
        phase=jnp.where(idle, BG_MOVE_SH, bg.phase),
        entry_key=jnp.where(idle, jnp.asarray(entry_key, jnp.int32),
                            bg.entry_key),
        target=jnp.where(idle, jnp.asarray(target, jnp.int32), bg.target))


def queue_merge(bg: BgState, left_keymax, right_keymax) -> BgState:
    """Host command: merge two adjacent sublists owned by this shard."""
    idle = bg.phase == BG_IDLE
    return bg._replace(
        phase=jnp.where(idle, BG_MERGE_EXEC, bg.phase),
        entry_key=jnp.where(idle, jnp.asarray(left_keymax, jnp.int32),
                            bg.entry_key),
        merge_key=jnp.where(idle, jnp.asarray(right_keymax, jnp.int32),
                            bg.merge_key))
