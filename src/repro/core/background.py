"""Backwards-compatibility shim: the background engine lives in
``repro.core.bg`` (fsm / util / handlers / phases / replay / engine).

Everything importable from here before the decomposition still is —
``from repro.core import background as B`` keeps working for tests,
benchmarks and downstream tools. New code should import ``repro.core.bg``
directly.
"""
from .bg import (  # noqa: F401
    BG_IDLE, BG_MERGE_EXEC, BG_MERGE_WAIT, BG_MOVE_COPY, BG_MOVE_SH,
    BG_MOVE_SH_WAIT, BG_MOVE_STABLE, BG_NUM_PHASES, BG_QUAR, BG_SPLIT_EXEC,
    BG_SPLIT_WAIT, BG_SWITCH_REG, BG_SWITCH_ST, BG_SWITCH_ST_WAIT,
    FL_MARKED, FL_ST, BgState, BgTable, ReplayOut, active_moves, any_active,
    bg_step, claimed_keys, free_slots, h_ack_delete, h_ack_insert, h_move_ack,
    h_move_item, h_move_sh, h_move_sh_ack, h_reg_merged, h_reg_split,
    h_rep_delete, h_rep_insert, h_switch_server, h_switch_st,
    h_switch_st_ack, init_bg, init_bg_table, queue_merge, queue_move,
    queue_split, replay_prepass, set_slot, slot_phases, slot_view)
from .bg.util import (  # noqa: F401
    find_by_identity as _find_by_identity,
    replay_insert as _replay_insert)
