"""Packed smart references — the paper's §4 'smart pointer', adapted to TPU.

The paper packs {16-bit server id, 47-bit address, 1-bit deletion mark} into a
single 64-bit word so one CAS atomically updates ownership, target and mark.
JAX arrays are index-addressed, and the native TPU vector lane is 32 bits, so we
pack into a ``uint32``::

    bit 31      : mark (Harris deletion mark — lives on the *next* pointer)
    bits 30..22 : shard id (9 bits, up to 512 shards = the 2-pod production mesh)
    bits 21..0  : node index into the owner shard's node pool (4M nodes/shard)

A single-word conditional store on this lane is the TPU-idiomatic equivalent of
the paper's single-word CAS (see DESIGN.md §2).
"""
from __future__ import annotations

import jax.numpy as jnp

REF_DTYPE = jnp.uint32

IDX_BITS = 22
SID_BITS = 9
IDX_MASK = (1 << IDX_BITS) - 1            # 0x003FFFFF
SID_MASK = ((1 << SID_BITS) - 1) << IDX_BITS
MARK_BIT = 1 << 31

# NULL is all-ones in the index field with shard 0 / no mark. Any real node
# index must be < IDX_MASK.
NULL_IDX = IDX_MASK
NULL_REF = NULL_IDX  # python int; use null_ref() for a traced constant

MAX_SHARDS = 1 << SID_BITS
POOL_LIMIT = IDX_MASK  # exclusive upper bound on per-shard pool capacity


def null_ref():
    return jnp.uint32(NULL_REF)


def make_ref(sid, idx, mark=False):
    """Pack (shard id, index, mark) into a uint32 Ref."""
    r = ((jnp.asarray(sid).astype(jnp.uint32) << IDX_BITS)
         | jnp.asarray(idx).astype(jnp.uint32))
    if isinstance(mark, bool):
        return r | jnp.uint32(MARK_BIT) if mark else r
    return jnp.where(mark, r | jnp.uint32(MARK_BIT), r)


def ref_idx(ref):
    """Index field (the masked pointer access '→' of the paper)."""
    return (ref & jnp.uint32(IDX_MASK)).astype(jnp.int32)


def ref_sid(ref):
    """Owner shard id — the paper's ``X.id``."""
    return ((ref & jnp.uint32(SID_MASK)) >> IDX_BITS).astype(jnp.int32)


def ref_mark(ref):
    """Deletion mark — the paper's ``X.mark``."""
    return (ref & jnp.uint32(MARK_BIT)) != 0


def with_mark(ref, mark=True):
    if isinstance(mark, bool):
        return ref | jnp.uint32(MARK_BIT) if mark else ref & jnp.uint32(~MARK_BIT & 0xFFFFFFFF)
    return jnp.where(mark, ref | jnp.uint32(MARK_BIT),
                     ref & jnp.uint32(~MARK_BIT & 0xFFFFFFFF))


def unmarked(ref):
    """Ref with the mark bit cleared (address+owner only)."""
    return ref & jnp.uint32(~MARK_BIT & 0xFFFFFFFF)


def is_null(ref):
    return unmarked(ref) == jnp.uint32(NULL_REF)
