"""SPMD backend: the DiLi round under ``shard_map`` on a real device mesh.

Each device of the (flattened) mesh is one DiLi shard ("server"). A round is:

  1. ``shard_round`` locally (same jitted body as the simulator — identical
     semantics by construction; ``cfg.find_fastpath`` therefore applies here
     too: eligible reads are answered by the vectorized pre-pass on-device,
     never entering the collective fabric),
  2. bucket the outbox by destination shard,
  3. one ``all_to_all`` — the paper's RPC fabric. ≤2 collective hops per
     client op (≤3 during a Switch) is exactly Theorem 4's delegation bound.

This is the module the multi-pod dry-run lowers for the ``dili-service``
architecture: the production mesh's devices become 256/512 DiLi servers.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from . import bg as B
from . import messages as M
from .shard import shard_round
from .types import DiLiConfig, ShardState

AXIS = "shard"


def bucket_by_dst(outbox, count, num_shards: int, cap_pair: int):
    """Scatter outbox rows into per-destination buckets [S, cap_pair, F].

    Overflow beyond ``cap_pair`` per pair is dropped; capacities are sized so
    tests/benchmarks never hit the cap (asserted in the simulator backend).
    """
    cap = outbox.shape[0]
    buckets = jnp.zeros((num_shards, cap_pair, M.FIELDS), M.MSG_DTYPE)
    counts = jnp.zeros((num_shards,), jnp.int32)

    def body(i, c):
        buckets, counts = c
        row = outbox[i]
        live = (row[M.F_KIND] != M.MSG_NONE) & (i < count)
        d = jnp.clip(row[M.F_DST], 0, num_shards - 1)
        p = jnp.clip(counts[d], 0, cap_pair - 1)
        buckets = jnp.where(live, buckets.at[d, p].set(row), buckets)
        counts = counts.at[d].add(live.astype(jnp.int32))
        return buckets, counts

    buckets, counts = jax.lax.fori_loop(0, cap, body, (buckets, counts))
    return buckets, counts


def make_dili_round(mesh: Mesh, cfg: DiLiConfig, cap_pair: int = 8):
    """Build the jitted SPMD round: (states, bgs, inbox, client) ->
    (states, bgs, inbox_next, comp_slot, comp_val, comp_src, comp_key,
    stats).

    All arguments are stacked over the leading shard axis and sharded over
    the mesh's flattened device axes. ``comp_src`` is the shard that
    executed each completed op (route-correction feedback for the client
    API); ``comp_key`` tags completion rows — SH_KEY for scalar results,
    a real key for RANGE items (DESIGN.md §16; the routed inbox never
    crosses to the host on this path, so the completion lanes are the
    only channel scan items can ride). ``stats`` is int32[9] per shard,
    computed on-device so the host driver never pulls the routed inbox:

      0  out_count — attempted outbox pushes (detects ``bucket_by_dst``
         overflow instead of silently losing rows)
      1  live rows routed to this shard (quiescence signal)
      2  delegated MSG_OP rows routed to this shard
      3  max delegation-hop count among those rows
      4  background slots still busy after the round (quiescence +
         rebalance-concurrency signal)
      5  MoveItems replayed by the batched scatter splice this round
      6  fast-path lanes answered via the packed-block kernel probe
         (DESIGN.md §12)
      7  FINDs answered from a replica slot (DESIGN.md §15)
      8  RANGE segments served by the packed-block gather pre-pass
         (DESIGN.md §16)

    The trailing ``ent_hits`` output is int32[S, M]: per-entry op
    attribution this round (the balancer's op-rate EWMA feed).
    """
    num = cfg.num_shards
    assert num == mesh.devices.size, (num, mesh.devices.size)
    axes = tuple(mesh.axis_names)

    def per_shard(state, bg, inbox, client):
        # leading singleton shard dim from shard_map
        state = jax.tree_util.tree_map(lambda x: x[0], state)
        bg = jax.tree_util.tree_map(lambda x: x[0], bg)
        inbox = inbox[0]
        client = client[0]
        me = jax.lax.axis_index(axes)
        out = shard_round(state, bg, me, inbox, client, cfg)
        buckets, _ = bucket_by_dst(out.outbox, out.out_count, num, cap_pair)
        # route: one all_to_all over the flattened mesh axes (paper's RPCs)
        routed = jax.lax.all_to_all(buckets, axes, split_axis=0,
                                    concat_axis=0)
        inbox_next = routed.reshape(1, num * cap_pair, M.FIELDS)
        rows = inbox_next[0]
        live = rows[:, M.F_KIND] != M.MSG_NONE
        is_op = rows[:, M.F_KIND] == M.MSG_OP
        stats = jnp.stack([
            out.out_count,
            jnp.sum(live).astype(jnp.int32),
            jnp.sum(is_op).astype(jnp.int32),
            jnp.max(jnp.where(is_op, rows[:, M.F_X2], 0)).astype(jnp.int32),
            out.bg_active,
            out.move_hits,
            out.blk_hits,
            out.rep_hits,
            out.range_hits,
        ])
        add1 = lambda x: x[None]
        return (jax.tree_util.tree_map(add1, out.state),
                jax.tree_util.tree_map(add1, out.bg),
                inbox_next,
                out.comp_slot[None], out.comp_val[None],
                out.comp_src[None], out.comp_key[None], stats[None],
                out.ent_hits[None])

    pspec = P(axes)

    fn = shard_map(
        per_shard, mesh=mesh,
        in_specs=(pspec, pspec, pspec, pspec),
        out_specs=(pspec, pspec, pspec, pspec, pspec, pspec, pspec,
                   pspec, pspec),
        check_rep=False)
    return jax.jit(fn)


def make_dili_round_hostroute(mesh: Mesh, cfg: DiLiConfig):
    """The SPMD round *without* the on-device ``all_to_all``: outboxes come
    back to the host, which routes them through ``core.net.Transport`` (the
    nemesis-enabled path — the adversary lives on the wire between
    outboxes and inboxes, so routing must cross the host).

    (states, bgs, inbox, client) ->
        (states, bgs, outbox, comp_slot, comp_val, comp_src, comp_key,
         stats)

    ``outbox`` is the raw [S, mailbox_cap, FIELDS] per-shard outbox;
    ``stats`` is int32[8] per shard: out_count, bg_active, move_hits,
    fast_hits, mut_hits, blk_hits, rep_hits, range_hits; the trailing
    ``ent_hits`` output is int32[S, M] per-entry op attribution.
    Delegation stats (hops) are computed host-side from the outbox rows
    themselves — the host sees every frame on this path.
    """
    num = cfg.num_shards
    assert num == mesh.devices.size, (num, mesh.devices.size)
    axes = tuple(mesh.axis_names)

    def per_shard(state, bg, inbox, client):
        state = jax.tree_util.tree_map(lambda x: x[0], state)
        bg = jax.tree_util.tree_map(lambda x: x[0], bg)
        me = jax.lax.axis_index(axes)
        out = shard_round(state, bg, me, inbox[0], client[0], cfg)
        stats = jnp.stack([
            out.out_count,
            out.bg_active,
            out.move_hits,
            out.fast_hits,
            out.mut_hits,
            out.blk_hits,
            out.rep_hits,
            out.range_hits,
        ])
        add1 = lambda x: x[None]
        return (jax.tree_util.tree_map(add1, out.state),
                jax.tree_util.tree_map(add1, out.bg),
                out.outbox[None],
                out.comp_slot[None], out.comp_val[None],
                out.comp_src[None], out.comp_key[None], stats[None],
                out.ent_hits[None])

    pspec = P(axes)
    fn = shard_map(
        per_shard, mesh=mesh,
        in_specs=(pspec, pspec, pspec, pspec),
        out_specs=(pspec, pspec, pspec, pspec, pspec, pspec, pspec,
                   pspec, pspec),
        check_rep=False)
    return jax.jit(fn)


def stack_states(states, bgs):
    st = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *states)
    bg = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *bgs)
    return st, bg


def service_input_specs(cfg: DiLiConfig, num_shards: int, in_cap: int):
    """ShapeDtypeStruct stand-ins for the dry-run (no allocation)."""
    from .types import init_shard
    proto_state = jax.eval_shape(lambda: init_shard(cfg, 0))
    proto_bg = jax.eval_shape(lambda: B.init_bg_table(cfg))

    def stackit(sds):
        return jax.ShapeDtypeStruct((num_shards,) + sds.shape, sds.dtype)

    states = jax.tree_util.tree_map(stackit, proto_state)
    bgs = jax.tree_util.tree_map(stackit, proto_bg)
    inbox = jax.ShapeDtypeStruct((num_shards, in_cap, M.FIELDS), jnp.int32)
    client = jax.ShapeDtypeStruct(
        (num_shards, cfg.batch_size, M.FIELDS), jnp.int32)
    return states, bgs, inbox, client
