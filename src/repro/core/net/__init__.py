"""Reliable transport + deterministic nemesis (DESIGN.md §11).

Layout:

* ``transport`` — per-(src,dst) sequence lanes, dedup windows,
  cumulative acks, bounded retransmit ring: exactly-once in-order
  delivery over a lossy wire;
* ``nemesis``   — the seeded adversary (drop/dup/reorder/delay,
  partitions, per-link overrides), a pure function of
  ``(seed, NemesisConfig)``;
* ``digest``    — state / round-trace fingerprints for byte-identical
  replay checks.

Both execution backends route through one ``Transport`` when a
``NemesisConfig`` is attached (``core.sim.Cluster(nemesis=...)``,
``api.ShardMapBackend(nemesis=...)``); with no nemesis the legacy
direct routing paths are untouched (zero overhead).
"""
from .digest import state_digest, trace_digest, trace_entry  # noqa: F401
from .nemesis import (LinkFaults, Nemesis, NemesisConfig,  # noqa: F401
                      Partition)
from .transport import Transport, TransportOverflow  # noqa: F401
