"""Deterministic digests for replay checking (DESIGN.md §11).

``state_digest`` fingerprints a shard state (or any pytree of arrays);
``trace_entry`` compresses one round's observable outcome. Two runs from
the same ``(seed, config)`` must produce identical round traces — the
single-seed reproducibility contract the nemesis harness rests on.
"""
from __future__ import annotations

import hashlib
from typing import Iterable, List, Sequence, Tuple

import jax
import numpy as np


def state_digest(*pytrees) -> str:
    """SHA-256 over every array leaf (shape + dtype + bytes) of the given
    pytrees, order-stable. Identical digests == identical states."""
    h = hashlib.sha256()
    for tree in pytrees:
        for leaf in jax.tree_util.tree_leaves(tree):
            arr = np.asarray(leaf)
            h.update(str(arr.shape).encode())
            h.update(str(arr.dtype).encode())
            h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()


def trace_entry(round_no: int, completions: Sequence[Tuple[int, int, int]],
                out_counts: Iterable[int], extra: int = 0) -> str:
    """One round's observable outcome, as a stable compact string."""
    comp = ",".join(f"{s}:{v}:{r}" for s, v, r in sorted(completions))
    outs = ",".join(str(int(c)) for c in out_counts)
    return f"r{round_no}|c[{comp}]|o[{outs}]|x{extra}"


def trace_digest(trace: List[str]) -> str:
    h = hashlib.sha256()
    for line in trace:
        h.update(line.encode())
        h.update(b"\n")
    return h.hexdigest()
