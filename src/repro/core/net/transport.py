"""Reliable transport: exactly-once, in-order delivery over a lossy wire.

The DiLi protocol (handlers, replay pre-passes, pacing budgets) is built
on a reliable-FIFO-per-(src,dst) channel contract. This module *provides*
that contract over a wire that may drop, duplicate, reorder and delay
frames (the nemesis), so at-least-once delivery with duplicates collapses
to exactly-once *effects*:

  * **Sender** — every (src, dst) lane stamps frames with a monotone
    sequence number (``F_SEQ``), retains unacked frames in a bounded
    retransmit ring, and re-ships frames whose last transmission is older
    than ``retransmit_after`` rounds.
  * **Receiver** — per lane, a cumulative cursor (all seqs ``<= cursor``
    delivered) plus an out-of-order dedup window. A frame at or below the
    cursor, or already buffered, is a duplicate and is dropped; anything
    newer is buffered and the *contiguous prefix* above the cursor is
    released — so handlers see each frame exactly once, in send order,
    no matter what the wire did.
  * **Acks** — receivers emit cumulative ``MSG_NET_ACK`` frames (one per
    lane per round with traffic, re-emitted on duplicate arrival so a
    lost ack heals). Acks are unsequenced — cumulative and idempotent —
    and ride the same lossy wire.

A wire frame is ``(src, dst, row)``: the lane identity travels out-of-band
of the int32 row because ``F_SRC`` is protocol metadata (for ``MSG_OP`` it
names the *reply* shard, not the emitter). ``F_SEQ`` is stamped into the
row itself so delivered rows are self-describing in dumps.

Loopback (src == dst) frames bypass the transport: a shard's self-retry
is machine-local memory, not a network link.

The transport is host-side ``numpy`` shared by both backends: the
simulator interposes it in ``Cluster.step`` routing, and
``ShardMapBackend`` routes host-side (instead of the on-device
``all_to_all``) when a nemesis is attached.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from .. import messages as M
from .nemesis import Frame, Nemesis


class TransportOverflow(RuntimeError):
    """A lane's unacked retransmit ring exceeded ``window`` frames.

    Raised loudly (like ``sim.OutboxOverflow``) instead of dropping the
    oldest frame: a silently un-retransmittable frame is a protocol
    message that will never arrive, which deadlocks quiescence. Fix:
    raise ``window``, lower the fault rates, or pace the feed.
    """


class _Lane:
    """Sender + receiver state for one directed (src, dst) pair."""

    __slots__ = ("next_seq", "unacked", "last_ship", "acked",
                 "cursor", "pending", "ack_due")

    def __init__(self):
        # sender side
        self.next_seq = 1
        self.unacked: Dict[int, np.ndarray] = {}    # seq -> stamped row
        self.last_ship: Dict[int, int] = {}         # seq -> round shipped
        self.acked = 0                              # highest cumulative ack
        # receiver side
        self.cursor = 0                             # delivered prefix
        self.pending: Dict[int, np.ndarray] = {}    # ooo dedup window
        self.ack_due = False                        # emit cumulative ack


class Transport:
    """One cluster-wide reliable transport instance (see module docstring).

    ``ship_round`` returns per-destination row batches in a deterministic
    order (lanes ascending by source, each lane's released contiguous
    prefix in sequence order) — any deterministic inter-lane interleave
    is legal; pair-FIFO is what the protocol needs.
    """

    def __init__(self, num_shards: int, nemesis: Optional[Nemesis] = None,
                 *, retransmit_after: int = 4, window: int = 4096):
        self.n = int(num_shards)
        self.nemesis = nemesis
        self.retransmit_after = max(1, int(retransmit_after))
        self.window = int(window)
        self._lanes: Dict[Tuple[int, int], _Lane] = {}
        self._staged: List[Frame] = []      # fresh frames this round
        self.stats = {"sent": 0, "retransmits": 0, "acks": 0,
                      "dup_dropped": 0, "delivered": 0}

    def _lane(self, src: int, dst: int) -> _Lane:
        key = (src, dst)
        lane = self._lanes.get(key)
        if lane is None:
            lane = self._lanes[key] = _Lane()
        return lane

    # ---------------------------------------------------------------- send
    def send(self, src: int, rows: np.ndarray) -> List[np.ndarray]:
        """Stage one shard's outbox rows for this round's wire.

        ``src`` is the *emitting* shard (the lane identity); rows keep
        whatever ``F_SRC`` the protocol wrote. Returns loopback rows
        (dst == src) for the caller to deliver directly — they never
        touch the wire.
        """
        loopback: List[np.ndarray] = []
        for row in np.asarray(rows, np.int32):
            dst = int(row[M.F_DST])
            if dst == src:
                loopback.append(row.copy())
                continue
            lane = self._lane(src, dst)
            if len(lane.unacked) >= self.window:
                raise TransportOverflow(
                    f"lane ({src}->{dst}) has {len(lane.unacked)} unacked "
                    f"frames (window={self.window}): the wire is losing "
                    f"more than retransmission can absorb")
            stamped = row.copy()
            stamped[M.F_SEQ] = lane.next_seq
            lane.unacked[lane.next_seq] = stamped
            lane.next_seq += 1
            self._staged.append((src, dst, stamped))
            self.stats["sent"] += 1
        return loopback

    # ---------------------------------------------------------------- ship
    def ship_round(self, round_no: int) -> List[np.ndarray]:
        """Route one round: fresh frames + due retransmissions + acks go
        through the nemesis; survivors are acked and deduped per lane.
        Returns ``deliveries`` — ``deliveries[dst]`` is a [K, FIELDS]
        array of rows released to shard ``dst``, in order."""
        wire: List[Frame] = []
        for src, dst, row in self._staged:
            self._lane(src, dst).last_ship[int(row[M.F_SEQ])] = round_no
            wire.append((src, dst, row))
        self._staged = []
        # due retransmissions (shipped but never cumulatively acked)
        for (src, dst), lane in sorted(self._lanes.items()):
            for seq in sorted(lane.unacked):
                shipped = lane.last_ship.get(seq)
                if shipped is not None and \
                        round_no - shipped >= self.retransmit_after:
                    lane.last_ship[seq] = round_no
                    wire.append((src, dst, lane.unacked[seq]))
                    self.stats["retransmits"] += 1
        # cumulative acks for lanes with (re)arrivals; an ack for lane
        # (src, dst) travels the reverse link (dst, src)
        for (src, dst), lane in sorted(self._lanes.items()):
            if lane.ack_due:
                lane.ack_due = False
                ack = np.zeros((M.FIELDS,), np.int32)
                ack[M.F_KIND] = M.MSG_NET_ACK
                ack[M.F_DST] = src
                ack[M.F_SRC] = dst
                ack[M.F_A] = lane.cursor
                wire.append((dst, src, ack))
                self.stats["acks"] += 1

        if self.nemesis is not None:
            wire = self.nemesis.perturb(wire, round_no)

        # receive: ack processing + per-lane dedup/buffer
        touched = set()
        for src, dst, row in wire:
            if int(row[M.F_KIND]) == M.MSG_NET_ACK:
                lane = self._lane(dst, src)     # the lane being acked
                cum = int(row[M.F_A])
                if cum > lane.acked:
                    lane.acked = cum
                    for seq in [q for q in lane.unacked if q <= cum]:
                        del lane.unacked[seq]
                        lane.last_ship.pop(seq, None)
                continue
            lane = self._lane(src, dst)
            seq = int(row[M.F_SEQ])
            lane.ack_due = True                 # re-ack even duplicates
            if seq <= lane.cursor or seq in lane.pending:
                self.stats["dup_dropped"] += 1
                continue
            lane.pending[seq] = row.copy()
            touched.add((src, dst))

        # release each touched lane's contiguous prefix, lanes in
        # deterministic (src asc) order per destination
        deliveries: List[List[np.ndarray]] = [[] for _ in range(self.n)]
        for (src, dst) in sorted(touched):
            lane = self._lane(src, dst)
            while lane.cursor + 1 in lane.pending:
                lane.cursor += 1
                deliveries[dst].append(lane.pending.pop(lane.cursor))
                self.stats["delivered"] += 1
        return [np.stack(rows).astype(np.int32) if rows
                else np.zeros((0, M.FIELDS), np.int32)
                for rows in deliveries]

    # --------------------------------------------------------------- route
    def route_round(self, backlogs: List[np.ndarray],
                    per_src_rows, round_no: int) -> None:
        """Route one round's outbox rows into per-destination host
        backlogs: loopback rows go straight to their own backlog, the
        rest cross the wire (send + ship + deliver). One home for the
        routing sequence — ``Cluster.step`` and
        ``ShardMapBackend._step_hostroute`` both call it, so the two
        backends the differential harness compares cannot drift.

        ``per_src_rows``: iterable of (src shard, [K, FIELDS] rows).
        ``backlogs`` is mutated in place.
        """
        for s, rows in per_src_rows:
            loop = self.send(s, rows)
            if loop:
                backlogs[s] = np.concatenate(
                    [backlogs[s], np.stack(loop)], axis=0)
        for d, rows in enumerate(self.ship_round(round_no)):
            if rows.size:
                backlogs[d] = np.concatenate([backlogs[d], rows], axis=0)

    # --------------------------------------------------- membership (§13)
    def shard_idle(self, shard: int) -> bool:
        """No frame anywhere in the system references a lane touching
        ``shard``: nothing staged, unacked, buffered out-of-order, owing
        an ack, or held by the nemesis' delay stage. This is the
        precondition for ``reset_shard`` — resetting a lane while any old
        frame survives would let a stale sequence number alias into the
        fresh lane's numbering (a delayed duplicate of old seq 5 would sit
        in the new lane's dedup window and eventually be *delivered* into
        the new stream)."""
        shard = int(shard)
        if any(s == shard or d == shard for s, d, _ in self._staged):
            return False
        for (src, dst), lane in self._lanes.items():
            if src != shard and dst != shard:
                continue
            if lane.unacked or lane.pending or lane.ack_due:
                return False
        if self.nemesis is not None and self.nemesis.held_touching(shard):
            return False
        return True

    def reset_shard(self, shard: int) -> None:
        """Drop every lane touching ``shard`` — the re-handshake across a
        membership epoch bump (DESIGN.md §13). A later send lazily
        allocates a fresh lane starting at seq 1 / cursor 0, so a slot
        reused by a future ``join_shard`` starts with clean channels.
        Refuses (loudly) while any such lane is non-idle: see
        ``shard_idle`` for why a hot reset would break exactly-once."""
        if not self.shard_idle(shard):
            raise RuntimeError(
                f"reset_shard({shard}): lanes touching the shard still "
                f"have frames in flight — retire must drain first")
        for key in [k for k in self._lanes
                    if k[0] == shard or k[1] == shard]:
            del self._lanes[key]

    # --------------------------------------------------------------- state
    def in_flight(self) -> int:
        """Frames whose delivery is not yet certain to be settled:
        unacked (possibly lost; will retransmit), buffered out-of-order,
        staged this round, or held by the nemesis' delay stage."""
        total = len(self._staged) + sum(
            len(l.unacked) + len(l.pending) for l in self._lanes.values())
        if self.nemesis is not None:
            total += self.nemesis.in_flight()
        return total

    def idle(self) -> bool:
        return self.in_flight() == 0
