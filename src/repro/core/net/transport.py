"""Reliable transport: exactly-once, in-order delivery over a lossy wire.

The DiLi protocol (handlers, replay pre-passes, pacing budgets) is built
on a reliable-FIFO-per-(src,dst) channel contract. This module *provides*
that contract over a wire that may drop, duplicate, reorder and delay
frames (the nemesis), so at-least-once delivery with duplicates collapses
to exactly-once *effects*:

  * **Sender** — every (src, dst) lane stamps frames with a monotone
    sequence number (``F_SEQ``), retains unacked frames in a bounded
    retransmit ring, and re-ships frames whose last transmission is older
    than ``retransmit_after`` rounds.
  * **Receiver** — per lane, a cumulative cursor (all seqs ``<= cursor``
    delivered) plus an out-of-order dedup window. A frame at or below the
    cursor, or already buffered, is a duplicate and is dropped; anything
    newer is buffered and the *contiguous prefix* above the cursor is
    released — so handlers see each frame exactly once, in send order,
    no matter what the wire did.
  * **Acks** — receivers emit cumulative ``MSG_NET_ACK`` frames (one per
    lane per round with traffic, re-emitted on duplicate arrival so a
    lost ack heals). Acks are unsequenced — cumulative and idempotent —
    and ride the same lossy wire.

A wire frame is ``(src, dst, row)``: the lane identity travels out-of-band
of the int32 row because ``F_SRC`` is protocol metadata (for ``MSG_OP`` it
names the *reply* shard, not the emitter). ``F_SEQ`` is stamped into the
row itself so delivered rows are self-describing in dumps.

Loopback (src == dst) frames bypass the transport: a shard's self-retry
is machine-local memory, not a network link.

The transport is host-side ``numpy`` shared by both backends: the
simulator interposes it in ``Cluster.step`` routing, and
``ShardMapBackend`` routes host-side (instead of the on-device
``all_to_all``) when a nemesis is attached.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from .. import messages as M
from .nemesis import Frame, Nemesis


class TransportOverflow(RuntimeError):
    """A lane's unacked retransmit ring exceeded ``window`` frames.

    Raised loudly (like ``sim.OutboxOverflow``) instead of dropping the
    oldest frame: a silently un-retransmittable frame is a protocol
    message that will never arrive, which deadlocks quiescence. Fix:
    raise ``window``, lower the fault rates, or pace the feed.
    """


class _Lane:
    """Sender + receiver state for one directed (src, dst) pair."""

    __slots__ = ("next_seq", "unacked", "last_ship", "acked",
                 "cursor", "pending", "ack_due")

    def __init__(self):
        # sender side
        self.next_seq = 1
        self.unacked: Dict[int, np.ndarray] = {}    # seq -> stamped row
        self.last_ship: Dict[int, int] = {}         # seq -> round shipped
        self.acked = 0                              # highest cumulative ack
        # receiver side
        self.cursor = 0                             # delivered prefix
        self.pending: Dict[int, np.ndarray] = {}    # ooo dedup window
        self.ack_due = False                        # emit cumulative ack


class Transport:
    """One cluster-wide reliable transport instance (see module docstring).

    ``ship_round`` returns per-destination row batches in a deterministic
    order (lanes ascending by source, each lane's released contiguous
    prefix in sequence order) — any deterministic inter-lane interleave
    is legal; pair-FIFO is what the protocol needs.
    """

    def __init__(self, num_shards: int, nemesis: Optional[Nemesis] = None,
                 *, retransmit_after: int = 4, window: int = 4096):
        self.n = int(num_shards)
        self.nemesis = nemesis
        self.retransmit_after = max(1, int(retransmit_after))
        self.window = int(window)
        self._lanes: Dict[Tuple[int, int], _Lane] = {}
        self._staged: List[Frame] = []      # fresh frames this round
        self.down: set = set()              # crashed shards (DESIGN.md §14)
        self.stats = {"sent": 0, "retransmits": 0, "acks": 0,
                      "dup_dropped": 0, "delivered": 0, "down_dropped": 0}

    def _lane(self, src: int, dst: int) -> _Lane:
        key = (src, dst)
        lane = self._lanes.get(key)
        if lane is None:
            lane = self._lanes[key] = _Lane()
        return lane

    # ---------------------------------------------------------------- send
    def send(self, src: int, rows: np.ndarray) -> List[np.ndarray]:
        """Stage one shard's outbox rows for this round's wire.

        ``src`` is the *emitting* shard (the lane identity); rows keep
        whatever ``F_SRC`` the protocol wrote. Returns loopback rows
        (dst == src) for the caller to deliver directly — they never
        touch the wire.
        """
        loopback: List[np.ndarray] = []
        for row in np.asarray(rows, np.int32):
            dst = int(row[M.F_DST])
            if dst == src:
                loopback.append(row.copy())
                continue
            lane = self._lane(src, dst)
            if len(lane.unacked) >= self.window:
                raise TransportOverflow(
                    f"lane ({src}->{dst}) has {len(lane.unacked)} unacked "
                    f"frames (window={self.window}): the wire is losing "
                    f"more than retransmission can absorb")
            stamped = row.copy()
            stamped[M.F_SEQ] = lane.next_seq
            lane.unacked[lane.next_seq] = stamped
            lane.next_seq += 1
            self._staged.append((src, dst, stamped))
            self.stats["sent"] += 1
        return loopback

    # ---------------------------------------------------------------- ship
    def ship_round(self, round_no: int) -> List[np.ndarray]:
        """Route one round: fresh frames + due retransmissions + acks go
        through the nemesis; survivors are acked and deduped per lane.
        Returns ``deliveries`` — ``deliveries[dst]`` is a [K, FIELDS]
        array of rows released to shard ``dst``, in order."""
        wire: List[Frame] = []
        for src, dst, row in self._staged:
            self._lane(src, dst).last_ship[int(row[M.F_SEQ])] = round_no
            wire.append((src, dst, row))
        self._staged = []
        # due retransmissions (shipped but never cumulatively acked); a
        # down sender can't retransmit and a down receiver is pointless
        # to ship at — skipping WITHOUT touching last_ship leaves the
        # frame immediately due once the shard restarts
        for (src, dst), lane in sorted(self._lanes.items()):
            if src in self.down or dst in self.down:
                continue
            for seq in sorted(lane.unacked):
                shipped = lane.last_ship.get(seq)
                if shipped is not None and \
                        round_no - shipped >= self.retransmit_after:
                    lane.last_ship[seq] = round_no
                    wire.append((src, dst, lane.unacked[seq]))
                    self.stats["retransmits"] += 1
        # cumulative acks for lanes with (re)arrivals; an ack for lane
        # (src, dst) travels the reverse link (dst, src). A dead process
        # emits nothing — its ack_due flags freeze until recovery
        # restores the receiver halves from the durable lane image.
        for (src, dst), lane in sorted(self._lanes.items()):
            if lane.ack_due and dst not in self.down:
                lane.ack_due = False
                ack = np.zeros((M.FIELDS,), np.int32)
                ack[M.F_KIND] = M.MSG_NET_ACK
                ack[M.F_DST] = src
                ack[M.F_SRC] = dst
                ack[M.F_A] = lane.cursor
                wire.append((dst, src, ack))
                self.stats["acks"] += 1

        if self.nemesis is not None:
            wire = self.nemesis.perturb(wire, round_no)

        # receive: ack processing + per-lane dedup/buffer. Frames whose
        # recipient is down hit a dead NIC — dropped here (not earlier)
        # so nemesis-held frames released mid-outage die the same way
        # fresh ones do; the sender's retransmit ring re-ships them
        # after the restart.
        touched = set()
        for src, dst, row in wire:
            if dst in self.down:
                self.stats["down_dropped"] += 1
                continue
            if int(row[M.F_KIND]) == M.MSG_NET_ACK:
                lane = self._lane(dst, src)     # the lane being acked
                cum = int(row[M.F_A])
                if cum > lane.acked:
                    lane.acked = cum
                    for seq in [q for q in lane.unacked if q <= cum]:
                        del lane.unacked[seq]
                        lane.last_ship.pop(seq, None)
                continue
            lane = self._lane(src, dst)
            seq = int(row[M.F_SEQ])
            lane.ack_due = True                 # re-ack even duplicates
            if seq <= lane.cursor or seq in lane.pending:
                self.stats["dup_dropped"] += 1
                continue
            lane.pending[seq] = row.copy()
            touched.add((src, dst))

        # release each touched lane's contiguous prefix, lanes in
        # deterministic (src asc) order per destination
        deliveries: List[List[np.ndarray]] = [[] for _ in range(self.n)]
        for (src, dst) in sorted(touched):
            lane = self._lane(src, dst)
            while lane.cursor + 1 in lane.pending:
                lane.cursor += 1
                deliveries[dst].append(lane.pending.pop(lane.cursor))
                self.stats["delivered"] += 1
        return [np.stack(rows).astype(np.int32) if rows
                else np.zeros((0, M.FIELDS), np.int32)
                for rows in deliveries]

    # --------------------------------------------------------------- route
    def route_round(self, backlogs: List[np.ndarray],
                    per_src_rows, round_no: int) -> None:
        """Route one round's outbox rows into per-destination host
        backlogs: loopback rows go straight to their own backlog, the
        rest cross the wire (send + ship + deliver). One home for the
        routing sequence — ``Cluster.step`` and
        ``ShardMapBackend._step_hostroute`` both call it, so the two
        backends the differential harness compares cannot drift.

        ``per_src_rows``: iterable of (src shard, [K, FIELDS] rows).
        ``backlogs`` is mutated in place.
        """
        for s, rows in per_src_rows:
            loop = self.send(s, rows)
            if loop:
                backlogs[s] = np.concatenate(
                    [backlogs[s], np.stack(loop)], axis=0)
        for d, rows in enumerate(self.ship_round(round_no)):
            if rows.size:
                backlogs[d] = np.concatenate([backlogs[d], rows], axis=0)

    # --------------------------------------------------- membership (§13)
    def shard_idle(self, shard: int) -> bool:
        """No frame anywhere in the system references a lane touching
        ``shard``: nothing staged, unacked, buffered out-of-order, owing
        an ack, or held by the nemesis' delay stage. This is the
        precondition for ``reset_shard`` — resetting a lane while any old
        frame survives would let a stale sequence number alias into the
        fresh lane's numbering (a delayed duplicate of old seq 5 would sit
        in the new lane's dedup window and eventually be *delivered* into
        the new stream)."""
        shard = int(shard)
        if any(s == shard or d == shard for s, d, _ in self._staged):
            return False
        for (src, dst), lane in self._lanes.items():
            if src != shard and dst != shard:
                continue
            if lane.unacked or lane.pending or lane.ack_due:
                return False
        if self.nemesis is not None and self.nemesis.held_touching(shard):
            return False
        return True

    def reset_shard(self, shard: int) -> None:
        """Drop every lane touching ``shard`` — the re-handshake across a
        membership epoch bump (DESIGN.md §13). A later send lazily
        allocates a fresh lane starting at seq 1 / cursor 0, so a slot
        reused by a future ``join_shard`` starts with clean channels.
        Refuses (loudly) while any such lane is non-idle: see
        ``shard_idle`` for why a hot reset would break exactly-once."""
        if not self.shard_idle(shard):
            raise RuntimeError(
                f"reset_shard({shard}): lanes touching the shard still "
                f"have frames in flight — retire must drain first")
        for key in [k for k in self._lanes
                    if k[0] == shard or k[1] == shard]:
            del self._lanes[key]

    # ------------------------------------------------- crash-restart (§14)
    # A crashed shard's halves of its lanes — sender rings on (s, *),
    # receiver cursors on (*, s) — are process memory and die with it.
    # They are journaled per round into the WAL as a flat str -> ndarray
    # image and reinstalled at restart; the surviving peers' halves of
    # the same lane objects are never touched. Frames the dead shard had
    # sent but nobody acked are still in the restored ring and retransmit
    # immediately; frames peers sent it while it was down were never
    # delivered (down-NIC drop above) and retransmit once it returns —
    # exactly-once holds across the reboot without a lane reset.

    def crash_shard(self, shard: int) -> None:
        """Mark ``shard``'s process dead: it ships nothing, acks nothing,
        and every frame addressed to it hits a dead NIC. Lane objects are
        left in place — the volatile halves are overwritten at restart."""
        self.down.add(int(shard))

    def export_shard_lanes(self, shard: int) -> Dict[str, np.ndarray]:
        """Snapshot the halves of every lane that live in ``shard``'s
        process memory, as a flat npz-able dict (the WAL lane image)."""
        shard = int(shard)
        img: Dict[str, np.ndarray] = {}
        for (src, dst), lane in sorted(self._lanes.items()):
            if src == shard:                      # sender half of (s, p)
                seqs = sorted(lane.unacked)
                img[f"send/{dst}/next_seq"] = np.int64(lane.next_seq)
                img[f"send/{dst}/acked"] = np.int64(lane.acked)
                img[f"send/{dst}/seqs"] = np.asarray(seqs, np.int64)
                img[f"send/{dst}/rows"] = (
                    np.stack([lane.unacked[q] for q in seqs])
                    if seqs else np.zeros((0, M.FIELDS), np.int32))
            if dst == shard:                      # receiver half of (p, s)
                seqs = sorted(lane.pending)
                img[f"recv/{src}/cursor"] = np.int64(lane.cursor)
                img[f"recv/{src}/ack_due"] = np.int64(int(lane.ack_due))
                img[f"recv/{src}/seqs"] = np.asarray(seqs, np.int64)
                img[f"recv/{src}/rows"] = (
                    np.stack([lane.pending[q] for q in seqs])
                    if seqs else np.zeros((0, M.FIELDS), np.int32))
        return img

    def restart_shard(self, shard: int,
                      image: Dict[str, np.ndarray]) -> None:
        """Reinstall ``shard``'s lane halves from a durable image and
        bring its NIC back up. Halves not present in the image (a peer
        opened the lane while the shard was down) reset to the fresh
        handshake state, which is what the restarted process remembers."""
        shard = int(shard)
        long_ago = -(1 << 30)   # restored unacked frames: due immediately
        for (src, dst), lane in self._lanes.items():
            if src == shard:
                lane.next_seq, lane.acked = 1, 0
                lane.unacked, lane.last_ship = {}, {}
            if dst == shard:
                lane.cursor, lane.pending, lane.ack_due = 0, {}, False
        peers = {key.split("/")[1] for key in image}
        for p in sorted(int(x) for x in peers):
            if f"send/{p}/next_seq" in image:
                lane = self._lane(shard, p)
                lane.next_seq = int(image[f"send/{p}/next_seq"])
                lane.acked = int(image[f"send/{p}/acked"])
                seqs = image[f"send/{p}/seqs"]
                rows = image[f"send/{p}/rows"]
                lane.unacked = {int(q): np.asarray(r, np.int32).copy()
                                for q, r in zip(seqs, rows)}
                lane.last_ship = {int(q): long_ago for q in seqs}
            if f"recv/{p}/cursor" in image:
                lane = self._lane(p, shard)
                lane.cursor = int(image[f"recv/{p}/cursor"])
                lane.ack_due = bool(int(image[f"recv/{p}/ack_due"]))
                seqs = image[f"recv/{p}/seqs"]
                rows = image[f"recv/{p}/rows"]
                lane.pending = {int(q): np.asarray(r, np.int32).copy()
                                for q, r in zip(seqs, rows)}
        self.down.discard(shard)

    # --------------------------------------------------------------- state
    def in_flight(self) -> int:
        """Frames whose delivery is not yet certain to be settled:
        unacked (possibly lost; will retransmit), buffered out-of-order,
        staged this round, or held by the nemesis' delay stage."""
        total = len(self._staged) + sum(
            len(l.unacked) + len(l.pending) for l in self._lanes.values())
        if self.nemesis is not None:
            total += self.nemesis.in_flight()
        return total

    def idle(self) -> bool:
        return self.in_flight() == 0
