"""Deterministic nemesis: a seeded adversary for the wire (DESIGN.md §11).

The nemesis sits *below* the reliable transport: it perturbs raw wire
frames (drop / duplicate / reorder / delay / partition) and the transport
above it must still deliver every DiLi message exactly once, in per-lane
order. Everything here is a pure function of ``(seed, NemesisConfig,
frame sequence)`` — the same schedule replays byte-identically from its
``(seed, config)`` pair, which is what turns a hunt-found failure into a
checked-in regression (tests/nemesis_corpus.json).

Fault model per frame, applied in this order each round:

  1. **partition** — frames crossing an active partition cut are dropped
     unconditionally (they retransmit after the cut heals);
  2. **drop** — lost with probability ``drop_prob``;
  3. **dup** — with probability ``dup_prob`` a surviving frame is
     delivered twice (the duplicate rides the same round);
  4. **delay** — with probability ``delay_prob`` a frame is held for
     1..``delay_rounds`` rounds before becoming deliverable;
  5. **reorder** — with probability ``reorder_prob`` per frame, the
     round's deliverable batch is locally shuffled (a perturbed sort, so
     reordering is also seed-deterministic).

``link_overrides`` replaces the four probabilities on named (src, dst)
links — e.g. one asymmetric lossy link in an otherwise clean fabric.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple

import numpy as np

# A wire frame: (emitting shard, destination shard, int32 message row).
# The lane identity travels out-of-band of the row because F_SRC is
# protocol metadata (the reply shard for MSG_OP), not the emitter.
Frame = Tuple[int, int, np.ndarray]


@dataclass(frozen=True)
class LinkFaults:
    """Per-link fault probabilities (also the global defaults)."""
    drop_prob: float = 0.0
    dup_prob: float = 0.0
    reorder_prob: float = 0.0
    delay_prob: float = 0.0


@dataclass(frozen=True)
class Partition:
    """Links between ``group`` and every other shard are cut while
    ``start_round <= round < end_round`` (both directions)."""
    start_round: int
    end_round: int
    group: Tuple[int, ...]


@dataclass(frozen=True)
class CrashPlan:
    """kill -9 ``shard`` at the top of ``crash_round``; restart it (via
    snapshot + WAL replay, DESIGN.md §14) at the top of
    ``restart_round``. The crash lands on a round boundary — the WAL's
    fsync-before-ack discipline means a round's effects are durable
    before any peer can observe them, so mid-round torn state is not a
    reachable fault (the wire-level nemesis already covers torn traffic).
    """
    shard: int
    crash_round: int
    restart_round: int

    def __post_init__(self):
        if self.restart_round <= self.crash_round:
            raise ValueError(
                f"CrashPlan(shard={self.shard}): restart_round "
                f"{self.restart_round} must follow crash_round "
                f"{self.crash_round}")


@dataclass(frozen=True)
class NemesisConfig:
    """One adversarial schedule, replayable from ``(seed, config)``."""
    drop_prob: float = 0.0
    dup_prob: float = 0.0
    reorder_prob: float = 0.0
    delay_prob: float = 0.0
    delay_rounds: int = 2
    partitions: Tuple[Partition, ...] = ()
    # (src, dst) -> LinkFaults overriding the global probabilities
    link_overrides: Tuple[Tuple[Tuple[int, int], LinkFaults], ...] = ()
    # crash-restart schedules (the durable-recovery fault axis, §14)
    crashes: Tuple[CrashPlan, ...] = ()

    def faults_for(self, src: int, dst: int) -> LinkFaults:
        for (s, d), lf in self.link_overrides:
            if s == src and d == dst:
                return lf
        return LinkFaults(self.drop_prob, self.dup_prob,
                          self.reorder_prob, self.delay_prob)

    def repro(self, seed: int) -> str:
        """The one-line ``(seed, config)`` repro string printed on failure
        and stored in the regression corpus."""
        return f"(seed={seed}, config={self.to_dict()})"

    def to_dict(self) -> dict:
        return {
            "drop_prob": self.drop_prob, "dup_prob": self.dup_prob,
            "reorder_prob": self.reorder_prob,
            "delay_prob": self.delay_prob,
            "delay_rounds": self.delay_rounds,
            "partitions": [[p.start_round, p.end_round, list(p.group)]
                           for p in self.partitions],
            "link_overrides": [
                [[s, d], [lf.drop_prob, lf.dup_prob, lf.reorder_prob,
                          lf.delay_prob]]
                for (s, d), lf in self.link_overrides],
            "crashes": [[c.shard, c.crash_round, c.restart_round]
                        for c in self.crashes],
        }

    @classmethod
    def from_dict(cls, d: dict) -> "NemesisConfig":
        return cls(
            drop_prob=float(d.get("drop_prob", 0.0)),
            dup_prob=float(d.get("dup_prob", 0.0)),
            reorder_prob=float(d.get("reorder_prob", 0.0)),
            delay_prob=float(d.get("delay_prob", 0.0)),
            delay_rounds=int(d.get("delay_rounds", 2)),
            partitions=tuple(Partition(int(a), int(b), tuple(g))
                             for a, b, g in d.get("partitions", ())),
            link_overrides=tuple(
                ((int(s), int(d_)), LinkFaults(*map(float, lf)))
                for (s, d_), lf in d.get("link_overrides", ())),
            crashes=tuple(CrashPlan(int(s), int(a), int(b))
                          for s, a, b in d.get("crashes", ())),
        )


class Nemesis:
    """Applies a ``NemesisConfig`` to each round's wire batch.

    Draws come from one ``numpy`` Generator seeded by a child of the
    run's root ``SeedSequence`` — the nemesis stream is independent of
    the sim's delay stream and the balancer stream, so adding faults
    never perturbs the other streams' draws (single-seed replayability).
    """

    def __init__(self, config: NemesisConfig, rng: np.random.Generator):
        self.config = config
        self.rng = rng
        # frames held back by `delay`, keyed by due round
        self._held: Dict[int, List[Frame]] = {}
        self.stats = {"dropped": 0, "duplicated": 0, "reordered": 0,
                      "delayed": 0, "partitioned": 0}

    # ------------------------------------------------------------ helpers
    def _cut(self, src: int, dst: int, round_no: int) -> bool:
        for p in self.config.partitions:
            if p.start_round <= round_no < p.end_round:
                if (src in p.group) != (dst in p.group):
                    return True
        return False

    def in_flight(self) -> int:
        """Frames held by `delay` and not yet released."""
        return sum(len(v) for v in self._held.values())

    def held_touching(self, shard: int) -> int:
        """Held frames on lanes touching ``shard`` — consulted by
        ``Transport.shard_idle`` so a lane reset can't race a delayed
        duplicate into the fresh sequence stream (DESIGN.md §13)."""
        return sum(1 for frames in self._held.values()
                   for src, dst, _ in frames
                   if src == shard or dst == shard)

    # ------------------------------------------------------------- perturb
    def perturb(self, frames: List[Frame], round_no: int) -> List[Frame]:
        """Adversarially filter one round's wire batch.

        ``frames``: (src, dst, row) wire frames, already transport-
        stamped. Returns the frames deliverable this round (including
        released delayed frames and injected duplicates), possibly
        reordered.
        """
        # frames coming due from the delay stage re-enter at the
        # partition check: a cut that started while they were held must
        # still cut them (they retransmit after it heals)
        out: List[Frame] = []
        for src, dst, row in self._held.pop(round_no, []):
            if self._cut(src, dst, round_no):
                self.stats["partitioned"] += 1
                continue
            out.append((src, dst, row))
        for src, dst, row in frames:
            if self._cut(src, dst, round_no):
                self.stats["partitioned"] += 1
                continue
            lf = self.config.faults_for(src, dst)
            # one draw per decision keeps the stream layout stable: a
            # frame consumes draws only for the stages it reaches
            if lf.drop_prob > 0.0 and self.rng.random() < lf.drop_prob:
                self.stats["dropped"] += 1
                continue
            copies = 1
            if lf.dup_prob > 0.0 and self.rng.random() < lf.dup_prob:
                copies = 2
                self.stats["duplicated"] += 1
            for _ in range(copies):
                if (lf.delay_prob > 0.0
                        and self.rng.random() < lf.delay_prob):
                    hold = 1 + int(self.rng.integers(
                        max(1, self.config.delay_rounds)))
                    self._held.setdefault(round_no + hold, []).append(
                        (src, dst, row.copy()))
                    self.stats["delayed"] += 1
                else:
                    out.append((src, dst, row.copy()))
        # reorder: perturb a stable sort key — frames flagged for reorder
        # jump a seeded distance, everything else keeps arrival order
        rp = max((self.config.reorder_prob,
                  *(lf.reorder_prob
                    for _, lf in self.config.link_overrides)))
        if rp > 0.0 and len(out) > 1:
            key = np.arange(len(out), dtype=np.float64)
            for i, (src, dst, _) in enumerate(out):
                lf = self.config.faults_for(src, dst)
                if lf.reorder_prob > 0.0 and \
                        self.rng.random() < lf.reorder_prob:
                    key[i] += self.rng.uniform(-len(out), len(out))
                    self.stats["reordered"] += 1
            out = [out[i] for i in np.argsort(key, kind="stable")]
        return out
