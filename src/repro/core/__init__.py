"""DiLi core: the paper's data structure and distributed protocol."""
from . import (background, balancer, messages, ops, oracle,  # noqa: F401
               refs, registry, shard, sim, skiplist, traverse, types)
