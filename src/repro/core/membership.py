"""Elastic shard membership: the epoch-stamped host-side view (DESIGN.md §13).

``cfg.num_shards`` stays what it always was — the jit-static *capacity* of
the cluster (mesh size, mailbox sizing, broadcast loop bounds). What varies
at runtime is which of those capacity slots are *members*, tracked here as
a per-shard lifecycle:

    RETIRED --begin_join--> JOINING --promote--> ACTIVE
    ACTIVE/JOINING --begin_drain--> DRAINING --finish_drain--> RETIRED
    ACTIVE/JOINING/DRAINING --crash--> CRASHED --restart--> JOINING

  * **active** — owns sublists, receives client ops, counts in balancer
    load means, and is a valid move target.
  * **joining** — participates in rounds and is a valid move target (the
    balancer drains sublists onto it), but clients do not route fresh ops
    to it until it owns something; promoted to active by the host once it
    owns its first sublist.
  * **draining** — still owns and executes (ops delegated to it must land
    somewhere), but the balancer force-evacuates everything it owns and
    never targets it with new moves.
  * **retired** — owns nothing, receives no client ops, excluded from the
    registry-broadcast fan-out (its replica goes stale, which is *safe* —
    the registry is lazily replicated by design). Its transport lanes are
    reset (re-handshaken) at the moment it leaves.
  * **crashed** — the process died mid-run (kill -9); unlike draining it
    still *owns* its sublists on durable storage, but it executes nothing
    and is excluded from routing, broadcast fan-out, and move targeting
    until recovery restarts it. Crash ≠ drain: a crashed shard re-enters
    as JOINING-with-state (it already owns entries, so host maintenance
    promotes it immediately), and carve-out / delegation healing repairs
    whatever restructured while it was down (DESIGN.md §14).

Every transition bumps ``epoch``. The on-device witness of the view is the
``(epoch, peers)`` pair in ``ShardState``, merged monotonically by the
``MSG_EPOCH`` handler — so broadcast fan-out loops can gate on the peer
bitmask without dynamic shapes, and a partitioned shard simply acts on a
stale-but-safe view until the transport heals.

The class is pure host-side bookkeeping: it queues no messages and reads
no device state. ``Cluster``/``ShardMapBackend`` own the actuation
(broadcasting MSG_EPOCH, checking drain completion, resetting lanes).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import messages as M

JOINING = "joining"
ACTIVE = "active"
DRAINING = "draining"
RETIRED = "retired"
CRASHED = "crashed"

# peers bitmask lives in one int32 message lane / ShardState scalar
MASK_BITS = 31


def live_mask(members: Sequence[int], capacity: int) -> int:
    """int32 bitmask with bit ``s`` set for every live (non-retired) shard.

    A full mask at capacity >= MASK_BITS is representable as -1 (all bits
    set; arithmetic right-shift keeps every probe true) — partial
    membership at that scale is rejected by ``Membership`` itself.
    """
    members = sorted(set(int(s) for s in members))
    if capacity >= MASK_BITS:
        if len(members) != capacity:
            raise ValueError(
                f"elastic membership needs capacity < {MASK_BITS} "
                f"(peer bitmask is one int32 lane), got {capacity}")
        return -1
    m = 0
    for s in members:
        m |= 1 << s
    return m


class Membership:
    """Epoch-stamped membership over a fixed capacity of shard slots."""

    def __init__(self, capacity: int, initial: Optional[int] = None):
        self.capacity = int(capacity)
        if self.capacity > MASK_BITS:
            # bit ``s`` of the int32 live_mask must exist for every slot;
            # widening past 31 needs a multi-lane mask (ROADMAP follow-on).
            raise ValueError(
                f"num_shards={self.capacity} exceeds the {MASK_BITS}-slot "
                f"int32 peer-bitmask bound; widen the mask before scaling "
                f"capacity past {MASK_BITS}")
        initial = self.capacity if initial is None else int(initial)
        if not 1 <= initial <= self.capacity:
            raise ValueError(
                f"initial_shards={initial} out of range 1..{self.capacity}")
        if initial != self.capacity and self.capacity >= MASK_BITS:
            raise ValueError(
                f"elastic membership needs capacity < {MASK_BITS} "
                f"(peer bitmask is one int32 lane), got {self.capacity}")
        self.epoch = 0
        self._state: List[str] = ([ACTIVE] * initial
                                  + [RETIRED] * (self.capacity - initial))
        # (epoch, event, shard) — the membership half of the replay witness
        self.log: List[Tuple[int, str, int]] = []

    # -------------------------------------------------------------- queries
    def _by_state(self, which: str) -> Tuple[int, ...]:
        return tuple(s for s in range(self.capacity)
                     if self._state[s] == which)

    @property
    def active(self) -> Tuple[int, ...]:
        return self._by_state(ACTIVE)

    @property
    def joining(self) -> Tuple[int, ...]:
        return self._by_state(JOINING)

    @property
    def draining(self) -> Tuple[int, ...]:
        return self._by_state(DRAINING)

    @property
    def retired(self) -> Tuple[int, ...]:
        return self._by_state(RETIRED)

    @property
    def crashed(self) -> Tuple[int, ...]:
        return self._by_state(CRASHED)

    @property
    def routable(self) -> Tuple[int, ...]:
        """Shards that may own sublists / execute ops right now."""
        return tuple(s for s in range(self.capacity)
                     if self._state[s] not in (RETIRED, CRASHED))

    @property
    def targets(self) -> Tuple[int, ...]:
        """Valid destinations for new Moves (active + joining)."""
        return tuple(s for s in range(self.capacity)
                     if self._state[s] in (ACTIVE, JOINING))

    def state_of(self, shard: int) -> str:
        return self._state[shard]

    def is_routable(self, shard: int) -> bool:
        return (0 <= shard < self.capacity
                and self._state[shard] not in (RETIRED, CRASHED))

    def is_active(self, shard: int) -> bool:
        return 0 <= shard < self.capacity and self._state[shard] == ACTIVE

    def mask(self) -> int:
        """Live-peer bitmask (what MSG_EPOCH carries in F_X1)."""
        return live_mask(self.routable, self.capacity)

    def view(self) -> Dict[str, object]:
        """Serializable snapshot (trace / repro artifacts)."""
        return {"epoch": self.epoch, "active": list(self.active),
                "joining": list(self.joining),
                "draining": list(self.draining),
                "retired": list(self.retired)}

    # ---------------------------------------------------------- transitions
    def _bump(self, event: str, shard: int) -> None:
        self.epoch += 1
        self.log.append((self.epoch, event, shard))

    def begin_join(self, shard: Optional[int] = None) -> int:
        """RETIRED -> JOINING. Picks the lowest retired slot when ``shard``
        is None; the new member enters empty."""
        if self.capacity >= MASK_BITS:
            raise ValueError(
                f"elastic membership needs capacity < {MASK_BITS}")
        if shard is None:
            retired = self.retired
            if not retired:
                raise ValueError("no retired shard slot available to join")
            shard = retired[0]
        shard = int(shard)
        if self._state[shard] != RETIRED:
            raise ValueError(
                f"shard {shard} is {self._state[shard]}, cannot join")
        self._state[shard] = JOINING
        self._bump("join", shard)
        return shard

    def promote(self, shard: int) -> None:
        """JOINING -> ACTIVE (host-driven, once the shard owns a sublist)."""
        shard = int(shard)
        if self._state[shard] != JOINING:
            raise ValueError(
                f"shard {shard} is {self._state[shard]}, cannot promote")
        self._state[shard] = ACTIVE
        self._bump("promote", shard)

    def begin_drain(self, shard: int) -> None:
        """ACTIVE/JOINING -> DRAINING. Refuses to drain the last member
        that could own data — someone must absorb the evacuation."""
        shard = int(shard)
        if self._state[shard] not in (ACTIVE, JOINING):
            raise ValueError(
                f"shard {shard} is {self._state[shard]}, cannot drain")
        others = [s for s in self.targets if s != shard]
        if not others:
            raise ValueError(
                f"cannot drain shard {shard}: no other active/joining "
                f"shard to evacuate onto")
        self._state[shard] = DRAINING
        self._bump("drain", shard)

    def finish_drain(self, shard: int) -> None:
        """DRAINING -> RETIRED (host-driven, once drain is provably
        complete — see Cluster._drain_complete for the gate)."""
        shard = int(shard)
        if self._state[shard] != DRAINING:
            raise ValueError(
                f"shard {shard} is {self._state[shard]}, cannot retire")
        self._state[shard] = RETIRED
        self._bump("retire", shard)

    def crash(self, shard: int) -> None:
        """ACTIVE/JOINING/DRAINING -> CRASHED (kill -9 at a round boundary).

        Unlike ``begin_drain`` this never refuses — a crash is not a
        request. A draining shard that crashes forgets the drain intent;
        after restart it re-enters as JOINING like any other survivor.
        """
        if self.capacity >= MASK_BITS:
            raise ValueError(
                f"crash-restart needs capacity < {MASK_BITS} "
                f"(partial membership is not representable at {MASK_BITS}+)")
        shard = int(shard)
        if self._state[shard] not in (ACTIVE, JOINING, DRAINING):
            raise ValueError(
                f"shard {shard} is {self._state[shard]}, cannot crash")
        self._state[shard] = CRASHED
        self._bump("crash", shard)

    def restart(self, shard: int) -> None:
        """CRASHED -> JOINING (recovery installed snapshot+WAL state).

        The restarted shard is JOINING-*with-state*: it still owns its
        pre-crash sublists, so the regular host maintenance pass promotes
        it back to ACTIVE on the next round it owns an entry."""
        shard = int(shard)
        if self._state[shard] != CRASHED:
            raise ValueError(
                f"shard {shard} is {self._state[shard]}, cannot restart")
        self._state[shard] = JOINING
        self._bump("restart", shard)


# ------------------------------------------------------- actuation helpers
# Shared by Cluster and ShardMapBackend so the two backends' membership
# mechanics cannot drift.

def epoch_row(dst: int, src: int, epoch: int, mask: int) -> np.ndarray:
    """One MSG_EPOCH announcement row: F_KEY carries the epoch, F_X1 the
    live-peer bitmask. The handler merges monotonically (max on epoch), so
    duplicated or reordered deliveries are idempotent."""
    row = np.zeros((M.FIELDS,), np.int32)
    row[M.F_KIND] = M.MSG_EPOCH
    row[M.F_DST] = dst
    row[M.F_SRC] = src
    row[M.F_KEY] = epoch
    row[M.F_X1] = mask
    return row


def epoch_broadcast(membership: Membership) -> List[np.ndarray]:
    """Announcement rows for every capacity slot (retired shards included —
    they keep their epoch register current for a later rejoin), emitted
    from a deterministic coordinator (the lowest active shard)."""
    src = min(membership.active)
    return [epoch_row(dst, src, membership.epoch, membership.mask())
            for dst in range(membership.capacity)]


def owned_entry_count(cfg, states, s: int) -> int:
    """Non-switched registry entries shard ``s``'s own replica says it
    owns — the ownership witness for promote/finish_drain decisions."""
    from .sim import state_sublists
    return sum(1 for e in state_sublists(cfg, states, s)
               if e["owner"] == s and not e["switched"])


def moves_targeting(bgs, s: int) -> int:
    """In-flight Moves (any source shard) whose target is ``s`` and whose
    registry transfer has not landed — retiring ``s`` under one would
    strand the sublist mid-copy."""
    from . import bg as B
    return sum(1 for bg in bgs for _, tgt in B.active_moves(bg)
               if tgt == s)
