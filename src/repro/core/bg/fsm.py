"""Background FSM state: phases, flags, and the slotted ``BgTable``.

Each shard runs up to ``cfg.bg_slots`` background operations concurrently
(the paper assigns one background thread per machine; DESIGN.md §10 extends
that to B independent ops under a per-registry-entry claim). A slot is one
``BgState`` (all-scalar leaves); a shard's table is the same NamedTuple
with ``[B]``-shaped leaves — pytree-compatible with stacking, shard_map and
checkpointing like every other state container.

Phase graph (per slot)::

   IDLE -> SPLIT_EXEC -> SPLIT_WAIT -> IDLE
   IDLE -> MOVE_SH -> MOVE_SH_WAIT -> MOVE_COPY -> MOVE_STABLE
        -> SWITCH_ST [-> SWITCH_ST_WAIT] -> SWITCH_REG -> QUAR -> IDLE
   IDLE -> MERGE_EXEC -> MERGE_WAIT -> IDLE          (Appendix B)

The *claim* discipline: a non-IDLE slot owns the registry entries named by
its ``entry_key`` (and ``merge_key`` for merges, sentinel ``SH_KEY``
otherwise); ``engine.queue_*`` refuses a command whose entry is already
claimed by any slot, which is what preserves the paper's per-sublist
safety argument slot-by-slot (DESIGN.md §10).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .. import refs
from ..types import DiLiConfig, SH_KEY

# ------------------------------------------------------------------ phases
BG_IDLE = 0
BG_SPLIT_EXEC = 1
BG_SPLIT_WAIT = 2
BG_MOVE_SH = 3
BG_MOVE_SH_WAIT = 4
BG_MOVE_COPY = 5
BG_MOVE_STABLE = 6
BG_SWITCH_ST = 7
BG_SWITCH_ST_WAIT = 8
BG_SWITCH_REG = 9
BG_QUAR = 10
BG_MERGE_EXEC = 11
BG_MERGE_WAIT = 12
BG_NUM_PHASES = 13   # dispatch-table size: every BG_* above is < this

# MOVE_ITEM / MOVE_ACK flag bits (message field F_A)
FL_MARKED = 1
FL_ST = 2


class BgState(NamedTuple):
    """One background op (scalar leaves) — or a whole shard's slotted
    table when every leaf carries a leading ``[bg_slots]`` axis."""
    phase: jnp.ndarray       # int32
    entry_key: jnp.ndarray   # int32 — keymax identifying the claimed entry
    target: jnp.ndarray      # int32 — destination shard of a Move
    sitem: jnp.ndarray       # int32 — split item pool idx
    cursor: jnp.ndarray      # int32 — acked-prefix cursor: last chain node
                             # whose newLoc is known (contiguously) set
    send_prev: jnp.ndarray   # int32 — pipelined send cursor: last chain
                             # node handed to the fabric (ack not awaited)
    sent: jnp.ndarray        # int32 — MoveItems sent since MOVE_COPY entry
    acked: jnp.ndarray       # int32
    st_sent: jnp.ndarray     # int32 bool — the SubTail has been sent
    st_acked: jnp.ndarray    # int32 bool
    sh_star: jnp.ndarray     # uint32 — target SubHead ref
    st_star: jnp.ndarray     # uint32 — target SubTail ref
    old_head: jnp.ndarray    # int32 — source SubHead pool idx
    quar_round: jnp.ndarray  # int32
    round: jnp.ndarray       # int32 — round counter
    new_slot: jnp.ndarray    # int32 — split: right-half counter slot
    old_slot: jnp.ndarray    # int32 — split: left-half counter slot
    split_key: jnp.ndarray   # int32
    sh_new: jnp.ndarray      # int32 — split: new SubHead pool idx
    st_new: jnp.ndarray      # int32 — split: new SubTail pool idx
    old_keymax: jnp.ndarray  # int32 — split: pre-split keymax (right keymax)
    merge_key: jnp.ndarray   # int32 — merge: right entry keymax (second
                             # claim); SH_KEY sentinel when not a merge


# ``BgTable`` is a type alias, not a distinct class: the slotted table is a
# ``BgState`` whose leaves are ``[bg_slots]``-shaped.
BgTable = BgState


def init_bg() -> BgState:
    z = jnp.zeros((), jnp.int32)
    return BgState(phase=z, entry_key=z, target=z, sitem=z, cursor=z,
                   send_prev=z, sent=z, acked=z, st_sent=z, st_acked=z,
                   sh_star=refs.null_ref(), st_star=refs.null_ref(),
                   old_head=z, quar_round=z, round=z, new_slot=z,
                   old_slot=z, split_key=z, sh_new=z, st_new=z,
                   old_keymax=z,
                   merge_key=jnp.asarray(SH_KEY, jnp.int32))


def init_bg_table(cfg: DiLiConfig) -> BgTable:
    """Fresh all-idle table of ``cfg.bg_slots`` background slots."""
    one = init_bg()
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (cfg.bg_slots,) + x.shape), one)


def slot_view(table: BgTable, j) -> BgState:
    """Slot ``j`` of a table as a scalar-leaf BgState (``j`` may be traced)."""
    return jax.tree_util.tree_map(lambda col: col[j], table)


def set_slot(table: BgTable, j, bg: BgState) -> BgTable:
    return jax.tree_util.tree_map(
        lambda col, leaf: col.at[j].set(leaf), table, bg)


# ----------------------------------------------------- host-side inspection
# Accept a single shard's table (leaves [B]) or a stacked one ([S, B]).

def slot_phases(table: BgTable) -> np.ndarray:
    return np.asarray(table.phase)


def any_active(table: BgTable) -> bool:
    """True if any slot is running a background op."""
    return bool((slot_phases(table) != BG_IDLE).any())


def free_slots(table: BgTable) -> int:
    return int((slot_phases(table) == BG_IDLE).sum())


def claimed_keys(table: BgTable):
    """Registry-entry keymaxes currently claimed by active slots."""
    phases = slot_phases(table).reshape(-1)
    ek = np.asarray(table.entry_key).reshape(-1)
    mk = np.asarray(table.merge_key).reshape(-1)
    out = set()
    for ph, a, b in zip(phases, ek, mk):
        if ph != BG_IDLE:
            out.add(int(a))
            if int(b) != SH_KEY:
                out.add(int(b))
    return out


def active_moves(table: BgTable):
    """(entry_keymax, target) of every in-flight Move whose registry
    transfer has not landed yet — i.e. whose load still counts against
    the *source* shard. A balancer that ignores these keeps re-issuing
    moves for load that is already en route."""
    phases = slot_phases(table).reshape(-1)
    ek = np.asarray(table.entry_key).reshape(-1)
    tg = np.asarray(table.target).reshape(-1)
    pre_transfer = {BG_MOVE_SH, BG_MOVE_SH_WAIT, BG_MOVE_COPY,
                    BG_MOVE_STABLE, BG_SWITCH_ST, BG_SWITCH_ST_WAIT,
                    BG_SWITCH_REG}
    return [(int(k), int(t)) for ph, k, t in zip(phases, ek, tg)
            if int(ph) in pre_transfer]
