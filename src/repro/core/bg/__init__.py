"""Background operations: Split (§5.3), Move + Replay (§5.4), Switch
(Alg. 5), Merge (Appendix B) — as a slotted concurrent engine.

Layout:

* ``fsm``      — phase constants, the ``BgState``/``BgTable`` containers,
                 host-side inspection helpers;
* ``util``     — identity walks, the serial Replay insert, allocation;
* ``handlers`` — message handlers (replicates, move/switch acks,
                 registry broadcasts), slot-addressed where acks credit a
                 background op;
* ``phases``   — per-phase step functions (``split``/``move``/``merge``);
* ``replay``   — the vectorized target-side replay of batched MoveItem
                 runs;
* ``engine``   — ``bg_step`` over the slot table + the claiming
                 ``queue_split/move/merge`` host commands.

``repro.core.background`` re-exports this surface for backwards
compatibility.
"""
from .engine import bg_step, queue_merge, queue_move, queue_split  # noqa: F401
from .fsm import (BG_IDLE, BG_MERGE_EXEC, BG_MERGE_WAIT,  # noqa: F401
                  BG_MOVE_COPY, BG_MOVE_SH, BG_MOVE_SH_WAIT, BG_MOVE_STABLE,
                  BG_NUM_PHASES, BG_QUAR, BG_SPLIT_EXEC, BG_SPLIT_WAIT,
                  BG_SWITCH_REG, BG_SWITCH_ST, BG_SWITCH_ST_WAIT, FL_MARKED,
                  FL_ST, BgState, BgTable, active_moves, any_active,
                  claimed_keys, free_slots, init_bg, init_bg_table, set_slot,
                  slot_phases, slot_view)
from .handlers import (h_ack_delete, h_ack_insert, h_move_ack,  # noqa: F401
                       h_move_item, h_move_sh, h_move_sh_ack, h_reg_merged,
                       h_reg_split, h_rep_delete, h_rep_insert,
                       h_switch_server, h_switch_st, h_switch_st_ack)
from .replay import ReplayOut, replay_prepass  # noqa: F401
