"""The slotted background engine: per-round slot stepping + host commands.

``bg_step`` advances every slot of a shard's ``BgTable`` by one phase per
round (a ``lax.scan`` over the slot axis — one switch compilation serves
all slots), so one shard can split one sublist while moving a second and
merging two others in the same rounds. Slots share the shard's state,
allocator and outbox; they are serialized *within* the round (slot j+1
sees slot j's state writes), which is exactly the round-linearization
discipline client ops already follow (DESIGN.md §2/§10).

``queue_split/move/merge`` are the host commands: each claims the first
idle slot, *unless* the named registry entry is already claimed by any
active slot (at-most-one-op-per-entry — the paper's per-sublist safety
argument, enforced per entry instead of per shard). They return
``(table, ok)``; ``ok`` is False when no slot was free or the entry was
claimed, and the command was dropped.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..types import DiLiConfig, SH_KEY, ShardState
from .fsm import (BG_IDLE, BG_MERGE_EXEC, BG_MERGE_WAIT, BG_MOVE_COPY,
                  BG_MOVE_SH, BG_MOVE_STABLE, BG_NUM_PHASES, BG_QUAR,
                  BG_SPLIT_EXEC, BG_SPLIT_WAIT, BG_SWITCH_REG, BG_SWITCH_ST,
                  BgTable)
from .phases import merge as PM
from .phases import move as PV
from .phases import split as PS

_PHASES = {
    BG_SPLIT_EXEC: PS.split_exec,
    BG_SPLIT_WAIT: PS.split_wait,
    BG_MOVE_SH: PV.move_sh,
    BG_MOVE_COPY: PV.move_copy,
    BG_MOVE_STABLE: PV.move_stable,
    BG_SWITCH_ST: PV.switch_st_phase,
    BG_SWITCH_REG: PV.switch_reg,
    BG_QUAR: PV.quarantine,
    BG_MERGE_EXEC: PM.merge_exec,
    BG_MERGE_WAIT: PM.merge_wait,
}
# a phase key outside the dispatch range would silently alias the no-op
# branch (the clip below) — refuse to import in that state
assert all(0 <= ph < BG_NUM_PHASES for ph in _PHASES), sorted(_PHASES)


def bg_step(state: ShardState, table: BgTable, me, outbox, count,
            cfg: DiLiConfig):
    """Advance every background slot by one phase this round."""
    def mk(fn):
        def br(args):
            st, b, slot_id, ob, ct = args
            st, b, ob, ct = fn(st, b, me, slot_id, ob, ct, cfg)
            return st, b, slot_id, ob, ct
        return br

    def noop(args):
        return args

    branches = [mk(_PHASES[ph]) if ph in _PHASES else noop
                for ph in range(BG_NUM_PHASES)]

    def body(carry, xs):
        st, ob, ct = carry
        bg, slot_id = xs
        st, bg, _, ob, ct = jax.lax.switch(
            jnp.clip(bg.phase, 0, BG_NUM_PHASES - 1), branches,
            (st, bg, slot_id, ob, ct))
        bg = bg._replace(round=bg.round + 1)
        return (st, ob, ct), bg

    slot_ids = jnp.arange(cfg.bg_slots, dtype=jnp.int32)
    (state, outbox, count), table = jax.lax.scan(
        body, (state, outbox, count), (table, slot_ids))
    return state, table, outbox, count


# ============================================================ host commands

def _claim(table: BgTable, key_a, key_b=None):
    """First idle slot + whether ``key_a``/``key_b`` are unclaimed."""
    active = table.phase != BG_IDLE

    def taken(k):
        return jnp.any(active & ((table.entry_key == k)
                                 | (table.merge_key == k)))

    conflict = taken(key_a)
    if key_b is not None:
        conflict = conflict | taken(key_b)
    j = jnp.argmin(active.astype(jnp.int32))     # first idle slot, if any
    ok = (~active[j]) & (~conflict)
    return j, ok


def _set_fields(table: BgTable, j, ok, **updates):
    def one(col, new):
        return col.at[j].set(jnp.where(ok, jnp.asarray(new, col.dtype),
                                       col[j]))
    return table._replace(**{k: one(getattr(table, k), v)
                             for k, v in updates.items()})


def queue_split(table: BgTable, entry_key, sitem_idx):
    """Host command: split ``entry`` (identified by keymax) at pool idx.
    Returns (table, ok)."""
    k = jnp.asarray(entry_key, jnp.int32)
    j, ok = _claim(table, k)
    table = _set_fields(table, j, ok, phase=BG_SPLIT_EXEC, entry_key=k,
                        sitem=sitem_idx, merge_key=SH_KEY)
    return table, ok


def queue_move(table: BgTable, entry_key, target):
    """Host command: move ``entry`` (identified by keymax) to ``target``.
    Returns (table, ok)."""
    k = jnp.asarray(entry_key, jnp.int32)
    j, ok = _claim(table, k)
    table = _set_fields(table, j, ok, phase=BG_MOVE_SH, entry_key=k,
                        target=target, merge_key=SH_KEY)
    return table, ok


def queue_merge(table: BgTable, left_keymax, right_keymax):
    """Host command: merge two adjacent sublists owned by this shard.
    Returns (table, ok)."""
    ka = jnp.asarray(left_keymax, jnp.int32)
    kb = jnp.asarray(right_keymax, jnp.int32)
    j, ok = _claim(table, ka, kb)
    table = _set_fields(table, j, ok, phase=BG_MERGE_EXEC, entry_key=ka,
                        merge_key=kb)
    return table, ok
