"""Vectorized replay of a round's batched MoveItem runs (DESIGN.md §10).

The source's pipelined copy phase ships each sublist as chain-contiguous
runs of ``MSG_MOVE_ITEMS`` rows (K per round per slot). Per-channel FIFO
keeps each (src, slot) run's rows in send order inside the inbox — under
a lossy wire this is *provided* by the reliable transport's per-lane
sequencing and dedup (core/net, DESIGN.md §11), so the eligibility
screen below never sees a duplicated or reordered run row — and the
target can replay a whole run with *one* identity walk (find the run
head's predecessor copy) plus *one* scatter splice — batched node
allocation (``batch_apply.batched_alloc``), one column scatter, one
relink — instead of K serial ``replay_insert`` walks through the row
loop.

Why the splice equals K serial replays: Replay (Lines 249-262) inserts
item_j after its predecessor's copy, before the first node whose
ts < comp_ts_j (comp_ts_j = the predecessor's ts, carried in F_X3). For a
contiguous run spliced after ``prev``, every item's walk starts at the
same successor node ``old_next`` (each item's predecessor copy is the
node the previous item just created, whose next is ``old_next``), so the
serial outcome is "all K directly in run order" exactly when
``old_next`` is the SubTail or ts(old_next) < min_j comp_ts_j — the
eligibility screen below. Anything else (run head's predecessor not yet
here, broken contiguity from interleaved retries, a racing replicate
with a fresh timestamp sitting at the splice point, allocator pressure)
bounces the whole run to the serial ``h_move_item`` handler, which is
the exact per-item algorithm with its own retry loop.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .. import messages as M
from .. import refs
from ..batch_apply import batched_alloc
from ..types import DiLiConfig, ST_KEY, ShardState
from .fsm import FL_MARKED, FL_ST

# bounce the pre-pass wholesale above this many move rows in one round
# (inboxes are sized for all-to-all fan-in; real rounds carry at most
# num_shards * bg_slots * move_batch rows plus retries)
_MAX_LANES = 128

# alloc slack left for the serial path (it owns pool-exhaustion edges)
_ALLOC_HEADROOM = 8


class ReplayOut(NamedTuple):
    state: ShardState
    handled: jnp.ndarray     # bool[R] — rows applied here (skip serially)
    outbox: jnp.ndarray
    count: jnp.ndarray


def replay_prepass(state: ShardState, rows, me, outbox, count,
                   cfg: DiLiConfig) -> ReplayOut:
    """Apply the round's eligible MSG_MOVE_ITEMS runs in one sweep."""
    me = jnp.asarray(me, jnp.int32)
    R = rows.shape[0]
    zb = jnp.zeros((R,), bool)
    if not cfg.move_fastpath:
        return ReplayOut(state, zb, outbox, count)

    is_mv = rows[:, M.F_KIND] == M.MSG_MOVE_ITEMS
    n_mv = jnp.sum(is_mv.astype(jnp.int32))
    k = min(R, _MAX_LANES)
    gate = (n_mv > 0) & (n_mv <= k)

    def run(_):
        pool = state.pool
        cap = pool.key.shape[0]
        # compact move rows into k lanes, keeping inbox (channel) order
        sel = jnp.argsort((~is_mv).astype(jnp.int32) * R
                          + jnp.arange(R, dtype=jnp.int32))[:k]
        live0 = is_mv[sel]
        r0 = rows[sel]
        # group by (src, slot): per-channel FIFO makes each run contiguous
        # in inbox order once lanes are sorted by group
        big = jnp.iinfo(jnp.int32).max
        gkey = jnp.where(live0,
                         r0[:, M.F_SRC] * cfg.bg_slots
                         + jnp.clip(r0[:, M.F_SLOT], 0, cfg.bg_slots - 1),
                         big)
        s2 = jnp.lexsort((jnp.arange(k, dtype=jnp.int32), gkey))
        g = gkey[s2]
        rf = r0[s2]
        live = live0[s2]
        start_any = jnp.concatenate(
            [jnp.ones((1,), bool), g[1:] != g[:-1]])
        sid_g = jnp.cumsum(start_any.astype(jnp.int32)) - 1

        # contiguity: every non-head lane's predecessor identity must be
        # the previous lane's item identity
        psid, pts = rf[:, M.F_X2], rf[:, M.F_X3]
        isid, its = rf[:, M.F_SID], rf[:, M.F_TS]
        prev_ok = jnp.concatenate([
            jnp.ones((1,), bool),
            (psid[1:] == isid[:-1]) & (pts[1:] == its[:-1])])
        cont = start_any | prev_ok
        no_st = (rf[:, M.F_A] & FL_ST) == 0

        # ---- one lock-step identity walk finds every run head's
        # predecessor copy (only head lanes matter; others ride inertly)
        anchor = jnp.clip(refs.ref_idx(M.i2ref(rf[:, M.F_REF1])), 0, cap - 1)

        def wcond(c):
            idx, steps, done = c
            return (~jnp.all(done)) & (steps < cfg.max_scan)

        def wbody(c):
            idx, steps, done = c
            hit = (pool.sid[idx] == psid) & (pool.ts[idx] == pts)
            at_end = (pool.key[idx] == ST_KEY) | \
                (refs.is_null(pool.nxt[idx]) & ~hit)
            nxt = jnp.clip(refs.ref_idx(refs.unmarked(pool.nxt[idx])),
                           0, cap - 1)
            idx = jnp.where(done | hit | at_end, idx, nxt)
            return idx, steps + 1, done | hit | at_end

        hit0 = (pool.sid[anchor] == psid) & (pool.ts[anchor] == pts)
        widx, _, _ = jax.lax.while_loop(
            wcond, wbody, (anchor, jnp.zeros((), jnp.int32), hit0 | ~live))
        found = (pool.sid[widx] == psid) & (pool.ts[widx] == pts)

        # ---- per-run aggregates (segments of the lane axis)
        pos = jnp.arange(k, dtype=jnp.int32)
        lead = jnp.clip(jax.ops.segment_min(pos, sid_g, num_segments=k),
                        0, k - 1)
        lastp = jnp.clip(jax.ops.segment_max(pos, sid_g, num_segments=k),
                         0, k - 1)
        lead_lane = lead[sid_g]                  # run head lane, per lane
        prev_copy = widx[lead_lane]
        seg_found = found[lead_lane]
        seg_cont = jax.ops.segment_min(cont.astype(jnp.int32), sid_g,
                                       num_segments=k)[sid_g] > 0
        seg_no_st = jax.ops.segment_min(no_st.astype(jnp.int32), sid_g,
                                        num_segments=k)[sid_g] > 0

        # splice point: prev_copy's successor must be the SubTail or older
        # than every comp_ts of the run (else serial replay would walk
        # past it — bounce)
        old_word = pool.nxt[prev_copy]
        old_ref = refs.unmarked(old_word)
        old_local = (~refs.is_null(old_ref)) & (refs.ref_sid(old_ref) == me)
        old_idx = jnp.clip(refs.ref_idx(old_ref), 0, cap - 1)
        min_comp = jax.ops.segment_min(
            jnp.where(live, pts, big), sid_g, num_segments=k)[sid_g]
        splice_ok = old_local & ((pool.key[old_idx] == ST_KEY)
                                 | (pool.ts[old_idx] < min_comp))

        elig = live & seg_found & seg_cont & seg_no_st & splice_ok

        # distinct-splice screen: two runs claiming one predecessor copy
        # would make the relink scatter order-dependent — claimed entries
        # are disjoint, so this never fires in healthy rounds; bounce both
        # if it somehow does
        is_head = start_any & live
        claim = jnp.where(elig & is_head, prev_copy,
                          cap + jnp.arange(k, dtype=jnp.int32))
        sc = jnp.sort(claim)
        dup = (jnp.searchsorted(sc, claim, side="right")
               - jnp.searchsorted(sc, claim, side="left")) >= 2
        seg_dup = jax.ops.segment_max(dup.astype(jnp.int32), sid_g,
                                      num_segments=k)[sid_g] > 0
        elig = elig & (~seg_dup)

        # allocator pressure: bounce wholesale near the edge — the serial
        # path owns RES_POOLFULL / retry semantics
        room = state.free_top + (cap - state.alloc_top)
        n_want = jnp.sum(elig.astype(jnp.int32))
        elig = elig & ((n_want + _ALLOC_HEADROOM) <= room)

        # ---- batched alloc + one splice scatter
        new_idx, _, _, free_top2, alloc_top2 = batched_alloc(state, elig)
        marked = (rf[:, M.F_A] & FL_MARKED) != 0
        is_last = pos == lastp[sid_g]
        next_new = jnp.concatenate([new_idx[1:], new_idx[:1]])
        succ_ref = jnp.where(is_last, old_ref,
                             refs.make_ref(me, next_new))
        node_nxt = refs.with_mark(succ_ref, marked)

        drop = cap
        at = jnp.where(elig, new_idx, drop)
        pool2 = pool._replace(
            key=pool.key.at[at].set(rf[:, M.F_KEY], mode="drop"),
            ts=pool.ts.at[at].set(its, mode="drop"),
            sid=pool.sid.at[at].set(isid, mode="drop"),
            ctr=pool.ctr.at[at].set(pool.ctr[prev_copy], mode="drop"),
            newloc=pool.newloc.at[at].set(refs.null_ref(), mode="drop"),
            keymax=pool.keymax.at[at].set(rf[:, M.F_VAL], mode="drop"),
        )
        nxt = pool2.nxt.at[at].set(node_nxt, mode="drop")
        # relink each run's predecessor copy, preserving its own mark
        head_at = jnp.where(elig & is_head, prev_copy, drop)
        prev_mark = old_word & jnp.uint32(refs.MARK_BIT)
        nxt = nxt.at[head_at].set(refs.make_ref(me, new_idx) | prev_mark,
                                  mode="drop")
        pool2 = pool2._replace(nxt=nxt)

        # §8 Lamport bump past everything absorbed
        max_ts = jnp.max(jnp.where(elig, its, jnp.iinfo(jnp.int32).min))
        clock2 = jnp.maximum(state.ts_clock, max_ts + 1)

        # packed-block compaction point (DESIGN.md §12): the splice grows
        # clone chains that are not registered entries yet, so no valid
        # block row can mirror them — but the scatter touches the shared
        # pool, and attribution is per-run, not per-entry; drop the whole
        # mirror (shard_round's blanket rule would too — this keeps the
        # invariant local to the writer).
        any_spliced = jnp.any(elig)
        st2 = state._replace(pool=pool2, free_top=free_top2,
                             alloc_top=alloc_top2, ts_clock=clock2,
                             blk=state.blk._replace(
                                 valid=jnp.where(any_spliced,
                                                 jnp.zeros_like(
                                                     state.blk.valid),
                                                 state.blk.valid)))

        # ---- acks, in lane (channel) order
        def push_ack(i, oc):
            ob, ct = oc
            ack = M.make_row(
                M.MSG_MOVE_ACK, rf[i, M.F_SRC], me,
                ref1=M.ref2i(refs.make_ref(me, new_idx[i])),
                sid=isid[i], ts=its[i], x1=rf[i, M.F_X1], a=rf[i, M.F_A],
                slot=rf[i, M.F_SLOT])
            return M.push(ob, ct, ack, elig[i])

        ob2, ct2 = jax.lax.fori_loop(0, k, push_ack, (outbox, count))

        handled_sel = jnp.zeros((k,), bool).at[s2].set(elig)
        handled = zb.at[sel].set(handled_sel)
        return st2, handled, ob2, ct2

    def skip(_):
        return state, zb, outbox, count

    st, handled, ob, ct = jax.lax.cond(gate, run, skip, None)
    return ReplayOut(state=st, handled=handled, outbox=ob, count=ct)
