"""Merge phases (Appendix B, Alg. 7): fold the right sublist into the left."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ... import messages as M
from ... import refs, registry as reg_ops
from ...types import ST_KEY
from .. import util as U
from ..fsm import BG_IDLE, BG_MERGE_WAIT


def merge_exec(state, bg, me, slot_id, outbox, count, cfg):
    """Merge steps 1-3: neutralize the mid block, link around it."""
    reg = state.registry
    le = U.entry_by_keymax(reg, bg.entry_key)      # left entry
    re_ = U.entry_by_keymax(reg, bg.merge_key)     # right entry
    lidx, ridx = jnp.clip(le, 0, None), jnp.clip(re_, 0, None)
    pool = state.pool
    n = pool.key.shape[0]
    lslot, rslot = reg.ctr[lidx], reg.ctr[ridx]
    valid = (le >= 0) & (re_ >= 0) & \
        (reg.keymax[lidx] == reg.keymin[ridx]) & \
        (refs.ref_sid(reg.subhead[lidx]) == me) & \
        (refs.ref_sid(reg.subhead[ridx]) == me) & \
        (state.stct[lslot] >= 0) & (state.stct[rslot] >= 0)

    key_mid = reg.keymax[lidx]
    mid_st = refs.ref_idx(reg.subtail[lidx])      # the block to neutralize
    right_sh = refs.ref_idx(reg.subhead[ridx])
    right_st_ref = reg.subtail[ridx]
    old_off_sum = reg.offset[lidx] + reg.offset[ridx]

    # Line 335: neutralize the mid SubTail so traversals cross it
    pool = pool._replace(
        keymax=U.set_at(pool.keymax, mid_st, reg.keymin[lidx], valid))

    # Lines 341-344: repoint the right half's counter slots to the left's
    def cond(c):
        ctr_col, idx, steps, done = c
        return (~done) & (steps < cfg.max_scan)

    def body(c):
        ctr_col, idx, steps, _ = c
        ctr_col = ctr_col.at[idx].set(lslot)
        at_st = pool.key[idx] == ST_KEY
        nxt = jnp.clip(refs.ref_idx(refs.unmarked(pool.nxt[idx])), 0, n - 1)
        return ctr_col, jnp.where(at_st, idx, nxt), steps + 1, at_st

    ctr_col, _, _, _ = jax.lax.while_loop(
        cond, body, (pool.ctr, jnp.clip(right_sh, 0, n - 1),
                     jnp.zeros((), jnp.int32), jnp.asarray(False)))
    pool = pool._replace(ctr=jnp.where(valid, ctr_col, pool.ctr))

    # Lines 346-352 (RDCSS): link leftLast directly to rightFirst. The mid
    # ST-SH block stays quarantined as a forwarder for stale delegations
    # (its nxt chain still reaches the merged items).
    def find_last(c):
        idx, steps = c
        nxt_ref = refs.unmarked(pool.nxt[idx])
        nxt = jnp.clip(refs.ref_idx(nxt_ref), 0, n - 1)
        at_last = nxt == mid_st
        return jnp.where(at_last, idx, nxt), steps + 1

    def not_last(c):
        idx, steps = c
        nxt = refs.ref_idx(refs.unmarked(pool.nxt[idx]))
        return (nxt != mid_st) & (steps < cfg.max_scan)

    left_sh = jnp.clip(refs.ref_idx(reg.subhead[lidx]), 0, n - 1)
    left_last, _ = jax.lax.while_loop(
        not_last, find_last, (left_sh, jnp.zeros((), jnp.int32)))
    right_first = refs.unmarked(pool.nxt[jnp.clip(right_sh, 0, n - 1)])
    ll_mark = pool.nxt[left_last] & jnp.uint32(refs.MARK_BIT)
    pool = pool._replace(
        nxt=U.set_at(pool.nxt, left_last, right_first | ll_mark, valid))
    state = state._replace(pool=pool)

    # Lines 336-338: extend the left entry, drop the right entry (local COW)
    new_reg = reg_ops.remove_entry(
        reg_ops.set_fields(reg, lidx, keymax=reg.keymax[ridx],
                           subtail=right_st_ref),
        ridx)
    state = state._replace(registry=jax.tree_util.tree_map(
        lambda a, b: jnp.where(valid, b, a), reg, new_reg))
    # packed-block compaction point (DESIGN.md §12): the relink changed
    # the left chain AND remove_entry shifted entry indexing — drop the
    # whole entry-indexed mirror.
    state = state._replace(blk=state.blk._replace(
        valid=jnp.where(valid, jnp.zeros_like(state.blk.valid),
                        state.blk.valid)))

    bg = bg._replace(
        phase=jnp.where(valid, BG_MERGE_WAIT, BG_IDLE),
        entry_key=jnp.where(valid, bg.merge_key, bg.entry_key),
        split_key=jnp.where(valid, key_mid, bg.split_key),
        old_slot=jnp.where(valid, lslot, bg.old_slot),
        new_slot=jnp.where(valid, rslot, bg.new_slot),
        old_keymax=jnp.where(valid, old_off_sum, bg.old_keymax))
    return state, bg, outbox, count


def merge_wait(state, bg, me, slot_id, outbox, count, cfg):
    """Alg. 7 Lines 353-358: offset stabilization + broadcast."""
    a1 = state.stct[bg.old_slot] - state.endct[bg.old_slot]
    a2 = state.stct[bg.new_slot] - state.endct[bg.new_slot]
    stable = (a1 + a2) == bg.old_keymax
    reg = state.registry
    e = U.entry_by_keymax(reg, bg.entry_key)
    eidx = jnp.clip(e, 0, None)
    new_reg = reg_ops.set_fields(reg, eidx, offset=a1)
    state = state._replace(registry=jax.tree_util.tree_map(
        lambda a, b: jnp.where(stable & (e >= 0), b, a), reg, new_reg))

    row = M.make_row(M.MSG_REG_MERGED, 0, me, key=bg.split_key,
                     x1=bg.entry_key)

    def send(i, oc):
        ob, ct = oc
        # peer-mask fan-out gate (DESIGN.md §13); merges are owner-local,
        # so skipping a retired peer only leaves its replica stale
        live = ((state.peers >> i) & 1) != 0
        return M.push(ob, ct, row.at[M.F_DST].set(i),
                      stable & (i != me) & live)

    outbox, count = jax.lax.fori_loop(0, cfg.num_shards, send,
                                      (outbox, count))
    bg = bg._replace(phase=jnp.where(stable, BG_IDLE, bg.phase))
    return state, bg, outbox, count
