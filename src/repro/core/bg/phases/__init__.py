"""Per-phase step functions of the background FSM (one module per op).

Every phase function shares the signature::

    (state, bg, me, slot_id, outbox, count, cfg) ->
        (state, bg, outbox, count)

``bg`` is one slot's scalar-leaf ``BgState``; ``slot_id`` is the slot's
index in the shard's ``BgTable``, stamped into outgoing move/switch
messages so their acks come back to the right slot.
"""
from . import merge, move, split  # noqa: F401
