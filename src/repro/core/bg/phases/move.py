"""Move phases (§5.4 + Alg. 5): MoveSH, the pipelined batched copy,
stabilization, Switch, and quarantine.

The copy phase is *pipelined* (DESIGN.md §10): instead of waiting for the
previous batch's acks before sending the next (the seed's behaviour, ~2
rounds per batch), the source keeps two cursors —

* ``send_prev``: the last chain node handed to the fabric. Each round it
  advances over the next chain-contiguous run of up to ``cfg.move_batch``
  un-replicated items, emitting one ``MSG_MOVE_ITEMS`` row per item
  without awaiting acks, so an n-item sublist crosses in ceil(n/K) + O(1)
  rounds.
* ``cursor``: the acked-prefix cursor, advanced only over the contiguous
  prefix of items whose ``newLoc`` is known — the safety anchor. Racing
  inserts can land *behind* ``send_prev`` with a null newLoc (their left
  was sent but not acked, so they neither self-replicate nor get picked
  up by the forward walk); they are exactly the nodes a re-walk from
  ``cursor`` finds once the pipeline drains (sent == acked), so the walk
  restarts there and ships the stragglers.

The SubTail is sent only when the walk from ``cursor`` reaches it
directly with nothing in flight — then every chain node before ST has a
newLoc, every concurrent update replicates (its left's newLoc is set),
and no item can be missed: the same invariant the seed's stop-and-wait
loop enforced, reached in O(1) extra rounds instead of O(n/K) ack waits.

``sent``/``acked`` accounting assumes each MSG_MOVE_ITEMS row produces
exactly one MOVE_ACK: under a lossy wire the reliable transport
(core/net, DESIGN.md §11) retransmits lost rows and dedups duplicated
acks, so the drained test (``sent == acked``) stays exact — a dropped
ack cannot wedge the pipeline and a duplicated one cannot let the ST
ship early.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ... import blocks as BL
from ... import messages as M
from ... import refs, registry as reg_ops
from ...types import NEG_INF_CT, SH_KEY, ST_KEY
from .. import util as U
from ..fsm import (BG_IDLE, BG_MOVE_SH_WAIT, BG_MOVE_STABLE, BG_QUAR,
                   BG_SWITCH_REG, BG_SWITCH_ST, BG_SWITCH_ST_WAIT,
                   FL_MARKED, FL_ST)


def move_sh(state, bg, me, slot_id, outbox, count, cfg):
    reg = state.registry
    e = U.entry_by_keymax(reg, bg.entry_key)
    eidx = jnp.clip(e, 0, None)
    ok = (e >= 0) & (refs.ref_sid(reg.subhead[eidx]) == me) & \
        (bg.target != me)
    head_idx = refs.ref_idx(reg.subhead[eidx])
    row = M.make_row(M.MSG_MOVE_SH, bg.target, me,
                     key=reg.keymin[eidx], x1=reg.keymax[eidx],
                     sid=state.pool.sid[head_idx],
                     ts=state.pool.ts[head_idx], slot=slot_id)
    outbox, count = M.push(outbox, count, row, ok)
    # packed-block compaction point (DESIGN.md §12): the entry is about to
    # start moving (items gain newLoc as copies land) — drop its block now
    # so no block probe answers a lane the serial path would treat as
    # moving; the row stays invalid until after the Switch (the rebuild
    # rejects moving/switched chains).
    state = state._replace(blk=BL.invalidate_entry(state.blk, eidx, ok))
    bg = bg._replace(
        phase=jnp.where(ok, BG_MOVE_SH_WAIT, BG_IDLE),
        old_head=jnp.where(ok, head_idx, bg.old_head))
    return state, bg, outbox, count


def move_copy(state, bg, me, slot_id, outbox, count, cfg):
    """One round of the pipelined copy (module docstring)."""
    pool = state.pool
    n = pool.key.shape[0]
    active = bg.st_sent == 0

    # 1. advance the acked-prefix cursor over items with a known newLoc
    def adv_cond(c):
        cur, steps = c
        nxt = jnp.clip(refs.ref_idx(refs.unmarked(pool.nxt[cur])), 0, n - 1)
        ok = (~refs.is_null(pool.newloc[nxt])) & (pool.key[nxt] != ST_KEY)
        return active & ok & (steps < cfg.max_scan)

    def adv_body(c):
        cur, steps = c
        nxt = jnp.clip(refs.ref_idx(refs.unmarked(pool.nxt[cur])), 0, n - 1)
        return nxt, steps + 1

    cursor, _ = jax.lax.while_loop(adv_cond, adv_body,
                                   (bg.cursor, jnp.zeros((), jnp.int32)))
    anchor = refs.unmarked(pool.newloc[cursor])
    drained = bg.sent == bg.acked

    # 2. ship the next chain-contiguous run of un-replicated items. The
    # run ends at the first newLoc'd node (contiguity is what lets the
    # target replay the whole run in one scatter splice) or at ST.
    def body(_, c):
        outbox, count, prev, sent, stop = c
        curr = jnp.clip(refs.ref_idx(refs.unmarked(pool.nxt[prev])),
                        0, n - 1)
        is_st = pool.key[curr] == ST_KEY
        has_newloc = ~refs.is_null(pool.newloc[curr])
        send = active & (~stop) & (~is_st) & (~has_newloc)
        flags = refs.ref_mark(pool.nxt[curr]).astype(jnp.int32) * FL_MARKED
        row = M.make_row(
            M.MSG_MOVE_ITEMS, bg.target, me, a=flags, key=pool.key[curr],
            ref1=M.ref2i(anchor), sid=pool.sid[curr], ts=pool.ts[curr],
            x1=curr, x2=pool.sid[prev], x3=pool.ts[prev],
            x4=M.ref2i(refs.unmarked(pool.nxt[curr])),
            val=pool.keymax[curr], slot=slot_id)
        outbox, count = M.push(outbox, count, row, send)
        sent = sent + send.astype(jnp.int32)
        stop = stop | is_st | has_newloc
        prev = jnp.where(send, curr, prev)
        return outbox, count, prev, sent, stop

    outbox, count, run_prev, nsent, _ = jax.lax.fori_loop(
        0, cfg.move_batch, body,
        (outbox, count, bg.send_prev, jnp.zeros((), jnp.int32),
         jnp.asarray(False)))

    # 3. nothing to send and nothing in flight: either the whole chain is
    # replicated (walk from the acked-prefix cursor meets ST directly —
    # ship the SubTail) or the forward walk is past stragglers/newLoc'd
    # nodes — restart it from the cursor.
    first_next = jnp.clip(refs.ref_idx(refs.unmarked(pool.nxt[bg.send_prev])),
                          0, n - 1)
    at_end = active & (nsent == 0) & drained
    send_st = at_end & (pool.key[first_next] == ST_KEY) & \
        (bg.send_prev == cursor)
    restart = at_end & (~send_st)

    st_idx = first_next
    st_flags = (refs.ref_mark(pool.nxt[st_idx]).astype(jnp.int32) * FL_MARKED
                + FL_ST)
    st_row = M.make_row(
        M.MSG_MOVE_ITEM, bg.target, me, a=st_flags,
        key=pool.keymax[st_idx], ref1=M.ref2i(anchor),
        sid=pool.sid[st_idx], ts=pool.ts[st_idx],
        x1=st_idx, x2=pool.sid[cursor], x3=pool.ts[cursor],
        x4=M.ref2i(refs.unmarked(pool.nxt[st_idx])),
        val=pool.keymax[st_idx], slot=slot_id)
    outbox, count = M.push(outbox, count, st_row, send_st)

    bg = bg._replace(
        cursor=jnp.where(active, cursor, bg.cursor),
        send_prev=jnp.where(restart, cursor,
                            jnp.where(active, run_prev, bg.send_prev)),
        sent=bg.sent + nsent + send_st.astype(jnp.int32),
        st_sent=jnp.where(send_st, 1, bg.st_sent),
        phase=jnp.where((bg.st_acked != 0) & (bg.sent == bg.acked),
                        BG_MOVE_STABLE, bg.phase))
    return state, bg, outbox, count


def move_stable(state, bg, me, slot_id, outbox, count, cfg):
    """Line 202-204: CAS stCt := -inf once both copies are provably equal."""
    reg = state.registry
    e = U.entry_by_keymax(reg, bg.entry_key)
    eidx = jnp.clip(e, 0, None)
    slot = reg.ctr[eidx]
    quiet = (e >= 0) & \
        (state.stct[slot] == state.endct[slot] + reg.offset[eidx])
    state = state._replace(
        stct=jnp.where(quiet, state.stct.at[slot].set(NEG_INF_CT),
                       state.stct))
    bg = bg._replace(phase=jnp.where(quiet, BG_SWITCH_ST, bg.phase))
    return state, bg, outbox, count


def switch_st_phase(state, bg, me, slot_id, outbox, count, cfg):
    """Alg. 5 Lines 269-280: repoint the previous sublist's SubTail."""
    reg = state.registry
    e = U.entry_by_keymax(reg, bg.entry_key)
    eidx = jnp.clip(e, 0, None)
    keymin = reg.keymin[eidx]
    no_left = keymin <= SH_KEY
    left = U.cover(reg, keymin)
    lidx = jnp.clip(left, 0, None)
    left_owner = refs.ref_sid(reg.subhead[lidx])
    local = (~no_left) & (left >= 0) & (left_owner == me)
    remote = (~no_left) & (left >= 0) & (left_owner != me)

    st2, ok = U.switch_next_st(state, me, keymin, bg.sh_star)
    state = jax.tree_util.tree_map(
        lambda a, b: jnp.where(local, b, a), state, st2)

    row = M.make_row(M.MSG_SWITCH_ST, left_owner, me, key=keymin,
                     ref1=M.ref2i(bg.sh_star), slot=slot_id)
    outbox, count = M.push(outbox, count, row, remote)

    next_phase = jnp.where(
        no_left | (local & ok), BG_SWITCH_REG,
        jnp.where(remote, BG_SWITCH_ST_WAIT, bg.phase))
    bg = bg._replace(phase=next_phase)
    return state, bg, outbox, count


def switch_reg(state, bg, me, slot_id, outbox, count, cfg):
    """Alg. 5 Lines 281-284: update own registry, broadcast SwitchServer."""
    reg = state.registry
    e = U.entry_by_keymax(reg, bg.entry_key)
    eidx = jnp.clip(e, 0, None)
    keymin = reg.keymin[eidx]
    new_reg = reg_ops.set_fields(reg, eidx, subhead=bg.sh_star,
                                 subtail=bg.st_star, ctr=0, offset=0)
    state = state._replace(registry=jax.tree_util.tree_map(
        lambda a, b: jnp.where(e >= 0, b, a), reg, new_reg))

    row = M.make_row(M.MSG_SWITCH_SERVER, 0, me, key=keymin,
                     x1=bg.entry_key, ref1=M.ref2i(bg.sh_star),
                     x3=M.ref2i(bg.st_star))

    def send(i, oc):
        ob, ct = oc
        # peer-mask fan-out gate (DESIGN.md §13) — except the move target,
        # which must always learn the transfer even if this shard's mask
        # is stale (the host validated the target against live membership
        # when it queued the move; skipping it would strand ownership)
        live = (((state.peers >> i) & 1) != 0) | (i == bg.target)
        return M.push(ob, ct, row.at[M.F_DST].set(i),
                      (e >= 0) & (i != me) & live)

    outbox, count = jax.lax.fori_loop(0, cfg.num_shards, send,
                                      (outbox, count))
    bg = bg._replace(phase=BG_QUAR, quar_round=bg.round)
    return state, bg, outbox, count


def quarantine(state, bg, me, slot_id, outbox, count, cfg):
    """Free the stale source chain (interior only — the old SubHead keeps
    forwarding via newLoc; the epoch-based analogue of hazard pointers)."""
    due = bg.round - bg.quar_round >= cfg.quarantine_rounds
    pool = state.pool
    n = pool.key.shape[0]

    def cond(c):
        flist, ftop, idx, steps, done = c
        return due & (~done) & (steps < cfg.max_scan)

    def body(c):
        flist, ftop, idx, steps, _ = c
        at_st = pool.key[idx] == ST_KEY
        pos = jnp.clip(ftop, 0, flist.shape[0] - 1)
        flist = flist.at[pos].set(idx)
        ftop = ftop + 1
        nxt = jnp.clip(refs.ref_idx(refs.unmarked(pool.nxt[idx])), 0, n - 1)
        return flist, ftop, nxt, steps + 1, at_st

    start = jnp.clip(refs.ref_idx(refs.unmarked(pool.nxt[bg.old_head])),
                     0, n - 1)
    flist, ftop, _, _, _ = jax.lax.while_loop(
        cond, body,
        (state.free_list, state.free_top, start,
         jnp.zeros((), jnp.int32), jnp.asarray(False)))
    state = state._replace(
        free_list=jnp.where(due, flist, state.free_list),
        free_top=jnp.where(due, ftop, state.free_top))
    bg = bg._replace(phase=jnp.where(due, BG_IDLE, bg.phase))
    return state, bg, outbox, count
