"""Split phases (§5.3): insert the ST-SH block, stabilize, register."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ... import blocks as BL
from ... import messages as M
from ... import refs, registry as reg_ops
from ...types import SH_KEY, ST_KEY
from .. import util as U
from ..fsm import BG_IDLE, BG_SPLIT_WAIT


def split_exec(state, bg, me, slot_id, outbox, count, cfg):
    """Split steps 1-3 (§5.3): insert the ST-SH block, repoint counters."""
    reg = state.registry
    e = U.entry_by_keymax(reg, bg.entry_key)
    eidx = jnp.clip(e, 0, None)
    sitem = jnp.clip(bg.sitem, 0, state.pool.key.shape[0] - 1)
    sitem_key = state.pool.key[sitem]
    valid = (e >= 0) & (refs.ref_sid(reg.subhead[eidx]) == me) & \
        (~refs.ref_mark(state.pool.nxt[sitem])) & \
        (state.pool.ctr[sitem] == reg.ctr[eidx]) & \
        (sitem_key > reg.keymin[eidx]) & (sitem_key < reg.keymax[eidx]) & \
        (state.pool.key[sitem] != SH_KEY) & (state.pool.key[sitem] != ST_KEY)

    new_slot = state.ctr_top
    slot_ok = new_slot < state.stct.shape[0]
    old_slot = reg.ctr[eidx]

    state2 = state._replace(ctr_top=new_slot + 1)
    state2, st_idx, ok1 = U.alloc_node(state2)
    state2, sh_idx, ok2 = U.alloc_node(state2)
    ok = valid & slot_ok & ok1 & ok2

    pool = state2.pool
    old_next = pool.nxt[sitem]          # unmarked by ``valid``
    ts1 = state2.ts_clock
    pool = pool._replace(
        key=U.set_at(U.set_at(pool.key, st_idx, ST_KEY, ok), sh_idx, SH_KEY,
                     ok),
        keymax=U.set_at(pool.keymax, st_idx, sitem_key, ok),
        ctr=U.set_at(U.set_at(pool.ctr, st_idx, old_slot, ok), sh_idx,
                     new_slot, ok),
        sid=U.set_at(U.set_at(pool.sid, st_idx, me, ok), sh_idx, me, ok),
        ts=U.set_at(U.set_at(pool.ts, st_idx, ts1, ok), sh_idx, ts1 + 1, ok),
        newloc=U.set_at(U.set_at(pool.newloc, st_idx, refs.null_ref(), ok),
                        sh_idx, refs.null_ref(), ok),
    )
    # ST -> SH -> old next; then CAS sItem.next := ST (Lines 131-139)
    pool = pool._replace(nxt=U.set_at(pool.nxt, sh_idx, old_next, ok))
    pool = pool._replace(
        nxt=U.set_at(pool.nxt, st_idx, refs.make_ref(me, sh_idx), ok))
    pool = pool._replace(
        nxt=U.set_at(pool.nxt, sitem, refs.make_ref(me, st_idx), ok))
    state2 = state2._replace(pool=pool, ts_clock=ts1 + 2)

    # repoint counter pointers of the right half (Lines 140-146),
    # old-subtail included
    n = pool.key.shape[0]

    def cond2(c):
        ctr_col, idx, steps, done = c
        return (~done) & (steps < cfg.max_scan)

    def body2(c):
        ctr_col, idx, steps, _ = c
        ctr_col = ctr_col.at[idx].set(new_slot)
        at_st = pool.key[idx] == ST_KEY
        nxt = jnp.clip(refs.ref_idx(refs.unmarked(pool.nxt[idx])), 0, n - 1)
        return ctr_col, jnp.where(at_st, idx, nxt), steps + 1, at_st

    start = jnp.clip(refs.ref_idx(refs.unmarked(old_next)), 0, n - 1)
    ctr_col, _, _, _ = jax.lax.while_loop(
        cond2, body2,
        (state2.pool.ctr, start, jnp.zeros((), jnp.int32),
         jnp.asarray(False)))
    state2 = state2._replace(pool=state2.pool._replace(
        ctr=jnp.where(ok, ctr_col, state2.pool.ctr)))
    # packed-block compaction point (DESIGN.md §12): the mid ST-SH block
    # now sits inside entry e's chain, so its packed mirror is stale; the
    # row stays invalid until split_wait lands the registry update (the
    # rebuild's subtail-identity check rejects the mid-split chain).
    state2 = state2._replace(blk=BL.invalidate_entry(state2.blk, eidx))

    state = jax.tree_util.tree_map(
        lambda a, b: jnp.where(ok, b, a), state, state2)
    bg = bg._replace(
        phase=jnp.where(ok, BG_SPLIT_WAIT, BG_IDLE),
        new_slot=jnp.where(ok, new_slot, bg.new_slot),
        old_slot=jnp.where(ok, old_slot, bg.old_slot),
        split_key=jnp.where(ok, sitem_key, bg.split_key),
        sh_new=jnp.where(ok, sh_idx, bg.sh_new),
        st_new=jnp.where(ok, st_idx, bg.st_new),
        old_keymax=jnp.where(ok, reg.keymax[eidx], bg.old_keymax))
    return state, bg, outbox, count


def split_wait(state, bg, me, slot_id, outbox, count, cfg):
    """Split step 4 (Lines 147-157): offset stabilization + registry COW."""
    reg = state.registry
    e = U.entry_by_keymax(reg, bg.entry_key)
    eidx = jnp.clip(e, 0, None)
    a1 = state.stct[bg.new_slot] - state.endct[bg.new_slot]
    a2 = state.stct[bg.old_slot] - state.endct[bg.old_slot]
    stable = (e >= 0) & (a1 + a2 == reg.offset[eidx]) & \
        (reg.size < reg.keymin.shape[0])

    old_subtail = reg.subtail[eidx]
    sh_ref = refs.make_ref(me, bg.sh_new)
    st_ref = refs.make_ref(me, bg.st_new)
    new_reg = reg_ops.add_entry(
        reg_ops.set_fields(reg, eidx, keymax=bg.split_key, subtail=st_ref,
                           offset=a2),
        bg.split_key, bg.old_keymax, sh_ref, old_subtail, bg.new_slot, a1)
    state = state._replace(registry=jax.tree_util.tree_map(
        lambda a, b: jnp.where(stable, b, a), reg, new_reg))
    # add_entry shifts every entry index at/after the insertion point —
    # blocks are entry-indexed, so the whole mirror drops (DESIGN.md §12)
    state = state._replace(blk=state.blk._replace(
        valid=jnp.where(stable, jnp.zeros_like(state.blk.valid),
                        state.blk.valid)))

    row = M.make_row(M.MSG_REG_SPLIT, 0, me, key=bg.split_key,
                     x1=bg.old_keymax, ref1=M.ref2i(sh_ref))

    def send(i, oc):
        ob, ct = oc
        r = row.at[M.F_DST].set(i)
        # fan-out gated on the live-peer bitmask (DESIGN.md §13): retired
        # shards drop out of registry replication without a recompile; a
        # stale mask only costs a later peer a stale replica, which the
        # lazily-replicated registry tolerates by design
        live = ((state.peers >> i) & 1) != 0
        return M.push(ob, ct, r, stable & (i != me) & live)

    outbox, count = jax.lax.fori_loop(0, cfg.num_shards, send,
                                      (outbox, count))
    bg = bg._replace(phase=jnp.where(stable, BG_IDLE, bg.phase))
    return state, bg, outbox, count
