"""Message handlers of the background protocol (§5.3-5.4, Alg. 5-7).

All handlers share one signature::

    (state, table, me, row, outbox, count, cfg) ->
        (state, table, outbox, count)

``table`` is the shard's slotted ``BgTable``. Handlers that complete a
request issued by a background slot (MOVE_SH_ACK, MOVE_ACK,
SWITCH_ST_ACK) address the slot named by the row's ``F_SLOT`` lane — the
request carried it out, the ack echoes it back — so concurrent ops on one
shard never credit each other's progress. Replicate/registry handlers
(RepInsert/RepDelete/Reg*) never touch the table.

Delivery contract: handlers assume exactly-once, per-(src,dst)-FIFO
message delivery. Several are *not* duplicate-safe (the endCt bumps in
h_ack_insert/h_ack_delete, the acked cursor in h_move_ack, whose Line-210
race check could fire a spurious RepDelete at a live copy if re-run after
the move completes) — under a lossy wire that contract is provided by the
reliable transport's dedup window (core/net, DESIGN.md §11), which is why
none of them need defensive re-delivery guards of their own.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import messages as M
from .. import refs, registry as reg_ops
from ..types import SH_KEY, ST_KEY
from . import util as U
from .fsm import (BG_IDLE, BG_MOVE_COPY, BG_MOVE_SH_WAIT, BG_SWITCH_REG,
                  BG_SWITCH_ST, BG_SWITCH_ST_WAIT, FL_MARKED, FL_ST,
                  slot_view)


def _row_slot(table, row):
    """Bg slot a move/switch ack addresses (clipped against the table)."""
    return jnp.clip(row[M.F_SLOT], 0, table.phase.shape[0] - 1)


def _set_slot_where(table, j, good, **updates):
    """Apply per-field updates to slot ``j`` when ``good`` (traced)."""
    def one(col, new):
        return col.at[j].set(jnp.where(good, new, col[j]))
    return table._replace(**{k: one(getattr(table, k), v)
                             for k, v in updates.items()})


def h_rep_insert(state, table, me, row, outbox, count, cfg):
    """RepInsertAfterRecv (Lines 226-231)."""
    anchor = refs.ref_idx(M.i2ref(row[M.F_REF1]))
    prev_sid, prev_ts = row[M.F_X2], row[M.F_X3]
    item_sid, item_ts = row[M.F_SID], row[M.F_TS]
    key, oldloc, slot = row[M.F_KEY], row[M.F_X1], row[M.F_X4]

    prev_idx, found = U.find_by_identity(state, anchor, prev_sid, prev_ts,
                                         cfg.max_scan)
    st2, new_idx, ok = U.replay_insert(
        state, me, prev_idx, item_ts, key, item_sid, item_ts,
        jnp.asarray(False), cfg, value=row[M.F_VAL])
    apply_it = found & ok
    state = jax.tree_util.tree_map(
        lambda a, b: jnp.where(apply_it, b, a), state, st2)

    ack = M.make_row(M.MSG_ACK_INSERT, row[M.F_SRC], me,
                     ref1=M.ref2i(refs.make_ref(me, new_idx)),
                     sid=item_sid, ts=item_ts, x1=oldloc, x4=slot)
    outbox, count = M.push(outbox, count, ack, apply_it)
    # prev's copy not here yet (out-of-order delivery): retry next round.
    retry_row = row.at[M.F_A].set(row[M.F_A] + 1)
    retry_row = retry_row.at[M.F_DST].set(me)
    outbox, count = M.push(outbox, count, retry_row,
                           (~apply_it) & (row[M.F_A] < cfg.max_retries))
    return state, table, outbox, count


def h_rep_delete(state, table, me, row, outbox, count, cfg):
    """RepDeleteRecv (Lines 232-239)."""
    anchor = refs.ref_idx(M.i2ref(row[M.F_REF1]))
    item_sid, item_ts = row[M.F_SID], row[M.F_TS]
    oldloc, slot = row[M.F_X1], row[M.F_X4]
    need_ack = row[M.F_X2] != 0

    idx, found = U.find_by_identity(state, anchor, item_sid, item_ts,
                                    cfg.max_scan)
    state = state._replace(pool=state.pool._replace(
        nxt=U.set_at(state.pool.nxt, idx, refs.with_mark(state.pool.nxt[idx]),
                     found)))
    ack = M.make_row(M.MSG_ACK_DELETE, row[M.F_SRC], me, x1=oldloc, x4=slot)
    outbox, count = M.push(outbox, count, ack, found & need_ack)
    retry_row = row.at[M.F_A].set(row[M.F_A] + 1)
    retry_row = retry_row.at[M.F_DST].set(me)
    outbox, count = M.push(outbox, count, retry_row,
                           (~found) & (row[M.F_A] < cfg.max_retries))
    return state, table, outbox, count


def h_ack_insert(state, table, me, row, outbox, count, cfg):
    """InsertReplayResponseRecv (Lines 263-265).

    No marked-while-in-flight race catch is needed here (unlike
    h_move_ack's Line 210): an item awaiting this ack was born with its
    left's non-null newLoc (ops.py Line 189), so a remove racing the
    replay sees node_moving and sends its own RepDelete — whose pair-FIFO
    channel guarantees it arrives after the replay it chases.
    """
    oldloc, slot = row[M.F_X1], row[M.F_X4]
    sid, ts = row[M.F_SID], row[M.F_TS]
    same = (state.pool.sid[oldloc] == sid) & (state.pool.ts[oldloc] == ts)
    state = state._replace(pool=state.pool._replace(
        newloc=U.set_at(state.pool.newloc, oldloc, M.i2ref(row[M.F_REF1]),
                        same)))
    # the deferred endCt increment always lands (balances the op's stCt++)
    state = state._replace(endct=state.endct.at[slot].add(1))
    return state, table, outbox, count


def h_ack_delete(state, table, me, row, outbox, count, cfg):
    """RemoveReplayResponseRecv (Lines 266-267)."""
    state = state._replace(endct=state.endct.at[row[M.F_X4]].add(1))
    return state, table, outbox, count


def h_move_sh(state, table, me, row, outbox, count, cfg):
    """MoveSHRecv (Lines 215-225): create SH*/ST* + fresh counters."""
    keymin, keymax = row[M.F_KEY], row[M.F_X1]
    sh_sid, sh_ts = row[M.F_SID], row[M.F_TS]

    slot = state.ctr_top
    slot_ok = slot < state.stct.shape[0]
    state = state._replace(ctr_top=slot + slot_ok.astype(jnp.int32))
    state, st_idx, ok1 = U.alloc_node(state)
    state, sh_idx, ok2 = U.alloc_node(state)
    ok = slot_ok & ok1 & ok2

    pool = state.pool
    pool = pool._replace(
        key=U.set_at(U.set_at(pool.key, st_idx, ST_KEY, ok), sh_idx, SH_KEY,
                     ok),
        keymax=U.set_at(pool.keymax, st_idx, keymax, ok),
        ctr=U.set_at(U.set_at(pool.ctr, st_idx, slot, ok), sh_idx, slot, ok),
        # the SubHead keeps the original's <sId, ts> identity (Line 219)
        sid=U.set_at(U.set_at(pool.sid, sh_idx, sh_sid, ok), st_idx, me, ok),
        ts=U.set_at(U.set_at(pool.ts, sh_idx, sh_ts, ok), st_idx,
                    state.ts_clock, ok),
        newloc=U.set_at(U.set_at(pool.newloc, sh_idx, refs.null_ref(), ok),
                        st_idx, refs.null_ref(), ok),
    )
    pool = pool._replace(
        nxt=U.set_at(U.set_at(pool.nxt, sh_idx, refs.make_ref(me, st_idx),
                              ok),
                     st_idx, refs.null_ref(), ok))
    state = state._replace(pool=pool, ts_clock=state.ts_clock + 1)
    state = U.lamport(state, sh_ts)

    ack = M.make_row(M.MSG_MOVE_SH_ACK, row[M.F_SRC], me,
                     ref1=M.ref2i(refs.make_ref(me, sh_idx)),
                     x3=M.ref2i(refs.make_ref(me, st_idx)),
                     key=keymin, x1=keymax, a=ok.astype(jnp.int32),
                     slot=row[M.F_SLOT])
    outbox, count = M.push(outbox, count, ack)
    return state, table, outbox, count


def h_move_sh_ack(state, table, me, row, outbox, count, cfg):
    """Line 200: head.newLoc = remoteSH; start copying."""
    j = _row_slot(table, row)
    bg = slot_view(table, j)
    waiting = bg.phase == BG_MOVE_SH_WAIT
    good = waiting & (row[M.F_A] != 0)
    sh_star = M.i2ref(row[M.F_REF1])
    state = state._replace(pool=state.pool._replace(
        newloc=U.set_at(state.pool.newloc, bg.old_head, sh_star, good)))
    z = jnp.zeros((), jnp.int32)
    table = _set_slot_where(
        table, j, good,
        phase=jnp.asarray(BG_MOVE_COPY, jnp.int32),
        sh_star=sh_star, st_star=M.i2ref(row[M.F_X3]),
        cursor=bg.old_head, send_prev=bg.old_head,
        sent=z, acked=z, st_sent=z, st_acked=z)
    # nack (target out of nodes / counter slots): abort the move and free
    # the slot — leaving it in MOVE_SH_WAIT would claim the entry forever
    # and wedge quiescence
    table = _set_slot_where(table, j, waiting & (row[M.F_A] == 0),
                            phase=jnp.asarray(BG_IDLE, jnp.int32))
    return state, table, outbox, count


def h_move_item(state, table, me, row, outbox, count, cfg):
    """MoveItemRecv (Lines 240-248): replay-insert the copied item.

    Serves both MSG_MOVE_ITEM (SubTail rows, retries) and any
    MSG_MOVE_ITEMS row the vectorized replay pre-pass bounced — the two
    kinds share one field layout by construction.
    """
    flags = row[M.F_A]
    is_st = (flags & FL_ST) != 0
    is_marked = (flags & FL_MARKED) != 0
    anchor = refs.ref_idx(M.i2ref(row[M.F_REF1]))
    prev_sid, prev_ts = row[M.F_X2], row[M.F_X3]
    item_sid, item_ts = row[M.F_SID], row[M.F_TS]
    key, oldloc = row[M.F_KEY], row[M.F_X1]

    prev_idx, found = U.find_by_identity(state, anchor, prev_sid, prev_ts,
                                         cfg.max_scan)

    # ---- ST: link the target SubTail into the global chain (Lines 241-247)
    pool = state.pool
    n = pool.key.shape[0]

    def walk_to_st(c):
        idx, steps = c
        nxt = jnp.clip(refs.ref_idx(refs.unmarked(pool.nxt[idx])), 0, n - 1)
        return jnp.where(pool.key[idx] == ST_KEY, idx, nxt), steps + 1

    def not_st(c):
        idx, steps = c
        return (pool.key[idx] != ST_KEY) & (steps < cfg.max_scan)

    st_idx, _ = jax.lax.while_loop(not_st, walk_to_st,
                                   (prev_idx, jnp.zeros((), jnp.int32)))
    do_st = found & is_st
    st_next = M.i2ref(row[M.F_X4])     # source ST's next: the global chain
    pool = pool._replace(
        nxt=U.set_at(pool.nxt, st_idx, st_next, do_st),
        keymax=U.set_at(pool.keymax, st_idx, key, do_st))
    state = state._replace(pool=pool)
    ack_ref = refs.make_ref(me, st_idx)

    # ---- ordinary item: replay insert with compTs = prev.ts (Line 248)
    st2, new_idx, ok = U.replay_insert(
        state, me, prev_idx, prev_ts, key, item_sid, item_ts, is_marked, cfg,
        value=row[M.F_VAL])
    do_item = found & (~is_st) & ok
    state = jax.tree_util.tree_map(
        lambda a, b: jnp.where(do_item, b, a), state, st2)
    ack_ref = jnp.where(is_st, ack_ref, refs.make_ref(me, new_idx))

    done = do_st | do_item
    ack = M.make_row(M.MSG_MOVE_ACK, row[M.F_SRC], me,
                     ref1=M.ref2i(ack_ref), sid=item_sid, ts=item_ts,
                     x1=oldloc, a=flags, slot=row[M.F_SLOT])
    outbox, count = M.push(outbox, count, ack, done)
    # bounded retry: the retry count rides in the flag word's high bits
    retries = flags >> 8
    retry = row.at[M.F_A].set(flags + 256)
    retry = retry.at[M.F_DST].set(me)
    outbox, count = M.push(outbox, count, retry,
                           (~done) & (retries < cfg.max_retries))
    return state, table, outbox, count


def h_move_ack(state, table, me, row, outbox, count, cfg):
    """Source side of MoveItem (Lines 208-211): record newLoc, detect races."""
    oldloc = row[M.F_X1]
    sid, ts = row[M.F_SID], row[M.F_TS]
    flags = row[M.F_A]
    is_st = (flags & FL_ST) != 0
    sent_marked = (flags & FL_MARKED) != 0
    new_ref = M.i2ref(row[M.F_REF1])

    same = (state.pool.sid[oldloc] == sid) & (state.pool.ts[oldloc] == ts)
    state = state._replace(pool=state.pool._replace(
        newloc=U.set_at(state.pool.newloc, oldloc, new_ref, same)))

    # Line 210: item got marked while the copy was in flight -> RepDelete
    now_marked = refs.ref_mark(state.pool.nxt[oldloc])
    race = same & now_marked & (~sent_marked) & (~is_st)
    rep = M.make_row(M.MSG_REP_DELETE, refs.ref_sid(new_ref), me,
                     ref1=M.ref2i(refs.unmarked(new_ref)),
                     sid=sid, ts=ts, x1=oldloc, x2=0, x4=0)
    # x2=0: no ack needed — the remove already balanced its endCt.
    outbox, count = M.push(outbox, count, rep, race)

    j = _row_slot(table, row)
    in_copy = table.phase[j] == BG_MOVE_COPY
    # NB: the acked-prefix cursor is advanced only by move_copy's
    # contiguous-prefix walk; advancing it here (to the last ack) would
    # skip inserts that landed between in-flight batch items.
    table = _set_slot_where(
        table, j, in_copy,
        acked=table.acked[j] + 1,
        st_acked=jnp.where(is_st, 1, table.st_acked[j]))
    return state, table, outbox, count


def h_switch_st(state, table, me, row, outbox, count, cfg):
    """SwitchSTRecv (Lines 272-277 + 297-302).

    A mover routes SwitchST by its *replica's* view of the left
    neighbor's owner. That view can be permanently stale for a shard
    that joined after restructures it never saw (DESIGN.md §13), so a
    misrouted request is delegated toward the owner this replica names —
    the same forwarding idiom client ops use — rather than failure-acked
    (the mover would re-route from the same stale replica forever). The
    token stays single-flighted: a forwarding hop does NOT ack, so the
    mover keeps waiting and only the terminal hop (the owner, or a hop
    whose budget ran out) replies; the mover never retries while a
    delegated copy is still in flight.
    """
    keymin = row[M.F_KEY]
    new_sh = M.i2ref(row[M.F_REF1])
    reg = state.registry
    left = reg_ops.get_by_key(reg, keymin)
    lidx = jnp.clip(left, 0, None)
    owner = refs.ref_sid(reg.subhead[lidx])
    delegate = (left >= 0) & (owner != me) & (row[M.F_A] < cfg.max_retries)
    state, success = U.switch_next_st(state, me, keymin, new_sh)
    fwd = row.at[M.F_A].set(row[M.F_A] + 1)
    fwd = fwd.at[M.F_DST].set(owner)
    outbox, count = M.push(outbox, count, fwd, delegate)
    ack = M.make_row(M.MSG_SWITCH_ST_ACK, row[M.F_SRC], me,
                     a=success.astype(jnp.int32), slot=row[M.F_SLOT])
    outbox, count = M.push(outbox, count, ack, ~delegate)
    return state, table, outbox, count


def h_switch_st_ack(state, table, me, row, outbox, count, cfg):
    j = _row_slot(table, row)
    good = table.phase[j] == BG_SWITCH_ST_WAIT
    ok = row[M.F_A] != 0
    table = _set_slot_where(
        table, j, good,
        phase=jnp.where(ok, BG_SWITCH_REG, BG_SWITCH_ST).astype(jnp.int32))
    return state, table, outbox, count


def h_reg_split(state, table, me, row, outbox, count, cfg):
    """RegisterSublistRecv (Lines 159-163) at a replica."""
    split_key, keymax = row[M.F_KEY], row[M.F_X1]
    sh_ref = M.i2ref(row[M.F_REF1])
    reg = state.registry
    e = reg_ops.get_by_key(reg, keymax)
    eidx = jnp.clip(e, 0, None)
    # exact right-half already present (duplicate) — drop
    dup = (e >= 0) & (reg.keymin[eidx] == split_key) & \
        (reg.keymax[eidx] == keymax)
    # parent entry present: split it
    can = (e >= 0) & (~dup) & (reg.keymin[eidx] < split_key) & \
        (reg.keymax[eidx] == keymax) & (reg.size < reg.keymin.shape[0])
    new_reg = reg_ops.add_entry(
        reg_ops.set_fields(reg, eidx, keymax=split_key),
        split_key, keymax, sh_ref, refs.null_ref(), 0, 0)
    state = state._replace(registry=jax.tree_util.tree_map(
        lambda a, b: jnp.where(can, b, a), reg, new_reg))
    retry = row.at[M.F_A].set(row[M.F_A] + 1)
    retry = retry.at[M.F_DST].set(me)
    outbox, count = M.push(outbox, count, retry,
                           (~can) & (~dup) & (row[M.F_A] < cfg.max_retries))
    return state, table, outbox, count


def h_switch_server(state, table, me, row, outbox, count, cfg):
    """SwitchServerRecv (Lines 285-287): repoint a registry entry.

    Replicas can be *coarser* than the sender's registry: a shard that was
    retired while splits happened rejoins with entries that cover the
    switched range without matching it (the peer-mask fan-out gate skipped
    it by design — DESIGN.md §13). Such a replica self-heals here: the
    switched range is carved out of the stale covering entry (the
    remainders keep the old routing ref, which delegation corrects
    lazily). Without the carve, a move targeting the rejoined shard would
    never record its new ownership, and the next Move's SwitchST against
    it would retry forever.
    """
    keymin, keymax = row[M.F_KEY], row[M.F_X1]
    sh_ref, st_ref = M.i2ref(row[M.F_REF1]), M.i2ref(row[M.F_X3])
    reg = state.registry
    e = reg_ops.get_by_key(reg, keymax)
    eidx = jnp.clip(e, 0, None)
    exact = (e >= 0) & (reg.keymin[eidx] == keymin) & \
        (reg.keymax[eidx] == keymax)
    i_am_new_owner = refs.ref_sid(sh_ref) == me
    sh_idx = jnp.clip(refs.ref_idx(sh_ref), 0, state.pool.key.shape[0] - 1)
    new_ctr = jnp.where(i_am_new_owner, state.pool.ctr[sh_idx], 0)
    new_reg = reg_ops.set_fields(reg, eidx, subhead=sh_ref, subtail=st_ref,
                                 ctr=new_ctr, offset=0)
    state = state._replace(registry=jax.tree_util.tree_map(
        lambda a, b: jnp.where(exact, b, a), reg, new_reg))

    # carve-out for a stale covering entry (never one of my own chains —
    # a range I own cannot be switched under me)
    reg = state.registry
    old_sh = reg.subhead[eidx]
    old_keymax = reg.keymax[eidx]
    covered = (e >= 0) & (~exact) & (reg.keymin[eidx] <= keymin) & \
        (old_keymax >= keymax) & (refs.ref_sid(old_sh) != me)
    left_rem = covered & (reg.keymin[eidx] < keymin)
    right_rem = covered & (old_keymax > keymax)
    room = (reg.size + left_rem.astype(jnp.int32)
            + right_rem.astype(jnp.int32)) <= reg.keymin.shape[0]
    carve = covered & room
    # stage 1: the covering entry becomes either the left remainder (old
    # ref) or, with no left remainder, the switched entry itself
    reg1 = jax.tree_util.tree_map(
        lambda a, b: jnp.where(left_rem, a, b),
        reg_ops.set_fields(reg, eidx, keymax=keymin),
        reg_ops.set_fields(reg, eidx, keymax=keymax, subhead=sh_ref,
                           subtail=st_ref, ctr=new_ctr, offset=0))
    # stage 2: with a left remainder, add the switched entry
    reg2 = jax.tree_util.tree_map(
        lambda a, b: jnp.where(left_rem, a, b),
        reg_ops.add_entry(reg1, keymin, keymax, sh_ref, st_ref,
                          new_ctr, 0),
        reg1)
    # stage 3: add the right remainder (old ref; replicas carry a null
    # subtail, same as h_reg_split)
    reg3 = jax.tree_util.tree_map(
        lambda a, b: jnp.where(right_rem, a, b),
        reg_ops.add_entry(reg2, keymax, old_keymax, old_sh,
                          refs.null_ref(), 0, 0),
        reg2)
    state = state._replace(registry=jax.tree_util.tree_map(
        lambda a, b: jnp.where(carve, b, a), reg, reg3))

    retry = row.at[M.F_A].set(row[M.F_A] + 1)
    retry = retry.at[M.F_DST].set(me)
    outbox, count = M.push(outbox, count, retry,
                           (~exact) & (~carve)
                           & (row[M.F_A] < cfg.max_retries))
    return state, table, outbox, count


def h_reg_merged(state, table, me, row, outbox, count, cfg):
    """RegisterMergedSublistRecv (Lines 360-365) at a replica."""
    key_mid = row[M.F_KEY]
    reg = state.registry
    right = U.entry_by_keymax(reg, row[M.F_X1])
    ridx = jnp.clip(right, 0, None)
    ok = (right >= 0) & (reg.keymin[ridx] == key_mid)
    left = U.cover(reg, key_mid)
    lidx = jnp.clip(left, 0, None)
    ok = ok & (left >= 0) & (reg.keymax[lidx] == key_mid)
    new_reg = reg_ops.remove_entry(
        reg_ops.set_fields(reg, lidx, keymax=reg.keymax[ridx]), ridx)
    state = state._replace(registry=jax.tree_util.tree_map(
        lambda a, b: jnp.where(ok, b, a), reg, new_reg))
    # already merged here (idempotent) — drop; otherwise out-of-order with a
    # pending REG_SPLIT: retry next round
    merged = (right < 0) & (U.cover(reg, key_mid) >= 0)
    retry = row.at[M.F_A].set(row[M.F_A] + 1)
    retry = retry.at[M.F_DST].set(me)
    outbox, count = M.push(outbox, count, retry,
                           (~ok) & (~merged) & (row[M.F_A] < cfg.max_retries))
    return state, table, outbox, count
