"""Shared helpers for the background engine: identity walks, the serial
Replay insert (Lines 249-262), allocation, and registry lookups.

Replay is implemented faithfully: items are identified by their <sId, ts>
tuple; an insert replays before the first node whose ts is smaller than the
inserted item's comparison timestamp (Lemmas 8/9). One adaptation
(DESIGN.md §8): the receiving shard Lamport-bumps its logical clock on
every replayed/moved item (clock = max(clock, item_ts + 1)) so that
timestamps stay comparable across repeated moves of the same sublist —
x86 DiLi gets this for free only until a sublist changes clock domain
twice.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import refs, registry as reg_ops
from ..types import DiLiConfig, ST_KEY, ShardState


def cover(reg, key):
    return reg_ops.get_by_key(reg, key)


def entry_by_keymax(reg, keymax):
    """Entry whose keymax equals ``keymax`` (the bg op's stable handle)."""
    e = cover(reg, keymax)
    ok = (e >= 0) & (reg.keymax[jnp.clip(e, 0, None)] == keymax)
    return jnp.where(ok, e, -1)


def alloc_node(state: ShardState):
    has_free = state.free_top > 0
    free_idx = state.free_list[jnp.clip(state.free_top - 1, 0, None)]
    bump_ok = state.alloc_top < state.pool.key.shape[0]
    idx = jnp.where(has_free, free_idx, state.alloc_top)
    ok = has_free | bump_ok
    state = state._replace(
        free_top=state.free_top - has_free.astype(jnp.int32),
        alloc_top=state.alloc_top + ((~has_free) & bump_ok).astype(jnp.int32))
    return state, jnp.where(ok, idx, 0), ok


def set_at(col, idx, val, do):
    return jnp.where(do, col.at[idx].set(val), col)


def lamport(state: ShardState, ts):
    return state._replace(ts_clock=jnp.maximum(state.ts_clock, ts + 1))


def find_by_identity(state: ShardState, start_idx, sid, ts, bound):
    """Walk the chain from ``start_idx`` for the node with <sId, ts>.

    Returns (idx, found). Stops at SubTail / null / ``bound`` steps.
    Used by Replay (Lines 227-230) and RepDelete (Lines 232-234).
    """
    pool = state.pool
    n = pool.key.shape[0]

    def cond(c):
        idx, steps, done = c
        return (~done) & (steps < bound)

    def body(c):
        idx, steps, _ = c
        hit = (pool.sid[idx] == sid) & (pool.ts[idx] == ts)
        at_end = (pool.key[idx] == ST_KEY) | \
                 refs.is_null(pool.nxt[idx]) & ~hit
        nxt_idx = jnp.clip(refs.ref_idx(refs.unmarked(pool.nxt[idx])), 0, n - 1)
        idx2 = jnp.where(hit | at_end, idx, nxt_idx)
        return idx2, steps + 1, hit | at_end

    idx0 = jnp.clip(start_idx, 0, n - 1)
    hit0 = (pool.sid[idx0] == sid) & (pool.ts[idx0] == ts)
    idx, _, done = jax.lax.while_loop(
        cond, body, (idx0, jnp.zeros((), jnp.int32), hit0))
    found = (pool.sid[idx] == sid) & (pool.ts[idx] == ts)
    return idx, found


def replay_insert(state: ShardState, me, prev_idx, comp_ts, key, item_sid,
                  item_ts, is_marked, cfg: DiLiConfig, value=0):
    """Replay algorithm Lines 249-262: insert after ``prev``, before the
    first node whose ts < comp_ts. Returns (state, new_idx, ok)."""
    pool = state.pool
    n = pool.key.shape[0]

    def cond(c):
        curr_prev, curr, steps = c
        go = (pool.ts[curr] >= comp_ts) & (pool.key[curr] != ST_KEY)
        return go & (steps < cfg.max_scan)

    def body(c):
        curr_prev, curr, steps = c
        nxt = jnp.clip(refs.ref_idx(refs.unmarked(pool.nxt[curr])), 0, n - 1)
        return curr, nxt, steps + 1

    first = jnp.clip(refs.ref_idx(refs.unmarked(pool.nxt[prev_idx])), 0, n - 1)
    curr_prev, curr, _ = jax.lax.while_loop(
        cond, body, (prev_idx, first, jnp.zeros((), jnp.int32)))

    state, new_idx, ok = alloc_node(state)
    pool = state.pool
    prev_nxt = pool.nxt[curr_prev]
    prev_mark = prev_nxt & jnp.uint32(refs.MARK_BIT)
    item_next = refs.with_mark(refs.make_ref(me, curr), is_marked)

    pool = pool._replace(
        key=set_at(pool.key, new_idx, key, ok),
        ts=set_at(pool.ts, new_idx, item_ts, ok),
        sid=set_at(pool.sid, new_idx, item_sid, ok),
        ctr=set_at(pool.ctr, new_idx, pool.ctr[curr_prev], ok),
        newloc=set_at(pool.newloc, new_idx, refs.null_ref(), ok),
        keymax=set_at(pool.keymax, new_idx, value, ok),
    )
    pool = pool._replace(nxt=set_at(pool.nxt, new_idx, item_next, ok))
    # Line 260: preserve currPrev's own deletion mark when relinking.
    pool = pool._replace(nxt=set_at(
        pool.nxt, curr_prev, refs.make_ref(me, new_idx) | prev_mark, ok))
    state = state._replace(pool=pool)
    state = lamport(state, item_ts)
    return state, new_idx, ok


def switch_next_st(state, me, keymin, new_sh):
    """switchNextST (Lines 297-302) on the local shard. Returns (state, ok)."""
    reg = state.registry
    left = reg_ops.get_by_key(reg, keymin)
    lidx = jnp.clip(left, 0, None)
    owner_ok = (left >= 0) & (refs.ref_sid(reg.subhead[lidx]) == me)
    st_idx = refs.ref_idx(reg.subtail[lidx])
    st_idx = jnp.clip(st_idx, 0, state.pool.key.shape[0] - 1)
    slot = state.pool.ctr[st_idx]
    state = state._replace(
        stct=jnp.where(owner_ok, state.stct.at[slot].add(1), state.stct))
    live = owner_ok & (state.stct[slot] >= 0)
    state = state._replace(pool=state.pool._replace(
        nxt=set_at(state.pool.nxt, st_idx, new_sh, live)))
    state = state._replace(
        endct=jnp.where(live, state.endct.at[slot].add(1), state.endct))
    return state, live
