"""Hot-sublist read replication (DESIGN.md §15).

Under Zipfian skew one hot sublist caps cluster throughput no matter how
well the balancer spreads *keys* — moving the hot entry just moves the
bottleneck. This module promotes the Move protocol's "temporary replica
of a sublist" into a first-class read path: the primary of a hot entry
streams a packed-block image of the sublist (the ``core.blocks`` layout:
one sorted ``int32[C]`` row of live keys) to replica shards, which then
answer FINDs in the entry's range locally. Inserts/removes still go to
the primary; replicas are bounded-staleness caches in the spirit of
distributionally linearizable relaxations.

Protocol (all rows cross the reliable transport, so delivery is
exactly-once in-order per (src, dst) lane):

  * A host ``replicate`` command claims a primary-side *session* keyed by
    the entry's keymax and poisons its published mirror, forcing the
    first publication to stream the full image.
  * Each round ``replica_step`` advances every session: it (re)walks the
    chain when the session has never committed, or on the lease-renewal
    cadence once the shard saw traffic or mutations (a cluster at rest
    stays quiescent, and a write-heavy primary pays one walk per
    ``replica_refresh_rounds``, not one per mutated round). Positions where the
    fresh image differs from the published mirror become REPLICA_DELTA
    rows, streamed ``replica_batch`` per round; when the diff drains, a
    REPLICA_INSTALL commit follows *on the same FIFO lane* — by the time
    it arrives, every delta before it has been applied, so the commit
    atomically (from the replica's view) publishes the new version and
    renews the staleness lease. A renewal with no content change is a
    single INSTALL row.
  * The replica applies deltas in place. In-place application is safe
    because FIND is a single-key probe: each cell is either the old or
    the new published value, both within the staleness bound.
  * The lease is hard: a slot serves only while ``ttl > 0``; ttl is set
    to ``replica_staleness_rounds`` by each commit and decremented every
    round. An un-refreshed replica therefore self-invalidates and
    FINDs fall through to normal delegation — the primary is always the
    correct fallback.
  * Sessions self-audit: if the entry is no longer owned, live and
    non-moving at the primary (a Move or Merge took it), the session
    drops its replicas (REPLICA_DROP) and frees itself. The balancer
    additionally drops replicas *before* restructuring a replicated
    entry (claim-aware lifecycle), so this is a safety net, not the
    normal path.

Replication state lives in ``ShardState`` (``rep`` sessions on the
primary, ``rslots`` images on the replica), so WAL round replay and
snapshots cover it with no extra machinery; the host ``replicate`` /
``drop_replica`` commands are journaled like balancer commands and
replay byte-identically.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import messages as M
from . import refs
from . import registry as REG
from .types import (DiLiConfig, OP_FIND, RES_FALSE, RES_TRUE, SH_KEY,
                    ST_KEY, ShardState)


# ------------------------------------------------------------- commands

def queue_replicate(state: ShardState, cfg: DiLiConfig, keymax, target):
    """Host command: start (or widen) read replication of the owned entry
    with upper bound ``keymax`` onto shard ``target``. Returns
    ``(state, ok)``; rejects an unknown/foreign entry, a self-target or
    session exhaustion. Pure, so WAL recovery can replay it literally.
    """
    keymax = jnp.asarray(keymax, jnp.int32)
    target = jnp.asarray(target, jnp.int32)
    rep = state.rep
    s = rep.keymax.shape[0]
    e = REG.get_by_key(state.registry, keymax)
    ec = jnp.clip(e, 0, state.registry.keymin.shape[0] - 1)
    owner = refs.ref_sid(state.registry.subhead[ec]).astype(jnp.int32)
    me = refs.ref_sid(state.registry.subhead[ec])  # owner == issuing shard
    valid = (e >= 0) & (state.registry.keymax[ec] == keymax) & \
        (target >= 0) & (target < cfg.num_shards) & (target != owner)

    have = (rep.keymax == keymax)
    free = (rep.keymax == SH_KEY)
    j = jnp.where(jnp.any(have), jnp.argmax(have), jnp.argmax(free))
    ok = valid & (jnp.any(have) | jnp.any(free))
    bit = jnp.where(ok, jnp.int32(1) << target, 0)
    at = jnp.where(ok, j, s)                      # s drops the scatter
    # a new target must receive the full image: poison the published
    # mirror (SH_KEY differs from every real image cell and every ST_KEY
    # pad) so the next publication streams all C positions
    rep = rep._replace(
        keymax=rep.keymax.at[at].set(keymax, mode="drop"),
        targets=rep.targets.at[at].set(rep.targets[j] | bit, mode="drop"),
        version=rep.version.at[at].set(
            jnp.where(jnp.any(have), rep.version[j], 0), mode="drop"),
        cursor=rep.cursor.at[at].set(-1, mode="drop"),
        age=rep.age.at[at].set(0, mode="drop"),
        keys=rep.keys.at[at].set(jnp.full((cfg.block_cap,), SH_KEY,
                                          jnp.int32), mode="drop"),
        diff=rep.diff.at[at].set(False, mode="drop"),
    )
    del me
    return state._replace(rep=rep), ok


def queue_drop_replica(state: ShardState, cfg: DiLiConfig, keymax,
                       target=-1):
    """Host command: retire replicas of ``keymax`` on ``target`` (or all
    targets when ``target`` is -1). The session flushes REPLICA_DROP rows
    next round and frees itself once no targets remain."""
    keymax = jnp.asarray(keymax, jnp.int32)
    target = jnp.asarray(target, jnp.int32)
    rep = state.rep
    s = rep.keymax.shape[0]
    have = (rep.keymax == keymax)
    j = jnp.argmax(have)
    ok = jnp.any(have)
    bits = jnp.where(target < 0, rep.targets[j],
                     rep.targets[j] & (jnp.int32(1) << jnp.clip(target, 0, 30)))
    at = jnp.where(ok, j, s)
    rep = rep._replace(
        targets=rep.targets.at[at].set(rep.targets[j] & ~bits, mode="drop"),
        drops=rep.drops.at[at].set(rep.drops[j] | bits, mode="drop"),
        cursor=rep.cursor.at[at].set(-1, mode="drop"),
        diff=rep.diff.at[at].set(False, mode="drop"),
    )
    return state._replace(rep=rep), ok & (bits != 0)


# Eagerly dispatched, each queue command costs tens of ms of per-op
# overhead on the balancer's path — enough to dominate a benchmark round.
# cfg is a NamedTuple of scalars, so it jits as a static argument; keymax
# and target stay dynamic so one compilation covers every entry/shard.
queue_replicate_jit = jax.jit(queue_replicate, static_argnums=(1,))
queue_drop_replica_jit = jax.jit(queue_drop_replica, static_argnums=(1,))


def warm_commands(state: ShardState, cfg: DiLiConfig) -> None:
    """Pre-compile the jitted queue commands (no-op state probe), so the
    first real ``replicate`` mid-run doesn't pay the trace+compile."""
    if not cfg.replication:
        return
    jax.block_until_ready(queue_replicate_jit(state, cfg, 0, 0))
    jax.block_until_ready(queue_drop_replica_jit(state, cfg, 0, -1))


# ---------------------------------------------------------- serve path

def replica_serve(state: ShardState, rows, me, cfg: DiLiConfig):
    """Vectorized replica read pre-pass: answer fresh local FINDs whose
    key falls in a serving replica slot's range. Returns ``(elig, res)``.

    Serving gate: slot occupied, committed (version >= 0), lease alive
    (ttl > 0), key in (keymin, keymax], and the key NOT covered by a
    locally-owned registry entry (if ownership moved here, the chain is
    the truth and the slot is a stale leftover pending its DROP).
    Delegated rows (sid != me) are never replica-served: their origin
    already made a routing decision and expects an authoritative answer.
    """
    rs = state.rslots
    me = jnp.asarray(me, jnp.int32)
    kind = rows[:, M.F_KIND]
    key = rows[:, M.F_KEY]
    cand = (kind == M.MSG_OP) & (rows[:, M.F_A] == OP_FIND) & \
        (rows[:, M.F_SID] == me)

    serving = (rs.keymax != SH_KEY) & (rs.version >= 0) & (rs.ttl > 0)
    inrange = (key[:, None] > rs.keymin[None, :]) & \
        (key[:, None] <= rs.keymax[None, :]) & serving[None, :]
    hit = jnp.any(inrange, axis=1)
    j = jnp.argmax(inrange, axis=1)

    reg = state.registry
    e = REG.get_by_key(reg, key)
    ec = jnp.clip(e, 0, reg.keymin.shape[0] - 1)
    owned = (e >= 0) & (refs.ref_sid(reg.subhead[ec]) == me)

    elig = cand & hit & ~owned
    krow = rs.keys[j]                                  # [B, C]
    pos = jax.vmap(lambda r, q: jnp.searchsorted(r, q, side="left"))(
        krow, key).astype(jnp.int32)
    found = krow[jnp.arange(rows.shape[0]),
                 jnp.clip(pos, 0, krow.shape[1] - 1)] == key
    res = jnp.where(found, RES_TRUE, RES_FALSE).astype(jnp.int32)
    return elig, res


# ------------------------------------------------------ replica handlers

def h_replica_delta(state, bg, me, row, outbox, count, cfg: DiLiConfig):
    """Apply one image-cell rewrite. Claims a free slot on first contact
    (version -1: deltas arriving, not serving until the commit lands);
    with no matching and no free slot the row is dropped — the replica
    simply never serves and reads keep bouncing home."""
    rs = state.rslots
    r = rs.keymax.shape[0]
    key = row[M.F_KEY]
    have = rs.keymax == key
    free = rs.keymax == SH_KEY
    j = jnp.where(jnp.any(have), jnp.argmax(have), jnp.argmax(free))
    ok = jnp.any(have) | jnp.any(free)
    claim = ok & ~jnp.any(have)
    at = jnp.where(ok, j, r)
    # a reclaimed slot must not leak the previous tenant's image
    keys_j = jnp.where(claim, jnp.full((rs.keys.shape[1],), ST_KEY,
                                       jnp.int32), rs.keys[j])
    pos = jnp.clip(row[M.F_X1], 0, rs.keys.shape[1] - 1)
    keys_j = keys_j.at[pos].set(row[M.F_X3])
    rs = rs._replace(
        keymax=rs.keymax.at[at].set(key, mode="drop"),
        keymin=rs.keymin.at[at].set(
            jnp.where(claim, key, rs.keymin[j]), mode="drop"),
        src=rs.src.at[at].set(row[M.F_SRC], mode="drop"),
        version=rs.version.at[at].set(
            jnp.where(claim, -1, rs.version[j]), mode="drop"),
        ttl=rs.ttl.at[at].set(jnp.where(claim, 0, rs.ttl[j]), mode="drop"),
        keys=rs.keys.at[at].set(keys_j, mode="drop"),
    )
    return state._replace(rslots=rs), bg, outbox, count


def h_replica_install(state, bg, me, row, outbox, count, cfg: DiLiConfig):
    """Commit a publication / renew the lease. Only an existing slot
    commits: the initial publication's deltas travel the same FIFO lane
    and created the slot, so a commit with no slot is a renewal that
    outlived an eviction — committing an empty image would serve wrong
    absences, so it is ignored."""
    rs = state.rslots
    r = rs.keymax.shape[0]
    key = row[M.F_KEY]
    have = rs.keymax == key
    j = jnp.argmax(have)
    ok = jnp.any(have)
    at = jnp.where(ok, j, r)
    rs = rs._replace(
        keymin=rs.keymin.at[at].set(row[M.F_X1], mode="drop"),
        src=rs.src.at[at].set(row[M.F_SRC], mode="drop"),
        version=rs.version.at[at].set(row[M.F_X2], mode="drop"),
        ttl=rs.ttl.at[at].set(
            jnp.asarray(cfg.replica_staleness_rounds, jnp.int32),
            mode="drop"),
    )
    return state._replace(rslots=rs), bg, outbox, count


def h_replica_drop(state, bg, me, row, outbox, count, cfg: DiLiConfig):
    """Free the slot the sending primary installed. Matches (keymax, src)
    so a late drop from a previous primary cannot kill a successor's
    fresh replica; a duplicate finds nothing and is a no-op."""
    rs = state.rslots
    r = rs.keymax.shape[0]
    have = (rs.keymax == row[M.F_KEY]) & (rs.src == row[M.F_SRC])
    j = jnp.argmax(have)
    at = jnp.where(jnp.any(have), j, r)
    rs = rs._replace(
        keymax=rs.keymax.at[at].set(SH_KEY, mode="drop"),
        keymin=rs.keymin.at[at].set(SH_KEY, mode="drop"),
        src=rs.src.at[at].set(-1, mode="drop"),
        version=rs.version.at[at].set(-1, mode="drop"),
        ttl=rs.ttl.at[at].set(0, mode="drop"),
        keys=rs.keys.at[at].set(jnp.full((rs.keys.shape[1],), ST_KEY,
                                         jnp.int32), mode="drop"),
    )
    return state._replace(rslots=rs), bg, outbox, count


# ------------------------------------------------------ publication step

def replica_step(state: ShardState, me, mutated, traffic, outbox, count,
                 cfg: DiLiConfig):
    """Advance every primary-side publication session by one round and
    tick the replica-side staleness leases. Runs after the serial loop
    and bg step, so a cadence walk sees every mutation up to and
    including this round's.

    Emission budget per session per round: ``replica_batch`` deltas plus
    one commit, each fanned to every target, plus owed DROP rows.
    """
    me_i = jnp.asarray(me, jnp.int32)
    rep = state.rep
    reg = state.registry
    n_sess = rep.keymax.shape[0]
    c = cfg.block_cap
    nsh = cfg.num_shards

    # --- replica-side lease tick (only occupied slots change at all;
    # ttl saturates at 0, so a cluster at rest goes bit-static)
    rs = state.rslots
    occupied = rs.keymax != SH_KEY
    rs = rs._replace(ttl=jnp.where(occupied, jnp.maximum(rs.ttl - 1, 0),
                                   rs.ttl))
    state = state._replace(rslots=rs)

    active = rep.keymax != SH_KEY
    if not bool(cfg.replication):
        return state, outbox, count

    # --- session entry audit: still owned, live, non-moving here?
    e = REG.get_by_key(reg, rep.keymax)
    ec = jnp.clip(e, 0, reg.keymin.shape[0] - 1)
    head_idx = jnp.clip(refs.ref_idx(reg.subhead[ec]).astype(jnp.int32),
                        0, state.pool.key.shape[0] - 1)
    slot = jnp.clip(reg.ctr[ec], 0, state.stct.shape[0] - 1)
    valid = active & (e >= 0) & (reg.keymax[ec] == rep.keymax) & \
        (refs.ref_sid(reg.subhead[ec]) == me_i) & \
        (state.stct[slot] >= 0) & refs.is_null(state.pool.newloc[head_idx])
    lost = active & ~valid
    drops = rep.drops | jnp.where(lost, rep.targets, 0)
    targets = jnp.where(lost, 0, rep.targets)
    rep = rep._replace(targets=targets, drops=drops,
                       cursor=jnp.where(lost, -1, rep.cursor))

    # --- age tick (saturating) and publication triggers
    refresh = jnp.asarray(cfg.replica_refresh_rounds, jnp.int32)
    rep = rep._replace(age=jnp.where(active & valid,
                                     jnp.minimum(rep.age + 1, refresh),
                                     rep.age))
    streaming = rep.cursor >= 0
    # publications run on the refresh cadence: a mutation is picked up by
    # the next cadence walk rather than forcing a full chain walk every
    # mutated round (under write traffic that walk dominated round cost).
    # Staleness is still bounded by the ttl lease alone — the cadence
    # only adds <= refresh rounds of propagation delay, and refresh <=
    # replica_staleness_rounds by construction.
    renewal_due = (rep.age >= refresh) & (traffic | mutated)
    need_walk = valid & (rep.targets != 0) & ~streaming & \
        ((rep.version == 0) | renewal_due)

    # Image source: the packed-block mirror the fast paths already
    # maintain (core.blocks). ``blk.keys[e]`` is exactly the publication
    # layout — sorted live keys, ST_KEY-padded — and a valid row proves
    # the chain was entirely local/non-moving/non-switched with writers
    # invalidating since, so validity at this point in the round means
    # the row is current. No chain walk on the publication path; an
    # invalid row defers the publication to a later cadence round (if
    # the row never revalidates, replica leases lapse and reads bounce
    # home — degraded, never stale).
    images = state.blk.keys[ec]
    good = state.blk.valid[ec]
    can = need_walk & good
    diff = (images != rep.keys) & can[:, None]
    anydiff = jnp.any(diff, axis=1)
    start = can & anydiff
    renew_only = can & ~anydiff & (rep.version > 0)
    rep = rep._replace(
        keys=jnp.where(start[:, None], images, rep.keys),
        diff=jnp.where(start[:, None], diff, rep.diff),
        version=jnp.where(start, rep.version + 1, rep.version),
        cursor=jnp.where(start, 0, rep.cursor),
    )

    # --- emit (vectorized): build every candidate row as one array and
    # append the valid ones with a single scatter. Per-session row order
    # is DROPs, then deltas in position order, then the commit — so on
    # each FIFO (src, dst) lane a commit still lands after the deltas of
    # the publication it seals, exactly as the unrolled per-row pushes
    # did. Per-row M.push here costs ~n_sess*(nsh*2 + batch*nsh) XLA ops
    # every round, which dominated round wall time on CPU.
    tgt = jnp.arange(nsh, dtype=jnp.int32)
    tbit = ((rep.targets[:, None] >> tgt[None, :]) & 1) != 0    # [S, T]
    dbit = ((rep.drops[:, None] >> tgt[None, :]) & 1) != 0
    live = rep.keymax != SH_KEY
    streaming = rep.cursor >= 0

    # first replica_batch set diff positions, lowest index first — the
    # same set a per-position argmax drain would pick. The argsort key
    # pushes clear positions past C, so the candidate block stays K rows
    # per session (K = replica_batch) instead of C: emit cost tracks the
    # per-round delta budget, not the block capacity.
    k = int(cfg.replica_batch)
    colix = jnp.arange(c, dtype=jnp.int32)
    pos = jnp.argsort(jnp.where(rep.diff, colix, colix + c),
                      axis=1)[:, :k].astype(jnp.int32)          # [S, K]
    picked = jnp.take_along_axis(rep.diff, pos, axis=1)         # [S, K]
    sent = live & streaming
    done = sent & (jnp.sum(rep.diff.astype(jnp.int32), axis=1) <= k)
    commit = done | (live & renew_only)
    livecnt = jnp.sum((rep.keys != ST_KEY).astype(jnp.int32), axis=1)

    def rows(shape, fields):
        out = jnp.zeros(shape + (M.FIELDS,), jnp.int32)
        for f, v in fields:
            out = out.at[..., f].set(v)
        return out

    drop_rows = rows((n_sess, nsh), [
        (M.F_KIND, M.MSG_REPLICA_DROP), (M.F_DST, tgt[None, :]),
        (M.F_SRC, me_i), (M.F_KEY, rep.keymax[:, None]),
        (M.F_SID, me_i)])
    delta_rows = rows((n_sess, k, nsh), [
        (M.F_KIND, M.MSG_REPLICA_DELTA), (M.F_DST, tgt[None, None, :]),
        (M.F_SRC, me_i), (M.F_KEY, rep.keymax[:, None, None]),
        (M.F_SID, me_i), (M.F_X1, pos[:, :, None]),
        (M.F_X2, rep.version[:, None, None]),
        (M.F_X3, jnp.take_along_axis(rep.keys, pos, axis=1)[:, :, None])])
    commit_rows = rows((n_sess, nsh), [
        (M.F_KIND, M.MSG_REPLICA_INSTALL), (M.F_DST, tgt[None, :]),
        (M.F_SRC, me_i), (M.F_KEY, rep.keymax[:, None]),
        (M.F_SID, me_i), (M.F_X1, reg.keymin[ec][:, None]),
        (M.F_X2, rep.version[:, None]), (M.F_X3, livecnt[:, None])])

    delta_ok = picked[:, :, None] & sent[:, None, None] & tbit[:, None, :]
    all_rows = jnp.concatenate(
        [drop_rows, delta_rows.reshape(n_sess, k * nsh, M.FIELDS),
         commit_rows], axis=1).reshape(-1, M.FIELDS)
    all_ok = jnp.concatenate(
        [dbit, delta_ok.reshape(n_sess, k * nsh),
         commit[:, None] & tbit], axis=1).reshape(-1)
    outbox, count = M.push_many(outbox, count, all_rows, all_ok)

    rows_ix = jnp.arange(n_sess, dtype=jnp.int32)[:, None]
    selmask = jnp.zeros_like(rep.diff).at[rows_ix, pos].set(
        picked & sent[:, None])
    rep = rep._replace(
        diff=rep.diff & ~selmask,
        drops=jnp.zeros_like(rep.drops),
        cursor=jnp.where(done, -1, rep.cursor),
        age=jnp.where(commit, 0, rep.age))

    # free fully-retired sessions (no targets, owed drops just flushed)
    gone = live & (rep.targets == 0)
    rep = rep._replace(
        keymax=jnp.where(gone, SH_KEY, rep.keymax),
        version=jnp.where(gone, 0, rep.version),
        cursor=jnp.where(gone, -1, rep.cursor),
        age=jnp.where(gone, 0, rep.age),
        keys=jnp.where(gone[:, None], ST_KEY, rep.keys),
        diff=rep.diff & ~gone[:, None])

    return state._replace(rep=rep), outbox, count
