"""Registry operations (paper Algorithm 6) in functional-JAX form.

The paper keeps the registry as a sorted array updated with copy-on-write under
a single writer (the background thread) and many lock-free readers. In JAX all
updates are copy-on-write by construction, so ``add_entry`` / ``remove_entry``
return new Registry pytrees; ``get_by_key`` is the wait-free binary search.

Empty slots hold keymin == ST_KEY so that the live prefix [0, size) is sorted
and padding sorts to the end — ``searchsorted`` stays correct without masking.
"""
from __future__ import annotations

import jax.numpy as jnp

from . import refs
from .types import Registry, ST_KEY


def get_by_key(reg: Registry, key):
    """Binary search: index of the entry whose (keymin, keymax] contains key.

    Paper Algorithm 6 sends ``key <= keyMin`` left, so an entry covers keys
    *strictly greater* than its keymin and up to (inclusive) its keymax —
    after a Split at sItem, sItem.key itself stays in the left half
    (left.keymax == right.keymin == sItem.key). Returns -1 if no entry covers
    the key (paper returns null). Vectorizes over ``key`` of any shape.
    """
    # Live prefix is sorted by keymin; padding is ST_KEY (sorts last since real
    # keys are < ST_KEY).
    i = jnp.searchsorted(reg.keymin, key, side="left").astype(jnp.int32) - 1
    i = jnp.clip(i, 0, reg.keymin.shape[0] - 1)
    ok = (
        (jnp.asarray(key) > reg.keymin[i])
        & (jnp.asarray(key) <= reg.keymax[i])
        & (i < reg.size)
    )
    return jnp.where(ok, i, -1)


def add_entry(reg: Registry, keymin, keymax, subhead, subtail, ctr, offset) -> Registry:
    """COW sorted insert of a new sublist entry (Algorithm 6 addEntry)."""
    m = reg.keymin.shape[0]
    pos = jnp.searchsorted(reg.keymin, keymin, side="left").astype(jnp.int32)
    idx = jnp.arange(m, dtype=jnp.int32)
    src = jnp.where(idx < pos, idx, idx - 1)        # shift right from pos
    take = jnp.clip(src, 0, m - 1)

    def shift(col, newval):
        shifted = jnp.where(idx < pos, col, col[take])
        return jnp.where(idx == pos, jnp.asarray(newval, col.dtype), shifted)

    return Registry(
        keymin=shift(reg.keymin, keymin),
        keymax=shift(reg.keymax, keymax),
        subhead=shift(reg.subhead, subhead),
        subtail=shift(reg.subtail, subtail),
        ctr=shift(reg.ctr, ctr),
        offset=shift(reg.offset, offset),
        size=reg.size + 1,
    )


def remove_entry(reg: Registry, pos) -> Registry:
    """COW delete of entry ``pos`` (used by Merge)."""
    m = reg.keymin.shape[0]
    idx = jnp.arange(m, dtype=jnp.int32)
    take = jnp.clip(jnp.where(idx >= pos, idx + 1, idx), 0, m - 1)

    def shift(col, pad):
        out = jnp.where(idx >= pos, col[take], col)
        return out.at[m - 1].set(jnp.asarray(pad, col.dtype))

    return Registry(
        keymin=shift(reg.keymin, ST_KEY),
        keymax=shift(reg.keymax, ST_KEY),
        subhead=shift(reg.subhead, refs.NULL_REF),
        subtail=shift(reg.subtail, refs.NULL_REF),
        ctr=shift(reg.ctr, 0),
        offset=shift(reg.offset, 0),
        size=reg.size - 1,
    )


def set_fields(reg: Registry, pos, *, keymax=None, subhead=None, subtail=None,
               ctr=None, offset=None) -> Registry:
    """Point updates to one entry (Split truncation, Switch subhead flip)."""
    out = reg
    if keymax is not None:
        out = out._replace(keymax=out.keymax.at[pos].set(jnp.asarray(keymax, jnp.int32)))
    if subhead is not None:
        out = out._replace(subhead=out.subhead.at[pos].set(jnp.asarray(subhead, refs.REF_DTYPE)))
    if subtail is not None:
        out = out._replace(subtail=out.subtail.at[pos].set(jnp.asarray(subtail, refs.REF_DTYPE)))
    if ctr is not None:
        out = out._replace(ctr=out.ctr.at[pos].set(jnp.asarray(ctr, jnp.int32)))
    if offset is not None:
        out = out._replace(offset=out.offset.at[pos].set(jnp.asarray(offset, jnp.int32)))
    return out
