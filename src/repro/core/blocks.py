"""Packed-block sublists (DESIGN.md §12): maintain and probe the
``Blocks`` mirror — each owned registry entry's live chain keys as one
contiguous, sorted ``int32[C]`` row — so the stage-2 probe of both batched
fast-paths can run as ``kernels/hybrid_search``'s single VMEM sweep
instead of ``traverse.probe_batch``'s lock-step pointer-gather walk.

The discipline is cache-with-detectable-staleness, never a second source
of truth:

  * ``refresh_blocks`` runs at round *start* (before anything mutates) and
    rebuilds only rows that are dirty AND owned-and-live. The rebuild is
    one lock-step chain walk across all M entries; a row validates only
    when its walk saw exclusively local, non-moving (newLoc == null),
    non-switched (stCt >= 0) nodes, collected at most C *live* keys, and
    terminated at the entry's *registered*, unmarked SubTail. Marked
    nodes are *skipped*, not rejected: they are logically absent (exactly
    what ``sim.chain_keys`` and the serial traversal do), and tombstones
    linger until a delinking walk — rejecting them would permanently
    invalidate any entry that ever saw a remove. The subtail-identity
    check screens out a mid-Split chain (the walk would stop at the
    freshly inserted mid-ST, capturing only the left half while the
    registry entry still covers both). Anything dirtier stays invalid and
    bounces to the pointer walk — the differential oracle.

  * writers invalidate: the mutation fast-path clears the rows it fires
    into (``batch_apply``), the bg phases clear at their compaction points
    (split/merge/replay hooks), and ``shard_round`` drops the whole mirror
    on any serial-path mutation or bg activity (the blanket rule — serial
    rows and bg phases may touch any chain or shift the registry's
    entry indexing, and per-entry attribution there is not worth the
    bookkeeping).

A valid block therefore proves more than membership: its chain is
entirely local/non-moving/non-switched *as of round start* and its live
keys are exactly the row, so a block-answered lane needs none of
``probe_batch``'s per-node screens — only the caller's usual left-node
re-check when the Harris window's left is the SubHead itself (never
walked by either probe). A block window ``(left, right)`` may have
*marked* nodes physically between its two live nodes; the mutation
fast-path's net-insert splice (``left.nxt = new, new.nxt = right``)
then delinks them — precisely the Harris delink the serial traversal
performs on the way, so the physical divergence from a pointer-walk
window is itself a legal step of the algorithm.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import refs
from ..kernels import ops as K
from .types import Blocks, DiLiConfig, SH_KEY, ST_KEY, ShardState


def invalidate_all(blk: Blocks) -> Blocks:
    return blk._replace(valid=jnp.zeros_like(blk.valid))


def invalidate_entry(blk: Blocks, e, when=True) -> Blocks:
    """Clear entry ``e``'s valid bit (scatter-drop when e is out of range
    or ``when`` is False)."""
    m = blk.valid.shape[0]
    at = jnp.where(when & (e >= 0), e, m)
    return blk._replace(valid=blk.valid.at[at].set(False, mode="drop"))


def refresh_blocks(state: ShardState, me, cfg: DiLiConfig) -> ShardState:
    """Rebuild every dirty, owned, live registry entry's packed block.

    One lock-step walk over all M entries with a per-row write cursor:
    live keys land at their cursor column, marked tombstones and in-chain
    SubHeads are stepped over without writing (matching ``chain_keys`` /
    the serial traversal's view). Cost is bounded by the longest owned
    chain (early exit), the same shape as ``probe_batch``'s sweep — but
    amortized: a row rebuilt once serves every subsequent round until a
    writer dirties it.
    """
    pool = state.pool
    reg = state.registry
    blk = state.blk
    m = reg.keymin.shape[0]
    c = cfg.block_cap
    n = pool.key.shape[0]
    me = jnp.asarray(me, jnp.int32)

    eidx = jnp.arange(m, dtype=jnp.int32)
    sh = reg.subhead
    head_idx = jnp.clip(refs.ref_idx(sh).astype(jnp.int32), 0, n - 1)
    slot = jnp.clip(reg.ctr, 0, state.stct.shape[0] - 1)
    live = (eidx < reg.size) & (~refs.is_null(sh)) & \
        (refs.ref_sid(sh) == me) & (state.stct[slot] >= 0) & \
        refs.is_null(pool.newloc[head_idx])
    need = live & (~blk.valid)

    keys0 = jnp.where(need[:, None], ST_KEY, blk.keys)
    idx0 = jnp.where(need[:, None], 0, blk.idx)
    st_ref = refs.unmarked(reg.subtail)
    rows_ = jnp.arange(m, dtype=jnp.int32)
    # chain steps, not live keys: tombstones stretch the walk past C
    bound = int(cfg.max_scan)

    def w_cond(carry):
        i, keys, idxs, col, cur, collecting, good = carry
        return (i < bound) & jnp.any(collecting)

    def w_body(carry):
        i, keys, idxs, col, cur, collecting, good = carry
        ci = jnp.clip(refs.ref_idx(cur).astype(jnp.int32), 0, n - 1)
        local = refs.ref_sid(cur) == me
        word = pool.nxt[ci]
        marked = refs.ref_mark(word)
        moving = ~refs.is_null(pool.newloc[ci])
        switched = state.stct[jnp.clip(pool.ctr[ci], 0,
                                       state.stct.shape[0] - 1)] < 0
        k = pool.key[ci]
        at_st = k == ST_KEY
        # the terminating ST must be the *registered* subtail, unmarked —
        # a mid-Split ST (or a merge-neutralized one) fails the identity
        # check and the row stays invalid until the registry catches up
        reach_ok = at_st & (~marked) & (refs.unmarked(cur) == st_ref)
        # marked non-ST nodes and in-chain SubHeads are logically absent:
        # step over them, exactly as chain_keys / the serial walk do
        hop = (k == SH_KEY) | (marked & ~at_st)
        want_write = (~at_st) & (~hop)
        bad = (~local) | refs.is_null(cur) | moving | switched \
            | (at_st & ~reach_ok) | (want_write & (col >= c))
        write = collecting & (~bad) & want_write

        at_col = jnp.where(write, col, c)          # col == C drops
        keys = keys.at[rows_, at_col].set(k, mode="drop")
        idxs = idxs.at[rows_, at_col].set(ci, mode="drop")
        good = good | (collecting & reach_ok)
        collecting = collecting & (~bad) & (~reach_ok)
        col = col + write.astype(jnp.int32)
        cur = jnp.where(collecting, word, cur)
        return i + 1, keys, idxs, col, cur, collecting, good

    init = (jnp.zeros((), jnp.int32), keys0, idx0,
            jnp.zeros((m,), jnp.int32), pool.nxt[head_idx], need,
            jnp.zeros((m,), bool))
    _, keys, idxs, _, _, _, good = jax.lax.while_loop(
        w_cond, w_body, init)
    # rows still collecting at the bound never reached their subtail (or
    # overflowed C live keys): not good.
    valid = (blk.valid | good) & live
    return state._replace(blk=Blocks(keys=keys, idx=idxs, valid=valid))


def probe_blocks(state: ShardState, entry, sh_ref, q, me, cfg: DiLiConfig):
    """Answer probe lanes from valid packed blocks via the Pallas kernel.

    ``entry`` is each lane's resolved registry entry (``Route.entry``),
    ``sh_ref`` its routed subhead Ref, ``q`` its key. Returns
    ``(usable, present, left, right)`` with ``left``/``right`` pool
    indices forming the same Harris window ``probe_batch`` would return:
    ``right`` is the first live node with key >= q (the entry's SubTail
    when q exceeds every block key — including the fixed pos == C
    full-block edge) and ``left`` its predecessor (the SubHead for
    pos == 0, which callers re-screen exactly as for probe_batch lanes).
    Lanes that are not ``usable`` (no entry, dirty block, hint pointing
    away from the registered subhead, sentinel key) carry no information
    — bounce them.
    """
    reg = state.registry
    blk = state.blk
    pool = state.pool
    m, c = blk.keys.shape
    n = pool.key.shape[0]

    e = jnp.clip(entry, 0, m - 1)
    usable = (entry >= 0) & blk.valid[e] & \
        (refs.unmarked(sh_ref) == refs.unmarked(reg.subhead[e])) & \
        (q > SH_KEY) & (q < ST_KEY)

    slot, found = K.hybrid_search(reg.keymin, blk.keys, q)
    # decode against OUR entry, never slot // C: a full block with every
    # key < q answers pos == C, where slot aliases (entry+1)*C
    pos = slot - e * c
    usable = usable & (pos >= 0) & (pos <= c)

    posc = jnp.clip(pos, 0, c - 1)
    past = (pos >= c) | (blk.keys[e, posc] == ST_KEY)
    st_idx = jnp.clip(refs.ref_idx(reg.subtail[e]).astype(jnp.int32),
                      0, n - 1)
    right = jnp.where(past, st_idx, blk.idx[e, posc])
    hd = jnp.clip(refs.ref_idx(reg.subhead[e]).astype(jnp.int32), 0, n - 1)
    left = jnp.where(pos == 0, hd, blk.idx[e, jnp.clip(pos - 1, 0, c - 1)])
    return usable, found, left, right
