"""One shard's round: process inbox, apply client ops, advance background op.

The round is the unit of linearization (DESIGN.md §2). Handlers are
dispatched per message kind with ``lax.switch`` — a single jit compilation
serves every shard (``me`` is a traced argument).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import background as B
from . import messages as M
from . import ops as O
from .types import DiLiConfig, RES_PENDING, ShardState


class RoundOut(NamedTuple):
    state: ShardState
    bg: B.BgState
    outbox: jnp.ndarray      # [cap, FIELDS]
    out_count: jnp.ndarray
    comp_slot: jnp.ndarray   # [K] client slots completed this round (-1 pad)
    comp_val: jnp.ndarray    # [K]


def _handle_op(state, bg, me, row, outbox, count, cfg):
    out = O.apply_op(state, me, row, outbox, count, cfg)
    reply_sid, slot = row[M.F_SID], row[M.F_TS]
    local_done = (out.result != RES_PENDING) & (reply_sid == me) & \
        (row[M.F_A] != 0)
    cslot = jnp.where(local_done, slot, -1)
    cval = jnp.where(local_done, out.result, 0)
    return out.state, bg, out.outbox, out.count, cslot, cval


def _handle_result(state, bg, me, row, outbox, count, cfg):
    return state, bg, outbox, count, row[M.F_TS], row[M.F_A]


def _wrap_bg(fn):
    def h(state, bg, me, row, outbox, count, cfg):
        state, bg, outbox, count = fn(state, bg, me, row, outbox, count, cfg)
        neg = jnp.asarray(-1, jnp.int32)
        return state, bg, outbox, count, neg, jnp.zeros((), jnp.int32)
    return h


def _noop(state, bg, me, row, outbox, count, cfg):
    neg = jnp.asarray(-1, jnp.int32)
    return state, bg, outbox, count, neg, jnp.zeros((), jnp.int32)


_HANDLERS = {
    M.MSG_OP: _handle_op,
    M.MSG_RESULT: _handle_result,
    M.MSG_REP_INSERT: _wrap_bg(B.h_rep_insert),
    M.MSG_REP_DELETE: _wrap_bg(B.h_rep_delete),
    M.MSG_ACK_INSERT: _wrap_bg(B.h_ack_insert),
    M.MSG_ACK_DELETE: _wrap_bg(B.h_ack_delete),
    M.MSG_MOVE_SH: _wrap_bg(B.h_move_sh),
    M.MSG_MOVE_SH_ACK: _wrap_bg(B.h_move_sh_ack),
    M.MSG_MOVE_ITEM: _wrap_bg(B.h_move_item),
    M.MSG_MOVE_ACK: _wrap_bg(B.h_move_ack),
    M.MSG_SWITCH_ST: _wrap_bg(B.h_switch_st),
    M.MSG_SWITCH_ST_ACK: _wrap_bg(B.h_switch_st_ack),
    M.MSG_REG_SPLIT: _wrap_bg(B.h_reg_split),
    M.MSG_SWITCH_SERVER: _wrap_bg(B.h_switch_server),
    M.MSG_REG_MERGED: _wrap_bg(B.h_reg_merged),
}
_N_KINDS = 16


@partial(jax.jit, static_argnames=("cfg",))
def shard_round(state: ShardState, bg: B.BgState, me, inbox, client,
                cfg: DiLiConfig) -> RoundOut:
    """``inbox``/``client``: [*, FIELDS] int32 rows, MSG_NONE-padded."""
    me = jnp.asarray(me, jnp.int32)
    rows = jnp.concatenate([inbox, client], axis=0)
    outbox, count = M.empty_outbox(cfg.mailbox_cap)

    branches = []
    for kind in range(_N_KINDS):
        fn = _HANDLERS.get(kind, _noop)

        def mk(f):
            def br(args):
                st, b, row, ob, ct = args
                return f(st, b, me, row, ob, ct, cfg)
            return br

        branches.append(mk(fn))

    def step(carry, row):
        st, b, ob, ct = carry
        kind = jnp.clip(row[M.F_KIND], 0, _N_KINDS - 1)
        st, b, ob, ct, cs, cv = jax.lax.switch(
            kind, branches, (st, b, row, ob, ct))
        return (st, b, ob, ct), (cs, cv)

    (state, bg, outbox, count), (cslots, cvals) = jax.lax.scan(
        step, (state, bg, outbox, count), rows)

    state, bg, outbox, count = B.bg_step(state, bg, me, outbox, count, cfg)
    return RoundOut(state=state, bg=bg, outbox=outbox, out_count=count,
                    comp_slot=cslots, comp_val=cvals)
