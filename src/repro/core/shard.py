"""One shard's round: process inbox, apply client ops, advance the
background slot table (up to ``cfg.bg_slots`` concurrent Split/Move/Merge
ops per shard — DESIGN.md §10).

The round is the unit of linearization (DESIGN.md §2). Handlers are
dispatched per message kind with ``lax.switch`` — a single jit compilation
serves every shard (``me`` is a traced argument).

With ``cfg.find_fastpath`` (DESIGN.md §4) a vectorized pre-pass answers the
round's eligible FIND rows before the serial scan; those rows dispatch to
the no-op branch (their per-op ``while_loop`` pointer chase is skipped) and
their completions are patched in from the pre-pass. Ineligible finds flow
through the serial path untouched. ``cfg.mut_fastpath`` (DESIGN.md §4b) is
the write-side twin: a second pre-pass *applies* the round's eligible
INSERT/REMOVE rows in one scatter sweep against round-start state, so those
rows skip the serial loop too. Both pre-passes classify against the same
round-start state (eligible finds never share a key with any mutation, so
the order between the two pre-passes is immaterial); the serial loop then
runs on the mutated state — safe because eligible mutations commute with
every remaining row.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import batch_apply as BA
from . import bg as B
from . import blocks as BL
from . import messages as M
from . import ops as O
from . import range_scan as RS
from . import refs
from . import registry as REG
from . import replica as R
from .types import DiLiConfig, RES_PENDING, SH_KEY, ShardState


class RoundOut(NamedTuple):
    state: ShardState
    bg: B.BgTable
    outbox: jnp.ndarray      # [cap, FIELDS]
    out_count: jnp.ndarray
    comp_slot: jnp.ndarray   # [K] client slots completed this round (-1 pad)
    comp_val: jnp.ndarray    # [K]
    comp_src: jnp.ndarray    # [K] shard that *executed* each completed op —
                             # != the submission shard means the op was
                             # delegated, i.e. the client's route was stale
                             # (the client API uses this to refresh its
                             # registry cache; DESIGN.md §9)
    comp_key: jnp.ndarray    # [K] SH_KEY for scalar completions; a real
                             # key marks the row as one RANGE item
                             # (comp_val is then the item's value and the
                             # host accumulates it instead of publishing
                             # a result; DESIGN.md §16)
    fast_hits: jnp.ndarray   # int32 — finds answered by the fast-path
    mut_hits: jnp.ndarray    # int32 — mutations applied by the fast-path
    bg_active: jnp.ndarray   # int32 — background slots busy after the round
    move_hits: jnp.ndarray   # int32 — MoveItems replayed by the batched
                             # scatter splice (vs the serial walk)
    blk_hits: jnp.ndarray    # int32 — fast-path lanes whose stage-2 probe
                             # was the packed-block hybrid-search kernel
                             # (subset of fast_hits + mut_hits;
                             # DESIGN.md §12)
    rep_hits: jnp.ndarray    # int32 — FINDs answered from a replica slot
                             # (DESIGN.md §15)
    range_hits: jnp.ndarray  # int32 — RANGE segments served by the
                             # packed-block gather pre-pass (vs the
                             # serial chain walk; DESIGN.md §16)
    ent_hits: jnp.ndarray    # int32[M] — ops this round attributed to
                             # each local registry entry (owned-entry
                             # arrivals + replica serves). The host feeds
                             # these into the per-entry op-rate EWMA the
                             # balancer's load model reads.


# handlers return (state, bg, outbox, count, cslot, cval, csrc, ckey);
# ckey is SH_KEY for scalar completions — only MSG_RANGE_ITEM rows carry
# a real key there (DESIGN.md §16).
_NOKEY = SH_KEY


def _handle_op(state, bg, me, row, outbox, count, cfg):
    out = O.apply_op(state, me, row, outbox, count, cfg)
    reply_sid, slot = row[M.F_SID], row[M.F_TS]
    local_done = (out.result != RES_PENDING) & (reply_sid == me) & \
        (row[M.F_A] != 0)
    cslot = jnp.where(local_done, slot, -1)
    cval = jnp.where(local_done, out.result, 0)
    return (out.state, bg, out.outbox, out.count, cslot, cval, me,
            jnp.asarray(_NOKEY, jnp.int32))


def _handle_result(state, bg, me, row, outbox, count, cfg):
    # F_SRC is the shard that executed the op and routed the result home —
    # the corrected route for the op's key.
    return (state, bg, outbox, count, row[M.F_TS], row[M.F_A],
            row[M.F_SRC], jnp.asarray(_NOKEY, jnp.int32))


def _wrap_bg(fn):
    def h(state, bg, me, row, outbox, count, cfg):
        state, bg, outbox, count = fn(state, bg, me, row, outbox, count, cfg)
        neg = jnp.asarray(-1, jnp.int32)
        return (state, bg, outbox, count, neg, jnp.zeros((), jnp.int32),
                jnp.zeros((), jnp.int32), jnp.asarray(_NOKEY, jnp.int32))
    return h


def _noop(state, bg, me, row, outbox, count, cfg):
    neg = jnp.asarray(-1, jnp.int32)
    return (state, bg, outbox, count, neg, jnp.zeros((), jnp.int32),
            jnp.zeros((), jnp.int32), jnp.asarray(_NOKEY, jnp.int32))


def _handle_epoch(state, bg, me, row, outbox, count, cfg):
    # Monotone merge of the membership announcement (DESIGN.md §13):
    # a newer epoch replaces the peer bitmask wholesale; an equal epoch
    # carries an identical mask (the host is the single writer), so
    # duplicates and cross-lane reorderings are idempotent by max().
    e = row[M.F_KEY]
    take = e > state.epoch
    state = state._replace(
        epoch=jnp.maximum(state.epoch, e),
        peers=jnp.where(take, row[M.F_X1], state.peers))
    neg = jnp.asarray(-1, jnp.int32)
    return (state, bg, outbox, count, neg, jnp.zeros((), jnp.int32),
            jnp.zeros((), jnp.int32), jnp.asarray(_NOKEY, jnp.int32))


_HANDLERS = {
    M.MSG_OP: _handle_op,
    M.MSG_RESULT: _handle_result,
    M.MSG_REP_INSERT: _wrap_bg(B.h_rep_insert),
    M.MSG_REP_DELETE: _wrap_bg(B.h_rep_delete),
    M.MSG_ACK_INSERT: _wrap_bg(B.h_ack_insert),
    M.MSG_ACK_DELETE: _wrap_bg(B.h_ack_delete),
    M.MSG_MOVE_SH: _wrap_bg(B.h_move_sh),
    M.MSG_MOVE_SH_ACK: _wrap_bg(B.h_move_sh_ack),
    M.MSG_MOVE_ITEM: _wrap_bg(B.h_move_item),
    # batch-run member the replay pre-pass bounced: same field layout, so
    # the serial per-item replay is the universal fallback
    M.MSG_MOVE_ITEMS: _wrap_bg(B.h_move_item),
    M.MSG_MOVE_ACK: _wrap_bg(B.h_move_ack),
    M.MSG_SWITCH_ST: _wrap_bg(B.h_switch_st),
    M.MSG_SWITCH_ST_ACK: _wrap_bg(B.h_switch_st_ack),
    M.MSG_REG_SPLIT: _wrap_bg(B.h_reg_split),
    M.MSG_SWITCH_SERVER: _wrap_bg(B.h_switch_server),
    M.MSG_REG_MERGED: _wrap_bg(B.h_reg_merged),
    M.MSG_EPOCH: _handle_epoch,
    M.MSG_REPLICA_DELTA: _wrap_bg(R.h_replica_delta),
    M.MSG_REPLICA_INSTALL: _wrap_bg(R.h_replica_install),
    M.MSG_REPLICA_DROP: _wrap_bg(R.h_replica_drop),
    M.MSG_RANGE: RS.h_range,
    M.MSG_RANGE_ITEM: RS.h_range_item,
}
_N_KINDS = M.N_KINDS


@partial(jax.jit, static_argnames=("cfg",))
def shard_round(state: ShardState, bg: B.BgTable, me, inbox, client,
                cfg: DiLiConfig) -> RoundOut:
    """``inbox``/``client``: [*, FIELDS] int32 rows, MSG_NONE-padded."""
    me = jnp.asarray(me, jnp.int32)
    rows = jnp.concatenate([inbox, client], axis=0)
    n_rows = rows.shape[0]
    outbox, count = M.empty_outbox(cfg.mailbox_cap)

    # rebuild dirty packed blocks against round-start state, BEFORE any
    # mutation — a block validated here mirrors exactly the state both
    # pre-passes classify against (DESIGN.md §12). Replication also needs
    # the mirror: replica_step publishes blk rows as session images
    # (§15), so a replicating shard refreshes even with the probe off.
    # With both off, the mirror stays all-invalid and costs nothing.
    if cfg.block_probe or cfg.replication or cfg.range_scan:
        state = BL.refresh_blocks(state, me, cfg)

    # RANGE gather pre-pass (DESIGN.md §16): serve scan cursors whose
    # covering entry has a valid packed block, against the same
    # round-start snapshot the blocks mirror — before anything mutates.
    # Unserved cursors fall through to the serial h_range walk.
    if cfg.range_scan:
        outbox, count, range_handled, range_hits = RS.range_prepass(
            state, rows, me, outbox, count, cfg)
    else:
        range_handled = jnp.zeros((n_rows,), bool)
        range_hits = jnp.zeros((), jnp.int32)

    # one combined pre-pass: answers eligible FINDs from round-start state
    # and applies eligible INSERT/REMOVEs against it (eligible finds never
    # share a key with a mutation, so their relative order is immaterial),
    # sharing a single route-resolve + bounded gather-walk.
    pre = BA.round_prepass(state, rows, me, cfg,
                           run_find=cfg.find_fastpath,
                           run_mut=cfg.mut_fastpath)
    state = pre.state

    # migration rounds get their own pre-pass (mutually exclusive with the
    # client one — any move row makes the round non-benign for §4/§4b):
    # chain-contiguous MSG_MOVE_ITEMS runs are replayed in one scatter
    # splice and their MOVE_ACKs pushed ahead of the serial rows'
    # messages. Acks interact with the source only through per-slot
    # counters and newLoc writes, so their position among the round's
    # other outbox rows is not semantically ordered (DESIGN.md §10).
    mrp = B.replay_prepass(state, rows, me, outbox, count, cfg)
    state, outbox, count = mrp.state, mrp.outbox, mrp.count

    # replica read pre-pass (DESIGN.md §15): fresh local FINDs whose key
    # lands in a serving replica slot are answered from the packed image
    # and skip the serial loop. Compiled out unless cfg.replication.
    if cfg.replication:
        rep_elig, rep_res = R.replica_serve(state, rows, me, cfg)
        rep_elig = rep_elig & ~pre.find_elig & ~pre.mut_elig & ~mrp.handled
    else:
        rep_elig = jnp.zeros((n_rows,), bool)
        rep_res = jnp.zeros((n_rows,), jnp.int32)

    # Stable-partition the rows the serial pass must execute to the front,
    # so it runs a *dynamic* trip count: padding costs nothing (rounds are
    # usually mostly MSG_NONE), and fast-path-answered rows never enter
    # the loop at all — fast finds neither mutate state nor emit messages,
    # and fast mutations commute with every remaining row and emit nothing
    # either, so removing them leaves the remaining rows' serial order (and
    # with it per-(src,dst) FIFO) intact. The composite key skip*n + i is
    # unique, so the sort is order-preserving on the kept rows.
    skip = (rows[:, M.F_KIND] == M.MSG_NONE) | pre.find_elig \
        | pre.mut_elig | mrp.handled | rep_elig | range_handled
    # blanket packed-block invalidation trigger (DESIGN.md §12): any row
    # the serial loop will execute, other than pure result routing and
    # transport acks, may mutate a chain or shift the registry's entry
    # indexing — per-entry attribution is done where the writer knows the
    # entry (fast-path apply, bg phase hooks); everything else drops the
    # whole mirror below.
    kind0 = rows[:, M.F_KIND]
    # replica rows rewrite only the rslots tables — never a chain, never
    # the registry — so they don't trigger the blanket block drop.
    # RANGE rows are pure reads (serial h_range walks without delinking),
    # so they don't either.
    serial_mut = jnp.any((~skip) & (kind0 != M.MSG_NONE)
                         & (kind0 != M.MSG_RESULT)
                         & (kind0 != M.MSG_NET_ACK)
                         & (kind0 != M.MSG_EPOCH)
                         & (kind0 != M.MSG_REPLICA_DELTA)
                         & (kind0 != M.MSG_REPLICA_INSTALL)
                         & (kind0 != M.MSG_REPLICA_DROP)
                         & (kind0 != M.MSG_RANGE)
                         & (kind0 != M.MSG_RANGE_ITEM))

    # per-entry op attribution (pre-reorder): an MSG_OP row counts at the
    # shard that will answer it — owned-entry arrivals here, or a replica
    # serve here; delegated-away rows count on arrival at their owner.
    m_ent = state.registry.keymin.shape[0]
    ent = REG.get_by_key(state.registry, rows[:, M.F_KEY])
    entc = jnp.clip(ent, 0, m_ent - 1)
    owned_ent = (ent >= 0) & \
        (refs.ref_sid(state.registry.subhead[entc]) == me)
    count_here = (kind0 == M.MSG_OP) & (owned_ent | rep_elig)
    ent_hits = jnp.zeros((m_ent,), jnp.int32).at[
        jnp.where(count_here, entc, m_ent)].add(1, mode="drop")

    order = jnp.argsort(skip.astype(jnp.int32) * n_rows
                        + jnp.arange(n_rows, dtype=jnp.int32))
    rows = rows[order]
    elig = pre.find_elig[order]
    melig = pre.mut_elig[order]
    relig = rep_elig[order]
    res_all = jnp.where(rep_elig, rep_res, pre.res)
    n_live = jnp.sum(~skip)

    branches = []
    for kind in range(_N_KINDS):
        fn = _HANDLERS.get(kind, _noop)

        def mk(f):
            def br(args):
                st, b, row, ob, ct = args
                return f(st, b, me, row, ob, ct, cfg)
            return br

        branches.append(mk(fn))

    def cond(c):
        return c[0] < n_live

    def body(c):
        i, st, b, ob, ct, cslots, cvals, csrcs, ckeys = c
        row = rows[i]
        kind = jnp.clip(row[M.F_KIND], 0, _N_KINDS - 1)
        st, b, ob, ct, cs, cv, cr, ck = jax.lax.switch(
            kind, branches, (st, b, row, ob, ct))
        return (i + 1, st, b, ob, ct,
                cslots.at[i].set(cs), cvals.at[i].set(cv),
                csrcs.at[i].set(cr), ckeys.at[i].set(ck))

    # completions start pre-filled with the pre-pass answers (those rows
    # sit past n_live); the serial loop overwrites its own rows' slots.
    # Pre-pass rows are local clients answered here, so their src is ``me``.
    init = (jnp.zeros((), jnp.int32), state, bg, outbox, count,
            jnp.where(elig | melig | relig,
                      rows[:, M.F_TS], -1).astype(jnp.int32),
            jnp.where(elig | melig | relig,
                      res_all[order], 0).astype(jnp.int32),
            jnp.full((n_rows,), me, jnp.int32),
            jnp.full((n_rows,), SH_KEY, jnp.int32))
    (_, state, bg, outbox, count, cslots, cvals, csrcs,
     ckeys) = jax.lax.while_loop(cond, body, init)

    bg_busy = jnp.any(bg.phase != B.BG_IDLE)
    state, bg, outbox, count = B.bg_step(state, bg, me, outbox, count, cfg)
    bg_busy = bg_busy | jnp.any(bg.phase != B.BG_IDLE)

    # publication engine (DESIGN.md §15): runs after the serial loop and
    # bg step so a fresh image walk already sees this round's mutations —
    # a change at the primary is on the wire the same round it happened.
    if cfg.replication:
        traffic = jnp.any(kind0 != M.MSG_NONE)
        mutated = serial_mut | jnp.any(pre.mut_elig) | bg_busy
        state, outbox, count = R.replica_step(
            state, me, mutated, traffic, outbox, count, cfg)

    # blanket invalidation: serial mutating rows, any bg slot active
    # around bg_step, or a replayed move splice — a stale valid bit here
    # would let next round's block probe answer from a chain that changed.
    dirty_all = serial_mut | bg_busy | jnp.any(mrp.handled)
    state = state._replace(blk=state.blk._replace(
        valid=jnp.where(dirty_all, jnp.zeros_like(state.blk.valid),
                        state.blk.valid)))
    return RoundOut(state=state, bg=bg, outbox=outbox, out_count=count,
                    comp_slot=cslots, comp_val=cvals, comp_src=csrcs,
                    comp_key=ckeys,
                    fast_hits=jnp.sum(pre.find_elig).astype(jnp.int32),
                    mut_hits=jnp.sum(pre.mut_elig).astype(jnp.int32),
                    bg_active=jnp.sum(bg.phase != B.BG_IDLE)
                    .astype(jnp.int32),
                    move_hits=jnp.sum(mrp.handled).astype(jnp.int32),
                    blk_hits=pre.blk_hits,
                    rep_hits=jnp.sum(rep_elig).astype(jnp.int32),
                    range_hits=range_hits,
                    ent_hits=ent_hits)
