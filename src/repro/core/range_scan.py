"""RANGE(lo, hi, limit) — ordered scans over the distributed list
(DESIGN.md §16).

A scan is a travelling cursor: an ``MSG_RANGE`` row carries the inclusive
low end of the *remaining* span (F_KEY), the exclusive high end (F_X1),
the remaining item budget (F_X3) and the count emitted so far (F_X4).
Each shard that receives the cursor serves the one registry entry covering
the cursor, emits ``MSG_RANGE_ITEM`` rows to the reply shard, and either
forwards a narrowed cursor to the next entry's owner or terminates with a
plain ``MSG_RESULT`` whose F_A is the total item count. The reply shard
surfaces items through the completion lanes (``comp_key`` tags a row as an
item rather than a scalar result); the host withholds the client
completion until the collected items match the terminal count, so
cross-shard segments may arrive on any lane order.

Two serving paths, mirroring the point-op fast/serial split:

  * ``range_prepass`` — when the covering entry's packed block
    (DESIGN.md §12) is valid, the segment is one masked gather over the
    block row: round-start snapshot, no pointer chasing. A valid block
    *is* the per-entry version check — it certifies the chain was
    entirely local, non-moving and non-switched as of round start.

  * ``h_range`` — the serial chain walk, the universal fallback for
    dirty/moving entries. It mirrors the §4 bounce taxonomy: a remote or
    switched subhead delegates the cursor to its owner (Thm 4 hops); a
    moving/switched/remote *interior* node aborts the walk and re-issues
    the cursor past the last emitted key — the "re-read on restructure"
    rule. The cursor only ever advances past keys that were emitted, so
    a re-read can neither skip nor duplicate a key.

Linearization: each segment linearizes at the round that serves it (the
gather pre-pass at round start, the serial walk at its position in the
round's serial order). The scan as a whole linearizes at its final
segment; the client pins mutations that overlap an in-flight span (and
vice versa), so no single client can observe a cut that contradicts its
own program order.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import messages as M
from . import refs
from . import registry as reg_ops
from .ops import RES_OVERFLOW, pool_slot
from .types import DiLiConfig, SH_KEY, ST_KEY, ShardState

# walk outcome codes
_D_NONE = 0   # still walking
_D_TERM = 1   # span complete — emit terminal result
_D_CONT = 2   # segment done / bounced — re-issue narrowed cursor
_D_OVER = 3   # traversal bound hit with no progress — error result


def make_range_row(shard: int, lo: int, hi: int, limit: int,
                   slot: int) -> np.ndarray:
    """Host-side builder for a fresh RANGE cursor row (both backends)."""
    row = np.zeros((M.FIELDS,), np.int32)
    row[M.F_KIND] = M.MSG_RANGE
    row[M.F_DST] = shard
    row[M.F_SRC] = shard
    row[M.F_KEY] = lo
    row[M.F_X1] = hi
    row[M.F_X3] = limit
    row[M.F_X4] = 0
    row[M.F_SID] = shard   # reply shard = submission shard
    row[M.F_TS] = slot
    return row


def _item_rows(shape, me, reply, slot, keys, vals):
    """MSG_RANGE_ITEM rows from broadcastable field arrays."""
    rows = jnp.zeros(shape + (M.FIELDS,), M.MSG_DTYPE)
    rows = rows.at[..., M.F_KIND].set(M.MSG_RANGE_ITEM)
    rows = rows.at[..., M.F_DST].set(reply)
    rows = rows.at[..., M.F_SRC].set(me)
    rows = rows.at[..., M.F_KEY].set(keys)
    rows = rows.at[..., M.F_VAL].set(vals)
    rows = rows.at[..., M.F_TS].set(slot)
    return rows


def h_range(state: ShardState, bg, me, row, outbox, count,
            cfg: DiLiConfig):
    """Serial RANGE segment serve — read-only, returns the 8-tuple handler
    shape. The walk collects up to ``range_batch`` in-span live keys from
    the covering entry's chain; any dirty node bounces the remainder."""
    me = jnp.asarray(me, jnp.int32)
    cursor = row[M.F_KEY]
    hi = row[M.F_X1]
    remaining = row[M.F_X3]
    emitted = row[M.F_X4]
    reply = row[M.F_SID]
    slot = row[M.F_TS]
    hops = row[M.F_X2]

    reg = state.registry
    pool = state.pool
    n = pool.key.shape[0]
    m = reg.keymin.shape[0]
    batch = int(cfg.range_batch)

    span_empty = (cursor >= hi) | (remaining <= 0)
    entry = reg_ops.get_by_key(reg, cursor)
    e = jnp.clip(entry, 0, m - 1)
    sh_ref = refs.unmarked(reg.subhead[e])
    owner = refs.ref_sid(sh_ref)
    head_idx = pool_slot(state, refs.ref_idx(sh_ref))
    head_ctr = jnp.clip(pool.ctr[head_idx], 0, state.stct.shape[0] - 1)
    head_moved = (owner == me) & (state.stct[head_ctr] < 0)
    head_newloc = refs.unmarked(pool.newloc[head_idx])

    no_route = (~span_empty) & (entry < 0)
    deleg = (~span_empty) & (entry >= 0) & ((owner != me) | head_moved)
    deleg_dst = jnp.where(owner != me, owner, refs.ref_sid(head_newloc))
    serve = (~span_empty) & (entry >= 0) & (~deleg)

    # ------------------------------------------------ bounded chain walk
    take = jnp.minimum(jnp.asarray(batch, jnp.int32), remaining)
    bound = int(cfg.max_scan)

    def w_cond(c):
        i, cur, keys, vals, got, code, nxt_cur = c
        return (code == _D_NONE) & (i < bound)

    def w_body(c):
        i, cur, keys, vals, got, code, nxt_cur = c
        ci = jnp.clip(refs.ref_idx(cur).astype(jnp.int32), 0, n - 1)
        word = pool.nxt[ci]
        marked = refs.ref_mark(word)
        moving = ~refs.is_null(pool.newloc[ci])
        switched = state.stct[jnp.clip(pool.ctr[ci], 0,
                                       state.stct.shape[0] - 1)] < 0
        k = pool.key[ci]
        is_sh = k == SH_KEY
        is_st = k == ST_KEY
        # dirty node → bounce: re-issue the cursor past the last emitted
        # key (or unchanged when nothing was emitted yet). A marked ST is
        # a merge-neutralized subtail mid-restructure — bounce too.
        bad = (refs.ref_sid(cur) != me) | refs.is_null(cur) | moving \
            | switched | (is_st & marked)
        last = jnp.where(got > 0, keys[jnp.clip(got - 1, 0, batch - 1)],
                         cursor - 1)
        st_stop = (~bad) & is_st
        st_covers = st_stop & (pool.keymax[ci] >= hi - 1)
        past = (~bad) & (~is_sh) & (~is_st) & (k >= hi)
        in_span = (~bad) & (~is_sh) & (~is_st) & (~marked) \
            & (k >= cursor) & (k < hi)
        trunc = in_span & (got >= take)
        coll = in_span & (got < take)

        code = jnp.where(bad, _D_CONT,
               jnp.where(st_covers | past, _D_TERM,
               jnp.where(st_stop | trunc, _D_CONT, _D_NONE)))
        nxt_cur = jnp.where(bad, last + 1,
                  jnp.where(st_stop & ~st_covers, pool.keymax[ci] + 1,
                  jnp.where(trunc, k, nxt_cur)))

        at = jnp.where(coll, got, batch)
        keys = keys.at[at].set(k, mode="drop")
        vals = vals.at[at].set(pool.keymax[ci], mode="drop")
        got = got + coll.astype(jnp.int32)
        cur = jnp.where(code == _D_NONE, word, cur)
        return i + 1, cur, keys, vals, got, code, nxt_cur

    init = (jnp.zeros((), jnp.int32), refs.make_ref(me, head_idx),
            jnp.full((batch,), ST_KEY, jnp.int32),
            jnp.zeros((batch,), jnp.int32), jnp.zeros((), jnp.int32),
            jnp.where(serve, _D_NONE, _D_TERM).astype(jnp.int32), cursor)
    _, _, keys, vals, got, code, nxt_cur = jax.lax.while_loop(
        w_cond, w_body, init)

    # bound hit while still walking: progress → continue, else overflow
    last = jnp.where(got > 0, keys[jnp.clip(got - 1, 0, batch - 1)],
                     cursor - 1)
    hit_bound = serve & (code == _D_NONE)
    nxt_cur = jnp.where(hit_bound, last + 1, nxt_cur)
    code = jnp.where(hit_bound,
                     jnp.where(got > 0, _D_CONT, _D_OVER), code)

    got = jnp.where(serve, got, 0)
    total = emitted + got
    rem2 = remaining - got

    # ------------------------------------------------ emit items
    items = _item_rows((batch,), me, reply, slot, keys, vals)
    do_items = serve & (jnp.arange(batch, dtype=jnp.int32) < got)
    outbox, count = M.push_many(outbox, count, items, do_items)

    # ------------------------------------------------ final row
    # terminal when the span is served out or the budget is spent;
    # otherwise forward the (possibly unchanged) cursor — to the next
    # entry's owner on a clean continue, to the delegate on a stale
    # route, to self on a transient registry gap or an interior bounce.
    over = serve & (code == _D_OVER)
    term = span_empty | (serve & (code == _D_TERM)) \
        | (serve & (code == _D_CONT) & (rem2 <= 0))
    is_term = term | over
    e2 = reg_ops.get_by_key(reg, nxt_cur)
    dst2 = jnp.where(
        e2 >= 0,
        refs.ref_sid(refs.unmarked(reg.subhead[jnp.clip(e2, 0, m - 1)])),
        me)
    fwd_dst = jnp.where(deleg, deleg_dst,
                        jnp.where(no_route, me, dst2))
    fwd_cursor = jnp.where(serve, nxt_cur, cursor)
    final = M.make_row(
        jnp.where(is_term, M.MSG_RESULT, M.MSG_RANGE),
        jnp.where(is_term, reply, fwd_dst), me,
        a=jnp.where(over, RES_OVERFLOW, total),
        key=fwd_cursor, x1=hi, x3=rem2, x4=total,
        sid=reply, ts=slot, x2=hops + 1)
    outbox, count = M.push(outbox, count, final)

    neg = jnp.asarray(-1, jnp.int32)
    z = jnp.zeros((), jnp.int32)
    return (state, bg, outbox, count, neg, z, z,
            jnp.asarray(SH_KEY, jnp.int32))


def h_range_item(state: ShardState, bg, me, row, outbox, count,
                 cfg: DiLiConfig):
    """One scanned pair arriving at the reply shard: echo it onto the
    completion lanes. ``comp_key`` carries the real key (> SH_KEY), which
    is what distinguishes an item row from a scalar completion."""
    return (state, bg, outbox, count, row[M.F_TS], row[M.F_VAL],
            row[M.F_SRC], row[M.F_KEY])


def range_prepass(state: ShardState, rows, me, outbox, count,
                  cfg: DiLiConfig):
    """Vectorized RANGE segment serve from valid packed blocks.

    Runs at round start, before any mutation, against the same snapshot
    ``refresh_blocks`` just validated. Up to ``range_lanes`` MSG_RANGE
    rows whose covering entry has a valid block are each answered with
    one masked gather over the block row; unservable cursors fall
    through to the serial ``h_range``. Returns
    ``(outbox, count, handled[n_rows], hits)``.
    """
    me = jnp.asarray(me, jnp.int32)
    kind = rows[:, M.F_KIND]
    n_rows = kind.shape[0]
    lanes = int(cfg.range_lanes)
    cand = kind == M.MSG_RANGE
    sel = jnp.argsort((~cand).astype(jnp.int32) * n_rows
                      + jnp.arange(n_rows, dtype=jnp.int32))[:lanes]
    lane = cand[sel]
    r = rows[sel]
    cursor = r[:, M.F_KEY]
    hi = r[:, M.F_X1]
    remaining = r[:, M.F_X3]
    emitted = r[:, M.F_X4]
    reply = r[:, M.F_SID]
    slot = r[:, M.F_TS]
    hops = r[:, M.F_X2]

    reg = state.registry
    blk = state.blk
    m, c = blk.keys.shape
    entry = reg_ops.get_by_key(reg, cursor)
    e = jnp.clip(entry, 0, m - 1)
    owned = refs.ref_sid(refs.unmarked(reg.subhead[e])) == me
    # a valid block IS the version check: chain entirely local,
    # non-moving, non-switched as of round start (DESIGN.md §12)
    usable = lane & (entry >= 0) & blk.valid[e] & owned \
        & (cursor < hi) & (remaining > 0)

    batch = jnp.minimum(jnp.asarray(int(cfg.range_batch), jnp.int32),
                        remaining)
    bkeys = blk.keys[e]                                        # [L, C]
    bvals = state.pool.keymax[pool_slot(state, blk.idx[e])]    # [L, C]
    in_span = (bkeys != ST_KEY) & (bkeys >= cursor[:, None]) \
        & (bkeys < hi[:, None])
    rank = jnp.cumsum(in_span.astype(jnp.int32), axis=1) - 1
    take = in_span & (rank < batch[:, None])
    got = jnp.sum(take.astype(jnp.int32), axis=1)

    items = _item_rows((lanes, c), me, reply[:, None], slot[:, None],
                       bkeys, bvals)
    do_items = usable[:, None] & take
    outbox, count = M.push_many(
        outbox, count, items.reshape(lanes * c, M.FIELDS),
        do_items.reshape(-1))

    # continuation / terminal — one row per served lane
    truncated = jnp.sum(in_span.astype(jnp.int32), axis=1) > batch
    last_taken = jnp.max(jnp.where(take, bkeys, SH_KEY), axis=1)
    ekmax = reg.keymax[e]
    total = emitted + got
    rem2 = remaining - got
    done = ((~truncated) & (ekmax >= hi - 1)) | (rem2 <= 0)
    nxt_cur = jnp.where(truncated, last_taken + 1, ekmax + 1)
    e2 = reg_ops.get_by_key(reg, nxt_cur)
    dst2 = jnp.where(
        e2 >= 0,
        refs.ref_sid(refs.unmarked(reg.subhead[jnp.clip(e2, 0, m - 1)])),
        me)
    final = jnp.zeros((lanes, M.FIELDS), M.MSG_DTYPE)
    final = final.at[:, M.F_KIND].set(
        jnp.where(done, M.MSG_RESULT, M.MSG_RANGE))
    final = final.at[:, M.F_DST].set(jnp.where(done, reply, dst2))
    final = final.at[:, M.F_SRC].set(me)
    final = final.at[:, M.F_A].set(jnp.where(done, total, 0))
    final = final.at[:, M.F_KEY].set(nxt_cur)
    final = final.at[:, M.F_X1].set(hi)
    final = final.at[:, M.F_X3].set(rem2)
    final = final.at[:, M.F_X4].set(total)
    final = final.at[:, M.F_SID].set(reply)
    final = final.at[:, M.F_TS].set(slot)
    final = final.at[:, M.F_X2].set(hops + 1)
    outbox, count = M.push_many(outbox, count, final, usable)

    handled = jnp.zeros((n_rows,), bool).at[sel].set(usable)
    return outbox, count, handled, jnp.sum(usable).astype(jnp.int32)
