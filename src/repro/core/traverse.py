"""Distributed list traversal — the paper's Algorithm 2 ``Search``.

Hybrid search: the registry binary search picked the subhead; here we do the
bounded linear traversal of one (or more, when crossing subtails mid-split)
sublists, Harris-style: marked nodes encountered are delinked on the way.

Status codes returned:
  * ``S_FOUND``    — right node located (first unmarked node with key' >= key
                     inside the covering sublist, or that sublist's SubTail).
  * ``S_DELEGATE`` — traversal left this shard's ownership: either the chain
                     crossed to a node owned by another shard (curr.id != me,
                     Line 41-42) or the sublist moved (stCt < 0 → head.newLoc,
                     Lines 23-28/53-55). ``deleg`` carries the subhead Ref to
                     continue from on the owner.
  * ``S_OVERFLOW`` — exceeded cfg.max_scan steps. Cannot happen while the load
                     balancer keeps sublists below the split threshold; tests
                     assert it never fires.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import refs
from .types import DiLiConfig, ShardState, SH_KEY, ST_KEY

S_FOUND = 0
S_DELEGATE = 1
S_OVERFLOW = 2


class ProbeOut(NamedTuple):
    ok: jnp.ndarray       # bool[B] — lane terminated cleanly within bound
    present: jnp.ndarray  # bool[B] — membership answer (valid where ok)
    left: jnp.ndarray     # int32[B] pool idx of the stop node's predecessor
    right: jnp.ndarray    # int32[B] pool idx of the stop node


def probe_batch(state: ShardState, head_idx, key, me, bound: int) -> ProbeOut:
    """Read-only batched traversal for the batched fast-paths (DESIGN.md
    §4/§4b).

    Walks every query's sublist chain simultaneously: one ``fori_loop`` of
    ``bound`` steps where each step advances all B cursors with vectorized
    pool gathers — the lock-step analogue of ``kernels/hybrid_search.py``'s
    bounded block sweep, run against the linked pool instead of packed
    blocks. Never mutates state (no Harris delinking, no counters).

    A lane is *clean* only while its walk touches exclusively local,
    unmarked, non-moving (newLoc == null), non-switched (stCt >= 0) nodes
    and terminates within ``bound`` steps. Anything else — a delegation
    boundary, a moved sublist, a marked node that the serial path would
    delink — makes the lane ineligible; the caller bounces it to the exact
    serial ``search``.

    ``ok`` lanes terminated cleanly; ``present`` is their membership
    answer; ``(left, right)`` is the Harris window the walk stopped at —
    ``right`` is the stop node (first node with key' >= key, or the
    covering SubTail) and ``left`` its predecessor (the SubHead when the
    walk stopped on its first step). The mutation fast-path
    (``core/batch_apply.py``) links inserts at ``left.nxt`` and marks
    removes at ``right.nxt``. NB the walk starts at ``head.nxt``, so
    ``left == head_idx`` lanes never had their left node screened here —
    callers must re-check the head before writing through it.
    """
    pool = state.pool
    n = pool.key.shape[0]
    key = jnp.asarray(key, jnp.int32)
    me = jnp.asarray(me, jnp.int32)
    head_idx = jnp.clip(jnp.asarray(head_idx, jnp.int32), 0, n - 1)

    def body(_, c):
        curr, prev, right, ok, done, present = c
        active = ok & (~done)
        idx = jnp.clip(refs.ref_idx(curr), 0, n - 1)

        remote = refs.ref_sid(curr) != me
        dead_end = refs.is_null(curr)
        curr_nxt = pool.nxt[idx]
        marked = refs.ref_mark(curr_nxt)
        switched = state.stct[jnp.clip(pool.ctr[idx], 0,
                                       state.stct.shape[0] - 1)] < 0
        moving = ~refs.is_null(pool.newloc[idx])
        bad = remote | dead_end | marked | switched | moving

        curr_key = pool.key[idx]
        is_sh = curr_key == SH_KEY
        is_st = curr_key == ST_KEY
        # stop at a covering SubTail (red lines 37-39) or the first node with
        # key' >= key; cross non-covering SubTails into the next sublist.
        st_stop = is_st & (key <= pool.keymax[idx])
        ord_stop = (~is_st) & (~is_sh) & (curr_key >= key)
        stop = (st_stop | ord_stop) & (~bad)

        ok = ok & jnp.where(active, ~bad, True)
        present = jnp.where(active & stop, (~is_st) & (curr_key == key),
                            present)
        right = jnp.where(active & stop, idx, right)
        done = done | (active & (stop | bad))
        advance = active & (~stop) & (~bad)
        prev = jnp.where(advance, idx, prev)
        curr = jnp.where(advance, curr_nxt, curr)
        return curr, prev, right, ok, done, present

    shape = key.shape
    init = (pool.nxt[head_idx], head_idx, head_idx,
            jnp.ones(shape, bool), jnp.zeros(shape, bool),
            jnp.zeros(shape, bool))

    # early-exit sweep: the fixed cost is the *longest* live lane, not the
    # bound — the balancer keeps that near split_threshold, typically well
    # under fast_scan_bound.
    def w_cond(c):
        i, (curr, prev, right, ok, done, present) = c
        return (i < bound) & jnp.any(ok & (~done))

    def w_body(c):
        i, carry = c
        return i + 1, body(i, carry)

    _, (_, prev, right, ok, done, present) = jax.lax.while_loop(
        w_cond, w_body, (jnp.zeros((), jnp.int32), init))
    return ProbeOut(ok=ok & done, present=present, left=prev, right=right)


class SearchOut(NamedTuple):
    status: jnp.ndarray   # int32
    left: jnp.ndarray     # int32 pool index of left node (valid if FOUND)
    right: jnp.ndarray    # int32 pool index of right node (valid if FOUND)
    head: jnp.ndarray     # int32 pool index of covering sublist's SubHead
    deleg: jnp.ndarray    # uint32 Ref to delegate to (valid if DELEGATE)
    nxt: jnp.ndarray      # updated pool.nxt (delinks applied)
    free_list: jnp.ndarray
    free_top: jnp.ndarray


def search(state: ShardState, head_idx, key, me, cfg: DiLiConfig) -> SearchOut:
    """Traverse from subhead ``head_idx`` for ``key`` on shard ``me``.

    Mutates (functionally) only pool.nxt (delinking) and the free list.
    A delinked node's slot is recycled; acknowledgement writes to recycled
    slots are guarded by the <sId, ts> identity check (see ops.py), the
    TPU-round analogue of hazard-pointer safety.
    """
    pool = state.pool
    nxt0 = pool.nxt
    key = jnp.asarray(key, jnp.int32)
    me = jnp.asarray(me, jnp.int32)
    head_idx = jnp.asarray(head_idx, jnp.int32)

    def moved(idx):
        # blue-line check: stCt of the node's counter slot went negative
        return state.stct[pool.ctr[idx]] < 0

    # carry: (nxt, free_list, free_top, prev, curr_ref, head, status, deleg, steps)
    def cond(c):
        return (c[6] < 0) & (c[8] < cfg.max_scan)

    def body(c):
        nxt, flist, ftop, prev, curr_ref, head, status, deleg, steps = c
        curr_sid = refs.ref_sid(curr_ref)
        curr_idx = refs.ref_idx(curr_ref)

        # --- crossed onto another shard's node (Line 41-42): delegate there.
        remote = curr_sid != me
        # --- node on a moved sublist (stCt < 0): delegate via head.newLoc.
        safe_idx = jnp.where(remote, 0, curr_idx)
        is_moved = (~remote) & moved(safe_idx)

        curr_key = pool.key[safe_idx]
        curr_nxt = nxt[safe_idx]
        curr_marked = refs.ref_mark(curr_nxt)
        is_sh = curr_key == SH_KEY
        is_st = curr_key == ST_KEY

        # entering a new sublist: its SubHead becomes the delegation anchor
        head2 = jnp.where((~remote) & is_sh, safe_idx, head)

        deleg_ref = jnp.where(remote, refs.unmarked(curr_ref),
                              refs.unmarked(pool.newloc[head2]))
        stop_deleg = remote | is_moved

        # --- marked node (and not a sentinel): delink it (Harris helping).
        # Exception (§5.4): items of a sublist being moved stay linked — the
        # mover still references them (its cursor) and the paper delinks
        # them "once the cloned sublist becomes active", on the target.
        # Recycling such a slot would dangle the move cursor. The check is
        # region-level, via the covering SubHead's newLoc, not just the
        # item's own: an item marked while its MoveItem copy is in flight
        # still has newLoc == null, and delinking it recycles the slot the
        # MOVE_ACK's <sId, ts> identity check needs — the ack's
        # marked-in-flight race RepDelete (h_move_ack Line 210) would be
        # silently skipped and the removed key would resurrect on the
        # target.
        do_delink = (~stop_deleg) & curr_marked & (~is_sh) & (~is_st) & \
            refs.is_null(pool.newloc[safe_idx]) & \
            refs.is_null(pool.newloc[head2])
        unlinked_to = refs.unmarked(curr_nxt)
        # preserve prev's own deletion mark when relinking (the mark lives
        # on prev's nxt word — same rule as replay's Line 260)
        prev_mark = nxt[prev] & jnp.uint32(refs.MARK_BIT)
        nxt = jnp.where(do_delink, nxt.at[prev].set(unlinked_to | prev_mark),
                        nxt)
        # recycle the slot
        pos = jnp.clip(ftop, 0, flist.shape[0] - 1)
        flist = jnp.where(do_delink, flist.at[pos].set(curr_idx), flist)
        ftop = ftop + do_delink.astype(jnp.int32)

        # --- SubTail: stop here if key is covered (red lines 37-39), else
        #     cross into the next sublist (red line 40).
        st_stop = (~stop_deleg) & is_st & (key <= pool.keymax[safe_idx])
        st_cross = (~stop_deleg) & is_st & (~st_stop)

        # --- ordinary stop: first node with key' >= key. A marked node that
        # ``do_delink`` exempted (item of a moving sublist, newLoc != null)
        # stops the walk too — it must stay linked for the mover's cursor,
        # and stopping keeps ``left`` unmarked — but it is NOT present:
        # callers must check right's mark before treating the key as found
        # (see key_present in ops.py).
        ord_stop = (~stop_deleg) & (~do_delink) & (~is_st) & (~is_sh) & \
            (curr_key >= key)

        stop_found = st_stop | ord_stop
        advance = (~stop_deleg) & (~do_delink) & (~stop_found)

        prev2 = jnp.where(advance, safe_idx, prev)
        next_ref = jnp.where(do_delink, unlinked_to, nxt[safe_idx])
        curr_ref2 = jnp.where(advance | do_delink, next_ref, curr_ref)

        status2 = jnp.where(stop_deleg, S_DELEGATE,
                            jnp.where(stop_found, S_FOUND, status))
        return (nxt, flist, ftop, prev2, curr_ref2, head2, status2,
                jnp.where(stop_deleg, deleg_ref, deleg), steps + 1)

    init = (nxt0, state.free_list, state.free_top,
            head_idx, nxt0[head_idx], head_idx,
            jnp.asarray(-1, jnp.int32), refs.null_ref(),
            jnp.zeros((), jnp.int32))
    nxt, flist, ftop, prev, curr_ref, head, status, deleg, _ = \
        jax.lax.while_loop(cond, body, init)

    status = jnp.where(status < 0, S_OVERFLOW, status)
    return SearchOut(
        status=status.astype(jnp.int32),
        left=prev,
        right=refs.ref_idx(curr_ref),
        head=head,
        deleg=deleg,
        nxt=nxt,
        free_list=flist,
        free_top=ftop,
    )
