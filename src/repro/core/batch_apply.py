"""Unified batched pre-pass: answer a round's eligible FINDs and *apply*
its eligible INSERT/REMOVE rows in one vectorized sweep (DESIGN.md §4/§4b).

The serial round answers every op through a per-row ``lax.while_loop``
pointer chase, so rows pay O(sum of path lengths) *sequential* steps. The
pre-pass is the §4 hybrid search applied to the round itself:

  1. one vectorized registry binary search over all op keys
     (``ops.resolve_route``) — shared by the read and write sides,
  2. one bounded lock-step gather-walk (``traverse.probe_batch``) over all
     candidate lanes, reads and writes together, returning presence plus
     each lane's Harris window ``(left, right)`` — sharing the sweep is
     what keeps the fixed cost at one walk per round,
  3. a *same-key group fold*: lanes are sorted by (key, row order) and a
     segmented scan replays each key group's serial semantics against its
     round-start presence — every lane's result, plus the group's *net*
     membership effect, falls out in O(log k) vector steps (zipfian rounds
     hammer a few hot keys; bouncing duplicates would send exactly the
     write-heavy rows this pass exists for back to the serial loop),
  4. a conflict screen that bounces every group the static schedule
     cannot guarantee (taxonomy below),
  5. one scatter-based apply of each surviving group's net effect: batched
     node allocation (free-list pops then bump), one ``nxt``-relink
     scatter preserving left-node marks, mark-bit sets for net removes,
     and stCt/endCt batch increments via ``segment_sum`` over counter
     slots — with the per-row logical-clock ticks replaced by a *block
     Lamport bump* (each materialized insert gets ``clock + rank``; the
     clock advances once by the insert count), which preserves the §8
     timestamp uniqueness/monotonicity lemmas.

Correctness (the commute argument, DESIGN.md §4/§4b): rounds linearize
rows in serial order, and an insert/remove changes the membership of *its
own key only* — so a *whole key group* (every round row carrying that key)
commutes with every other row of the round, as a result-and-membership
equivalence. The fold replays the group's internal serial order exactly;
group results and the group's net state change are therefore identical to
the serial loop's, at any interleaving with other keys' rows. Everything
outside the argument bounces to the exact serial ``ops.apply_op`` *by
construction*:

  * rounds carrying any non-benign message kind (replicate/move/switch
    traffic can change membership physically) — everything bounces;
  * incomplete groups: if ANY row of a key group is not an eligible
    candidate lane (a remote-client row, a delegating row, a row past the
    lane budget, a row of a side whose fast-path is disabled), the whole
    group bounces — partial application would reorder against the
    serial remainder;
  * shared link words: two groups writing the same ``nxt`` word (a net
    insert's ``left`` colliding with another group's ``left`` or net
    remove's node — adjacent keys racing for one link word): both bounce;
  * dirty walks: any group lane whose walk touched a marked, moving
    (newLoc != null), switched (stCt < 0) or remote node, ran past
    ``fast_scan_bound``, delegated or had no route — plus the same checks
    on a net insert's ``left`` node, which the walk never inspects when
    it is the SubHead itself;
  * allocator-pressure rounds: the whole batch bounces when pool room
    (free slots + bump space) comes within ``cfg.mut_alloc_headroom`` of
    the batch's allocation demand — the serial path owns the RES_POOLFULL
    edge.

Eligible groups emit *no* messages (local clients, not moving, not
delegating), so the serial rows' outbox positions — and with them
per-(src,dst) FIFO order — are untouched. ``tests/test_fastpath.py`` and
``tests/test_batch_apply.py`` check all of this differentially (each
fast-path on vs. off, op-for-op, under channel delays and
balancer-driven Split/Move/Merge churn).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import blocks as BL
from . import messages as M
from . import refs
from .ops import pool_slot, resolve_route
from .traverse import ProbeOut, probe_batch
from .types import (DiLiConfig, OP_FIND, OP_INSERT, OP_REMOVE, RES_FALSE,
                    RES_TRUE, ShardState)

# message kinds that cannot invalidate a round-start read or mutation
# window: padding, result routing (no list-state writes), client ops
# (same-key interactions are handled by the group fold) and RANGE rows
# (pure reads — the gather pre-pass serves them against the round-start
# snapshot before any fast-path mutation, and the serial walk never
# delinks; DESIGN.md §16).
_BENIGN_KINDS = (M.MSG_NONE, M.MSG_RESULT, M.MSG_OP, M.MSG_RANGE,
                 M.MSG_RANGE_ITEM)


class PreOut(NamedTuple):
    state: ShardState        # post-apply state (== input when no mut ran)
    find_elig: jnp.ndarray   # bool[R] — FIND answered here
    mut_elig: jnp.ndarray    # bool[R] — INSERT/REMOVE applied here
    res: jnp.ndarray         # int32[R] (valid where find_elig | mut_elig)
    blk_hits: jnp.ndarray    # int32 — eligible lanes whose stage-2 probe
                             # was the packed-block kernel (DESIGN.md §12)


def _count_eq(sorted_keys, query):
    """Occurrences of each ``query`` value in ``sorted_keys``."""
    return (jnp.searchsorted(sorted_keys, query, side="right")
            - jnp.searchsorted(sorted_keys, query, side="left"))


def batched_alloc(state: ShardState, want):
    """Vectorized node allocation over a boolean lane mask: free-list pops
    first, then bump — the exact policy of ``ops._alloc_node``. Shared by
    the mutation fast-path below and the batched move replay
    (``bg.replay``). Returns ``(new_idx, rank, n_ins, free_top2,
    alloc_top2)``; ``new_idx`` is only meaningful where ``want``.
    """
    cap = state.pool.key.shape[0]
    rank = jnp.cumsum(want.astype(jnp.int32)) - 1
    n_ins = jnp.sum(want.astype(jnp.int32))
    from_free = rank < state.free_top
    free_pos = jnp.clip(state.free_top - 1 - rank, 0,
                        state.free_list.shape[0] - 1)
    new_idx = jnp.where(from_free, state.free_list[free_pos],
                        state.alloc_top + (rank - state.free_top))
    new_idx = jnp.clip(new_idx, 0, cap - 1)
    free_top2 = state.free_top - jnp.minimum(n_ins, state.free_top)
    alloc_top2 = state.alloc_top + jnp.maximum(n_ins - state.free_top, 0)
    return new_idx, rank, n_ins, free_top2, alloc_top2


def _seg_last_nonzero(start, code):
    """Segmented inclusive scan of 'last nonzero code so far'."""
    def comb(a, b):
        ra, va = a
        rb, vb = b
        return ra | rb, jnp.where(rb | (vb != 0), vb, va)
    _, out = jax.lax.associative_scan(comb, (start, code))
    return out


def round_prepass(state: ShardState, rows, me, cfg: DiLiConfig,
                  *, run_find: bool, run_mut: bool) -> PreOut:
    """Classify + answer/apply the round's eligible rows. ``rows`` is the
    round's full [R, FIELDS] inbox+client block. ``run_find``/``run_mut``
    are the static cfg gates (find_fastpath / mut_fastpath)."""
    me = jnp.asarray(me, jnp.int32)
    kind = rows[:, M.F_KIND]
    op = rows[:, M.F_A]
    key = rows[:, M.F_KEY]
    n = key.shape[0]
    zb = jnp.zeros((n,), bool)
    zi = jnp.zeros((n,), jnp.int32)
    z0 = jnp.zeros((), jnp.int32)
    if not (run_find or run_mut):
        return PreOut(state, zb, zb, zi, z0)

    is_op = kind == M.MSG_OP
    benign = jnp.zeros(kind.shape, bool)
    for k in _BENIGN_KINDS:
        benign = benign | (kind == k)
    round_ok = jnp.all(benign)

    is_find = is_op & (op == OP_FIND)
    is_mut = is_op & ((op == OP_INSERT) | (op == OP_REMOVE))
    is_fir = is_find | is_mut
    local_client = rows[:, M.F_SID] == me

    # the sweep costs per round whether one lane rides or a hundred, so it
    # only pays off with enough candidates on at least one side; below
    # both cuts (and on drain / bg-message rounds) skip it wholesale. Once
    # it runs, the other side rides along for free.
    gate = jnp.zeros((), bool)
    if run_find:
        gate = gate | (jnp.sum(round_ok & is_find & local_client)
                       >= max(1, cfg.fast_min_batch))
    if run_mut:
        gate = gate | (jnp.sum(round_ok & is_mut & local_client)
                       >= max(1, cfg.mut_min_batch))
    bound = min(cfg.fast_scan_bound, cfg.max_scan)
    imax = jnp.iinfo(jnp.int32).max

    def run(_):
        rt = resolve_route(state, key, M.i2ref(rows[:, M.F_REF1]), me)
        routed = (~rt.no_route) & (rt.owner == me) & (~rt.head_moved)
        side_on = (is_find if run_find else zb) | \
            (is_mut if run_mut else zb)
        cand = round_ok & side_on & local_client & routed

        # compact candidates into k lanes before sweeping: inboxes are
        # sized for worst-case all-to-all fan-in (R can be 64x the client
        # batch) and the sweep costs per *lane*, not per candidate. k
        # covers a full client batch plus slack; overflow lanes just
        # bounce to the serial path (their whole key group with them).
        k = min(n, max(2 * cfg.batch_size, 64))
        sel = jnp.argsort((~cand).astype(jnp.int32) * n
                          + jnp.arange(n, dtype=jnp.int32))[:k]
        cand_k = cand[sel]
        key_k = key[sel]
        op_k = op[sel]
        ent_k = rt.entry[sel]
        pr = probe_batch(state, rt.head_idx[sel], key[sel], me, bound)

        # packed-block stage-2 probe (DESIGN.md §12): lanes whose entry
        # has a valid block are answered by the hybrid-search kernel's
        # window instead of the pointer walk; everything the block can't
        # vouch for (dirty/moving/switched rows, hint-vs-registry
        # disagreement) keeps the probe_batch verdict and, failing that,
        # bounces to the exact serial search.
        use_blk = jnp.zeros((k,), bool)
        if cfg.block_probe:
            b_ok, b_present, b_left, b_right = BL.probe_blocks(
                state, ent_k, rt.sh_ref[sel], key_k, me, cfg)
            use_blk = cand_k & b_ok
            pr = ProbeOut(
                ok=pr.ok | use_blk,
                present=jnp.where(use_blk, b_present, pr.present),
                left=jnp.where(use_blk, b_left, pr.left),
                right=jnp.where(use_blk, b_right, pr.right))

        pool = state.pool
        cap = pool.key.shape[0]
        left = pool_slot(state, pr.left)
        right = pool_slot(state, pr.right)

        # whole-group check: every op row of this key, eligible side or
        # not, must be a selected candidate lane — otherwise bounce the
        # group (padding lanes hold INT32_MAX, never a valid key).
        cnt_all = _count_eq(jnp.sort(jnp.where(is_fir, key, imax)), key_k)
        cnt_sel = _count_eq(jnp.sort(jnp.where(cand_k, key_k, imax)), key_k)
        whole = cnt_sel == cnt_all

        if not run_mut:
            # read-only side: finds never interact with each other, so
            # eligibility is per-lane — clean walk plus no same-key op row
            # outside the candidate set (``whole`` is the §4 rule that a
            # find colliding with any mutation bounces). The whole write
            # pipeline below drops out of the trace.
            elig_k = cand_k & pr.ok & whole
            res_k = jnp.where(pr.present, RES_TRUE, RES_FALSE)
            return (state, zb.at[sel].set(elig_k), zb,
                    zi.at[sel].set(res_k.astype(jnp.int32)),
                    jnp.sum(elig_k & use_blk).astype(jnp.int32))

        # ---- group fold: sort lanes by (key, original row position) so
        # each key group is a contiguous segment in serial order. Padding
        # lanes sort to one inert trailing segment.
        fold_key = jnp.where(cand_k, key_k, imax)
        s2 = jnp.lexsort((sel.astype(jnp.int32), fold_key))
        kf = fold_key[s2]
        start = jnp.concatenate(
            [jnp.ones((1,), bool), kf[1:] != kf[:-1]])
        sid_g = jnp.cumsum(start.astype(jnp.int32)) - 1   # segment ids
        candf = cand_k[s2]
        opf = op_k[s2]
        okf = (~candf) | pr.ok[s2]
        p0f = pr.present[s2]
        is_insf = candf & (opf == OP_INSERT)
        is_remf = candf & (opf == OP_REMOVE)

        # presence evolves as 'last membership-setting op wins': insert
        # sets present, remove sets absent, find passes through — a
        # segmented last-nonzero scan over codes gives presence *after*
        # every lane; shifting within the segment gives presence *before*.
        code = jnp.where(is_insf, 2, jnp.where(is_remf, 1, 0))
        last = _seg_last_nonzero(start, code)
        paft = jnp.where(last == 2, True, jnp.where(last == 1, False, p0f))
        pbef = jnp.where(start, p0f,
                         jnp.concatenate([p0f[:1], paft[:-1]]))

        # per-lane serial results and which mutations actually fire
        fired = (is_insf & (~pbef)) | (is_remf & pbef)
        resf = jnp.where(is_insf, ~pbef, pbef)

        # ---- per-group (segment) aggregates
        pos = jnp.arange(k, dtype=jnp.int32)
        lead = jnp.clip(jax.ops.segment_min(pos, sid_g, num_segments=k),
                        0, k - 1)
        lastp = jnp.clip(jax.ops.segment_max(pos, sid_g, num_segments=k),
                         0, k - 1)
        seg_has = jax.ops.segment_max(candf.astype(jnp.int32), sid_g,
                                      num_segments=k) > 0
        clean = jax.ops.segment_min(okf.astype(jnp.int32), sid_g,
                                    num_segments=k) > 0
        any_fired = jax.ops.segment_max(fired.astype(jnp.int32), sid_g,
                                        num_segments=k) > 0
        n_fired = jax.ops.segment_sum(fired.astype(jnp.int32), sid_g,
                                      num_segments=k)
        # the lane whose insert materializes the group's final node
        jstar = jax.ops.segment_max(jnp.where(fired & is_insf, pos, -1),
                                    sid_g, num_segments=k)

        p0_g = p0f[lead]
        pend_g = paft[lastp]
        whole_g = whole[s2][lead]
        left_g = left[s2][lead]
        right_g = right[s2][lead]

        # net effect per group: the original node is removed iff it was
        # present and any mutation fired (while present, only removes can
        # fire first); a fresh node materializes iff the group ends
        # present on a node other than the original.
        does_mark = seg_has & p0_g & any_fired
        does_ins = seg_has & pend_g & ~(p0_g & (~any_fired))

        # left-node screen: the walk starts at head.nxt, so a left that is
        # the SubHead itself was never inspected by the probe — re-check
        # marked / moving (newLoc != null) / switched (stCt < 0) on every
        # net insert's left before writing through its nxt word.
        left_bad = refs.ref_mark(pool.nxt[left_g]) \
            | (~refs.is_null(pool.newloc[left_g])) \
            | (state.stct[jnp.clip(pool.ctr[left_g], 0,
                                   state.stct.shape[0] - 1)] < 0)
        elig_g = seg_has & clean & whole_g & \
            jnp.where(does_ins, ~left_bad, True)

        # shared-link-word screen: each group claims the existing nxt
        # words it writes — a net insert claims left.nxt, a net remove
        # claims right.nxt (the node's own word; within a group the two
        # never coincide since left precedes right). Two groups claiming
        # one word are adjacent keys racing for a single link: both
        # bounce. Non-claiming slots get unique out-of-range tags.
        does_mark = does_mark & elig_g
        does_ins = does_ins & elig_g
        dummies = cap + jnp.arange(2 * k, dtype=jnp.int32)
        claim = jnp.concatenate([
            jnp.where(does_ins, left_g, dummies[:k]),
            jnp.where(does_mark, right_g, dummies[k:]),
        ])
        sc = jnp.sort(claim)
        shared2 = _count_eq(sc, claim) >= 2
        racing = shared2[:k] | shared2[k:]
        elig_g = elig_g & (~racing)
        does_mark = does_mark & (~racing)
        does_ins = does_ins & (~racing)

        # allocator-pressure screen (whole-batch): the serial path owns
        # pool exhaustion (RES_POOLFULL), so near the edge nothing applies.
        n_ins0 = jnp.sum(does_ins.astype(jnp.int32))
        room = state.free_top + (cap - state.alloc_top)
        alloc_ok = (n_ins0 + cfg.mut_alloc_headroom) <= room
        elig_g = elig_g & alloc_ok
        does_mark = does_mark & alloc_ok
        does_ins = does_ins & alloc_ok

        # ---- batched allocation (shared helper): free-list pops first,
        # then bump — the exact policy of ops._alloc_node over net inserts.
        new_idx, rank, n_ins, free_top2, alloc_top2 = batched_alloc(
            state, does_ins)

        # ---- block Lamport bump (DESIGN.md §4b/§8): one clock advance
        # covers the batch; each materialized node gets a unique,
        # monotone ts.
        new_ts = state.ts_clock + rank
        clock2 = state.ts_clock + n_ins

        # ---- single scatter-based apply of the groups' net effects.
        # Bounced groups scatter to an out-of-bounds index and drop; all
        # in-bounds targets are distinct by the screens above, so scatter
        # order cannot matter.
        drop = cap
        ins_at = jnp.where(does_ins, new_idx, drop)
        left_at = jnp.where(does_ins, left_g, drop)
        rem_at = jnp.where(does_mark, right_g, drop)
        left_ctr = pool.ctr[left_g]
        # eligible lefts are unmarked/non-moving by screen; preserving the
        # word's mark bit and inheriting newLoc keeps the write identical
        # to the serial relink (Line 189 / replay Line 260) regardless.
        left_mark = pool.nxt[left_g] & jnp.uint32(refs.MARK_BIT)
        new_ref = refs.make_ref(me, new_idx)
        key_g = key_k[s2][lead]
        val_g = rows[sel, M.F_VAL][s2][jnp.clip(jstar, 0, k - 1)]

        pool = pool._replace(
            key=pool.key.at[ins_at].set(key_g, mode="drop"),
            ts=pool.ts.at[ins_at].set(new_ts, mode="drop"),
            sid=pool.sid.at[ins_at].set(me, mode="drop"),
            ctr=pool.ctr.at[ins_at].set(left_ctr, mode="drop"),
            newloc=pool.newloc.at[ins_at].set(pool.newloc[left_g],
                                              mode="drop"),
            keymax=pool.keymax.at[ins_at].set(val_g, mode="drop"),
        )
        nxt = pool.nxt.at[ins_at].set(refs.make_ref(me, right_g),
                                      mode="drop")
        nxt = nxt.at[left_at].set(new_ref | left_mark, mode="drop")
        nxt = nxt.at[rem_at].set(refs.with_mark(state.pool.nxt[right_g]),
                                 mode="drop")
        pool = pool._replace(nxt=nxt)

        # ---- counter batch increments: stCt++ and endCt++ per *fired*
        # mutation, exactly the serial count (no eligible group is moving,
        # so no endCt deferral), summed per counter slot in one
        # segment_sum. left and right share a counter slot by
        # construction (a walk enters a sublist through its SubHead).
        w = elig_g & (n_fired > 0)
        slot = jnp.where(w, jnp.clip(left_ctr, 0, state.stct.shape[0] - 1),
                         0)
        bump = jax.ops.segment_sum(jnp.where(w, n_fired, 0), slot,
                                   num_segments=state.stct.shape[0])

        # ---- packed-block invalidation (DESIGN.md §12): a group that
        # changed its chain (net insert or net mark) dirties its entry's
        # block row. A fired group can carry entry == -1 — a hinted lane
        # routed by a replica that doesn't cover the key yet — and then
        # the mutated chain can't be attributed, so the whole mirror
        # drops. Counter-only groups (insert+remove folding to a net
        # no-op) leave membership intact and dirty nothing.
        ent_lead = ent_k[s2][lead]
        chain_mut = does_ins | does_mark
        mblk = state.blk.valid.shape[0]
        dirty_at = jnp.where(chain_mut & (ent_lead >= 0), ent_lead, mblk)
        blk_valid = state.blk.valid.at[dirty_at].set(False, mode="drop")
        blk_valid = jnp.where(jnp.any(chain_mut & (ent_lead < 0)),
                              jnp.zeros_like(blk_valid), blk_valid)

        st2 = state._replace(
            pool=pool,
            stct=state.stct + bump,
            endct=state.endct + bump,
            free_top=free_top2,
            alloc_top=alloc_top2,
            ts_clock=clock2,
            blk=state.blk._replace(valid=blk_valid),
        )

        # ---- scatter lane verdicts back to rows
        eligf = candf & elig_g[sid_g]
        elig_k = jnp.zeros((k,), bool).at[s2].set(eligf)
        res_k = jnp.zeros((k,), jnp.int32).at[s2].set(
            jnp.where(resf, RES_TRUE, RES_FALSE))
        is_find_k = op_k == OP_FIND
        felig = zb.at[sel].set(elig_k & is_find_k)
        melig = zb.at[sel].set(elig_k & (~is_find_k))
        hits = jnp.sum(elig_k & use_blk).astype(jnp.int32)
        return st2, felig, melig, zi.at[sel].set(res_k), hits

    def skip(_):
        return state, zb, zb, zi, z0

    st, felig, melig, res, bh = jax.lax.cond(gate, run, skip, None)
    return PreOut(state=st, find_elig=felig, mut_elig=melig, res=res,
                  blk_hits=bh)
