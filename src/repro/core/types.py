"""State containers for DiLi (Algorithm 1 of the paper, array-of-structs form).

Everything is a NamedTuple of JAX arrays so states are pytrees: jit-able,
shard_map-able and checkpointable with the rest of the framework.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from . import refs

# Sentinel keys. Real keys must lie strictly between them.
SH_KEY = -(2**31)          # SubHead
ST_KEY = 2**31 - 1         # SubTail
KEY_MIN = SH_KEY + 1
KEY_MAX = ST_KEY - 1
NEG_INF_CT = np.int32(-(2**31))  # the paper's stCt := -infinity

# Op kinds (client ops §5.2)
OP_NOP = 0
OP_FIND = 1
OP_INSERT = 2
OP_REMOVE = 3

# Result codes
RES_FALSE = 0
RES_TRUE = 1
RES_PENDING = -1      # not yet applied (e.g. delegated to another shard)


class DiLiConfig(NamedTuple):
    """Static capacities — all shapes derive from these (jit-static)."""
    num_shards: int = 1
    pool_capacity: int = 4096        # nodes per shard
    max_sublists: int = 256          # registry entries (global)
    max_ctrs: int = 256              # counter-slot pairs per shard
    max_scan: int = 512              # traversal bound (>= split_threshold + slack)
    batch_size: int = 64             # client ops per shard per round
    mailbox_cap: int = 64            # delegation/replicate slots per shard-pair round
    split_threshold: int = 125       # the paper's load-balancer threshold (§7.1)
    move_batch: int = 8              # MoveItems packed per round per slot (K)
    bg_slots: int = 2                # concurrent background ops per shard (B):
                                     # one BgTable row each, at most one op
                                     # per registry entry (DESIGN.md §10)
    move_fastpath: bool = True       # vectorized target-side replay of a
                                     # round's chain-contiguous MOVE_ITEMS
                                     # runs (one scatter splice instead of
                                     # K serial replay walks)
    quarantine_rounds: int = 4       # rounds before a switched chain is freed
    max_retries: int = 64            # replay requeue bound (tests assert << this)
    find_fastpath: bool = True       # batched FIND pre-pass (DESIGN.md §4)
    fast_scan_bound: int = 192       # fast-path walk bound (>= split_threshold
                                     # + insert slack; longer walks bounce to
                                     # the serial path)
    fast_min_batch: int = 4          # min local finds in a round to run the
                                     # pre-pass (below it the vector sweep
                                     # costs more than the serial rows saved)
    mut_fastpath: bool = True        # batched INSERT/REMOVE pre-pass
                                     # (DESIGN.md §4b)
    mut_min_batch: int = 4           # min eligible mutations in a round to
                                     # run the mutation pre-pass
    mut_alloc_headroom: int = 32     # bounce the whole mutation batch when
                                     # pool room (free slots + bump space)
                                     # falls within this margin of the
                                     # batch's allocation demand
    block_probe: bool = False        # packed-block stage-2 probe: answer
                                     # fast-path lanes via the Pallas
                                     # hybrid-search kernel over per-entry
                                     # key blocks (DESIGN.md §12); lanes
                                     # whose block is stale bounce to
                                     # probe_batch and the serial search
    block_cap: int = 160             # keys per packed block (>= the split
                                     # threshold + insert slack, like
                                     # fast_scan_bound; fuller sublists
                                     # simply never validate a block)
    replication: bool = False        # hot-sublist read replication
                                     # (DESIGN.md §15): compile the replica
                                     # serve pre-pass + publication engine
                                     # into shard_round. Off by default so
                                     # non-replicated runs pay nothing.
    replica_sessions: int = 2        # primary-side publication sessions per
                                     # shard (concurrently replicated
                                     # entries a shard can be primary for)
    replica_slots: int = 4           # replica-side image slots per shard
    replica_batch: int = 8           # delta rows a session streams per
                                     # round per target (outbox budget)
    replica_refresh_rounds: int = 8  # lease-renewal cadence: an idle
                                     # session republishes (or re-commits)
                                     # once this old, but only in rounds
                                     # where the primary saw live traffic —
                                     # a cluster at rest stays quiescent
    replica_staleness_rounds: int = 32  # hard staleness lease: a replica
                                     # slot serves for at most this many
                                     # rounds after its last commit, then
                                     # self-invalidates and bounces reads
                                     # to the primary
    range_scan: bool = False         # RANGE(lo, hi, limit) scan op
                                     # (DESIGN.md §16): compile the
                                     # packed-block gather pre-pass and
                                     # the serial chain-walk fallback
                                     # into shard_round. Off by default
                                     # so point-op runs pay nothing.
    range_lanes: int = 4             # RANGE cursors the gather pre-pass
                                     # serves per round; excess cursors
                                     # fall to the serial handler
    range_batch: int = 32            # items one RANGE cursor emits per
                                     # round per segment (outbox budget);
                                     # longer spans continue via a
                                     # self-forwarded narrowed cursor


class Pool(NamedTuple):
    """Per-shard node pool — the paper's ``struct Item`` fields, columnar.

    ``nxt`` carries the deletion mark of the *owning* node in its mark bit,
    exactly like Harris / the paper (mark lives on the next pointer).
    """
    key: jnp.ndarray      # int32[N]
    nxt: jnp.ndarray      # uint32[N] packed Ref (mark|sid|idx)
    ts: jnp.ndarray       # int32[N] logical timestamp at creation (Line 189)
    sid: jnp.ndarray      # int32[N] origin server id — <sId, ts> identity (§5.4)
    ctr: jnp.ndarray      # int32[N] counter-slot this node charges (stCt/endCt)
    newloc: jnp.ndarray   # uint32[N] Ref of the moved copy (NULL unless moving)
    keymax: jnp.ndarray   # int32[N] subtail keyMax (red lines 37-45); 0 otherwise


class Registry(NamedTuple):
    """The lazily-replicated sorted index (§5.1 / Algorithm 6).

    Entries sorted by keymin; entry i covers [keymin[i], keymax[i]).
    JAX immutability makes every update copy-on-write by construction.
    """
    keymin: jnp.ndarray   # int32[M]
    keymax: jnp.ndarray   # int32[M]
    subhead: jnp.ndarray  # uint32[M] packed Ref (owner shard in sid bits)
    subtail: jnp.ndarray  # uint32[M]
    ctr: jnp.ndarray      # int32[M] counter slot on the owner shard
    offset: jnp.ndarray   # int32[M] the paper's sublist offset (§5.3)
    size: jnp.ndarray     # int32[] live entry count


class Blocks(NamedTuple):
    """Packed-block mirror of the owned sublists (DESIGN.md §12): per
    registry entry, a contiguous sorted copy of the chain's live keys plus
    their pool slots — the Braginsky & Petrank chunked-sublist layout the
    paper's §8 points at, and the operand ``kernels/hybrid_search`` sweeps.

    A block is a *cache*, never the source of truth: ``valid[e]`` means
    row e byte-mirrors entry e's chain as of this round's start. Any
    mutation that could touch a chain or shift the registry clears valid
    bits (per-entry where the writer knows the entry, wholesale otherwise)
    — staleness is detectable, not silent.
    """
    keys: jnp.ndarray    # int32[M, C] sorted live keys, padding = ST_KEY
    idx: jnp.ndarray     # int32[M, C] pool slot of each key (valid where
                         #             keys != ST_KEY)
    valid: jnp.ndarray   # bool[M]


class RepSessions(NamedTuple):
    """Primary-side replication sessions (DESIGN.md §15): one row per
    entry this shard is currently publishing read replicas for. Sessions
    are keyed by the entry's keymax (like BgTable slots), not by registry
    index — registry indices shift under unrelated splits/merges, keymax
    is stable for the entry's upper half. ``keys`` holds the last image
    committed to (or being streamed at) the replicas; ``diff`` marks the
    positions of the in-flight publication still to stream.
    """
    keymax: jnp.ndarray   # int32[S]; SH_KEY = free session
    targets: jnp.ndarray  # int32[S] live replica bitmask (bit t = shard t)
    drops: jnp.ndarray    # int32[S] bitmask of targets owed a DROP row
    version: jnp.ndarray  # int32[S] publication version counter
    cursor: jnp.ndarray   # int32[S] stream position; -1 = idle/committed
    age: jnp.ndarray      # int32[S] rounds since last commit send
                          # (saturates at replica_refresh_rounds)
    keys: jnp.ndarray     # int32[S, C] published image, padding = ST_KEY
    diff: jnp.ndarray     # bool[S, C] positions still to stream


class ReplicaSlots(NamedTuple):
    """Replica-side read-only sublist images (DESIGN.md §15). A slot
    serves FINDs in (keymin, keymax] while its lease holds (ttl > 0 and a
    commit has been seen); an expired slot keeps its image but bounces
    reads home until the next commit renews the lease.
    """
    keymax: jnp.ndarray   # int32[R]; SH_KEY = free slot
    keymin: jnp.ndarray   # int32[R] serving range lower bound (exclusive)
    src: jnp.ndarray      # int32[R] primary shard id
    version: jnp.ndarray  # int32[R] last committed version; -1 = deltas
                          # arriving but no commit yet (not serving)
    ttl: jnp.ndarray      # int32[R] staleness lease, rounds remaining
    keys: jnp.ndarray     # int32[R, C] sorted image, padding = ST_KEY


class ShardState(NamedTuple):
    """Everything one 'server' (device) owns."""
    pool: Pool
    stct: jnp.ndarray       # int32[C] start counters
    endct: jnp.ndarray      # int32[C] end counters
    alloc_top: jnp.ndarray  # int32[] bump allocator head for pool
    free_list: jnp.ndarray  # int32[N] stack of freed node slots
    free_top: jnp.ndarray   # int32[] stack height
    ctr_top: jnp.ndarray    # int32[] bump allocator for counter slots
    ts_clock: jnp.ndarray   # int32[] logical clock (the paper's ts.fetch_add)
    registry: Registry      # this shard's (possibly stale) replica
    blk: Blocks             # packed-block sublist mirror (all-invalid until
                            # cfg.block_probe refreshes it)
    epoch: jnp.ndarray      # int32[] last membership epoch seen (merged
                            # monotonically by the MSG_EPOCH handler;
                            # DESIGN.md §13)
    peers: jnp.ndarray      # int32[] live-peer bitmask at that epoch —
                            # gates registry-broadcast fan-out so retired
                            # shards drop out of the mesh without a
                            # recompile (bit s set => shard s is a member)
    rep: RepSessions        # primary-side replication sessions (§15);
                            # all-free when replication is unused, and
                            # bit-static then — non-replicated runs keep
                            # their exact pre-replication state digests
    rslots: ReplicaSlots    # replica-side read images (§15)


class OpBatch(NamedTuple):
    """A round's client operations for one shard."""
    kind: jnp.ndarray     # int32[B] OP_*
    key: jnp.ndarray      # int32[B]


def empty_registry(cfg: DiLiConfig) -> Registry:
    m = cfg.max_sublists
    return Registry(
        keymin=jnp.full((m,), ST_KEY, jnp.int32),
        keymax=jnp.full((m,), ST_KEY, jnp.int32),
        subhead=jnp.full((m,), refs.NULL_REF, refs.REF_DTYPE),
        subtail=jnp.full((m,), refs.NULL_REF, refs.REF_DTYPE),
        ctr=jnp.zeros((m,), jnp.int32),
        offset=jnp.zeros((m,), jnp.int32),
        size=jnp.zeros((), jnp.int32),
    )


def empty_pool(cfg: DiLiConfig) -> Pool:
    n = cfg.pool_capacity
    assert n < refs.POOL_LIMIT, "pool exceeds 22-bit index space"
    return Pool(
        key=jnp.zeros((n,), jnp.int32),
        nxt=jnp.full((n,), refs.NULL_REF, refs.REF_DTYPE),
        ts=jnp.zeros((n,), jnp.int32),
        sid=jnp.zeros((n,), jnp.int32),
        ctr=jnp.zeros((n,), jnp.int32),
        newloc=jnp.full((n,), refs.NULL_REF, refs.REF_DTYPE),
        keymax=jnp.zeros((n,), jnp.int32),
    )


def empty_blocks(cfg: DiLiConfig) -> Blocks:
    m, c = cfg.max_sublists, cfg.block_cap
    return Blocks(
        keys=jnp.full((m, c), ST_KEY, jnp.int32),
        idx=jnp.zeros((m, c), jnp.int32),
        valid=jnp.zeros((m,), bool),
    )


def empty_rep_sessions(cfg: DiLiConfig) -> RepSessions:
    s, c = cfg.replica_sessions, cfg.block_cap
    return RepSessions(
        keymax=jnp.full((s,), SH_KEY, jnp.int32),
        targets=jnp.zeros((s,), jnp.int32),
        drops=jnp.zeros((s,), jnp.int32),
        version=jnp.zeros((s,), jnp.int32),
        cursor=jnp.full((s,), -1, jnp.int32),
        age=jnp.zeros((s,), jnp.int32),
        keys=jnp.full((s, c), ST_KEY, jnp.int32),
        diff=jnp.zeros((s, c), bool),
    )


def empty_replica_slots(cfg: DiLiConfig) -> ReplicaSlots:
    r, c = cfg.replica_slots, cfg.block_cap
    return ReplicaSlots(
        keymax=jnp.full((r,), SH_KEY, jnp.int32),
        keymin=jnp.full((r,), SH_KEY, jnp.int32),
        src=jnp.full((r,), -1, jnp.int32),
        version=jnp.full((r,), -1, jnp.int32),
        ttl=jnp.zeros((r,), jnp.int32),
        keys=jnp.full((r, c), ST_KEY, jnp.int32),
    )


def full_peer_mask(num_shards: int) -> int:
    """All-capacity live-peer bitmask; -1 (every bit set, and arithmetic
    shift keeps every probe true) once the count exceeds the int32 lane."""
    return -1 if num_shards >= 31 else (1 << num_shards) - 1


def init_shard(cfg: DiLiConfig, sid: int, *, bootstrap: bool = False,
               key_lo: int = KEY_MIN, key_hi: int = KEY_MAX,
               peers_mask: int | None = None) -> ShardState:
    """Fresh shard. If ``bootstrap``, seed one sublist (key_lo-1, key_hi] here.

    The bootstrap sublist is SubHead -> SubTail with counter slot 0, mirroring
    the paper's initial single-sublist list. Registry ranges are half-open
    (keymin, keymax] per Algorithm 6, so the stored keymin is key_lo - 1.
    """
    pool = empty_pool(cfg)
    reg = empty_registry(cfg)
    alloc_top = jnp.zeros((), jnp.int32)
    ctr_top = jnp.zeros((), jnp.int32)

    if bootstrap:
        # node 0 = SH, node 1 = ST
        sh_ref = refs.make_ref(sid, 0)
        st_ref = refs.make_ref(sid, 1)
        pool = pool._replace(
            key=pool.key.at[0].set(SH_KEY).at[1].set(ST_KEY),
            nxt=pool.nxt.at[0].set(st_ref),
            keymax=pool.keymax.at[1].set(key_hi),
            ctr=pool.ctr.at[0].set(0).at[1].set(0),
            ts=pool.ts.at[0].set(0).at[1].set(1),
            sid=pool.sid.at[0].set(sid).at[1].set(sid),
        )
        reg = reg._replace(
            keymin=reg.keymin.at[0].set(key_lo - 1),
            keymax=reg.keymax.at[0].set(key_hi),
            subhead=reg.subhead.at[0].set(sh_ref),
            subtail=reg.subtail.at[0].set(st_ref),
            ctr=reg.ctr.at[0].set(0),
            offset=reg.offset.at[0].set(0),
            size=jnp.ones((), jnp.int32),
        )
        alloc_top = jnp.asarray(2, jnp.int32)
        ctr_top = jnp.asarray(1, jnp.int32)

    return ShardState(
        pool=pool,
        stct=jnp.zeros((cfg.max_ctrs,), jnp.int32),
        endct=jnp.zeros((cfg.max_ctrs,), jnp.int32),
        alloc_top=alloc_top,
        free_list=jnp.full((cfg.pool_capacity,), -1, jnp.int32),
        free_top=jnp.zeros((), jnp.int32),
        ctr_top=ctr_top,
        ts_clock=jnp.asarray(2, jnp.int32),
        registry=reg,
        blk=empty_blocks(cfg),
        epoch=jnp.zeros((), jnp.int32),
        peers=jnp.asarray(full_peer_mask(cfg.num_shards)
                          if peers_mask is None else peers_mask, jnp.int32),
        rep=empty_rep_sessions(cfg),
        rslots=empty_replica_slots(cfg),
    )
