"""Message records for the round-based distributed runtime.

The paper's RPCs/async messages (delegation, replicates, move items, switch
notifications) become fixed-width int32 records routed between shards once
per round by an ``all_to_all`` (real mesh) or a vectorized permutation
(single-host simulation). Channels are reliable and FIFO per (src, dst)
pair — exactly the paper's "communication takes a finite number of steps"
condition of conditional lock-freedom (Definition 1).

A message is a row of ``FIELDS`` int32 lanes. Refs (uint32) are bitcast.

The reliable-and-FIFO channel property is *provided*, not assumed: when a
``core.net.Transport`` is interposed (any nemesis-enabled run), the raw
wire may drop, duplicate, reorder and delay frames, and the transport's
seq/ack/dedup machinery (DESIGN.md §11) restores exactly-once in-order
delivery per (src, dst) pair before rows reach ``shard_round``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------- kinds
MSG_NONE = 0
MSG_OP = 1              # client operation (fresh or delegated)        §5.2
MSG_RESULT = 2          # response routed back to the client's shard
MSG_REP_INSERT = 3      # RepInsertAfter replicate                     §5.4
MSG_REP_DELETE = 4      # RepDelete replicate                          §5.4
MSG_ACK_INSERT = 5      # InsertReplayResponse (sets newLoc, endCt++)  L264
MSG_ACK_DELETE = 6      # RemoveReplayResponse (endCt++)               L266
MSG_MOVE_SH = 7         # MoveSH: create SH/ST + counters on target    L215
MSG_MOVE_SH_ACK = 8
MSG_MOVE_ITEM = 9       # MoveItem: copy one item                      L240
MSG_MOVE_ACK = 10
MSG_SWITCH_ST = 11      # SwitchST: repoint previous subtail           L272
MSG_SWITCH_ST_ACK = 12
MSG_REG_SPLIT = 13      # RegisterSublist broadcast after Split        L159
MSG_SWITCH_SERVER = 14  # SwitchServer registry update broadcast       L285
MSG_REG_MERGED = 15     # RegisterMergedSublist broadcast              L360
MSG_MOVE_ITEMS = 16     # MoveItem batch member: one row of a chain-
                        # contiguous run the target may replay in a
                        # single scatter sweep (DESIGN.md §10); field
                        # layout is identical to MSG_MOVE_ITEM, so the
                        # serial handler is the universal fallback
MSG_NET_ACK = 17        # transport-level cumulative ack (DESIGN.md §11):
                        # consumed by core.net.Transport at the receiving
                        # host, never delivered to shard_round. It still
                        # gets a (no-op) dispatch branch so a leaked frame
                        # cannot clip onto a real handler.
MSG_EPOCH = 18          # membership-epoch announcement (DESIGN.md §13):
                        # F_KEY = epoch, F_X1 = live-peer bitmask. The
                        # handler merges monotonically (max on epoch), so
                        # duplicated/reordered deliveries are idempotent.
MSG_REPLICA_DELTA = 19  # read-replication image delta (DESIGN.md §15):
                        # F_KEY = entry keymax (replica-slot identity),
                        # F_X1 = image position, F_X3 = key at that
                        # position (ST_KEY clears it), F_X2 = publication
                        # version. Rewrites one cell of the replica image
                        # in place, so re-application is idempotent.
MSG_REPLICA_INSTALL = 20  # publication commit / lease grant: sent after a
                        # publication's deltas on the same FIFO lane, so
                        # the image it commits is fully applied on
                        # arrival. F_KEY = keymax, F_X1 = keymin,
                        # F_X2 = version, F_X3 = live key count. Resets
                        # the replica's staleness lease (ttl). A
                        # duplicate re-commits the same image — benign.
MSG_REPLICA_DROP = 21   # primary retires a replica: F_KEY = keymax.
                        # Frees the matching slot; a duplicate (or a drop
                        # for a slot never installed) finds no slot and
                        # is a no-op.
MSG_RANGE = 22          # range-scan segment cursor (DESIGN.md §16):
                        # F_KEY = cursor (inclusive lo of the remaining
                        # span), F_X1 = hi (exclusive), F_X3 = remaining
                        # item budget, F_X4 = items emitted so far,
                        # F_SID = reply shard, F_TS = client op slot,
                        # F_X2 = hops. Read-only: serves one covering
                        # registry entry, emits MSG_RANGE_ITEM rows, and
                        # either forwards a narrowed cursor or terminates
                        # with MSG_RESULT (F_A = total count emitted).
MSG_RANGE_ITEM = 23     # one scanned (key, value) pair flowing back to
                        # the reply shard: F_KEY = key, F_VAL = value,
                        # F_TS = client op slot, F_SRC = serving shard.
                        # Surfaced to the host through the completion
                        # lanes (comp_key marks it as an item, not a
                        # scalar result) — the device-path inbox never
                        # crosses to host, so completions are the only
                        # host-visible channel.
N_KINDS = 24            # dispatch-table size (shard_round lax.switch)

# ---------------------------------------------------------------- layout
# field meanings are per-kind; see docstrings at the emit sites.
F_KIND = 0
F_DST = 1
F_SRC = 2
F_A = 3        # op kind / flag / result value
F_KEY = 4
F_REF1 = 5     # primary ref (bitcast uint32): subhead / prev newLoc / new ref
F_SID = 6      # item identity: origin shard id          (<sId, ts> of §5.4)
F_TS = 7       # item identity: logical timestamp / client slot
F_X1 = 8       # oldLoc pool index / keymax / marked flag
F_X2 = 9       # hops / prev_sid / ok flag
F_X3 = 10      # prev_ts / secondary ref (bitcast)
F_X4 = 11      # spare (client slot for MSG_OP)
F_VAL = 12     # item payload value (page slot etc.) — rides with inserts
F_SLOT = 13    # background slot id (BgTable row) a move/switch message
               # belongs to; echoed by acks so concurrent background ops
               # on one shard credit the right slot
F_SEQ = 14     # per-(src,dst)-lane sequence number stamped by the
               # reliable transport (core.net, DESIGN.md §11); 0 for
               # frames that never crossed a transport (direct routing,
               # self-retries) and for unsequenced MSG_NET_ACK frames.
               # For MSG_NET_ACK, F_A carries the cumulative ack cursor.
FIELDS = 15

MSG_DTYPE = jnp.int32


def ref2i(ref):
    """Bitcast a uint32 Ref into an int32 message lane."""
    return jax.lax.bitcast_convert_type(jnp.asarray(ref, jnp.uint32), jnp.int32)


def i2ref(i):
    """Bitcast an int32 message lane back into a uint32 Ref."""
    return jax.lax.bitcast_convert_type(jnp.asarray(i, jnp.int32), jnp.uint32)


def empty_outbox(cap: int):
    """(buffer[cap, FIELDS], count) — MSG_NONE rows are padding."""
    return jnp.zeros((cap, FIELDS), MSG_DTYPE), jnp.zeros((), jnp.int32)


def push(outbox, count, row, do: bool | jnp.ndarray = True):
    """Functionally append ``row`` when ``do``.

    ``count`` counts every *attempted* push, so it can exceed the buffer
    capacity; rows past the cap are not stored. A final count above the cap
    is the overflow signal: the routing layer must fail the round loudly
    (``sim.Cluster.step`` raises ``OutboxOverflow`` unconditionally — a
    dropped replicate/ack would deadlock the protocol silently). Capacities
    are budgeted so healthy rounds never overflow.
    """
    cap = outbox.shape[0]
    do = jnp.asarray(do)
    pos = jnp.clip(count, 0, cap - 1)
    new = jnp.where(do & (count < cap), outbox.at[pos].set(row), outbox)
    return new, count + do.astype(jnp.int32)


def push_many(outbox, count, rows, do):
    """Functionally append every ``rows[i]`` where ``do[i]``, in order —
    one scatter instead of ``len(rows)`` chained ``push`` calls (the
    replication publisher emits hundreds of candidate rows per round and
    per-row pushes dominate the round's op count). Order is preserved, so
    the per-lane FIFO contract holds exactly as with sequential ``push``;
    ``count`` counts every attempted push and rows past the cap are
    dropped, leaving the final count as the overflow signal."""
    cap = outbox.shape[0]
    do = jnp.asarray(do)
    idx = count + jnp.cumsum(do.astype(jnp.int32)) - 1
    at = jnp.where(do & (idx < cap), idx, cap)
    outbox = outbox.at[at].set(rows, mode="drop")
    return outbox, count + jnp.sum(do.astype(jnp.int32))


def make_row(kind, dst, src, *, a=0, key=0, ref1=0, sid=0, ts=0,
             x1=0, x2=0, x3=0, x4=0, val=0, slot=0, seq=0):
    vals = [kind, dst, src, a, key, ref1, sid, ts, x1, x2, x3, x4, val,
            slot, seq]
    return jnp.stack([jnp.asarray(v, MSG_DTYPE) for v in vals])
