"""Lock-free-style skip list baseline (Fraser [11]) in functional JAX.

The paper benchmarks DiLi against a lock-free skip list (Fig. 3a); this is
that comparator under the same batched-linearization execution model as the
DiLi core, so single-machine throughput comparisons are apples-to-apples:
both implementations pay the same per-op JAX dispatch and differ only in
traversal structure (O(log n) tower descent vs registry binary search +
bounded scan).

Deterministic tower heights come from a hash of the key (matching the
standard p=1/2 geometric distribution in expectation), which keeps the
structure reproducible across runs.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

HEAD = 0          # sentinel node index (key = -inf)
NIL = -1          # end-of-level


class SkipList(NamedTuple):
    key: jnp.ndarray      # int32[N]
    nxt: jnp.ndarray      # int32[L, N]  next pointers per level
    live: jnp.ndarray     # bool[N]
    height: jnp.ndarray   # int32[N]
    alloc_top: jnp.ndarray
    free_list: jnp.ndarray
    free_top: jnp.ndarray


def _key_height(key, max_level: int):
    """Deterministic geometric(1/2) height from a key hash."""
    h = jnp.uint32(key) * jnp.uint32(0x9E3779B9)
    h ^= h >> 16
    h = h * jnp.uint32(0x85EBCA6B)
    h ^= h >> 13
    # count trailing ones => geometric
    lvl = jnp.int32(1)
    for i in range(max_level - 1):
        lvl = lvl + ((h >> i) & 1).astype(jnp.int32) * (lvl == i + 1)
    return jnp.clip(lvl, 1, max_level)


def init(capacity: int, max_level: int) -> SkipList:
    key = jnp.full((capacity,), -(2 ** 31), jnp.int32)
    nxt = jnp.full((max_level, capacity), NIL, jnp.int32)
    live = jnp.zeros((capacity,), bool).at[HEAD].set(True)
    height = jnp.zeros((capacity,), jnp.int32).at[HEAD].set(max_level)
    return SkipList(key=key, nxt=nxt, live=live, height=height,
                    alloc_top=jnp.asarray(1, jnp.int32),
                    free_list=jnp.full((capacity,), -1, jnp.int32),
                    free_top=jnp.zeros((), jnp.int32))


def _find_preds(sl: SkipList, key, max_level: int, max_steps: int):
    """Descend the towers; returns preds[L] and the level-0 successor."""
    def level_body(carry, lvl_rev):
        node, steps = carry
        lvl = max_level - 1 - lvl_rev

        def cond(c):
            node, steps = c
            nx = sl.nxt[lvl, node]
            ok = (nx != NIL)
            nx_c = jnp.clip(nx, 0, sl.key.shape[0] - 1)
            return ok & (sl.key[nx_c] < key) & (steps < max_steps)

        def body(c):
            node, steps = c
            return sl.nxt[lvl, node], steps + 1

        node, steps = jax.lax.while_loop(cond, body, (node, steps))
        return (node, steps), node

    (node, _), preds_rev = jax.lax.scan(
        level_body, (jnp.asarray(HEAD, jnp.int32), jnp.zeros((), jnp.int32)),
        jnp.arange(max_level))
    preds = preds_rev[::-1]
    succ = sl.nxt[0, node]
    return preds, succ


def find(sl: SkipList, key, max_level: int, max_steps: int = 1 << 30):
    _, succ = _find_preds(sl, key, max_level, max_steps)
    succ_c = jnp.clip(succ, 0, sl.key.shape[0] - 1)
    return (succ != NIL) & (sl.key[succ_c] == key)


def insert(sl: SkipList, key, max_level: int, max_steps: int = 1 << 30):
    preds, succ = _find_preds(sl, key, max_level, max_steps)
    succ_c = jnp.clip(succ, 0, sl.key.shape[0] - 1)
    present = (succ != NIL) & (sl.key[succ_c] == key)

    has_free = sl.free_top > 0
    free_idx = sl.free_list[jnp.clip(sl.free_top - 1, 0, None)]
    bump_ok = sl.alloc_top < sl.key.shape[0]
    idx = jnp.where(has_free, free_idx, sl.alloc_top)
    ok = (~present) & (has_free | bump_ok)

    h = _key_height(key, max_level)
    lvl_idx = jnp.arange(max_level)
    in_tower = (lvl_idx < h) & ok
    # splice: new.nxt[l] = preds[l].nxt[l]; preds[l].nxt[l] = idx
    pred_next = sl.nxt[lvl_idx, preds]
    nxt = sl.nxt
    nxt = jnp.where(in_tower[:, None],
                    nxt.at[lvl_idx, idx].set(pred_next), nxt)
    nxt = jnp.where(in_tower[:, None],
                    nxt.at[lvl_idx, preds].set(idx), nxt)

    sl = sl._replace(
        key=jnp.where(ok, sl.key.at[idx].set(key), sl.key),
        live=jnp.where(ok, sl.live.at[idx].set(True), sl.live),
        height=jnp.where(ok, sl.height.at[idx].set(h), sl.height),
        nxt=nxt,
        free_top=sl.free_top - (ok & has_free).astype(jnp.int32),
        alloc_top=sl.alloc_top + (ok & ~has_free & bump_ok).astype(jnp.int32),
    )
    return sl, ok


def remove(sl: SkipList, key, max_level: int, max_steps: int = 1 << 30):
    preds, succ = _find_preds(sl, key, max_level, max_steps)
    succ_c = jnp.clip(succ, 0, sl.key.shape[0] - 1)
    present = (succ != NIL) & (sl.key[succ_c] == key)
    idx = succ_c
    h = sl.height[idx]
    lvl_idx = jnp.arange(max_level)
    in_tower = (lvl_idx < h) & present
    # unsplice every level where pred points at idx
    pred_next = sl.nxt[lvl_idx, preds]
    tgt = sl.nxt[lvl_idx, idx]
    do = in_tower & (pred_next == idx)
    nxt = jnp.where(do[:, None], sl.nxt.at[lvl_idx, preds].set(tgt), sl.nxt)
    pos = jnp.clip(sl.free_top, 0, sl.free_list.shape[0] - 1)
    sl = sl._replace(
        nxt=nxt,
        live=jnp.where(present, sl.live.at[idx].set(False), sl.live),
        key=jnp.where(present, sl.key.at[idx].set(-(2 ** 31)), sl.key),
        free_list=jnp.where(present, sl.free_list.at[pos].set(idx),
                            sl.free_list),
        free_top=sl.free_top + present.astype(jnp.int32),
    )
    return sl, present


def apply_batch(sl: SkipList, kinds, keys, max_level: int):
    """Sequentially linearized batch, mirroring the DiLi round model."""
    from .types import OP_FIND, OP_INSERT, OP_REMOVE

    def step(sl, x):
        kind, key = x
        f = find(sl, key, max_level)
        sl_i, r_i = insert(sl, key, max_level)
        sl_r, r_r = remove(sl, key, max_level)
        is_i = kind == OP_INSERT
        is_r = kind == OP_REMOVE
        sl = jax.tree_util.tree_map(
            lambda a, b, c: jnp.where(is_i, b, jnp.where(is_r, c, a)),
            sl, sl_i, sl_r)
        res = jnp.where(kind == OP_FIND, f,
                        jnp.where(is_i, r_i, r_r)).astype(jnp.int32)
        return sl, res

    return jax.lax.scan(step, sl, (jnp.asarray(kinds, jnp.int32),
                                   jnp.asarray(keys, jnp.int32)))
