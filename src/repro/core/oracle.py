"""Sequential oracle for DiLi client semantics.

A linearizable sorted set: applying the same linearized op sequence to the
oracle and to DiLi (in DiLi's linearization order) must give identical
results and identical final key sets — regardless of any interleaved
Split/Move/Switch/Merge background operations (which are invisible to
clients). This is the property every system test asserts.
"""
from __future__ import annotations

from typing import Iterable, List, Sequence, Tuple

from .types import OP_FIND, OP_INSERT, OP_NOP, OP_REMOVE


class OracleList:
    """Plain sorted-set semantics of find/insert/remove."""

    def __init__(self, keys: Iterable[int] = ()):  # noqa: D107
        self._keys = set(int(k) for k in keys)

    def find(self, key: int) -> bool:
        return int(key) in self._keys

    def insert(self, key: int) -> bool:
        key = int(key)
        if key in self._keys:
            return False
        self._keys.add(key)
        return True

    def remove(self, key: int) -> bool:
        key = int(key)
        if key not in self._keys:
            return False
        self._keys.remove(key)
        return True

    def apply(self, kind: int, key: int) -> bool:
        if kind == OP_FIND:
            return self.find(key)
        if kind == OP_INSERT:
            return self.insert(key)
        if kind == OP_REMOVE:
            return self.remove(key)
        if kind == OP_NOP:
            return False
        raise ValueError(f"unknown op kind {kind}")

    def apply_batch(self, kinds: Sequence[int], keys: Sequence[int]) -> List[bool]:
        return [self.apply(int(k), int(x)) for k, x in zip(kinds, keys)]

    def snapshot(self) -> Tuple[int, ...]:
        return tuple(sorted(self._keys))

    def __len__(self) -> int:
        return len(self._keys)

    def __contains__(self, key: int) -> bool:
        return int(key) in self._keys
