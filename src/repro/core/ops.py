"""Client operations — the paper's Find / Insert / Remove (§5.2, Alg. 2-3).

Each op is applied atomically within a round (rounds linearize the per-shard
op order; see DESIGN.md §2 "batched linearization"). The paper's CAS race
outcomes are reproduced by the *order* of application; the cross-round races
(background Split/Move/Switch, replicate delivery) are the real concurrency
and follow the paper's counter/replicate protocol exactly:

  * stCt is incremented before an update, endCt after it (§5.4);
  * if the sublist is moving (head.newLoc != null propagated to items via
    Line 189's newLoc inheritance), the endCt increment is deferred until the
    replay acknowledgement (Lines 264-267) — that is what Move's termination
    CAS observes;
  * ops that hit a switched sublist (stCt < 0) are delegated (blue lines).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import messages as M
from . import refs, registry as reg_ops
from .traverse import S_DELEGATE, S_FOUND, S_OVERFLOW, search
from .types import (DiLiConfig, OP_FIND, OP_INSERT, OP_NOP, OP_REMOVE,
                    RES_FALSE, RES_PENDING, RES_TRUE, ShardState, ST_KEY)

RES_OVERFLOW = -2   # traversal bound exceeded — tests assert never seen
RES_POOLFULL = -3   # allocator exhausted — tests assert never seen


class OpOut(NamedTuple):
    state: ShardState
    result: jnp.ndarray      # int32 RES_*
    outbox: jnp.ndarray
    count: jnp.ndarray


class Route(NamedTuple):
    """Resolved subhead for an op (Find lines 72-74). Vectorizes over keys."""
    sh_ref: jnp.ndarray      # uint32 subhead Ref (hint, or registry entry)
    owner: jnp.ndarray       # int32 shard id owning the subhead
    head_idx: jnp.ndarray    # int32 pool index of the subhead on ``owner``
    head_moved: jnp.ndarray  # bool — subhead's sublist switched away (stCt<0)
    head_newloc: jnp.ndarray # uint32 forwarding Ref when head_moved
    no_route: jnp.ndarray    # bool — registry has no covering entry
    entry: jnp.ndarray       # int32 covering registry entry on this shard's
                             # replica (-1 if none) — the packed-block row
                             # a block-probe lane addresses; hinted lanes
                             # may route fine with entry == -1 on a stale
                             # replica, so callers must not require it


def pool_slot(state: ShardState, idx):
    """Clip a (possibly hostile) pool index into the pool's single capacity
    bound. Every ``Pool`` column shares ``pool.key.shape[0]`` — route every
    clamped gather through this helper so a future capacity split cannot
    leave one column clipped against another's bound (an out-of-bounds
    gather in disguise)."""
    return jnp.clip(idx, 0, state.pool.key.shape[0] - 1)


def resolve_route(state: ShardState, key, sh_hint, me) -> Route:
    """Resolve the subhead an op must start from, shared by the serial
    ``apply_op`` path and the batched fast-paths (DESIGN.md §4/§4b).

    A null/stale hint forces a registry lookup; a hinted subhead that has
    itself moved (stCt < 0) forwards via its newLoc. All lanes vectorize:
    ``key``/``sh_hint`` may be scalars or equally-shaped arrays.
    """
    me = jnp.asarray(me, jnp.int32)
    need_lookup = refs.is_null(sh_hint)
    entry = reg_ops.get_by_key(state.registry, key)
    entry_sh = state.registry.subhead[jnp.clip(entry, 0, None)]
    sh_ref = jnp.where(need_lookup, entry_sh, sh_hint)
    no_route = need_lookup & (entry < 0)

    owner = refs.ref_sid(sh_ref)
    head_idx = refs.ref_idx(sh_ref)

    safe_head = pool_slot(state, head_idx)
    head_ctr = state.pool.ctr[safe_head]
    head_moved = (owner == me) & (state.stct[head_ctr] < 0)
    head_newloc = refs.unmarked(state.pool.newloc[safe_head])
    return Route(sh_ref=sh_ref, owner=owner, head_idx=head_idx,
                 head_moved=head_moved, head_newloc=head_newloc,
                 no_route=no_route, entry=jnp.asarray(entry, jnp.int32))


def _alloc_node(state: ShardState):
    """Pop the free list, else bump-allocate. Returns (state, idx, ok)."""
    has_free = state.free_top > 0
    free_idx = state.free_list[jnp.clip(state.free_top - 1, 0, None)]
    bump_ok = state.alloc_top < state.pool.key.shape[0]
    idx = jnp.where(has_free, free_idx, state.alloc_top)
    ok = has_free | bump_ok
    state = state._replace(
        free_top=state.free_top - has_free.astype(jnp.int32),
        alloc_top=state.alloc_top + ((~has_free) & bump_ok).astype(jnp.int32),
    )
    return state, jnp.where(ok, idx, 0), ok


def _tick(state: ShardState):
    ts = state.ts_clock
    return state._replace(ts_clock=ts + 1), ts


def apply_op(state: ShardState, me, row, outbox, count,
             cfg: DiLiConfig) -> OpOut:
    """Apply one MSG_OP row (fresh client op or delegated op).

    Row fields: a=op kind, key, ref1=subhead hint (NULL => registry lookup),
    sid=reply shard, ts/x4=client slot, x2=hops.
    """
    me = jnp.asarray(me, jnp.int32)
    kind = row[M.F_A]
    key = row[M.F_KEY]
    sh_hint = M.i2ref(row[M.F_REF1])
    reply_sid = row[M.F_SID]
    slot = row[M.F_TS]
    hops = row[M.F_X2]

    # ------------------------------------------------ resolve the subhead
    rt = resolve_route(state, key, sh_hint, me)
    sh_ref, owner, head_idx = rt.sh_ref, rt.owner, rt.head_idx
    no_route = rt.no_route

    deleg_now = (owner != me) | rt.head_moved
    deleg_ref = jnp.where(owner != me, refs.unmarked(sh_ref), rt.head_newloc)

    # ------------------------------------------------ traverse
    do_search = (~no_route) & (~deleg_now) & (kind != OP_NOP)
    s = search(state, jnp.where(do_search, head_idx, 0), key, me, cfg)
    state = state._replace(
        pool=state.pool._replace(nxt=jnp.where(do_search, s.nxt, state.pool.nxt)),
        free_list=jnp.where(do_search, s.free_list, state.free_list),
        free_top=jnp.where(do_search, s.free_top, state.free_top),
    )

    deleg_now = deleg_now | (do_search & (s.status == S_DELEGATE))
    deleg_ref = jnp.where(do_search & (s.status == S_DELEGATE), s.deleg, deleg_ref)
    overflow = do_search & (s.status == S_OVERFLOW)
    found_ok = do_search & (s.status == S_FOUND)

    left, right = s.left, s.right
    right_key = state.pool.key[right]
    # a marked right is NOT present: the search cannot delink items of a
    # moving sublist (newLoc != null), so a deleted-while-moving node may
    # still be returned — treat it as absent. An insert then places the new
    # (unmarked) node before it, so first-unmarked-wins order is preserved.
    right_marked = refs.ref_mark(state.pool.nxt[right])
    key_present = found_ok & (right_key == key) & (~right_marked)

    # ------------------------------------------------ FIND
    find_res = jnp.where(key_present, RES_TRUE, RES_FALSE)

    # ------------------------------------------------ INSERT (Alg. 3)
    do_insert = found_ok & (kind == OP_INSERT) & (~key_present)
    state, new_idx, alloc_ok = jax.lax.cond(
        do_insert, _alloc_node, lambda st: (st, jnp.zeros((), jnp.int32),
                                            jnp.asarray(True)), state)
    state, new_ts = _tick(state)
    ins_ok = do_insert & alloc_ok

    left_ctr = state.pool.ctr[left]
    left_newloc = state.pool.newloc[left]
    moving = ~refs.is_null(left_newloc)

    pool = state.pool
    right_ref = refs.make_ref(me, right)
    new_ref = refs.make_ref(me, new_idx)

    def _set(col, idx, val, do):
        return jnp.where(do, col.at[idx].set(val), col)

    pool = pool._replace(
        key=_set(pool.key, new_idx, key, ins_ok),
        ts=_set(pool.ts, new_idx, new_ts, ins_ok),
        sid=_set(pool.sid, new_idx, me, ins_ok),
        ctr=_set(pool.ctr, new_idx, left_ctr, ins_ok),
        # Line 189: the new item inherits leftNode.newLoc — non-null marks
        # "this region is being moved", making the mover skip it (Line 207)
        # while the replicate recreates it on the target.
        newloc=_set(pool.newloc, new_idx, left_newloc, ins_ok),
        # keymax doubles as the item payload (page slot) on non-sentinels
        keymax=_set(pool.keymax, new_idx, row[M.F_VAL], ins_ok),
    )
    pool = pool._replace(nxt=_set(pool.nxt, new_idx, right_ref, ins_ok))
    # preserve left's own deletion mark when relinking (left can be a marked
    # moving item the search could not delink — replay's Line 260 rule)
    left_mark = pool.nxt[left] & jnp.uint32(refs.MARK_BIT)
    pool = pool._replace(nxt=_set(pool.nxt, left, new_ref | left_mark, ins_ok))
    state = state._replace(pool=pool)

    # counters: stCt++ always; endCt++ only if no replicate (else deferred)
    state = state._replace(
        stct=jnp.where(ins_ok, state.stct.at[left_ctr].add(1), state.stct),
        endct=jnp.where(ins_ok & ~moving,
                        state.endct.at[left_ctr].add(1), state.endct),
    )
    rep_ins_row = M.make_row(
        M.MSG_REP_INSERT, refs.ref_sid(left_newloc), me,
        key=key, ref1=M.ref2i(refs.unmarked(left_newloc)),
        x2=state.pool.sid[left], x3=state.pool.ts[left],
        sid=me, ts=new_ts, x1=new_idx, x4=left_ctr, val=row[M.F_VAL])
    outbox, count = M.push(outbox, count, rep_ins_row, ins_ok & moving)
    ins_res = jnp.where(key_present, RES_FALSE,
                        jnp.where(alloc_ok, RES_TRUE, RES_POOLFULL))

    # ------------------------------------------------ REMOVE (Delete, Alg. 2)
    do_remove = found_ok & (kind == OP_REMOVE) & key_present
    node = right
    node_ctr = state.pool.ctr[node]
    node_newloc = state.pool.newloc[node]
    node_moving = ~refs.is_null(node_newloc)

    marked_nxt = refs.with_mark(state.pool.nxt[node])
    state = state._replace(pool=state.pool._replace(
        nxt=_set(state.pool.nxt, node, marked_nxt, do_remove)))
    state = state._replace(
        stct=jnp.where(do_remove, state.stct.at[node_ctr].add(1), state.stct),
        endct=jnp.where(do_remove & ~node_moving,
                        state.endct.at[node_ctr].add(1), state.endct),
    )
    rep_del_row = M.make_row(
        M.MSG_REP_DELETE, refs.ref_sid(node_newloc), me,
        key=key, ref1=M.ref2i(refs.unmarked(node_newloc)),
        sid=state.pool.sid[node], ts=state.pool.ts[node],
        x1=node, x2=1, x4=node_ctr)  # x2=1: ack carries the deferred endCt
    outbox, count = M.push(outbox, count, rep_del_row, do_remove & node_moving)
    rem_res = jnp.where(key_present, RES_TRUE, RES_FALSE)

    # ------------------------------------------------ result / routing
    result = jnp.where(kind == OP_FIND, find_res,
                       jnp.where(kind == OP_INSERT, ins_res,
                                 jnp.where(kind == OP_REMOVE, rem_res,
                                           RES_FALSE)))
    result = jnp.where(overflow, RES_OVERFLOW, result)
    result = jnp.where(no_route, RES_FALSE, result)
    result = jnp.where(deleg_now, RES_PENDING, result)

    # delegate: forward the op with the resolved subhead ref (Thm 4 hops)
    deleg_row = M.make_row(
        M.MSG_OP, refs.ref_sid(deleg_ref), me,
        a=kind, key=key, ref1=M.ref2i(deleg_ref),
        sid=reply_sid, ts=slot, x2=hops + 1)
    outbox, count = M.push(outbox, count, deleg_row,
                           deleg_now & (kind != OP_NOP))

    # completed op for a remote client: route the result home
    res_row = M.make_row(M.MSG_RESULT, reply_sid, me, a=result, ts=slot)
    outbox, count = M.push(
        outbox, count, res_row,
        (~deleg_now) & (kind != OP_NOP) & (reply_sid != me))

    return OpOut(state=state, result=result, outbox=outbox, count=count)
