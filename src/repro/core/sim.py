"""Cluster simulator: N shards, reliable FIFO routing, round-based execution.

This is the single-host execution backend for the DiLi runtime. Each round:

  1. every shard consumes its inbox + a batch of fresh client ops
     (``shard.shard_round`` — one jit compilation reused by all shards),
  2. outboxes are routed host-side into next-round inboxes (per-(src,dst)
     FIFO preserved; undeliverable overflow is backlogged, never dropped —
     the reliable-channel condition of conditional lock-freedom).

With ``delay_prob > 0`` (deterministic under ``seed``) whole (src,dst)
channels are held back for a round to exercise out-of-order-across-pairs
delivery (replay retries must heal).

With ``nemesis=NemesisConfig(...)`` the cluster routes through the
reliable transport (``core.net``, DESIGN.md §11): the wire below it may
drop, duplicate, reorder and delay frames, and the transport's
seq/ack/dedup machinery restores exactly-once in-order delivery. Every
random stream (channel delays, nemesis, balancer tie-breaks) is spawned
from one root ``SeedSequence``, so an entire run — including its
per-round ``round_trace`` — is a pure function of ``(seed, config)``.

The shard_map/TPU backend with ``all_to_all`` routing lives in
``distributed.py``; it runs the same ``shard_round``.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from . import bg as B
from . import messages as M
from . import range_scan as RS
from . import refs
from . import replica as R
from .durability import Durability, wal
from .membership import (Membership, epoch_broadcast, moves_targeting,
                         owned_entry_count)
from .net import Nemesis, NemesisConfig, Transport, trace_entry
from .shard import shard_round
from .types import (DiLiConfig, KEY_MAX, KEY_MIN, OP_FIND, OP_INSERT,
                    OP_REMOVE, SH_KEY, ST_KEY, ShardState, init_shard)


class OutboxOverflow(RuntimeError):
    """A shard emitted more messages in one round than ``mailbox_cap``.

    Overflowing rows are not stored (``messages.push``), and a lost
    replicate/ack deadlocks ``run_until_quiet`` — so this is raised
    unconditionally (never an ``assert``: ``python -O`` must not turn it
    into silent truncation). Fix: raise ``cfg.mailbox_cap`` or feed the
    shard fewer ops per round.
    """


# ------------------------------------------------------ client-op plumbing
# Shared by every execution backend (Cluster below, api.ShardMapBackend) so
# the MSG_OP row layout and the op-id lifecycle have exactly one home —
# divergence here is precisely what the Local-vs-ShardMap parity test
# guards against.

class OpIdAllocator:
    """Op ids for the int32 ``F_TS`` message lane, with recycling.

    ``alloc`` reissues released ids first and raises before the int32
    ceiling — a wrapped id would silently alias a live op.
    """

    def __init__(self):
        self.next_id = 0
        self.free: List[int] = []

    def alloc(self) -> int:
        if self.free:
            return self.free.pop()
        if self.next_id >= np.iinfo(np.int32).max:
            raise RuntimeError(
                "op-id space exhausted: op ids are int32 message lanes and "
                "would wrap — drain results (take_result / backend.step) "
                "so ids recycle")
        nid = self.next_id
        self.next_id += 1
        return nid

    def release(self, op_id: int) -> None:
        self.free.append(op_id)


def materialize_ops(kinds, keys, values):
    """Materialize (once) and length-check a client op batch."""
    kinds = [int(k) for k in kinds]
    keys = [int(k) for k in keys]
    if len(kinds) != len(keys):
        raise ValueError(f"submit: {len(kinds)} kinds vs {len(keys)} keys")
    values = ([0] * len(keys) if values is None
              else [int(v) for v in values])
    if len(values) != len(keys):
        raise ValueError(f"submit: {len(values)} values vs {len(keys)} keys")
    return kinds, keys, values


def make_op_row(shard: int, kind: int, key: int, val: int,
                slot: int) -> np.ndarray:
    """One fresh MSG_OP row addressed at server ``shard`` (null subhead
    hint — the server resolves the route; reply shard = ``shard``)."""
    row = np.zeros((M.FIELDS,), np.int32)
    row[M.F_KIND] = M.MSG_OP
    row[M.F_DST] = shard
    row[M.F_SRC] = shard
    row[M.F_A] = kind
    row[M.F_KEY] = key
    row[M.F_REF1] = np.int64(refs.NULL_REF).astype(np.int32)
    row[M.F_SID] = shard
    row[M.F_TS] = slot
    row[M.F_VAL] = val
    return row


# ------------------------------------------------------- state inspection
# Free functions over (cfg, states) so every execution backend (the
# simulator below, the shard_map backend behind ``api.ShardMapBackend``)
# shares one chain walker and one registry reader.

def chain_keys(cfg: DiLiConfig, states: Sequence[ShardState], s: int,
               head_idx: int, include_meta: bool = False):
    """Walk a chain from a subhead; returns live keys, or (key, idx, value)
    triples with ``include_meta``.

    A healthy chain terminates (SubTail, null, or a foreign ref) within
    ``pool_capacity`` steps — the nodes of one chain are distinct pool
    slots. Exhausting the bound therefore proves a cycle (corruption), and
    raising beats returning a silent prefix: ``all_keys()``-based
    assertions must not pass vacuously on a truncated walk.
    """
    st = states[s]
    nxt = np.asarray(st.pool.nxt)
    key = np.asarray(st.pool.key)
    vals = np.asarray(st.pool.keymax)
    out = []
    ref = int(nxt[head_idx])
    for _ in range(int(cfg.pool_capacity) + 2):
        idx = ref & refs.IDX_MASK
        sid = (ref & refs.SID_MASK) >> refs.IDX_BITS
        if idx == refs.NULL_IDX or sid != s:
            break
        k = int(key[idx])
        marked = bool(int(nxt[idx]) & refs.MARK_BIT)
        if k == ST_KEY:
            break
        if k != SH_KEY and not marked:
            out.append((k, idx, int(vals[idx])) if include_meta else k)
        ref = int(nxt[idx])
    else:
        raise RuntimeError(
            f"shard {s} chain from head {head_idx} did not terminate "
            f"within pool_capacity={int(cfg.pool_capacity)} steps "
            f"— cyclic or corrupted chain")
    return out


def state_sublists(cfg: DiLiConfig, states: Sequence[ShardState], s: int):
    """(keymin, keymax, owner, size, head_idx, switched) per entry of
    shard s's registry replica; ``size`` is None for entries owned
    elsewhere. ``switched`` flags an owned entry whose sublist has been
    switched away (stCt < 0) — a stale local copy awaiting quarantine."""
    st = states[s]
    reg = st.registry
    out = []
    for e in range(int(reg.size)):
        sh = int(np.asarray(reg.subhead)[e])
        sid = (sh & refs.SID_MASK) >> refs.IDX_BITS
        head_idx = sh & refs.IDX_MASK
        size = None
        switched = False
        if sid == s:
            size = len(chain_keys(cfg, states, s, head_idx))
            slot = int(np.asarray(st.pool.ctr)[head_idx])
            switched = int(np.asarray(st.stct)[slot]) < 0
        out.append(dict(
            keymin=int(np.asarray(reg.keymin)[e]),
            keymax=int(np.asarray(reg.keymax)[e]),
            owner=int(sid), size=size, head_idx=int(head_idx),
            switched=switched))
    return out


def global_keys(cfg: DiLiConfig, states: Sequence[ShardState]) -> List[int]:
    """Global key set: union over every shard's owned, non-switched
    sublists (one registry walk, shared with ``state_sublists``)."""
    keys: List[int] = []
    for s in range(len(states)):
        for e in state_sublists(cfg, states, s):
            if e["owner"] != s or e["switched"]:
                continue
            keys.extend(chain_keys(cfg, states, s, e["head_idx"]))
    return sorted(keys)


def registry_entries(state: ShardState):
    """One shard's registry replica as (keymin, keymax, owner) triples,
    sorted by keymin — the view a client seeds/refreshes its route cache
    from (DESIGN.md §9)."""
    reg = state.registry
    size = int(reg.size)
    kmin = np.asarray(reg.keymin)[:size]
    kmax = np.asarray(reg.keymax)[:size]
    sh = np.asarray(reg.subhead)[:size].astype(np.int64)
    owner = (sh & refs.SID_MASK) >> refs.IDX_BITS
    return [(int(a), int(b), int(o)) for a, b, o in zip(kmin, kmax, owner)]


class Cluster:
    def __init__(self, cfg: DiLiConfig, *, seed: int = 0,
                 delay_prob: float = 0.0,
                 nemesis: Optional[NemesisConfig] = None,
                 retransmit_after: int = 4, net_window: int = 4096,
                 trace: Optional[bool] = None,
                 key_lo: int = KEY_MIN, key_hi: int = KEY_MAX,
                 initial_shards: Optional[int] = None,
                 durability=None):
        self.cfg = cfg
        self.n = cfg.num_shards
        # elastic membership (DESIGN.md §13): cfg.num_shards is the
        # jit-static *capacity*; all capacity shards are constructed and
        # stepped every round, and which of them are members is a
        # host-side overlay. initial_shards=None means all-active (the
        # legacy fixed-membership cluster, byte-identical to before).
        self.membership = Membership(self.n, initial_shards)
        self._mb_logged = 0
        # host->shard control rows (MSG_EPOCH broadcasts) staged between
        # rounds; flushed into the routed message stream in step() so they
        # ride the same (partitionable, retransmitted) wire as everything
        # else.
        self._ctrl_out: List[Tuple[int, np.ndarray]] = []
        # shard 0 bootstraps the full key range; the others hold registry
        # replicas routing to it (the paper's lazily-replicated registry
        # starts synchronized). Initially-retired slots get the replica
        # too — a later join_shard must be able to route from round one.
        peers0 = self.membership.mask()
        self.states: List[ShardState] = [
            init_shard(cfg, s, bootstrap=(s == 0),
                       key_lo=key_lo, key_hi=key_hi, peers_mask=peers0)
            for s in range(self.n)
        ]
        from . import registry as reg_ops
        for s in range(1, self.n):
            st = self.states[s]
            reg = reg_ops.add_entry(
                st.registry, key_lo - 1, key_hi,
                refs.make_ref(0, 0), refs.make_ref(0, 1), 0, 0)
            self.states[s] = st._replace(registry=reg)
        self.bgs: List[B.BgTable] = [B.init_bg_table(cfg)
                                     for _ in range(self.n)]
        self.in_cap = max(cfg.mailbox_cap * self.n, cfg.batch_size * 2)
        self.inboxes = [np.zeros((0, M.FIELDS), np.int32)
                        for _ in range(self.n)]
        self.backlog = [np.zeros((0, M.FIELDS), np.int32)
                        for _ in range(self.n)]
        self.results: Dict[int, int] = {}
        self.result_src: Dict[int, int] = {}
        self.last_completions: List[Tuple[int, int, int]] = []
        self._ids = OpIdAllocator()
        self._pending_ops: Dict[int, Tuple[int, int]] = {}
        # RANGE scans in flight (DESIGN.md §16): item rows accumulate in
        # ``_range_parts`` until the terminal result's count says the set
        # is complete — items from different serving shards ride
        # different transport lanes, so arrival order proves nothing.
        self._range_ops: set = set()
        self._range_parts: Dict[int, List[Tuple[int, int]]] = {}
        self._range_done: Dict[int, Tuple[int, int]] = {}
        self.round_no = 0
        self.delay_prob = delay_prob
        # One splittable root: independent child streams for channel
        # delays, the nemesis, and balancer tie-breaks — adding a consumer
        # to one stream never perturbs another, so the whole run (and its
        # round_trace) is a pure function of (seed, config).
        self.seed = seed
        root = np.random.SeedSequence(seed)
        delay_ss, nemesis_ss, balancer_ss = root.spawn(3)
        self.rng = np.random.default_rng(delay_ss)
        self.balancer_rng = np.random.default_rng(balancer_ss)
        self.nemesis_config = nemesis
        self.net: Optional[Transport] = None
        if nemesis is not None:
            if delay_prob > 0.0:
                # the legacy channel-hold knob is replaced wholesale by
                # transport routing; accepting both would silently run
                # weaker fault injection than asked for
                raise ValueError(
                    "delay_prob and nemesis are mutually exclusive — "
                    "use NemesisConfig.delay_prob for delays under the "
                    "reliable transport")
            self.net = Transport(
                self.n, Nemesis(nemesis, np.random.default_rng(nemesis_ss)),
                retransmit_after=retransmit_after, window=net_window)
        # durability (DESIGN.md §14): per-shard WAL + snapshots. Crash
        # plans require it (recovery needs a durable base), so a run
        # with crashes and no explicit store gets an ephemeral tempdir.
        # ``durability`` accepts a directory path, a Durability, or None.
        self._crash_plans = tuple(nemesis.crashes) if nemesis else ()
        if self._crash_plans:
            from .durability.engine import validate_crash_plans
            validate_crash_plans(self._crash_plans, self.n)
        self._tmp_durability = None
        if durability is None and self._crash_plans:
            import tempfile
            self._tmp_durability = tempfile.TemporaryDirectory(
                prefix="dili-durability-")
            durability = self._tmp_durability.name
        self.durability: Optional[Durability] = None
        if durability is not None:
            self.durability = (durability if isinstance(durability,
                                                        Durability)
                               else Durability(durability, cfg))
            for s in range(self.n):
                self.durability.ensure_genesis(
                    s, self.states[s], self.bgs[s], self.backlog[s],
                    self._lane_image(s))
        # per-round observable-outcome trace, the byte-identical-replay
        # witness. Default: on for nemesis runs (where the (seed, config)
        # repro contract needs it), off on the clean fast path (a per-
        # round string append for nothing).
        self.trace_enabled = (nemesis is not None) if trace is None \
            else bool(trace)
        self.round_trace: List[str] = []
        self.stats = {"max_outbox": 0, "max_hops": 0, "rounds": 0,
                      "fast_hits": 0, "mut_hits": 0, "delegated": 0,
                      "move_hits": 0, "blk_hits": 0, "max_bg_active": 0,
                      "rep_hits": 0, "range_hits": 0}
        # per-entry op-rate EWMA (keyed by entry keymax), fed from every
        # round's RoundOut.ent_hits — the load signal the balancer's
        # op-rate model and hot-entry replication stage read (§15). Decays
        # to zero at rest, so key-count calibrated behavior is unchanged
        # for settled clusters.
        self.op_rate_ewma: Dict[int, float] = {}
        # per-shard EWMA of replica-served FINDs (keyed by shard id) — the
        # balancer folds this into shard load so serving replicas don't
        # read as idle (see step()).
        self.rep_rate_ewma: Dict[int, float] = {}
        # host-authoritative replica map (keymax -> (primary, targets)),
        # maintained by the replicate/drop_replica commands; replica_epoch
        # bumps on every change so clients know to refresh routing.
        self._replica_map: Dict[int, Tuple[int, set]] = {}
        self.replica_epoch = 0
        # pre-compile the jitted replicate/drop commands so the first hot
        # entry detected mid-run doesn't pay trace+compile on that round
        R.warm_commands(self.states[0], cfg)

    # ------------------------------------------------------------ client API
    def submit(self, shard: int, kinds: Sequence[int],
               keys: Sequence[int],
               values: Optional[Sequence[int]] = None) -> List[int]:
        """Enqueue fresh client ops at their assigned server ``shard``.

        Returns op ids; results appear in ``self.results`` once linearized.
        ``values`` ride with inserts (item payload, e.g. a KV-page slot).
        ``kinds``/``keys``/``values`` may be any iterables (generators
        included) — they are materialized exactly once up front.

        Op ids travel in an int32 message lane, so they must stay below
        2**31. Ids returned to ``take_result`` are recycled; ids whose
        results linger in ``self.results`` are not — a long-running caller
        that never drains them exhausts the space and ``submit`` raises
        (never silently wraps).
        """
        if not self.membership.is_routable(shard):
            raise ValueError(
                f"submit: shard {shard} is "
                f"{self.membership.state_of(shard)} at epoch "
                f"{self.membership.epoch} — route ops to one of "
                f"{self.membership.routable}")
        kinds, keys, values = materialize_ops(kinds, keys, values)
        ids = []
        rows = []
        for kind, key, val in zip(kinds, keys, values):
            slot = self._ids.alloc()
            rows.append(make_op_row(shard, kind, key, val, slot))
            ids.append(slot)
            self._pending_ops[slot] = (kind, key)
        if rows:
            self.backlog[shard] = np.concatenate(
                [self.backlog[shard], np.stack(rows)], axis=0)
            if self.durability is not None:
                # journal on acceptance: an op whose id was handed out
                # must survive a crash of its server (DESIGN.md §14)
                self.durability.log_submit(shard, self.round_no,
                                           np.stack(rows))
        return ids

    def submit_range(self, shard: int, lo: int, hi: int,
                     limit: int) -> int:
        """Enqueue a RANGE(lo, hi, limit) scan at server ``shard``
        (DESIGN.md §16): all keys in ``[lo, hi)``, at most ``limit`` of
        them. Returns an op id; the result value is the item count and
        ``take_range_items`` pops the (key, value) pairs — call it
        *before* ``take_result`` recycles the id."""
        if not self.cfg.range_scan:
            raise ValueError(
                "submit_range: cfg.range_scan is off — the RANGE "
                "pre-pass and serial walk are compiled out of "
                "shard_round")
        if not self.membership.is_routable(shard):
            raise ValueError(
                f"submit_range: shard {shard} is "
                f"{self.membership.state_of(shard)} at epoch "
                f"{self.membership.epoch} — route ops to one of "
                f"{self.membership.routable}")
        lo, hi, limit = int(lo), int(hi), int(limit)
        if lo < KEY_MIN or hi > KEY_MAX + 1 or limit < 1:
            raise ValueError(
                f"submit_range: span [{lo}, {hi}) / limit {limit} out "
                f"of bounds (keys in [{KEY_MIN}, {KEY_MAX}], "
                f"limit >= 1)")
        slot = self._ids.alloc()
        row = RS.make_range_row(shard, lo, hi, limit, slot)
        self.backlog[shard] = np.concatenate(
            [self.backlog[shard], row[None]], axis=0)
        if self.durability is not None:
            self.durability.log_submit(shard, self.round_no, row[None])
        self._pending_ops[slot] = (-1, lo)
        self._range_ops.add(slot)
        self._range_parts[slot] = []
        return slot

    def take_range_items(self, op_id: int) -> List[Tuple[int, int]]:
        """Pop a completed RANGE's (key, value) pairs, sorted by key."""
        return sorted(self._range_parts.pop(op_id, []))

    def take_result(self, op_id: int) -> int:
        """Pop a completed op's result and recycle its id.

        Raises ``KeyError`` while the op is still pending. This is the
        drain path long-running clients must use: ids handed back here are
        reissued by ``submit`` instead of growing the id space toward the
        int32 wraparound guard.
        """
        val = self.results.pop(op_id)
        self.result_src.pop(op_id, None)
        # a recycled id must not inherit a stale scan's items
        self._range_parts.pop(op_id, None)
        self._range_ops.discard(op_id)
        self._ids.release(op_id)
        return val

    # ------------------------------------------------- membership (§13)
    def join_shard(self, shard: Optional[int] = None) -> int:
        """Admit a retired capacity slot as a JOINING member (empty — the
        balancer's rebalancing drains sublists onto it; the host promotes
        it to ACTIVE once it owns one). Returns the joined shard id."""
        s = self.membership.begin_join(shard)
        self._broadcast_epoch()
        return s

    def retire_shard(self, shard: int) -> None:
        """Begin draining ``shard``: the balancer force-evacuates every
        sublist it owns, it keeps executing (delegations in flight must
        land), and the host retires it — resetting its transport lanes —
        once ``_drain_complete`` proves nothing can still reach it."""
        self.membership.begin_drain(shard)
        self._broadcast_epoch()

    def _broadcast_epoch(self) -> None:
        """Stage a MSG_EPOCH announcement to every capacity slot, from the
        lowest *active* shard — never from a draining one, whose own
        retirement is gated on its lanes going idle (a self-announcement
        would deadlock that gate)."""
        rows = epoch_broadcast(self.membership)
        src = int(min(self.membership.active))
        self._ctrl_out.append((src, np.stack(rows).astype(np.int32)))

    def _drain_complete(self, s: int) -> bool:
        """True when retiring ``s`` can strand nothing: it owns no
        sublist, runs no bg op, no peer's in-flight Move targets it, no
        queued/staged row can still be delivered to it, and every
        transport lane touching it is idle (incl. nemesis-held frames)."""
        if owned_entry_count(self.cfg, self.states, s) != 0:
            return False
        if B.any_active(self.bgs[s]):
            return False
        if moves_targeting(self.bgs, s) != 0:
            return False
        if self.backlog[s].shape[0]:
            return False
        if self._ctrl_out:
            return False
        if self.net is not None and not self.net.shard_idle(s):
            return False
        return True

    def _membership_maintenance(self) -> None:
        """Host-driven lifecycle advance, once per round (deterministic:
        a pure function of post-round state). Promotes joining shards
        that own their first sublist; retires draining shards whose drain
        is provably complete, resetting their lanes before announcing."""
        mb = self.membership
        if not (mb.joining or mb.draining):
            return
        changed = False
        for s in mb.joining:
            if owned_entry_count(self.cfg, self.states, s) > 0:
                mb.promote(s)
                changed = True
        for s in mb.draining:
            if self._drain_complete(s):
                mb.finish_drain(s)
                if self.net is not None:
                    self.net.reset_shard(s)
                changed = True
        if changed:
            self._broadcast_epoch()

    # ------------------------------------------------- crash-restart (§14)
    def _lane_image(self, s: int) -> Dict[str, np.ndarray]:
        return (self.net.export_shard_lanes(s)
                if self.net is not None else {})

    def _down(self):
        return self.net.down if self.net is not None else ()

    def _apply_crash_plans(self) -> None:
        """Execute due CrashPlans at the top of the round. Restarts run
        before crashes so a plan pair sharing a round boundary recovers
        one shard while killing another deterministically."""
        for c in self._crash_plans:
            if c.restart_round == self.round_no and c.shard in self._down():
                self._restart_shard(c.shard)
        for c in self._crash_plans:
            if c.crash_round == self.round_no:
                self._crash_shard(c.shard)

    def _crash_shard(self, s: int) -> None:
        """kill -9: the process's memory — shard state, BgTable, host
        backlog, its halves of every transport lane — vanishes. Durable
        WAL + snapshots (and everything client-side: results, pending op
        ids) survive."""
        self.membership.crash(s)
        if not self.membership.active:
            raise RuntimeError(
                f"crash of shard {s} leaves no active shard — the "
                f"coordinator for epoch broadcasts must survive")
        self._broadcast_epoch()
        self.states[s] = init_shard(self.cfg, s, peers_mask=0)
        self.bgs[s] = B.init_bg_table(self.cfg)
        self.backlog[s] = np.zeros((0, M.FIELDS), np.int32)
        self.net.crash_shard(s)

    def _restart_shard(self, s: int) -> None:
        """Recovery: snapshot + WAL replay rebuilds the shard at its last
        durable round; the lane image re-arms its retransmit rings and
        receiver cursors, so exactly-once delivery spans the reboot. The
        shard re-enters as JOINING-with-state (crash ≠ drain) — host
        maintenance promotes it back to ACTIVE since it still owns its
        pre-crash sublists, and carve-out / delegation healing repairs
        anything that restructured while it was down."""
        rec = self.durability.recover(s, in_cap=self.in_cap)
        self.states[s] = rec.state
        self.bgs[s] = rec.bg
        self.backlog[s] = rec.backlog
        self.net.restart_shard(s, rec.lanes)
        self.membership.restart(s)
        self._broadcast_epoch()
        # fresh durable base: the replayed suffix is now redundant
        self.durability.snapshot_now(s, self.round_no - 1, self.states[s],
                                     self.bgs[s], self.backlog[s],
                                     self._lane_image(s))

    # ------------------------------------------------------------- execution
    def step(self) -> int:
        """One synchronized round across all shards. Returns #completed."""
        cfg = self.cfg
        self._apply_crash_plans()
        down = self._down()
        outs = []
        client_feeds: List[np.ndarray] = []
        for s in range(self.n):
            if s in down:
                outs.append(None)
                client_feeds.append(np.zeros((0, M.FIELDS), np.int32))
                continue
            # feed: backlog first (FIFO), bounded by in_cap
            feed = self.backlog[s][:self.in_cap]
            self.backlog[s] = self.backlog[s][self.in_cap:]
            inbox = np.zeros((self.in_cap, M.FIELDS), np.int32)
            inbox[:feed.shape[0]] = feed
            client = np.zeros((0, M.FIELDS), np.int32)
            client_feeds.append(client)
            out = shard_round(self.states[s], self.bgs[s], s,
                              jnp.asarray(inbox),
                              jnp.asarray(client.reshape(0, M.FIELDS)),
                              cfg)
            outs.append(out)

        ndone = 0
        self.last_completions = []
        new_msgs: List[np.ndarray] = []
        out_counts: List[int] = []
        comp_by_shard: List[np.ndarray] = []
        ent_rates: Dict[int, int] = {}
        rep_served: Dict[int, int] = {}
        for s, out in enumerate(outs):
            if out is None:                      # crashed: emitted nothing
                out_counts.append(0)
                comp_by_shard.append(np.zeros((0, 4), np.int32))
                continue
            self.states[s] = out.state
            self.bgs[s] = out.bg
            self.stats["fast_hits"] += int(out.fast_hits)
            self.stats["mut_hits"] += int(out.mut_hits)
            self.stats["move_hits"] += int(out.move_hits)
            self.stats["blk_hits"] += int(out.blk_hits)
            rh = int(out.rep_hits)
            self.stats["rep_hits"] += rh
            self.stats["range_hits"] += int(out.range_hits)
            if rh:
                rep_served[s] = rep_served.get(s, 0) + rh
            self.stats["max_bg_active"] = max(self.stats["max_bg_active"],
                                              int(out.bg_active))
            hits = np.asarray(out.ent_hits)
            nz = np.nonzero(hits)[0]
            if nz.size:
                kmax = np.asarray(out.state.registry.keymax)
                for e in nz:
                    k = int(kmax[e])
                    if k != ST_KEY:
                        ent_rates[k] = ent_rates.get(k, 0) + int(hits[e])
            cnt = int(out.out_count)
            out_counts.append(cnt)
            self.stats["max_outbox"] = max(self.stats["max_outbox"], cnt)
            if cnt > cfg.mailbox_cap:
                # not an assert: under ``python -O`` a dropped message
                # (replicate/ack) would silently deadlock run_until_quiet.
                raise OutboxOverflow(
                    f"shard {s} emitted {cnt} messages in round "
                    f"{self.round_no}, mailbox_cap={cfg.mailbox_cap}: "
                    f"{cnt - cfg.mailbox_cap} rows dropped — raise "
                    f"mailbox_cap or reduce the per-round feed")
            ob = np.asarray(out.outbox)[:cnt]
            if ob.size:
                new_msgs.append((s, ob))
                hops = ob[ob[:, M.F_KIND] == M.MSG_OP, M.F_X2]
                if hops.size:
                    self.stats["max_hops"] = max(self.stats["max_hops"],
                                                 int(hops.max()))
                    self.stats["delegated"] += int(hops.size)
            cs = np.asarray(out.comp_slot)
            cv = np.asarray(out.comp_val)
            cr = np.asarray(out.comp_src)
            ck = np.asarray(out.comp_key)
            done = cs >= 0
            comp_by_shard.append(np.stack(
                [cs[done], cv[done], cr[done], ck[done]],
                axis=1).astype(np.int32))
            for slot, val, src, key in zip(cs[done], cv[done], cr[done],
                                           ck[done]):
                slot = int(slot)
                if int(key) != SH_KEY:
                    # one RANGE item — accumulate, publication waits for
                    # the terminal count (DESIGN.md §16)
                    self._range_parts.setdefault(slot, []).append(
                        (int(key), int(val)))
                    continue
                if slot in self._range_ops:
                    # terminal scan result: F_A is the total item count
                    self._range_done[slot] = (int(val), int(src))
                    continue
                self.results[slot] = int(val)
                self.result_src[slot] = int(src)
                self.last_completions.append((slot, int(val), int(src)))
                self._pending_ops.pop(slot, None)
                ndone += 1
        ndone += self._publish_ranges()

        # per-entry op-rate EWMA update (once per round): decay every
        # tracked entry, add this round's hits, drop entries decayed to
        # noise so the dict tracks only recently-active sublists.
        alpha = 0.3
        nxt_rates: Dict[int, float] = {}
        for k, v in self.op_rate_ewma.items():
            d = v * (1.0 - alpha)
            if d > 1e-3:
                nxt_rates[k] = d
        for k, h in ent_rates.items():
            nxt_rates[k] = nxt_rates.get(k, 0.0) + alpha * h
        self.op_rate_ewma = nxt_rates
        # per-shard replica-service EWMA (keyed by shard): FINDs a shard
        # serves from its read replicas are real load but invisible to the
        # registry-keyed entry rates (the entry lives on the primary), so
        # without this the balancer sees serving replicas as idle and
        # churns moves against phantom imbalance.
        nxt_rep: Dict[int, float] = {}
        for s2, v in self.rep_rate_ewma.items():
            d = v * (1.0 - alpha)
            if d > 1e-3:
                nxt_rep[s2] = d
        for s2, h in rep_served.items():
            nxt_rep[s2] = nxt_rep.get(s2, 0.0) + alpha * h
        self.rep_rate_ewma = nxt_rep

        # host->shard membership announcements join the routed stream
        # here (after the shard outboxes, a deterministic position) so
        # they are partitioned/retransmitted like any protocol message.
        if self._ctrl_out:
            new_msgs.extend(self._ctrl_out)
            self._ctrl_out = []

        # ------------------------------------------------ route (FIFO/pair)
        pre_lens = [b.shape[0] for b in self.backlog]
        if self.net is not None:
            # reliable transport over the (possibly nemesis-perturbed)
            # wire: loopback rows bypass it, everything else is
            # sequenced, retransmitted and delivered exactly once in
            # per-lane order. Runs even on quiet rounds so retransmit
            # timers, acks and delayed frames keep moving.
            self.net.route_round(self.backlog, new_msgs, self.round_no)
        elif new_msgs:
            allm = np.concatenate([ob for _, ob in new_msgs], axis=0)
            for d in range(self.n):
                mine = allm[allm[:, M.F_DST] == d]
                if self.delay_prob > 0.0 and mine.size:
                    # hold back whole (src,dst) channels — preserves pair
                    # FIFO while exercising cross-pair reordering
                    srcs = np.unique(mine[:, M.F_SRC])
                    held = srcs[self.rng.random(srcs.shape) < self.delay_prob]
                    hold_mask = np.isin(mine[:, M.F_SRC], held)
                    later, now = mine[hold_mask], mine[~hold_mask]
                    self.backlog[d] = np.concatenate(
                        [self.backlog[d], now, later], axis=0)
                else:
                    self.backlog[d] = np.concatenate(
                        [self.backlog[d], mine], axis=0)
        self._membership_maintenance()
        if self.durability is not None:
            # journal the round per live shard: the inputs consumed (the
            # feed discipline re-derives them from backlog + appends),
            # the completions produced (replay audit), and the post-
            # routing lane image. fsync'd before this round's effects
            # become observable via next round's acks (§14).
            for s in range(self.n):
                if s in down:
                    continue
                self.durability.log_round(
                    s, self.round_no,
                    appends=self.backlog[s][pre_lens[s]:],
                    client=client_feeds[s], comp=comp_by_shard[s],
                    bg_phases=B.slot_phases(self.bgs[s]),
                    epoch=int(np.asarray(self.states[s].epoch)),
                    lanes=self._lane_image(s))
                self.durability.maybe_snapshot(
                    s, self.round_no, self.states[s], self.bgs[s],
                    self.backlog[s], self._lane_image(s))
        if self.trace_enabled:
            # membership transitions are part of the replay witness: a
            # run that joins/retires at a different round is not a replay
            for ep, ev, sh in self.membership.log[self._mb_logged:]:
                self.round_trace.append(
                    f"r{self.round_no} mb {ev} s{sh} e{ep}")
            self._mb_logged = len(self.membership.log)
            self.round_trace.append(trace_entry(
                self.round_no, self.last_completions, out_counts,
                extra=sum(b.shape[0] for b in self.backlog)
                + (self.net.in_flight() if self.net is not None else 0)))
        self.round_no += 1
        self.stats["rounds"] += 1
        return ndone

    def _publish_ranges(self) -> int:
        """Publish RANGE completions whose item parts have all arrived.
        Items from different serving shards ride different transport
        lanes, so the terminal count — not arrival order — gates
        publication. A negative count is an error result (e.g.
        RES_OVERFLOW) and publishes immediately."""
        n = 0
        for slot, (total, src) in list(self._range_done.items()):
            if total >= 0 and len(self._range_parts.get(slot, ())) < total:
                continue
            self.results[slot] = total
            self.result_src[slot] = src
            self.last_completions.append((slot, total, src))
            self._pending_ops.pop(slot, None)
            del self._range_done[slot]
            n += 1
        return n

    def run(self, rounds: int) -> None:
        for _ in range(rounds):
            self.step()

    def run_until_quiet(self, max_rounds: int = 200) -> None:
        """Step until no messages are in flight and all bg ops are idle."""
        for _ in range(max_rounds):
            self.step()
            busy = any(b.shape[0] for b in self.backlog)
            busy = busy or any(B.any_active(bg) for bg in self.bgs)
            busy = busy or bool(self._pending_ops)
            busy = busy or bool(self._ctrl_out)
            busy = busy or (self.net is not None and not self.net.idle())
            # a crashed shard is not quiet — keep stepping toward its
            # scheduled restart so recovery (and retransmission into it)
            # can finish the run
            busy = busy or bool(self.membership.crashed)
            if not busy:
                return
        raise RuntimeError(
            f"cluster did not quiesce: backlog="
            f"{[b.shape[0] for b in self.backlog]} "
            f"bg={[B.slot_phases(bg).tolist() for bg in self.bgs]} "
            f"pending={len(self._pending_ops)} "
            f"net={self.net.in_flight() if self.net is not None else 0}")

    # ----------------------------------------------------------- inspection
    def shard_chain(self, s: int, head_idx: int, include_meta=False):
        """Walk a chain from a subhead (see ``chain_keys``); raises on a
        cyclic/corrupted chain instead of returning a silent prefix."""
        return chain_keys(self.cfg, self.states, s, head_idx, include_meta)

    def all_keys(self) -> List[int]:
        """Global key set: union over every shard's owned sublists."""
        return global_keys(self.cfg, self.states)

    def sublists(self, s: int):
        """(keymin, keymax, owner, size, head_idx) per entry."""
        return state_sublists(self.cfg, self.states, s)

    def registry_entries(self, s: int = 0):
        """Shard ``s``'s registry replica as (keymin, keymax, owner)."""
        return registry_entries(self.states[s])

    # ---------------------------------------------------------- bg commands
    # Each returns True if a slot accepted the command, False if it was
    # dropped (no idle slot, or the entry is claimed by an in-flight op) —
    # the balancer uses the verdict to keep its load model honest.
    def split(self, s: int, entry_keymax: int, sitem_idx: int) -> bool:
        self.bgs[s], ok = B.queue_split(self.bgs[s], entry_keymax, sitem_idx)
        self._log_command(s, wal.CMD_SPLIT, (entry_keymax, sitem_idx), ok)
        return bool(ok)

    def move(self, s: int, entry_keymax: int, target: int) -> bool:
        self.bgs[s], ok = B.queue_move(self.bgs[s], entry_keymax, target)
        self._log_command(s, wal.CMD_MOVE, (entry_keymax, target), ok)
        return bool(ok)

    def merge(self, s: int, left_keymax: int, right_keymax: int) -> bool:
        self.bgs[s], ok = B.queue_merge(self.bgs[s], left_keymax,
                                        right_keymax)
        self._log_command(s, wal.CMD_MERGE, (left_keymax, right_keymax), ok)
        return bool(ok)

    def replicate(self, s: int, entry_keymax: int, target: int) -> bool:
        """Start (or widen) read replication of the entry ``s`` owns with
        upper bound ``entry_keymax`` onto shard ``target`` (§15). Like the
        bg commands, this is a host-side state edit journaled through the
        WAL so recovery replays it byte-identically."""
        if not self.cfg.replication:
            raise ValueError(
                "replicate: cfg.replication is off — replica serve and "
                "publication are compiled out of shard_round")
        self.states[s], ok = R.queue_replicate_jit(
            self.states[s], self.cfg, entry_keymax, target)
        ok = bool(np.asarray(ok))
        self._log_command(s, wal.CMD_REPLICATE, (entry_keymax, target), ok)
        if ok:
            prim, tg = self._replica_map.get(entry_keymax, (s, set()))
            tg = set(tg) | {int(target)}
            self._replica_map[int(entry_keymax)] = (s, tg)
            self.replica_epoch += 1
        return ok

    def drop_replica(self, s: int, entry_keymax: int,
                     target: int = -1) -> bool:
        """Retire replicas of ``entry_keymax`` on ``target`` (-1 = all)."""
        if not self.cfg.replication:
            raise ValueError("drop_replica: cfg.replication is off")
        self.states[s], ok = R.queue_drop_replica_jit(
            self.states[s], self.cfg, entry_keymax, target)
        ok = bool(np.asarray(ok))
        self._log_command(s, wal.CMD_DROP_REPLICA,
                          (entry_keymax, target), ok)
        if entry_keymax in self._replica_map:
            prim, tg = self._replica_map[entry_keymax]
            tg = set() if target < 0 else set(tg) - {int(target)}
            if tg:
                self._replica_map[entry_keymax] = (prim, tg)
            else:
                del self._replica_map[entry_keymax]
            self.replica_epoch += 1
        return ok

    def replica_sets(self):
        """Live replica routing view for clients: ``{keymax: (keymin,
        primary, [replica shards])}``. Entries whose primary no longer
        owns a matching registry entry are pruned (ownership moved; the
        session's self-audit is dropping those replicas anyway)."""
        out = {}
        stale = []
        for kmax, (prim, tg) in self._replica_map.items():
            reg = self.states[prim].registry
            size = int(np.asarray(reg.size))
            kmaxes = np.asarray(reg.keymax)[:size]
            at = np.nonzero(kmaxes == kmax)[0]
            owned = False
            if at.size:
                sh = int(np.asarray(reg.subhead)[at[0]])
                owned = ((sh & refs.SID_MASK) >> refs.IDX_BITS) == prim
            if not owned:
                stale.append(kmax)
                continue
            kmin = int(np.asarray(reg.keymin)[at[0]])
            out[int(kmax)] = (kmin, int(prim), sorted(tg))
        for kmax in stale:
            del self._replica_map[kmax]
            self.replica_epoch += 1
        return out

    def _log_command(self, s: int, cmd: int, args, ok) -> None:
        """Balancer commands mutate the BgTable outside the inbox, so
        replay needs them journaled (wal.py KIND_COMMAND)."""
        if self.durability is not None:
            self.durability.log_command(s, self.round_no, cmd, args,
                                        bool(ok))

    def middle_item(self, s: int, head_idx: int) -> Optional[int]:
        """Pool idx of the middle live item of a sublist (split point)."""
        items = self.shard_chain(s, head_idx, include_meta=True)
        if len(items) < 2:
            return None
        return items[len(items) // 2][1]
