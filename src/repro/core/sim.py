"""Cluster simulator: N shards, reliable FIFO routing, round-based execution.

This is the single-host execution backend for the DiLi runtime. Each round:

  1. every shard consumes its inbox + a batch of fresh client ops
     (``shard.shard_round`` — one jit compilation reused by all shards),
  2. outboxes are routed host-side into next-round inboxes (per-(src,dst)
     FIFO preserved; undeliverable overflow is backlogged, never dropped —
     the reliable-channel condition of conditional lock-freedom).

An optional ``delay_rng`` holds back whole (src,dst) channels for a round to
exercise out-of-order-across-pairs delivery (replay retries must heal).

The shard_map/TPU backend with ``all_to_all`` routing lives in
``distributed.py``; it runs the same ``shard_round``.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from . import background as B
from . import messages as M
from . import refs
from .shard import shard_round
from .types import (DiLiConfig, KEY_MAX, KEY_MIN, OP_FIND, OP_INSERT,
                    OP_REMOVE, SH_KEY, ST_KEY, ShardState, init_shard)


class OutboxOverflow(RuntimeError):
    """A shard emitted more messages in one round than ``mailbox_cap``.

    Overflowing rows are not stored (``messages.push``), and a lost
    replicate/ack deadlocks ``run_until_quiet`` — so this is raised
    unconditionally (never an ``assert``: ``python -O`` must not turn it
    into silent truncation). Fix: raise ``cfg.mailbox_cap`` or feed the
    shard fewer ops per round.
    """


class Cluster:
    def __init__(self, cfg: DiLiConfig, *, seed: int = 0,
                 delay_prob: float = 0.0,
                 key_lo: int = KEY_MIN, key_hi: int = KEY_MAX):
        self.cfg = cfg
        self.n = cfg.num_shards
        # shard 0 bootstraps the full key range; the others hold registry
        # replicas routing to it (the paper's lazily-replicated registry
        # starts synchronized).
        self.states: List[ShardState] = [
            init_shard(cfg, s, bootstrap=(s == 0),
                       key_lo=key_lo, key_hi=key_hi)
            for s in range(self.n)
        ]
        from . import registry as reg_ops
        for s in range(1, self.n):
            st = self.states[s]
            reg = reg_ops.add_entry(
                st.registry, key_lo - 1, key_hi,
                refs.make_ref(0, 0), refs.make_ref(0, 1), 0, 0)
            self.states[s] = st._replace(registry=reg)
        self.bgs: List[B.BgState] = [B.init_bg() for _ in range(self.n)]
        self.in_cap = max(cfg.mailbox_cap * self.n, cfg.batch_size * 2)
        self.inboxes = [np.zeros((0, M.FIELDS), np.int32)
                        for _ in range(self.n)]
        self.backlog = [np.zeros((0, M.FIELDS), np.int32)
                        for _ in range(self.n)]
        self.results: Dict[int, int] = {}
        self._next_slot = 0
        self._pending_ops: Dict[int, Tuple[int, int]] = {}
        self.round_no = 0
        self.delay_prob = delay_prob
        self.rng = np.random.default_rng(seed)
        self.stats = {"max_outbox": 0, "max_hops": 0, "rounds": 0,
                      "fast_hits": 0, "mut_hits": 0}

    # ------------------------------------------------------------ client API
    def submit(self, shard: int, kinds: Sequence[int],
               keys: Sequence[int],
               values: Optional[Sequence[int]] = None) -> List[int]:
        """Enqueue fresh client ops at their assigned server ``shard``.

        Returns op ids; results appear in ``self.results`` once linearized.
        ``values`` ride with inserts (item payload, e.g. a KV-page slot).
        ``kinds``/``keys``/``values`` may be any iterables (generators
        included) — they are materialized exactly once up front.
        """
        kinds = [int(k) for k in kinds]
        keys = [int(k) for k in keys]
        if len(kinds) != len(keys):
            raise ValueError(
                f"submit: {len(kinds)} kinds vs {len(keys)} keys")
        values = ([0] * len(keys) if values is None
                  else [int(v) for v in values])
        if len(values) != len(keys):
            raise ValueError(
                f"submit: {len(values)} values vs {len(keys)} keys")
        ids = []
        rows = []
        for kind, key, val in zip(kinds, keys, values):
            slot = self._next_slot
            self._next_slot += 1
            row = np.zeros((M.FIELDS,), np.int32)
            row[M.F_KIND] = M.MSG_OP
            row[M.F_DST] = shard
            row[M.F_SRC] = shard
            row[M.F_A] = int(kind)
            row[M.F_KEY] = int(key)
            row[M.F_REF1] = np.int64(refs.NULL_REF).astype(np.int32)
            row[M.F_SID] = shard
            row[M.F_TS] = slot
            row[M.F_VAL] = int(val)
            rows.append(row)
            ids.append(slot)
            self._pending_ops[slot] = (int(kind), int(key))
        if rows:
            self.backlog[shard] = np.concatenate(
                [self.backlog[shard], np.stack(rows)], axis=0)
        return ids

    # ------------------------------------------------------------- execution
    def step(self) -> int:
        """One synchronized round across all shards. Returns #completed."""
        cfg = self.cfg
        outs = []
        for s in range(self.n):
            # feed: backlog first (FIFO), bounded by in_cap
            feed = self.backlog[s][:self.in_cap]
            self.backlog[s] = self.backlog[s][self.in_cap:]
            inbox = np.zeros((self.in_cap, M.FIELDS), np.int32)
            inbox[:feed.shape[0]] = feed
            client = np.zeros((0, M.FIELDS), np.int32)
            out = shard_round(self.states[s], self.bgs[s], s,
                              jnp.asarray(inbox),
                              jnp.asarray(client.reshape(0, M.FIELDS)),
                              cfg)
            outs.append(out)

        ndone = 0
        new_msgs: List[np.ndarray] = []
        for s, out in enumerate(outs):
            self.states[s] = out.state
            self.bgs[s] = out.bg
            self.stats["fast_hits"] += int(out.fast_hits)
            self.stats["mut_hits"] += int(out.mut_hits)
            cnt = int(out.out_count)
            self.stats["max_outbox"] = max(self.stats["max_outbox"], cnt)
            if cnt > cfg.mailbox_cap:
                # not an assert: under ``python -O`` a dropped message
                # (replicate/ack) would silently deadlock run_until_quiet.
                raise OutboxOverflow(
                    f"shard {s} emitted {cnt} messages in round "
                    f"{self.round_no}, mailbox_cap={cfg.mailbox_cap}: "
                    f"{cnt - cfg.mailbox_cap} rows dropped — raise "
                    f"mailbox_cap or reduce the per-round feed")
            ob = np.asarray(out.outbox)[:cnt]
            if ob.size:
                new_msgs.append(ob)
                hops = ob[ob[:, M.F_KIND] == M.MSG_OP, M.F_X2]
                if hops.size:
                    self.stats["max_hops"] = max(self.stats["max_hops"],
                                                 int(hops.max()))
            cs = np.asarray(out.comp_slot)
            cv = np.asarray(out.comp_val)
            for slot, val in zip(cs[cs >= 0], cv[cs >= 0]):
                self.results[int(slot)] = int(val)
                self._pending_ops.pop(int(slot), None)
                ndone += 1

        # ------------------------------------------------ route (FIFO/pair)
        if new_msgs:
            allm = np.concatenate(new_msgs, axis=0)
            for d in range(self.n):
                mine = allm[allm[:, M.F_DST] == d]
                if self.delay_prob > 0.0 and mine.size:
                    # hold back whole (src,dst) channels — preserves pair
                    # FIFO while exercising cross-pair reordering
                    srcs = np.unique(mine[:, M.F_SRC])
                    held = srcs[self.rng.random(srcs.shape) < self.delay_prob]
                    hold_mask = np.isin(mine[:, M.F_SRC], held)
                    later, now = mine[hold_mask], mine[~hold_mask]
                    self.backlog[d] = np.concatenate(
                        [self.backlog[d], now, later], axis=0)
                else:
                    self.backlog[d] = np.concatenate(
                        [self.backlog[d], mine], axis=0)
        self.round_no += 1
        self.stats["rounds"] += 1
        return ndone

    def run(self, rounds: int) -> None:
        for _ in range(rounds):
            self.step()

    def run_until_quiet(self, max_rounds: int = 200) -> None:
        """Step until no messages are in flight and all bg ops are idle."""
        for _ in range(max_rounds):
            self.step()
            busy = any(b.shape[0] for b in self.backlog)
            busy = busy or any(int(bg.phase) != B.BG_IDLE for bg in self.bgs)
            busy = busy or bool(self._pending_ops)
            if not busy:
                return
        raise RuntimeError(
            f"cluster did not quiesce: backlog="
            f"{[b.shape[0] for b in self.backlog]} "
            f"bg={[int(bg.phase) for bg in self.bgs]} "
            f"pending={len(self._pending_ops)}")

    # ----------------------------------------------------------- inspection
    def shard_chain(self, s: int, head_idx: int, include_meta=False):
        """Walk a chain from a subhead; returns live keys, or
        (key, idx, value) triples with ``include_meta``."""
        st = self.states[s]
        nxt = np.asarray(st.pool.nxt)
        key = np.asarray(st.pool.key)
        vals = np.asarray(st.pool.keymax)
        out = []
        ref = int(nxt[head_idx])
        for _ in range(int(self.cfg.max_scan) * 4):
            idx = ref & refs.IDX_MASK
            sid = (ref & refs.SID_MASK) >> refs.IDX_BITS
            if idx == refs.NULL_IDX or sid != s:
                break
            k = int(key[idx])
            marked = bool(int(nxt[idx]) & refs.MARK_BIT)
            if k == ST_KEY:
                break
            if k != SH_KEY and not marked:
                out.append((k, idx, int(vals[idx])) if include_meta else k)
            ref = int(nxt[idx])
        return out

    def all_keys(self) -> List[int]:
        """Global key set: union over every shard's owned sublists."""
        keys: List[int] = []
        for s in range(self.n):
            st = self.states[s]
            reg = st.registry
            size = int(reg.size)
            for e in range(size):
                sh = int(np.asarray(reg.subhead)[e])
                sid = (sh & refs.SID_MASK) >> refs.IDX_BITS
                if sid != s:
                    continue
                head_idx = sh & refs.IDX_MASK
                slot = int(np.asarray(st.pool.ctr)[head_idx])
                if int(np.asarray(st.stct)[slot]) < 0:
                    continue  # switched-away stale copy
                keys.extend(self.shard_chain(s, head_idx))
        return sorted(keys)

    def sublists(self, s: int):
        """(keymin, keymax, owner, size, head_idx, keymax_id) per entry."""
        st = self.states[s]
        reg = st.registry
        out = []
        for e in range(int(reg.size)):
            sh = int(np.asarray(reg.subhead)[e])
            sid = (sh & refs.SID_MASK) >> refs.IDX_BITS
            head_idx = sh & refs.IDX_MASK
            size = None
            if sid == s:
                size = len(self.shard_chain(s, head_idx))
            out.append(dict(
                keymin=int(np.asarray(reg.keymin)[e]),
                keymax=int(np.asarray(reg.keymax)[e]),
                owner=int(sid), size=size, head_idx=int(head_idx)))
        return out

    # ---------------------------------------------------------- bg commands
    def split(self, s: int, entry_keymax: int, sitem_idx: int) -> None:
        self.bgs[s] = B.queue_split(self.bgs[s], entry_keymax, sitem_idx)

    def move(self, s: int, entry_keymax: int, target: int) -> None:
        self.bgs[s] = B.queue_move(self.bgs[s], entry_keymax, target)

    def merge(self, s: int, left_keymax: int, right_keymax: int) -> None:
        self.bgs[s] = B.queue_merge(self.bgs[s], left_keymax, right_keymax)

    def middle_item(self, s: int, head_idx: int) -> Optional[int]:
        """Pool idx of the middle live item of a sublist (split point)."""
        items = self.shard_chain(s, head_idx, include_meta=True)
        if len(items) < 2:
            return None
        return items[len(items) // 2][1]
