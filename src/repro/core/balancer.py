"""The naive load balancer of §7.1, as a host-side policy over the cluster.

One decision per shard per invocation (the paper runs one background thread
per machine). Policy, verbatim from the paper:

  * Split any owned sublist larger than ``split_threshold`` (125) roughly in
    the middle — this bounds the linear-traversal length of the hybrid search.
  * When a machine holds more than ``move_headroom`` (110%) of the mean load,
    Move one of its sublists to the least-loaded machine.
  * (Extension, Appendix B) Merge adjacent tiny sublists on the same shard
    when both fall below ``merge_threshold`` — keeps the registry compact.

The Split/Move primitives are the *interface*; this policy is deliberately
simple and replaceable (the paper calls for workload-specific balancers).
``Balancer`` is one ``BalancePolicy`` — the client driver loop
(``repro.api.DiLiClient``) runs any policy with a ``step() -> dict``
method at a configurable cadence, over any object exposing the balance
surface (``Cluster`` or an ``api.Backend``: ``n``/``cfg``/``bgs``/
``states``/``sublists``/``middle_item``/``split``/``move``/``merge``).
"""
from __future__ import annotations

from typing import Dict, Optional, Protocol

from . import background as B


class BalancePolicy(Protocol):
    """A pluggable balancing policy: one pass of decisions per call.

    ``step`` inspects the cluster/backend it was constructed over, queues
    Split/Move/Merge commands, and returns issued-command counts; an
    all-zero dict means the policy reached a fixed point (how
    ``DiLiClient.settle`` detects convergence).
    """

    def step(self) -> Dict[str, int]: ...


class Balancer:
    def __init__(self, cluster, *, split_threshold: Optional[int] = None,
                 move_headroom: float = 1.10, merge_threshold: int = 0,
                 registry_headroom: int = 4):
        self.cl = cluster
        self.split_threshold = (split_threshold if split_threshold is not None
                                else cluster.cfg.split_threshold)
        self.move_headroom = move_headroom
        self.merge_threshold = merge_threshold
        self.registry_headroom = registry_headroom

    def _owned(self, s: int):
        return [e for e in self.cl.sublists(s) if e["owner"] == s
                and e["size"] is not None]

    def step(self) -> dict:
        """One balancing pass; returns counts of issued commands."""
        cl = self.cl
        issued = {"split": 0, "move": 0, "merge": 0}
        owned = {s: self._owned(s) for s in range(cl.n)}
        loads = {s: sum(e["size"] for e in owned[s]) for s in range(cl.n)}
        total = sum(loads.values())
        mean = total / max(cl.n, 1)

        for s in range(cl.n):
            if int(cl.bgs[s].phase) != B.BG_IDLE:
                continue
            entries = owned[s]
            # 1) split oversized sublists (registry capacity permitting)
            reg_room = (cl.cfg.max_sublists - int(cl.states[s].registry.size)
                        > self.registry_headroom)
            big = [e for e in entries if e["size"] > self.split_threshold]
            if big and reg_room:
                e = max(big, key=lambda x: x["size"])
                mid = cl.middle_item(s, e["head_idx"])
                if mid is not None:
                    cl.split(s, e["keymax"], mid)
                    issued["split"] += 1
                    continue
            # 2) move a sublist off an overloaded shard
            if cl.n > 1 and loads[s] > self.move_headroom * mean and entries:
                tgt = min(range(cl.n), key=lambda d: loads[d])
                if tgt != s and loads[s] - loads[tgt] > 1:
                    # move the sublist that best evens the load — but only
                    # if it strictly improves the pairwise imbalance (else a
                    # lone big sublist ping-pongs between shards forever)
                    gap = (loads[s] - loads[tgt]) / 2
                    e = min(entries, key=lambda x: abs(x["size"] - gap))
                    if loads[tgt] + e["size"] < loads[s]:
                        cl.move(s, e["keymax"], tgt)
                        issued["move"] += 1
                        continue
            # 3) merge adjacent runts on the same shard
            if self.merge_threshold > 0:
                entries_sorted = sorted(entries, key=lambda x: x["keymin"])
                for a, b in zip(entries_sorted, entries_sorted[1:]):
                    if (a["keymax"] == b["keymin"]
                            and a["size"] + b["size"] < self.merge_threshold):
                        cl.merge(s, a["keymax"], b["keymax"])
                        issued["merge"] += 1
                        break
        return issued
