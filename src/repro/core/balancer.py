"""The naive load balancer of §7.1, as a host-side policy over the cluster.

Policy, verbatim from the paper:

  * Split any owned sublist larger than ``split_threshold`` (125) roughly in
    the middle — this bounds the linear-traversal length of the hybrid search.
  * When a machine holds more than ``move_headroom`` (110%) of the mean load,
    Move one of its sublists to the least-loaded machine.
  * (Extension, Appendix B) Merge adjacent tiny sublists on the same shard
    when both fall below ``merge_threshold`` — keeps the registry compact.

With the slotted background engine (DESIGN.md §10) a pass is no longer
one-decision-per-shard: the gate is per registry *entry* (an entry already
claimed by an in-flight Split/Move/Merge is skipped; every other entry is
fair game), and a shard accepts up to ``bg_slots`` commands per pass. The
load model is kept honest within a pass — each issued Move immediately
transfers the sublist's size from source to target in the working
``loads`` snapshot, so one overloaded pass cannot dogpile every donor
onto the same least-loaded shard.

The load model reads sublist sizes and the BgTable's in-flight moves —
state advanced by move/switch *acks*. Under a lossy wire those acks ride
the reliable transport (DESIGN.md §11), whose per-lane dedup window
guarantees each ack reaches its handler exactly once, so ``acked``
counters (and with them the ``active_moves`` load discount) can never be
double-counted by duplicated deliveries; the balancer needs no defensive
clamping of its own.

The Split/Move/Merge primitives are the *interface*; this policy is
deliberately simple and replaceable (the paper calls for workload-specific
balancers). ``Balancer`` is one ``BalancePolicy`` — the client driver loop
(``repro.api.DiLiClient``) runs any policy with a ``step() -> dict``
method at a configurable cadence, over any object exposing the balance
surface (``Cluster`` or an ``api.Backend``: ``n``/``cfg``/``bgs``/
``states``/``sublists``/``middle_item``/``split``/``move``/``merge``).
"""
from __future__ import annotations

from typing import Dict, Optional, Protocol

from . import bg as B


class BalancePolicy(Protocol):
    """A pluggable balancing policy: one pass of decisions per call.

    ``step`` inspects the cluster/backend it was constructed over, queues
    Split/Move/Merge commands, and returns issued-command counts; an
    all-zero dict means the policy reached a fixed point (how
    ``DiLiClient.settle`` detects convergence).
    """

    def step(self) -> Dict[str, int]: ...


class Balancer:
    def __init__(self, cluster, *, split_threshold: Optional[int] = None,
                 move_headroom: float = 1.10, merge_threshold: int = 0,
                 registry_headroom: int = 4, rng=None,
                 rate_weight: float = 1.0, hot_rate: float = 8.0,
                 cold_rate: float = 2.0, hot_share: float = 0.0,
                 replica_fanout: int = 1):
        self.cl = cluster
        self.split_threshold = (split_threshold if split_threshold is not None
                                else cluster.cfg.split_threshold)
        self.move_headroom = move_headroom
        self.merge_threshold = merge_threshold
        self.registry_headroom = registry_headroom
        # Load model (§15): L(e) = size + rate_weight * op_rate_ewma(e).
        # The op-rate term is the primary signal under traffic; it decays
        # to zero at rest, where the key count is the tiebreak — so a
        # settled cluster balances exactly as the key-calibrated policy
        # always did.
        self.rate_weight = float(rate_weight)
        # Hot/cold hysteresis for read replication: an entry whose op-rate
        # EWMA crosses ``hot_rate`` gets replicated onto the
        # ``replica_fanout`` least-loaded other shards; replicas are
        # dropped only once the rate falls below ``cold_rate`` (< hot) —
        # the band keeps a sublist hovering near the threshold from
        # flapping replicate/drop every pass.
        self.hot_rate = float(hot_rate)
        self.cold_rate = float(cold_rate)
        # Absolute rate alone can't tell skew from volume: a driven
        # shard's hottest entry pins near the admission rate at *any*
        # skew. ``hot_share`` additionally requires the entry to carry
        # that fraction of the cluster-wide rate (0 disables the gate).
        self.hot_share = float(hot_share)
        self.replica_fanout = int(replica_fanout)
        # Move-target tie-break stream. None keeps the historical
        # lowest-index tie-break; passing the backend's ``balancer_rng``
        # (a child of the run's root SeedSequence) makes randomized
        # policies a pure function of the run seed — required for the
        # byte-identical (seed, config) replay contract (DESIGN.md §11).
        self.rng = rng

    def _owned(self, s: int):
        return [e for e in self.cl.sublists(s) if e["owner"] == s
                and e["size"] is not None]

    def step(self) -> dict:
        """One balancing pass; returns counts of issued commands."""
        cl = self.cl
        issued = {"split": 0, "move": 0, "merge": 0, "evacuate": 0,
                  "replicate": 0, "drop": 0}
        # membership view (DESIGN.md §13): sources of load are every
        # routable shard, valid destinations for new moves are
        # active+joining, and draining shards get force-evacuated below.
        # A membership-less cluster (raw duck-typed surface) balances over
        # all shards, exactly as before.
        mb = getattr(cl, "membership", None)
        if mb is None:
            routable = targets = list(range(cl.n))
            draining = []
        else:
            routable = list(mb.routable)
            targets = list(mb.targets)
            draining = list(mb.draining)
        owned = {s: self._owned(s) for s in routable}
        # per-entry effective load: op-rate EWMA (keyed by keymax, pulled
        # off the backend) weighted on top of the key count
        rates = getattr(cl, "op_rate_ewma", None) or {}

        # read replication (§15): the current replica map, and whether the
        # backend supports replication at all (raw duck-typed surfaces
        # without the command are balanced exactly as before)
        rep_on = (getattr(cl.cfg, "replication", False)
                  and hasattr(cl, "replica_sets"))
        repsets = cl.replica_sets() if rep_on else {}

        def eload(e):
            r = rates.get(e["keymax"], 0.0)
            rs = repsets.get(e["keymax"])
            if rs:
                # the entry rate is cluster-wide (replica shards bump the
                # same global registry entry when they serve), but the
                # client spreads reads round-robin over primary+replicas —
                # charge the owner only its share, or the primary looks
                # crushed by load it isn't serving and the balancer churns
                # moves it can never satisfy (the hot entry is pinned).
                # Serving shards are charged via rep_rate_ewma below.
                r /= 1 + len(rs[2])
            return e["size"] + self.rate_weight * r

        def shed_replicas(s, kmax):
            """True when ``kmax`` is replicated: its replicas are told to
            drop and the caller must skip restructuring it this pass —
            Move/Split/Merge on a replicated entry first retires the
            replicas (the primary's session self-audit is only the safety
            net for races, not the clean path)."""
            if kmax not in repsets:
                return False
            if cl.drop_replica(s, kmax):
                issued["drop"] += 1
            del repsets[kmax]
            return True

        loads = {s: sum(eload(e) for e in owned[s]) for s in routable}
        # replica service is real load on the serving shard but invisible
        # to the registry-keyed entry rates (the entry lives on the
        # primary): fold each shard's replica-served FIND EWMA in, or the
        # model reads serving replicas as idle and churns moves (and
        # `shed_replicas` teardowns) against phantom imbalance.
        rep_rates = getattr(cl, "rep_rate_ewma", None) or {}
        for s in routable:
            loads[s] += self.rate_weight * rep_rates.get(s, 0.0)
        total = sum(loads.values())
        # the mean the policy steers toward is over the shards that will
        # still hold data after the drains complete
        mean = total / max(len(targets), 1)

        # per-shard slot budget + per-entry claims of in-flight ops; both
        # are maintained locally as commands are issued this pass. Snapshot
        # ``cl.bgs`` once: on ShardMapBackend every access pulls the whole
        # stacked table device-to-host
        bgs = cl.bgs
        free = {s: B.free_slots(bgs[s]) for s in routable}
        claimed = {s: B.claimed_keys(bgs[s]) for s in routable}

        # account load already *en route*: an in-flight Move's sublist
        # still counts against its source until the registry transfer
        # lands, so without this discount every pass during the (multi-
        # round) copy re-diagnoses the same overload and dogpiles more
        # moves onto it
        for s in routable:
            for key, tgt in B.active_moves(bgs[s]):
                e = next((x for x in owned[s] if x["keymax"] == key), None)
                if e is not None and tgt in loads and tgt != s:
                    loads[s] -= eload(e)
                    loads[tgt] += eload(e)

        # registry budget for *new* splits this pass. The registry is
        # global (every split adds an entry on every replica), and a split
        # whose stabilization finds it full waits in BG_SPLIT_WAIT
        # forever — so the budget must discount (a) splits issued earlier
        # in this pass, and (b) splits still in flight from previous
        # passes on any shard, not just re-read a registry.size those
        # entries haven't landed in yet.
        inflight_splits = sum(
            int(((ph == B.BG_SPLIT_EXEC) | (ph == B.BG_SPLIT_WAIT)).sum())
            for ph in (B.slot_phases(bgs[s]) for s in routable))
        reg_used = max(int(cl.states[s].registry.size) for s in range(cl.n))
        reg_room = (cl.cfg.max_sublists - reg_used
                    - self.registry_headroom - inflight_splits)

        def pick_target(exclude):
            cands = [d for d in targets if d != exclude]
            if not cands:
                return None
            if self.rng is not None:
                # seeded tie-break among equally-loaded targets; min() is
                # stable, so shuffling only reorders ties
                cands = list(cands)
                self.rng.shuffle(cands)
            return min(cands, key=lambda d: loads[d])

        # 0) evacuate draining shards: every sublist they own is force-
        # moved onto the least-loaded target, bypassing the improvement
        # gates of stage 2 — the point is to empty the shard, not to even
        # the load (retire_shard's finish gate waits on owned == 0)
        for s in draining:
            for e in sorted(owned[s], key=lambda x: -x["size"]):
                if free[s] <= 0:
                    break
                if e["keymax"] in claimed[s] or e["switched"]:
                    continue
                if shed_replicas(s, e["keymax"]):
                    continue
                tgt = pick_target(s)
                if tgt is None:
                    break
                if cl.move(s, e["keymax"], tgt):
                    issued["evacuate"] += 1
                    free[s] -= 1
                    claimed[s].add(e["keymax"])
                    loads[s] -= eload(e)
                    loads[tgt] += eload(e)

        for s in targets:
            entries = owned[s]

            def unclaimed(e):
                return e["keymax"] not in claimed[s] and not e["switched"]

            # 1) split oversized sublists (registry budget permitting)
            big = sorted((e for e in entries
                          if e["size"] > self.split_threshold
                          and unclaimed(e)),
                         key=lambda x: -x["size"])
            for e in big:
                if free[s] <= 0 or reg_room <= 0:
                    break
                if shed_replicas(s, e["keymax"]):
                    continue
                mid = cl.middle_item(s, e["head_idx"])
                if mid is None:
                    continue
                if cl.split(s, e["keymax"], mid):
                    issued["split"] += 1
                    free[s] -= 1
                    reg_room -= 1
                    claimed[s].add(e["keymax"])

            # 2) move sublists off an overloaded shard; the working
            # ``loads`` snapshot is adjusted per issued move so parallel
            # donors (and repeated moves within this pass) spread over
            # *currently* least-loaded targets instead of dogpiling the
            # pass-start minimum
            while (len(targets) > 1 and free[s] > 0
                   and loads[s] > self.move_headroom * mean):
                cands = [e for e in entries if unclaimed(e)]
                if not cands:
                    break
                tgt = pick_target(s)
                if tgt is None or loads[s] - loads[tgt] <= 1:
                    break
                # move the sublist that best evens the load — but only
                # if it strictly improves the pairwise imbalance (else a
                # lone big sublist ping-pongs between shards forever)
                gap = (loads[s] - loads[tgt]) / 2
                e = min(cands, key=lambda x: abs(eload(x) - gap))
                if loads[tgt] + eload(e) >= loads[s]:
                    break
                if shed_replicas(s, e["keymax"]):
                    # replicas retire first; the move is re-evaluated on a
                    # later pass once the entry is replica-free
                    entries = [x for x in entries if x is not e]
                    continue
                if not cl.move(s, e["keymax"], tgt):
                    break
                issued["move"] += 1
                free[s] -= 1
                claimed[s].add(e["keymax"])
                loads[s] -= eload(e)
                loads[tgt] += eload(e)
                entries = [x for x in entries if x is not e]

            # 3) merge adjacent runts on the same shard
            if self.merge_threshold > 0:
                entries_sorted = sorted(entries, key=lambda x: x["keymin"])
                for a, b in zip(entries_sorted, entries_sorted[1:]):
                    if free[s] <= 0:
                        break
                    if (a["keymax"] == b["keymin"]
                            and a["size"] + b["size"] < self.merge_threshold
                            and unclaimed(a) and unclaimed(b)):
                        if (shed_replicas(s, a["keymax"])
                                or shed_replicas(s, b["keymax"])):
                            continue
                        if cl.merge(s, a["keymax"], b["keymax"]):
                            issued["merge"] += 1
                            free[s] -= 1
                            claimed[s].add(a["keymax"])
                            claimed[s].add(b["keymax"])

            # 4) hot-sublist read replication (§15): entries whose op-rate
            # EWMA crossed the hot threshold get read replicas on the
            # least-loaded other shards; entries that cooled below the
            # (lower) cold threshold shed theirs. Claimed/switched entries
            # are skipped — a sublist mid-restructure is about to change
            # hands, and replicate-then-drop within one pass is churn.
            if rep_on and len(targets) > 1:
                total_rate = sum(rates.values())
                for e in entries:
                    kmax = e["keymax"]
                    if not unclaimed(e):
                        continue
                    r = rates.get(kmax, 0.0)
                    share = r / total_rate if total_rate > 0 else 0.0
                    have = set(repsets.get(kmax, (0, 0, []))[2])
                    if r >= self.hot_rate and share >= self.hot_share:
                        cands = sorted((d for d in targets
                                        if d != s and d not in have),
                                       key=lambda d: loads[d])
                        want = self.replica_fanout - len(have)
                        for tgt in cands[:max(want, 0)]:
                            if cl.replicate(s, kmax, tgt):
                                issued["replicate"] += 1
                                have.add(tgt)
                            else:
                                break   # session table full: stop asking
                    elif have and r <= self.cold_rate:
                        if cl.drop_replica(s, kmax):
                            issued["drop"] += 1
                        repsets.pop(kmax, None)
        return issued


class AutoscalePolicy:
    """Elastic sizing over a membership-aware backend (DESIGN.md §13):
    the human does not choose the shard count.

    Wraps a ``Balancer`` — every pass first runs the inner policy (splits,
    moves, evacuations), then considers at most *one* membership change:

      * **join** when total load exceeds ``join_headroom`` (125%) of what
        the current active set should carry at ``target_load`` keys per
        shard — a retired slot is admitted and the inner balancer's next
        passes drain sublists onto it;
      * **retire** the least-loaded active shard when total load falls
        below ``retire_headroom`` (45%) of the active set's target
        capacity.

    The wide hysteresis band between the two thresholds, plus a
    ``cooldown`` of quiet passes after every change and the one-change-
    at-a-time rule (no decision while any shard is joining or draining),
    keeps the policy from flapping when load hovers near a boundary.

    Returned counts include ``join``/``retire``, so ``DiLiClient.settle``
    treats a pass that resized the cluster as progress, not a fixed point.
    """

    def __init__(self, backend, *, target_load: int,
                 join_headroom: float = 1.25, retire_headroom: float = 0.45,
                 min_shards: int = 1, max_shards: Optional[int] = None,
                 cooldown: int = 3, balancer: Optional[Balancer] = None,
                 rng=None, rate_weight: float = 1.0):
        if not hasattr(backend, "membership"):
            raise ValueError(
                "AutoscalePolicy needs a membership-aware backend "
                "(Cluster / LocalBackend / ShardMapBackend)")
        self.cl = backend
        self.balancer = (balancer if balancer is not None
                         else Balancer(backend, rng=rng,
                                       rate_weight=rate_weight))
        self.target_load = int(target_load)
        self.join_headroom = float(join_headroom)
        self.retire_headroom = float(retire_headroom)
        self.min_shards = int(min_shards)
        self.max_shards = max_shards
        self.cooldown = int(cooldown)
        # same load model as the inner balancer: op-rate EWMA weighted on
        # top of the key count (rate decays to zero at rest, where the
        # sizing decision falls back to pure key counts)
        self.rate_weight = float(rate_weight)
        self._cool = 0

    def _load(self, s: int) -> float:
        rates = getattr(self.cl, "op_rate_ewma", None) or {}
        return sum(e["size"] + self.rate_weight
                   * rates.get(e["keymax"], 0.0)
                   for e in self.cl.sublists(s)
                   if e["owner"] == s and e["size"] is not None
                   and not e["switched"])

    def step(self) -> dict:
        issued = self.balancer.step()
        issued.setdefault("join", 0)
        issued.setdefault("retire", 0)
        mb = self.cl.membership
        if self._cool > 0:
            # a cooling pass is NOT a fixed point — without the marker,
            # DiLiClient.settle would read the all-zero counts as "done"
            # and stop before the post-cooldown decision ever runs
            self._cool -= 1
            issued["cooldown"] = 1
            return issued
        if mb.joining or mb.draining:
            # one membership change at a time: the previous one must
            # finish (promote / retire) before the next decision —
            # marked as progress for the same reason as cooldown
            issued["inflight"] = 1
            return issued
        loads = {s: self._load(s) for s in mb.active}
        total = sum(loads.values())
        n = len(mb.active)
        cap = mb.capacity if self.max_shards is None else self.max_shards
        if (total > self.join_headroom * self.target_load * n
                and n < cap and mb.retired):
            self.cl.join_shard()
            issued["join"] += 1
            self._cool = self.cooldown
        elif (total < self.retire_headroom * self.target_load * n
                and n > self.min_shards):
            victim = min(mb.active, key=lambda s: (loads[s], s))
            self.cl.retire_shard(victim)
            issued["retire"] += 1
            self._cool = self.cooldown
        return issued
