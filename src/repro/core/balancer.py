"""The naive load balancer of §7.1, as a host-side policy over the cluster.

Policy, verbatim from the paper:

  * Split any owned sublist larger than ``split_threshold`` (125) roughly in
    the middle — this bounds the linear-traversal length of the hybrid search.
  * When a machine holds more than ``move_headroom`` (110%) of the mean load,
    Move one of its sublists to the least-loaded machine.
  * (Extension, Appendix B) Merge adjacent tiny sublists on the same shard
    when both fall below ``merge_threshold`` — keeps the registry compact.

With the slotted background engine (DESIGN.md §10) a pass is no longer
one-decision-per-shard: the gate is per registry *entry* (an entry already
claimed by an in-flight Split/Move/Merge is skipped; every other entry is
fair game), and a shard accepts up to ``bg_slots`` commands per pass. The
load model is kept honest within a pass — each issued Move immediately
transfers the sublist's size from source to target in the working
``loads`` snapshot, so one overloaded pass cannot dogpile every donor
onto the same least-loaded shard.

The load model reads sublist sizes and the BgTable's in-flight moves —
state advanced by move/switch *acks*. Under a lossy wire those acks ride
the reliable transport (DESIGN.md §11), whose per-lane dedup window
guarantees each ack reaches its handler exactly once, so ``acked``
counters (and with them the ``active_moves`` load discount) can never be
double-counted by duplicated deliveries; the balancer needs no defensive
clamping of its own.

The Split/Move/Merge primitives are the *interface*; this policy is
deliberately simple and replaceable (the paper calls for workload-specific
balancers). ``Balancer`` is one ``BalancePolicy`` — the client driver loop
(``repro.api.DiLiClient``) runs any policy with a ``step() -> dict``
method at a configurable cadence, over any object exposing the balance
surface (``Cluster`` or an ``api.Backend``: ``n``/``cfg``/``bgs``/
``states``/``sublists``/``middle_item``/``split``/``move``/``merge``).
"""
from __future__ import annotations

from typing import Dict, Optional, Protocol

from . import bg as B


class BalancePolicy(Protocol):
    """A pluggable balancing policy: one pass of decisions per call.

    ``step`` inspects the cluster/backend it was constructed over, queues
    Split/Move/Merge commands, and returns issued-command counts; an
    all-zero dict means the policy reached a fixed point (how
    ``DiLiClient.settle`` detects convergence).
    """

    def step(self) -> Dict[str, int]: ...


class Balancer:
    def __init__(self, cluster, *, split_threshold: Optional[int] = None,
                 move_headroom: float = 1.10, merge_threshold: int = 0,
                 registry_headroom: int = 4, rng=None):
        self.cl = cluster
        self.split_threshold = (split_threshold if split_threshold is not None
                                else cluster.cfg.split_threshold)
        self.move_headroom = move_headroom
        self.merge_threshold = merge_threshold
        self.registry_headroom = registry_headroom
        # Move-target tie-break stream. None keeps the historical
        # lowest-index tie-break; passing the backend's ``balancer_rng``
        # (a child of the run's root SeedSequence) makes randomized
        # policies a pure function of the run seed — required for the
        # byte-identical (seed, config) replay contract (DESIGN.md §11).
        self.rng = rng

    def _owned(self, s: int):
        return [e for e in self.cl.sublists(s) if e["owner"] == s
                and e["size"] is not None]

    def step(self) -> dict:
        """One balancing pass; returns counts of issued commands."""
        cl = self.cl
        issued = {"split": 0, "move": 0, "merge": 0}
        owned = {s: self._owned(s) for s in range(cl.n)}
        loads = {s: sum(e["size"] for e in owned[s]) for s in range(cl.n)}
        total = sum(loads.values())
        mean = total / max(cl.n, 1)

        # per-shard slot budget + per-entry claims of in-flight ops; both
        # are maintained locally as commands are issued this pass. Snapshot
        # ``cl.bgs`` once: on ShardMapBackend every access pulls the whole
        # stacked table device-to-host
        bgs = cl.bgs
        free = {s: B.free_slots(bgs[s]) for s in range(cl.n)}
        claimed = {s: B.claimed_keys(bgs[s]) for s in range(cl.n)}

        # account load already *en route*: an in-flight Move's sublist
        # still counts against its source until the registry transfer
        # lands, so without this discount every pass during the (multi-
        # round) copy re-diagnoses the same overload and dogpiles more
        # moves onto it
        for s in range(cl.n):
            for key, tgt in B.active_moves(bgs[s]):
                e = next((x for x in owned[s] if x["keymax"] == key), None)
                if e is not None and 0 <= tgt < cl.n and tgt != s:
                    loads[s] -= e["size"]
                    loads[tgt] += e["size"]

        # registry budget for *new* splits this pass. The registry is
        # global (every split adds an entry on every replica), and a split
        # whose stabilization finds it full waits in BG_SPLIT_WAIT
        # forever — so the budget must discount (a) splits issued earlier
        # in this pass, and (b) splits still in flight from previous
        # passes on any shard, not just re-read a registry.size those
        # entries haven't landed in yet.
        inflight_splits = sum(
            int(((ph == B.BG_SPLIT_EXEC) | (ph == B.BG_SPLIT_WAIT)).sum())
            for ph in (B.slot_phases(bgs[s]) for s in range(cl.n)))
        reg_used = max(int(cl.states[s].registry.size) for s in range(cl.n))
        reg_room = (cl.cfg.max_sublists - reg_used
                    - self.registry_headroom - inflight_splits)

        for s in range(cl.n):
            entries = owned[s]

            def unclaimed(e):
                return e["keymax"] not in claimed[s] and not e["switched"]

            # 1) split oversized sublists (registry budget permitting)
            big = sorted((e for e in entries
                          if e["size"] > self.split_threshold
                          and unclaimed(e)),
                         key=lambda x: -x["size"])
            for e in big:
                if free[s] <= 0 or reg_room <= 0:
                    break
                mid = cl.middle_item(s, e["head_idx"])
                if mid is None:
                    continue
                if cl.split(s, e["keymax"], mid):
                    issued["split"] += 1
                    free[s] -= 1
                    reg_room -= 1
                    claimed[s].add(e["keymax"])

            # 2) move sublists off an overloaded shard; the working
            # ``loads`` snapshot is adjusted per issued move so parallel
            # donors (and repeated moves within this pass) spread over
            # *currently* least-loaded targets instead of dogpiling the
            # pass-start minimum
            while (cl.n > 1 and free[s] > 0
                   and loads[s] > self.move_headroom * mean):
                cands = [e for e in entries if unclaimed(e)]
                if not cands:
                    break
                order = list(range(cl.n))
                if self.rng is not None:
                    # seeded tie-break among equally-loaded targets; the
                    # min() below is stable, so shuffling only reorders
                    # ties (load ranking is untouched)
                    self.rng.shuffle(order)
                tgt = min(order, key=lambda d: loads[d])
                if tgt == s or loads[s] - loads[tgt] <= 1:
                    break
                # move the sublist that best evens the load — but only
                # if it strictly improves the pairwise imbalance (else a
                # lone big sublist ping-pongs between shards forever)
                gap = (loads[s] - loads[tgt]) / 2
                e = min(cands, key=lambda x: abs(x["size"] - gap))
                if loads[tgt] + e["size"] >= loads[s]:
                    break
                if not cl.move(s, e["keymax"], tgt):
                    break
                issued["move"] += 1
                free[s] -= 1
                claimed[s].add(e["keymax"])
                loads[s] -= e["size"]
                loads[tgt] += e["size"]
                entries = [x for x in entries if x is not e]

            # 3) merge adjacent runts on the same shard
            if self.merge_threshold > 0:
                entries_sorted = sorted(entries, key=lambda x: x["keymin"])
                for a, b in zip(entries_sorted, entries_sorted[1:]):
                    if free[s] <= 0:
                        break
                    if (a["keymax"] == b["keymin"]
                            and a["size"] + b["size"] < self.merge_threshold
                            and unclaimed(a) and unclaimed(b)):
                        if cl.merge(s, a["keymax"], b["keymax"]):
                            issued["merge"] += 1
                            free[s] -= 1
                            claimed[s].add(a["keymax"])
                            claimed[s].add(b["keymax"])
        return issued
