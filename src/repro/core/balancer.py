"""The naive load balancer of §7.1, as a host-side policy over the cluster.

Policy, verbatim from the paper:

  * Split any owned sublist larger than ``split_threshold`` (125) roughly in
    the middle — this bounds the linear-traversal length of the hybrid search.
  * When a machine holds more than ``move_headroom`` (110%) of the mean load,
    Move one of its sublists to the least-loaded machine.
  * (Extension, Appendix B) Merge adjacent tiny sublists on the same shard
    when both fall below ``merge_threshold`` — keeps the registry compact.

With the slotted background engine (DESIGN.md §10) a pass is no longer
one-decision-per-shard: the gate is per registry *entry* (an entry already
claimed by an in-flight Split/Move/Merge is skipped; every other entry is
fair game), and a shard accepts up to ``bg_slots`` commands per pass. The
load model is kept honest within a pass — each issued Move immediately
transfers the sublist's size from source to target in the working
``loads`` snapshot, so one overloaded pass cannot dogpile every donor
onto the same least-loaded shard.

The load model reads sublist sizes and the BgTable's in-flight moves —
state advanced by move/switch *acks*. Under a lossy wire those acks ride
the reliable transport (DESIGN.md §11), whose per-lane dedup window
guarantees each ack reaches its handler exactly once, so ``acked``
counters (and with them the ``active_moves`` load discount) can never be
double-counted by duplicated deliveries; the balancer needs no defensive
clamping of its own.

The Split/Move/Merge primitives are the *interface*; this policy is
deliberately simple and replaceable (the paper calls for workload-specific
balancers). ``Balancer`` is one ``BalancePolicy`` — the client driver loop
(``repro.api.DiLiClient``) runs any policy with a ``step() -> dict``
method at a configurable cadence, over any object exposing the balance
surface (``Cluster`` or an ``api.Backend``: ``n``/``cfg``/``bgs``/
``states``/``sublists``/``middle_item``/``split``/``move``/``merge``).
"""
from __future__ import annotations

from typing import Dict, Optional, Protocol

from . import bg as B


class BalancePolicy(Protocol):
    """A pluggable balancing policy: one pass of decisions per call.

    ``step`` inspects the cluster/backend it was constructed over, queues
    Split/Move/Merge commands, and returns issued-command counts; an
    all-zero dict means the policy reached a fixed point (how
    ``DiLiClient.settle`` detects convergence).
    """

    def step(self) -> Dict[str, int]: ...


class Balancer:
    def __init__(self, cluster, *, split_threshold: Optional[int] = None,
                 move_headroom: float = 1.10, merge_threshold: int = 0,
                 registry_headroom: int = 4, rng=None):
        self.cl = cluster
        self.split_threshold = (split_threshold if split_threshold is not None
                                else cluster.cfg.split_threshold)
        self.move_headroom = move_headroom
        self.merge_threshold = merge_threshold
        self.registry_headroom = registry_headroom
        # Move-target tie-break stream. None keeps the historical
        # lowest-index tie-break; passing the backend's ``balancer_rng``
        # (a child of the run's root SeedSequence) makes randomized
        # policies a pure function of the run seed — required for the
        # byte-identical (seed, config) replay contract (DESIGN.md §11).
        self.rng = rng

    def _owned(self, s: int):
        return [e for e in self.cl.sublists(s) if e["owner"] == s
                and e["size"] is not None]

    def step(self) -> dict:
        """One balancing pass; returns counts of issued commands."""
        cl = self.cl
        issued = {"split": 0, "move": 0, "merge": 0, "evacuate": 0}
        # membership view (DESIGN.md §13): sources of load are every
        # routable shard, valid destinations for new moves are
        # active+joining, and draining shards get force-evacuated below.
        # A membership-less cluster (raw duck-typed surface) balances over
        # all shards, exactly as before.
        mb = getattr(cl, "membership", None)
        if mb is None:
            routable = targets = list(range(cl.n))
            draining = []
        else:
            routable = list(mb.routable)
            targets = list(mb.targets)
            draining = list(mb.draining)
        owned = {s: self._owned(s) for s in routable}
        loads = {s: sum(e["size"] for e in owned[s]) for s in routable}
        total = sum(loads.values())
        # the mean the policy steers toward is over the shards that will
        # still hold data after the drains complete
        mean = total / max(len(targets), 1)

        # per-shard slot budget + per-entry claims of in-flight ops; both
        # are maintained locally as commands are issued this pass. Snapshot
        # ``cl.bgs`` once: on ShardMapBackend every access pulls the whole
        # stacked table device-to-host
        bgs = cl.bgs
        free = {s: B.free_slots(bgs[s]) for s in routable}
        claimed = {s: B.claimed_keys(bgs[s]) for s in routable}

        # account load already *en route*: an in-flight Move's sublist
        # still counts against its source until the registry transfer
        # lands, so without this discount every pass during the (multi-
        # round) copy re-diagnoses the same overload and dogpiles more
        # moves onto it
        for s in routable:
            for key, tgt in B.active_moves(bgs[s]):
                e = next((x for x in owned[s] if x["keymax"] == key), None)
                if e is not None and tgt in loads and tgt != s:
                    loads[s] -= e["size"]
                    loads[tgt] += e["size"]

        # registry budget for *new* splits this pass. The registry is
        # global (every split adds an entry on every replica), and a split
        # whose stabilization finds it full waits in BG_SPLIT_WAIT
        # forever — so the budget must discount (a) splits issued earlier
        # in this pass, and (b) splits still in flight from previous
        # passes on any shard, not just re-read a registry.size those
        # entries haven't landed in yet.
        inflight_splits = sum(
            int(((ph == B.BG_SPLIT_EXEC) | (ph == B.BG_SPLIT_WAIT)).sum())
            for ph in (B.slot_phases(bgs[s]) for s in routable))
        reg_used = max(int(cl.states[s].registry.size) for s in range(cl.n))
        reg_room = (cl.cfg.max_sublists - reg_used
                    - self.registry_headroom - inflight_splits)

        def pick_target(exclude):
            cands = [d for d in targets if d != exclude]
            if not cands:
                return None
            if self.rng is not None:
                # seeded tie-break among equally-loaded targets; min() is
                # stable, so shuffling only reorders ties
                cands = list(cands)
                self.rng.shuffle(cands)
            return min(cands, key=lambda d: loads[d])

        # 0) evacuate draining shards: every sublist they own is force-
        # moved onto the least-loaded target, bypassing the improvement
        # gates of stage 2 — the point is to empty the shard, not to even
        # the load (retire_shard's finish gate waits on owned == 0)
        for s in draining:
            for e in sorted(owned[s], key=lambda x: -x["size"]):
                if free[s] <= 0:
                    break
                if e["keymax"] in claimed[s] or e["switched"]:
                    continue
                tgt = pick_target(s)
                if tgt is None:
                    break
                if cl.move(s, e["keymax"], tgt):
                    issued["evacuate"] += 1
                    free[s] -= 1
                    claimed[s].add(e["keymax"])
                    loads[s] -= e["size"]
                    loads[tgt] += e["size"]

        for s in targets:
            entries = owned[s]

            def unclaimed(e):
                return e["keymax"] not in claimed[s] and not e["switched"]

            # 1) split oversized sublists (registry budget permitting)
            big = sorted((e for e in entries
                          if e["size"] > self.split_threshold
                          and unclaimed(e)),
                         key=lambda x: -x["size"])
            for e in big:
                if free[s] <= 0 or reg_room <= 0:
                    break
                mid = cl.middle_item(s, e["head_idx"])
                if mid is None:
                    continue
                if cl.split(s, e["keymax"], mid):
                    issued["split"] += 1
                    free[s] -= 1
                    reg_room -= 1
                    claimed[s].add(e["keymax"])

            # 2) move sublists off an overloaded shard; the working
            # ``loads`` snapshot is adjusted per issued move so parallel
            # donors (and repeated moves within this pass) spread over
            # *currently* least-loaded targets instead of dogpiling the
            # pass-start minimum
            while (len(targets) > 1 and free[s] > 0
                   and loads[s] > self.move_headroom * mean):
                cands = [e for e in entries if unclaimed(e)]
                if not cands:
                    break
                tgt = pick_target(s)
                if tgt is None or loads[s] - loads[tgt] <= 1:
                    break
                # move the sublist that best evens the load — but only
                # if it strictly improves the pairwise imbalance (else a
                # lone big sublist ping-pongs between shards forever)
                gap = (loads[s] - loads[tgt]) / 2
                e = min(cands, key=lambda x: abs(x["size"] - gap))
                if loads[tgt] + e["size"] >= loads[s]:
                    break
                if not cl.move(s, e["keymax"], tgt):
                    break
                issued["move"] += 1
                free[s] -= 1
                claimed[s].add(e["keymax"])
                loads[s] -= e["size"]
                loads[tgt] += e["size"]
                entries = [x for x in entries if x is not e]

            # 3) merge adjacent runts on the same shard
            if self.merge_threshold > 0:
                entries_sorted = sorted(entries, key=lambda x: x["keymin"])
                for a, b in zip(entries_sorted, entries_sorted[1:]):
                    if free[s] <= 0:
                        break
                    if (a["keymax"] == b["keymin"]
                            and a["size"] + b["size"] < self.merge_threshold
                            and unclaimed(a) and unclaimed(b)):
                        if cl.merge(s, a["keymax"], b["keymax"]):
                            issued["merge"] += 1
                            free[s] -= 1
                            claimed[s].add(a["keymax"])
                            claimed[s].add(b["keymax"])
        return issued


class AutoscalePolicy:
    """Elastic sizing over a membership-aware backend (DESIGN.md §13):
    the human does not choose the shard count.

    Wraps a ``Balancer`` — every pass first runs the inner policy (splits,
    moves, evacuations), then considers at most *one* membership change:

      * **join** when total load exceeds ``join_headroom`` (125%) of what
        the current active set should carry at ``target_load`` keys per
        shard — a retired slot is admitted and the inner balancer's next
        passes drain sublists onto it;
      * **retire** the least-loaded active shard when total load falls
        below ``retire_headroom`` (45%) of the active set's target
        capacity.

    The wide hysteresis band between the two thresholds, plus a
    ``cooldown`` of quiet passes after every change and the one-change-
    at-a-time rule (no decision while any shard is joining or draining),
    keeps the policy from flapping when load hovers near a boundary.

    Returned counts include ``join``/``retire``, so ``DiLiClient.settle``
    treats a pass that resized the cluster as progress, not a fixed point.
    """

    def __init__(self, backend, *, target_load: int,
                 join_headroom: float = 1.25, retire_headroom: float = 0.45,
                 min_shards: int = 1, max_shards: Optional[int] = None,
                 cooldown: int = 3, balancer: Optional[Balancer] = None,
                 rng=None):
        if not hasattr(backend, "membership"):
            raise ValueError(
                "AutoscalePolicy needs a membership-aware backend "
                "(Cluster / LocalBackend / ShardMapBackend)")
        self.cl = backend
        self.balancer = (balancer if balancer is not None
                         else Balancer(backend, rng=rng))
        self.target_load = int(target_load)
        self.join_headroom = float(join_headroom)
        self.retire_headroom = float(retire_headroom)
        self.min_shards = int(min_shards)
        self.max_shards = max_shards
        self.cooldown = int(cooldown)
        self._cool = 0

    def _load(self, s: int) -> int:
        return sum(e["size"] for e in self.cl.sublists(s)
                   if e["owner"] == s and e["size"] is not None
                   and not e["switched"])

    def step(self) -> dict:
        issued = self.balancer.step()
        issued.setdefault("join", 0)
        issued.setdefault("retire", 0)
        mb = self.cl.membership
        if self._cool > 0:
            # a cooling pass is NOT a fixed point — without the marker,
            # DiLiClient.settle would read the all-zero counts as "done"
            # and stop before the post-cooldown decision ever runs
            self._cool -= 1
            issued["cooldown"] = 1
            return issued
        if mb.joining or mb.draining:
            # one membership change at a time: the previous one must
            # finish (promote / retire) before the next decision —
            # marked as progress for the same reason as cooldown
            issued["inflight"] = 1
            return issued
        loads = {s: self._load(s) for s in mb.active}
        total = sum(loads.values())
        n = len(mb.active)
        cap = mb.capacity if self.max_shards is None else self.max_shards
        if (total > self.join_headroom * self.target_load * n
                and n < cap and mb.retired):
            self.cl.join_shard()
            issued["join"] += 1
            self._cool = self.cooldown
        elif (total < self.retire_headroom * self.target_load * n
                and n > self.min_shards):
            victim = min(mb.active, key=lambda s: (loads[s], s))
            self.cl.retire_shard(victim)
            issued["retire"] += 1
            self._cool = self.cooldown
        return issued
