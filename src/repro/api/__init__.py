"""Public client API for the DiLi distributed list (DESIGN.md §9).

    from repro.api import DiLiClient, LocalBackend

    backend = LocalBackend(DiLiConfig(num_shards=4, ...))
    client = DiLiClient(backend, balance=Balancer(backend))
    fut = client.insert(42)
    client.drain()
    assert fut.result()

The same client runs against ``ShardMapBackend`` (SPMD device mesh) with
no workload changes.
"""
from .backend import Backend, LocalBackend, ShardMapBackend
from .client import DiLiClient, RegistryCache, local_client
from .futures import BatchResult, OpFuture

__all__ = [
    "Backend", "BatchResult", "DiLiClient", "LocalBackend", "OpFuture",
    "RegistryCache", "ShardMapBackend", "local_client",
]
