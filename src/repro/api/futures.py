"""Futures for the DiLi client API (DESIGN.md §9).

A ``DiLiClient`` call returns immediately with an ``OpFuture``; the op is
admitted, routed, executed and its result harvested by the client's
``pump()``/``drain()`` driver loop. Batched calls return a ``BatchResult``
wrapping one future per op in submission order.

Futures deliberately carry routing metadata (``shard`` = the predicted
owner at admission, ``src`` = the shard that actually executed the op) —
the mismatch between the two is the wrong-route signal the client's
registry cache refreshes on.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple


class OpFuture:
    """One pending DiLi operation."""

    __slots__ = ("kind", "key", "value", "shard", "src", "op_id",
                 "via_replica", "_client", "_result")

    def __init__(self, client, kind: int, key: int, value: int = 0):
        self._client = client
        self.kind = int(kind)
        self.key = int(key)
        self.value = int(value)
        self.shard: Optional[int] = None    # predicted owner at admission
        self.src: Optional[int] = None      # shard that executed the op
        self.op_id: Optional[int] = None    # backend op id while in flight
        self.via_replica = False            # FIND aimed at a read replica
        self._result: Optional[int] = None

    # ------------------------------------------------------------- protocol
    @property
    def done(self) -> bool:
        return self._result is not None

    def result(self, wait: bool = True) -> bool:
        """The op's linearized boolean result.

        If the op is still pending and ``wait`` is true, drives the owning
        client's ``drain()`` loop until it resolves; with ``wait=False`` a
        pending future raises ``RuntimeError`` instead.
        """
        if self._result is None:
            if not wait:
                raise RuntimeError(
                    f"op {self._opname()} key={self.key} still pending — "
                    f"pump()/drain() the client first")
            self._client.drain()
            if self._result is None:    # pragma: no cover - drain raises
                raise RuntimeError("drain() returned with op unresolved")
        return bool(self._result)

    def raw(self) -> int:
        """The raw RES_* code (result(wait=False) without bool coercion)."""
        if self._result is None:
            raise RuntimeError("op still pending")
        return int(self._result)

    def _resolve(self, value: int, src: int) -> None:
        self._result = int(value)
        self.src = int(src)

    def _opname(self) -> str:
        from repro.core.types import OP_FIND, OP_INSERT, OP_REMOVE
        return {OP_FIND: "find", OP_INSERT: "insert",
                OP_REMOVE: "remove"}.get(self.kind, str(self.kind))

    def __repr__(self) -> str:
        state = (f"done result={bool(self._result)}" if self.done
                 else "pending")
        return f"<OpFuture {self._opname()}({self.key}) {state}>"


class RangeResult:
    """One pending RANGE(lo, hi, limit) scan (DESIGN.md §16).

    Resolves to the scan's sorted ``(key, value)`` items plus the item
    count the terminal result reported. A negative count is a protocol
    error code (e.g. ``RES_OVERFLOW`` when the scan exhausted its hop
    budget before emitting anything); ``items()``/``count()`` raise on
    it, ``raw()`` exposes it.
    """

    __slots__ = ("lo", "hi", "limit", "shard", "src", "op_id",
                 "_client", "_count", "_items")

    def __init__(self, client, lo: int, hi: int, limit: int):
        self._client = client
        self.lo = int(lo)
        self.hi = int(hi)
        self.limit = int(limit)
        self.shard: Optional[int] = None    # predicted owner of ``lo``
        self.src: Optional[int] = None      # shard that sent the terminal
        self.op_id: Optional[int] = None
        self._count: Optional[int] = None
        self._items: Optional[List[Tuple[int, int]]] = None

    @property
    def done(self) -> bool:
        return self._count is not None

    def _wait(self, wait: bool) -> None:
        if self._count is None:
            if not wait:
                raise RuntimeError(
                    f"range [{self.lo}, {self.hi}) still pending — "
                    f"pump()/drain() the client first")
            self._client.drain()

    def items(self, wait: bool = True) -> List[Tuple[int, int]]:
        """The scanned ``(key, value)`` pairs, sorted by key."""
        self._wait(wait)
        if self._count < 0:
            raise RuntimeError(
                f"range [{self.lo}, {self.hi}) failed with code "
                f"{self._count}")
        return list(self._items)

    def keys(self, wait: bool = True) -> List[int]:
        return [k for k, _ in self.items(wait)]

    def count(self, wait: bool = True) -> int:
        self._wait(wait)
        if self._count < 0:
            raise RuntimeError(
                f"range [{self.lo}, {self.hi}) failed with code "
                f"{self._count}")
        return int(self._count)

    def raw(self) -> int:
        """The raw terminal count / error code (no wait)."""
        if self._count is None:
            raise RuntimeError("range still pending")
        return int(self._count)

    def _resolve(self, count: int, src: int,
                 items: List[Tuple[int, int]]) -> None:
        self._count = int(count)
        self.src = int(src)
        self._items = items

    def __repr__(self) -> str:
        state = (f"done count={self._count}" if self.done else "pending")
        return f"<RangeResult [{self.lo}, {self.hi}) {state}>"


class BatchResult:
    """Futures of one batched submission, in submission order."""

    __slots__ = ("futures",)

    def __init__(self, futures: Sequence[OpFuture]):
        self.futures = list(futures)

    @property
    def done(self) -> bool:
        return all(f.done for f in self.futures)

    def results(self, wait: bool = True) -> List[bool]:
        return [f.result(wait=wait) for f in self.futures]

    def __iter__(self):
        return iter(self.futures)

    def __len__(self) -> int:
        return len(self.futures)

    def __getitem__(self, i):
        return self.futures[i]

    def __repr__(self) -> str:
        ndone = sum(f.done for f in self.futures)
        return f"<BatchResult {ndone}/{len(self.futures)} done>"
