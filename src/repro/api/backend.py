"""Execution backends behind ``DiLiClient`` (DESIGN.md §9).

A backend is one round-based execution engine for the DiLi protocol. The
client is backend-agnostic: the same workload runs unchanged against the
single-host simulator (``LocalBackend`` wrapping ``core.sim.Cluster``) or
the SPMD device mesh (``ShardMapBackend`` wrapping
``core.distributed.make_dili_round``).

The contract (``Backend`` protocol):

  * ``submit(shard, kinds, keys, values)`` enqueues fresh client ops at a
    server and returns op ids;
  * ``step()`` runs one synchronized round and returns the ops completed in
    it as ``(op_id, result, src_shard)`` triples — ``src_shard`` is the
    shard that *executed* the op, the client's route-correction signal.
    Returned op ids are recycled by the backend;
  * ``quiescent()`` — no messages in flight and all background ops idle;
  * ``registry_entries(shard)`` — one shard's (lazily-replicated) registry
    view, which clients seed/refresh their route cache from;
  * the balance surface (``sublists``/``middle_item``/``split``/``move``/
    ``merge`` plus ``states``/``bgs``/``cfg``/``n``) — the same duck type
    ``core.balancer.Balancer`` has always driven, so today's balancer runs
    unmodified as a policy over either backend.
"""
from __future__ import annotations

from collections import deque
from typing import Dict, List, Optional, Protocol, Sequence, Tuple

import numpy as np

from repro.core import bg as B
from repro.core.durability import wal
from repro.core import messages as M
from repro.core import range_scan as RS
from repro.core import refs
from repro.core import replica as R
from repro.core.membership import (Membership, epoch_row, moves_targeting,
                                   owned_entry_count)
from repro.core.sim import (Cluster, OpIdAllocator, OutboxOverflow,
                            chain_keys, global_keys, make_op_row,
                            materialize_ops, registry_entries,
                            state_sublists)
from repro.core.types import (DiLiConfig, KEY_MAX, KEY_MIN, SH_KEY,
                              ST_KEY)

Completion = Tuple[int, int, int]           # (op_id, result, src_shard)
RegEntry = Tuple[int, int, int]             # (keymin, keymax, owner)


class Backend(Protocol):
    """Round-based DiLi execution engine (see module docstring)."""

    cfg: DiLiConfig
    stats: Dict[str, int]

    @property
    def n(self) -> int: ...

    def submit(self, shard: int, kinds: Sequence[int], keys: Sequence[int],
               values: Optional[Sequence[int]] = None) -> List[int]: ...

    # RANGE scans (DESIGN.md §16): completion carries the item *count*
    # (or a negative RES_* error); the (key, value) pairs are fetched
    # once with ``take_range_items`` after the op completes.
    def submit_range(self, shard: int, lo: int, hi: int,
                     limit: int) -> int: ...

    def take_range_items(self, op_id: int) -> List[Tuple[int, int]]: ...

    def step(self) -> List[Completion]: ...

    def quiescent(self) -> bool: ...

    def registry_entries(self, shard: int = 0) -> List[RegEntry]: ...

    # ------------------------------------------------------ balance surface
    def sublists(self, s: int) -> List[dict]: ...

    def middle_item(self, s: int, head_idx: int) -> Optional[int]: ...

    # each returns True when a background slot accepted the command,
    # False when it was dropped (no idle slot / entry already claimed)
    def split(self, s: int, entry_keymax: int, sitem_idx: int) -> bool: ...

    def move(self, s: int, entry_keymax: int, target: int) -> bool: ...

    def merge(self, s: int, left_keymax: int, right_keymax: int) -> bool: ...

    # -------------------------------------------------- replication (§15)
    # op-rate load signal + hot-entry read replication; ``replica_epoch``
    # bumps whenever the replica map changes so clients know to re-pull
    # ``replica_sets()`` for FIND routing.
    def replicate(self, s: int, entry_keymax: int, target: int) -> bool: ...

    def drop_replica(self, s: int, entry_keymax: int,
                     target: int = -1) -> bool: ...

    def replica_sets(self) -> Dict[int, Tuple[int, int, List[int]]]: ...


class LocalBackend:
    """The single-host simulator as a client backend.

    Wraps ``core.sim.Cluster`` — which stays the execution machinery (round
    loop, host-side routing, overflow detection) while this class adapts it
    to the backend contract: per-step completion harvesting with executing
    shard, and op-id recycling via ``Cluster.take_result``.
    """

    def __init__(self, cfg: Optional[DiLiConfig] = None, *,
                 cluster: Optional[Cluster] = None, seed: int = 0,
                 delay_prob: float = 0.0, nemesis=None,
                 retransmit_after: int = 4, net_window: int = 4096,
                 key_lo: int = KEY_MIN, key_hi: int = KEY_MAX,
                 initial_shards: Optional[int] = None,
                 trace: Optional[bool] = None, durability=None):
        if cluster is None:
            if cfg is None:
                raise ValueError("LocalBackend needs a DiLiConfig or Cluster")
            cluster = Cluster(cfg, seed=seed, delay_prob=delay_prob,
                              nemesis=nemesis,
                              retransmit_after=retransmit_after,
                              net_window=net_window,
                              key_lo=key_lo, key_hi=key_hi,
                              initial_shards=initial_shards, trace=trace,
                              durability=durability)
        self.cluster = cluster
        self.cfg = cluster.cfg
        self._issued: set = set()
        # RANGE ops issued through this backend; items are captured at
        # harvest time (``Cluster.take_result`` purges the cluster-side
        # parts, so they must be pulled *before* the id is recycled) and
        # held here until the caller fetches them.
        self._range_issued: set = set()
        self._range_items: Dict[int, List[Tuple[int, int]]] = {}

    # ------------------------------------------------------------- protocol
    @property
    def n(self) -> int:
        return self.cluster.n

    @property
    def stats(self) -> Dict[str, int]:
        return self.cluster.stats

    def submit(self, shard, kinds, keys, values=None) -> List[int]:
        ids = self.cluster.submit(shard, kinds, keys, values)
        self._issued.update(ids)
        return ids

    def submit_range(self, shard: int, lo: int, hi: int,
                     limit: int) -> int:
        op_id = self.cluster.submit_range(shard, lo, hi, limit)
        self._issued.add(op_id)
        self._range_issued.add(op_id)
        return op_id

    def take_range_items(self, op_id: int) -> List[Tuple[int, int]]:
        return self._range_items.pop(op_id)

    def step(self) -> List[Completion]:
        """One round; returns and recycles completions of ops issued
        *through this backend*. Ops submitted raw at the wrapped cluster
        keep their results in ``cluster.results`` untouched — draining
        them would orphan the raw caller's poll loop and let its live id
        be reissued to a client op. Harvesting goes through
        ``cluster.results`` (not ``last_completions``, which the next raw
        ``Cluster.step`` overwrites) so tools stepping the cluster
        directly between backend rounds cannot orphan client futures."""
        self.cluster.step()
        comps = []
        done = [op_id for op_id in self._issued
                if op_id in self.cluster.results]
        for op_id in done:
            src = self.cluster.result_src.get(op_id, -1)
            if op_id in self._range_issued:
                # pull the scan items before take_result purges them
                self._range_items[op_id] = \
                    self.cluster.take_range_items(op_id)
                self._range_issued.discard(op_id)
            val = self.cluster.take_result(op_id)   # pops + recycles the id
            self._issued.discard(op_id)
            comps.append((op_id, val, src))
        return comps

    @property
    def net(self):
        """The reliable transport, or None when routing is direct."""
        return self.cluster.net

    @property
    def balancer_rng(self):
        """Balancer child stream of the run's root SeedSequence."""
        return self.cluster.balancer_rng

    # ------------------------------------------------- membership (§13)
    @property
    def membership(self) -> Membership:
        return self.cluster.membership

    def join_shard(self, shard: Optional[int] = None) -> int:
        return self.cluster.join_shard(shard)

    def retire_shard(self, shard: int) -> None:
        self.cluster.retire_shard(shard)

    def quiescent(self) -> bool:
        cl = self.cluster
        if cl.membership.crashed:
            return False        # keep stepping toward the scheduled restart
        if any(b.shape[0] for b in cl.backlog):
            return False
        if cl.net is not None and not cl.net.idle():
            return False
        return not any(B.any_active(bg) for bg in cl.bgs)

    def registry_entries(self, shard: int = 0) -> List[RegEntry]:
        return self.cluster.registry_entries(shard)

    # ------------------------------------------------------ balance surface
    @property
    def states(self):
        return self.cluster.states

    @property
    def bgs(self):
        return self.cluster.bgs

    def sublists(self, s: int):
        return self.cluster.sublists(s)

    def middle_item(self, s: int, head_idx: int) -> Optional[int]:
        return self.cluster.middle_item(s, head_idx)

    def split(self, s, entry_keymax, sitem_idx) -> bool:
        return self.cluster.split(s, entry_keymax, sitem_idx)

    def move(self, s, entry_keymax, target) -> bool:
        return self.cluster.move(s, entry_keymax, target)

    def merge(self, s, left_keymax, right_keymax) -> bool:
        return self.cluster.merge(s, left_keymax, right_keymax)

    # -------------------------------------------------- replication (§15)
    @property
    def op_rate_ewma(self):
        return self.cluster.op_rate_ewma

    @property
    def rep_rate_ewma(self):
        return self.cluster.rep_rate_ewma

    @property
    def replica_epoch(self) -> int:
        return self.cluster.replica_epoch

    def replicate(self, s, entry_keymax, target) -> bool:
        return self.cluster.replicate(s, entry_keymax, target)

    def drop_replica(self, s, entry_keymax, target=-1) -> bool:
        return self.cluster.drop_replica(s, entry_keymax, target)

    def replica_sets(self):
        return self.cluster.replica_sets()

    # ------------------------------------------------------------ debugging
    def all_keys(self) -> List[int]:
        return self.cluster.all_keys()

    def shard_chain(self, s, head_idx, include_meta=False):
        return self.cluster.shard_chain(s, head_idx, include_meta)


class ShardMapBackend:
    """The SPMD ``shard_map`` round as a client backend.

    One device of the mesh per DiLi shard; routing is the on-device
    ``all_to_all`` inside ``make_dili_round``. The host side here only
    feeds client batches, harvests completions, and keeps the same overflow
    discipline as the simulator: ``cap_pair`` defaults to ``mailbox_cap``
    so no per-destination bucket can drop a row without the (host-checked)
    total outbox count exceeding ``mailbox_cap`` first — which raises
    ``OutboxOverflow`` exactly like ``Cluster.step``.

    The balance surface works on host snapshots of the stacked device
    state (pulled lazily, invalidated each round); Split/Move/Merge are
    queued by editing the stacked ``BgState`` in place, and execute inside
    the jitted round like any other background phase.
    """

    def __init__(self, cfg: DiLiConfig, *, mesh=None,
                 cap_pair: Optional[int] = None, seed: int = 0,
                 nemesis=None, retransmit_after: int = 4,
                 net_window: int = 4096,
                 key_lo: int = KEY_MIN, key_hi: int = KEY_MAX,
                 initial_shards: Optional[int] = None,
                 durability=None):
        import jax
        import jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.core.distributed import (make_dili_round,
                                            make_dili_round_hostroute,
                                            stack_states)
        from repro.core.net import Nemesis, Transport
        self._jnp = jnp
        self._jax = jax
        self.cfg = cfg
        if mesh is None:
            devs = np.array(jax.devices())
            if devs.size < cfg.num_shards:
                raise ValueError(
                    f"need {cfg.num_shards} devices for {cfg.num_shards} "
                    f"shards, have {devs.size} (set "
                    f"--xla_force_host_platform_device_count)")
            mesh = Mesh(devs[:cfg.num_shards].reshape(cfg.num_shards),
                        ("shard",))
        self.mesh = mesh
        self.cap_pair = int(cap_pair if cap_pair is not None
                            else cfg.mailbox_cap)
        if self.cap_pair < cfg.mailbox_cap:
            # with cap_pair < mailbox_cap a single destination's bucket can
            # drop rows while the total outbox stays under mailbox_cap —
            # the host-side overflow check would never fire, and a dropped
            # replicate/ack deadlocks the protocol silently
            raise ValueError(
                f"cap_pair={self.cap_pair} < mailbox_cap="
                f"{cfg.mailbox_cap}: per-destination buckets could drop "
                f"rows undetected")
        # borrow the simulator's init: bootstrap sublist on shard 0 plus
        # synchronized registry replicas everywhere else — and the
        # membership overlay, so both backends share one lifecycle engine
        boot = Cluster(cfg, seed=seed, key_lo=key_lo, key_hi=key_hi,
                       initial_shards=initial_shards)
        self.membership = boot.membership
        self._mb_logged = 0
        self._states, self._bgs = stack_states(boot.states, boot.bgs)
        # same child-stream layout as Cluster: (delay, nemesis, balancer)
        self.seed = seed
        root = np.random.SeedSequence(seed)
        _, nemesis_ss, balancer_ss = root.spawn(3)
        self.balancer_rng = np.random.default_rng(balancer_ss)
        self.nemesis_config = nemesis
        self.net = None
        self.round_trace: List[str] = []
        if nemesis is not None:
            # nemesis lives on the wire between outboxes and inboxes, so
            # routing crosses the host: the round skips its on-device
            # all_to_all and the Transport does delivery
            self.net = Transport(
                cfg.num_shards,
                Nemesis(nemesis, np.random.default_rng(nemesis_ss)),
                retransmit_after=retransmit_after, window=net_window)
            self._rnd = make_dili_round_hostroute(mesh, cfg)
            self.in_cap = max(cfg.mailbox_cap * cfg.num_shards,
                              cfg.batch_size * 2)
            self._net_backlog = [np.zeros((0, M.FIELDS), np.int32)
                                 for _ in range(cfg.num_shards)]
        else:
            self._rnd = make_dili_round(mesh, cfg, cap_pair=self.cap_pair)
            self.in_cap = cfg.num_shards * self.cap_pair
            # the persistent device inbox feeds the all_to_all round;
            # the hostroute path builds a fresh host inbox each round
            self._inbox = jnp.zeros(
                (cfg.num_shards, self.in_cap, M.FIELDS), jnp.int32)
        self._inflight_msgs = 0
        self._queues: List[deque] = [deque() for _ in range(cfg.num_shards)]
        self._ids = OpIdAllocator()
        self._host_states: Optional[list] = None
        self.round_no = 0
        # durability + crash plans (DESIGN.md §14): same semantics as
        # Cluster — crashes ride the nemesis config (hostroute path), so
        # the transport's down-NIC model and the WAL see the same rounds.
        from repro.core.durability import Durability
        from repro.core.durability.engine import validate_crash_plans
        self._crash_plans = tuple(nemesis.crashes) if nemesis else ()
        if self._crash_plans:
            validate_crash_plans(self._crash_plans, cfg.num_shards)
        self._tmp_durability = None
        if durability is None and self._crash_plans:
            import tempfile
            self._tmp_durability = tempfile.TemporaryDirectory(
                prefix="dili-durability-")
            durability = self._tmp_durability.name
        self.durability: Optional[Durability] = None
        if durability is not None:
            self.durability = (durability if isinstance(durability,
                                                        Durability)
                               else Durability(durability, cfg))
            empty = np.zeros((0, M.FIELDS), np.int32)
            for s in range(cfg.num_shards):
                self.durability.ensure_genesis(
                    s, boot.states[s], boot.bgs[s], empty,
                    self.net.export_shard_lanes(s)
                    if self.net is not None else {})
        self.stats = {"max_outbox": 0, "max_hops": 0, "rounds": 0,
                      "fast_hits": 0, "mut_hits": 0, "delegated": 0,
                      "move_hits": 0, "blk_hits": 0, "max_bg_active": 0,
                      "rep_hits": 0, "range_hits": 0}
        # RANGE reassembly (DESIGN.md §16) — same count-gated protocol
        # as ``Cluster``: items and the terminal count ride separate
        # completion rows (and, across shards, separate transport lanes),
        # so publication waits until every journaled item arrived.
        self._range_ops: set = set()
        self._range_parts: Dict[int, List[Tuple[int, int]]] = {}
        self._range_done: Dict[int, Tuple[int, int]] = {}
        self._range_items: Dict[int, List[Tuple[int, int]]] = {}
        # same load/replication host state as Cluster (see sim.py): the
        # balancer and client API read an identical surface off either
        # backend.
        self.op_rate_ewma: Dict[int, float] = {}
        self.rep_rate_ewma: Dict[int, float] = {}
        self._replica_map: Dict[int, Tuple[int, set]] = {}
        self.replica_epoch = 0
        if cfg.replication:
            tree_map = self._jax.tree_util.tree_map
            R.warm_commands(tree_map(lambda x: x[0], self._states), cfg)

    # ------------------------------------------------------------- protocol
    @property
    def n(self) -> int:
        return self.cfg.num_shards

    def submit(self, shard, kinds, keys, values=None) -> List[int]:
        if not self.membership.is_routable(shard):
            raise ValueError(
                f"submit: shard {shard} is "
                f"{self.membership.state_of(shard)} at epoch "
                f"{self.membership.epoch} — route ops to one of "
                f"{self.membership.routable}")
        kinds, keys, values = materialize_ops(kinds, keys, values)
        ids = []
        for kind, key, val in zip(kinds, keys, values):
            slot = self._ids.alloc()
            self._queues[shard].append(make_op_row(shard, kind, key, val,
                                                   slot))
            ids.append(slot)
        return ids

    def submit_range(self, shard: int, lo: int, hi: int,
                     limit: int) -> int:
        """Enqueue one RANGE(lo, hi, limit) scan at ``shard`` (§16)."""
        if not self.cfg.range_scan:
            raise ValueError(
                "submit_range: cfg.range_scan is off — the scan pre-pass "
                "and MSG_RANGE handlers are compiled out of shard_round")
        if not self.membership.is_routable(shard):
            raise ValueError(
                f"submit_range: shard {shard} is "
                f"{self.membership.state_of(shard)} at epoch "
                f"{self.membership.epoch}")
        if lo < KEY_MIN or hi > KEY_MAX + 1 or limit < 1:
            raise ValueError(
                f"submit_range: span [{lo}, {hi}) limit={limit} outside "
                f"[{KEY_MIN}, {KEY_MAX + 1}) or non-positive limit")
        slot = self._ids.alloc()
        self._queues[shard].append(RS.make_range_row(shard, lo, hi,
                                                     limit, slot))
        self._range_ops.add(slot)
        self._range_parts[slot] = []
        # a recycled id must not inherit a prior scan's unfetched items
        self._range_items.pop(slot, None)
        return slot

    def take_range_items(self, op_id: int) -> List[Tuple[int, int]]:
        return self._range_items.pop(op_id)

    # ------------------------------------------------- membership (§13)
    def join_shard(self, shard: Optional[int] = None) -> int:
        """Admit a retired mesh slot as a JOINING member. The SPMD mesh
        stays at its jit-static capacity — the slot was stepping empty
        rounds all along, so no recompilation happens on join."""
        s = self.membership.begin_join(shard)
        self._broadcast_epoch()
        return s

    def retire_shard(self, shard: int) -> None:
        """Begin draining ``shard``; the host retires it (and resets its
        transport lanes, when routing is host-side) once drain completion
        is provable. The device keeps stepping the empty slot."""
        self.membership.begin_drain(shard)
        self._broadcast_epoch()

    def _broadcast_epoch(self) -> None:
        """Announce the membership view by injecting one MSG_EPOCH row
        into every capacity slot's client feed. The host feeds each
        device directly (the rows never cross the shard-to-shard wire),
        so a nemesis partition cannot block the announcement — shards
        behind a cut still act on a stale mask safely, exactly as in the
        Cluster backend, for the *data*-path messages."""
        mb = self.membership
        for dst in range(mb.capacity):
            self._queues[dst].append(
                epoch_row(dst, dst, mb.epoch, mb.mask()))

    def _drain_complete(self, s: int) -> bool:
        """Backend-specific half of the retire gate (see
        ``Cluster._drain_complete`` for the invariant): on the hostroute
        path the transport's per-lane idleness is exact; on the device
        path the on-device inbox is opaque, so the conservative witness
        is the routed-message total hitting zero."""
        bgs = self.bgs
        if owned_entry_count(self.cfg, self.states, s) != 0:
            return False
        if B.any_active(bgs[s]):
            return False
        if moves_targeting(bgs, s) != 0:
            return False
        if len(self._queues[s]):
            return False
        if self.net is not None:
            if self._net_backlog[s].shape[0]:
                return False
            if not self.net.shard_idle(s):
                return False
        elif self._inflight_msgs:
            return False
        return True

    def _membership_maintenance(self) -> None:
        """Host-driven lifecycle advance, once per round (same rules as
        ``Cluster._membership_maintenance`` — the differential harness
        holds the two backends to the same membership schedule)."""
        mb = self.membership
        if not (mb.joining or mb.draining):
            return
        changed = False
        for s in mb.joining:
            if owned_entry_count(self.cfg, self.states, s) > 0:
                mb.promote(s)
                changed = True
        for s in mb.draining:
            if self._drain_complete(s):
                mb.finish_drain(s)
                if self.net is not None:
                    self.net.reset_shard(s)
                changed = True
        if changed:
            self._broadcast_epoch()

    def _feed_client(self, down=()) -> np.ndarray:
        cfg = self.cfg
        client = np.zeros((self.n, cfg.batch_size, M.FIELDS), np.int32)
        for s in range(self.n):
            if s in down:
                continue        # queue is client-side memory: it survives
            q = self._queues[s]
            for b in range(min(len(q), cfg.batch_size)):
                client[s, b] = q.popleft()
        return client

    # ------------------------------------------------- crash-restart (§14)
    def _set_shard(self, s: int, state, bg) -> None:
        """Overwrite slot ``s`` of the stacked device state."""
        tree_map = self._jax.tree_util.tree_map
        jnp = self._jnp
        self._states = tree_map(
            lambda col, leaf: col.at[s].set(jnp.asarray(leaf)),
            self._states, state)
        self._bgs = tree_map(
            lambda col, leaf: col.at[s].set(jnp.asarray(leaf)),
            self._bgs, bg)
        self._host_states = None

    def _apply_crash_plans(self) -> None:
        """Same top-of-round ordering as ``Cluster._apply_crash_plans``:
        restarts before crashes, so both backends execute one schedule
        identically (the differential harness compares their traces)."""
        for c in self._crash_plans:
            if c.restart_round == self.round_no and c.shard in self.net.down:
                self._restart_shard(c.shard)
        for c in self._crash_plans:
            if c.crash_round == self.round_no:
                self._crash_shard(c.shard)

    def _crash_shard(self, s: int) -> None:
        from repro.core.types import init_shard
        self.membership.crash(s)
        if not self.membership.active:
            raise RuntimeError(
                f"crash of shard {s} leaves no active shard — the "
                f"coordinator for epoch broadcasts must survive")
        self._broadcast_epoch()
        self._set_shard(s, init_shard(self.cfg, s, peers_mask=0),
                        B.init_bg_table(self.cfg))
        self._net_backlog[s] = np.zeros((0, M.FIELDS), np.int32)
        self.net.crash_shard(s)

    def _restart_shard(self, s: int) -> None:
        rec = self.durability.recover(s, in_cap=self.in_cap)
        self._set_shard(s, rec.state, rec.bg)
        self._net_backlog[s] = rec.backlog
        self.net.restart_shard(s, rec.lanes)
        self.membership.restart(s)
        self._broadcast_epoch()
        self.durability.snapshot_now(
            s, self.round_no - 1, rec.state, rec.bg, rec.backlog,
            self.net.export_shard_lanes(s))

    def _check_overflow(self, out_counts) -> None:
        """Shared overflow discipline of both round paths (the same check
        ``Cluster.step`` applies): a count past ``mailbox_cap`` means rows
        were silently not stored — raise, never truncate."""
        over = max(out_counts)
        self.stats["max_outbox"] = max(self.stats["max_outbox"], over)
        if over > self.cfg.mailbox_cap:
            s = int(np.argmax(np.asarray(out_counts)))
            raise OutboxOverflow(
                f"shard {s} emitted {over} messages in round "
                f"{self.round_no}, mailbox_cap={self.cfg.mailbox_cap} — "
                f"raise mailbox_cap or reduce the per-round feed")

    def _harvest(self, cs, cv, cr, ck) -> List[Completion]:
        """Completions of one round as (op_id, result, src) with id
        recycling — shared by both round paths. ``ck`` is the comp_key
        lane: SH_KEY marks a scalar completion; a real key marks a RANGE
        item row (key, value) for the slot's scan (DESIGN.md §16)."""
        comps: List[Completion] = []
        cs, cv = np.asarray(cs), np.asarray(cv)
        cr, ck = np.asarray(cr), np.asarray(ck)
        done = cs >= 0
        for slot, val, src, key in zip(cs[done], cv[done], cr[done],
                                       ck[done]):
            slot, key = int(slot), int(key)
            if key != SH_KEY:
                self._range_parts.setdefault(slot, []).append(
                    (key, int(val)))
                continue
            if slot in self._range_ops:
                # terminal row: F_A is the total item count (negative =
                # error). Publication is count-gated below — items from
                # other serving shards may still be in flight.
                self._range_done[slot] = (int(val), int(src))
                continue
            comps.append((slot, int(val), int(src)))
            self._ids.release(slot)
        for slot, (total, src) in list(self._range_done.items()):
            if total >= 0 and len(self._range_parts.get(slot, ())) < total:
                continue
            self._range_items[slot] = sorted(
                self._range_parts.pop(slot, []))
            self._range_ops.discard(slot)
            del self._range_done[slot]
            comps.append((slot, total, src))
            self._ids.release(slot)
        return comps

    def _update_op_rates(self, ent_hits, rep_hits=None) -> None:
        """Per-entry op-rate EWMA, mirroring ``Cluster.step``'s update
        (same alpha/prune so the differential harness sees one model):
        decay every tracked entry, add this round's per-shard hits keyed
        by registry keymax, drop entries decayed to noise. ``rep_hits``
        (per-shard replica-served FIND counts, [S]) feeds the per-shard
        ``rep_rate_ewma`` the balancer folds into shard load — replica
        service is invisible to the registry-keyed rates (the entry lives
        on the primary), and an uncorrected model reads serving replicas
        as idle and churns moves against phantom imbalance."""
        hits = np.asarray(ent_hits)                       # [S, M]
        ent_rates: Dict[int, int] = {}
        if hits.any():
            kmax = np.asarray(self._states.registry.keymax)   # [S, M]
            for s, e in zip(*np.nonzero(hits)):
                k = int(kmax[s, e])
                if k != ST_KEY:
                    ent_rates[k] = ent_rates.get(k, 0) + int(hits[s, e])
        alpha = 0.3
        nxt: Dict[int, float] = {}
        for k, v in self.op_rate_ewma.items():
            d = v * (1.0 - alpha)
            if d > 1e-3:
                nxt[k] = d
        for k, h in ent_rates.items():
            nxt[k] = nxt.get(k, 0.0) + alpha * h
        self.op_rate_ewma = nxt
        nxt_rep: Dict[int, float] = {}
        for s, v in self.rep_rate_ewma.items():
            d = v * (1.0 - alpha)
            if d > 1e-3:
                nxt_rep[s] = d
        if rep_hits is not None:
            for s, h in enumerate(np.asarray(rep_hits)):
                if h:
                    nxt_rep[s] = nxt_rep.get(s, 0.0) + alpha * int(h)
        self.rep_rate_ewma = nxt_rep

    def _step_hostroute(self) -> List[Completion]:
        """One round on the nemesis path: device round (no all_to_all),
        host-side transport routing of the raw outboxes."""
        from repro.core.net import trace_entry
        cfg = self.cfg
        if self._crash_plans:
            self._apply_crash_plans()
        down = self.net.down
        client = self._feed_client(down)
        inbox = np.zeros((self.n, self.in_cap, M.FIELDS), np.int32)
        for s in range(self.n):
            feed = self._net_backlog[s][:self.in_cap]
            self._net_backlog[s] = self._net_backlog[s][self.in_cap:]
            inbox[s, :feed.shape[0]] = feed
        out = self._rnd(self._states, self._bgs,
                        self._jnp.asarray(inbox),
                        self._jnp.asarray(client))
        self._states, self._bgs, outbox, cs, cv, cr, ck, rstats, \
            ent_hits = out
        self._host_states = None
        rstats = np.asarray(rstats)
        out_counts = [int(c) for c in rstats[:, 0]]
        self._check_overflow(out_counts)
        self.stats["max_bg_active"] = max(self.stats["max_bg_active"],
                                          int(rstats[:, 1].max()))
        self.stats["move_hits"] += int(rstats[:, 2].sum())
        self.stats["fast_hits"] += int(rstats[:, 3].sum())
        self.stats["mut_hits"] += int(rstats[:, 4].sum())
        self.stats["blk_hits"] += int(rstats[:, 5].sum())
        self.stats["rep_hits"] += int(rstats[:, 6].sum())
        self.stats["range_hits"] += int(rstats[:, 7].sum())
        self._update_op_rates(ent_hits, rstats[:, 6])
        outbox = np.asarray(outbox)
        per_src = []
        for s in range(self.n):
            rows = outbox[s][:out_counts[s]]
            hops = rows[rows[:, M.F_KIND] == M.MSG_OP, M.F_X2]
            if hops.size:
                self.stats["max_hops"] = max(self.stats["max_hops"],
                                             int(hops.max()))
                self.stats["delegated"] += int(hops.size)
            per_src.append((s, rows))
        pre_lens = [b.shape[0] for b in self._net_backlog]
        self.net.route_round(self._net_backlog, per_src, self.round_no)
        comps = self._harvest(cs, cv, cr, ck)
        self._membership_maintenance()
        if self.durability is not None:
            # journal per live shard (same record layout as Cluster.step):
            # the client feed consumed, the routed appends, completions +
            # bg phases + epoch (replay audit), post-routing lane image.
            cs_h = np.asarray(cs)
            cv_h, cr_h = np.asarray(cv), np.asarray(cr)
            ck_h = np.asarray(ck)
            phases = np.asarray(self._bgs.phase)
            epochs = np.asarray(self._states.epoch)
            for s in range(self.n):
                if s in down:
                    continue
                done = cs_h[s] >= 0
                comp = np.stack([cs_h[s][done], cv_h[s][done],
                                 cr_h[s][done], ck_h[s][done]],
                                axis=1).astype(np.int32)
                lanes = self.net.export_shard_lanes(s)
                self.durability.log_round(
                    s, self.round_no,
                    appends=self._net_backlog[s][pre_lens[s]:],
                    client=client[s], comp=comp, bg_phases=phases[s],
                    epoch=int(epochs[s]), lanes=lanes)
                if (self.durability.config.snapshot_every > 0
                        and (self.round_no + 1)
                        % self.durability.config.snapshot_every == 0):
                    st = self._jax.tree_util.tree_map(
                        lambda x, s=s: np.asarray(x)[s], self._states)
                    bg = self._jax.tree_util.tree_map(
                        lambda x, s=s: np.asarray(x)[s], self._bgs)
                    self.durability.snapshot_now(
                        s, self.round_no, st, bg, self._net_backlog[s],
                        lanes)
        for ep, ev, sh in self.membership.log[self._mb_logged:]:
            self.round_trace.append(f"r{self.round_no} mb {ev} s{sh} e{ep}")
        self._mb_logged = len(self.membership.log)
        self.round_trace.append(trace_entry(
            self.round_no, comps, out_counts,
            extra=sum(b.shape[0] for b in self._net_backlog)
            + self.net.in_flight()))
        self.round_no += 1
        self.stats["rounds"] += 1
        return comps

    def step(self) -> List[Completion]:
        if self.net is not None:
            return self._step_hostroute()
        cfg = self.cfg
        client = self._feed_client()
        out = self._rnd(self._states, self._bgs, self._inbox,
                        self._jnp.asarray(client))
        self._states, self._bgs, self._inbox, cs, cv, cr, ck, rstats, \
            ent_hits = out
        self._host_states = None
        # per-shard int32[9] round stats computed on-device (the routed
        # inbox itself never crosses to host on the hot path; see
        # make_dili_round's docstring for the lane layout)
        rstats = np.asarray(rstats)
        self._check_overflow([int(c) for c in rstats[:, 0]])
        self._inflight_msgs = int(rstats[:, 1].sum())
        self.stats["max_bg_active"] = max(self.stats["max_bg_active"],
                                          int(rstats[:, 4].max()))
        self.stats["move_hits"] += int(rstats[:, 5].sum())
        self.stats["blk_hits"] += int(rstats[:, 6].sum())
        self.stats["rep_hits"] += int(rstats[:, 7].sum())
        self.stats["range_hits"] += int(rstats[:, 8].sum())
        self._update_op_rates(ent_hits, rstats[:, 7])
        delegated = int(rstats[:, 2].sum())
        if delegated:
            self.stats["delegated"] += delegated
            self.stats["max_hops"] = max(self.stats["max_hops"],
                                         int(rstats[:, 3].max()))
        comps = self._harvest(cs, cv, cr, ck)
        self._membership_maintenance()
        self.round_no += 1
        self.stats["rounds"] += 1
        return comps

    def quiescent(self) -> bool:
        if self.membership.crashed:
            return False        # keep stepping toward the scheduled restart
        if any(len(q) for q in self._queues):
            return False
        if self.net is not None:
            if any(b.shape[0] for b in self._net_backlog):
                return False
            if not self.net.idle():
                return False
        elif self._inflight_msgs:
            return False
        phases = np.asarray(self._bgs.phase)
        return bool((phases == B.BG_IDLE).all())

    def registry_entries(self, shard: int = 0) -> List[RegEntry]:
        return registry_entries(self.states[shard])

    # ------------------------------------------------------ balance surface
    @property
    def states(self):
        if self._host_states is None:
            tree_map = self._jax.tree_util.tree_map
            host = tree_map(np.asarray, self._states)
            self._host_states = [
                tree_map(lambda x, s=s: x[s], host) for s in range(self.n)]
        return self._host_states

    @property
    def bgs(self):
        tree_map = self._jax.tree_util.tree_map
        host = tree_map(np.asarray, self._bgs)
        return [tree_map(lambda x, s=s: x[s], host) for s in range(self.n)]

    def sublists(self, s: int):
        return state_sublists(self.cfg, self.states, s)

    def middle_item(self, s: int, head_idx: int) -> Optional[int]:
        items = chain_keys(self.cfg, self.states, s, head_idx,
                           include_meta=True)
        if len(items) < 2:
            return None
        return items[len(items) // 2][1]

    def _queue_bg(self, s: int, fn, cmd: int, *args) -> bool:
        tree_map = self._jax.tree_util.tree_map
        bg = tree_map(lambda x: x[s], self._bgs)
        bg, ok = fn(bg, *args)
        self._bgs = tree_map(lambda col, leaf: col.at[s].set(leaf),
                             self._bgs, bg)
        if self.durability is not None:
            # host-side BgTable mutation bypasses the inbox — journal it
            # so WAL replay re-queues the command (wal.py KIND_COMMAND)
            self.durability.log_command(s, self.round_no, cmd, args,
                                        bool(ok))
        return bool(ok)

    def split(self, s, entry_keymax, sitem_idx) -> bool:
        return self._queue_bg(s, B.queue_split, wal.CMD_SPLIT,
                              entry_keymax, sitem_idx)

    def move(self, s, entry_keymax, target) -> bool:
        return self._queue_bg(s, B.queue_move, wal.CMD_MOVE,
                              entry_keymax, target)

    def merge(self, s, left_keymax, right_keymax) -> bool:
        return self._queue_bg(s, B.queue_merge, wal.CMD_MERGE,
                              left_keymax, right_keymax)

    # -------------------------------------------------- replication (§15)
    def _queue_state(self, s: int, fn, cmd: int, *args) -> bool:
        """Like ``_queue_bg`` but for commands that edit ``ShardState``
        (the replication session table) instead of the BgTable."""
        tree_map = self._jax.tree_util.tree_map
        st = tree_map(lambda x: x[s], self._states)
        st, ok = fn(st, self.cfg, *args)
        self._states = tree_map(lambda col, leaf: col.at[s].set(leaf),
                                self._states, st)
        self._host_states = None
        ok = bool(np.asarray(ok))
        if self.durability is not None:
            self.durability.log_command(s, self.round_no, cmd, args, ok)
        return ok

    def replicate(self, s, entry_keymax, target) -> bool:
        if not self.cfg.replication:
            raise ValueError(
                "replicate: cfg.replication is off — replica serve and "
                "publication are compiled out of shard_round")
        ok = self._queue_state(s, R.queue_replicate_jit, wal.CMD_REPLICATE,
                               entry_keymax, target)
        if ok:
            prim, tg = self._replica_map.get(entry_keymax, (s, set()))
            tg = set(tg) | {int(target)}
            self._replica_map[int(entry_keymax)] = (s, tg)
            self.replica_epoch += 1
        return ok

    def drop_replica(self, s, entry_keymax, target=-1) -> bool:
        if not self.cfg.replication:
            raise ValueError("drop_replica: cfg.replication is off")
        ok = self._queue_state(s, R.queue_drop_replica_jit,
                               wal.CMD_DROP_REPLICA, entry_keymax, target)
        if entry_keymax in self._replica_map:
            prim, tg = self._replica_map[entry_keymax]
            tg = set() if target < 0 else set(tg) - {int(target)}
            if tg:
                self._replica_map[entry_keymax] = (prim, tg)
            else:
                del self._replica_map[entry_keymax]
            self.replica_epoch += 1
        return ok

    def replica_sets(self):
        """Same contract as ``Cluster.replica_sets`` (the two backends
        must expose one routing view to the client API)."""
        out = {}
        stale = []
        states = self.states
        for kmax, (prim, tg) in self._replica_map.items():
            reg = states[prim].registry
            size = int(np.asarray(reg.size))
            kmaxes = np.asarray(reg.keymax)[:size]
            at = np.nonzero(kmaxes == kmax)[0]
            owned = False
            if at.size:
                sh = int(np.asarray(reg.subhead)[at[0]])
                owned = ((sh & refs.SID_MASK) >> refs.IDX_BITS) == prim
            if not owned:
                stale.append(kmax)
                continue
            kmin = int(np.asarray(reg.keymin)[at[0]])
            out[int(kmax)] = (kmin, int(prim), sorted(tg))
        for kmax in stale:
            del self._replica_map[kmax]
            self.replica_epoch += 1
        return out

    # ------------------------------------------------------------ debugging
    def all_keys(self) -> List[int]:
        return global_keys(self.cfg, self.states)

    def shard_chain(self, s, head_idx, include_meta=False):
        return chain_keys(self.cfg, self.states, s, head_idx, include_meta)
