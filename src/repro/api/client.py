"""``DiLiClient`` — the public client API of the DiLi runtime (DESIGN.md §9).

The paper's clients are first-class participants: they cache registry
entries, learn corrected routes from wrong-shard replies, and keep
operating while sublists split and move underneath them. This client
reproduces that contract over any ``Backend``:

  * **Routing.** A client-side registry cache (seeded from a server
    replica at construction) predicts each key's owner, so ops are
    submitted where they will execute instead of a fixed shard. Stale
    routes are *safe* — servers delegate mis-routed ops (Theorem 4 bounds
    the hops) — they only cost hops, and every completion reports the
    shard that executed the op, so a mismatch triggers a cache refresh.
  * **Pacing.** Admission is bounded against ``mailbox_cap`` so overload
    queues client-side instead of surfacing ``OutboxOverflow`` from the
    round engine: every in-flight op occupies at most one message per
    round, so capping in-flight ops leaves outbox headroom for move
    replicates and registry broadcasts.
  * **Ordering.** At most one *mutation* per key is in flight at a time,
    and a mutation waits for every in-flight op on its key; FINDs on the
    same key may fly concurrently (reads commute when no write separates
    them, and any separating write still queued keeps later same-key ops
    behind it via the skip set). Same-key ops are admitted in submission
    order — exactly the per-key discipline linearizability needs, relaxed
    only where commutativity makes the relaxation unobservable. Without
    the relaxation a Zipfian read-mostly workload would serialize its hot
    keys one FIND per round, which is the workload replication exists to
    spread (DESIGN.md §15).
  * **Replica routing.** When replication is on, the client learns replica
    sets from the backend (``replica_sets()``, re-pulled whenever
    ``replica_epoch`` moves) and spreads FINDs round-robin over
    [primary] + replicas; mutations always go to the primary. A stale or
    expired replica is safe: the serving gate on the replica shard simply
    does not fire and the op delegates home like any mis-routed op.
  * **Balancing.** ``pump()`` periodically runs a pluggable balance policy
    (``core.balancer.Balancer`` is the paper's §7.1 policy) over the
    backend's balance surface.
"""
from __future__ import annotations

from bisect import bisect_left
from collections import deque
from itertools import islice
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.core.types import OP_FIND, OP_INSERT, OP_REMOVE

from .backend import Backend, LocalBackend
from .futures import BatchResult, OpFuture, RangeResult


class RegistryCache:
    """Client-side replica of the registry: sorted (keymin, keymax, owner).

    Same semantics as ``core.registry.get_by_key``: an entry covers keys
    strictly greater than its keymin and up to (inclusive) its keymax.
    """

    def __init__(self, entries: Sequence[Tuple[int, int, int]] = ()):
        self._mins: List[int] = []
        self._maxs: List[int] = []
        self._owners: List[int] = []
        self.load(entries)

    def load(self, entries: Sequence[Tuple[int, int, int]]) -> None:
        ordered = sorted(entries)
        self._mins = [e[0] for e in ordered]
        self._maxs = [e[1] for e in ordered]
        self._owners = [e[2] for e in ordered]

    def lookup(self, key: int) -> Optional[int]:
        i = bisect_left(self._mins, key) - 1
        if i < 0:
            return None
        if self._mins[i] < key <= self._maxs[i]:
            return self._owners[i]
        return None

    def __len__(self) -> int:
        return len(self._mins)


class DiLiClient:
    """Futures-based client over a DiLi execution backend.

    ``route_cache=False`` degrades to fixed-shard submission (every op goes
    to ``home_shard``) — the pre-redesign behaviour, kept for comparison
    benchmarks and tests.
    """

    def __init__(self, backend: Backend, *, route_cache: bool = True,
                 balance=None, balance_every: int = 4,
                 home_shard: int = 0,
                 max_inflight: Optional[int] = None):
        self.backend = backend
        self.cfg = backend.cfg
        self.route_cache = route_cache
        self.balance = balance          # any object with .step() -> dict
        self.balance_every = max(1, int(balance_every))
        self.home_shard = int(home_shard)
        # Pacing budget (see _auto_inflight). A caller-pinned budget is
        # never recomputed; the automatic one follows the membership epoch
        # (DESIGN.md §13) — the fan-out reserve tracks the *live* shard
        # count, not the construction-time capacity.
        self._pinned_inflight = max_inflight is not None
        mb = getattr(backend, "membership", None)
        self._seen_epoch = mb.epoch if mb is not None else 0
        if mb is not None and not mb.is_routable(self.home_shard):
            self.home_shard = min(mb.active)
        self.max_inflight = int(max_inflight if self._pinned_inflight
                                else self._auto_inflight())
        self._queue: deque = deque()                 # unadmitted OpFutures
        self._inflight: Dict[int, OpFuture] = {}     # op_id -> future
        self._busy_mut: Set[int] = set()             # keys with mutation out
        self._find_out: Dict[int, int] = {}          # key -> in-flight FINDs
        self._scan_spans: Dict[int, Tuple[int, int]] = {}  # op_id -> [lo,hi)
        self._cache = RegistryCache(backend.registry_entries(self.home_shard))
        self._refresh_from: Optional[int] = None     # pending cache refresh
        self._rounds = 0
        self.wrong_routes = 0                        # completions off-route
        # replica routing (§15): {keymax: (keymin, primary, [replicas])}
        # plus the sorted keymax index for range lookup; re-pulled whenever
        # the backend's replica_epoch moves.
        self._replica_sets: Dict[int, Tuple[int, int, List[int]]] = {}
        self._replica_maxs: List[int] = []
        self._seen_replica_epoch = getattr(backend, "replica_epoch", 0)
        self._rr = 0                                 # read spread counter

    def _auto_inflight(self) -> int:
        """Pacing budget: each in-flight op contributes at most one outbox
        row per shard per round (its delegation XOR its result), plus one
        replicate while its sublist moves. Reserve headroom for the
        background slots (each can have ``move_batch`` MoveItems plus
        their acks in fabric per round, and a registry broadcast) and one
        broadcast row per *live* shard — the fan-out a registry update or
        epoch announcement can add to a single outbox. The reserve assumes
        ≤ bg_slots concurrent migrations touch any one shard (the §7.1
        balancer's behaviour); policies aiming more moves at a single
        target need a larger mailbox_cap or an explicit max_inflight
        (DESIGN.md §9).

        The budget stays a *global* cap equal to one shard's headroom (it
        does not scale with the live shard count): after a partition heals
        the transport can concentrate a multi-round backlog of delegated
        ops at one executor in one round, and a budget any wider than one
        shard's headroom turns that burst into OutboxOverflow.
        """
        mb = getattr(self.backend, "membership", None)
        n_live = (len(mb.routable) if mb is not None
                  else self.cfg.num_shards)
        bg_budget = self.cfg.bg_slots * (2 * self.cfg.move_batch + 2)
        if getattr(self.cfg, "replication", False):
            # publication reserve (§15): each replication session can put
            # ``replica_batch`` delta rows + an INSTALL/DROP on the wire
            # in one round
            bg_budget += self.cfg.replica_sessions * (
                self.cfg.replica_batch + 2)
        budget = max(1, self.cfg.mailbox_cap - bg_budget - n_live - 4)
        if getattr(self.backend, "net", None) is not None:
            # Lossy-wire headroom (DESIGN.md §11): the transport can
            # release a multi-round backlog of frames in one round
            # (retransmit bursts after a partition heals, delayed frames
            # coming due together), concentrating handler replies that a
            # clean run spreads out — so in-flight ops claim only half
            # the budget, leaving the rest for retransmit-burst fan-out.
            budget = max(1, budget // 2)
        return budget

    # ------------------------------------------------------------ submission
    def find(self, key: int) -> OpFuture:
        return self._enqueue(OP_FIND, key)

    def insert(self, key: int, value: int = 0) -> OpFuture:
        return self._enqueue(OP_INSERT, key, value)

    def remove(self, key: int) -> OpFuture:
        return self._enqueue(OP_REMOVE, key)

    def range(self, lo: int, hi: int, limit: int = 4096) -> RangeResult:
        """RANGE(lo, hi, limit): the sorted (key, value) pairs in
        ``[lo, hi)``, at most ``limit`` of them (DESIGN.md §16).

        Always aimed at the *primary* predicted to own ``lo`` — scans
        never ride read replicas (a replica's bounded staleness is fine
        for a single FIND but would tear a multi-key snapshot). Ordering:
        a scan waits for every in-flight mutation inside its span, and
        later mutations into the span hold until the scan resolves — the
        per-key discipline lifted to key *ranges*.
        """
        if not getattr(self.cfg, "range_scan", False):
            raise ValueError(
                "range: cfg.range_scan is off — the scan pre-pass and "
                "MSG_RANGE handlers are compiled out of shard_round")
        if limit < 1:
            raise ValueError(f"range: limit={limit} must be >= 1")
        fut = RangeResult(self, lo, hi, limit)
        self._queue.append(fut)
        return fut

    def find_batch(self, keys: Sequence[int]) -> BatchResult:
        return BatchResult([self.find(k) for k in keys])

    def insert_batch(self, keys: Sequence[int],
                     values: Optional[Sequence[int]] = None) -> BatchResult:
        values = [0] * len(keys) if values is None else list(values)
        if len(values) != len(keys):
            raise ValueError(f"{len(values)} values vs {len(keys)} keys")
        return BatchResult([self.insert(k, v)
                            for k, v in zip(keys, values)])

    def remove_batch(self, keys: Sequence[int]) -> BatchResult:
        return BatchResult([self.remove(k) for k in keys])

    def submit(self, kinds: Sequence[int], keys: Sequence[int],
               values: Optional[Sequence[int]] = None) -> BatchResult:
        """Mixed batch, one future per (kind, key) in submission order."""
        kinds, keys = list(kinds), list(keys)
        if len(kinds) != len(keys):
            raise ValueError(f"{len(kinds)} kinds vs {len(keys)} keys")
        values = [0] * len(keys) if values is None else list(values)
        if len(values) != len(keys):
            raise ValueError(f"{len(values)} values vs {len(keys)} keys")
        return BatchResult([self._enqueue(k, x, v)
                            for k, x, v in zip(kinds, keys, values)])

    def _enqueue(self, kind: int, key: int, value: int = 0) -> OpFuture:
        fut = OpFuture(self, kind, key, value)
        self._queue.append(fut)
        return fut

    # ---------------------------------------------------------- driver loop
    @property
    def pending(self) -> int:
        """Ops submitted but not yet resolved."""
        return len(self._queue) + len(self._inflight)

    def pump(self, run_balance: bool = True) -> int:
        """One round: refresh-route, admit, execute, harvest. Returns the
        number of futures resolved this round."""
        mb = getattr(self.backend, "membership", None)
        if mb is not None and mb.epoch != self._seen_epoch:
            # membership changed (DESIGN.md §13): re-aim the home shard if
            # it left, recompute the pacing budget against the new live
            # count (unless the caller pinned it), and refresh the route
            # cache so draining shards stop receiving fresh ops promptly
            # (stale routes would still be *safe* — just slower to heal).
            self._seen_epoch = mb.epoch
            if not mb.is_routable(self.home_shard):
                self.home_shard = min(mb.active)
            if not self._pinned_inflight:
                self.max_inflight = self._auto_inflight()
            if self.route_cache:
                self._refresh_from = self.home_shard
        if self._refresh_from is not None and self.route_cache:
            self.refresh_route_cache(self._refresh_from)
        rep_epoch = getattr(self.backend, "replica_epoch", 0)
        if rep_epoch != self._seen_replica_epoch:
            self._seen_replica_epoch = rep_epoch
            self._replica_sets = dict(self.backend.replica_sets())
            self._replica_maxs = sorted(self._replica_sets)
        self._admit()
        ndone = 0
        for op_id, val, src in self.backend.step():
            fut = self._inflight.pop(op_id, None)
            if fut is None:
                # backends only report ops issued through them, and a
                # backend supports one driving client — unreachable unless
                # two clients share a backend (unsupported)
                continue
            if isinstance(fut, RangeResult):
                # the completion value is the item count (or error code);
                # the pairs are fetched once from the backend. The src
                # shard is whichever served the *last* segment — not a
                # routing signal, so no wrong-route refresh for scans.
                fut._resolve(val, src, self.backend.take_range_items(op_id))
                fut.op_id = None
                self._scan_spans.pop(op_id, None)
                ndone += 1
                continue
            fut._resolve(val, src)
            fut.op_id = None
            if fut.kind == OP_FIND:
                left = self._find_out.get(fut.key, 1) - 1
                if left > 0:
                    self._find_out[fut.key] = left
                else:
                    self._find_out.pop(fut.key, None)
            else:
                self._busy_mut.discard(fut.key)
            ndone += 1
            if src != fut.shard and not getattr(fut, "via_replica", False):
                # wrong-route reply: the executing shard's replica covers
                # this key freshest — refresh from it next pump. FINDs
                # deliberately aimed at read replicas (or bounced home by
                # an expired one) are not routing errors and don't
                # trigger refresh churn.
                self.wrong_routes += 1
                self._refresh_from = src
        self._rounds += 1
        if (run_balance and self.balance is not None
                and self._rounds % self.balance_every == 0):
            self.balance.step()
        return ndone

    def drain(self, max_rounds: int = 2000, *,
              run_balance: bool = False) -> None:
        """Pump until every future is resolved and the backend is quiet."""
        for _ in range(max_rounds):
            self.pump(run_balance=run_balance)
            if self.pending == 0 and self.backend.quiescent():
                return
        raise RuntimeError(
            f"client did not drain in {max_rounds} rounds: "
            f"queued={len(self._queue)} inflight={len(self._inflight)} "
            f"backend_quiet={self.backend.quiescent()}")

    def settle(self, max_passes: int = 200, max_rounds: int = 2000) -> None:
        """Drain, then run the balance policy to a fixed point (no commands
        issued), draining after each pass."""
        self.drain(max_rounds)
        if self.balance is None:
            return
        for _ in range(max_passes):
            if not any(self.balance.step().values()):
                return
            self.drain(max_rounds)
        raise RuntimeError(f"balance did not settle in {max_passes} passes")

    # -------------------------------------------------------------- routing
    def route(self, key: int) -> int:
        """Predicted owner shard for ``key`` (home shard when uncached or
        when the cached owner is no longer a routable member)."""
        if self.route_cache:
            owner = self._cache.lookup(key)
            if owner is not None and 0 <= owner < self.backend.n:
                mb = getattr(self.backend, "membership", None)
                if mb is None or mb.is_routable(owner):
                    return owner
        return self.home_shard

    def route_find(self, key: int) -> Tuple[int, bool]:
        """Route for a FIND: ``(shard, via_replica)``. When ``key`` falls
        in a replicated range, reads spread round-robin over the primary
        and its replicas; everything else (and all mutations) uses
        ``route``."""
        if self._replica_maxs:
            i = bisect_left(self._replica_maxs, key)
            if i < len(self._replica_maxs):
                kmax = self._replica_maxs[i]
                kmin, prim, reps = self._replica_sets[kmax]
                if kmin < key <= kmax and reps:
                    mb = getattr(self.backend, "membership", None)
                    choices = [prim] + [r for r in reps
                                        if mb is None or mb.is_routable(r)]
                    pick = choices[self._rr % len(choices)]
                    self._rr += 1
                    return pick, pick != prim
        return self.route(key), False

    def refresh_route_cache(self, shard: Optional[int] = None) -> None:
        """Re-seed the route cache from a server's registry replica."""
        src = self.home_shard if shard is None else int(shard)
        self._cache.load(self.backend.registry_entries(src))
        self._refresh_from = None

    def _admit(self) -> None:
        """Admit queued ops up to the pacing budget, preserving per-key
        submission order (a key with an earlier op deferred this pass
        keeps its later ops queued). Mutations wait for *every* in-flight
        op on their key; FINDs only wait for in-flight mutations — any
        number of same-key FINDs may fly at once (see module docstring)."""
        if not self._queue:
            return
        budget = self.max_inflight - len(self._inflight)
        per_round = self.cfg.batch_size      # backend feed bound per shard
        # a RANGE occupies one feed row but its serving shard may emit up
        # to range_batch items + a forward/terminal in one round — charge
        # it that many budget units so scans cannot overrun the outbox
        # headroom the pacing model reserves (see _auto_inflight)
        scan_cost = getattr(self.cfg, "range_batch", 32) + 2
        admit: Dict[int, List[OpFuture]] = {}
        scans: Dict[int, List[RangeResult]] = {}
        kept: deque = deque()
        skip: Set[int] = set()
        skip_spans: List[Tuple[int, int]] = []   # deferred scans' spans
        inflight_spans = list(self._scan_spans.values())
        for qi, fut in enumerate(self._queue):
            if budget <= 0:
                # budget spent: everything left stays queued in order —
                # stop scanning (a deep overload queue would otherwise make
                # each pump O(queue) for nothing)
                kept.extend(islice(self._queue, qi, None))
                break
            if isinstance(fut, RangeResult):
                lo, hi = fut.lo, fut.hi
                # a scan waits for in-flight mutations in its span and
                # for earlier-deferred ops on keys inside it (submission
                # order); concurrent FINDs and scans commute with it
                blocked = (any(lo <= k < hi for k in self._busy_mut)
                           or any(lo <= k < hi for k in skip))
                if blocked or budget < scan_cost:
                    kept.append(fut)
                    skip_spans.append((lo, hi))
                    continue
                shard = self.route(lo)          # primary-pinned (§16)
                lane = scans.setdefault(shard, [])
                if (len(lane) + len(admit.get(shard, ()))) >= per_round:
                    kept.append(fut)
                    skip_spans.append((lo, hi))
                    continue
                fut.shard = shard
                lane.append(fut)
                inflight_spans.append((lo, hi))
                budget -= scan_cost
                continue
            key = fut.key
            is_find = fut.kind == OP_FIND
            blocked = (key in self._busy_mut or key in skip
                       or (not is_find and self._find_out.get(key, 0)))
            if not is_find and not blocked:
                # mutations hold while any scan (in flight or deferred
                # ahead of us) covers their key — the span-level ordering
                # that makes a scan a consistent cut (DESIGN.md §16)
                blocked = any(lo <= key < hi
                              for lo, hi in inflight_spans) \
                    or any(lo <= key < hi for lo, hi in skip_spans)
            if blocked:
                kept.append(fut)
                skip.add(key)
                continue
            if is_find:
                shard, via_rep = self.route_find(key)
            else:
                shard, via_rep = self.route(key), False
            lane = admit.setdefault(shard, [])
            if (len(lane) + len(scans.get(shard, ()))) >= per_round:
                kept.append(fut)
                skip.add(key)
                continue
            fut.shard = shard
            fut.via_replica = via_rep
            lane.append(fut)
            if is_find:
                self._find_out[key] = self._find_out.get(key, 0) + 1
            else:
                self._busy_mut.add(key)
            budget -= 1
        self._queue = kept
        for shard, futs in admit.items():
            ids = self.backend.submit(
                shard, [f.kind for f in futs], [f.key for f in futs],
                [f.value for f in futs])
            for f, op_id in zip(futs, ids):
                f.op_id = op_id
                self._inflight[op_id] = f
        for shard, rfuts in scans.items():
            for f in rfuts:
                op_id = self.backend.submit_range(shard, f.lo, f.hi,
                                                  f.limit)
                f.op_id = op_id
                self._inflight[op_id] = f
                self._scan_spans[op_id] = (f.lo, f.hi)

    # ------------------------------------------------------------ inspection
    @property
    def stats(self) -> Dict[str, int]:
        return self.backend.stats

    def all_keys(self) -> List[int]:
        return self.backend.all_keys()


def local_client(cfg, **kw) -> DiLiClient:
    """Convenience: a ``DiLiClient`` over a fresh ``LocalBackend``."""
    backend_kw = {k: kw.pop(k) for k in
                  ("seed", "delay_prob", "nemesis", "retransmit_after",
                   "net_window", "key_lo", "key_hi", "initial_shards",
                   "trace") if k in kw}
    return DiLiClient(LocalBackend(cfg, **backend_kw), **kw)
