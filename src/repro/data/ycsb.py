"""YCSB-style zipfian op-stream generator (paper §7.2).

Workloads: a load phase of N inserts, then a mixed phase with the paper's
read proportions (10% / 50% / 90%), writes split evenly between inserts and
removes, keys drawn zipfian — matching the evaluation protocol of the paper.

``zipf_keys`` is the *bounded* YCSB Zipfian(θ) generator (Gray et al.,
"Quickly generating billion-record synthetic databases"): rank ``i`` of
``n`` has probability ``(1/i^θ) / ζ_n(θ)``, drawn by the closed-form
inverse-CDF approximation every YCSB port uses. This is NOT numpy's
``rng.zipf`` — that one samples an *unbounded* power law with exponent
``a > 1`` whose tail mass depends on ``a`` alone; rejection-sampling it
into ``[1, n]`` both mis-maps θ (YCSB θ→1 means *more* skew, while
exponent→1 under rejection flattens toward the truncation) and distorts
the head/tail ratio the benchmark is calibrated against.
"""
from __future__ import annotations

from typing import Iterator, Tuple

import numpy as np

from repro.core.types import OP_FIND, OP_INSERT, OP_REMOVE

FNV_OFFSET = np.uint64(0xCBF29CE484222325)
FNV_PRIME = np.uint64(0x100000001B3)


def _zeta(n: int, theta: float) -> float:
    return float(np.sum(1.0 / np.arange(1, n + 1) ** theta))


def zipf_keys(rng: np.random.Generator, n: int, key_space: int,
              theta: float = 0.99, scrambled: bool = False) -> np.ndarray:
    """``n`` draws of the bounded YCSB Zipfian(θ) over ``[1, key_space]``.

    θ ∈ [0, 1): 0 is uniform, →1 is maximally skewed; rank 1 is the
    hottest key. ``scrambled=True`` applies YCSB's ScrambledZipfian
    variant — ranks are FNV-hashed over the key space, so the hot keys
    scatter instead of forming a contiguous prefix (a hot *sublist* vs
    hot *keys* distinction that matters to range-partitioned stores).
    """
    if not 0.0 <= theta < 1.0:
        raise ValueError(f"YCSB theta must be in [0, 1), got {theta}")
    if theta == 0.0:
        ranks = rng.integers(1, key_space + 1, size=n)
    else:
        zetan = _zeta(key_space, theta)
        zeta2 = _zeta(2, theta)
        alpha = 1.0 / (1.0 - theta)
        eta = ((1.0 - (2.0 / key_space) ** (1.0 - theta))
               / (1.0 - zeta2 / zetan))
        u = rng.random(n)
        uz = u * zetan
        ranks = (1 + (key_space * (eta * u - eta + 1.0) ** alpha)).astype(
            np.int64)
        ranks = np.where(uz < 1.0, 1, ranks)
        ranks = np.where((uz >= 1.0) & (uz < 1.0 + 0.5 ** theta), 2, ranks)
        ranks = np.clip(ranks, 1, key_space)
    if scrambled:
        h = (FNV_OFFSET ^ ranks.astype(np.uint64)) * FNV_PRIME
        h ^= h >> np.uint64(27)
        h *= FNV_PRIME
        ranks = 1 + (h % np.uint64(key_space)).astype(np.int64)
    return ranks.astype(np.int32)


def load_phase(n_keys: int, key_space: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    keys = rng.permutation(key_space)[:n_keys] + 1
    kinds = np.full(n_keys, OP_INSERT, np.int32)
    return kinds, keys.astype(np.int32)


def mixed_phase(n_ops: int, key_space: int, read_frac: float,
                seed: int = 0, theta: float = 0.99,
                scrambled: bool = False):
    rng = np.random.default_rng(seed + 1)
    keys = zipf_keys(rng, n_ops, key_space, theta=theta,
                     scrambled=scrambled)
    r = rng.random(n_ops)
    w = (1.0 - read_frac) / 2.0
    kinds = np.where(r < read_frac, OP_FIND,
                     np.where(r < read_frac + w, OP_INSERT,
                              OP_REMOVE)).astype(np.int32)
    return kinds, keys
