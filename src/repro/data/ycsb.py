"""YCSB-style zipfian op-stream generator (paper §7.2).

Workloads: a load phase of N inserts, then a mixed phase with the paper's
read proportions (10% / 50% / 90%), writes split evenly between inserts and
removes, keys drawn zipfian — matching the evaluation protocol of the paper.
"""
from __future__ import annotations

from typing import Iterator, Tuple

import numpy as np

from repro.core.types import OP_FIND, OP_INSERT, OP_REMOVE


def zipf_keys(rng: np.random.Generator, n: int, key_space: int,
              theta: float = 0.99) -> np.ndarray:
    """Zipfian over [1, key_space] via the standard YCSB skew parameter."""
    # numpy's zipf is unbounded; rejection-sample into the key space
    out = np.empty(n, np.int64)
    filled = 0
    while filled < n:
        cand = rng.zipf(1.0 + (1.0 - theta) + 1e-3, size=2 * (n - filled))
        cand = cand[cand <= key_space]
        take = min(cand.size, n - filled)
        out[filled:filled + take] = cand[:take]
        filled += take
    return out.astype(np.int32)


def load_phase(n_keys: int, key_space: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    keys = rng.permutation(key_space)[:n_keys] + 1
    kinds = np.full(n_keys, OP_INSERT, np.int32)
    return kinds, keys.astype(np.int32)


def mixed_phase(n_ops: int, key_space: int, read_frac: float,
                seed: int = 0):
    rng = np.random.default_rng(seed + 1)
    keys = zipf_keys(rng, n_ops, key_space)
    r = rng.random(n_ops)
    w = (1.0 - read_frac) / 2.0
    kinds = np.where(r < read_frac, OP_FIND,
                     np.where(r < read_frac + w, OP_INSERT,
                              OP_REMOVE)).astype(np.int32)
    return kinds, keys
