from . import synthetic, ycsb  # noqa: F401
