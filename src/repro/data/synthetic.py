"""Deterministic sharded synthetic LM data pipeline.

Design goals (the parts of a production pipeline that matter for fault
tolerance): (1) content is a pure function of (seed, step, shard) — restart
at step N reproduces the same stream with no data loss or duplication
(checkpoint stores only the step counter); (2) shards are disjoint across
data-parallel ranks; (3) batches can be materialized host-side (numpy) for
the input pipeline or device-side (jnp) for fully-jitted benchmarks.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ArchConfig, ShapeCell


def _tokens(seed: int, step: int, shard: int, shape, vocab: int):
    rng = np.random.default_rng(
        np.random.SeedSequence([seed, step, shard, 0xD171]))
    return rng.integers(0, vocab, size=shape, dtype=np.int32)


def make_train_batch(cfg: ArchConfig, cell: ShapeCell, *, seed: int = 0,
                     step: int = 0, shard: int = 0, num_shards: int = 1,
                     dtype=jnp.bfloat16) -> Dict[str, Any]:
    """One data-parallel shard's batch for a training step."""
    assert cell.global_batch % num_shards == 0
    b = cell.global_batch // num_shards
    s = cell.seq_len
    base = _tokens(seed, step, shard, (b, s + 1), cfg.vocab)
    tokens, targets = base[:, :-1], base[:, 1:]
    if cfg.modality == "audio_stub":
        rng = np.random.default_rng(
            np.random.SeedSequence([seed, step, shard, 1]))
        emb = rng.standard_normal((b, s, cfg.d_model)).astype(np.float32)
        return {"frame_embeds": jnp.asarray(emb, dtype),
                "targets": jnp.asarray(targets)}
    if cfg.modality == "vision_stub":
        li = min(s // 2, 2048)           # anyres patch budget
        lt = s - li
        rng = np.random.default_rng(
            np.random.SeedSequence([seed, step, shard, 2]))
        patches = rng.standard_normal((b, li, cfg.d_model)).astype(np.float32)
        return {"patch_embeds": jnp.asarray(patches, dtype),
                "tokens": jnp.asarray(tokens[:, :lt]),
                "targets": jnp.asarray(targets)}
    return {"tokens": jnp.asarray(tokens), "targets": jnp.asarray(targets)}


def make_serve_batch(cfg: ArchConfig, cell: ShapeCell, *, decode: bool,
                     seed: int = 0, shard: int = 0, num_shards: int = 1,
                     dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Request batch for prefill (full prompt) or decode (one token)."""
    assert cell.global_batch % num_shards == 0
    b = cell.global_batch // num_shards
    t = 1 if decode else cell.seq_len
    tokens = _tokens(seed, 0, shard, (b, t), cfg.vocab)
    if cfg.modality == "audio_stub":
        rng = np.random.default_rng(np.random.SeedSequence([seed, shard, 3]))
        emb = rng.standard_normal((b, t, cfg.d_model)).astype(np.float32)
        return {"frame_embeds": jnp.asarray(emb, dtype)}
    if cfg.modality == "vision_stub" and not decode:
        li = min(t // 2, 2048)
        rng = np.random.default_rng(np.random.SeedSequence([seed, shard, 4]))
        patches = rng.standard_normal((b, li, cfg.d_model)).astype(np.float32)
        return {"patch_embeds": jnp.asarray(patches, dtype),
                "tokens": jnp.asarray(tokens[:, :t - li])}
    return {"tokens": jnp.asarray(tokens)}
