"""MusicGen-medium [arXiv:2306.05284; hf] — decoder-only over EnCodec
tokens; the EnCodec frontend is a stub (input_specs provides precomputed
frame embeddings). MHA (kv == heads)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,
    d_ff=6144, vocab=2048, head_dim=64, qkv_bias=False,
    modality="audio_stub", rope_theta=1e4,
)

def smoke():
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                          d_ff=128, vocab=64, head_dim=16,
                          attn_q_chunk=32, loss_chunk=64)
