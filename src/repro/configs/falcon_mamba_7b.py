"""Falcon-Mamba-7B [arXiv:2410.05355; unverified] — pure Mamba1, attn-free.
The long_500k cell runs here (O(1) state, sub-quadratic by construction)."""
from repro.models.config import ArchConfig, SSMCfg

CONFIG = ArchConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab=65024, head_dim=64, qkv_bias=False,
    ssm=SSMCfg(version=1, state=16, expand=2, conv_width=4),
)

def smoke():
    return CONFIG.replace(n_layers=2, d_model=64, vocab=256,
                          ssm=SSMCfg(version=1, state=4, expand=2,
                                     conv_width=4),
                          loss_chunk=64, ssm_chunk=16)
