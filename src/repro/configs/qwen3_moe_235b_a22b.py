"""Qwen3-MoE-235B-A22B [hf:Qwen/Qwen3-235B-A22B; hf] — 128 experts, top-8,
GQA kv=4. d_ff below is the per-expert intermediate width."""
from repro.models.config import ArchConfig, MoECfg

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4,
    d_ff=1536, vocab=151936, head_dim=128, qkv_bias=False,
    moe=MoECfg(n_experts=128, top_k=8, d_ff_expert=1536),
    rope_theta=1e6,
)

def smoke():
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=32, vocab=256, head_dim=16,
                          moe=MoECfg(n_experts=8, top_k=2, d_ff_expert=32),
                          attn_q_chunk=32, loss_chunk=64)
