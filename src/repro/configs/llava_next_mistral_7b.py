"""LLaVA-NeXT (Mistral-7B backbone) [hf:llava-hf/llava-v1.6-mistral-7b-hf;
unverified] — the anyres vision frontend is a stub: input_specs provides
precomputed patch embeddings concatenated before the text tokens."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=32000, head_dim=128, qkv_bias=False,
    modality="vision_stub", rope_theta=1e6,
)

def smoke():
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=128, vocab=256, head_dim=16,
                          attn_q_chunk=32, loss_chunk=64)
