"""Qwen2.5-3B [hf:Qwen/Qwen2.5-3B; hf] — dense GQA, QKV bias."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-3b", family="dense",
    n_layers=36, d_model=2048, n_heads=16, n_kv_heads=2,
    d_ff=11008, vocab=151936, head_dim=128, qkv_bias=True,
    tie_embeddings=True, rope_theta=1e6,
)

def smoke():
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=128, vocab=256, head_dim=16,
                          attn_q_chunk=32, loss_chunk=64)
