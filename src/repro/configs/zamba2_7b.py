"""Zamba2-7B [arXiv:2411.15242; unverified] — Mamba2 backbone with a shared
attention(+MLP) block applied every ``hybrid_period`` layers."""
from repro.models.config import ArchConfig, SSMCfg

CONFIG = ArchConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab=32000, head_dim=112, qkv_bias=False,
    ssm=SSMCfg(version=2, state=64, expand=2, conv_width=4, head_dim=64),
    hybrid_period=6, rope_theta=1e4,
)

def smoke():
    return CONFIG.replace(n_layers=5, d_model=64, n_heads=4, n_kv_heads=4,
                          d_ff=128, vocab=256, head_dim=16,
                          ssm=SSMCfg(version=2, state=4, expand=2,
                                     conv_width=4, head_dim=8),
                          hybrid_period=2, attn_q_chunk=32, loss_chunk=64,
                          ssm_chunk=16)
