"""Assigned architecture registry: ``get_config(name)`` / ``--arch <id>``.

Each module defines ``CONFIG`` (the exact published configuration) and
``smoke()`` (a reduced same-family config for CPU tests). ``dili-service``
is the paper's own "architecture": the distributed list service itself.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ArchConfig

ARCH_IDS: List[str] = [
    "qwen2_72b",
    "internlm2_20b",
    "qwen2_0_5b",
    "qwen2_5_3b",
    "musicgen_medium",
    "zamba2_7b",
    "qwen3_moe_235b_a22b",
    "granite_moe_3b_a800m",
    "llava_next_mistral_7b",
    "falcon_mamba_7b",
]

_ALIASES: Dict[str, str] = {
    "qwen2-72b": "qwen2_72b",
    "internlm2-20b": "internlm2_20b",
    "qwen2-0.5b": "qwen2_0_5b",
    "qwen2.5-3b": "qwen2_5_3b",
    "musicgen-medium": "musicgen_medium",
    "zamba2-7b": "zamba2_7b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "falcon-mamba-7b": "falcon_mamba_7b",
}


def canonical(name: str) -> str:
    return _ALIASES.get(name, name)


def get_config(name: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.CONFIG


def get_smoke_config(name: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.smoke()


def all_configs() -> Dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
