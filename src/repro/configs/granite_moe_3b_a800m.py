"""Granite-3.0-3B-A800M MoE [hf:ibm-granite; hf] — 40 experts, top-8."""
from repro.models.config import ArchConfig, MoECfg

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
    d_ff=512, vocab=49155, head_dim=64, qkv_bias=False,
    moe=MoECfg(n_experts=40, top_k=8, d_ff_expert=512),
    rope_theta=1e4,
)

def smoke():
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=32, vocab=256, head_dim=16,
                          moe=MoECfg(n_experts=8, top_k=2, d_ff_expert=32),
                          attn_q_chunk=32, loss_chunk=64)
