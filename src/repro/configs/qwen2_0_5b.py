"""Qwen2-0.5B [arXiv:2407.10671; hf] — dense GQA, QKV bias, tied embeds."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-0.5b", family="dense",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
    d_ff=4864, vocab=151936, head_dim=64, qkv_bias=True,
    tie_embeddings=True, rope_theta=1e6,
)

def smoke():
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=128, vocab=256, head_dim=16,
                          attn_q_chunk=32, loss_chunk=64)
