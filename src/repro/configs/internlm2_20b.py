"""InternLM2-20B [arXiv:2403.17297; hf] — dense GQA decoder."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="internlm2-20b", family="dense",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab=92544, head_dim=128, qkv_bias=False,
    rope_theta=1e6,
)

def smoke():
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=128, vocab=256, head_dim=16,
                          attn_q_chunk=32, loss_chunk=64)
