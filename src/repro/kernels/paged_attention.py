"""Pallas TPU kernel: paged decode attention over a DiLi page table.

The serving layer stores KV pages in per-device pools indexed by a DiLi
registry (DESIGN.md §3.1); a decode step gathers each sequence's pages via
the page table produced by ``hybrid_search`` and attends over them. This is
the compute hot-spot of the decode path (memory-bandwidth-bound at batch
decode), so it gets a flash-decode style kernel:

  grid = (batch, pages_per_seq)  — pages innermost, sequential on TPU, so a
  VMEM scratch accumulator carries the running (max, sum, weighted-V) across
  a sequence's pages; the page table and sequence lengths ride in scalar
  prefetch so each page's BlockSpec index_map can do the indirection
  (HBM -> VMEM copy of exactly one page per step, no host gather).

GQA: query heads are grouped onto KV heads inside the kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(page_table_ref, seq_len_ref,      # scalar prefetch
            q_ref, k_ref, v_ref,              # VMEM tiles
            o_ref,                            # output tile
            m_scr, l_scr, acc_scr,            # VMEM scratch
            *, page_size: int, groups: int):
    b = pl.program_id(0)
    p = pl.program_id(1)
    num_pages = pl.num_programs(1)

    @pl.when(p == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr[...], NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr[...])
        acc_scr[...] = jnp.zeros_like(acc_scr[...])

    q = q_ref[0]                # [H, D]
    k = k_ref[0]                # [S, KH, D]
    v = v_ref[0]                # [S, KH, D]
    h, d = q.shape
    s, kh, _ = k.shape

    qg = q.reshape(kh, groups, d)
    # scores[kh, g, s]
    scores = jnp.einsum("kgd,skd->kgs", qg, k,
                        preferred_element_type=jnp.float32)
    scores = scores * (d ** -0.5)

    # mask positions beyond this sequence's length
    base = p * page_size
    pos = base + jax.lax.broadcasted_iota(jnp.int32, (1, 1, s), 2)
    valid = pos < seq_len_ref[b]
    scores = jnp.where(valid, scores, NEG_INF)

    m_prev = m_scr[...]                       # [KH, G]
    m_cur = jnp.max(scores, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    pexp = jnp.exp(scores - m_new[..., None])          # [KH, G, S]
    l_new = l_scr[...] * alpha + jnp.sum(pexp, axis=-1)
    # acc[kh, g, d]
    acc_new = acc_scr[...] * alpha[..., None] + \
        jnp.einsum("kgs,skd->kgd", pexp, v,
                   preferred_element_type=jnp.float32)

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc_new

    @pl.when(p == num_pages - 1)
    def _done():
        denom = jnp.maximum(l_scr[...], 1e-30)[..., None]
        o_ref[0] = (acc_scr[...] / denom).reshape(h, d).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("page_size", "interpret"))
def paged_attention(q, k_pages, v_pages, page_table, seq_lens, *,
                    page_size: int, interpret: bool = True):
    """Decode attention.

    q:          [B, H, D]
    k_pages:    [P, S, KH, D]   (P = pool pages, S = page_size)
    v_pages:    [P, S, KH, D]
    page_table: [B, PP] int32   (DiLi slot per logical page; unused slots
                                 may repeat a valid page — masked by length)
    seq_lens:   [B] int32
    returns     [B, H, D]
    """
    b, h, d = q.shape
    _, s, kh, _ = k_pages.shape
    assert s == page_size
    pp = page_table.shape[1]
    groups = h // kh

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, pp),
        in_specs=[
            pl.BlockSpec((1, h, d), lambda i, p, pt, sl: (i, 0, 0)),
            pl.BlockSpec((1, s, kh, d),
                         lambda i, p, pt, sl: (pt[i, p], 0, 0, 0)),
            pl.BlockSpec((1, s, kh, d),
                         lambda i, p, pt, sl: (pt[i, p], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, d), lambda i, p, pt, sl: (i, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((kh, groups), jnp.float32),
            pltpu.VMEM((kh, groups), jnp.float32),
            pltpu.VMEM((kh, groups, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, page_size=page_size, groups=groups),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        interpret=interpret,
    )(page_table, seq_lens, q, k_pages, v_pages)
