"""Pallas TPU kernel: DiLi hybrid search (registry binary search + bounded
sublist scan) for batched key lookups — the paper's §4 "hybrid search",
restructured for the TPU memory hierarchy.

Hardware adaptation (DESIGN.md §2): the C++ DiLi chases ``next`` pointers —
a latency-bound random walk that is hostile to the TPU's vector unit. The
paper itself notes (§8) that the chunked-sublist optimization of Braginsky &
Petrank "is also applicable to the sublists of DiLi". We apply it: each
sublist's keys live in a contiguous, sorted, fixed-capacity block (the load
balancer's split threshold bounds occupancy), so the hybrid search becomes

    1. vectorized binary search over the registry's keymin column (VMEM),
    2. one VMEM row gather + a vectorized compare over the sublist block,

which is exactly the paper's "logarithmic index + bounded linear scan", with
the linear scan now a single VPU sweep instead of ~125 dependent loads.

The runtime's batched round pre-pass (``core/batch_apply.py`` — FINDs per
DESIGN.md §4, INSERT/REMOVE per §4b) implements the same two stages
against the live linked pool — stage 1 is ``registry.get_by_key``
over the identical sorted-keymin layout, stage 2 a lock-step bounded walk
(``traverse.probe_batch``) in place of the block sweep — so on TPU, once
sublists are kept in packed blocks, this kernel drops in as both
fast-paths' probe with no contract change: the mutation pre-pass consumes
stage 2's Harris window ``(left, right)``, and this kernel already returns
its packed-block equivalent — ``pos`` (the insertion point inside the
block) IS the link slot an insert writes and the slot a remove marks, so
the §4b conflict screen ("two lanes, one link word") maps to "two lanes,
one (entry, pos) pair" verbatim.

Layout:
  * ``keymin``  int32[M]      — registry, padding rows = INT32_MAX
  * ``blocks``  int32[M, C]   — per-sublist sorted keys, padding = INT32_MAX
  * ``queries`` int32[B]      — keys to look up
Returns:
  * ``slot``  int32[B] — M*C-flattened position of the match (or insertion
                         point) — this is the "page slot" the serving layer
                         addresses. When every key of a *full* block is
                         below q the insertion point is C (past the block),
                         so ``slot == entry*C + C`` aliases ``(entry+1)*C``
                         numerically: callers that need (entry, pos) must
                         decode against their own resolved entry, never
                         ``slot // C``.
  * ``found`` bool[B]
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INT_MAX = jnp.iinfo(jnp.int32).max


def _kernel(keymin_ref, blocks_ref, q_ref, slot_ref, found_ref, *,
            levels: int):
    q = q_ref[...]                       # [TQ]
    keymin = keymin_ref[...]             # [M]
    m = keymin.shape[0]

    # --- registry binary search: entry covers keys > keymin[i] (Alg. 6)
    lo = jnp.zeros(q.shape, jnp.int32)
    hi = jnp.full(q.shape, m - 1, jnp.int32)
    for _ in range(levels):
        mid = (lo + hi + 1) // 2
        go = keymin[mid] < q             # vectorized VMEM gather
        lo = jnp.where(go, mid, lo)
        hi = jnp.where(go, hi, mid - 1)
    entry = lo                           # [TQ]

    # --- bounded "linear traversal": one row gather + vector compare
    rows = blocks_ref[...][entry]        # [TQ, C]
    eq = rows == q[:, None]
    ge = rows >= q[:, None]
    # insertion point. A full block with every key < q leaves ``ge``
    # all-False, where argmax alone would report position 0 — the exact
    # opposite end of the block. pos must be C there: insertion past the
    # block, i.e. the caller delegates to whatever follows the block
    # (next registry entry / the sublist's tail).
    pos = jnp.where(jnp.any(ge, axis=1),
                    jnp.argmax(ge, axis=1),
                    rows.shape[1]).astype(jnp.int32)
    found = jnp.any(eq, axis=1)
    slot_ref[...] = entry * rows.shape[1] + pos
    found_ref[...] = found


@functools.partial(jax.jit, static_argnames=("tile_q", "interpret"))
def hybrid_search(keymin, blocks, queries, *, tile_q: int = 128,
                  interpret: bool = True):
    """Batched DiLi lookup. See module docstring for layout contracts.

    ``queries`` may be ragged: batches are padded internally to the next
    ``tile_q`` multiple and the outputs sliced back, so hot-path callers
    never need to know the tile size.
    """
    b = queries.shape[0]
    m, c = blocks.shape
    pad = (-b) % tile_q
    if pad:
        queries = jnp.concatenate(
            [queries, jnp.zeros((pad,), queries.dtype)])
    bp = b + pad
    levels = max(1, math.ceil(math.log2(max(m, 2))))

    grid = (bp // tile_q,)
    slot, found = pl.pallas_call(
        functools.partial(_kernel, levels=levels),
        grid=grid,
        in_specs=[
            pl.BlockSpec((m,), lambda i: (0,)),          # registry: resident
            pl.BlockSpec((m, c), lambda i: (0, 0)),      # blocks: resident
            pl.BlockSpec((tile_q,), lambda i: (i,)),     # query tile
        ],
        out_specs=[
            pl.BlockSpec((tile_q,), lambda i: (i,)),
            pl.BlockSpec((tile_q,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp,), jnp.int32),
            jax.ShapeDtypeStruct((bp,), jnp.bool_),
        ],
        interpret=interpret,
    )(keymin, blocks, queries)
    return slot[:b], found[:b]
