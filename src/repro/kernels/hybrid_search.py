"""Pallas TPU kernel: DiLi hybrid search (registry binary search + bounded
sublist scan) for batched key lookups — the paper's §4 "hybrid search",
restructured for the TPU memory hierarchy.

Hardware adaptation (DESIGN.md §2): the C++ DiLi chases ``next`` pointers —
a latency-bound random walk that is hostile to the TPU's vector unit. The
paper itself notes (§8) that the chunked-sublist optimization of Braginsky &
Petrank "is also applicable to the sublists of DiLi". We apply it: each
sublist's keys live in a contiguous, sorted, fixed-capacity block (the load
balancer's split threshold bounds occupancy), so the hybrid search becomes

    1. vectorized binary search over the registry's keymin column (VMEM),
    2. one VMEM row gather + a vectorized compare over the sublist block,

which is exactly the paper's "logarithmic index + bounded linear scan", with
the linear scan now a single VPU sweep instead of ~125 dependent loads.

The runtime's batched round pre-pass (``core/batch_apply.py`` — FINDs per
DESIGN.md §4, INSERT/REMOVE per §4b) implements the same two stages
against the live linked pool — stage 1 is ``registry.get_by_key``
over the identical sorted-keymin layout, stage 2 a lock-step bounded walk
(``traverse.probe_batch``) in place of the block sweep — so on TPU, once
sublists are kept in packed blocks, this kernel drops in as both
fast-paths' probe with no contract change: the mutation pre-pass consumes
stage 2's Harris window ``(left, right)``, and this kernel already returns
its packed-block equivalent — ``pos`` (the insertion point inside the
block) IS the link slot an insert writes and the slot a remove marks, so
the §4b conflict screen ("two lanes, one link word") maps to "two lanes,
one (entry, pos) pair" verbatim.

Layout:
  * ``keymin``  int32[M]      — registry, padding rows = INT32_MAX
  * ``blocks``  int32[M, C]   — per-sublist sorted keys, padding = INT32_MAX
  * ``queries`` int32[B]      — keys to look up
Returns:
  * ``slot``  int32[B] — M*C-flattened position of the match (or insertion
                         point) — this is the "page slot" the serving layer
                         addresses
  * ``found`` bool[B]
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INT_MAX = jnp.iinfo(jnp.int32).max


def _kernel(keymin_ref, blocks_ref, q_ref, slot_ref, found_ref, *,
            levels: int):
    q = q_ref[...]                       # [TQ]
    keymin = keymin_ref[...]             # [M]
    m = keymin.shape[0]

    # --- registry binary search: entry covers keys > keymin[i] (Alg. 6)
    lo = jnp.zeros(q.shape, jnp.int32)
    hi = jnp.full(q.shape, m - 1, jnp.int32)
    for _ in range(levels):
        mid = (lo + hi + 1) // 2
        go = keymin[mid] < q             # vectorized VMEM gather
        lo = jnp.where(go, mid, lo)
        hi = jnp.where(go, hi, mid - 1)
    entry = lo                           # [TQ]

    # --- bounded "linear traversal": one row gather + vector compare
    rows = blocks_ref[...][entry]        # [TQ, C]
    eq = rows == q[:, None]
    ge = rows >= q[:, None]
    pos = jnp.argmax(ge, axis=1).astype(jnp.int32)   # insertion point
    found = jnp.any(eq, axis=1)
    slot_ref[...] = entry * rows.shape[1] + pos
    found_ref[...] = found


@functools.partial(jax.jit, static_argnames=("tile_q", "interpret"))
def hybrid_search(keymin, blocks, queries, *, tile_q: int = 128,
                  interpret: bool = True):
    """Batched DiLi lookup. See module docstring for layout contracts."""
    b = queries.shape[0]
    m, c = blocks.shape
    assert b % tile_q == 0, (b, tile_q)
    levels = max(1, math.ceil(math.log2(max(m, 2))))

    grid = (b // tile_q,)
    return pl.pallas_call(
        functools.partial(_kernel, levels=levels),
        grid=grid,
        in_specs=[
            pl.BlockSpec((m,), lambda i: (0,)),          # registry: resident
            pl.BlockSpec((m, c), lambda i: (0, 0)),      # blocks: resident
            pl.BlockSpec((tile_q,), lambda i: (i,)),     # query tile
        ],
        out_specs=[
            pl.BlockSpec((tile_q,), lambda i: (i,)),
            pl.BlockSpec((tile_q,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.bool_),
        ],
        interpret=interpret,
    )(keymin, blocks, queries)
