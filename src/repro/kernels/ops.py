"""Public jit'd entry points for the kernels package.

``interpret`` defaults to True on CPU (this container) and False when a real
TPU backend is present — the kernels are written for TPU BlockSpec tiling
and validated against ``ref.py`` in interpret mode.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from . import ref as ref_ops
from .hybrid_search import hybrid_search as _hybrid_search
from .paged_attention import paged_attention as _paged_attention

_INT32_MAX = jnp.iinfo(jnp.int32).max

_TRUTHY = ("1", "true", "on", "yes")
_FALSY = ("0", "false", "off", "no")


def _default_interpret() -> bool:
    """Platform default (interpret everywhere but TPU), overridable via
    the ``REPRO_INTERPRET`` env var — forcing interpret *on* reproduces a
    CI failure on a TPU host, forcing it *off* exercises the compiled
    kernel path regardless of platform. Unrecognized values raise rather
    than silently fall back (a typo like ``REPRO_INTERPRET=ture`` must
    not quietly change which code path a repro runs)."""
    env = os.environ.get("REPRO_INTERPRET")
    if env is not None:
        val = env.strip().lower()
        if val in _TRUTHY:
            return True
        if val in _FALSY:
            return False
        raise ValueError(
            f"REPRO_INTERPRET={env!r}: expected one of "
            f"{_TRUTHY + _FALSY}")
    return jax.default_backend() != "tpu"


def hybrid_search(keymin, blocks, queries, *, tile_q: int = 128,
                  interpret: bool | None = None):
    """Batched DiLi lookup (registry binary search + block sweep).

    Contract: real keys are strictly below ``INT32_MAX`` — that value is
    the block/registry padding sentinel, so a query of ``INT32_MAX`` would
    compare equal to every padding cell and report a spurious hit. Such
    queries are masked here: their ``found`` is always False (their
    ``slot`` still points at the row's first padding cell, a correct
    insertion point for "past every real key"). Ragged batch sizes are
    handled internally (padded to the tile, outputs sliced back).
    """
    if interpret is None:
        interpret = _default_interpret()
    slot, found = _hybrid_search(keymin, blocks, queries, tile_q=tile_q,
                                 interpret=interpret)
    return slot, found & (queries != _INT32_MAX)


def paged_attention(q, k_pages, v_pages, page_table, seq_lens, *,
                    page_size: int, interpret: bool | None = None):
    if interpret is None:
        interpret = _default_interpret()
    return _paged_attention(q, k_pages, v_pages, page_table, seq_lens,
                            page_size=page_size, interpret=interpret)


# re-exported oracles
def hybrid_search_ref(keymin, blocks, queries):
    """Oracle twin of ``hybrid_search`` above — same sentinel masking, so
    the public pair stays bit-identical on every int32 input."""
    slot, found = ref_ops.hybrid_search_ref(keymin, blocks, queries)
    return slot, found & (queries != _INT32_MAX)


paged_attention_ref = ref_ops.paged_attention_ref
