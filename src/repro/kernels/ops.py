"""Public jit'd entry points for the kernels package.

``interpret`` defaults to True on CPU (this container) and False when a real
TPU backend is present — the kernels are written for TPU BlockSpec tiling
and validated against ``ref.py`` in interpret mode.
"""
from __future__ import annotations

import jax

from . import ref as ref_ops
from .hybrid_search import hybrid_search as _hybrid_search
from .paged_attention import paged_attention as _paged_attention


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def hybrid_search(keymin, blocks, queries, *, tile_q: int = 128,
                  interpret: bool | None = None):
    if interpret is None:
        interpret = _default_interpret()
    return _hybrid_search(keymin, blocks, queries, tile_q=tile_q,
                          interpret=interpret)


def paged_attention(q, k_pages, v_pages, page_table, seq_lens, *,
                    page_size: int, interpret: bool | None = None):
    if interpret is None:
        interpret = _default_interpret()
    return _paged_attention(q, k_pages, v_pages, page_table, seq_lens,
                            page_size=page_size, interpret=interpret)


# re-exported oracles
hybrid_search_ref = ref_ops.hybrid_search_ref
paged_attention_ref = ref_ops.paged_attention_ref
