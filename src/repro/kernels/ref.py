"""Pure-jnp oracles for every kernel in this package (allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def hybrid_search_ref(keymin, blocks, queries):
    """Reference for kernels.hybrid_search: searchsorted + row scan."""
    m, c = blocks.shape
    # entry covers keys > keymin[i] — first i with keymin >= q, minus 1
    entry = jnp.searchsorted(keymin, queries, side="left").astype(jnp.int32) - 1
    entry = jnp.clip(entry, 0, m - 1)
    rows = blocks[entry]                       # [B, C]
    eq = rows == queries[:, None]
    ge = rows >= queries[:, None]
    # full block, every key < q: ge is all-False and argmax alone would say
    # position 0 — the insertion point is C (past the block). Same fix as
    # the kernel; the two must stay bit-identical or differential tests go
    # blind to exactly this edge.
    pos = jnp.where(jnp.any(ge, axis=1),
                    jnp.argmax(ge, axis=1),
                    c).astype(jnp.int32)
    found = jnp.any(eq, axis=1)
    return entry * c + pos, found


def paged_attention_ref(q, k_pages, v_pages, page_table, seq_lens, *,
                        page_size: int):
    """Reference paged decode attention: dense gather + masked softmax."""
    b, h, d = q.shape
    _, s, kh, _ = k_pages.shape
    pp = page_table.shape[1]
    groups = h // kh

    k = k_pages[page_table].reshape(b, pp * s, kh, d)
    v = v_pages[page_table].reshape(b, pp * s, kh, d)
    qg = q.reshape(b, kh, groups, d).astype(jnp.float32)
    scores = jnp.einsum("bkgd,blkd->bkgl", qg, k.astype(jnp.float32))
    scores = scores * (d ** -0.5)
    pos = jnp.arange(pp * s)[None, None, None, :]
    valid = pos < seq_lens[:, None, None, None]
    scores = jnp.where(valid, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgl,blkd->bkgd", w, v.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)
