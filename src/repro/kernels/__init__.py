"""Pallas TPU kernels (validated in interpret mode on CPU; see ref.py)."""
from . import ops, ref  # noqa: F401
