# Submodules import models; keep this __init__ lazy to avoid import cycles
# (models.transformer -> runtime.actctx).
from . import actctx  # noqa: F401
