"""Activation-sharding context: model code asks for constraints by *role*,
the launcher binds roles to mesh-specific shardings before lowering.

Keeps model code mesh-agnostic while letting the dry-run/trainer pin the
partitioning that matters for memory (sequence-parallel hidden states
between layers, MoE dispatch buffers, logits).
"""
from __future__ import annotations

from contextlib import contextmanager
from typing import Dict, Optional

import jax

_CTX: Dict[str, Optional[object]] = {}


def set_roles(**roles) -> None:
    _CTX.clear()
    _CTX.update(roles)


@contextmanager
def roles(**kw):
    old = dict(_CTX)
    _CTX.update(kw)
    try:
        yield
    finally:
        _CTX.clear()
        _CTX.update(old)


def constrain(x, role: str):
    s = _CTX.get(role)
    if s is None:
        return x
    return jax.lax.with_sharding_constraint(x, s)
