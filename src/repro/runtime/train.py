"""Fault-tolerant training runtime.

``build_train_step`` produces the jitted (params, opt, batch) -> ... step
with explicit in/out shardings (this is what the dry-run lowers). ``Trainer``
wraps it with the production loop mechanics:

  * checkpoint/restart — resume is bitwise (data pipeline is a pure function
    of step, optimizer state checkpointed; asserted in tests);
  * straggler mitigation — the data loader never blocks on a slow shard:
    synthetic/deterministic generation is compute-local; for a real reader
    the deterministic skip-ahead gives the same property (documented);
  * simulated failures — ``failure_hook`` lets tests kill the loop at an
    arbitrary step and assert recovery;
  * gradient accumulation and optional int8 cross-pod gradient compression
    (error feedback) hook in here.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.models import transformer as T
from repro.models.config import ArchConfig, ShapeCell
from repro.optim import AdamWConfig, adamw_init, adamw_update
from . import sharding as S


def build_train_step(cfg: ArchConfig, opt_cfg: AdamWConfig,
                     mesh: Optional[Mesh] = None, *, donate: bool = True):
    """Returns (step_fn, shardings) — step_fn jitted with explicit specs."""

    def step(params, opt_state, batch):
        def loss_fn(p):
            loss, metrics = T.forward_train(p, cfg, batch)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        new_params, new_opt, opt_metrics = adamw_update(
            opt_cfg, params, grads, opt_state)
        metrics = {"loss": loss, **metrics, **opt_metrics}
        return new_params, new_opt, metrics

    if mesh is None:
        return jax.jit(step, donate_argnums=(0, 1) if donate else ()), None

    def shardings_for(params, opt_state, batch):
        ps = S.param_shardings(params, mesh)
        os_ = {"mu": ps, "nu": ps,
               "step": NamedSharding(mesh, P())}
        bs = S.batch_shardings(batch, mesh)
        return ps, os_, bs

    def jit_with(params_sds, opt_sds, batch_sds):
        ps, os_, bs = shardings_for(params_sds, opt_sds, batch_sds)
        rep = NamedSharding(mesh, P())
        out_metrics = None  # inferred (scalars -> replicated)
        return jax.jit(
            step,
            in_shardings=(ps, os_, bs),
            out_shardings=(ps, os_, None),
            donate_argnums=(0, 1) if donate else (),
        )

    return step, jit_with


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    log_every: int = 10


class Trainer:
    def __init__(self, cfg: ArchConfig, cell: ShapeCell,
                 opt_cfg: AdamWConfig, tcfg: TrainerConfig, *,
                 make_batch: Callable[[int], Any], dtype=jnp.float32,
                 seed: int = 0,
                 failure_hook: Optional[Callable[[int], None]] = None):
        self.cfg, self.cell, self.opt_cfg, self.tcfg = cfg, cell, opt_cfg, tcfg
        self.make_batch = make_batch
        self.failure_hook = failure_hook
        self.step_fn, _ = build_train_step(cfg, opt_cfg, donate=False)
        self.params = T.init_params(cfg, jax.random.PRNGKey(seed),
                                    dtype=dtype)
        self.opt_state = adamw_init(self.params)
        self.mgr = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.keep)
        self.start_step = 0
        self.metrics_log: list = []

    def maybe_resume(self) -> bool:
        tpl = {"params": self.params, "opt": self.opt_state}
        step, tree = self.mgr.restore_latest(tpl)
        if step is None:
            return False
        self.params, self.opt_state = tree["params"], tree["opt"]
        self.start_step = step
        return True

    def run(self) -> Dict[str, Any]:
        step = self.start_step
        while step < self.tcfg.total_steps:
            batch = self.make_batch(step)   # pure function of step: a
            # restarted run regenerates the identical stream (no loss/dup)
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch)
            step += 1
            if step % self.tcfg.log_every == 0 or step == 1:
                self.metrics_log.append(
                    {k: float(v) for k, v in metrics.items()} | {"step": step})
            if step % self.tcfg.ckpt_every == 0:
                self.mgr.save(step, {"params": self.params,
                                     "opt": self.opt_state})
            if self.failure_hook is not None:
                self.failure_hook(step)   # may raise SimulatedFailure
        self.mgr.wait()
        return {"final_step": step, "metrics": self.metrics_log}


class SimulatedFailure(RuntimeError):
    pass
