"""Logical-axis sharding rules: param/opt/batch PartitionSpecs per mesh.

Strategy (DESIGN.md §6):
  * FSDP over ``data``: every weight matrix shards its d_model-sized axis
    over the data axis for storage; XLA inserts all-gathers on use and
    reduce-scatters on the gradient.
  * TP over ``model``: heads / ffn / vocab / experts axes.
  * ``pod`` (multi-pod mesh) is pure DP: batch shards over it; parameters
    are replicated across pods; gradient all-reduce crosses pods once.

Rules are matched on flattened param paths — the registry below covers every
family's parameter names; anything unmatched is replicated (asserted small).
"""
from __future__ import annotations

import re
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# (path regex, candidate spec builders) — d = data axis, m = model axis.
# Candidates are tried in order; the first whose assigned dims all divide
# the axis sizes wins (e.g. 40-expert MoE cannot shard experts 16-way, so
# EP falls back to sharding the expert FFN dim instead).
# Specs are given per *trailing* dims (ignoring a leading layer-stack dim,
# which is always unsharded).
_RULES: Tuple[Tuple[str, Tuple[Tuple[Optional[str], ...], ...]], ...] = (
    # embeddings / lm head: vocab over model, d_model over data
    (r"embed$", (("m", "d"),)),
    (r"lm_head$", (("d", "m"),)),
    # attention
    (r"attn/w[qkv]$", (("d", "m"),)),
    (r"attn/wo$", (("m", "d"),)),
    (r"attn/b[qkv]$", (("m",), (None,))),
    # dense mlp
    (r"mlp/w_(gate|up)$", (("d", "m"),)),
    (r"mlp/w_down$", (("m", "d"),)),
    # moe: experts over model (EP); fallback = TP inside each expert
    (r"moe/router$", (("d", None),)),
    (r"moe/w_(gate|up)$", (("m", "d", None), (None, "d", "m"))),
    (r"moe/w_down$", (("m", None, "d"), (None, "m", "d"))),
    # mamba: channel dims over model
    (r"mamba/in_proj$", (("d", "m"),)),
    (r"mamba/out_proj$", (("m", "d"),)),
    (r"mamba/x_bc$", (("m", None),)),
    (r"mamba/dt_proj$", ((None, "m"),)),
    (r"mamba/conv_w$", ((None, "m"),)),
    (r"mamba/(conv_b|dt_bias|a_log|d_skip|norm_scale)$", (("m",), (None,))),
    # norms: replicated
    (r"(ln1|ln2|final_norm|norm_scale)$", ((None,),)),
)


def _leaf_path(path) -> str:
    return "/".join(str(p).strip("[].'") for p in path)


def spec_for(path: str, shape, *, data_axis, model_axis,
             axis_sizes) -> P:
    ndim = len(shape)
    for pat, candidates in _RULES:
        if not re.search(pat, path):
            continue
        for axes in candidates:
            spec = [None] * ndim
            trail = len(axes)
            off = ndim - trail
            use = axes[-ndim:] if off < 0 else axes
            off = max(off, 0)
            ok = True
            for i, a in enumerate(use):
                name = data_axis if a == "d" else (
                    model_axis if a == "m" else None)
                if name is None:
                    continue
                if shape[off + i] % axis_sizes.get(name, 1) != 0:
                    ok = False
                    break
                spec[off + i] = name
            if ok:
                return P(*spec)
        return P()  # no candidate divides: replicate
    return P()  # replicate


def param_specs(params, mesh: Mesh):
    """PartitionSpec pytree for a param/opt-state tree."""
    names = mesh.axis_names
    data_axis = "data" if "data" in names else None
    model_axis = "model" if "model" in names else None
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        p = _leaf_path(path)
        spec = spec_for(p, tuple(np.shape(leaf)), data_axis=data_axis,
                        model_axis=model_axis, axis_sizes=axis_sizes)
        specs.append(spec)
    return jax.tree_util.tree_unflatten(treedef, specs)


def param_shardings(params, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), param_specs(params, mesh))


def batch_spec(mesh: Mesh) -> P:
    """Batch dim over (pod, data) jointly."""
    names = mesh.axis_names
    dp = tuple(a for a in ("pod", "data") if a in names)
    return P(dp if len(dp) > 1 else (dp[0] if dp else None))


def batch_shardings(batch, mesh: Mesh):
    bs = batch_spec(mesh)
    return jax.tree_util.tree_map(
        lambda x: NamedSharding(mesh, bs), batch)


def cache_specs(cache, mesh: Mesh, *, seq_axis: bool = False):
    """Decode-cache specs, keyed by cache entry name.

      k/v  : [L, B, S, KH, D] — batch over DP, KV heads over model; with
             ``seq_axis=True`` (long-context, batch=1) the sequence dim
             shards over ``data`` instead (context parallelism).
      conv : [L, B, W-1, C]   — channels over model.
      ssm  : [L, B, C, N] or [L, B, H, P, N] — channels/heads over model.
    """
    names = mesh.axis_names
    dp = tuple(a for a in ("pod", "data") if a in names)
    dp = dp if len(dp) > 1 else (dp[0] if dp else None)

    model_size = dict(zip(mesh.axis_names, mesh.devices.shape)).get(
        "model", 1)

    def one(path, x):
        name = _leaf_path(path).split("/")[-1]
        nd = np.ndim(x)
        bdim = None if seq_axis else dp
        if name in ("k", "v"):
            # KV heads over model when divisible, else sequence over model
            # (GQA archs with few KV heads); long-context additionally
            # shards the sequence over data (seq_axis).
            kh = x.shape[3]
            sdim = dp if seq_axis else None
            if kh % model_size == 0:
                return P(None, bdim, sdim, "model", None)
            if seq_axis:
                return P(None, bdim, ("data", "model")
                         if "data" in mesh.axis_names else "model",
                         None, None)
            return P(None, bdim, "model", None, None)
        if name in ("k_scale", "v_scale"):   # [L, B, S, KH]
            kh = x.shape[3]
            if kh % model_size == 0:
                return P(None, bdim, dp if seq_axis else None, "model")
            return P(None, bdim, "model", None)
        if name == "conv":
            return P(None, bdim, None, "model")
        if name == "ssm":
            if nd == 5:                      # [L, B, H, P, N]
                return P(None, bdim, "model", None, None)
            return P(None, bdim, "model", None)
        return P()

    return jax.tree_util.tree_map_with_path(one, cache)


def cache_shardings(cache, mesh: Mesh, *, seq_axis: bool = False):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        cache_specs(cache, mesh, seq_axis=seq_axis))
