"""repro: DiLi (distributable lock-free index) + multi-pod JAX LM framework.

Subpackages:
  api        — public client surface (DiLiClient futures API + backends)
  core       — the paper's contribution (DiLi protocol + runtimes)
  kernels    — Pallas TPU kernels (hybrid_search, paged_attention)
  models     — the 10 assigned architectures' backbones
  data/optim/checkpoint/runtime/serving — production substrates
  configs    — architecture registry (--arch <id>)
  launch     — mesh / dryrun / train / serve entry points
"""
