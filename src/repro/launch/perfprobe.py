import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse          # noqa: E402
import collections       # noqa: E402
import re                # noqa: E402

import jax               # noqa: E402

from repro.configs import get_config                  # noqa: E402
from repro.launch import roofline as R                # noqa: E402
from repro.launch.dryrun import (_compile_cell,       # noqa: E402
                                 probe_costs)
from repro.launch.mesh import make_production_mesh    # noqa: E402
from repro.models.config import shape_by_name         # noqa: E402

"""Perf probe: per-collective breakdown for one (arch, shape, mesh) cell.

Prints the top collective ops by total bytes with their shapes and source
op names — the 'profile' of the dry-run-only workflow (DESIGN.md §7).
"""

_LINE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9_]+\[[0-9,]*\][^ ]*))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r".*?metadata=\{op_name=\"([^\"]*)\"", re.I)


def breakdown(hlo_text, top=15):
    agg = collections.Counter()
    meta = {}
    for m in _LINE.finditer(hlo_text):
        shape, kind, op = m.group(1), m.group(2), m.group(3)
        nbytes = R._shape_bytes(shape)
        key = (kind, shape.split("{")[0][:60], op[:90])
        agg[key] += nbytes
        meta[key] = meta.get(key, 0) + 1
    rows = agg.most_common(top)
    return rows, meta


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--layers", type=int, default=2,
                    help="probe depth (unrolled)")
    ap.add_argument("--model-size", type=int, default=16,
                    help="logical model-axis size (256/model = data)")
    ap.add_argument("--override", default="",
                    help="comma k=v ArchConfig overrides, e.g. "
                         "attn_q_chunk=1024,remat=False")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    over = {}
    for kv in filter(None, args.override.split(",")):
        k, v = kv.split("=")
        over[k] = eval(v)  # noqa: S307 - trusted CLI
    cfg = cfg.replace(n_layers=args.layers, scan_layers=False, **over)
    cell = shape_by_name(args.shape)
    mesh = make_production_mesh(multi_pod=args.multi_pod,
                                model_size=args.model_size)

    kind, compiled = _compile_cell(cfg, cell, mesh)
    text = compiled.as_text()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    print(f"probe {args.arch} x {args.shape} L={args.layers} kind={kind} "
          f"overrides={over}")
    print(f"  flops/dev={float(cost.get('flops', 0)):.4e}  "
          f"bytes/dev={float(cost.get('bytes accessed', 0)):.4e}")
    try:
        ma = compiled.memory_analysis()
        print(f"  temp={ma.temp_size_in_bytes/1e9:.2f}GB "
              f"args={ma.argument_size_in_bytes/1e9:.2f}GB")
    except Exception:
        pass
    rows, counts = breakdown(text)
    total = sum(R.collective_bytes(text).values())
    print(f"  collective total/dev: {total:.4e} bytes")
    for (ck, shape, op), nbytes in rows:
        print(f"   {nbytes/1e6:10.1f}MB x{counts[(ck, shape, op)]:3d} "
              f"{ck:18s} {shape:45s} {op}")


if __name__ == "__main__":
    main()
