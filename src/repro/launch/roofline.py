"""Roofline terms from a compiled dry-run artifact (no hardware needed).

  compute    = HLO_FLOPs_per_device / peak_FLOP/s
  memory     = HLO_bytes_per_device / HBM_bw
  collective = collective_bytes_per_device / ICI_link_bw

cost_analysis() on a SPMD-partitioned executable reports the *per-device*
module, so no division by chip count is applied to its numbers; the
MODEL_FLOPS utility baseline is divided by the device count explicitly.
Collective bytes are not in cost_analysis — they are summed from the
partitioned HLO text over all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute output shapes.
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional

# TPU v5e-class hardware constants (per chip)
PEAK_FLOPS = 197e12     # bf16
HBM_BW = 819e9          # bytes/s
ICI_BW = 50e9           # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "tf32": 4, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9_]+\[[0-9,]*\][^ ]*))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[^a-z-]", re.I)
_SHAPE_RE = re.compile(r"([a-z0-9_]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        nbytes = _DTYPE_BYTES.get(dt)
        if nbytes is None:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * nbytes
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output bytes of every collective op in (partitioned) HLO text."""
    out: Dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2).lower()
        out[kind] = out.get(kind, 0) + _shape_bytes(shape_str)
    return out


def model_flops(cfg, cell) -> float:
    """6·N·D for training, 2·N·D for inference (N = active params)."""
    n = active_params(cfg)
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * cell.global_batch  # decode: one token per sequence


def active_params(cfg) -> float:
    """Active parameter count (MoE counts top_k experts per token)."""
    d, v, L = cfg.d_model, cfg.vocab, cfg.n_layers
    hd, h, kh = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    emb = v * d * (1 if cfg.tie_embeddings else 2)
    if cfg.family == "ssm":
        s = cfg.ssm
        di = s.expand * d
        dt_rank = s.dt_rank or (d + 15) // 16
        per = d * 2 * di + di * (dt_rank + 2 * s.state) + dt_rank * di \
            + di * d
        return emb + L * per
    attn = d * (h * hd) + 2 * d * (kh * hd) + (h * hd) * d
    if cfg.family == "moe":
        ffn = 3 * d * cfg.moe.d_ff_expert * cfg.moe.top_k + d * cfg.moe.n_experts
        return emb + L * (attn + ffn)
    ffn = 3 * d * cfg.d_ff
    if cfg.family == "hybrid":
        s = cfg.ssm
        di = s.expand * d
        nh = di // s.head_dim
        per = d * (2 * di + 2 * s.state + nh) + di * d
        groups = max(1, L // max(cfg.hybrid_period, 1))
        return emb + L * per + (attn + ffn)  # shared block counted once
    return emb + L * (attn + ffn)


def analyze(compiled, *, n_devices: int, cfg, cell,
            hlo_text: Optional[str] = None) -> Dict[str, Any]:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes(text)
    coll_dev = float(sum(coll.values()))

    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)

    mf = model_flops(cfg, cell)
    mf_dev = mf / n_devices
    useful_ratio = mf_dev / flops_dev if flops_dev else 0.0
    bound = max(terms.values())
    mfu_bound = (mf_dev / PEAK_FLOPS) / bound if bound else 0.0

    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                mem[k] = int(v)
    except Exception as e:  # CPU backend may not implement it
        mem["error"] = str(e)

    return {
        "arch": cfg.name, "cell": cell.name, "devices": n_devices,
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "collective_bytes_per_device": coll_dev,
        "collectives": coll,
        "terms_seconds": terms,
        "dominant": dominant,
        "model_flops_global": mf,
        "useful_flops_ratio": useful_ratio,
        "roofline_mfu_bound": mfu_bound,
        "memory_analysis": mem,
    }
