import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import: jax locks the device
# count at first init, and the production meshes below need 512 placeholder
# host devices (2 pods x 16 x 16).

import argparse        # noqa: E402
import json            # noqa: E402
import math            # noqa: E402
import time            # noqa: E402
import traceback       # noqa: E402

import jax             # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCH_IDS, get_config          # noqa: E402
from repro.launch import roofline as R                  # noqa: E402
from repro.launch.inputs import (activation_roles,      # noqa: E402
                                 input_specs)
from repro.launch.mesh import make_production_mesh      # noqa: E402
from repro.models import transformer as T               # noqa: E402
from repro.models.config import SHAPES, shape_by_name   # noqa: E402
from repro.optim import AdamWConfig                     # noqa: E402
from repro.runtime import actctx                        # noqa: E402
from repro.runtime.train import build_train_step        # noqa: E402

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this proves the distribution config is coherent (sharding
propagates, collectives are supported, the program fits) and extracts the
roofline terms (launch/roofline.py) from the compiled artifact.

Usage:
  python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]
  python -m repro.launch.dryrun --arch dili-service
"""


def cells_for(cfg):
    """The shape cells an arch runs (long_500k only for sub-quadratic)."""
    out = []
    for cell in SHAPES:
        if cell.name == "long_500k" and not cfg.sub_quadratic:
            continue  # full-attention archs skip 524k ctx (DESIGN.md §5)
        out.append(cell)
    return out


def _compile_cell(cfg, cell, mesh):
    """Lower+compile one cell's step for ``cfg``. Returns compiled exec."""
    kind, args, shardings = input_specs(cfg, cell, mesh)
    actctx.set_roles(**activation_roles(cfg, cell, mesh))
    if kind == "train":
        opt_cfg = AdamWConfig()
        step, _ = build_train_step(cfg, opt_cfg, mesh, donate=True)
        pshard, oshard, _ = shardings
        fn = jax.jit(step, in_shardings=shardings,
                     out_shardings=(pshard, oshard, None),
                     donate_argnums=(0, 1))
    else:
        decode = kind == "decode"
        _, _, cshard, _ = shardings

        def serve_step(params, batch, cache, cache_len):
            return T.forward_serve(params, cfg, batch, cache, cache_len,
                                   decode=decode)

        fn = jax.jit(serve_step, in_shardings=shardings,
                     out_shardings=(None, cshard), donate_argnums=(2,))
    with mesh:
        lowered = fn.lower(*args)
        compiled = lowered.compile()
    return kind, compiled


def _cost_triple(compiled, hlo_text=None):
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = R.collective_bytes(text)
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)),
            float(sum(coll.values())), coll)


def probe_costs(cfg, cell, mesh):
    """Per-device (flops, bytes, coll_bytes) extrapolated to full depth.

    XLA's cost_analysis counts a While body once regardless of trip count,
    so the scanned full-depth program under-reports. Probes compile the
    same cell *unrolled* at tiny depths and extrapolate linearly:
      total = c(L0) + (depth - L0)/(L1 - L0) * (c(L1) - c(L0)).
    """
    if cfg.family == "hybrid":
        p = max(cfg.hybrid_period, 1)
        l0, l1 = p, 2 * p
        groups = max(1, cfg.n_layers // p)
        trailing = cfg.n_layers - groups * p
        pc = cfg.replace(n_layers=l0, scan_layers=False)
        _, c0 = _compile_cell(pc, cell, mesh)
        pc = cfg.replace(n_layers=l1, scan_layers=False)
        _, c1 = _compile_cell(pc, cell, mesh)
        pc = cfg.replace(n_layers=l0 + 1, scan_layers=False)
        _, cm = _compile_cell(pc, cell, mesh)
        f0, b0, co0, _ = _cost_triple(c0)
        f1, b1, co1, _ = _cost_triple(c1)
        fm, bm, com, _ = _cost_triple(cm)

        def tot(x0, x1, xm):
            group = x1 - x0
            mamba = xm - x0
            return x0 + (groups - 1) * group + trailing * mamba

        return tot(f0, f1, fm), tot(b0, b1, bm), tot(co0, co1, com)

    l0, l1 = 1, 2
    pc = cfg.replace(n_layers=l0, scan_layers=False)
    _, c0 = _compile_cell(pc, cell, mesh)
    pc = cfg.replace(n_layers=l1, scan_layers=False)
    _, c1 = _compile_cell(pc, cell, mesh)
    f0, b0, co0, _ = _cost_triple(c0)
    f1, b1, co1, _ = _cost_triple(c1)
    n = cfg.n_layers

    def tot(x0, x1):
        return x0 + (n - l0) * (x1 - x0)

    return tot(f0, f1), tot(b0, b1), tot(co0, co1)


def run_cell(arch: str, cell_name: str, *, multi_pod: bool,
             verbose: bool = True, probes: bool = True,
             model_size: int = 16, overrides: dict | None = None):
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    cell = shape_by_name(cell_name)
    mesh = make_production_mesh(multi_pod=multi_pod, model_size=model_size)
    n_dev = mesh.devices.size

    t0 = time.time()
    kind, compiled = _compile_cell(cfg, cell, mesh)
    t1 = time.time()

    hlo_text = compiled.as_text()
    res = R.analyze(compiled, n_devices=n_dev, cfg=cfg, cell=cell,
                    hlo_text=hlo_text)
    mesh_name = ("2x" if multi_pod else "") + \
        f"{256 // model_size}x{model_size}"
    res.update(mesh=mesh_name, kind=kind,
               compile_seconds=round(t1 - t0, 1))
    if overrides:
        res["overrides"] = {k: str(v) for k, v in overrides.items()}

    if probes:
        pf, pb, pc_ = probe_costs(cfg, cell, mesh)
        res["flops_per_device"] = pf
        res["bytes_per_device"] = pb
        res["collective_bytes_per_device"] = pc_
        terms = {"compute": pf / R.PEAK_FLOPS, "memory": pb / R.HBM_BW,
                 "collective": pc_ / R.ICI_BW}
        res["terms_seconds"] = terms
        res["dominant"] = max(terms, key=terms.get)
        mf_dev = res["model_flops_global"] / n_dev
        res["useful_flops_ratio"] = mf_dev / pf if pf else 0.0
        bound = max(terms.values())
        res["roofline_mfu_bound"] = \
            (mf_dev / R.PEAK_FLOPS) / bound if bound else 0.0
        res["probe_extrapolated"] = True
    if verbose:
        mem = res["memory_analysis"]
        print(f"[{arch} × {cell.name} × {res['mesh']}] kind={kind} "
              f"compile={res['compile_seconds']}s")
        print(f"  memory_analysis: {mem}")
        print(f"  flops/dev={res['flops_per_device']:.3e} "
              f"bytes/dev={res['bytes_per_device']:.3e} "
              f"coll/dev={res['collective_bytes_per_device']:.3e}")
        t = res["terms_seconds"]
        print(f"  terms(s): compute={t['compute']:.4e} "
              f"memory={t['memory']:.4e} collective={t['collective']:.4e} "
              f"-> dominant={res['dominant']}")
        print(f"  MODEL_FLOPS={res['model_flops_global']:.3e} "
              f"useful/HLO={res['useful_flops_ratio']:.3f} "
              f"roofline_MFU_bound={res['roofline_mfu_bound']:.3f}")
    actctx.set_roles()
    return res


def run_dili_service(*, multi_pod: bool, verbose: bool = True):
    """Dry-run the paper's own architecture: the DiLi service round."""
    from repro.core import messages as M
    from repro.core.distributed import make_dili_round, service_input_specs
    from repro.core.types import DiLiConfig

    mesh = make_production_mesh(multi_pod=multi_pod)
    n = mesh.devices.size
    cfg = DiLiConfig(num_shards=n, pool_capacity=1 << 16, max_sublists=512,
                     max_ctrs=512, max_scan=2048, batch_size=64,
                     mailbox_cap=192, move_batch=16)
    cap_pair = 4
    rnd = make_dili_round(mesh, cfg, cap_pair=cap_pair)
    args = service_input_specs(cfg, n, n * cap_pair)
    t0 = time.time()
    with mesh:
        lowered = rnd.lower(*args)
        compiled = lowered.compile()
    t1 = time.time()
    hlo_text = compiled.as_text()
    coll = R.collective_bytes(hlo_text)
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    res = {
        "arch": "dili-service", "cell": f"round_b{cfg.batch_size}",
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": n, "kind": "service_round",
        "compile_seconds": round(t1 - t0, 1),
        "flops_per_device": float(cost.get("flops", 0.0)),
        "bytes_per_device": float(cost.get("bytes accessed", 0.0)),
        "collectives": coll,
        "collective_bytes_per_device": float(sum(coll.values())),
    }
    if verbose:
        print(f"[dili-service × {res['mesh']}] "
              f"compile={res['compile_seconds']}s "
              f"coll/dev={res['collective_bytes_per_device']:.3e} {coll}")
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="arch id, or 'dili-service', or omit with --all")
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--model-size", type=int, default=16)
    ap.add_argument("--override", default="",
                    help="comma k=v ArchConfig overrides")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None, help="append JSONL here")
    args = ap.parse_args()

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    jobs = []
    if args.all:
        for a in ARCH_IDS:
            for cell in cells_for(get_config(a)):
                for mp in meshes:
                    jobs.append((a, cell.name, mp))
        for mp in meshes:
            jobs.append(("dili-service", None, mp))
    else:
        assert args.arch
        if args.arch == "dili-service":
            jobs = [("dili-service", None, mp) for mp in meshes]
        elif args.shape:
            jobs = [(args.arch, args.shape, mp) for mp in meshes]
        else:
            jobs = [(args.arch, c.name, mp) for mp in meshes
                    for c in cells_for(get_config(args.arch))]

    overrides = {}
    for kv in filter(None, args.override.split(",")):
        k, v = kv.split("=")
        overrides[k] = eval(v)  # noqa: S307 - trusted CLI

    results, failures = [], []
    for arch, shape, mp in jobs:
        try:
            if arch == "dili-service":
                res = run_dili_service(multi_pod=mp)
            else:
                res = run_cell(arch, shape, multi_pod=mp,
                               model_size=args.model_size,
                               overrides=overrides)
            results.append(res)
            if args.out:
                with open(args.out, "a") as f:
                    f.write(json.dumps(res) + "\n")
        except Exception as e:
            traceback.print_exc()
            failures.append({"arch": arch, "cell": shape,
                             "mesh": "2x16x16" if mp else "16x16",
                             "error": f"{type(e).__name__}: {e}"})

    print(f"\n=== dry-run: {len(results)} ok, {len(failures)} failed ===")
    for f_ in failures:
        print("FAILED:", f_)
    return 0 if not failures else 1


if __name__ == "__main__":
    raise SystemExit(main())
