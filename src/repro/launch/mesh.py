"""Production mesh builders.

Functions (not module-level constants) so importing never touches jax
device state — the dry-run must set XLA_FLAGS before any jax init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False, model_size: int = 16):
    """256 chips per pod; multi_pod adds a 2-pod leading axis.

    ``model_size`` re-slices the same physical chips into a different
    logical (data, model) split — the §Perf hillclimb lever: the hardware
    mesh is fixed, the axis assignment is a sharding choice.
    """
    assert 256 % model_size == 0
    data = 256 // model_size
    shape = (2, data, model_size) if multi_pod else (data, model_size)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host has, as a 1-D data mesh (examples/tests)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))
