"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs a real (small, CPU-feasible) training run through the full production
stack — config, data pipeline, jitted train step, checkpointing, resume.
The production mesh path is exercised by the dry-run; here the mesh is the
host's devices.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.data.synthetic import make_train_batch
from repro.models.config import ShapeCell
from repro.optim import AdamWConfig
from repro.runtime.train import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cell = ShapeCell("cli", "train", args.seq, args.batch)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                          total_steps=args.steps)

    def mk(step):
        return make_train_batch(cfg, cell, seed=0, step=step,
                                dtype=jnp.float32)

    tr = Trainer(cfg, cell, opt_cfg,
                 TrainerConfig(total_steps=args.steps,
                               ckpt_every=args.ckpt_every,
                               ckpt_dir=args.ckpt_dir, log_every=10),
                 make_batch=mk)
    if args.resume and tr.maybe_resume():
        print(f"resumed from step {tr.start_step}")
    out = tr.run()
    for m in out["metrics"]:
        print({k: round(v, 4) if isinstance(v, float) else v
               for k, v in m.items()})
    print(f"done at step {out['final_step']}")


if __name__ == "__main__":
    main()
