"""``input_specs()``: ShapeDtypeStruct stand-ins + shardings for every
(arch × shape) cell — weak-type-correct, shardable, zero allocation."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import transformer as T
from repro.models.config import ArchConfig, ShapeCell
from repro.optim import AdamWConfig, adamw_init
from repro.runtime import sharding as S


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_sds(cfg: ArchConfig, cell: ShapeCell, *, decode: bool,
              dtype=jnp.bfloat16) -> Dict[str, Any]:
    b, s = cell.global_batch, cell.seq_len
    t = 1 if decode else s
    if cell.kind == "train":
        if cfg.modality == "audio_stub":
            return {"frame_embeds": _sds((b, s, cfg.d_model), dtype),
                    "targets": _sds((b, s), jnp.int32)}
        if cfg.modality == "vision_stub":
            li = min(s // 2, 2048)
            return {"patch_embeds": _sds((b, li, cfg.d_model), dtype),
                    "tokens": _sds((b, s - li), jnp.int32),
                    "targets": _sds((b, s), jnp.int32)}
        return {"tokens": _sds((b, s), jnp.int32),
                "targets": _sds((b, s), jnp.int32)}
    # serving
    if cfg.modality == "audio_stub":
        return {"frame_embeds": _sds((b, t, cfg.d_model), dtype)}
    if cfg.modality == "vision_stub" and not decode:
        li = min(t // 2, 2048)
        return {"patch_embeds": _sds((b, li, cfg.d_model), dtype),
                "tokens": _sds((b, t - li), jnp.int32)}
    return {"tokens": _sds((b, t), jnp.int32)}


def _dp_size(mesh: Mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes.get("pod", 1) * sizes.get("data", 1)


def input_specs(cfg: ArchConfig, cell: ShapeCell, mesh: Mesh, *,
                dtype=jnp.bfloat16,
                opt_cfg: AdamWConfig = AdamWConfig()):
    """Returns (kind, args_sds, in_shardings) for the cell's step function.

    kind: 'train' -> (params, opt_state, batch)
          'prefill'/'decode' -> (params, batch, cache, cache_len)
    """
    params = jax.eval_shape(
        lambda: T.init_params(cfg, jax.random.PRNGKey(0), dtype=dtype))
    pshard = S.param_shardings(params, mesh)

    seq_axis = cell.global_batch < _dp_size(mesh)  # long-context: shard seq
    if cell.kind == "train":
        opt = jax.eval_shape(lambda p: adamw_init(p), params)
        oshard = {"mu": pshard, "nu": pshard,
                  "step": NamedSharding(mesh, P())}
        batch = batch_sds(cfg, cell, decode=False, dtype=dtype)
        bshard = S.batch_shardings(batch, mesh)
        return "train", (params, opt, batch), (pshard, oshard, bshard)

    decode = cell.kind == "decode"
    batch = batch_sds(cfg, cell, decode=decode, dtype=dtype)
    if seq_axis:
        bshard = jax.tree_util.tree_map(
            lambda x: NamedSharding(mesh, P()), batch)
    else:
        bshard = S.batch_shardings(batch, mesh)
    cache = jax.eval_shape(
        lambda: T.init_cache(cfg, cell.global_batch, cell.seq_len,
                             dtype=dtype))
    cshard = S.cache_shardings(cache, mesh, seq_axis=seq_axis)
    clen = _sds((cell.global_batch,), jnp.int32)
    clen_shard = NamedSharding(mesh, P())
    return cell.kind, (params, batch, cache, clen), \
        (pshard, bshard, cshard, clen_shard)


def activation_roles(cfg: ArchConfig, cell: ShapeCell, mesh: Mesh):
    """Role -> sharding bindings for repro.runtime.actctx."""
    names = mesh.axis_names
    dp = tuple(a for a in ("pod", "data") if a in names)
    dp = dp if len(dp) > 1 else (dp[0] if dp else None)
    roles = {}
    if cell.kind in ("train", "prefill") and cfg.seq_parallel:
        # sequence parallelism for the inter-layer hidden state
        roles["hidden"] = NamedSharding(mesh, P(dp, "model", None))
    elif cell.kind in ("train", "prefill"):
        roles["hidden"] = NamedSharding(mesh, P(dp, None, None))
    if cfg.family == "moe":
        roles["moe_dispatch"] = NamedSharding(
            mesh, P(dp, "model", None, None))
        if cfg.seq_parallel:
            # boundary pin needed only when tokens arrive seq-sharded
            roles["moe_predispatch"] = NamedSharding(
                mesh, P(dp, None, None, None))
    return roles
