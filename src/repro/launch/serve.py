"""Serving launcher: batched requests through the paged DiLi engine.

``python -m repro.launch.serve --arch qwen2-0.5b --smoke --requests 4``
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import transformer as T
from repro.serving.engine import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--dili-shards", type=int, default=2)
    ap.add_argument("--rebalance", action="store_true",
                    help="run the DiLi load balancer between decode steps")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    assert cfg.family in ("dense", "vlm", "moe"), \
        "the paged engine demo drives dense-family backbones"
    params = T.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    eng = ServingEngine(cfg, params, page_size=args.page_size,
                        num_pages=256, max_batch=args.requests,
                        dili_shards=args.dili_shards)

    rng = np.random.default_rng(0)
    reqs = [Request(seq_id=i,
                    prompt=rng.integers(0, cfg.vocab,
                                        args.prompt_len).astype(np.int32),
                    max_new=args.max_new)
            for i in range(args.requests)]
    for r in reqs:
        eng.admit(r)
        print(f"admitted seq {r.seq_id} ({len(r.prompt)} prompt tokens)")

    step = 0
    while any(not r.done for r in reqs):
        eng.step(rebalance=args.rebalance and step % 2 == 1)
        step += 1
    for r in reqs:
        print(f"seq {r.seq_id}: generated {r.out}")
    print(f"page-table sublists per shard: "
          f"{[len(eng.kv.backend.sublists(s)) for s in range(eng.kv.backend.n)]}")


if __name__ == "__main__":
    main()
