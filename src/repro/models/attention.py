"""GQA attention: q-chunked (flash-style) training path + cached decode.

The training path chunks queries and scans, keeping the live score tile at
[B, H, Cq, S] instead of [B, H, S, S] — the standard memory/roofline
trade-off knob (cfg.attn_q_chunk).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import apply_rope

NEG_INF = -1e30


def gqa_scores_ein(q, k):
    """q: [B, T, KH, G, D], k: [B, S, KH, D] -> [B, KH, G, T, S]."""
    return jnp.einsum("btkgd,bskd->bkgts", q, k,
                      preferred_element_type=jnp.float32)


def causal_attention(q, k, v, q_offset: int = 0, q_chunk: int = 512):
    """Causal GQA attention.

    q: [B, T, H, D]; k/v: [B, S, KH, D]; positions of q are
    q_offset + [0..T). Returns [B, T, H, D].
    """
    b, t, h, d = q.shape
    _, s, kh, _ = k.shape
    g = h // kh
    qg = q.reshape(b, t, kh, g, d)
    scale = d ** -0.5

    q_chunk = min(q_chunk, t)
    assert t % q_chunk == 0
    nchunks = t // q_chunk

    def chunk_body(carry, idx):
        start = idx * q_chunk
        qc = jax.lax.dynamic_slice_in_dim(qg, start, q_chunk, axis=1)
        scores = gqa_scores_ein(qc, k) * scale          # [B,KH,G,Cq,S]
        qpos = q_offset + start + jnp.arange(q_chunk)
        kpos = jnp.arange(s)
        mask = kpos[None, :] <= qpos[:, None]           # [Cq, S]
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        w = jax.nn.softmax(scores, axis=-1)
        oc = jnp.einsum("bkgts,bskd->btkgd", w.astype(v.dtype), v)
        return carry, oc.reshape(b, q_chunk, h, d)

    if nchunks == 1:
        _, out = chunk_body(None, jnp.asarray(0))
        return out
    _, outs = jax.lax.scan(chunk_body, None, jnp.arange(nchunks))
    return jnp.moveaxis(outs, 0, 1).reshape(b, t, h, d)


def decode_attention(q, k_cache, v_cache, cache_len):
    """Single-token decode: q [B, 1, H, D]; caches [B, S, KH, D]."""
    b, _, h, d = q.shape
    _, s, kh, _ = k_cache.shape
    g = h // kh
    qg = q.reshape(b, 1, kh, g, d)
    scores = gqa_scores_ein(qg, k_cache) * (d ** -0.5)  # [B,KH,G,1,S]
    pos = jnp.arange(s)
    valid = pos[None] < cache_len[:, None]              # [B, S]
    scores = jnp.where(valid[:, None, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgts,bskd->btkgd", w.astype(v_cache.dtype), v_cache)
    return out.reshape(b, 1, h, d)


def _quant_kv(x):
    """int8-quantize [B,T,KH,D] with per-(token, head) scales."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float16)


def _dequant_kv(q, scale, dtype):
    return (q.astype(jnp.float32) *
            scale.astype(jnp.float32)[..., None]).astype(dtype)


def attention_block(params, x, cfg, *, positions, kv_cache=None,
                    cache_len=None, decode=False):
    """Full attention sub-layer: qkv proj + rope + attn + out proj.

    kv_cache: None (training) or dict(k=[B,S,KH,D], v=[B,S,KH,D]) plus
    optional int8 scales (k_scale/v_scale, §Perf cell B).
    Returns (out, new_kv_cache).
    """
    b, t, _ = x.shape
    hd, h, kh = cfg.hd, cfg.n_heads, cfg.n_kv_heads

    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = q.reshape(b, t, h, hd)
    k = k.reshape(b, t, kh, hd)
    v = v.reshape(b, t, kh, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    if kv_cache is not None:
        quant = "k_scale" in kv_cache
        if decode:
            # insert the new token at cache_len (per batch row).
            # §Perf B1 (refuted): a batched scatter (.at[b, len].set) looks
            # cheaper but does NOT partition under SPMD when the batch dim
            # is sharded — XLA falls back to involuntary full
            # rematerialization of the cache (+60% HBM bytes measured).
            # The masked-select form partitions elementwise on every dim.
            def put(cache, new):
                idx = jnp.reshape(cache_len,
                                  (-1,) + (1,) * (cache.ndim - 1))
                pos = jnp.reshape(jnp.arange(cache.shape[1]),
                                  (1, -1) + (1,) * (cache.ndim - 2))
                return jnp.where(pos == idx, new.astype(cache.dtype), cache)

            if quant:
                kq, ks = _quant_kv(k)
                vq, vs = _quant_kv(v)
                new_cache = {"k": put(kv_cache["k"], kq),
                             "v": put(kv_cache["v"], vq),
                             "k_scale": put(kv_cache["k_scale"], ks),
                             "v_scale": put(kv_cache["v_scale"], vs)}
                kf = _dequant_kv(new_cache["k"], new_cache["k_scale"],
                                 x.dtype)
                vf = _dequant_kv(new_cache["v"], new_cache["v_scale"],
                                 x.dtype)
            else:
                new_cache = {"k": put(kv_cache["k"], k),
                             "v": put(kv_cache["v"], v)}
                kf, vf = new_cache["k"], new_cache["v"]
            out = decode_attention(q, kf, vf, cache_len + 1)
        else:  # prefill: write the whole prefix
            def put_prefix(cache, new):
                return jax.lax.dynamic_update_slice_in_dim(
                    cache, new.astype(cache.dtype), 0, axis=1)

            if quant:
                kq, ks = _quant_kv(k)
                vq, vs = _quant_kv(v)
                new_cache = {"k": put_prefix(kv_cache["k"], kq),
                             "v": put_prefix(kv_cache["v"], vq),
                             "k_scale": put_prefix(kv_cache["k_scale"], ks),
                             "v_scale": put_prefix(kv_cache["v_scale"], vs)}
            else:
                new_cache = {"k": put_prefix(kv_cache["k"], k),
                             "v": put_prefix(kv_cache["v"], v)}
            out = causal_attention(q, k, v, q_chunk=cfg.attn_q_chunk)
    else:
        out = causal_attention(q, k, v, q_chunk=cfg.attn_q_chunk)
        new_cache = None

    out = out.reshape(b, t, h * hd) @ params["wo"]
    return out, new_cache
