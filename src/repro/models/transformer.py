"""Top-level model: composes attention / MoE / SSM blocks per ArchConfig.

One code path serves every assigned architecture:

  dense / vlm / audio : [RMSNorm -> GQA attn] + [RMSNorm -> SwiGLU]
  moe                 : [RMSNorm -> GQA attn] + [RMSNorm -> MoE FFN]
  ssm                 : [RMSNorm -> Mamba1]
  hybrid (zamba2)     : groups of ``hybrid_period`` Mamba2 blocks followed by
                        one *shared* attention+MLP block (single param set
                        reused per application, as in Zamba)

Layers are stacked and scanned (``cfg.scan_layers``) with rematerialization
(``cfg.remat``) so the lowered HLO stays O(1) in depth — required for the
512-device dry-run of 80-94 layer models.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.runtime.actctx import constrain

from .attention import attention_block
from .config import ArchConfig
from .layers import cross_entropy, init_dense, rms_norm, swiglu
from .moe import init_moe_params, moe_ffn
from .ssm import (init_mamba1_params, init_mamba2_params, mamba1_block,
                  mamba2_block)


# ================================================================== params

def _init_attn(key, cfg: ArchConfig, dtype):
    hd, h, kh, d = cfg.hd, cfg.n_heads, cfg.n_kv_heads, cfg.d_model
    ks = jax.random.split(key, 4)
    p = {
        "wq": init_dense(ks[0], (d, h * hd), dtype=dtype),
        "wk": init_dense(ks[1], (d, kh * hd), dtype=dtype),
        "wv": init_dense(ks[2], (d, kh * hd), dtype=dtype),
        "wo": init_dense(ks[3], (h * hd, d), dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((kh * hd,), dtype)
        p["bv"] = jnp.zeros((kh * hd,), dtype)
    return p


def _init_mlp(key, cfg: ArchConfig, dtype):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "w_gate": init_dense(ks[0], (d, f), dtype=dtype),
        "w_up": init_dense(ks[1], (d, f), dtype=dtype),
        "w_down": init_dense(ks[2], (f, d), dtype=dtype),
    }


def _init_block(key, cfg: ArchConfig, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    if cfg.family == "ssm":
        return {"ln1": jnp.ones((d,), dtype),
                "mamba": init_mamba1_params(ks[0], cfg, dtype)}
    if cfg.family == "hybrid":
        return {"ln1": jnp.ones((d,), dtype),
                "mamba": init_mamba2_params(ks[0], cfg, dtype)}
    blk = {"ln1": jnp.ones((d,), dtype),
           "attn": _init_attn(ks[0], cfg, dtype),
           "ln2": jnp.ones((d,), dtype)}
    if cfg.family == "moe":
        blk["moe"] = init_moe_params(ks[1], cfg, dtype)
    else:
        blk["mlp"] = _init_mlp(ks[1], cfg, dtype)
    return blk


def init_params(cfg: ArchConfig, key, dtype=jnp.bfloat16) -> Dict[str, Any]:
    ks = jax.random.split(key, 6)
    n_stack = _n_stacked(cfg)
    blocks = jax.vmap(
        lambda k: _init_block(k, cfg, dtype))(jax.random.split(ks[0], n_stack))
    params = {
        # d**-0.5 keeps tied-head logits O(1) at init
        "embed": init_dense(ks[1], (cfg.vocab, cfg.d_model),
                            scale=cfg.d_model ** -0.5, dtype=dtype),
        "blocks": blocks,
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_dense(ks[2], (cfg.d_model, cfg.vocab),
                                       dtype=dtype)
    if cfg.family == "hybrid":
        params["shared"] = {
            "ln1": jnp.ones((cfg.d_model,), dtype),
            "attn": _init_attn(ks[3], cfg, dtype),
            "ln2": jnp.ones((cfg.d_model,), dtype),
            "mlp": _init_mlp(ks[4], cfg, dtype),
        }
    return params


def _n_stacked(cfg: ArchConfig) -> int:
    if cfg.family == "hybrid":
        return cfg.n_layers  # mamba blocks (shared attn is separate)
    return cfg.n_layers


def _n_groups(cfg: ArchConfig) -> int:
    """Hybrid: number of shared-attention applications."""
    return max(1, cfg.n_layers // max(cfg.hybrid_period, 1))


# ================================================================ forward

def _dense_block(blk, h, cfg, positions, kv=None, cache_len=None,
                 decode=False):
    x, new_kv = attention_block(
        blk["attn"], rms_norm(h, blk["ln1"], cfg.norm_eps), cfg,
        positions=positions, kv_cache=kv, cache_len=cache_len, decode=decode)
    h = h + x
    hn = rms_norm(h, blk["ln2"], cfg.norm_eps)
    if "moe" in blk:
        x, aux = moe_ffn(blk["moe"], hn, cfg)
    else:
        x = swiglu(hn, blk["mlp"]["w_gate"], blk["mlp"]["w_up"],
                   blk["mlp"]["w_down"])
        aux = {}
    return h + x, new_kv, aux


def _ssm_block(blk, h, cfg, state=None, decode=False):
    fn = mamba1_block if cfg.ssm.version == 1 else mamba2_block
    x, new_state = fn(blk["mamba"], rms_norm(h, blk["ln1"], cfg.norm_eps),
                      cfg, state=state, decode=decode)
    return h + x, new_state


def _embed_input(params, cfg: ArchConfig, batch):
    """Returns (h [B,S,D], targets [B,S], loss_mask [B,S])."""
    if cfg.modality == "audio_stub":
        h = batch["frame_embeds"]
        return h, batch["targets"], jnp.ones(batch["targets"].shape, bool)
    if cfg.modality == "vision_stub":
        tok_emb = params["embed"][batch["tokens"]]
        h = jnp.concatenate([batch["patch_embeds"].astype(tok_emb.dtype),
                             tok_emb], axis=1)
        li = batch["patch_embeds"].shape[1]
        tgt = batch["targets"]
        mask = jnp.arange(tgt.shape[1])[None, :] >= li
        return h, tgt, mask
    h = params["embed"][batch["tokens"]]
    return h, batch["targets"], jnp.ones(batch["targets"].shape, bool)


def _backbone_train(params, cfg: ArchConfig, h, positions):
    """Run all blocks (training path, no caches). Returns (h, aux)."""
    blocks = params["blocks"]
    aux0 = {"moe_aux": jnp.zeros((), jnp.float32),
            "moe_z": jnp.zeros((), jnp.float32)}

    if cfg.family in ("dense", "vlm", "audio", "moe"):
        def body(carry, blk):
            h, aux = carry
            h, _, a = _dense_block(blk, h, cfg, positions)
            h = constrain(h, "hidden")
            aux = {k: aux[k] + a.get(k, 0.0) for k in aux}
            return (h, aux), None

        body = jax.checkpoint(body) if cfg.remat else body
        if cfg.scan_layers:
            (h, aux), _ = jax.lax.scan(body, (h, aux0), blocks)
        else:
            aux = aux0
            for i in range(cfg.n_layers):
                blk = jax.tree_util.tree_map(lambda x: x[i], blocks)
                (h, aux), _ = body((h, aux), blk)
        return h, aux

    if cfg.family == "ssm":
        def body(h, blk):
            h, _ = _ssm_block(blk, h, cfg)
            h = constrain(h, "hidden")
            return h, None

        body = jax.checkpoint(body) if cfg.remat else body
        if cfg.scan_layers:
            h, _ = jax.lax.scan(body, h, blocks)
        else:
            for i in range(cfg.n_layers):
                blk = jax.tree_util.tree_map(lambda x: x[i], blocks)
                h, _ = body(h, blk)
        return h, aux0

    # hybrid (zamba2): groups of mamba blocks + one shared attn block
    period = max(cfg.hybrid_period, 1)
    groups = _n_groups(cfg)
    used = groups * period
    gblocks = jax.tree_util.tree_map(
        lambda x: x[:used].reshape(groups, period, *x.shape[1:]), blocks)
    shared = params["shared"]

    def group_body(h, gblk):
        def m_body(h, blk):
            h, _ = _ssm_block(blk, h, cfg)
            return h, None

        h, _ = jax.lax.scan(m_body, h, gblk)
        x, _ = attention_block(
            shared["attn"], rms_norm(h, shared["ln1"], cfg.norm_eps), cfg,
            positions=positions)
        h = h + x
        x = swiglu(rms_norm(h, shared["ln2"], cfg.norm_eps),
                   shared["mlp"]["w_gate"], shared["mlp"]["w_up"],
                   shared["mlp"]["w_down"])
        return constrain(h + x, "hidden"), None

    group_body = jax.checkpoint(group_body) if cfg.remat else group_body
    if cfg.scan_layers:
        h, _ = jax.lax.scan(group_body, h, gblocks)
    else:
        for g in range(groups):
            gb = jax.tree_util.tree_map(lambda x: x[g], gblocks)
            h, _ = group_body(h, gb)
    # trailing mamba blocks beyond the last full group
    for i in range(used, cfg.n_layers):
        blk = jax.tree_util.tree_map(lambda x: x[i], blocks)
        h, _ = _ssm_block(blk, h, cfg)
    return h, aux0


def _lm_logits(params, cfg, h):
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    return h @ head


def _chunked_loss(params, cfg: ArchConfig, h, targets, mask):
    """CE computed over sequence chunks to bound the [.., V] logit tile."""
    b, s, d = h.shape
    c = min(cfg.loss_chunk, s)
    assert s % c == 0

    def body(acc, idx):
        hs = jax.lax.dynamic_slice_in_dim(h, idx * c, c, axis=1)
        ts = jax.lax.dynamic_slice_in_dim(targets, idx * c, c, axis=1)
        ms = jax.lax.dynamic_slice_in_dim(mask, idx * c, c, axis=1)
        logits = _lm_logits(params, cfg, hs)
        ls = cross_entropy(logits, ts)
        return (acc[0] + jnp.sum(ls * ms), acc[1] + jnp.sum(ms)), None

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        jnp.arange(s // c))
    return tot / jnp.maximum(cnt, 1.0)


def forward_train(params, cfg: ArchConfig, batch):
    """Training forward: returns (loss, metrics)."""
    h, targets, mask = _embed_input(params, cfg, batch)
    h = constrain(h, "hidden")
    positions = jnp.arange(h.shape[1])[None, :]
    h, aux = _backbone_train(params, cfg, h, positions)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    loss = _chunked_loss(params, cfg, h, targets, mask)
    total = loss + 0.01 * aux["moe_aux"] + 1e-3 * aux["moe_z"]
    return total, {"ce_loss": loss, **aux}


# ============================================================ serving paths

def init_cache(cfg: ArchConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    """Decode caches: KV for attention layers, conv+ssm for SSM layers.

    With ``cfg.kv_quant`` the KV tensors are int8 with per-(token, kv-head)
    fp16 scales — 2x less HBM traffic on the decode-dominating term
    (§Perf cell B).
    """
    kh, hd = cfg.n_kv_heads, cfg.hd
    if cfg.family in ("dense", "vlm", "audio", "moe"):
        n = cfg.n_layers
        if cfg.kv_quant:
            return {"k": jnp.zeros((n, batch, max_seq, kh, hd), jnp.int8),
                    "v": jnp.zeros((n, batch, max_seq, kh, hd), jnp.int8),
                    "k_scale": jnp.zeros((n, batch, max_seq, kh),
                                         jnp.float16),
                    "v_scale": jnp.zeros((n, batch, max_seq, kh),
                                         jnp.float16)}
        return {"k": jnp.zeros((n, batch, max_seq, kh, hd), dtype),
                "v": jnp.zeros((n, batch, max_seq, kh, hd), dtype)}
    s = cfg.ssm
    di = s.expand * cfg.d_model
    n = cfg.n_layers
    if cfg.family == "ssm":
        return {
            "conv": jnp.zeros((n, batch, s.conv_width - 1, di), dtype),
            "ssm": jnp.zeros((n, batch, di, s.state), jnp.float32),
        }
    # hybrid: mamba states for all blocks + KV for the shared-attn groups
    nh = di // s.head_dim
    g = _n_groups(cfg)
    return {
        "conv": jnp.zeros((n, batch, s.conv_width - 1, di + 2 * s.state),
                          dtype),
        "ssm": jnp.zeros((n, batch, nh, s.head_dim, s.state), jnp.float32),
        "k": jnp.zeros((g, batch, max_seq, kh, hd), dtype),
        "v": jnp.zeros((g, batch, max_seq, kh, hd), dtype),
    }


def _attn_families_step(params, cfg, h, positions, cache, cache_len, decode):
    blocks = params["blocks"]

    def body(carry, xs):
        h, = carry
        blk, kv = xs
        h, new_kv, _ = _dense_block(blk, h, cfg, positions, kv=kv,
                                    cache_len=cache_len, decode=decode)
        return (h,), new_kv

    if cfg.scan_layers:
        (h,), new_cache = jax.lax.scan(body, (h,), (blocks, cache))
        return h, new_cache
    outs = []
    for i in range(cfg.n_layers):
        blk = jax.tree_util.tree_map(lambda x: x[i], blocks)
        kv = jax.tree_util.tree_map(lambda x: x[i], cache)
        (h,), kv_i = body((h,), (blk, kv))
        outs.append(kv_i)
    return h, jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *outs)


def _ssm_families_step(params, cfg, h, cache, decode):
    blocks = params["blocks"]

    def body(carry, xs):
        h, = carry
        blk, conv, ssm_st = xs
        h, st = _ssm_block(blk, h, cfg,
                           state={"conv": conv, "ssm": ssm_st},
                           decode=decode)
        return (h,), (st["conv"], st["ssm"])

    if cfg.scan_layers:
        (h,), (convs, ssms) = jax.lax.scan(
            body, (h,), (blocks, cache["conv"], cache["ssm"]))
        return h, {"conv": convs, "ssm": ssms}
    convs, ssms = [], []
    for i in range(cfg.n_layers):
        blk = jax.tree_util.tree_map(lambda x: x[i], blocks)
        (h,), (c_i, s_i) = body((h,), (blk, cache["conv"][i],
                                       cache["ssm"][i]))
        convs.append(c_i)
        ssms.append(s_i)
    return h, {"conv": jnp.stack(convs), "ssm": jnp.stack(ssms)}


def _hybrid_step(params, cfg, h, positions, cache, cache_len, decode):
    period = max(cfg.hybrid_period, 1)
    groups = _n_groups(cfg)
    used = groups * period
    blocks = params["blocks"]
    gblocks = jax.tree_util.tree_map(
        lambda x: x[:used].reshape(groups, period, *x.shape[1:]), blocks)
    gconv = cache["conv"][:used].reshape(groups, period,
                                         *cache["conv"].shape[1:])
    gssm = cache["ssm"][:used].reshape(groups, period,
                                       *cache["ssm"].shape[1:])
    shared = params["shared"]

    def group_body(carry, xs):
        h, = carry
        gblk, conv_g, ssm_g, kc, vc = xs

        def m_body(carry2, xs2):
            h2, = carry2
            blk, conv, sst = xs2
            h2, st = _ssm_block(blk, h2, cfg,
                                state={"conv": conv, "ssm": sst},
                                decode=decode)
            return (h2,), (st["conv"], st["ssm"])

        (h,), (conv_n, ssm_n) = jax.lax.scan(m_body, (h,),
                                             (gblk, conv_g, ssm_g))
        x, new_kv = attention_block(
            shared["attn"], rms_norm(h, shared["ln1"], cfg.norm_eps), cfg,
            positions=positions, kv_cache={"k": kc, "v": vc},
            cache_len=cache_len, decode=decode)
        h = h + x
        x = swiglu(rms_norm(h, shared["ln2"], cfg.norm_eps),
                   shared["mlp"]["w_gate"], shared["mlp"]["w_up"],
                   shared["mlp"]["w_down"])
        return (h + x,), (conv_n, ssm_n, new_kv["k"], new_kv["v"])

    if cfg.scan_layers:
        (h,), (conv_n, ssm_n, ks, vs) = jax.lax.scan(
            group_body, (h,), (gblocks, gconv, gssm, cache["k"], cache["v"]))
    else:
        cn, sn, kl, vl = [], [], [], []
        for g in range(groups):
            gb = jax.tree_util.tree_map(lambda x: x[g], gblocks)
            (h,), (c_g, s_g, k_g, v_g) = group_body(
                (h,), (gb, gconv[g], gssm[g], cache["k"][g], cache["v"][g]))
            cn.append(c_g)
            sn.append(s_g)
            kl.append(k_g)
            vl.append(v_g)
        conv_n = jnp.stack(cn)
        ssm_n = jnp.stack(sn)
        ks = jnp.stack(kl)
        vs = jnp.stack(vl)

    new_cache = dict(cache)
    conv_flat = conv_n.reshape(used, *cache["conv"].shape[1:])
    ssm_flat = ssm_n.reshape(used, *cache["ssm"].shape[1:])
    for i in range(used, cfg.n_layers):  # trailing blocks, unrolled
        blk = jax.tree_util.tree_map(lambda x: x[i], blocks)
        h, st = _ssm_block(
            blk, h, cfg,
            state={"conv": cache["conv"][i], "ssm": cache["ssm"][i]},
            decode=decode)
        conv_flat = jnp.concatenate([conv_flat, st["conv"][None]], 0)
        ssm_flat = jnp.concatenate([ssm_flat, st["ssm"][None]], 0)
    new_cache["conv"] = conv_flat
    new_cache["ssm"] = ssm_flat
    new_cache["k"] = ks
    new_cache["v"] = vs
    return h, new_cache


def forward_serve(params, cfg: ArchConfig, batch, cache, cache_len, *,
                  decode: bool):
    """Prefill (decode=False) or single-token decode (decode=True).

    Returns (logits of last position [B, V], new_cache).
    """
    if cfg.modality == "audio_stub":
        h = batch["frame_embeds"]
    elif cfg.modality == "vision_stub" and not decode:
        tok = params["embed"][batch["tokens"]]
        h = jnp.concatenate(
            [batch["patch_embeds"].astype(tok.dtype), tok], axis=1)
    else:
        h = params["embed"][batch["tokens"]]
    b, t, _ = h.shape
    if decode:
        positions = cache_len[:, None]
    else:
        positions = jnp.arange(t)[None, :]

    if cfg.family in ("dense", "vlm", "audio", "moe"):
        h, new_cache = _attn_families_step(params, cfg, h, positions, cache,
                                           cache_len, decode)
    elif cfg.family == "ssm":
        h, new_cache = _ssm_families_step(params, cfg, h, cache, decode)
    else:
        h, new_cache = _hybrid_step(params, cfg, h, positions, cache,
                                    cache_len, decode)

    h = rms_norm(h[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = _lm_logits(params, cfg, h)[:, 0]
    return logits, new_cache
