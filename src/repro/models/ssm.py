"""Selective state-space blocks: Mamba1 (falcon-mamba) and Mamba2/SSD
(zamba2), with chunked scans for training and O(1) recurrent decode.

Training scans are chunked (cfg.ssm_chunk): an outer ``lax.scan`` carries the
[B, ...| state] across chunks (rematerialized), an inner scan runs the
recurrence — bounding backward-pass state materialization to one chunk.
Channel dimensions are embarrassingly parallel and shard over the ``model``
axis; the carried state is tiny (B × d_inner × N), which is what makes
SSM/hybrid archs the best case for the paper's live migration (DESIGN.md §5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .layers import init_dense


# ------------------------------------------------------------------ Mamba1

def init_mamba1_params(key, cfg, dtype):
    s = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    dt_rank = s.dt_rank or int(np.ceil(d / 16))
    ks = jax.random.split(key, 8)
    a_init = jnp.tile(jnp.log(jnp.arange(1, s.state + 1, dtype=jnp.float32)),
                      (di, 1))
    return {
        "in_proj": init_dense(ks[0], (d, 2 * di), dtype=dtype),
        "conv_w": init_dense(ks[1], (s.conv_width, di), dtype=dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_bc": init_dense(ks[2], (di, dt_rank + 2 * s.state), dtype=dtype),
        "dt_proj": init_dense(ks[3], (dt_rank, di), dtype=dtype),
        "dt_bias": jnp.full((di,), -4.6, dtype),   # softplus^-1(0.01)
        "a_log": a_init.astype(jnp.float32),       # [di, N]
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": init_dense(ks[4], (di, d), dtype=dtype),
    }


def _causal_conv(x, w, b, conv_state=None):
    """x: [B, T, C]; w: [W, C] depthwise. Returns (y, new_state[W-1])."""
    wdt = x.dtype
    width = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), wdt)
    else:
        pad = conv_state.astype(wdt)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(width))
    new_state = xp[:, -(width - 1):] if width > 1 else None
    return y + b, new_state


def mamba1_scan(dt, a_log, bmat, cmat, x, h0, chunk: int):
    """Selective scan.

    dt: [B,T,C] (softplus'd), bmat/cmat: [B,T,N], x: [B,T,C], h0: [B,C,N].
    Returns (y [B,T,C], hT).
    """
    bsz, t, c = x.shape
    n = bmat.shape[-1]
    a = -jnp.exp(a_log)                               # [C, N]

    chunk = min(chunk, t)
    assert t % chunk == 0

    def inner(h, xs):
        dt_t, b_t, c_t, x_t = xs                      # [B,C],[B,N],[B,N],[B,C]
        da = jnp.exp(dt_t[..., None] * a)             # [B,C,N]
        h = h * da + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bcn,bn->bc", h, c_t)
        return h, y

    def outer(h, xs):
        dt_c, b_c, c_c, x_c = xs                      # [chunk, B, ...]
        h, y = jax.lax.scan(inner, h, (dt_c, b_c, c_c, x_c))
        return h, y

    tmaj = lambda z: jnp.moveaxis(z, 1, 0).reshape(
        t // chunk, chunk, *z.shape[0:1], *z.shape[2:])
    outer = jax.checkpoint(outer)
    hT, y = jax.lax.scan(outer, h0, (tmaj(dt), tmaj(bmat), tmaj(cmat),
                                     tmaj(x)))
    y = jnp.moveaxis(y.reshape(t, bsz, c), 0, 1)
    return y, hT


def mamba1_block(params, x, cfg, *, state=None, decode=False):
    """x: [B, T, D]. state: dict(conv, ssm) or None. -> (out, new_state)."""
    s = cfg.ssm
    b, t, d = x.shape
    di = s.expand * d
    dt_rank = s.dt_rank or int(np.ceil(d / 16))

    xz = x @ params["in_proj"]
    xs, z = jnp.split(xz, 2, axis=-1)                 # [B,T,di]
    conv_state = state["conv"] if state is not None else None
    xs, new_conv = _causal_conv(xs, params["conv_w"], params["conv_b"],
                                conv_state)
    xs = jax.nn.silu(xs)

    proj = xs @ params["x_bc"]                        # [B,T,r+2N]
    dt_in, bmat, cmat = jnp.split(
        proj, [dt_rank, dt_rank + s.state], axis=-1)
    dt = jax.nn.softplus(dt_in @ params["dt_proj"] +
                         params["dt_bias"]).astype(jnp.float32)
    bmat = bmat.astype(jnp.float32)
    cmat = cmat.astype(jnp.float32)
    xf = xs.astype(jnp.float32)

    h0 = (state["ssm"] if state is not None
          else jnp.zeros((b, di, s.state), jnp.float32))
    if decode:
        a = -jnp.exp(params["a_log"])
        da = jnp.exp(dt[:, 0, :, None] * a)
        h = h0 * da + (dt[:, 0] * xf[:, 0])[..., None] * bmat[:, 0][:, None]
        y = jnp.einsum("bcn,bn->bc", h, cmat[:, 0])[:, None]
        hT = h
    else:
        y, hT = mamba1_scan(dt, params["a_log"], bmat, cmat, xf, h0,
                            cfg.ssm_chunk)
    y = y + params["d_skip"] * xf
    out = (y.astype(x.dtype) * jax.nn.silu(z)) @ params["out_proj"]
    new_state = {"conv": new_conv, "ssm": hT}
    return out, new_state


# ------------------------------------------------------------- Mamba2 / SSD

def init_mamba2_params(key, cfg, dtype):
    s = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    nh = di // s.head_dim
    ks = jax.random.split(key, 6)
    return {
        "in_proj": init_dense(ks[0], (d, 2 * di + 2 * s.state + nh),
                              dtype=dtype),
        "conv_w": init_dense(ks[1], (s.conv_width, di + 2 * s.state),
                             dtype=dtype),
        "conv_b": jnp.zeros((di + 2 * s.state,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "dt_bias": jnp.full((nh,), -4.6, jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "norm_scale": jnp.ones((di,), dtype),
        "out_proj": init_dense(ks[2], (di, d), dtype=dtype),
    }


def mamba2_scan(dt, a_log, bmat, cmat, x, h0, chunk: int):
    """SSD recurrence with scalar-per-head decay.

    dt: [B,T,H] softplus'd; bmat/cmat: [B,T,N]; x: [B,T,H,P]; h0: [B,H,P,N].
    """
    bsz, t, nh, p = x.shape
    a = -jnp.exp(a_log)                               # [H]

    chunk = min(chunk, t)
    assert t % chunk == 0

    def inner(h, xs):
        dt_t, b_t, c_t, x_t = xs                      # [B,H],[B,N],[B,N],[B,H,P]
        da = jnp.exp(dt_t * a)[..., None, None]       # [B,H,1,1]
        upd = jnp.einsum("bhp,bn->bhpn", x_t * dt_t[..., None], b_t)
        h = h * da + upd
        y = jnp.einsum("bhpn,bn->bhp", h, c_t)
        return h, y

    def outer(h, xs):
        h, y = jax.lax.scan(inner, h, xs)
        return h, y

    tm = lambda z: jnp.moveaxis(z, 1, 0).reshape(
        t // chunk, chunk, *z.shape[0:1], *z.shape[2:])
    outer = jax.checkpoint(outer)
    hT, y = jax.lax.scan(outer, h0, (tm(dt), tm(bmat), tm(cmat), tm(x)))
    y = jnp.moveaxis(y.reshape(t, bsz, nh, p), 0, 1)
    return y, hT


def mamba2_block(params, x, cfg, *, state=None, decode=False):
    s = cfg.ssm
    b, t, d = x.shape
    di = s.expand * d
    nh = di // s.head_dim

    proj = x @ params["in_proj"]
    z, xbc, dt_in = jnp.split(proj, [di, 2 * di + 2 * s.state], axis=-1)
    conv_state = state["conv"] if state is not None else None
    xbc, new_conv = _causal_conv(xbc, params["conv_w"], params["conv_b"],
                                 conv_state)
    xbc = jax.nn.silu(xbc)
    xs, bmat, cmat = jnp.split(xbc, [di, di + s.state], axis=-1)
    dt = jax.nn.softplus(dt_in.astype(jnp.float32) + params["dt_bias"])
    xh = xs.reshape(b, t, nh, s.head_dim).astype(jnp.float32)
    bmat = bmat.astype(jnp.float32)
    cmat = cmat.astype(jnp.float32)

    h0 = (state["ssm"] if state is not None
          else jnp.zeros((b, nh, s.head_dim, s.state), jnp.float32))
    if decode:
        a = -jnp.exp(params["a_log"])
        da = jnp.exp(dt[:, 0] * a)[..., None, None]
        upd = jnp.einsum("bhp,bn->bhpn", xh[:, 0] * dt[:, 0, :, None],
                         bmat[:, 0])
        h = h0 * da + upd
        y = jnp.einsum("bhpn,bn->bhp", h, cmat[:, 0])[:, None]
        hT = h
    else:
        y, hT = mamba2_scan(dt, params["a_log"], bmat, cmat, xh, h0,
                            cfg.ssm_chunk)
    y = y + params["d_skip"][:, None] * xh[:, :t]
    y = y.reshape(b, t, di).astype(x.dtype)
    # gated RMSNorm (Mamba2)
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6)).astype(x.dtype)
    y = y * params["norm_scale"]
    out = y @ params["out_proj"]
    return out, {"conv": new_conv, "ssm": hT}
