"""Shared neural building blocks (pure functions over param dicts)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(x, scale, eps: float):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dt)


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, D]; positions: [..., S] int32."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta), jnp.float32)
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, D/2]
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down):
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


def init_dense(key, shape, scale=None, dtype=jnp.float32):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def cross_entropy(logits, targets, *, z_loss: float = 1e-4):
    """Token CE with optional z-loss; logits fp32 [..., V], targets int."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None],
                               axis=-1)[..., 0]
    loss = lse - gold
    if z_loss:
        loss = loss + z_loss * jnp.square(lse)
    return loss
