"""Architecture configuration schema for every supported model family."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    version: int            # 1 = Mamba1 (falcon-mamba), 2 = Mamba2/SSD
    state: int
    expand: int = 2         # d_inner = expand * d_model
    conv_width: int = 4
    head_dim: int = 64      # Mamba2 only
    dt_rank: int = 0        # 0 => ceil(d_model/16) (Mamba1 default)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None    # default d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 1e6
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    moe: Optional[MoECfg] = None
    ssm: Optional[SSMCfg] = None
    hybrid_period: int = 0            # zamba: shared attn block every k layers
    modality: str = "text"            # text | audio_stub | vision_stub
    # dry-run / training knobs (overridable per shape cell)
    remat: bool = True
    attn_q_chunk: int = 512
    loss_chunk: int = 2048
    scan_layers: bool = True
    ssm_chunk: int = 256
    seq_parallel: bool = True    # shard inter-layer hidden over model axis
    kv_quant: bool = False       # int8 KV cache (decode): per-token/head scales

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k cell (SSM state or hybrid)."""
        return self.family in ("ssm", "hybrid")

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str               # train_4k | prefill_32k | decode_32k | long_500k
    kind: str               # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", "train", 4_096, 256),
    ShapeCell("prefill_32k", "prefill", 32_768, 32),
    ShapeCell("decode_32k", "decode", 32_768, 128),
    ShapeCell("long_500k", "decode", 524_288, 1),
)


def shape_by_name(name: str) -> ShapeCell:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)
