"""Mixture-of-Experts FFN with top-k routing and capacity-bounded dispatch.

Dispatch is *per batch row*: each row of B independently sorts its T·k
routed slots by expert and scatters into an [B, E, C, D] buffer with
C = T·k·cf/E. Under the production mesh the buffer shards as
P(dp, "model", None, None) — batch rows over data, experts over model (EP) —
so each device holds only its experts' tokens and XLA lowers the token
redistribution to the EP collective. DESIGN.md §3.2 maps this onto the
paper's delegation all_to_all: the expert-placement "registry" routes each
token to the shard owning its expert range.

Aux losses: load-balancing (Switch-style) + router z-loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.runtime.actctx import constrain

from .layers import init_dense


def init_moe_params(key, cfg, dtype):
    m = cfg.moe
    d, f, e = cfg.d_model, m.d_ff_expert, m.n_experts
    ks = jax.random.split(key, 4)
    return {
        "router": init_dense(ks[0], (d, e), dtype=jnp.float32),
        "w_gate": init_dense(ks[1], (e, d, f), dtype=dtype),
        "w_up": init_dense(ks[2], (e, d, f), dtype=dtype),
        "w_down": init_dense(ks[3], (e, f, d), dtype=dtype),
    }


def moe_ffn(params, x, cfg):
    """x: [B, T, D] -> (out [B, T, D], aux dict)."""
    m = cfg.moe
    b, t, d = x.shape
    e, k = m.n_experts, m.top_k

    logits = x.astype(jnp.float32) @ params["router"]        # [B, T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                   # [B, T, k]
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # ---- aux losses (global)
    me = jnp.mean(probs, axis=(0, 1))                        # [E]
    ce = jnp.mean(jnp.sum(jax.nn.one_hot(top_e, e, dtype=jnp.float32),
                          axis=2), axis=(0, 1))
    aux_loss = e * jnp.sum(me * ce) / k
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))

    # ---- capacity-bounded sort dispatch, per batch row
    cap = max(int(t * k * m.capacity_factor / e), 8)
    flat_e = top_e.reshape(b, t * k)                         # [B, T*k]
    order = jnp.argsort(flat_e, axis=1)                      # stable
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    tok_of = order // k                                      # source token
    pos_in_e = jnp.arange(t * k)[None, :] - \
        jax.vmap(lambda se: jnp.searchsorted(se, jnp.arange(e)))(
            sorted_e)[jnp.arange(b)[:, None], sorted_e]
    keep = pos_in_e < cap
    slot = jnp.clip(sorted_e * cap + pos_in_e, 0, e * cap - 1)
    slot = jnp.where(keep, slot, e * cap - 1)

    gathered = jnp.take_along_axis(x, tok_of[..., None], axis=1)  # [B,T*k,D]
    gathered = jnp.where(keep[..., None], gathered, 0).astype(x.dtype)
    dispatched = jnp.zeros((b, e * cap, d), x.dtype)
    dispatched = jax.vmap(
        lambda buf, sl, g: buf.at[sl].add(g, mode="drop"))(
            dispatched, slot, gathered)
    dispatched = dispatched.reshape(b, e, cap, d)
    # §Perf C1: pin the scatter output to the *data-only* sharding before
    # re-sharding experts onto the model axis. Without the boundary, XLA
    # propagates the model sharding backwards into the scatter and
    # all-gathers the full token buffer on every model rank (~16x the
    # traffic of the explicit reshard below, which lowers to all-to-all).
    dispatched = constrain(dispatched, "moe_predispatch")
    dispatched = constrain(dispatched, "moe_dispatch")

    # ---- expert FFN (einsum over per-expert weights; EP via sharding)
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", dispatched,
                               params["w_gate"]))
    h = h * jnp.einsum("becd,edf->becf", dispatched, params["w_up"])
    out_e = jnp.einsum("becf,efd->becd", h, params["w_down"])
    out_e = constrain(out_e, "moe_dispatch")
    # reshard back to data-only before the token-order combine gather
    out_e = constrain(out_e, "moe_predispatch")
    out_flat = out_e.reshape(b, e * cap, d)

    # ---- combine: weighted gather back to token order
    back = jnp.take_along_axis(out_flat, slot[..., None], axis=1)
    w = jnp.take_along_axis(top_p.reshape(b, t * k), order, axis=1)
    back = back * jnp.where(keep, w, 0.0)[..., None].astype(x.dtype)
    out = jnp.zeros((b, t, d), x.dtype)
    out = jax.vmap(lambda o, idx, v: o.at[idx].add(v))(out, tok_of, back)
    return out, {"moe_aux": aux_loss, "moe_z": z_loss}
