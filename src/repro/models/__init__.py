from . import config, layers, attention, moe, ssm, transformer  # noqa: F401
