"""Int8 gradient compression with error feedback.

For cross-pod (DCN-ish) gradient reduction: quantize per-tensor to int8 with
a shared fp32 scale before the all-reduce, keep the quantization residual
locally and fold it into the next step's gradient (error feedback), which
keeps SGD convergence unbiased in expectation. 4x less inter-pod traffic on
the collective-bound term of the roofline.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def int8_compress(x, residual=None):
    """Returns (q_int8, scale, new_residual)."""
    xf = x.astype(jnp.float32)
    if residual is not None:
        xf = xf + residual
    amax = jnp.max(jnp.abs(xf))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, xf - deq


def int8_decompress(q, scale):
    return q.astype(jnp.float32) * scale


def compress_tree(grads, residuals=None):
    if residuals is None:
        residuals = jax.tree_util.tree_map(lambda x: None, grads,
                                           is_leaf=lambda _: True)
    qs, scales, res = {}, {}, {}
    flat, treedef = jax.tree_util.tree_flatten(grads)
    rflat = jax.tree_util.tree_leaves(residuals) if residuals else \
        [None] * len(flat)
    out = [int8_compress(g, r) for g, r in zip(flat, rflat)]
    q = treedef.unflatten([o[0] for o in out])
    s = treedef.unflatten([o[1] for o in out])
    r = treedef.unflatten([o[2] for o in out])
    return q, s, r


def decompress_tree(q, s):
    return jax.tree_util.tree_map(int8_decompress, q, s)
