from .adamw import (AdamWConfig, adamw_init, adamw_update,  # noqa: F401
                    cosine_schedule, global_norm)
from .compress import int8_compress, int8_decompress  # noqa: F401
