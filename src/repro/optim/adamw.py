"""AdamW with decoupled weight decay, cosine schedule, global-norm clipping
and gradient accumulation — the training substrate for every arch."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    accum_steps: int = 1


def cosine_schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * \
        (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def adamw_init(params) -> Dict[str, Any]:
    zeros = lambda p: jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32), p)
    return {"mu": zeros(params), "nu": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(cfg: AdamWConfig, params, grads, state):
    """One optimizer step. Gradients are expected pre-averaged over DP."""
    step = state["step"] + 1
    lr = cosine_schedule(cfg, step)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        mu_hat = mu / (1 - cfg.b1 ** step.astype(jnp.float32))
        nu_hat = nu / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * (delta + decay)
        return new_p.astype(p.dtype), mu, nu

    out = jax.tree_util.tree_map(upd, params, grads, state["mu"],
                                 state["nu"])
    new_params = jax.tree_util.tree_map(lambda t: t[0], out,
                                        is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree_util.tree_map(lambda t: t[1], out,
                                    is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree_util.tree_map(lambda t: t[2], out,
                                    is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"mu": new_mu, "nu": new_nu, "step": step}
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, new_state, metrics
