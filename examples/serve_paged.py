"""Serving example: batched generation over a DiLi-indexed paged KV cache,
with a live Split/Move of the page index between decode steps.

This is the paper's headline capability applied to LM serving: the
(sequence, page) -> slot index is re-partitioned and migrated *while
decoding continues*, and the outputs are bit-identical to an undisturbed
run (asserted below).

Run:  PYTHONPATH=src python examples/serve_paged.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import transformer as T
from repro.serving.engine import Request, ServingEngine

cfg = get_smoke_config("qwen2.5-3b")
params = T.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
rng = np.random.default_rng(7)
prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32)
           for n in (12, 9, 15)]
N_NEW = 8


def generate(rebalance: bool):
    eng = ServingEngine(cfg, params, page_size=8, num_pages=128,
                        dili_shards=2)
    reqs = [Request(seq_id=i, prompt=p, max_new=N_NEW)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.admit(r)
    for step in range(N_NEW):
        if rebalance and step == 2:
            subs = [e for e in eng.kv.backend.sublists(0) if e["owner"] == 0]
            if subs:
                eng.kv.backend.move(0, subs[0]["keymax"], 1)
                print("  [step 2] issued Move of the page-index sublist "
                      "shard0 -> shard1")
        eng.step(rebalance=rebalance)
    owners = sorted({e["owner"] for s in range(2)
                     for e in eng.kv.backend.sublists(s)})
    return [r.out for r in reqs], owners


print("run A: undisturbed decode")
out_a, _ = generate(rebalance=False)
print("run B: decode with live page-index migration")
out_b, owners = generate(rebalance=True)

for i, (a, b) in enumerate(zip(out_a, out_b)):
    status = "OK" if a == b else "MISMATCH"
    print(f"seq {i}: {a[:N_NEW]}  [{status}]")
assert out_a == out_b, "live migration changed the outputs!"
print(f"page-index owners after migration: shards {owners}")
print("outputs identical under live Split/Move — the paper's asynchronous "
      "re-partitioning, applied to KV-cache serving. OK")
