"""End-to-end driver: train a ~100M-param dense LM for a few hundred steps
through the full production stack (config -> data -> jitted step ->
checkpointing -> resume), on whatever devices this host has.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.synthetic import make_train_batch
from repro.models.config import ShapeCell
from repro.optim import AdamWConfig
from repro.runtime.train import Trainer, TrainerConfig

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
args = ap.parse_args()

# ~100M params: qwen2-0.5b scaled down in depth, full width
cfg = get_config("qwen2-0.5b").replace(n_layers=4, vocab=32768,
                                       loss_chunk=128)
cell = ShapeCell("example", "train", 256, 8)
opt = AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)

n_params = sum(x.size for x in jax.tree_util.tree_leaves(
    jax.eval_shape(lambda: __import__(
        "repro.models.transformer", fromlist=["T"]).init_params(
            cfg, jax.random.PRNGKey(0), dtype=jnp.float32))))
print(f"model: {cfg.name}-deep4  params={n_params/1e6:.1f}M  "
      f"tokens/step={cell.global_batch * cell.seq_len}")

tr = Trainer(cfg, cell, opt,
             TrainerConfig(total_steps=args.steps, ckpt_every=100,
                           ckpt_dir=args.ckpt_dir, log_every=20),
             make_batch=lambda s: make_train_batch(cfg, cell, seed=0, step=s,
                                                   dtype=jnp.float32))
if tr.maybe_resume():
    print(f"resumed from checkpoint at step {tr.start_step}")
t0 = time.time()
out = tr.run()
dt = time.time() - t0
for m in out["metrics"]:
    print(f"step {m['step']:4d}  loss {m['loss']:.4f}  "
          f"grad_norm {m['grad_norm']:.3f}  lr {m['lr']:.2e}")
steps_done = out["final_step"] - tr.start_step
if steps_done:
    tok_s = steps_done * cell.global_batch * cell.seq_len / dt
    print(f"throughput: {tok_s:,.0f} tokens/s over {steps_done} steps")
first, last = out["metrics"][0]["loss"], out["metrics"][-1]["loss"]
assert last < first, "loss did not decrease"
print(f"loss {first:.3f} -> {last:.3f}  OK")
