"""Quickstart: the DiLi distributed list as a library.

Builds a 4-server cluster behind the futures-based ``DiLiClient``, loads
keys, lets the load balancer Split/Move sublists while a mixed client
workload runs, and verifies linearizability against the sequential oracle
— the paper's core claims, in ~60 lines.

The client routes each op to its key's likely owner via a client-side
registry cache (refreshed from wrong-route replies), paces admission so
overload queues client-side, and drives the balance policy from its pump
loop. Swap ``LocalBackend`` for ``ShardMapBackend`` to run the identical
workload on an SPMD device mesh.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.api import DiLiClient, LocalBackend
from repro.core.balancer import Balancer
from repro.core.oracle import OracleList
from repro.core.types import DiLiConfig, OP_FIND, OP_INSERT, OP_REMOVE

cfg = DiLiConfig(num_shards=4, pool_capacity=8192, max_sublists=64,
                 max_ctrs=64, max_scan=8192, batch_size=32,
                 mailbox_cap=256, split_threshold=50, move_batch=16)
backend = LocalBackend(cfg)
client = DiLiClient(backend, balance=Balancer(backend))
oracle = OracleList()
rng = np.random.default_rng(0)

# ---- load phase: 800 keys (the client picks the serving shards)
keys = rng.permutation(np.arange(1, 5000))[:800].tolist()
load = client.insert_batch(keys)
oracle.apply_batch([OP_INSERT] * len(keys), keys)
client.drain(run_balance=True)

# ---- mixed phase: ops race the balancer's Split/Move churn
checks = []
for round_i in range(20):
    kinds = rng.choice([OP_FIND, OP_INSERT, OP_REMOVE], 32).tolist()
    ks = rng.integers(1, 5000, 32).tolist()
    checks.append((client.submit(kinds, ks), oracle.apply_batch(kinds, ks)))
    client.pump()      # one round; runs the balance policy at its cadence
client.settle()        # drain futures, run balance to a fixed point

# ---- verify
wrong = sum(f.result() != exp
            for batch, exps in checks for f, exp in zip(batch, exps))
assert wrong == 0, f"{wrong} ops violated linearizability"
assert all(load.results()), "load-phase inserts must all succeed"
assert client.all_keys() == sorted(oracle.snapshot())
loads = [sum(e["size"] or 0 for e in backend.sublists(s)
             if e["owner"] == s) for s in range(4)]
print(f"ops linearized correctly : {sum(len(b) for b, _ in checks) + len(keys)}")
print(f"final key count          : {len(oracle.snapshot())}")
print(f"keys per server          : {loads}")
print(f"sublists per server      : "
      f"{[sum(1 for e in backend.sublists(s) if e['owner'] == s) for s in range(4)]}")
print(f"max delegation hops seen : {client.stats['max_hops']}")
print(f"stale-route corrections  : {client.wrong_routes}")
print("OK")
