"""Quickstart: the DiLi distributed list as a library.

Builds a 4-server cluster, loads keys, lets the load balancer Split/Move
sublists while a mixed client workload runs, and verifies linearizability
against the sequential oracle — the paper's core claims, in ~60 lines.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.balancer import Balancer
from repro.core.oracle import OracleList
from repro.core.sim import Cluster
from repro.core.types import DiLiConfig, OP_FIND, OP_INSERT, OP_REMOVE

cfg = DiLiConfig(num_shards=4, pool_capacity=8192, max_sublists=64,
                 max_ctrs=64, max_scan=8192, batch_size=32,
                 mailbox_cap=256, split_threshold=50, move_batch=16)
cluster = Cluster(cfg)
balancer = Balancer(cluster)
oracle = OracleList()
rng = np.random.default_rng(0)

# ---- load phase: 800 keys through server 0
keys = rng.permutation(np.arange(1, 5000))[:800]
ids = cluster.submit(0, [OP_INSERT] * len(keys), keys.tolist())
oracle.apply_batch([OP_INSERT] * len(keys), keys.tolist())
cluster.run_until_quiet(400)

# ---- mixed phase: clients hit all 4 servers while the balancer works
expected = {}
for round_i in range(20):
    for server in range(4):
        kinds = rng.choice([OP_FIND, OP_INSERT, OP_REMOVE], 8).tolist()
        ks = rng.integers(1, 5000, 8).tolist()
        for i, exp in zip(cluster.submit(server, kinds, ks),
                          oracle.apply_batch(kinds, ks)):
            expected[i] = exp
    cluster.step()
    balancer.step()
cluster.run_until_quiet(600)
for _ in range(60):       # let splits/moves settle
    if not any(balancer.step().values()):
        break
    cluster.run_until_quiet(600)

# ---- verify
wrong = sum(bool(cluster.results[i]) != exp for i, exp in expected.items())
assert wrong == 0, f"{wrong} ops violated linearizability"
assert cluster.all_keys() == sorted(oracle.snapshot())
loads = [sum(e["size"] or 0 for e in cluster.sublists(s)
             if e["owner"] == s) for s in range(4)]
print(f"ops linearized correctly : {len(expected) + len(keys)}")
print(f"final key count          : {len(oracle.snapshot())}")
print(f"keys per server          : {loads}")
print(f"sublists per server      : "
      f"{[sum(1 for e in cluster.sublists(s) if e['owner'] == s) for s in range(4)]}")
print(f"max delegation hops seen : {cluster.stats['max_hops']}")
print("OK")
