#!/usr/bin/env python
"""Perf-regression guard over BENCH_*.json artifacts.

Reads the guard table from benchmarks/baselines.json and checks each
bound against the artifact directory. Two profiles:

  full  — the checked-in full-size artifacts at the repo root. Guards
          the headline ratios (replication speedup at high skew, no
          uniform-skew regression, fast-path and baseline-structure
          speedups, batching, nemesis degradation floor). Ratios come
          from same-process on/off runs, so wall-clock noise cancels.
  tiny  — the CI `--tiny` smoke artifacts. Machine-independent signals
          only: correctness flags, deterministic round counts, and hit
          counters. Wall-clock throughput on shared CI runners is too
          noisy to bound.

Usage:
  python scripts/perf_guard.py                       # full, repo root
  python scripts/perf_guard.py --profile tiny --dir .  # CI smoke

Exit status is nonzero if any bound is violated or any guarded
artifact/metric is missing (a silently vanished benchmark must fail,
not pass).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def load_rows(art_dir: Path, bench: str):
    path = art_dir / f"BENCH_{bench}.json"
    if not path.exists():
        return None
    data = json.loads(path.read_text())
    return {r["metric"]: r["value"] for r in data["rows"]}


def check(guard, rows):
    """Return None if the bound holds, else a human-readable violation."""
    metric = guard["metric"]
    if metric not in rows:
        return f"metric {metric!r} missing from artifact"
    val = rows[metric]
    if "min" in guard and val < guard["min"]:
        return f"{metric} = {val} < floor {guard['min']}"
    if "max" in guard and val > guard["max"]:
        return f"{metric} = {val} > ceiling {guard['max']}"
    if "max_metric" in guard:
        other = guard["max_metric"]
        if other not in rows:
            return f"metric {other!r} missing from artifact"
        if val > rows[other]:
            return f"{metric} = {val} > {other} = {rows[other]}"
    return None


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--dir", default=str(REPO),
                    help="directory holding BENCH_*.json (default: repo root)")
    ap.add_argument("--profile", choices=("full", "tiny"), default="full")
    ap.add_argument("--baselines",
                    default=str(REPO / "benchmarks" / "baselines.json"))
    args = ap.parse_args(argv)

    guards = json.loads(Path(args.baselines).read_text())[args.profile]
    art_dir = Path(args.dir)

    failures = []
    cache = {}
    for g in guards:
        bench = g["bench"]
        if bench not in cache:
            cache[bench] = load_rows(art_dir, bench)
        rows = cache[bench]
        if rows is None:
            failures.append(f"[{bench}] artifact BENCH_{bench}.json missing "
                            f"from {art_dir}")
            continue
        msg = check(g, rows)
        tag = f"[{bench}] {g['metric']}"
        if msg is None:
            print(f"ok    {tag} = {rows[g['metric']]}")
        else:
            failures.append(f"{tag}: {msg}")
            print(f"FAIL  {tag}: {msg}")

    if failures:
        print(f"\nperf_guard ({args.profile}): "
              f"{len(failures)} violation(s)", file=sys.stderr)
        return 1
    print(f"\nperf_guard ({args.profile}): all {len(guards)} bounds hold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
