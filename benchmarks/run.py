"""Benchmark harness — one function per paper table/figure.

  fig3a  single-machine throughput: DiLi vs Harris vs lock-free skip list,
         YCSB zipfian workloads at 10/50/90% reads (paper Fig. 3a)
  fig3b  distributed scalability: DiLi throughput at 1/2/4/8 servers
         (paper Fig. 3b)
  bgops  Split and Move latency under insert load (paper §C / Fig. 4)
  kernels hybrid_search + paged_attention micro-bench vs jnp reference
  lmstep small-LM train-step walltime (framework overhead sanity)
  zipf   skewed-read throughput vs YCSB θ, hot-sublist read replication
         on vs off (DESIGN.md §15)
  nemesis throughput under lossy/duplicating/reordering channels via the
         reliable transport, vs the direct-routing baseline (DESIGN.md §11)
  recovery crash-restart cost vs snapshot cadence: WAL replay length,
         restart-round wall time, client latency through the crash window
         (DESIGN.md §14)
  serving decode throughput during live page-table migration:
         refresh_seq-via-RANGE vs the full-rescan fallback, with a
         deterministic token-equality check (DESIGN.md §16); zipf also
         gains a YCSB-E scan-mix row

Prints ``name,metric,value`` CSV rows; ``python -m benchmarks.run [names]``.
Each benchmark additionally persists a ``BENCH_<name>.json`` artifact (rows
+ run metadata) next to the CSV prints — into ``$BENCH_OUT_DIR`` (default:
current directory) — so the perf trajectory survives the run. ``--tiny``
shrinks workloads for the CI smoke job.

Scale note: sizes are CPU-feasible fractions of the paper's 1M-key/2M-op
runs; the *comparisons* (relative throughput, latency orders) are the
reproduction target. Every workload generator matches §7.2 (zipfian keys,
write split evenly between insert/remove, load phase first).
"""
from __future__ import annotations

import json
import os
import platform
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import DiLiClient, LocalBackend
from repro.core import skiplist as SL
from repro.core.balancer import Balancer
from repro.core.types import DiLiConfig, OP_FIND, OP_INSERT, OP_REMOVE
from repro.data.ycsb import load_phase, mixed_phase

ROWS = []


def emit(name, metric, value):
    ROWS.append((name, metric, value))
    print(f"{name},{metric},{value}", flush=True)


def write_artifact(name, rows, duration_s, params=None):
    """Persist one benchmark's rows + metadata as ``BENCH_<name>.json``."""
    payload = {
        "bench": name,
        "rows": [{"name": n, "metric": m, "value": v} for n, m, v in rows],
        "meta": {
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            "python": platform.python_version(),
            "duration_s": round(duration_s, 1),
            "params": params or {},
        },
    }
    path = os.path.join(os.environ.get("BENCH_OUT_DIR", "."),
                        f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    print(f"# wrote {path}", flush=True)


# ------------------------------------------------------------------ helpers

def _drive_client(client, kinds, keys, batch):
    """Feed ops batch-per-round through the client; returns wall seconds.

    The client routes each op via its registry cache, paces admission
    against ``mailbox_cap`` (overload queues client-side instead of
    raising ``OutboxOverflow``), and runs its balance policy from the pump
    loop at the configured cadence. This path times the *public client*
    (futures + routing + pacing) — used for the ``client_*`` rows.
    """
    n = len(kinds)
    per_round = batch * client.backend.n
    t0 = time.perf_counter()
    i = 0
    while i < n:
        j = min(i + per_round, n)
        client.submit(kinds[i:j].tolist(), keys[i:j].tolist())
        i = j
        client.pump()
    client.drain(4000)
    return time.perf_counter() - t0


def _drive_backend(backend, kinds, keys, batch, *, balancer=None,
                   max_drain=4000):
    """Feed ops round-robin at the raw ``Backend`` surface (no futures);
    returns wall seconds. This is the measurement path for the
    paper-figure rows: it times the round engine itself, keeping the
    metric lineage of earlier artifacts (the Python client machinery is
    measured separately by the ``client_*`` rows). Runs unchanged against
    ``LocalBackend`` or ``ShardMapBackend``.
    """
    n = len(kinds)
    pending = 0
    t0 = time.perf_counter()
    i = 0
    r = 0
    mb = getattr(backend, "membership", None)
    while i < n:
        for s in (mb.routable if mb is not None else range(backend.n)):
            j = min(i + batch, n)
            if i < j:
                backend.submit(s, kinds[i:j].tolist(), keys[i:j].tolist())
                pending += j - i
                i = j
        pending -= len(backend.step())
        if balancer is not None and r % 4 == 3:
            balancer.step()
        r += 1
    for _ in range(max_drain):
        if pending == 0 and backend.quiescent():
            break
        pending -= len(backend.step())
    else:
        raise RuntimeError(f"backend did not drain: pending={pending}")
    return time.perf_counter() - t0


def _bench_cfg(n_shards, *, batch=64, fastpath=True, block_probe=False):
    return DiLiConfig(num_shards=n_shards, pool_capacity=1 << 15,
                      max_sublists=256, max_ctrs=256, max_scan=1 << 15,
                      batch_size=batch, mailbox_cap=512,
                      split_threshold=125, move_batch=32,
                      find_fastpath=fastpath, mut_fastpath=fastpath,
                      block_probe=block_probe)


def _make_client(n_shards, *, split: bool, batch=64, fastpath=True,
                 route_cache=True):
    backend = LocalBackend(_bench_cfg(n_shards, batch=batch,
                                      fastpath=fastpath))
    bal = Balancer(backend) if split else None
    return DiLiClient(backend, balance=bal, route_cache=route_cache)


def _settle(backend, balancer, *, max_passes=200):
    for _ in range(max_passes):
        if not any(balancer.step().values()):
            return
        _drive_backend(backend, np.zeros(0, np.int64), np.zeros(0, np.int64),
                       64)


def _dili_throughput(n_shards, kinds, keys, *, split: bool,
                     load_kinds, load_keys, batch=64, fastpath=True,
                     block_probe=False):
    """``fastpath`` toggles BOTH batched pre-passes (find §4 + mutation
    §4b); False is the serial-only scan baseline. ``block_probe`` layers
    the packed-block kernel probe (DESIGN.md §12) over the pre-passes."""
    backend = LocalBackend(_bench_cfg(n_shards, batch=batch,
                                      fastpath=fastpath,
                                      block_probe=block_probe))
    bal = Balancer(backend) if split else None
    # load phase (timed separately from the measured mixed phase)
    _drive_backend(backend, load_kinds, load_keys, batch, balancer=bal)
    if bal is not None:
        _settle(backend, bal)
    dt = _drive_backend(backend, kinds, keys, batch, balancer=bal)
    return len(kinds) / dt, backend


# ------------------------------------------------------------------- fig3a

def fig3a(n_load=2000, n_ops=4000, key_space=8000):
    """Single-machine: DiLi (split on) vs Harris (split off) vs skip list.

    DiLi runs twice per mix — both batched pre-passes on (find §4 +
    mutation §4b, the default runtime) vs. off (serial scan only) — so
    their combined contribution lands in the bench trajectory as
    ``fastpath_over_scan_r*``. The write-side pre-pass is what moves the
    10%-read row (90% mutations).
    """
    load_kinds, load_keys = load_phase(n_load, key_space, seed=1)
    for read_pct in (10, 50, 90):
        kinds, keys = mixed_phase(n_ops, key_space, read_pct / 100, seed=2)

        thr_dili, cl = _dili_throughput(1, kinds, keys, split=True,
                                        load_kinds=load_kinds,
                                        load_keys=load_keys)
        n_sub = sum(1 for e in cl.sublists(0) if e["owner"] == 0)
        emit("fig3a", f"dili_r{read_pct}_ops_per_s", round(thr_dili))
        emit("fig3a", f"dili_r{read_pct}_sublists", n_sub)
        emit("fig3a", f"dili_r{read_pct}_fast_hits", cl.stats["fast_hits"])
        emit("fig3a", f"dili_r{read_pct}_mut_hits", cl.stats["mut_hits"])

        thr_scan, _ = _dili_throughput(1, kinds, keys, split=True,
                                       load_kinds=load_kinds,
                                       load_keys=load_keys, fastpath=False)
        emit("fig3a", f"dili_scan_r{read_pct}_ops_per_s", round(thr_scan))
        emit("fig3a", f"fastpath_over_scan_r{read_pct}",
             round(thr_dili / thr_scan, 2))

        # packed-block kernel probe over the same mix (DESIGN.md §12):
        # block-probe vs pointer-walk probe_batch vs serial scan, plus
        # the fraction of pre-pass answers the kernel served
        thr_blk, cb = _dili_throughput(1, kinds, keys, split=True,
                                       load_kinds=load_kinds,
                                       load_keys=load_keys,
                                       block_probe=True)
        emit("fig3a", f"dili_blk_r{read_pct}_ops_per_s", round(thr_blk))
        emit("fig3a", f"dili_blk_r{read_pct}_blk_hits", cb.stats["blk_hits"])
        emit("fig3a", f"dili_blk_r{read_pct}_hit_rate",
             round(cb.stats["blk_hits"]
                   / max(1, cb.stats["fast_hits"] + cb.stats["mut_hits"]),
                   3))
        emit("fig3a", f"blockprobe_over_scan_r{read_pct}",
             round(thr_blk / thr_scan, 2))
        emit("fig3a", f"blockprobe_over_fastpath_r{read_pct}",
             round(thr_blk / thr_dili, 2))

        thr_harris, _ = _dili_throughput(1, kinds, keys, split=False,
                                         load_kinds=load_kinds,
                                         load_keys=load_keys)
        emit("fig3a", f"harris_r{read_pct}_ops_per_s", round(thr_harris))

        # skip list under the same batched-linearization regime
        sl = SL.init(capacity=1 << 15, max_level=14)
        step = jax.jit(lambda s, k, x: SL.apply_batch(s, k, x, 14))
        sl, _ = step(sl, jnp.asarray(load_kinds), jnp.asarray(load_keys))
        jax.block_until_ready(sl.key)
        t0 = time.perf_counter()
        bs = 64
        for i in range(0, n_ops, bs):
            sl, _ = step(sl, jnp.asarray(kinds[i:i + bs]),
                         jnp.asarray(keys[i:i + bs]))
        jax.block_until_ready(sl.key)
        thr_skip = n_ops / (time.perf_counter() - t0)
        emit("fig3a", f"skiplist_r{read_pct}_ops_per_s", round(thr_skip))
        emit("fig3a", f"dili_over_harris_r{read_pct}",
             round(thr_dili / thr_harris, 2))
        emit("fig3a", f"dili_over_skip_r{read_pct}",
             round(thr_dili / thr_skip, 2))

    # client routing: cached-registry vs fixed-shard submission on a
    # 4-server cluster — the delegation hops the client cache saves
    # (ISSUE 3 acceptance metric; the hop window covers the measured
    # phase only, after an explicit cache refresh).
    kinds, keys = mixed_phase(n_ops, key_space, 0.5, seed=6)
    for label, cached in (("cached", True), ("fixed", False)):
        client = _make_client(4, split=True, route_cache=cached)
        _drive_client(client, load_kinds, load_keys, 64)
        client.settle(max_rounds=4000)
        client.balance = None             # freeze topology for the window
        if cached:
            client.refresh_route_cache()
        client.stats.update(max_hops=0, delegated=0)
        dt = _drive_client(client, kinds, keys, 64)
        emit("fig3a", f"client_{label}_ops_per_s", round(len(kinds) / dt))
        emit("fig3a", f"client_{label}_max_hops", client.stats["max_hops"])
        emit("fig3a", f"client_{label}_delegated", client.stats["delegated"])


# ------------------------------------------------------------------- fig3b

def fig3b(n_load=1500, n_ops=3000, key_space=6000):
    """Throughput scaling with server count (paper Fig. 3b).

    The simulator runs all shards on one host core, so wall-clock cannot
    exhibit parallel speedup; the faithful metric is *rounds to complete
    the same op stream* — one round is one synchronous parallel step of
    all machines (what real hardware executes concurrently). Linear
    scaling = rounds shrink ~1/n while per-round shard work stays bounded.
    """
    load_kinds, load_keys = load_phase(n_load, key_space, seed=3)
    base_opr = None
    for n in (1, 2, 4, 8):
        # weak scaling: op volume grows with server count so every server
        # stays fed; the capacity metric is ops per synchronous round
        kinds, keys = mixed_phase(n_ops * n, key_space, 0.5, seed=4)

        walls = {}
        for fastpath in (True, False):
            backend = LocalBackend(_bench_cfg(n, fastpath=fastpath))
            bal = Balancer(backend)
            _drive_backend(backend, load_kinds, load_keys, 64, balancer=bal)
            _settle(backend, bal)
            r0 = backend.stats["rounds"]
            walls[fastpath] = _drive_backend(backend, kinds, keys, 64,
                                             balancer=bal)
            rounds = backend.stats["rounds"] - r0
            if not fastpath:
                continue  # scan-only run contributes its wall time only
            loads = [sum(e["size"] or 0 for e in backend.sublists(s)
                         if e["owner"] == s) for s in range(n)]
            opr = len(kinds) / rounds
            base_opr = base_opr or opr
            emit("fig3b", f"dili_{n}srv_rounds", rounds)
            emit("fig3b", f"dili_{n}srv_ops_per_round", round(opr, 1))
            emit("fig3b", f"dili_{n}srv_speedup", round(opr / base_opr, 2))
            emit("fig3b", f"dili_{n}srv_load_spread",
                 round(max(loads) / max(sum(loads) / n, 1), 2))
            emit("fig3b", f"dili_{n}srv_max_hops", backend.stats["max_hops"])
            emit("fig3b", f"dili_{n}srv_fast_hits",
                 backend.stats["fast_hits"])
        # completions per round are fastpath-invariant by construction, so
        # the fastpath-vs-scan comparison here is wall-clock throughput.
        # NB the simulator runs shards sequentially on one core, and with
        # round-robin submission only ~1/n of finds resolve locally (the
        # rest delegate and take the serial path on the owner), so the
        # multi-shard ratios understate the device-parallel gain: the
        # honest per-server read speedup is the 1srv row and fig3a.
        emit("fig3b", f"dili_{n}srv_ops_per_s",
             round(len(kinds) / walls[True]))
        emit("fig3b", f"dili_{n}srv_scan_ops_per_s",
             round(len(kinds) / walls[False]))
        emit("fig3b", f"fastpath_over_scan_{n}srv",
             round(walls[False] / walls[True], 2))


# ------------------------------------------------------------------- bgops

def bgops(n_keys=1200, key_space=4000):
    """Split / Move latency (rounds + wall ms) under insert load (§C)."""
    from repro.core import background as B
    cfg = DiLiConfig(num_shards=2, pool_capacity=1 << 14, max_sublists=128,
                     max_ctrs=128, max_scan=1 << 14, batch_size=32,
                     mailbox_cap=512, split_threshold=125, move_batch=32)
    backend = LocalBackend(cfg)
    client = DiLiClient(backend)
    cl = backend.cluster      # bg-phase instrumentation reads the machinery
    rng = np.random.default_rng(5)
    keys = rng.permutation(np.arange(1, key_space))[:n_keys]

    stats = {"split": [], "move": []}
    starts = {}                       # (shard, slot) -> (round, t0, kind)
    bal = Balancer(backend)
    i = 0
    guard = 0
    idle_streak = 0
    while guard < 4000 and idle_streak < 12:
        guard += 1
        j = min(i + 32, len(keys))
        if i < j:
            client.submit([OP_INSERT] * (j - i), keys[i:j].tolist())
            i = j
        client.pump(run_balance=False)
        # completions are visible right after the round, before the
        # balancer possibly queues the next op
        for s in range(cl.n):
            phases = B.slot_phases(cl.bgs[s])
            for b, ph in enumerate(phases):
                if int(ph) == B.BG_IDLE and (s, b) in starts:
                    r0, t0, kind = starts.pop((s, b))
                    stats[kind].append((cl.round_no - r0,
                                        (time.perf_counter() - t0) * 1e3))
        issued = bal.step()
        for s in range(cl.n):
            phases = B.slot_phases(cl.bgs[s])
            for b, ph in enumerate(phases):
                ph = int(ph)
                if ph != B.BG_IDLE and (s, b) not in starts:
                    kind = "split" if ph in (B.BG_SPLIT_EXEC,
                                             B.BG_SPLIT_WAIT,
                                             B.BG_MERGE_EXEC) else "move"
                    starts[(s, b)] = (cl.round_no, time.perf_counter(),
                                      kind)
        busy = (i < len(keys) or client.pending > 0
                or any(issued.values())
                or any(B.any_active(bg) for bg in cl.bgs)
                or any(b.shape[0] for b in cl.backlog))
        idle_streak = 0 if busy else idle_streak + 1

    for kind in ("split", "move"):
        if stats[kind]:
            rounds = [r for r, _ in stats[kind]]
            walls = [w for _, w in stats[kind]]
            emit("bgops", f"{kind}_count", len(rounds))
            emit("bgops", f"{kind}_mean_rounds", round(np.mean(rounds), 1))
            emit("bgops", f"{kind}_mean_ms", round(np.mean(walls), 2))
            emit("bgops", f"{kind}_p95_rounds",
                 round(float(np.percentile(rounds, 95)), 1))
    emit("bgops", "keys_preserved",
         int(cl.all_keys() == sorted(set(keys.tolist()))))


# --------------------------------------------------------------- rebalance

def rebalance(n_keys=125, n_churn=600, key_space=4000):
    """Rebalance-plane throughput (DESIGN.md §10).

    Part A: rounds to migrate one ``split_threshold``-sized sublist vs K
    (``move_batch``) — K=1 is the single-item-per-round path, so
    ``move_rounds_k1_over_k16`` is the acceptance ratio for the batched
    pipeline (target: ≥4x).

    Part B: time-to-balance (rounds until load spread ≤ 1.25) and
    client-op latency (rounds from submission to completion, p50/p99)
    while a skewed cluster rebalances under mixed churn, vs the
    background slot count B.
    """
    from repro.core import bg as B
    from repro.core.sim import Cluster

    # ---- A) migration rounds vs K
    base_rounds = None
    for k in (1, 4, 16, 32):
        cfg = DiLiConfig(num_shards=2, pool_capacity=4096, max_sublists=32,
                         max_ctrs=32, max_scan=4096, batch_size=32,
                         mailbox_cap=256, move_batch=k)
        cl = Cluster(cfg)
        keys = list(range(10, 10 + n_keys * 7, 7))
        cl.submit(0, [OP_INSERT] * len(keys), keys)
        cl.run_until_quiet(600)
        subs = cl.sublists(0)
        r0 = cl.round_no
        t0 = time.perf_counter()
        if not cl.move(0, subs[0]["keymax"], 1):
            # not an assert: under ``python -O`` the command (the measured
            # side effect) would silently never be queued
            raise RuntimeError("move command refused")
        cl.run_until_quiet(1200)
        rounds = cl.round_no - r0
        emit("rebalance", f"move_rounds_k{k}", rounds)
        emit("rebalance", f"move_ms_k{k}",
             round((time.perf_counter() - t0) * 1e3, 1))
        emit("rebalance", f"move_keys_ok_k{k}",
             int(cl.all_keys() == sorted(keys)))
        if k == 1:
            base_rounds = rounds
        else:
            emit("rebalance", f"move_rounds_k1_over_k{k}",
                 round(base_rounds / rounds, 2))

    # ---- B) time-to-balance + client tail latency during churn, vs slots
    for slots in (1, 2, 4):
        cfg = DiLiConfig(num_shards=4, pool_capacity=1 << 14,
                         max_sublists=128, max_ctrs=128, max_scan=1 << 14,
                         batch_size=32, mailbox_cap=512,
                         split_threshold=48, move_batch=16, bg_slots=slots)
        backend = LocalBackend(cfg)
        # skewed load phase: everything lands on shard 0 (no balancer yet)
        rng = np.random.default_rng(7)
        load_keys = rng.permutation(np.arange(1, key_space))[:n_churn]
        _drive_backend(backend, np.full(len(load_keys), OP_INSERT),
                       load_keys, 64)
        bal = Balancer(backend)
        kinds, keys2 = mixed_phase(n_churn, key_space, 0.5, seed=8)
        pend = {}
        lat = []
        settle_round = None
        i = r = 0
        while r < 6000:
            j = min(i + 32, len(kinds))
            if i < j:
                # rotate the submission shard: op latency (rounds from
                # submission to completion) then includes the delegation
                # hops rebalance churn induces, not just local answers
                ids = backend.submit(r % backend.n, kinds[i:j].tolist(),
                                     keys2[i:j].tolist())
                for oid in ids:
                    pend[oid] = r
                i = j
            for oid, _val, _src in backend.step():
                lat.append(r - pend.pop(oid))
            if r % 2 == 1:
                bal.step()
            if settle_round is None and r % 4 == 3:
                loads = [sum(e["size"] or 0 for e in backend.sublists(s)
                             if e["owner"] == s) for s in range(backend.n)]
                mean = max(sum(loads) / backend.n, 1)
                if max(loads) / mean <= 1.25:
                    settle_round = r
            r += 1
            # run to a *balance-policy fixed point*, not just op drain:
            # the policy verdict only counts when evaluated at quiescence
            # (a pass that found every slot busy proves nothing) —
            # time-to-balance below is then comparable across slot counts
            if (i >= len(kinds) and not pend and backend.quiescent()
                    and not any(bal.step().values())):
                break
        # balanced_b* disambiguates "balanced at round r" from "never
        # reached the spread target before the loop exited at round r"
        emit("rebalance", f"balanced_b{slots}",
             int(settle_round is not None))
        emit("rebalance", f"balance_rounds_b{slots}",
             settle_round if settle_round is not None else r)
        emit("rebalance", f"churn_lat_p50_b{slots}",
             round(float(np.percentile(lat, 50)), 1))
        emit("rebalance", f"churn_lat_p99_b{slots}",
             round(float(np.percentile(lat, 99)), 1))
        emit("rebalance", f"churn_lat_max_b{slots}", int(np.max(lat)))
        emit("rebalance", f"max_bg_active_b{slots}",
             backend.stats["max_bg_active"])
        emit("rebalance", f"move_hits_b{slots}",
             backend.stats["move_hits"])

    # ---- C) elastic membership (DESIGN.md §13): rounds to absorb a
    # joining shard / evacuate a retiring one, and what the change does
    # to client op latency while mixed churn keeps flowing
    cfg = DiLiConfig(num_shards=4, pool_capacity=1 << 14,
                     max_sublists=128, max_ctrs=128, max_scan=1 << 14,
                     batch_size=32, mailbox_cap=512,
                     split_threshold=48, move_batch=16, bg_slots=2)
    backend = LocalBackend(cfg, initial_shards=3)
    mb = backend.membership
    rng = np.random.default_rng(9)
    load_keys = rng.permutation(np.arange(1, key_space))[:n_churn]
    _drive_backend(backend, np.full(len(load_keys), OP_INSERT),
                   load_keys, 64)
    bal = Balancer(backend, rng=backend.balancer_rng)
    _settle(backend, bal)

    def churn_through_change(tag, fire, done, seed):
        kinds2, keys2 = mixed_phase(n_churn, key_space, 0.5, seed=seed)
        pend, lat = {}, []
        fired_at = change_rounds = None
        i = r = 0
        while r < 8000:
            j = min(i + 32, len(kinds2))
            if i < j:
                rt = mb.routable
                ids = backend.submit(rt[r % len(rt)],
                                     kinds2[i:j].tolist(),
                                     keys2[i:j].tolist())
                for oid in ids:
                    pend[oid] = r
                i = j
            for oid, _val, _src in backend.step():
                lat.append((r, r - pend.pop(oid)))
            if r % 2 == 1:
                bal.step()
            if fired_at is None and r >= 10:
                fire()
                fired_at = r
            if fired_at is not None and change_rounds is None and done():
                change_rounds = r - fired_at
            r += 1
            if (i >= len(kinds2) and not pend
                    and change_rounds is not None and backend.quiescent()
                    and not any(bal.step().values())):
                break
        # tail latency *during* the change window (all-run fallback when
        # the window closed before any op completed inside it)
        hi = fired_at + (change_rounds or 8000)
        win = [d for (cr, d) in lat if fired_at <= cr <= hi] \
            or [d for _, d in lat]
        emit("rebalance", f"{tag}_ok", int(change_rounds is not None))
        emit("rebalance", f"{tag}_rounds",
             change_rounds if change_rounds is not None else r)
        emit("rebalance", f"{tag}_lat_p50",
             round(float(np.percentile(win, 50)), 1))
        emit("rebalance", f"{tag}_lat_p99",
             round(float(np.percentile(win, 99)), 1))

    churn_through_change("absorb_new_shard",
                         lambda: backend.join_shard(),
                         lambda: not mb.joining, seed=10)
    churn_through_change("evacuate_shard",
                         lambda: backend.retire_shard(max(mb.active)),
                         lambda: not mb.draining, seed=11)


# ----------------------------------------------------------------- kernels

def kernels():
    from repro.kernels import ops as K
    rng = np.random.default_rng(0)
    m, c, b = 128, 128, 1024
    bounds = np.sort(rng.choice(np.arange(0, 100000), m, replace=False))
    bounds[0] = -1
    keymin = jnp.asarray(bounds.astype(np.int32))
    blocks = np.full((m, c), np.iinfo(np.int32).max, np.int32)
    for i in range(m):
        lo = bounds[i] + 1
        blocks[i, :c // 2] = np.sort(rng.integers(lo, lo + 400, c // 2))
    blocks = jnp.asarray(blocks)
    queries = jnp.asarray(rng.integers(0, 100000, b).astype(np.int32))

    for name, fn in [
        ("hybrid_search_pallas",
         lambda: K.hybrid_search(keymin, blocks, queries, tile_q=256)),
        ("hybrid_search_ref",
         lambda: K.hybrid_search_ref(keymin, blocks, queries)),
    ]:
        out = fn()  # warm / compile
        t0 = time.perf_counter()
        for _ in range(20):
            out = fn()
        jax.block_until_ready(out)
        us = (time.perf_counter() - t0) / 20 * 1e6
        emit("kernels", f"{name}_us", round(us, 1))

    bq, h, kh, d, pages, ps = 8, 8, 2, 64, 16, 16
    pool = pages * 2
    q = jnp.asarray(rng.standard_normal((bq, h, d)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((pool, ps, kh, d)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((pool, ps, kh, d)), jnp.float32)
    pt = jnp.asarray(rng.integers(0, pool, (bq, pages)).astype(np.int32))
    sl = jnp.asarray(rng.integers(ps, pages * ps, (bq,)).astype(np.int32))
    for name, fn in [
        ("paged_attention_pallas",
         lambda: K.paged_attention(q, kp, vp, pt, sl, page_size=ps)),
        ("paged_attention_ref",
         lambda: K.paged_attention_ref(q, kp, vp, pt, sl, page_size=ps)),
    ]:
        out = fn()
        t0 = time.perf_counter()
        for _ in range(10):
            out = fn()
        jax.block_until_ready(out)
        us = (time.perf_counter() - t0) / 10 * 1e6
        emit("kernels", f"{name}_us", round(us, 1))


# ------------------------------------------------------------------ lmstep

def lmstep():
    from repro.configs import get_smoke_config
    from repro.data.synthetic import make_train_batch
    from repro.models import transformer as T
    from repro.models.config import ShapeCell
    from repro.optim import AdamWConfig, adamw_init, adamw_update

    cfg = get_smoke_config("qwen2_5_3b")
    cell = ShapeCell("bench", "train", 256, 4)
    params = T.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    opt_cfg = AdamWConfig()
    opt = adamw_init(params)
    batch = make_train_batch(cfg, cell, dtype=jnp.float32)

    @jax.jit
    def step(p, o):
        (l, _), g = jax.value_and_grad(
            lambda p: T.forward_train(p, cfg, batch), has_aux=True)(p)
        p, o, _ = adamw_update(opt_cfg, p, g, o)
        return p, o

    params, opt = step(params, opt)
    jax.block_until_ready(params["embed"])
    t0 = time.perf_counter()
    for _ in range(5):
        params, opt = step(params, opt)
    jax.block_until_ready(params["embed"])
    ms = (time.perf_counter() - t0) / 5 * 1e3
    tok = cell.global_batch * cell.seq_len
    emit("lmstep", "smoke_train_step_ms", round(ms, 1))
    emit("lmstep", "smoke_tokens_per_s", round(tok / ms * 1e3))


# -------------------------------------------------------------------- zipf

def zipf(n_load=1000, n_ops=4000, key_space=4000):
    """Skewed-read throughput with/without hot-sublist replication (§15).

    Bounded YCSB Zipfian(θ) at θ ∈ {0.5, 0.9, 0.99} on a fixed 4-shard
    cluster: a 90%-read mixed warm phase (YCSB-B; exercises the delta
    stream — replicas track live mutations), then a read-only measured
    phase (YCSB-C, the standard shape for read-throughput numbers; the
    balancer stays live throughout). Unscrambled Zipfian means the hot
    ranks are a contiguous key prefix — one hot *sublist* — so at high θ
    the read stream funnels into a single shard's per-round admission
    lane. Replication on: the balancer's op-rate EWMA flags the hot
    entry, replicates it, and the client spreads FINDs over
    [primary] + replicas — the acceptance metric is the on/off
    throughput ratio at θ=0.99 (target ≥1.5x) with θ=0.5 unharmed
    (the ``hot_share`` gate keeps low-skew traffic from replicating).
    """
    def cfg_for(rep: bool) -> DiLiConfig:
        return DiLiConfig(num_shards=4, pool_capacity=1 << 15,
                          max_sublists=256, max_ctrs=256,
                          max_scan=1 << 15, batch_size=32,
                          mailbox_cap=512, split_threshold=125,
                          move_batch=32, block_probe=True,
                          replication=rep,
                          replica_sessions=4, replica_slots=8,
                          replica_batch=16, replica_refresh_rounds=4,
                          replica_staleness_rounds=64)

    load_kinds, load_keys = load_phase(n_load, key_space, seed=12)
    for theta in (0.5, 0.9, 0.99):
        warm_kinds, warm_keys = mixed_phase(n_ops, key_space, 0.9, seed=13,
                                            theta=theta)
        kinds, keys = mixed_phase(n_ops, key_space, 1.0, seed=14,
                                  theta=theta)
        tlab = f"t{int(theta * 100):03d}"
        thr = {}
        for label, rep in (("off", False), ("on", True)):
            backend = LocalBackend(cfg_for(rep))
            bal = Balancer(backend, hot_rate=6.0, cold_rate=1.0,
                           hot_share=0.45, replica_fanout=3)
            client = DiLiClient(backend, balance=bal, max_inflight=1024)
            _drive_client(client, load_kinds, load_keys, 32)
            client.settle(max_rounds=8000)
            _drive_client(client, warm_kinds, warm_keys, 32)
            r0 = backend.stats["rounds"]
            h0 = backend.stats["rep_hits"]
            dt = _drive_client(client, kinds, keys, 32)
            thr[label] = len(kinds) / dt
            emit("zipf", f"{tlab}_{label}_ops_per_s", round(thr[label]))
            emit("zipf", f"{tlab}_{label}_rounds",
                 backend.stats["rounds"] - r0)
            if rep:
                emit("zipf", f"{tlab}_rep_hits",
                     backend.stats["rep_hits"] - h0)
        emit("zipf", f"{tlab}_on_over_off",
             round(thr["on"] / thr["off"], 2))

    # YCSB-E: scan-heavy mix (95% short RANGE scans / 5% inserts) at
    # θ=0.99 — the ordered-structure payoff row (DESIGN.md §16). Each
    # scan routes to its span's primary via one registry lookup and is
    # served by the gather pre-pass; a hash-partitioned store would
    # scatter-gather every shard per scan. Replication stays off (scans
    # are pinned to primaries) and the client keeps its automatic
    # outbox budget — each in-flight scan charges range_batch + 2.
    backend = LocalBackend(cfg_for(False)._replace(range_scan=True))
    bal = Balancer(backend, hot_rate=6.0, cold_rate=1.0)
    client = DiLiClient(backend, balance=bal)
    _drive_client(client, load_kinds, load_keys, 32)
    client.settle(max_rounds=8000)
    n_e = max(n_ops // 8, 64)
    _, starts = mixed_phase(n_e, key_space, 1.0, seed=15, theta=0.99)
    rng = np.random.default_rng(16)
    scans = []
    t0 = time.perf_counter()
    for i, st in enumerate(starts):
        if i % 20 == 19:                       # the 5% insert leg
            client.insert(int(rng.integers(1, key_space)))
        else:
            scans.append(client.range(int(st), int(st) + 100, limit=50))
        client.pump()
    client.drain(16000)
    dt = time.perf_counter() - t0
    emit("zipf", "ycsbE_ops_per_s", round(len(starts) / dt))
    emit("zipf", "ycsbE_scans_done", sum(1 for f in scans if f.done))
    emit("zipf", "ycsbE_items_scanned", sum(f.count() for f in scans))
    emit("zipf", "ycsbE_range_hits", backend.stats["range_hits"])


# ----------------------------------------------------------------- nemesis

def nemesis(n_load=800, n_ops=1600, key_space=3000):
    """Throughput under adversarial channels (DESIGN.md §11).

    One 4-server client-driven run per fault level: ``off`` is the
    direct-routing baseline (no transport), ``p0.00`` is the reliable
    transport with a zero-fault wire (pure seq/ack/dedup overhead), and
    ``p0.05`` / ``p0.20`` drop+duplicate+reorder that fraction of frames
    (delay rides at p/2). The interesting rows are the *ratios*: what a
    lossy fabric costs end-to-end once retransmission and dedup absorb
    it, and how much retransmit traffic the wire added.
    """
    from repro.core.net import NemesisConfig
    load_kinds, load_keys = load_phase(n_load, key_space, seed=5)
    kinds, keys = mixed_phase(n_ops, key_space, 0.5, seed=6)
    base = None
    for p in (None, 0.0, 0.05, 0.20):
        label = "off" if p is None else f"p{int(p * 100):02d}"
        nem = None if p is None else NemesisConfig(
            drop_prob=p, dup_prob=p, reorder_prob=p,
            delay_prob=p / 2, delay_rounds=3)
        backend = LocalBackend(_bench_cfg(4), seed=0, nemesis=nem)
        # low split threshold so the load spreads across all 4 servers
        # and the op stream actually crosses the (lossy) wire —
        # delegations, results, move replicates and registry broadcasts
        bal = Balancer(backend, split_threshold=max(20, n_load // 12),
                       rng=backend.balancer_rng)
        client = DiLiClient(backend, balance=bal)
        _drive_client(client, load_kinds, load_keys, 64)
        client.settle(max_rounds=8000)    # spread sublists over servers
        r0 = backend.stats["rounds"]
        dt = _drive_client(client, kinds, keys, 64)
        thr = len(kinds) / dt
        base = base or thr
        emit("nemesis", f"{label}_ops_per_s", round(thr))
        emit("nemesis", f"{label}_rounds", backend.stats["rounds"] - r0)
        emit("nemesis", f"{label}_vs_off", round(thr / base, 3))
        if nem is not None:
            net = backend.net
            emit("nemesis", f"{label}_retransmits", net.stats["retransmits"])
            emit("nemesis", f"{label}_dup_dropped", net.stats["dup_dropped"])
            emit("nemesis", f"{label}_wire_dropped",
                 net.nemesis.stats["dropped"])


# ---------------------------------------------------------------- recovery

def recovery(n_load=400, n_ops=800, key_space=2500, crash_r=90, outage=50):
    """Durable-recovery cost vs snapshot cadence (DESIGN.md §14).

    One 4-server run per cadence: every run journals through the same
    durability pipeline, and a seeded ``CrashPlan`` kill -9s shard 1 at
    round ``crash_r`` and restarts it ``outage`` rounds later. Rows per
    cadence: WAL replay length and the restart step's wall time (the
    snapshot-cadence/replay-length tradeoff), plus client op latency
    (rounds from submission to completion, p50/p99) through the crash
    window. ``base`` is the same run journaling but never crashing, so
    ``crash_over_base_p99_*`` is what the outage cost the clients.
    """
    import tempfile

    from repro.core.durability import Durability, DurabilityConfig
    from repro.core.net import NemesisConfig
    from repro.core.net.nemesis import CrashPlan

    cfg = DiLiConfig(num_shards=4, pool_capacity=4096, max_sublists=32,
                     max_ctrs=32, max_scan=4096, batch_size=32,
                     mailbox_cap=256, split_threshold=48, move_batch=8)
    restart_r = crash_r + outage
    win_hi = restart_r + 30

    def run(crash: bool, snapshot_every: int):
        nem = NemesisConfig(
            crashes=(CrashPlan(1, crash_r, restart_r),) if crash else ())
        with tempfile.TemporaryDirectory(prefix="dili-bench-") as d:
            dur = Durability(d, cfg,
                             DurabilityConfig(snapshot_every=snapshot_every))
            backend = LocalBackend(cfg, seed=0, nemesis=nem, durability=dur)
            bal = Balancer(backend, split_threshold=48,
                           rng=backend.balancer_rng)
            mb = backend.membership
            rng = np.random.default_rng(2)
            load_keys = rng.permutation(np.arange(1, key_space))[:n_load]
            kinds, keys = mixed_phase(n_ops, key_space, 0.5, seed=3)
            all_kinds = np.concatenate([np.full(n_load, OP_INSERT), kinds])
            all_keys = np.concatenate([load_keys, keys])
            pend, lat = {}, []
            restart_ms = None
            i = r = 0
            while r < 10000:
                j = min(i + 32, len(all_kinds))
                if i < j:
                    rt = mb.routable
                    ids = backend.submit(rt[r % len(rt)],
                                         all_kinds[i:j].tolist(),
                                         all_keys[i:j].tolist())
                    for oid in ids:
                        pend[oid] = r
                    i = j
                t0 = time.perf_counter()
                for oid, _v, _s in backend.step():
                    lat.append((r, r - pend.pop(oid)))
                if r == restart_r:
                    restart_ms = (time.perf_counter() - t0) * 1e3
                if r % 2 == 1:
                    bal.step()
                r += 1
                # the break must outlast the schedule — with a tiny op
                # stream the cluster drains before crash_r and the crash
                # would otherwise never fire
                if (r > win_hi and i >= len(all_kinds) and not pend
                        and backend.quiescent()
                        and not any(bal.step().values())):
                    break
            win = [d_ for (cr, d_) in lat if crash_r <= cr <= win_hi] \
                or [d_ for _, d_ in lat]
            return {"lat": [d_ for _, d_ in lat], "win": win,
                    "restart_ms": restart_ms, "stats": dict(dur.stats),
                    "quiet": backend.quiescent(), "rounds": r}

    base = run(False, 64)
    emit("recovery", "base_lat_p50",
         round(float(np.percentile(base["lat"], 50)), 1))
    emit("recovery", "base_win_p99",
         round(float(np.percentile(base["win"], 99)), 1))
    emit("recovery", "base_quiet", int(base["quiet"]))
    base_p99 = max(float(np.percentile(base["win"], 99)), 1.0)
    for every in (8, 32, 128):
        res = run(True, every)
        st = res["stats"]
        emit("recovery", f"replayed_rounds_s{every}", st["replayed_rounds"])
        emit("recovery", f"snapshots_s{every}", st["snapshots"])
        emit("recovery", f"wal_records_s{every}", st["records"])
        emit("recovery", f"restart_step_ms_s{every}",
             round(res["restart_ms"], 1))
        p50 = float(np.percentile(res["win"], 50))
        p99 = float(np.percentile(res["win"], 99))
        emit("recovery", f"crash_win_p50_s{every}", round(p50, 1))
        emit("recovery", f"crash_win_p99_s{every}", round(p99, 1))
        emit("recovery", f"crash_over_base_p99_s{every}",
             round(p99 / base_p99, 2))
        emit("recovery", f"recovered_s{every}",
             int(st["recoveries"] == 1 and res["quiet"]))


# ----------------------------------------------------------------- serving

def serving(steps=20, migrate_every=4, max_batch=4, prompt_len=24,
            max_new=64, idle_seqs=60, page_size=8):
    """Decode throughput during live page-table migration (DESIGN.md §16).

    A smoke-sized model decodes a fixed batch while ``idle_seqs`` parked
    sequences pad the DiLi page table (the realistic shape: the table is
    dominated by sequences that are *not* decoding this step). Every
    ``migrate_every`` steps the balancer splits/moves the page index and
    the engine heals its snapshot — three modes:

      static   no migration (ceiling)
      rescan   migrate + cluster-wide chain walk (``refresh_table``):
               pays for every parked sequence on each heal
      range    migrate + one RANGE scan per *live* sequence
               (``refresh_seq``): pays only for the decode batch

    The acceptance row is ``range_over_rescan`` (>1 means the RANGE path
    wins); the ``*_tokens_match`` rows assert migration never corrupted
    the KV mapping (greedy decode is deterministic, so all three modes
    must emit identical tokens — the aliasing regression this PR fixes
    would flip them).
    """
    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    from repro.serving.engine import Request, ServingEngine

    acfg = get_smoke_config("qwen2_5_3b")
    params = T.init_params(acfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    rng = np.random.default_rng(5)
    prompts = [rng.integers(1, acfg.vocab, prompt_len).astype(np.int32)
               for _ in range(max_batch)]
    pages_per_seq = (prompt_len + max_new + page_size - 1) // page_size
    num_pages = (max_batch + idle_seqs + 2) * pages_per_seq

    def run(refresh_mode, migrate):
        eng = ServingEngine(acfg, params, page_size=page_size,
                            num_pages=num_pages, max_batch=max_batch,
                            dili_shards=2, refresh_mode=refresh_mode)
        eng.balancer = Balancer(eng.kv.backend, split_threshold=48,
                                merge_threshold=4)
        for sid in range(max_batch, max_batch + idle_seqs):
            eng.kv.alloc_pages(sid, pages_per_seq)
        reqs = [Request(seq_id=i, prompt=p, max_new=max_new)
                for i, p in enumerate(prompts)]
        for r in reqs:
            eng.admit(r)
        eng.step()                               # warm the jit cache
        migrations = 0
        t0 = time.perf_counter()
        for s in range(steps):
            reb = migrate and (s % migrate_every == migrate_every - 1)
            migrations += int(reb)
            eng.step(rebalance=reb)
        dt = time.perf_counter() - t0
        toks = steps * max_batch
        return {"tok_per_s": toks / dt, "migrations": migrations,
                "range_hits": eng.kv.backend.stats["range_hits"],
                "out": [list(r.out) for r in reqs]}

    static = run("rescan", migrate=False)
    rescan = run("rescan", migrate=True)
    ranged = run("range", migrate=True)
    emit("serving", "static_tok_per_s", round(static["tok_per_s"], 1))
    emit("serving", "migrate_rescan_tok_per_s",
         round(rescan["tok_per_s"], 1))
    emit("serving", "migrate_range_tok_per_s",
         round(ranged["tok_per_s"], 1))
    emit("serving", "range_over_rescan",
         round(ranged["tok_per_s"] / rescan["tok_per_s"], 2))
    emit("serving", "migrations", ranged["migrations"])
    emit("serving", "range_refresh_hits", ranged["range_hits"])
    emit("serving", "rescan_tokens_match",
         int(rescan["out"] == static["out"]))
    emit("serving", "range_tokens_match",
         int(ranged["out"] == static["out"]))


ALL = {"fig3a": fig3a, "fig3b": fig3b, "bgops": bgops,
       "rebalance": rebalance, "kernels": kernels, "lmstep": lmstep,
       "zipf": zipf, "nemesis": nemesis, "recovery": recovery,
       "serving": serving}

# shrunken workloads for the CI smoke lane (--tiny): same code paths,
# minutes -> seconds. Benches without parameters run as-is.
TINY = {
    "fig3a": dict(n_load=300, n_ops=600, key_space=1200),
    "fig3b": dict(n_load=200, n_ops=400, key_space=1000),
    "bgops": dict(n_keys=300, key_space=1200),
    "rebalance": dict(n_keys=125, n_churn=200, key_space=1000),
    "zipf": dict(n_load=300, n_ops=800, key_space=1200),
    "nemesis": dict(n_load=200, n_ops=400, key_space=1000),
    "recovery": dict(n_load=150, n_ops=300, key_space=1000,
                     crash_r=40, outage=25),
    "serving": dict(steps=8, migrate_every=4, max_batch=2, prompt_len=12,
                    max_new=16, idle_seqs=16, page_size=4),
}


def main() -> None:
    flags = [a for a in sys.argv[1:] if a.startswith("-")]
    names = [a for a in sys.argv[1:] if not a.startswith("-")] or list(ALL)
    tiny = "--tiny" in flags
    print("name,metric,value")
    for n in names:
        params = TINY.get(n, {}) if tiny else {}
        start = len(ROWS)
        t0 = time.perf_counter()
        ALL[n](**params)
        write_artifact(n, ROWS[start:], time.perf_counter() - t0,
                       params=params)


if __name__ == "__main__":
    main()
