"""Render EXPERIMENTS.md tables from experiments/dryrun_results.jsonl."""
import json
import sys


def load(path="experiments/dryrun_results.jsonl"):
    rows = [json.loads(l) for l in open(path)]
    seen = {}
    for r in rows:  # last write wins (re-runs)
        seen[(r["arch"], str(r["cell"]), r["mesh"],
              json.dumps(r.get("overrides", {}), sort_keys=True))] = r
    return list(seen.values())


def fmt_bytes(b):
    if b >= 1e12:
        return f"{b/1e12:.2f}T"
    if b >= 1e9:
        return f"{b/1e9:.2f}G"
    if b >= 1e6:
        return f"{b/1e6:.1f}M"
    return f"{b/1e3:.0f}K"


def roofline_table(rows, mesh="16x16"):
    out = ["| arch | cell | FLOPs/dev | bytes/dev | coll/dev | compute s | "
           "memory s | collective s | dominant | MODEL_FLOPS | useful | "
           "MFU bound | fits (temp GB) |",
           "|---|---|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], str(r["cell"]))):
        if r["mesh"] != mesh or r["arch"] == "dili-service":
            continue
        if r.get("overrides"):
            continue
        t = r["terms_seconds"]
        mem = r.get("memory_analysis", {})
        temp = mem.get("temp_size_in_bytes")
        fit = f"{temp/1e9:.1f}" if temp is not None else "n/a"
        out.append(
            f"| {r['arch']} | {r['cell']} | {r['flops_per_device']:.2e} | "
            f"{fmt_bytes(r['bytes_per_device'])} | "
            f"{fmt_bytes(r['collective_bytes_per_device'])} | "
            f"{t['compute']:.3e} | {t['memory']:.3e} | "
            f"{t['collective']:.3e} | **{r['dominant']}** | "
            f"{r['model_flops_global']:.2e} | "
            f"{r['useful_flops_ratio']:.2f} | "
            f"{r['roofline_mfu_bound']:.3f} | {fit} |")
    return "\n".join(out)


def dryrun_table(rows):
    out = ["| arch | cell | mesh | kind | compile s | args GB | temp GB | "
           "collective mix |",
           "|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], str(r["cell"]),
                                         r["mesh"])):
        if r.get("overrides"):
            continue
        mem = r.get("memory_analysis", {})
        a = mem.get("argument_size_in_bytes")
        t = mem.get("temp_size_in_bytes")
        coll = r.get("collectives", {})
        mix = " ".join(f"{k}:{fmt_bytes(v)}" for k, v in
                       sorted(coll.items(), key=lambda kv: -kv[1])[:3])
        out.append(
            f"| {r['arch']} | {r['cell']} | {r['mesh']} | "
            f"{r.get('kind','-')} | {r.get('compile_seconds','-')} | "
            f"{a/1e9:.2f} | " if a is not None else
            f"| {r['arch']} | {r['cell']} | {r['mesh']} | "
            f"{r.get('kind','-')} | {r.get('compile_seconds','-')} | n/a | ")
        out[-1] += (f"{t/1e9:.2f} | {mix} |" if t is not None
                    else f"n/a | {mix} |")
    return "\n".join(out)


if __name__ == "__main__":
    rows = load()
    which = sys.argv[1] if len(sys.argv) > 1 else "roofline"
    if which == "roofline":
        print(roofline_table(rows, sys.argv[2] if len(sys.argv) > 2
                             else "16x16"))
    else:
        print(dryrun_table(rows))
