"""Differential + invariant tests for the batched mutation fast-path
(core/batch_apply.py, DESIGN.md §4b).

W1  Differential equivalence: identical random write-heavy workloads driven
    through two clusters — mut_fastpath on vs. off — with channel delays
    and a live balancer issuing Splits/Moves, must produce op-for-op
    identical results and identical final key sets, both equal to the
    sequential oracle.
W2  The mutation fast-path actually fires (guards against a silently
    never-eligible pre-pass making W1 vacuous).
W3  Merge path under load: a remove-heavy workload with
    ``merge_threshold > 0`` actually triggers Balancer merges, and the
    on/off runs still agree op-for-op and on the final key set.
W4  A pure-remove batch over spread keys on a quiescent list is applied
    entirely by the fast-path (each remove marks its own node — no shared
    link words).
W5  Adjacent-key inserts (shared link word) bounce to the serial path and
    stay correct; same-key duplicate rounds are answered by the group
    fold with exact serial-order semantics (including finds interleaved
    between mutations of their key).
W6  Removed-while-copy-in-flight regression (the lost-RepDelete
    resurrection): a key removed mid-Move, after its MoveItem copy was
    sent but before the ack returns, must stay removed after the Switch.
"""
import numpy as np
import pytest

from repro.core.balancer import Balancer
from repro.core.oracle import OracleList
from repro.core.sim import Cluster
from repro.core.types import (DiLiConfig, OP_FIND, OP_INSERT, OP_REMOVE)

CFG = DiLiConfig(num_shards=2, pool_capacity=4096, max_sublists=32,
                 max_ctrs=32, max_scan=4096, batch_size=16,
                 mailbox_cap=256, move_batch=8, split_threshold=48,
                 find_fastpath=True, mut_fastpath=True)


def _workload(seed, n_ops, key_space, read_frac):
    rng = np.random.default_rng(seed)
    w = (1 - read_frac) / 2
    kinds = rng.choice([OP_FIND, OP_INSERT, OP_REMOVE], n_ops,
                       p=[read_frac, w, w])
    keys = rng.integers(1, key_space, n_ops)
    return kinds.tolist(), keys.tolist()


def _drive(cfg, kinds, keys, *, seed, delay, merge_threshold=0,
           balance_every=3, settle=0):
    """Run one cluster over the workload; returns
    (results, final keys, stats, balancer command counts)."""
    cl = Cluster(cfg, seed=seed, delay_prob=delay)
    bal = Balancer(cl, merge_threshold=merge_threshold)
    issued = {"split": 0, "move": 0, "merge": 0}
    ids = []
    b = cfg.batch_size
    r = 0
    for i in range(0, len(kinds), b):
        ids += cl.submit(0, kinds[i:i + b], keys[i:i + b])
        cl.step()
        if r % balance_every == balance_every - 1:
            for k, v in bal.step().items():
                issued[k] = issued.get(k, 0) + v
        r += 1
    cl.run_until_quiet(2000)
    for _ in range(settle):
        got = bal.step()
        for k, v in got.items():
            issued[k] = issued.get(k, 0) + v
        cl.run_until_quiet(2000)
        if not any(got.values()):
            break
    return [cl.results[j] for j in ids], cl.all_keys(), cl.stats, issued


@pytest.mark.parametrize("seed,read_frac,delay,key_space", [
    (0, 0.1, 0.25, 160),
    (2, 0.1, 0.15, 160),
    (3, 0.3, 0.3, 160),
    # hot-key regimes: nearly every round is one big same-key group fold
    (4, 0.1, 0.2, 12),
    (7, 0.1, 0.0, 8),
])
def test_differential_mut_fastpath_vs_serial(seed, read_frac, delay,
                                             key_space):
    """W1 + W2: mut_fastpath on == off, op for op, under bg churn."""
    kinds, keys = _workload(seed, 480, key_space, read_frac)

    res_on, keys_on, st_on, _ = _drive(
        CFG, kinds, keys, seed=seed + 7, delay=delay)
    res_off, keys_off, st_off, _ = _drive(
        CFG._replace(mut_fastpath=False), kinds, keys,
        seed=seed + 7, delay=delay)

    assert st_off["mut_hits"] == 0
    assert st_on["mut_hits"] > 0, \
        "mutation fast-path never fired — differential test is vacuous"
    assert res_on == res_off, "mut_fastpath changed an op result"
    assert keys_on == keys_off, "mut_fastpath changed the final key set"

    oracle = OracleList()
    expected = oracle.apply_batch(kinds, keys)
    assert [bool(v) for v in res_on] == expected
    assert keys_on == sorted(oracle.snapshot())


@pytest.mark.parametrize("seed", [0, 1])
def test_merge_under_load_differential(seed):
    """W3: remove-heavy workload with merge_threshold > 0 — merges actually
    fire, and mut_fastpath on/off agree with each other and the oracle."""
    rng = np.random.default_rng(seed + 20)
    base = (rng.permutation(np.arange(1, 400))[:240]).tolist()
    rem = (rng.permutation(np.asarray(base))[:200]).tolist()
    kinds = [OP_INSERT] * len(base) + [OP_REMOVE] * len(rem)
    keys = base + rem

    runs = {}
    for on in (True, False):
        cfg = CFG._replace(mut_fastpath=on)
        runs[on] = _drive(cfg, kinds, keys, seed=seed + 5, delay=0.15,
                          merge_threshold=30, settle=60)
        _, _, _, issued = runs[on]
        assert issued["merge"] > 0, \
            f"no merge fired (mut_fastpath={on}) — test is vacuous"

    res_on, keys_on, st_on, _ = runs[True]
    res_off, keys_off, _, _ = runs[False]
    assert st_on["mut_hits"] > 0
    assert res_on == res_off
    assert keys_on == keys_off

    oracle = OracleList()
    expected = oracle.apply_batch(kinds, keys)
    assert [bool(v) for v in res_on] == expected
    assert keys_on == sorted(oracle.snapshot())


def test_pure_remove_batch_all_hit():
    """W4: on a quiescent list, a spread remove batch is applied entirely
    by the fast-path (each remove marks its own node's link word)."""
    cl = Cluster(CFG)
    base = list(range(10, 400, 3))
    cl.submit(0, [OP_INSERT] * len(base), base)
    cl.run_until_quiet(800)
    hits0 = cl.stats["mut_hits"]

    rem = base[::4][:24]
    ids = cl.submit(0, [OP_REMOVE] * len(rem), rem)
    cl.run_until_quiet(400)
    assert cl.stats["mut_hits"] - hits0 == len(rem)
    assert all(bool(cl.results[j]) for j in ids)
    oracle = OracleList(base)
    for k in rem:
        oracle.remove(k)
    assert cl.all_keys() == sorted(oracle.snapshot())


def test_adjacent_and_duplicate_keys_stay_correct():
    """W5: shared-link-word inserts bounce to the serial path; same-key
    duplicate rounds fold with exact serial-order semantics."""
    cl = Cluster(CFG)
    base = [10, 20, 30, 40]
    cl.submit(0, [OP_INSERT] * len(base), base)
    cl.run_until_quiet(200)

    # adjacent keys: all four inserts share the same left node (key 10)
    ids = cl.submit(0, [OP_INSERT, OP_INSERT, OP_INSERT, OP_INSERT],
                    [14, 15, 16, 17])
    cl.run_until_quiet(200)
    assert [bool(cl.results[j]) for j in ids] == [True] * 4

    # same-key group, finds interleaved: serial order inside the group
    ids = cl.submit(0, [OP_INSERT, OP_FIND, OP_REMOVE, OP_FIND, OP_INSERT],
                    [50, 50, 50, 50, 50])
    cl.run_until_quiet(200)
    assert [bool(cl.results[j]) for j in ids] == [True, True, True, False,
                                                  True]

    # insert-then-remove nets to nothing; the remove still reports True
    ids = cl.submit(0, [OP_INSERT, OP_REMOVE, OP_FIND] * 2,
                    [60, 60, 60, 70, 70, 70])
    cl.run_until_quiet(200)
    assert [bool(cl.results[j]) for j in ids] == [True, True, False] * 2

    oracle = OracleList(base + [14, 15, 16, 17, 50])
    assert cl.all_keys() == sorted(oracle.snapshot())


def test_removed_while_copy_in_flight_stays_removed():
    """W6 (regression): a key removed after its MoveItem copy was sent but
    before the MOVE_ACK returns must not resurrect on the move target.

    The serial search must not delink+recycle the marked source slot while
    its sublist's SubHead is moving — once the recycled slot is *reused*
    (by an insert popping the free list) the ack's <sId, ts> identity
    check fails and the marked-in-flight race RepDelete (h_move_ack
    Line 210) is silently skipped, leaving the target copy live."""
    from repro.core import background as B
    from repro.core import messages as M
    from repro.core import refs

    cfg = CFG._replace(move_batch=2, find_fastpath=False,
                       mut_fastpath=False)
    cl = Cluster(cfg)
    base = list(range(10, 170, 10))
    cl.submit(0, [OP_INSERT] * len(base), base)
    cl.run_until_quiet(400)

    subs = [e for e in cl.sublists(0) if e["owner"] == 0]
    cl.move(0, subs[0]["keymax"], 1)
    # catch a copy batch whose MoveItem is in flight but whose MOVE_ACK
    # has not even been produced yet (not queued for delivery): the ack
    # then lands one round *after* the ops below, maximizing the window
    caught = None
    for _ in range(60):
        cl.step()
        bg = cl.bgs[0]          # slotted table; the move runs in slot 0
        ack_queued = any(int(row[M.F_KIND]) == M.MSG_MOVE_ACK
                         for row in cl.backlog[0])
        if int(bg.phase[0]) == B.BG_MOVE_COPY and \
                int(bg.sent[0]) > int(bg.acked[0]) and not ack_queued:
            st = cl.states[0]
            pk = np.asarray(st.pool.key)
            nl = np.asarray(st.pool.newloc)
            # the in-flight batch walks from the cursor, i.e. it holds the
            # first chain items with newLoc still null — the smallest such
            # key is in the unacked batch
            for k in base:
                idxs = np.where(pk == k)[0]
                if len(idxs) and all(int(nl[i]) == refs.NULL_REF
                                     for i in idxs):
                    caught = k
                    break
            if caught is not None:
                break
    if caught is None:
        pytest.skip("could not catch the unacked-copy window")

    # one round: mark it, walk past it (a delinking search would recycle
    # the slot), then insert fresh keys (a recycled slot gets reused and
    # loses its <sId, ts> identity before the ack arrives)
    ids = cl.submit(0, [OP_REMOVE, OP_FIND, OP_INSERT, OP_INSERT],
                    [caught, base[-1], 171, 173])
    cl.run_until_quiet(1500)
    assert bool(cl.results[ids[0]]) is True

    oracle = OracleList(base)
    oracle.remove(caught)
    oracle.insert(171)
    oracle.insert(173)
    assert cl.all_keys() == sorted(oracle.snapshot()), \
        f"key {caught} resurrected after the move"
