"""Optimizer, checkpointing, and fault-tolerant training loop."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore_pytree, save_pytree
from repro.configs import get_smoke_config
from repro.data.synthetic import make_train_batch
from repro.models import transformer as T
from repro.models.config import ShapeCell
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         cosine_schedule, global_norm)
from repro.optim.compress import int8_compress, int8_decompress
from repro.runtime.train import (SimulatedFailure, Trainer, TrainerConfig)

CELL = ShapeCell("smoke_train", "train", 128, 2)


def test_adamw_reduces_loss():
    cfg = get_smoke_config("qwen2_0_5b")
    params = T.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=40)
    opt = adamw_init(params)
    batch = make_train_batch(cfg, CELL, dtype=jnp.float32)

    @jax.jit
    def step(p, o):
        (loss, _), g = jax.value_and_grad(
            lambda p: T.forward_train(p, cfg, batch), has_aux=True)(p)
        p, o, m = adamw_update(opt_cfg, p, g, o)
        return p, o, loss

    losses = []
    for _ in range(25):
        params, opt, loss = step(params, opt)
        losses.append(float(loss))
    # memorizing one small batch must drive the loss down hard
    assert losses[-1] < losses[0] - 1.0, losses[::6]


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    lrs = [float(cosine_schedule(cfg, jnp.asarray(s))) for s in
           [0, 5, 10, 55, 100]]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 0.5) < 1e-6
    assert abs(lrs[2] - 1.0) < 1e-6
    assert 0.1 < lrs[3] < 1.0
    assert abs(lrs[4] - 0.1) < 1e-6


def test_int8_compression_error_feedback():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(1000).astype(np.float32))
    q, s, r = int8_compress(x)
    deq = int8_decompress(q, s)
    # quantization error bounded by scale/2 per element
    assert float(jnp.max(jnp.abs(deq - x))) <= float(s) * 0.51
    # error feedback: residual + deq == original
    np.testing.assert_allclose(np.asarray(deq + r), np.asarray(x),
                               atol=1e-6)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 4), jnp.bfloat16),
                  "d": jnp.asarray(7, jnp.int32)}}
    p = str(tmp_path / "ck")
    save_pytree(tree, p)
    out = restore_pytree(tree, p)
    for x, y in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


def test_checkpoint_manager_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    tree = {"w": jnp.zeros((4,))}
    for s in [10, 20, 30, 40]:
        mgr.save(s, tree)
    mgr.wait()
    assert mgr.latest_step() == 40
    files = sorted(os.listdir(tmp_path))
    assert len(files) == 2, files


def test_trainer_failure_recovery_bitwise(tmp_path):
    """Kill training mid-run; restart must reproduce the uninterrupted run
    bit-for-bit (deterministic data + checkpointed optimizer)."""
    cfg = get_smoke_config("qwen2_5_3b")
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=30)

    def mk(step):
        return make_train_batch(cfg, CELL, seed=7, step=step,
                                dtype=jnp.float32)

    def run(ckpt_dir, fail_at=None):
        def hook(step):
            if fail_at is not None and step == fail_at:
                raise SimulatedFailure(f"injected at {step}")

        tr = Trainer(cfg, CELL, opt_cfg,
                     TrainerConfig(total_steps=12, ckpt_every=5,
                                   ckpt_dir=ckpt_dir, log_every=100),
                     make_batch=mk, failure_hook=hook, seed=3)
        resumed = tr.maybe_resume()
        try:
            tr.run()
        except SimulatedFailure:
            tr.mgr.wait()
            return None, resumed
        return tr.params, resumed

    # uninterrupted reference
    ref_params, _ = run(str(tmp_path / "ref"))

    # failing run: dies at step 8 (after the step-5 checkpoint)
    out, resumed = run(str(tmp_path / "ft"), fail_at=8)
    assert out is None and not resumed
    # restart: resumes from step 5 and finishes
    params2, resumed = run(str(tmp_path / "ft"))
    assert resumed
    for a, b in zip(jax.tree_util.tree_leaves(ref_params),
                    jax.tree_util.tree_leaves(params2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
