"""Differential + invariant tests for the packed-block probe
(DESIGN.md §12): the Pallas hybrid-search kernel on the hot path.

B1  Differential equivalence: identical random mixed workloads driven
    through two clusters — ``block_probe`` on vs. off — with channel
    delays and a live balancer issuing Splits/Moves/Merges, must produce
    op-for-op identical results and identical final key sets, both equal
    to the sequential oracle. The off-side's pointer-walk ``probe_batch``
    is the differential oracle the kernel path is judged against.
B2  Nemesis-schedule parity: one known-nasty corpus schedule (drop + dup
    + reorder + delay) replayed with the probe on and off; both must pass
    the oracle check and end with identical key sets, and the on-side
    must actually hit blocks (non-vacuity).
B3  Whitebox mirror invariant: at quiescence with the probe on, every
    ``blk.valid`` row's key/idx columns byte-mirror its registered
    sublist's live chain, padded with ST_KEY — the "blocks are a cache,
    never a source of truth" discipline is observable, not aspirational.
B4  Non-vacuity on a quiescent list: a read-only batch over a stable
    cluster is answered entirely by the block probe (``blk_hits`` counts
    every lane), and the answers are right.
"""
import json
import pathlib

import numpy as np
import pytest

from repro.core.balancer import Balancer
from repro.core.oracle import OracleList
from repro.core.sim import Cluster
from repro.core.types import (DiLiConfig, ST_KEY, OP_FIND, OP_INSERT,
                              OP_REMOVE)

CFG = DiLiConfig(num_shards=2, pool_capacity=4096, max_sublists=32,
                 max_ctrs=32, max_scan=4096, batch_size=16,
                 mailbox_cap=256, move_batch=8, split_threshold=48,
                 find_fastpath=True, block_probe=True)


def _workload(seed, n_ops, key_space, read_frac):
    rng = np.random.default_rng(seed)
    w = (1 - read_frac) / 2
    kinds = rng.choice([OP_FIND, OP_INSERT, OP_REMOVE], n_ops,
                       p=[read_frac, w, w])
    keys = rng.integers(1, key_space, n_ops)
    return kinds.tolist(), keys.tolist()


def _drive(cfg, kinds, keys, *, seed, delay, balance_every=3):
    cl = Cluster(cfg, seed=seed, delay_prob=delay)
    bal = Balancer(cl)
    ids = []
    b = cfg.batch_size
    r = 0
    for i in range(0, len(kinds), b):
        ids += cl.submit(0, kinds[i:i + b], keys[i:i + b])
        cl.step()
        if r % balance_every == balance_every - 1:
            bal.step()
        r += 1
    cl.run_until_quiet(2000)
    return [cl.results[j] for j in ids], cl.all_keys(), dict(cl.stats), cl


@pytest.mark.parametrize("seed,read_frac,delay", [
    (0, 0.6, 0.25),
    (2, 0.3, 0.2),
])
def test_differential_block_probe_vs_pointer_walk(seed, read_frac, delay):
    """B1: block probe on == off, op for op, under bg churn + delays."""
    kinds, keys = _workload(seed, 480, 160, read_frac)

    res_on, keys_on, st_on, _ = _drive(
        CFG, kinds, keys, seed=seed + 7, delay=delay)
    res_off, keys_off, st_off, _ = _drive(
        CFG._replace(block_probe=False), kinds, keys,
        seed=seed + 7, delay=delay)

    assert st_off["blk_hits"] == 0
    assert st_on["blk_hits"] > 0, \
        "block probe never fired — differential test is vacuous"
    assert res_on == res_off, "block probe changed an op result"
    assert keys_on == keys_off, "block probe changed the final key set"

    oracle = OracleList()
    expected = oracle.apply_batch(kinds, keys)
    assert [bool(v) for v in res_on] == expected
    assert keys_on == sorted(oracle.snapshot())


def test_block_probe_nemesis_schedule_parity():
    """B2: a nemesis corpus schedule with the probe on and off — both
    oracle-clean, identical key sets, on-side non-vacuous."""
    from nemesis_harness import check, run_differential
    from repro.core.net import NemesisConfig

    corpus = json.loads(
        (pathlib.Path(__file__).parent / "nemesis_corpus.json").read_text())
    entry = corpus["entries"][0]          # mixed-p02
    nemesis = NemesisConfig.from_dict(entry["config"])
    repro = nemesis.repro(entry["seed"])

    runs = {}
    for on in (True, False):
        res = run_differential(
            "local", entry["seed"], nemesis, n_ops=entry["n_ops"],
            num_shards=2, key_space=300, keep_backend=True,
            cfg_overrides={"block_probe": on})
        check(res, repro + f" block_probe={on}")
        runs[on] = res
    assert runs[True]["final_keys"] == runs[False]["final_keys"]
    assert runs[False]["backend"].cluster.stats["blk_hits"] == 0
    assert runs[True]["backend"].cluster.stats["blk_hits"] > 0, \
        "probe never fired under the nemesis schedule"


def test_block_rows_mirror_chains_at_quiescence():
    """B3: every valid block row == its chain, in keys AND link idxs."""
    cl = Cluster(CFG)
    bal = Balancer(cl)
    rng = np.random.default_rng(5)
    kinds, keys = _workload(5, 480, 400, 0.3)
    b = CFG.batch_size
    for r, i in enumerate(range(0, len(kinds), b)):
        cl.submit(0, kinds[i:i + b], keys[i:i + b])
        cl.step()
        if r % 3 == 2:
            bal.step()
    cl.run_until_quiet(2000)
    # settle one more round so refresh_blocks runs over the quiet state
    cl.submit(0, [OP_FIND], [1])
    cl.run_until_quiet(200)

    c = CFG.block_cap
    checked = 0
    for s in range(cl.n):
        st = cl.states[s]
        valid = np.asarray(st.blk.valid)
        bkeys = np.asarray(st.blk.keys)
        bidx = np.asarray(st.blk.idx)
        subs = cl.sublists(s)
        for e, sub in enumerate(subs):
            if not valid[e]:
                continue
            assert sub["owner"] == s and not sub["switched"], \
                (s, e, "valid block row for a non-local/switched entry")
            items = cl.shard_chain(s, sub["head_idx"], include_meta=True)
            ck = [k for k, _, _ in items]
            ci = [i for _, i, _ in items]
            n = len(ck)
            assert n <= c
            np.testing.assert_array_equal(bkeys[e, :n], ck, err_msg=(s, e))
            np.testing.assert_array_equal(bidx[e, :n], ci, err_msg=(s, e))
            assert (bkeys[e, n:] == ST_KEY).all(), (s, e, "pad not ST_KEY")
            checked += 1
    assert checked > 0, "no valid block rows at quiescence — vacuous"


def test_block_probe_pure_reads_all_hit():
    """B4: on a quiescent list every read is answered by the kernel."""
    cl = Cluster(CFG)
    base = list(range(10, 400, 3))
    cl.submit(0, [OP_INSERT] * len(base), base)
    cl.run_until_quiet(800)
    hits0 = cl.stats["blk_hits"]

    rng = np.random.default_rng(3)
    qs = rng.integers(1, 450, 64).tolist()
    ids = cl.submit(0, [OP_FIND] * len(qs), qs)
    cl.run_until_quiet(400)
    assert cl.stats["blk_hits"] - hits0 == len(qs)
    for j, q in zip(ids, qs):
        assert bool(cl.results[j]) == (q in set(base))
