"""Split / Move / Switch under concurrent client load, vs the oracle."""
import numpy as np
import pytest

from repro.core import background as B
from repro.core.oracle import OracleList
from repro.core.sim import Cluster
from repro.core.types import (DiLiConfig, OP_FIND, OP_INSERT, OP_REMOVE)


def mkcfg(**kw):
    base = dict(num_shards=2, pool_capacity=2048, max_sublists=32,
                max_ctrs=32, max_scan=2048, batch_size=32, mailbox_cap=256,
                move_batch=8)
    base.update(kw)
    return DiLiConfig(**base)


def submit_and_expect(cl, oracle, shard, kinds, keys):
    ids = cl.submit(shard, kinds, keys)
    exp = oracle.apply_batch(kinds, keys)
    return list(zip(ids, exp))


def check(cl, expected):
    for op_id, exp in expected:
        assert op_id in cl.results, f"op {op_id} never completed"
        got = cl.results[op_id]
        assert got in (0, 1), f"op {op_id} error code {got}"
        assert bool(got) == exp, f"op {op_id}: got {got}, want {exp}"


def test_split_preserves_semantics():
    cfg = mkcfg(num_shards=1)
    cl = Cluster(cfg)
    oracle = OracleList()
    keys = list(range(10, 110, 2))
    exp = submit_and_expect(cl, oracle, 0, [OP_INSERT] * len(keys), keys)
    cl.run_until_quiet()
    check(cl, exp)

    subs = cl.sublists(0)
    assert len(subs) == 1
    mid = cl.middle_item(0, subs[0]["head_idx"])
    cl.split(0, subs[0]["keymax"], mid)
    cl.run_until_quiet()

    subs = cl.sublists(0)
    assert len(subs) == 2, subs
    assert subs[0]["keymax"] == subs[1]["keymin"]
    assert subs[0]["size"] + subs[1]["size"] == len(keys)
    assert cl.all_keys() == sorted(oracle.snapshot())

    # ops keep working across the split boundary
    kinds = [OP_FIND, OP_INSERT, OP_REMOVE, OP_FIND, OP_INSERT]
    ks = [10, 11, 10, 10, 10]
    exp = submit_and_expect(cl, oracle, 0, kinds, ks)
    cl.run_until_quiet()
    check(cl, exp)
    assert cl.all_keys() == sorted(oracle.snapshot())


def test_split_during_concurrent_ops():
    cfg = mkcfg(num_shards=1)
    cl = Cluster(cfg)
    oracle = OracleList()
    rng = np.random.default_rng(0)
    keys = sorted(rng.choice(np.arange(1, 1000), 80, replace=False).tolist())
    exp = submit_and_expect(cl, oracle, 0, [OP_INSERT] * len(keys), keys)
    cl.run_until_quiet()
    check(cl, exp)

    subs = cl.sublists(0)
    mid = cl.middle_item(0, subs[0]["head_idx"])
    cl.split(0, subs[0]["keymax"], mid)
    # interleave client ops with the split's rounds
    all_exp = []
    for _ in range(6):
        kinds = rng.choice([OP_FIND, OP_INSERT, OP_REMOVE], 10).tolist()
        ks = rng.integers(1, 1000, 10).tolist()
        all_exp += submit_and_expect(cl, oracle, 0, kinds, ks)
        cl.step()
    cl.run_until_quiet()
    check(cl, all_exp)
    assert cl.all_keys() == sorted(oracle.snapshot())
    assert len(cl.sublists(0)) == 2


def test_move_quiet():
    """Move a sublist with no concurrent load; ownership transfers."""
    cfg = mkcfg()
    cl = Cluster(cfg)
    oracle = OracleList()
    keys = list(range(5, 65, 3))
    exp = submit_and_expect(cl, oracle, 0, [OP_INSERT] * len(keys), keys)
    cl.run_until_quiet()
    check(cl, exp)

    subs = cl.sublists(0)
    cl.move(0, subs[0]["keymax"], target=1)
    cl.run_until_quiet(400)

    # ownership switched to shard 1, registry replicated on both shards
    for s in range(2):
        subs = cl.sublists(s)
        assert len(subs) == 1
        assert subs[0]["owner"] == 1, subs
    assert cl.all_keys() == sorted(oracle.snapshot())

    # ops from either assigned server still linearize correctly
    kinds = [OP_FIND, OP_REMOVE, OP_INSERT, OP_FIND]
    ks = [5, 5, 5, 5]
    exp = submit_and_expect(cl, oracle, 0, kinds, ks)
    cl.run_until_quiet()
    check(cl, exp)
    exp = submit_and_expect(cl, oracle, 1, [OP_FIND], [8])
    cl.run_until_quiet()
    check(cl, exp)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_move_under_write_load(seed):
    """Client updates race the Move; temporary replication + replay must
    reconstruct an identical live clone (paper Thm 10)."""
    cfg = mkcfg()
    cl = Cluster(cfg)
    oracle = OracleList()
    rng = np.random.default_rng(seed)
    keys = sorted(rng.choice(np.arange(1, 500), 60, replace=False).tolist())
    exp = submit_and_expect(cl, oracle, 0, [OP_INSERT] * len(keys), keys)
    cl.run_until_quiet()
    check(cl, exp)

    subs = cl.sublists(0)
    cl.move(0, subs[0]["keymax"], target=1)
    all_exp = []
    for i in range(12):
        kinds = rng.choice([OP_INSERT, OP_REMOVE, OP_FIND], 8,
                           p=[0.45, 0.45, 0.1]).tolist()
        ks = rng.integers(1, 500, 8).tolist()
        # alternate the assigned server to exercise delegation
        all_exp += submit_and_expect(cl, oracle, i % 2, kinds, ks)
        cl.step()
    cl.run_until_quiet(600)
    check(cl, all_exp)
    assert cl.all_keys() == sorted(oracle.snapshot())
    # the move completed: shard 1 owns the sublist everywhere
    for s in range(2):
        assert all(e["owner"] == 1 for e in cl.sublists(s))
    assert cl.stats["max_hops"] <= 4, cl.stats


@pytest.mark.parametrize("seed", [0, 1])
def test_move_with_channel_delays(seed):
    """Cross-pair reordering: replicates may arrive before the items they
    reference — the replay retry loop must heal (bounded retries)."""
    cfg = mkcfg()
    cl = Cluster(cfg, delay_prob=0.35, seed=seed)
    oracle = OracleList()
    rng = np.random.default_rng(seed + 100)
    keys = sorted(rng.choice(np.arange(1, 300), 40, replace=False).tolist())
    exp = submit_and_expect(cl, oracle, 0, [OP_INSERT] * len(keys), keys)
    cl.run_until_quiet(400)
    check(cl, exp)

    subs = cl.sublists(0)
    cl.move(0, subs[0]["keymax"], target=1)
    all_exp = []
    for i in range(16):
        kinds = rng.choice([OP_INSERT, OP_REMOVE], 6).tolist()
        ks = rng.integers(1, 300, 6).tolist()
        all_exp += submit_and_expect(cl, oracle, i % 2, kinds, ks)
        cl.step()
    cl.run_until_quiet(800)
    check(cl, all_exp)
    assert cl.all_keys() == sorted(oracle.snapshot())


def test_split_then_move_each_half():
    cfg = mkcfg(num_shards=3)
    cl = Cluster(cfg)
    oracle = OracleList()
    keys = list(range(2, 202, 4))
    exp = submit_and_expect(cl, oracle, 0, [OP_INSERT] * len(keys), keys)
    cl.run_until_quiet()
    check(cl, exp)

    subs = cl.sublists(0)
    mid = cl.middle_item(0, subs[0]["head_idx"])
    cl.split(0, subs[0]["keymax"], mid)
    cl.run_until_quiet()
    subs = cl.sublists(0)
    assert len(subs) == 2

    cl.move(0, subs[0]["keymax"], target=1)
    cl.run_until_quiet(400)
    cl.move(0, subs[1]["keymax"], target=2)
    cl.run_until_quiet(400)

    owners = sorted(e["owner"] for e in cl.sublists(0))
    assert owners == [1, 2]
    assert cl.all_keys() == sorted(oracle.snapshot())

    # traffic from every assigned server, spanning both moved sublists
    all_exp = []
    rng = np.random.default_rng(7)
    for s in range(3):
        kinds = rng.choice([OP_FIND, OP_INSERT, OP_REMOVE], 12).tolist()
        ks = rng.integers(1, 220, 12).tolist()
        all_exp += submit_and_expect(cl, oracle, s, kinds, ks)
    cl.run_until_quiet(400)
    check(cl, all_exp)
    assert cl.all_keys() == sorted(oracle.snapshot())
