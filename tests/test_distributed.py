"""SPMD shard_map backend: semantics must match the simulator backend.

Runs in a subprocess with XLA host devices so the main test session keeps a
single-device view (the dry-run is the only consumer of many devices).
"""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh

    from repro.core import messages as M
    from repro.core import background as B
    from repro.core.distributed import make_dili_round, stack_states
    from repro.core.oracle import OracleList
    from repro.core.sim import Cluster
    from repro.core.types import (DiLiConfig, OP_FIND, OP_INSERT, OP_REMOVE,
                                  RES_PENDING)

    cfg = DiLiConfig(num_shards=4, pool_capacity=1024, max_sublists=16,
                     max_ctrs=16, max_scan=1024, batch_size=8,
                     mailbox_cap=64, move_batch=4)
    CAP_PAIR = 16
    mesh = Mesh(np.array(jax.devices()).reshape(4), ("shard",))

    # borrow the simulator for initial states (registry replicas included)
    sim = Cluster(cfg)
    states, bgs = stack_states(sim.states, sim.bgs)
    rnd = make_dili_round(mesh, cfg, cap_pair=CAP_PAIR)

    inbox = jnp.zeros((4, 4 * CAP_PAIR, M.FIELDS), jnp.int32)
    oracle = OracleList()
    rng = np.random.default_rng(0)
    results = {}
    expected = {}
    slot = 0

    def client_batch(round_i):
        global slot
        rows = np.zeros((4, cfg.batch_size, M.FIELDS), np.int32)
        if round_i % 2:          # alternate load and drain rounds
            return jnp.asarray(rows)
        for s in range(4):
            for b in range(cfg.batch_size):
                kind = int(rng.choice([OP_FIND, OP_INSERT, OP_REMOVE]))
                key = int(rng.integers(1, 60))
                rows[s, b] = 0
                rows[s, b, M.F_KIND] = M.MSG_OP
                rows[s, b, M.F_DST] = s
                rows[s, b, M.F_SRC] = s
                rows[s, b, M.F_A] = kind
                rows[s, b, M.F_KEY] = key
                rows[s, b, M.F_REF1] = np.int64(0x003FFFFF).astype(np.int32)
                rows[s, b, M.F_SID] = s
                rows[s, b, M.F_TS] = slot
                expected[slot] = oracle.apply(kind, key)
                slot += 1
        return jnp.asarray(rows)

    zeros = jnp.zeros((4, cfg.batch_size, M.FIELDS), jnp.int32)
    for r in range(38):
        batch = client_batch(r) if r < 30 else zeros  # 8 drain rounds
        states, bgs, inbox, cs, cv, _csrc, _ckey, _cnt, _hits = rnd(
            states, bgs, inbox, batch)
        cs, cv = np.asarray(cs), np.asarray(cv)
        for s in range(4):
            for a, b in zip(cs[s], cv[s]):
                if a >= 0:
                    results[int(a)] = int(b)

    missing = [k for k in expected if k not in results]
    assert not missing, f"ops never completed: {missing[:10]}"
    bad = {k: (results[k], expected[k]) for k in expected
           if bool(results[k]) != expected[k]}
    assert not bad, f"mismatches: {dict(list(bad.items())[:5])}"
    print(f"OK {len(expected)} ops linearized correctly on shard_map backend")
""")


@pytest.mark.slow
def test_shard_map_backend_matches_oracle():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "OK" in r.stdout
