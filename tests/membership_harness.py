"""Shared elastic-membership differential harness (DESIGN.md §13).

One workload, importable by the tests and runnable as a script (the
ShardMap backend needs ``XLA_FLAGS=--xla_force_host_platform_device_count``
set before jax imports, so multi-device runs go through a subprocess):

  * a fixed-capacity cluster boots with a subset of its shards active;
  * a round-scheduled membership script fires ``join_shard`` /
    ``retire_shard`` while a ``DiLiClient`` drives continuous mixed
    find/insert/remove traffic (per-key FIFO admission makes the
    sequential oracle the referee, exactly as in the nemesis harness);
  * each event waits for the previous one to finish (a join is done when
    the shard is promoted, a retire when the drain completes) — the
    membership layer itself enforces one overlapping change per kind;
  * every op's result, the final key set, quiescence, AND the membership
    outcome (expected final active set, empty joining/draining) are
    checked; with a nemesis attached the schedule must still converge.

``python tests/membership_harness.py <backend> <n_ops> <p> <seed>...``
runs one differential per seed under drop/dup/reorder probability ``p``
(0 disables the nemesis) and prints ``OK`` lines; failures print a
``FAILING-SEEDS`` json line and exit 1.
"""
from __future__ import annotations

import json
import sys

import numpy as np

from nemesis_harness import default_nemesis, make_backend, small_cfg

# The acid-test schedule: 3 -> 5 -> 2 under continuous traffic.  Events
# are (round_due, op, shard); ``shard=None`` lets the membership layer
# pick (joins take the lowest retired slot, retires evict the highest
# active id — deterministic either way).  An event only fires once the
# cluster is past its due round AND no other change is in flight.
SCALE_3_5_2 = (
    (10, "join", None),
    (30, "join", None),
    (60, "retire", None),
    (90, "retire", None),
    (120, "retire", None),
)


def _round_no(backend):
    return backend.cluster.round_no if hasattr(backend, "cluster") \
        else backend.round_no


def _fire(backend, op, shard):
    mb = backend.membership
    if op == "join":
        return backend.join_shard(shard)
    if shard is None:
        shard = max(mb.active)
    backend.retire_shard(shard)
    return shard


def run_membership_differential(backend_kind: str, seed: int, nemesis, *,
                                schedule=SCALE_3_5_2, n_ops: int = 600,
                                key_space: int = 500, capacity: int = 6,
                                initial_shards: int = 3,
                                ops_per_round: int = 8,
                                drain_rounds: int = 20000,
                                keep_backend: bool = False):
    """One elastic-membership differential; returns a result dict
    (raises on drain timeout, asserts nothing itself)."""
    from repro.api import DiLiClient, LocalBackend, ShardMapBackend
    from repro.core.balancer import Balancer
    from repro.core.oracle import OracleList
    from repro.core.types import OP_FIND, OP_INSERT, OP_REMOVE

    cfg = small_cfg(capacity, big=(backend_kind == "local"))
    cls = LocalBackend if backend_kind == "local" else ShardMapBackend
    backend = cls(cfg, seed=seed, nemesis=nemesis,
                  initial_shards=initial_shards)
    bal = Balancer(backend, split_threshold=24, merge_threshold=6,
                   rng=backend.balancer_rng)
    client = DiLiClient(backend, balance=bal, balance_every=3)
    oracle = OracleList()
    rng = np.random.default_rng(seed + 1)
    mb = backend.membership

    n_load = min(max(key_space // 4, 20), 150)
    base = rng.permutation(np.arange(1, key_space))[:n_load].tolist()
    load = client.insert_batch(base)
    oracle.apply_batch([OP_INSERT] * len(base), base)
    client.drain(drain_rounds, run_balance=True)

    pending = list(schedule)
    fired = []        # (round_fired, op, shard)

    def maybe_fire():
        if not pending or mb.joining or mb.draining:
            return
        due, op, shard = pending[0]
        if _round_no(backend) < due:
            return
        s = _fire(backend, op, shard)
        fired.append((_round_no(backend), op, s))
        pending.pop(0)

    futs, exps = [load], [[True] * len(base)]
    done = 0
    stall = 0
    while done < n_ops or pending:
        maybe_fire()
        if done < n_ops:
            k = min(ops_per_round, n_ops - done)
            kinds = rng.choice([OP_FIND, OP_INSERT, OP_REMOVE], k).tolist()
            keys = rng.integers(1, key_space, k).tolist()
            futs.append(client.submit(kinds, keys))
            exps.append(oracle.apply_batch(kinds, keys))
            done += k
            client.pump()
        else:
            # op stream exhausted but the schedule isn't: finish any
            # in-flight change (settle runs the balancer, which drains
            # retiring shards and seeds joining ones), then idle-step up
            # to the next event's due round
            client.settle(max_rounds=drain_rounds)
            if pending and not (mb.joining or mb.draining) \
                    and _round_no(backend) < pending[0][0]:
                client.pump()
            stall += 1
            if stall > drain_rounds:
                raise RuntimeError(
                    f"membership schedule stalled: fired={fired} "
                    f"pending={pending} view={mb.view()}")
    client.drain(drain_rounds)
    client.settle(max_rounds=drain_rounds)

    mismatches = []
    for batch, exp in zip(futs, exps):
        for fut, (got, e) in zip(batch, zip(batch.results(), exp)):
            if bool(got) != e:
                mismatches.append((fut.kind, fut.key, e, got))
    final = backend.all_keys()
    n_joins = sum(1 for _, op, _ in fired if op == "join")
    n_retires = sum(1 for _, op, _ in fired if op == "retire")
    return {
        "mismatches": mismatches,
        "keys_match": final == sorted(oracle.snapshot()),
        "final_keys": final,
        "oracle_keys": sorted(oracle.snapshot()),
        "quiescent": backend.quiescent(),
        "rounds": _round_no(backend),
        "schedule_done": not pending,
        "fired": fired,
        "view": mb.view(),
        "mb_log": list(mb.log),
        "expected_active": initial_shards + n_joins - n_retires,
        "net_stats": dict(backend.net.stats) if backend.net else {},
        "trace": (backend.cluster.round_trace
                  if backend_kind == "local" else backend.round_trace),
        "backend": backend if keep_backend else None,
    }


def check(res: dict, repro: str) -> None:
    assert not res["mismatches"], \
        f"op results diverged {res['mismatches'][:5]} — repro {repro}"
    assert res["keys_match"], \
        (f"final key sets diverged — repro {repro}\n"
         f"extra={sorted(set(res['final_keys'])-set(res['oracle_keys']))} "
         f"missing={sorted(set(res['oracle_keys'])-set(res['final_keys']))}")
    assert res["schedule_done"], \
        f"membership schedule stalled ({res['fired']}) — repro {repro}"
    v = res["view"]
    assert not v["joining"] and not v["draining"], \
        f"membership change still in flight {v} — repro {repro}"
    assert len(v["active"]) == res["expected_active"], \
        f"active set {v['active']} != expected — repro {repro}"
    assert res["quiescent"], f"backend did not quiesce — repro {repro}"


def main(argv) -> int:
    kind, n_ops, p = argv[0], int(argv[1]), float(argv[2])
    seeds = [int(s) for s in argv[3:]]
    nemesis = default_nemesis(p) if p > 0 else None
    failures = []
    for seed in seeds:
        repro = nemesis.repro(seed) if nemesis else f"seed={seed} (no nemesis)"
        try:
            res = run_membership_differential(kind, seed, nemesis,
                                              n_ops=n_ops)
            check(res, repro)
            print(f"OK {kind} seed={seed} p={p} rounds={res['rounds']} "
                  f"fired={res['fired']} active={res['view']['active']}",
                  flush=True)
        except AssertionError as e:
            print(f"FAIL {kind} {repro}\n{e}", flush=True)
            failures.append({"seed": seed, "p": p, "backend": kind,
                             "config": nemesis.to_dict() if nemesis else None,
                             "error": str(e)})
    if failures:
        print("FAILING-SEEDS " + json.dumps(failures), flush=True)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
