"""Differential + invariant tests for the batched FIND fast-path.

D1  Differential equivalence (DESIGN.md §4): identical random mixed
    workloads driven through two clusters — fastpath on vs. off — with
    channel delays and a live balancer issuing Splits/Moves, must produce
    op-for-op identical results and identical final key sets, both equal
    to the sequential oracle.
D2  The fast-path actually fires (guards against a silently never-eligible
    pre-pass making D1 vacuous).
D3  Sentinel error codes: RES_OVERFLOW / RES_POOLFULL never surface while
    the balancer keeps sublists under split_threshold — the invariant
    ops.py promises but nothing asserted until now.
D4  Deleted-while-moving regression: a marked item of a moving sublist is
    delink-exempt, so the search may return it — it must read as absent
    (find FALSE, re-insert TRUE) and a subsequent insert must not erase
    its deletion mark (resurrection).
"""
import numpy as np
import pytest

from repro.core.balancer import Balancer
from repro.core.oracle import OracleList
from repro.core.ops import RES_OVERFLOW, RES_POOLFULL
from repro.core.sim import Cluster
from repro.core.types import (DiLiConfig, OP_FIND, OP_INSERT, OP_REMOVE,
                              RES_FALSE, RES_PENDING, RES_TRUE)

CFG = DiLiConfig(num_shards=2, pool_capacity=4096, max_sublists=32,
                 max_ctrs=32, max_scan=4096, batch_size=16,
                 mailbox_cap=256, move_batch=8, split_threshold=48,
                 find_fastpath=True)


def _workload(seed, n_ops, key_space, read_frac):
    rng = np.random.default_rng(seed)
    w = (1 - read_frac) / 2
    kinds = rng.choice([OP_FIND, OP_INSERT, OP_REMOVE], n_ops,
                       p=[read_frac, w, w])
    keys = rng.integers(1, key_space, n_ops)
    return kinds.tolist(), keys.tolist()


def _drive(cfg, kinds, keys, *, seed, delay, balance_every=3):
    """Run one cluster over the workload; returns (results, keys, hits)."""
    cl = Cluster(cfg, seed=seed, delay_prob=delay)
    bal = Balancer(cl)
    ids = []
    b = cfg.batch_size
    r = 0
    for i in range(0, len(kinds), b):
        # all fresh ops enter at shard 0 so it overloads and Moves fire
        ids += cl.submit(0, kinds[i:i + b], keys[i:i + b])
        cl.step()
        if r % balance_every == balance_every - 1:
            bal.step()
        r += 1
    cl.run_until_quiet(2000)
    return [cl.results[j] for j in ids], cl.all_keys(), cl.stats["fast_hits"]


@pytest.mark.parametrize("seed,read_frac,delay", [
    (0, 0.6, 0.25),
    (1, 0.9, 0.15),
])
def test_differential_fastpath_vs_serial(seed, read_frac, delay):
    """D1 + D2: fastpath on == fastpath off, op for op, under bg churn."""
    kinds, keys = _workload(seed, 480, 160, read_frac)

    res_on, keys_on, hits_on = _drive(
        CFG, kinds, keys, seed=seed + 7, delay=delay)
    res_off, keys_off, hits_off = _drive(
        CFG._replace(find_fastpath=False, mut_fastpath=False), kinds, keys,
        seed=seed + 7, delay=delay)

    assert hits_off == 0
    assert hits_on > 0, "fast-path never fired — differential test is vacuous"
    assert res_on == res_off, "fastpath changed an op result"
    assert keys_on == keys_off, "fastpath changed the final key set"

    oracle = OracleList()
    expected = oracle.apply_batch(kinds, keys)
    assert [bool(v) for v in res_on] == expected
    assert keys_on == sorted(oracle.snapshot())


def test_fastpath_pure_reads_all_hit():
    """D2: on a quiescent list, a read-only batch is answered entirely by
    the fast-path (nothing to collide with, nothing moving)."""
    cl = Cluster(CFG)
    base = list(range(10, 400, 3))
    cl.submit(0, [OP_INSERT] * len(base), base)
    cl.run_until_quiet(800)
    hits0 = cl.stats["fast_hits"]

    rng = np.random.default_rng(3)
    qs = rng.integers(1, 450, 64).tolist()
    ids = cl.submit(0, [OP_FIND] * len(qs), qs)
    cl.run_until_quiet(400)
    assert cl.stats["fast_hits"] - hits0 == len(qs)
    for j, q in zip(ids, qs):
        assert bool(cl.results[j]) == (q in set(base))


def test_deleted_while_moving_reads_absent():
    """D4: mid-Move, remove a copied item (marked + newLoc set, so the
    search returns it undelinked), then re-insert it and insert its
    successor — presence answers and the final key set must match the
    oracle, with no mark erasure resurrecting the removed key."""
    from repro.core import refs

    cfg = CFG._replace(move_batch=1, find_fastpath=False,
                       mut_fastpath=False)
    cl = Cluster(cfg)
    base = list(range(10, 90, 10))        # 10..80, one bootstrap sublist
    cl.submit(0, [OP_INSERT] * len(base), base)
    cl.run_until_quiet(400)

    subs = [e for e in cl.sublists(0) if e["owner"] == 0]
    cl.move(0, subs[0]["keymax"], 1)
    # step until the first items are copied (newLoc set) but the sublist
    # has not switched (stCt >= 0): the deleted-while-moving window
    k = base[0]
    for _ in range(40):
        cl.step()
        st = cl.states[0]
        pool_keys = np.asarray(st.pool.key)
        idxs = np.where(pool_keys == k)[0]
        has_newloc = any(
            int(np.asarray(st.pool.newloc)[i]) != refs.NULL_REF
            for i in idxs)
        slot = int(np.asarray(st.pool.ctr)[idxs[0]]) if len(idxs) else 0
        if has_newloc and int(np.asarray(st.stct)[slot]) >= 0:
            break
    else:
        pytest.skip("could not catch the mid-move window")

    ids = cl.submit(0, [OP_REMOVE, OP_FIND, OP_INSERT, OP_INSERT, OP_FIND,
                        OP_FIND],
                    [k, k, k, k + 1, k, k + 1])
    cl.run_until_quiet(1500)
    got = [bool(cl.results[i]) for i in ids]
    assert got == [True, False, True, True, True, True], got

    oracle = OracleList(base)
    oracle.apply_batch([OP_REMOVE, OP_INSERT, OP_INSERT], [k, k, k + 1])
    assert cl.all_keys() == sorted(oracle.snapshot())


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_sentinel_codes_never_surface_under_balancer(seed):
    """D3: with the balancer holding sublists under split_threshold, no op
    ever reports RES_OVERFLOW or RES_POOLFULL (and none stays pending)."""
    cfg = CFG._replace(max_scan=512, split_threshold=40)
    kinds, keys = _workload(seed, 480, 300, 0.2)  # write-heavy: growth
    res, final_keys, _ = _drive(cfg, kinds, keys, seed=seed, delay=0.1,
                                balance_every=2)
    bad = {RES_OVERFLOW, RES_POOLFULL, RES_PENDING}
    assert not bad.intersection(res), \
        f"sentinel codes surfaced: {sorted(set(res) & bad)}"
    assert set(res) <= {RES_FALSE, RES_TRUE}

    oracle = OracleList()
    oracle.apply_batch(kinds, keys)
    assert final_keys == sorted(oracle.snapshot())
