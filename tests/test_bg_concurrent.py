"""Slotted background engine: concurrent Split+Move+Merge on one shard,
entry claims, the batched migration pipeline, and the background shim."""
import numpy as np
import pytest

from repro.core import background as B          # the compat shim, on purpose
from repro.core import bg
from repro.core import messages as M
from repro.core import refs
from repro.core.oracle import OracleList
from repro.core.sim import Cluster, make_op_row
from repro.core.types import DiLiConfig, OP_FIND, OP_INSERT, OP_REMOVE


def mkcfg(**kw):
    base = dict(num_shards=2, pool_capacity=4096, max_sublists=32,
                max_ctrs=32, max_scan=4096, batch_size=32, mailbox_cap=256,
                move_batch=4, bg_slots=3)
    base.update(kw)
    return DiLiConfig(**base)


def submit_and_expect(cl, oracle, shard, kinds, keys):
    ids = cl.submit(shard, kinds, keys)
    exp = oracle.apply_batch(kinds, keys)
    return list(zip(ids, exp))


def check(cl, expected):
    for op_id, exp in expected:
        assert op_id in cl.results, f"op {op_id} never completed"
        got = cl.results[op_id]
        assert got in (0, 1), f"op {op_id} error code {got}"
        assert bool(got) == exp, f"op {op_id}: got {got}, want {exp}"


def _grow_sublists(cl, oracle, keys, want):
    """Insert ``keys`` then split shard 0's largest sublist until it owns
    ``want`` sublists."""
    exp = submit_and_expect(cl, oracle, 0, [OP_INSERT] * len(keys), keys)
    cl.run_until_quiet(600)
    check(cl, exp)
    for _ in range(want * 2):
        owned = [e for e in cl.sublists(0) if e["owner"] == 0]
        if len(owned) >= want:
            break
        e = max(owned, key=lambda x: x["size"])
        mid = cl.middle_item(0, e["head_idx"])
        assert mid is not None
        assert cl.split(0, e["keymax"], mid)
        cl.run_until_quiet(600)
    owned = sorted((e for e in cl.sublists(0) if e["owner"] == 0),
                   key=lambda x: x["keymin"])
    assert len(owned) >= want, owned
    return owned


@pytest.mark.parametrize("delay,move_fastpath", [
    (0.0, True), (0.3, True), (0.3, False)])
def test_concurrent_split_move_merge_same_shard(delay, move_fastpath):
    """Oracle differential: one shard runs a Split, a Move and a Merge
    in-flight *simultaneously* (3 slots) under client churn and channel
    delays — full result parity and an identical final key set."""
    cfg = mkcfg(move_fastpath=move_fastpath)
    cl = Cluster(cfg, seed=11, delay_prob=delay)
    oracle = OracleList()
    keys = list(range(2, 242, 2))
    owned = _grow_sublists(cl, oracle, keys, want=4)

    e_merge_l, e_merge_r = owned[0], owned[1]
    e_move, e_split = owned[2], owned[3]
    assert e_merge_l["keymax"] == e_merge_r["keymin"]

    assert cl.merge(0, e_merge_l["keymax"], e_merge_r["keymax"])
    assert cl.move(0, e_move["keymax"], 1)
    mid = cl.middle_item(0, e_split["head_idx"])
    assert mid is not None
    assert cl.split(0, e_split["keymax"], mid)
    assert bg.free_slots(cl.bgs[0]) == 0     # all three slots busy

    rng = np.random.default_rng(5)
    all_exp = []
    max_active = 0
    for i in range(14):
        kinds = rng.choice([OP_FIND, OP_INSERT, OP_REMOVE], 8,
                           p=[0.2, 0.4, 0.4]).tolist()
        ks = rng.integers(1, 260, 8).tolist()
        all_exp += submit_and_expect(cl, oracle, i % 2, kinds, ks)
        cl.step()
        max_active = max(max_active,
                         int((bg.slot_phases(cl.bgs[0]) != bg.BG_IDLE).sum()))
    cl.run_until_quiet(2000)

    # the acceptance bar: at least two background ops genuinely in flight
    # on one shard at once, with full oracle parity
    assert max_active >= 2, max_active
    assert cl.stats["max_bg_active"] >= 2
    check(cl, all_exp)
    assert cl.all_keys() == sorted(oracle.snapshot())
    # the moved sublist switched ownership everywhere
    movers = [e for s in range(2) for e in cl.sublists(s)
              if e["keymax"] == e_move["keymax"]]
    assert movers and all(e["owner"] == 1 for e in movers)
    if move_fastpath and delay == 0.0:
        # quiet channels: every MoveItem should ride the scatter splice
        assert cl.stats["move_hits"] > 0


def test_entry_claims_are_exclusive():
    """At most one background op per registry entry: a second command on a
    claimed entry is refused until the first completes."""
    cfg = mkcfg()
    cl = Cluster(cfg)
    oracle = OracleList()
    owned = _grow_sublists(cl, oracle, list(range(5, 165, 2)), want=2)
    e = owned[0]

    assert cl.move(0, e["keymax"], 1)
    # same entry: refused regardless of free slots
    assert bg.free_slots(cl.bgs[0]) == cfg.bg_slots - 1
    mid = cl.middle_item(0, e["head_idx"])
    assert cl.split(0, e["keymax"], mid) is False
    assert cl.move(0, e["keymax"], 1) is False
    assert e["keymax"] in bg.claimed_keys(cl.bgs[0])
    # a different entry is fair game
    other = owned[1]
    mid2 = cl.middle_item(0, other["head_idx"])
    assert cl.split(0, other["keymax"], mid2)

    cl.run_until_quiet(800)
    assert bg.free_slots(cl.bgs[0]) == cfg.bg_slots
    assert bg.claimed_keys(cl.bgs[0]) == set()
    assert cl.all_keys() == sorted(oracle.snapshot())


def test_no_free_slot_drops_command():
    """With every slot claimed, further commands are refused (and report
    it) instead of silently overwriting an in-flight op."""
    cfg = mkcfg(bg_slots=1)
    cl = Cluster(cfg)
    oracle = OracleList()
    owned = _grow_sublists(cl, oracle, list(range(5, 165, 2)), want=2)
    assert cl.move(0, owned[0]["keymax"], 1)
    assert cl.split(0, owned[1]["keymax"],
                    cl.middle_item(0, owned[1]["head_idx"])) is False
    cl.run_until_quiet(800)
    assert cl.all_keys() == sorted(oracle.snapshot())


def test_move_nack_frees_slot_and_claim():
    """A MoveSH nack (target out of counter slots) must abort the move and
    free the slot — not wedge it in MOVE_SH_WAIT with the entry claimed
    forever (quiescence would never clear)."""
    import jax.numpy as jnp
    cfg = mkcfg()
    cl = Cluster(cfg)
    oracle = OracleList()
    owned = _grow_sublists(cl, oracle, list(range(5, 105, 2)), want=1)
    # exhaust the target's counter slots: h_move_sh must ack with a=0
    cl.states[1] = cl.states[1]._replace(
        ctr_top=jnp.asarray(cfg.max_ctrs, jnp.int32))
    assert cl.move(0, owned[0]["keymax"], 1)
    cl.run_until_quiet(400)          # would raise if the slot stayed busy
    assert bg.free_slots(cl.bgs[0]) == cfg.bg_slots
    assert bg.claimed_keys(cl.bgs[0]) == set()
    # the move aborted: ownership unchanged, data intact
    assert all(e["owner"] == 0 for e in cl.sublists(0))
    assert cl.all_keys() == sorted(oracle.snapshot())


def test_stale_delegation_through_quarantine_during_batched_copy():
    """Regression: an op carrying a stale subhead hint (the pre-Switch
    chain) must still forward through the quarantined block via newLoc —
    while a *second* move's batched copy is in flight on the same shard."""
    cfg = mkcfg(quarantine_rounds=64, move_batch=8)
    cl = Cluster(cfg)
    oracle = OracleList()
    owned = _grow_sublists(cl, oracle, list(range(4, 244, 3)), want=2)
    e_a, e_b = owned[0], owned[1]
    old_head_a = e_a["head_idx"]
    probe_key = next(k for k in sorted(oracle.snapshot())
                     if e_a["keymin"] < k <= e_a["keymax"])

    # move A; run until its chain is switched away (stCt < 0) but still
    # quarantined on shard 0 (quarantine_rounds is large)
    assert cl.move(0, e_a["keymax"], 1)
    for _ in range(200):
        cl.step()
        if any(e["keymax"] == e_a["keymax"] and e["switched"]
               for e in cl.sublists(0)):
            break
    else:
        pytest.fail("move A never reached the quarantine window")

    # start move B: a batched copy in flight on the same shard
    assert cl.move(0, e_b["keymax"], 1)

    # inject an op whose hint is the *old* (quarantined) subhead of A —
    # exactly what a delegation raced by the Switch would carry
    row = make_op_row(0, OP_FIND, probe_key, 0, slot=1 << 20)
    row[M.F_REF1] = np.int64(int(refs.make_ref(0, old_head_a))).astype(
        np.int32)
    cl.backlog[0] = np.concatenate([cl.backlog[0], row[None]], axis=0)
    exp = oracle.apply(OP_FIND, probe_key)

    cl.run_until_quiet(2000)
    assert (1 << 20) in cl.results
    assert bool(cl.results[1 << 20]) == exp is True
    assert cl.all_keys() == sorted(oracle.snapshot())
    for s in range(2):
        assert all(e["owner"] == 1 for e in cl.sublists(s))


def test_background_shim_reexports():
    """``repro.core.background`` must keep the pre-decomposition surface:
    old imports (tests, notebooks, downstream tools) stay working."""
    for name in ("BgState", "BgTable", "init_bg", "init_bg_table",
                 "bg_step", "queue_split", "queue_move", "queue_merge",
                 "h_rep_insert", "h_rep_delete", "h_ack_insert",
                 "h_ack_delete", "h_move_sh", "h_move_sh_ack",
                 "h_move_item", "h_move_ack", "h_switch_st",
                 "h_switch_st_ack", "h_reg_split", "h_switch_server",
                 "h_reg_merged", "BG_IDLE", "BG_SPLIT_EXEC",
                 "BG_SPLIT_WAIT", "BG_MOVE_SH", "BG_MOVE_SH_WAIT",
                 "BG_MOVE_COPY", "BG_MOVE_STABLE", "BG_SWITCH_ST",
                 "BG_SWITCH_ST_WAIT", "BG_SWITCH_REG", "BG_QUAR",
                 "BG_MERGE_EXEC", "BG_MERGE_WAIT", "BG_NUM_PHASES",
                 "FL_MARKED", "FL_ST", "any_active", "free_slots",
                 "claimed_keys", "slot_phases"):
        assert hasattr(B, name), f"shim lost {name}"
        assert getattr(B, name) is getattr(bg, name), name
    # phase ids must all fit the dispatch table (satellite: adding a phase
    # outside the range would silently alias the no-op branch)
    from repro.core.bg.engine import _PHASES
    assert all(0 <= ph < B.BG_NUM_PHASES for ph in _PHASES)
    # the slotted table really is cfg.bg_slots wide
    cfg = mkcfg(bg_slots=5)
    assert B.init_bg_table(cfg).phase.shape == (5,)
