"""Shared nemesis differential harness (DESIGN.md §11).

One workload, importable by the tests and runnable as a script (the
ShardMap backend needs ``XLA_FLAGS=--xla_force_host_platform_device_count``
set before jax imports, so multi-device runs go through a subprocess):

  * seed a key range, then drive rounds of mixed find/insert/remove
    through ``DiLiClient`` (per-key FIFO admission is what makes the
    sequential oracle the right referee — raw ``Cluster.submit`` has no
    cross-round same-key ordering, so multi-round nemesis delays could
    legally reorder concurrent same-key ops);
  * a ``Balancer`` (low split threshold + merges, seeded tie-breaks)
    races splits/moves/merges against the op stream;
  * every op's result and the final key set are checked against the
    oracle, and the backend must quiesce.

``python tests/nemesis_harness.py <backend> <n_ops> <seed> [<seed>...]``
runs one differential per seed and prints ``OK <backend> seed=<s> ...``
lines; any failure prints the ``(seed, config)`` repro and exits 1.
"""
from __future__ import annotations

import json
import sys

import numpy as np


def small_cfg(num_shards=4, *, big=True):
    from repro.core.types import DiLiConfig
    if big:
        return DiLiConfig(num_shards=num_shards, pool_capacity=4096,
                          max_sublists=32, max_ctrs=32, max_scan=4096,
                          batch_size=16, mailbox_cap=256, move_batch=8)
    # shard_map-sized: smaller pools keep the per-device round cheap on
    # a host-platform CPU mesh
    return DiLiConfig(num_shards=num_shards, pool_capacity=1024,
                      max_sublists=16, max_ctrs=16, max_scan=1024,
                      batch_size=8, mailbox_cap=64, move_batch=4)


def default_nemesis(p=0.15):
    from repro.core.net import NemesisConfig
    return NemesisConfig(drop_prob=p, dup_prob=p, reorder_prob=p,
                         delay_prob=p / 2, delay_rounds=3)


def make_backend(kind: str, cfg, seed: int, nemesis):
    if kind == "local":
        from repro.api import LocalBackend
        return LocalBackend(cfg, seed=seed, nemesis=nemesis)
    if kind == "shardmap":
        from repro.api import ShardMapBackend
        return ShardMapBackend(cfg, seed=seed, nemesis=nemesis)
    raise ValueError(f"unknown backend kind {kind!r}")


def run_differential(backend_kind: str, seed: int, nemesis, *,
                     n_ops: int = 600, key_space: int = 500,
                     num_shards: int = 4, ops_per_round: int = 8,
                     split_threshold: int = 24,
                     drain_rounds: int = 12000, keep_backend: bool = False,
                     cfg_overrides: dict | None = None,
                     balancer_kwargs: dict | None = None,
                     scan_every: int = 0):
    """One full differential run; returns a result dict (raises on a
    drain timeout, asserts nothing itself — callers check the fields).
    ``cfg_overrides`` are ``DiLiConfig._replace`` kwargs layered over
    ``small_cfg`` (e.g. ``{"block_probe": True}`` for probe-parity runs);
    ``balancer_kwargs`` reach the ``Balancer`` (e.g. ``hot_rate`` to force
    replication in a replication-enabled run).

    With ``cfg.replication`` on, FINDs the client routed to a read
    replica (``fut.via_replica``) are judged by a *windowed* referee: the
    replica serves a bounded-staleness image, so the correct result is
    any membership state the key held within the staleness window before
    submission — the strict current-state oracle still referees every
    mutation, every primary-served FIND, and the final key set."""
    from repro.api import DiLiClient
    from repro.core.balancer import Balancer
    from repro.core.oracle import OracleList
    from repro.core.types import OP_FIND, OP_INSERT, OP_REMOVE

    cfg = small_cfg(num_shards, big=(backend_kind == "local"))
    if cfg_overrides:
        cfg = cfg._replace(**cfg_overrides)
    if scan_every:
        # RANGE parity (DESIGN.md §16): every ``scan_every`` batches a
        # scan over a random span races the op stream; the client's
        # span-conflict admission makes the sequential oracle *at the
        # scan's submission index* the exact referee. The outbox must
        # absorb a full gather pre-pass burst (lanes × (batch+1)) on top
        # of normal traffic.
        cfg = cfg._replace(
            range_scan=True,
            mailbox_cap=max(cfg.mailbox_cap,
                            cfg.range_lanes * (cfg.range_batch + 1) + 64))
    backend = make_backend(backend_kind, cfg, seed, nemesis)
    bal = Balancer(backend, split_threshold=split_threshold,
                   merge_threshold=6, rng=backend.balancer_rng,
                   **(balancer_kwargs or {}))
    client = DiLiClient(backend, balance=bal, balance_every=3)
    oracle = OracleList()
    rng = np.random.default_rng(seed + 1)

    # per-key membership-change history as (global op index, state after):
    # the windowed referee for replica-served FINDs
    hist: dict = {}
    opno = 0

    def apply_and_record(kinds_, keys_):
        nonlocal opno
        out = []
        for kk, ky in zip(kinds_, keys_):
            out.append(oracle.apply(kk, ky))
            if kk != OP_FIND:
                hist.setdefault(ky, []).append((opno, ky in oracle))
            opno += 1
        return out

    n_load = min(max(key_space // 4, 20), 150)
    base = rng.permutation(np.arange(1, key_space))[:n_load].tolist()
    load = client.insert_batch(base)
    apply_and_record([OP_INSERT] * len(base), base)
    client.drain(drain_rounds, run_balance=True)

    futs, exps, starts = [load], [[True] * len(base)], [0]
    # RANGE scans race the stream on a separate rng child so the main op
    # schedule (and its byte-identical trace digests) is untouched when
    # scan_every == 0
    srng = np.random.default_rng(seed + 2)
    scans = []                       # (lo, hi, limit, expected_keys, fut)
    done = batch_no = 0
    while done < n_ops:
        k = min(ops_per_round, n_ops - done)
        kinds = rng.choice([OP_FIND, OP_INSERT, OP_REMOVE], k).tolist()
        keys = rng.integers(1, key_space, k).tolist()
        futs.append(client.submit(kinds, keys))
        starts.append(opno)
        exps.append(apply_and_record(kinds, keys))
        if scan_every and batch_no % scan_every == 0:
            lo = int(srng.integers(0, key_space))
            hi = lo + int(srng.integers(1, key_space // 2))
            limit = int(srng.integers(1, 64))
            # the span-conflict admission holds later span mutations
            # behind the scan and the scan behind earlier ones, so the
            # oracle *right now* — after this batch — is the exact
            # expected snapshot, truncated from the low end
            exp_keys = sorted(x for x in oracle.snapshot()
                              if lo <= x < hi)[:limit]
            scans.append((lo, hi, limit, exp_keys,
                          client.range(lo, hi, limit)))
        client.pump()
        done += k
        batch_no += 1
    client.drain(drain_rounds)

    scan_mismatches = []
    for lo, hi, limit, exp_keys, fut in scans:
        got = [kv[0] for kv in fut.items(wait=False)]
        if got != exp_keys:
            scan_mismatches.append((lo, hi, limit, exp_keys, got))

    # ops-per-window: staleness bound is in rounds; at most one submitted
    # batch per round, so ops_per_round per round is a safe upper bound
    # on op-index drift across the window (plus streaming/cadence slack)
    rep_window = 0
    if getattr(cfg, "replication", False):
        rep_window = (cfg.replica_staleness_rounds
                      + cfg.replica_refresh_rounds + 16) * ops_per_round

    def replica_ok(key, t, got):
        lo, base_state, seen = t - rep_window, False, set()
        for when, st in hist.get(key, []):
            if when <= lo:
                base_state = st
            elif when <= t:
                seen.add(bool(st))
        seen.add(bool(base_state))
        return bool(got) in seen

    mismatches = []
    for start, batch, exp in zip(starts, futs, exps):
        for i, (fut, (got, e)) in enumerate(
                zip(batch, zip(batch.results(), exp))):
            if bool(got) == e:
                continue
            if (rep_window and fut.kind == OP_FIND
                    and getattr(fut, "via_replica", False)
                    and replica_ok(fut.key, start + i, got)):
                continue
            mismatches.append((fut.kind, fut.key, e, got))
    final = backend.all_keys()
    return {
        "mismatches": mismatches,
        "scan_mismatches": scan_mismatches,
        "n_scans": len(scans),
        "keys_match": final == sorted(oracle.snapshot()),
        "final_keys": final,
        "oracle_keys": sorted(oracle.snapshot()),
        "quiescent": backend.quiescent(),
        "rounds": backend.cluster.round_no if backend_kind == "local"
        else backend.round_no,
        "net_stats": dict(backend.net.stats),
        "nemesis_stats": dict(backend.net.nemesis.stats),
        "trace": (backend.cluster.round_trace
                  if backend_kind == "local" else backend.round_trace),
        "backend": backend if keep_backend else None,
    }


def check(res: dict, repro: str) -> None:
    assert not res["mismatches"], \
        f"result mismatches {res['mismatches'][:5]} — repro {repro}"
    assert not res.get("scan_mismatches"), \
        f"scan mismatches {res['scan_mismatches'][:3]} — repro {repro}"
    assert res["keys_match"], \
        (f"final key sets diverged — repro {repro}\n"
         f"extra={sorted(set(res['final_keys'])-set(res['oracle_keys']))} "
         f"missing={sorted(set(res['oracle_keys'])-set(res['final_keys']))}")
    assert res["quiescent"], f"backend did not quiesce — repro {repro}"


def main(argv) -> int:
    """``NEMESIS_CONFIG`` (a ``NemesisConfig.to_dict()`` JSON) overrides
    the default schedule — this is how the crash-restart corpus reaches
    the shardmap subprocess. The ``digest=`` field on OK lines is the
    round-trace digest, compared across two executions by the
    byte-identical-replay tests."""
    import os
    kind, n_ops, seeds = argv[0], int(argv[1]), [int(s) for s in argv[2:]]
    cfg_json = os.environ.get("NEMESIS_CONFIG")
    # RANGE_EVERY=<n> races one scan per n batches through the schedule
    # (used by the shardmap scan-parity subprocess test)
    scan_every = int(os.environ.get("RANGE_EVERY", "0"))
    if cfg_json:
        from repro.core.net import NemesisConfig
        nemesis = NemesisConfig.from_dict(json.loads(cfg_json))
    else:
        nemesis = default_nemesis()
    failures = []
    for seed in seeds:
        repro = nemesis.repro(seed)
        try:
            res = run_differential(kind, seed, nemesis, n_ops=n_ops,
                                   scan_every=scan_every)
            check(res, repro)
            from repro.core.net.digest import trace_digest
            print(f"OK {kind} seed={seed} rounds={res['rounds']} "
                  f"digest={trace_digest(res['trace'])} "
                  f"net={res['net_stats']}", flush=True)
        except AssertionError as e:
            print(f"FAIL {kind} {repro}\n{e}", flush=True)
            failures.append({"seed": seed, "config": nemesis.to_dict(),
                             "backend": kind, "error": str(e)})
    if failures:
        print("FAILING-SEEDS " + json.dumps(failures), flush=True)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
