"""Durable recovery tests (DESIGN.md §14).

D1  WAL framing: roundtrip, torn-tail tolerance, incremental truncation.
D2  Snapshot store: genesis/latest/retention, full roundtrip through
    ``CheckpointManager``.
D3  Transport under crash: the down-NIC drop filter, lane-image export /
    restore, retransmission resuming after restart.
D4  Membership lifecycle: crash/restart transitions + guards (crash is
    not a drain: the drain intent is forgotten, restart re-enters as
    JOINING-with-state).
D5  Checkpoint writer loudness (satellite): a failed sync save raises at
    the call site; a failed async save surfaces on ``wait()``.
D6  Crash-restart differential: seeded kill -9 + recovery vs the
    sequential oracle, two executions byte-identical (local inline,
    shardmap via subprocess with a NEMESIS_CONFIG crash schedule).
D7  Crash during a move copy: the receiver dies mid-copy; recovery +
    retransmission complete the migration, no lost/resurrected keys.
D8  Crash soak (slow): seeds x schedules, scaled by CRASH_SOAK_* env
    vars in the crash-soak CI job; failures land in crash_failures/.
"""
import json
import os
import re
import subprocess
import sys

import numpy as np
import pytest

from nemesis_harness import check, run_differential, small_cfg
from repro.checkpoint.ckpt import CheckpointManager
from repro.core import bg as B
from repro.core import messages as M
from repro.core.durability import (KIND_ROUND, KIND_SUBMIT, ShardSnapshots,
                                   WriteAheadLog)
from repro.core.membership import Membership
from repro.core.net import NemesisConfig, Transport
from repro.core.net.nemesis import CrashPlan
from repro.core.sim import Cluster
from repro.core.types import DiLiConfig, init_shard, OP_FIND, OP_INSERT

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ----------------------------------------------------------------- D1: WAL

def _round_rec(rnd, **extra):
    rec = {"round": np.int64(rnd), "kind": np.int64(KIND_ROUND),
           "appends": np.zeros((0, M.FIELDS), np.int32)}
    rec.update(extra)
    return rec


def test_wal_roundtrip_and_kinds(tmp_path):
    w = WriteAheadLog(str(tmp_path / "s.wal"))
    rows = np.arange(2 * M.FIELDS, dtype=np.int32).reshape(2, M.FIELDS)
    w.append({"round": np.int64(3), "kind": np.int64(KIND_SUBMIT),
              "appends": rows})
    w.append(_round_rec(3, **{"lane/send/1/next_seq": np.int64(7)}))
    recs = list(w.records())
    assert [int(r["kind"]) for r in recs] == [KIND_SUBMIT, KIND_ROUND]
    assert np.array_equal(recs[0]["appends"], rows)
    assert int(recs[1]["lane/send/1/next_seq"]) == 7
    # a reopened log sees the same records (the restart read path)
    w.close()
    assert len(list(WriteAheadLog(str(tmp_path / "s.wal")).records())) == 2


def test_wal_torn_tail_is_dropped(tmp_path):
    path = str(tmp_path / "s.wal")
    w = WriteAheadLog(path)
    for r in range(3):
        w.append(_round_rec(r))
    w.close()
    # a crash mid-append leaves a half-written frame at the tail
    with open(path, "ab") as fh:
        fh.write(b"DWAL\x99\x00\x00\x00\x07")
    assert [int(r["round"]) for r in WriteAheadLog(path).records()] == \
        [0, 1, 2]
    # a corrupt (bit-flipped) tail frame is dropped by the crc check
    path2 = str(tmp_path / "s2.wal")
    w2 = WriteAheadLog(path2)
    for r in range(3):
        w2.append(_round_rec(r))
    w2.close()
    blob = open(path2, "rb").read()
    with open(path2, "wb") as fh:          # flip a payload byte of rec 2
        fh.write(blob[:-5] + bytes([blob[-5] ^ 0xFF]) + blob[-4:])
    kept = list(WriteAheadLog(path2).records())
    assert [int(r["round"]) for r in kept] == [0, 1]


def test_wal_truncate_keeps_suffix_and_stays_appendable(tmp_path):
    w = WriteAheadLog(str(tmp_path / "s.wal"))
    for r in range(10):
        w.append(_round_rec(r))
    assert w.truncate_upto(4) == 5
    assert [int(r["round"]) for r in w.records()] == list(range(5, 10))
    w.append(_round_rec(10))      # the handle survives the rewrite
    assert [int(r["round"]) for r in w.records()] == list(range(5, 11))


# ----------------------------------------------------------- D2: snapshots

def _mini_cfg(n=2):
    return DiLiConfig(num_shards=n, pool_capacity=256, max_sublists=8,
                      max_ctrs=8, max_scan=256, batch_size=4,
                      mailbox_cap=16, move_batch=2)


def test_snapshot_roundtrip_and_retention(tmp_path):
    cfg = _mini_cfg()
    snaps = ShardSnapshots(str(tmp_path), 0, keep=2)
    assert snaps.latest_round() is None

    state = init_shard(cfg, 0, bootstrap=True)
    bg = B.init_bg_table(cfg)
    backlog = np.zeros((3, M.FIELDS), np.int32)
    backlog[:, M.F_KEY] = [1, 2, 3]
    lanes = {"send/1/next_seq": np.int64(5),
             "recv/1/rows": np.ones((4, M.FIELDS), np.int32)}
    snaps.save(7, state, bg, backlog, lanes)
    assert snaps.latest_round() == 7

    base = snaps.load_latest(cfg)
    assert base["round"] == 7
    assert np.array_equal(base["backlog"], backlog)
    assert int(base["lanes"]["send/1/next_seq"]) == 5
    assert np.array_equal(base["lanes"]["recv/1/rows"], lanes["recv/1/rows"])
    import jax
    for got, want in zip(jax.tree_util.tree_leaves(base["state"]),
                         jax.tree_util.tree_leaves(state)):
        assert np.array_equal(np.asarray(got), np.asarray(want))

    # retention: keep=2 drops the oldest once a third lands
    snaps.save(15, state, bg, backlog, lanes)
    snaps.save(23, state, bg, backlog, lanes)
    assert snaps.latest_round() == 23
    assert snaps.load_latest(cfg)["round"] == 23


# ----------------------------------------------------------- D3: transport

def _mkrow(src, dst, payload, kind=M.MSG_OP):
    row = np.zeros((M.FIELDS,), np.int32)
    row[M.F_KIND] = kind
    row[M.F_SRC] = src
    row[M.F_DST] = dst
    row[M.F_KEY] = payload
    return row


def _pump(tp, start, rounds):
    got = [[] for _ in range(tp.n)]
    for r in range(start, start + rounds):
        for d, rows in enumerate(tp.ship_round(r)):
            got[d].extend(rows)
    return got


def test_down_shard_receives_nothing_then_retransmission_heals():
    tp = Transport(2, retransmit_after=2)
    tp.send(0, np.stack([_mkrow(0, 1, p) for p in (10, 11, 12)]))
    image = tp.export_shard_lanes(1)      # pre-delivery cursor state
    tp.crash_shard(1)
    got = _pump(tp, 0, 6)
    assert got[1] == []
    assert tp.stats["down_dropped"] > 0
    tp.restart_shard(1, image)
    got = _pump(tp, 6, 8)
    assert [int(r[M.F_KEY]) for r in got[1]] == [10, 11, 12]
    assert tp.idle(), tp.in_flight()


def test_lane_image_preserves_dedup_window_across_restart():
    """The restored receiver cursor keeps seq continuity: frames sent
    while the shard was down arrive exactly once after restart; losing
    the image would either re-deliver or stall the lane forever."""
    tp = Transport(2, retransmit_after=2)
    tp.send(0, np.stack([_mkrow(0, 1, p) for p in (1, 2)]))
    _pump(tp, 0, 4)                       # delivered + acked
    image = tp.export_shard_lanes(1)      # cursor is now at seq 2
    tp.crash_shard(1)
    tp.send(0, np.stack([_mkrow(0, 1, 3)]))
    _pump(tp, 4, 3)                       # dropped at the down NIC
    tp.restart_shard(1, image)
    got = _pump(tp, 7, 8)
    assert [int(r[M.F_KEY]) for r in got[1]] == [3]
    assert tp.stats["delivered"] == 3
    assert tp.idle(), tp.in_flight()


# ---------------------------------------------------------- D4: membership

def test_membership_crash_restart_lifecycle():
    mb = Membership(4, 3)
    with pytest.raises(ValueError, match="cannot crash"):
        mb.crash(3)                       # retired slots have no process
    e0 = mb.epoch
    mb.crash(1)
    assert mb.crashed == (1,)
    assert mb.routable == (0, 2)
    assert 1 not in mb.targets
    assert mb.epoch == e0 + 1
    with pytest.raises(ValueError, match="cannot crash"):
        mb.crash(1)
    mb.restart(1)
    assert mb.state_of(1) == "joining"    # JOINING-with-state
    mb.promote(1)
    assert mb.is_active(1)
    # crash forgets drain intent: the shard re-enters as a plain joiner
    mb.begin_drain(2)
    mb.crash(2)
    assert mb.draining == ()
    mb.restart(2)
    assert mb.state_of(2) == "joining"
    events = [ev for _, ev, _ in mb.log]
    assert events.count("crash") == 2 and events.count("restart") == 2
    with pytest.raises(ValueError, match="cannot restart"):
        mb.restart(0)                     # active, never crashed


# -------------------------------------------- D5: checkpoint writer (sat.)

def test_ckpt_sync_save_raises_at_call_site(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "ck"), keep=2, async_write=False)
    blocker = tmp_path / "blocker"
    blocker.write_text("x")
    mgr.dir = str(blocker)                # step path now points into a file
    with pytest.raises(OSError):
        mgr.save(0, {"a": np.zeros(3)})
    # the error does not linger: a subsequent good save succeeds
    mgr.dir = str(tmp_path / "ck")
    mgr.save(1, {"a": np.zeros(3)})
    assert mgr.latest_step() == 1


def test_ckpt_async_save_error_surfaces_on_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "ck"), keep=2, async_write=True)
    blocker = tmp_path / "blocker"
    blocker.write_text("x")
    mgr.dir = str(blocker)
    mgr.save(0, {"a": np.zeros(3)})
    with pytest.raises(OSError):
        mgr.wait()


# -------------------------------------- D6: crash-restart differential

CRASH_NEM = NemesisConfig(drop_prob=0.05, dup_prob=0.05, reorder_prob=0.05,
                          crashes=(CrashPlan(1, 40, 80),
                                   CrashPlan(2, 120, 150)))


def test_local_crash_restart_differential_and_replay():
    """Seeded kill -9 + recovery: client ops across the crash match the
    sequential oracle (no lost or resurrected keys), and a second
    execution of the same (seed, config) replays byte-identically —
    crash/restart rounds included in the witness."""
    res = run_differential("local", 23, CRASH_NEM, n_ops=300,
                           keep_backend=True)
    check(res, CRASH_NEM.repro(23))
    trace = res["trace"]
    assert any("mb crash s1" in ln for ln in trace)
    assert any("mb restart s1" in ln for ln in trace)
    assert any("mb crash s2" in ln for ln in trace)
    dur = res["backend"].cluster.durability
    assert dur.stats["recoveries"] == 2
    assert dur.stats["replayed_rounds"] > 0

    res2 = run_differential("local", 23, CRASH_NEM, n_ops=300)
    assert res2["trace"] == trace


@pytest.mark.slow
def test_shardmap_crash_differential_replays_byte_identically():
    """ShardMap backend through a crash schedule, twice, in subprocesses
    (multi-device XLA host platform): both pass the differential and
    print the same round-trace digest."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["JAX_PLATFORMS"] = "cpu"
    env["NEMESIS_CONFIG"] = json.dumps({
        "drop_prob": 0.05, "dup_prob": 0.05, "reorder_prob": 0.05,
        "crashes": [[1, 40, 80]]})
    digests = []
    for _ in range(2):
        r = subprocess.run(
            [sys.executable, os.path.join("tests", "nemesis_harness.py"),
             "shardmap", "150", "31"],
            env=env, capture_output=True, text=True, timeout=900, cwd=REPO)
        assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
        m = re.search(r"digest=(\w+)", r.stdout)
        assert m, r.stdout
        digests.append(m.group(1))
    assert digests[0] == digests[1]


# ------------------------------------------- D7: crash during a move copy

def _move_script(crashes, probe=None):
    """Deterministic 2-shard run: load shard 0, split, move one sublist
    to shard 1, stepping manually through the copy (``probe`` sees the
    cluster each round), then verify with FINDs. Returns the cluster."""
    cfg = small_cfg(2)._replace(move_batch=2)
    cl = Cluster(cfg, seed=5, nemesis=NemesisConfig(crashes=tuple(crashes)))
    keys = list(range(10, 250, 3))
    cl.submit(0, [OP_INSERT] * len(keys), keys)
    cl.run_until_quiet(600)
    subs = [e for e in cl.sublists(0) if e["owner"] == 0]
    mid = cl.middle_item(0, subs[0]["head_idx"])
    assert cl.split(0, subs[0]["keymax"], mid)
    cl.run_until_quiet(600)
    subs = sorted((e for e in cl.sublists(0) if e["owner"] == 0),
                  key=lambda e: e["keymin"])
    assert cl.move(0, subs[0]["keymax"], 1)
    for _ in range(400):
        if probe is not None:
            probe(cl)
        cl.step()
        if not B.any_active(cl.bgs[0]) and not cl.membership.crashed \
                and cl.net.idle() \
                and not any(b.shape[0] for b in cl.backlog):
            break
    cl.submit(0, [OP_FIND] * 3, [19, 100, 202])
    cl.run_until_quiet(600)
    return cl, keys


def test_crash_during_move_copy_recovers_without_key_loss():
    # pass 1 (no crash): find the rounds where the copy is actually in
    # flight — determinism makes them the same rounds in pass 2
    active = []
    cl0, keys = _move_script(
        (), probe=lambda c: active.append(c.round_no)
        if B.any_active(c.bgs[0]) else None)
    assert sorted(cl0.all_keys()) == sorted(keys)
    assert len(active) >= 3, "move finished too fast to crash into"

    # pass 2: kill the receiver mid-copy, restart 25 rounds later
    crash_r = active[len(active) // 2]
    saw_active = []
    cl, keys = _move_script(
        (CrashPlan(1, crash_r, crash_r + 25),),
        probe=lambda c: saw_active.append(B.any_active(c.bgs[0]))
        if c.round_no == crash_r else None)
    assert saw_active == [True], "crash round missed the copy window"
    assert any("mb crash s1" in ln for ln in cl.round_trace)
    assert cl.durability.stats["recoveries"] == 1
    assert sorted(cl.all_keys()) == sorted(keys)
    # the migration still completed: shard 1 owns the moved sublist
    assert any(e["owner"] == 1 for e in cl.sublists(1))


# ----------------------------------------------------------- D8: soak

@pytest.mark.slow
def test_crash_soak_many_seeds():
    """Crash-schedule differential sweep; the crash-soak CI job scales
    seeds/ops via CRASH_SOAK_SEEDS / CRASH_SOAK_OPS and uploads
    crash_failures/ on failure."""
    per = int(os.environ.get("CRASH_SOAK_SEEDS", "2"))
    n_ops = int(os.environ.get("CRASH_SOAK_OPS", "300"))
    schedules = [
        (CrashPlan(1, 40, 80),),
        (CrashPlan(2, 60, 100), CrashPlan(1, 140, 170)),
    ]
    failures = []
    for si, crashes in enumerate(schedules):
        config = NemesisConfig(drop_prob=0.05, dup_prob=0.05,
                               reorder_prob=0.05, crashes=crashes)
        for seed in range(3000 + 100 * si, 3000 + 100 * si + per):
            repro = config.repro(seed)
            try:
                res = run_differential("local", seed, config, n_ops=n_ops)
                check(res, repro)
                assert any("mb crash" in ln for ln in res["trace"]), \
                    f"schedule never fired — run too short ({repro})"
            except AssertionError as e:
                failures.append({"seed": seed, "config": config.to_dict(),
                                 "backend": "local", "error": str(e)})
    if failures:
        outdir = os.path.join(REPO, "crash_failures")
        os.makedirs(outdir, exist_ok=True)
        path = os.path.join(outdir, "local_soak.json")
        with open(path, "w") as f:
            json.dump(failures, f, indent=1)
        pytest.fail(f"{len(failures)} failing seeds written to {path}: "
                    + ", ".join(str(x["seed"]) for x in failures))


# -------------------------------------- D9: group commit (satellite)

def _group_commit_run(tmpdir, every, crashes=()):
    from repro.core.durability import Durability, DurabilityConfig
    cfg = small_cfg(2)
    dur = Durability(str(tmpdir), cfg,
                     DurabilityConfig(snapshot_every=0,
                                      group_commit_rounds=every))
    nem = NemesisConfig(crashes=tuple(crashes)) if crashes else None
    cl = Cluster(cfg, seed=3, nemesis=nem, durability=dur)
    keys = list(range(10, 310, 3))
    cl.submit(0, [OP_INSERT] * len(keys), keys)
    cl.run_until_quiet(600)
    while cl.round_no < 64:           # fixed round horizon for a clean
        cl.step()                     # fsync-per-round comparison
    return cl, dur, keys


def test_group_commit_write_amplification(tmp_path):
    """``group_commit_rounds=G`` defers the per-round WAL fsync to every
    G-th round: the fsync count drops ~G:1 on a round-dominated run
    (submits/commands still sync on acceptance, a constant floor)."""
    _, d1, _ = _group_commit_run(tmp_path / "g1", 1)
    _, d8, _ = _group_commit_run(tmp_path / "g8", 8)
    f1, f8 = d1.fsync_count(), d8.fsync_count()
    assert f1 > 0 and f8 > 0
    # identical workloads, identical record counts — only sync cadence
    # differs. The ratio is < 8 only because of the always-sync floor.
    assert d1.stats["records"] == d8.stats["records"]
    assert f1 >= 4 * f8, (f1, f8)


def test_group_commit_crash_recovery_still_exact(tmp_path):
    """Crash-restart under group commit: recovery replays through the
    journaled suffix and retransmission heals the rest — no lost or
    resurrected keys, exactly as with per-round sync."""
    cl, dur, keys = _group_commit_run(
        tmp_path, 8, crashes=[CrashPlan(shard=1, crash_round=20,
                                        restart_round=40)])
    assert dur.stats["recoveries"] == 1
    cl.run_until_quiet(800)
    assert sorted(cl.all_keys()) == sorted(keys)
