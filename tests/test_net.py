"""Unit tests for the reliable transport + nemesis (core/net, DESIGN.md §11).

The contract under test: whatever the wire does (drop, duplicate,
reorder, delay, partition), every frame a sender stages is delivered to
its destination exactly once, in per-(src,dst)-lane order — and the
whole schedule is a pure function of (seed, config).
"""
import numpy as np
import pytest

from repro.core import messages as M
from repro.core.net import (LinkFaults, Nemesis, NemesisConfig, Partition,
                            Transport, TransportOverflow)


def mkrow(src, dst, payload, kind=M.MSG_OP):
    row = np.zeros((M.FIELDS,), np.int32)
    row[M.F_KIND] = kind
    row[M.F_SRC] = src
    row[M.F_DST] = dst
    row[M.F_KEY] = payload
    return row


def nemesis(config, seed=0):
    return Nemesis(config, np.random.default_rng(seed))


def pump(tp, start, rounds):
    """Drive empty rounds; collect deliveries per destination."""
    got = [[] for _ in range(tp.n)]
    for r in range(start, start + rounds):
        for d, rows in enumerate(tp.ship_round(r)):
            got[d].extend(rows)
    return got


def payloads(rows):
    return [int(r[M.F_KEY]) for r in rows]


# ------------------------------------------------------------- clean wire

def test_clean_wire_delivers_in_order_and_goes_idle():
    tp = Transport(2)
    tp.send(0, np.stack([mkrow(0, 1, p) for p in (10, 11, 12)]))
    got = pump(tp, 0, 6)
    assert payloads(got[1]) == [10, 11, 12]
    assert got[0] == []         # only transport acks flow back
    assert tp.idle(), tp.in_flight()
    assert tp.stats["delivered"] == 3
    assert tp.stats["retransmits"] == 0


def test_loopback_bypasses_the_wire():
    tp = Transport(2)
    loop = tp.send(0, np.stack([mkrow(0, 0, 5), mkrow(0, 1, 6)]))
    assert payloads(loop) == [5]
    assert tp.stats["sent"] == 1       # only the cross-shard frame staged
    got = pump(tp, 0, 4)
    assert payloads(got[1]) == [6]


def test_seq_stamped_per_lane():
    tp = Transport(3)
    tp.send(0, np.stack([mkrow(0, 1, 1), mkrow(0, 2, 2), mkrow(0, 1, 3)]))
    tp.send(2, np.stack([mkrow(2, 1, 4)]))
    got = pump(tp, 0, 4)
    seqs = {(int(r[M.F_SRC]), int(r[M.F_KEY])): int(r[M.F_SEQ])
            for r in got[1] + got[2]}
    # per-lane monotone from 1: lane (0,1) got 1,2; lanes (0,2), (2,1) got 1
    assert seqs == {(0, 1): 1, (0, 3): 2, (0, 2): 1, (2, 4): 1}


# ------------------------------------------------------------ lossy wire

def test_drops_heal_by_retransmission():
    cfg = NemesisConfig(drop_prob=0.5)
    tp = Transport(2, nemesis(cfg, seed=3), retransmit_after=2)
    n = 40
    tp.send(0, np.stack([mkrow(0, 1, p) for p in range(n)]))
    got = pump(tp, 0, 120)
    assert payloads(got[1]) == list(range(n))
    assert tp.idle()
    assert tp.stats["retransmits"] > 0
    assert tp.nemesis.stats["dropped"] > 0


def test_duplicates_are_suppressed_exactly_once_delivery():
    cfg = NemesisConfig(dup_prob=1.0)     # every frame delivered twice
    tp = Transport(2, nemesis(cfg), retransmit_after=2)
    tp.send(0, np.stack([mkrow(0, 1, p) for p in range(10)]))
    got = pump(tp, 0, 20)
    assert payloads(got[1]) == list(range(10))
    assert tp.stats["dup_dropped"] >= 10
    assert tp.idle()


def test_reordering_is_straightened_per_lane():
    cfg = NemesisConfig(reorder_prob=0.8)
    tp = Transport(3, nemesis(cfg, seed=1), retransmit_after=3)
    for r in range(6):
        tp.send(0, np.stack([mkrow(0, 1, 100 + 6 * r + i)
                             for i in range(6)]))
        tp.send(2, np.stack([mkrow(2, 1, 900 + r)]))
        tp.ship_round(r)
    got = pump(tp, 6, 60)
    all_lane0 = [p for p in payloads(got[1]) if p < 900]
    all_lane2 = [p for p in payloads(got[1]) if p >= 900]
    # pre-pumped rounds also delivered some; recollect from scratch instead
    # by checking monotonicity of what arrived during the drain
    assert all_lane0 == sorted(all_lane0)
    assert all_lane2 == sorted(all_lane2)
    assert tp.idle()


def test_delay_holds_frames_then_releases_in_order():
    cfg = NemesisConfig(delay_prob=1.0, delay_rounds=4)
    tp = Transport(2, nemesis(cfg, seed=2), retransmit_after=50)
    tp.send(0, np.stack([mkrow(0, 1, p) for p in (1, 2, 3)]))
    first = tp.ship_round(0)
    assert payloads(first[1]) == []       # all held
    assert not tp.idle()
    got = pump(tp, 1, 12)
    assert payloads(got[1]) == [1, 2, 3]
    assert tp.nemesis.stats["delayed"] >= 3


def test_partition_cuts_then_heals():
    cfg = NemesisConfig(partitions=(Partition(0, 10, (0,)),))
    tp = Transport(2, nemesis(cfg), retransmit_after=2)
    tp.send(0, np.stack([mkrow(0, 1, p) for p in (7, 8)]))
    during = pump(tp, 0, 10)              # rounds 0..9: cut
    assert payloads(during[1]) == []
    assert tp.nemesis.stats["partitioned"] > 0
    after = pump(tp, 10, 10)              # healed: retransmits land
    assert payloads(after[1]) == [7, 8]
    assert tp.idle()


def test_delayed_frames_respect_partitions_at_release():
    """A frame held by the delay stage that comes due mid-cut is cut —
    the delay stage must not smuggle frames through a partition."""
    cfg = NemesisConfig(delay_prob=1.0, delay_rounds=1,
                        partitions=(Partition(1, 20, (0,)),))
    tp = Transport(2, nemesis(cfg, seed=0), retransmit_after=3)
    tp.send(0, np.stack([mkrow(0, 1, 9)]))
    arrived_at = None
    for r in range(40):
        rows = tp.ship_round(r)[1]
        if len(rows):
            arrived_at = r
            break
    assert arrived_at is not None and arrived_at >= 20, arrived_at
    assert tp.nemesis.stats["partitioned"] > 0


def test_link_overrides_scope_faults_to_one_link():
    # only the 0->1 link drops; 0->2 is clean
    cfg = NemesisConfig(link_overrides=(
        ((0, 1), LinkFaults(drop_prob=1.0)),))
    tp = Transport(3, nemesis(cfg), retransmit_after=100)
    tp.send(0, np.stack([mkrow(0, 1, 1), mkrow(0, 2, 2)]))
    got = pump(tp, 0, 4)
    assert payloads(got[1]) == []
    assert payloads(got[2]) == [2]


def test_ack_loss_heals_sender_ring_eventually_drains():
    # acks travel the reverse link and are dropped hard; data is clean.
    # Retransmits of delivered frames are dup-dropped but re-arm the
    # receiver's cumulative ack until one survives.
    cfg = NemesisConfig(link_overrides=(
        ((1, 0), LinkFaults(drop_prob=0.8)),))
    tp = Transport(2, nemesis(cfg, seed=11), retransmit_after=2)
    tp.send(0, np.stack([mkrow(0, 1, p) for p in range(5)]))
    got = pump(tp, 0, 200)
    assert payloads(got[1]) == list(range(5))
    assert tp.idle(), tp.in_flight()
    assert tp.stats["dup_dropped"] > 0


# ---------------------------------------------------------- misc contract

def test_window_overflow_raises_loudly():
    cfg = NemesisConfig(drop_prob=1.0)    # nothing is ever delivered
    tp = Transport(2, nemesis(cfg), window=8)
    with pytest.raises(TransportOverflow):
        for r in range(4):
            tp.send(0, np.stack([mkrow(0, 1, p) for p in range(4)]))
            tp.ship_round(r)


def test_net_ack_frames_never_reach_inboxes():
    tp = Transport(2)
    tp.send(0, np.stack([mkrow(0, 1, 1)]))
    for r in range(8):
        for rows in tp.ship_round(r):
            assert all(int(x[M.F_KIND]) != M.MSG_NET_ACK for x in rows)
    assert tp.stats["acks"] > 0           # acks flowed, invisibly


def test_same_seed_same_schedule():
    cfg = NemesisConfig(drop_prob=0.3, dup_prob=0.3, reorder_prob=0.3,
                        delay_prob=0.2, delay_rounds=3)

    def run(seed):
        tp = Transport(2, nemesis(cfg, seed), retransmit_after=2)
        log = []
        for r in range(40):
            if r < 10:
                tp.send(0, np.stack([mkrow(0, 1, 10 * r + i)
                                     for i in range(3)]))
            for d, rows in enumerate(tp.ship_round(r)):
                log.append((r, d, payloads(rows)))
        return log, dict(tp.stats), dict(tp.nemesis.stats)

    a, b, c = run(7), run(7), run(8)
    assert a == b                          # byte-identical replay
    assert a != c                          # the seed actually matters


def test_config_round_trips_through_json_dict():
    cfg = NemesisConfig(
        drop_prob=0.1, dup_prob=0.2, reorder_prob=0.3, delay_prob=0.05,
        delay_rounds=4, partitions=(Partition(5, 9, (0, 2)),),
        link_overrides=(((1, 0), LinkFaults(drop_prob=0.9)),))
    assert NemesisConfig.from_dict(cfg.to_dict()) == cfg
    assert "seed=3" in cfg.repro(3)
