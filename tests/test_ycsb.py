"""Distribution tests for the bounded YCSB Zipfian generator (§7.2).

The generator is the closed-form inverse-CDF from Gray et al. — rank i
of n has probability (1/i^θ)/ζ_n(θ). These tests pin the head masses
against that theory (the property the zipf benchmark's skew sweep is
calibrated on), the θ=0 uniform degenerate case, the scrambled variant's
dispersal, and the bounds/rejection contract.
"""
import numpy as np
import pytest

from repro.data.ycsb import _zeta, mixed_phase, zipf_keys
from repro.core.types import OP_FIND, OP_INSERT, OP_REMOVE

N = 200_000
SPACE = 1000


def _mass(keys, ranks):
    return np.isin(keys, ranks).mean()


@pytest.mark.parametrize("theta", [0.5, 0.9, 0.99])
def test_head_mass_matches_zeta_theory(theta):
    rng = np.random.default_rng(0)
    keys = zipf_keys(rng, N, SPACE, theta=theta)
    zetan = _zeta(SPACE, theta)
    p1 = 1.0 / zetan
    p10 = float(np.sum(1.0 / np.arange(1, 11) ** theta)) / zetan
    got1 = _mass(keys, [1])
    got10 = _mass(keys, np.arange(1, 11))
    # the closed-form inverse CDF is an approximation; YCSB accepts a
    # few percent of relative error at the head
    assert got1 == pytest.approx(p1, rel=0.08), (got1, p1)
    assert got10 == pytest.approx(p10, rel=0.05), (got10, p10)


def test_theta_orders_skew():
    rng = np.random.default_rng(1)
    heads = [_mass(zipf_keys(rng, N, SPACE, theta=t), np.arange(1, 11))
             for t in (0.0, 0.5, 0.9, 0.99)]
    assert heads == sorted(heads), heads
    # θ=0 is uniform: top-10 mass is 10/SPACE
    assert heads[0] == pytest.approx(10 / SPACE, rel=0.15)


def test_bounds_and_dtype():
    rng = np.random.default_rng(2)
    for theta in (0.0, 0.5, 0.99):
        for scrambled in (False, True):
            keys = zipf_keys(rng, 10_000, SPACE, theta=theta,
                             scrambled=scrambled)
            assert keys.dtype == np.int32
            assert keys.min() >= 1 and keys.max() <= SPACE
    with pytest.raises(ValueError):
        zipf_keys(rng, 10, SPACE, theta=1.0)


def test_scrambled_disperses_the_hot_prefix():
    rng = np.random.default_rng(3)
    plain = zipf_keys(rng, N, SPACE, theta=0.99)
    rng = np.random.default_rng(3)
    scram = zipf_keys(rng, N, SPACE, theta=0.99, scrambled=True)
    # same skew: the hottest single key carries (at least) rank 1's mass
    # either way — FNV collisions can only merge ranks, never split one
    top_plain = np.bincount(plain).max() / N
    top_scram = np.bincount(scram).max() / N
    assert top_scram >= 0.9 * top_plain
    assert top_scram <= 2.0 * top_plain
    # but the plain hot ranks are a contiguous prefix while the
    # scrambled ones scatter: compare the span of the top-10 hot keys
    def top10_span(keys):
        counts = np.bincount(keys, minlength=SPACE + 1)
        hot = np.argsort(counts)[-10:]
        return int(hot.max() - hot.min())
    assert top10_span(plain) <= 10
    assert top10_span(scram) > SPACE // 10


def test_mixed_phase_read_write_split():
    kinds, keys = mixed_phase(N, SPACE, 0.9, seed=4, theta=0.9)
    frac_find = (kinds == OP_FIND).mean()
    frac_ins = (kinds == OP_INSERT).mean()
    frac_rem = (kinds == OP_REMOVE).mean()
    assert frac_find == pytest.approx(0.9, abs=0.01)
    # writes split evenly between inserts and removes
    assert frac_ins == pytest.approx(0.05, abs=0.01)
    assert frac_rem == pytest.approx(0.05, abs=0.01)
    assert keys.shape == kinds.shape
