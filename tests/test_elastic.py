"""Elastic scaling: checkpoints restore onto a different mesh topology.

A run checkpointed on one device layout must restore bit-identically onto
another (failover re-provisioning / pod-count changes). The save path is
host-gathered numpy; the restore path applies arbitrary target shardings.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore_pytree, save_pytree
from repro.configs import get_smoke_config
from repro.models import transformer as T

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    from repro.checkpoint import restore_pytree
    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    from repro.runtime.sharding import param_shardings

    path = sys.argv[1]
    cfg = get_smoke_config("qwen2_5_3b").replace(
        d_model=64, n_heads=4, n_kv_heads=2)
    template = jax.eval_shape(
        lambda: T.init_params(cfg, jax.random.PRNGKey(7),
                              dtype=jnp.float32))
    mesh = Mesh(np.array(jax.devices()).reshape(2, 2), ("data", "model"))
    shardings = param_shardings(template, mesh)
    params = restore_pytree(template, path, shardings)
    # restored onto the 2x2 mesh with the rule-derived shardings
    leaf = params["blocks"]["attn"]["wq"]
    assert len(leaf.sharding.device_set) == 4, leaf.sharding
    # bitwise identical to the single-device original
    ref = restore_pytree(template, path)
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("ELASTIC_OK")
""")


@pytest.mark.slow
def test_restore_onto_different_mesh(tmp_path):
    cfg = get_smoke_config("qwen2_5_3b").replace(
        d_model=64, n_heads=4, n_kv_heads=2)
    params = T.init_params(cfg, jax.random.PRNGKey(7), dtype=jnp.float32)
    path = str(tmp_path / "elastic.npz")
    save_pytree(params, path)

    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT, path], env=env,
                       capture_output=True, text=True, timeout=600,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "ELASTIC_OK" in r.stdout
