"""Serving: paged decode over a DiLi page table == contiguous decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import transformer as T
from repro.serving.engine import Request, ServingEngine
from repro.serving.paged import PagedKVManager, paged_decode_step


@pytest.fixture(scope="module")
def model():
    cfg = get_smoke_config("qwen2_5_3b")
    params = T.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    return cfg, params


def _greedy_contiguous(cfg, params, prompt, n_new):
    b, s = 1, len(prompt)
    cache = T.init_cache(cfg, b, 256, dtype=jnp.float32)
    toks = jnp.asarray(np.asarray(prompt)[None, :])
    logits, cache = T.forward_serve(params, cfg, {"tokens": toks}, cache,
                                    jnp.zeros((b,), jnp.int32), decode=False)
    out = [int(jnp.argmax(logits[0]))]
    cache_len = jnp.asarray([s], jnp.int32)
    for _ in range(n_new - 1):
        logits, cache = T.forward_serve(
            params, cfg, {"tokens": jnp.asarray([[out[-1]]], jnp.int32)},
            cache, cache_len, decode=True)
        out.append(int(jnp.argmax(logits[0])))
        cache_len = cache_len + 1
    return out


@pytest.mark.parametrize("use_kernel", [False, True])
def test_paged_engine_matches_contiguous(model, use_kernel):
    cfg, params = model
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, 12).astype(np.int32),
               rng.integers(0, cfg.vocab, 7).astype(np.int32)]
    n_new = 6

    ref = [_greedy_contiguous(cfg, params, p, n_new) for p in prompts]

    eng = ServingEngine(cfg, params, page_size=8, num_pages=64,
                        use_kernel=use_kernel)
    for i, p in enumerate(prompts):
        eng.admit(Request(seq_id=i, prompt=p, max_new=n_new))
    for _ in range(n_new):
        eng.step()
    got = {}
    for r in [*eng.active]:
        got[r.seq_id] = r.out
    # engine drops finished requests from active; recover via closure
    assert not eng.active  # all done
    # rerun to capture outputs
    eng2 = ServingEngine(cfg, params, page_size=8, num_pages=64,
                         use_kernel=use_kernel)
    reqs = [Request(seq_id=i, prompt=p, max_new=n_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng2.admit(r)
    for _ in range(n_new):
        eng2.step()
    for i, r in enumerate(reqs):
        assert r.out[:n_new] == ref[i][:n_new], (i, r.out, ref[i])


def test_paged_engine_with_live_rebalance(model):
    """Split/Move the page index between decode steps: outputs unchanged."""
    cfg, params = model
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab, 10).astype(np.int32)
               for _ in range(3)]
    n_new = 5
    ref = [_greedy_contiguous(cfg, params, p, n_new) for p in prompts]

    eng = ServingEngine(cfg, params, page_size=8, num_pages=64,
                        dili_shards=2)
    reqs = [Request(seq_id=i, prompt=p, max_new=n_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.admit(r)
    for step in range(n_new):
        # force a move of the whole page-index sublist mid-decode
        if step == 1:
            subs = eng.kv.dili.sublists(0)
            owned = [e for e in subs if e["owner"] == 0]
            if owned:
                eng.kv.dili.move(0, owned[0]["keymax"], 1)
        eng.step(rebalance=True)
    for i, r in enumerate(reqs):
        assert r.out[:n_new] == ref[i][:n_new], (i, r.out, ref[i])
    # the index did move
    owners = {e["owner"] for s in range(2) for e in eng.kv.dili.sublists(s)}
    assert 1 in owners


def test_int8_kv_cache_numerics(model):
    """kv_quant decode matches full-precision logits within int8 tolerance
    and greedy tokens agree (§Perf cell B optimization)."""
    import jax.numpy as jnp
    cfg, params = model
    qcfg = cfg.replace(kv_quant=True)
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab, 12).astype(np.int32)

    def run(c):
        cache = T.init_cache(c, 1, 64, dtype=jnp.float32)
        toks = jnp.asarray(prompt[None, :])
        logits, cache = T.forward_serve(params, c, {"tokens": toks}, cache,
                                        jnp.zeros((1,), jnp.int32),
                                        decode=False)
        outs = [logits]
        cache_len = jnp.asarray([len(prompt)], jnp.int32)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        for _ in range(4):
            logits, cache = T.forward_serve(params, c, {"tokens": tok},
                                            cache, cache_len, decode=True)
            outs.append(logits)
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            cache_len = cache_len + 1
        return outs

    ref = run(cfg)
    qnt = run(qcfg)
    for a, b in zip(ref, qnt):
        # same greedy decision, logits close
        assert int(jnp.argmax(a[0])) == int(jnp.argmax(b[0]))
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=0.15, rtol=0.1)
