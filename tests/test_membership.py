"""Elastic shard membership tests (DESIGN.md §13).

M1  Membership lifecycle: the host-side state machine — transitions bump
    the epoch and land in the log; invalid transitions raise; the peer
    bitmask tracks the routable set; capacity >= 31 rejects partial
    membership (the mask is one int32 lane).
M2  Transport lane reset: ``reset_shard`` refuses while frames touching
    the shard are in flight and drops exactly that shard's lanes once
    idle (the re-handshake a retiring shard's slot gets on rejoin).
M3  Scale 3 -> 5 -> 2 under continuous client traffic: every op result
    and the final key set match the sequential oracle, zero failed ops.
M4  Replay: a membership schedule under nemesis faults is byte-identical
    from one (seed, config) — including the ``mb`` trace lines.
M5  Partition during a membership change: a cut overlapping a join and a
    retire (isolating the epoch coordinator) heals to oracle parity.
M6  Client pacing: the inflight budget is recomputed on epoch bumps in
    both directions (PR 3's reserve math held cfg.num_shards static);
    a caller-pinned budget is never touched.
M7  AutoscalePolicy: joins under load, retires under shrink, and holds
    still inside the hysteresis band.
M8  ShardMap parity: the same 3->5->2 differential through the SPMD
    backend (subprocess; fixed mesh capacity, activity-masked).
M9  Soak: seeds x schedules x fault levels, scaled by MEMBERSHIP_SOAK_*
    env vars in the membership-soak CI job; failing seeds become
    artifacts under membership_failures/.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from membership_harness import (SCALE_3_5_2, check, default_nemesis,
                                run_membership_differential)
from nemesis_harness import small_cfg
from repro.core import messages as M
from repro.core.membership import (MASK_BITS, Membership, epoch_row,
                                   live_mask)
from repro.core.net import NemesisConfig, Partition, Transport

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ----------------------------------------------------- M1: state machine

def test_membership_lifecycle_and_log():
    mb = Membership(4, 2)
    assert mb.active == (0, 1) and mb.retired == (2, 3)
    assert mb.epoch == 0 and mb.mask() == 0b0011

    s = mb.begin_join()
    assert s == 2 and mb.joining == (2,) and mb.epoch == 1
    assert mb.routable == (0, 1, 2) and mb.targets == (0, 1, 2)
    assert mb.mask() == 0b0111
    mb.promote(2)
    assert mb.active == (0, 1, 2) and mb.epoch == 2

    mb.begin_drain(0)
    assert mb.draining == (0,) and mb.epoch == 3
    # draining: still routable (owns data), no longer a move target
    assert 0 in mb.routable and 0 not in mb.targets
    mb.finish_drain(0)
    assert mb.retired == (0, 3) and mb.mask() == 0b0110
    assert mb.log == [(1, "join", 2), (2, "promote", 2),
                      (3, "drain", 0), (4, "retire", 0)]
    assert mb.view()["active"] == [1, 2]


def test_membership_invalid_transitions_raise():
    mb = Membership(3, 3)
    with pytest.raises(ValueError, match="cannot join"):
        mb.begin_join(0)            # already active
    with pytest.raises(ValueError, match="no retired"):
        mb.begin_join()
    with pytest.raises(ValueError, match="cannot promote"):
        mb.promote(1)               # not joining
    with pytest.raises(ValueError, match="cannot retire"):
        mb.finish_drain(1)          # not draining
    mb.begin_drain(0)
    mb.begin_drain(1)
    with pytest.raises(ValueError, match="no other"):
        mb.begin_drain(2)           # last possible owner
    with pytest.raises(ValueError, match="out of range"):
        Membership(4, 0)


def test_membership_mask_capacity_limit():
    # full membership at huge capacity: representable as all-bits
    assert live_mask(range(64), 64) == -1
    with pytest.raises(ValueError, match="capacity"):
        live_mask(range(10), MASK_BITS)      # partial at >= 31
    with pytest.raises(ValueError, match="bitmask"):
        Membership(40, 3)
    # capacity > MASK_BITS is now rejected outright at construction —
    # bit ``s`` of the int32 live_mask must exist for every slot, and a
    # silent overflow at 32+ shards corrupted peer-mask gating.
    with pytest.raises(ValueError, match="bitmask"):
        Membership(40)
    mb31 = Membership(MASK_BITS)              # the bound itself still works
    assert mb31.mask() == -1
    with pytest.raises(ValueError):
        mb31.begin_join()


# -------------------------------------------------- M2: transport reset

def _route_rounds(net, n, per_src_rows, rounds, start=0):
    empty = np.zeros((0, M.FIELDS), np.int32)
    backlogs = [empty for _ in range(n)]
    for r in range(start, start + rounds):
        backlogs = [empty for _ in range(n)]
        net.route_round(backlogs, per_src_rows, r)
        per_src_rows = []
    return backlogs


def test_transport_reset_shard_requires_idle():
    net = Transport(4, retransmit_after=2)
    row = epoch_row(dst=1, src=0, epoch=1, mask=0b0011)[None]
    backlogs = _route_rounds(net, 4, [(0, row.astype(np.int32))], 1)
    assert backlogs[1].shape[0] == 1          # delivered...
    assert not net.shard_idle(0) and not net.shard_idle(1)
    assert net.shard_idle(2)
    with pytest.raises(RuntimeError, match="in flight"):
        net.reset_shard(1)                    # ...but the ack is pending
    _route_rounds(net, 4, [], 4, start=1)
    assert net.idle() and net.shard_idle(1)
    net.reset_shard(1)
    assert not any(1 in k for k in net._lanes)
    net.reset_shard(2)                        # no lanes: trivially ok


# ------------------------------------------- M3: the 3 -> 5 -> 2 acid run

def test_scale_up_down_differential_local():
    res = run_membership_differential("local", 11, None, n_ops=200)
    check(res, "seed=11 local (no nemesis)")
    ops = [op for _, op, _ in res["fired"]]
    assert ops == ["join", "join", "retire", "retire", "retire"]
    assert len(res["view"]["active"]) == 2


# --------------------------------------------------------- M4: replay

def test_membership_schedule_replays_byte_identically():
    config = default_nemesis(0.15)
    a = run_membership_differential("local", 13, config, n_ops=150)
    b = run_membership_differential("local", 13, config, n_ops=150)
    assert a["trace"] == b["trace"]
    assert a["mb_log"] == b["mb_log"]
    mb_lines = [ln for ln in a["trace"] if " mb " in ln]
    assert len(mb_lines) == len(a["mb_log"])   # every event is traced
    c = run_membership_differential("local", 14, config, n_ops=150)
    assert a["trace"] != c["trace"]


# ---------------------------------------- M5: partition during a change

def test_partition_during_join_and_retire_heals():
    """The acid test from ISSUE 7: a cut isolating shard 0 — the epoch
    coordinator — overlaps both scheduled changes; announcements and
    evacuation traffic are held, and everything converges post-heal."""
    config = NemesisConfig(drop_prob=0.05,
                           partitions=(Partition(8, 40, (0,)),))
    schedule = ((10, "join", None), (12, "retire", None))
    res = run_membership_differential(
        "local", 17, config, schedule=schedule, n_ops=200,
        capacity=4, initial_shards=3, keep_backend=True)
    check(res, config.repro(17))
    nem = res["backend"].net.nemesis
    assert nem.stats["partitioned"] > 0        # the cut really fired
    assert res["mb_log"][-1][1] == "retire"
    # replay is byte-identical even with the cut crossing the change
    res2 = run_membership_differential(
        "local", 17, config, schedule=schedule, n_ops=200,
        capacity=4, initial_shards=3)
    assert res2["trace"] == res["trace"]
    assert res2["mb_log"] == res["mb_log"]


# ----------------------------------------------------- M6: client pacing

def _pacing_cfg():
    return small_cfg(5)._replace(mailbox_cap=128)


def test_pacing_budget_tracks_membership_both_ways():
    from repro.api.client import local_client
    from repro.core.balancer import Balancer

    cfg = _pacing_cfg()
    cl = local_client(cfg, seed=0, initial_shards=3)
    cl.balance = Balancer(cl.backend, split_threshold=16, merge_threshold=4,
                          rng=cl.backend.balancer_rng)
    bg_budget = cfg.bg_slots * (2 * cfg.move_batch + 2)
    want = lambda n_live: max(1, cfg.mailbox_cap - bg_budget - n_live - 4)
    assert cl.max_inflight == want(3)          # PR 3 snapshot bug: this
    cl.insert_batch(list(range(10, 400, 4)))   # was cfg.num_shards (=5)
    cl.settle()
    cl.backend.join_shard()
    cl.pump()                                  # epoch bump seen here
    assert cl.max_inflight == want(4)
    cl.settle()                                # promote completes
    cl.backend.retire_shard(3)
    cl.settle()                                # drain completes -> retired
    cl.pump()
    assert cl.max_inflight == want(3)
    assert sorted(cl.all_keys()) == list(range(10, 400, 4))


def test_pinned_inflight_survives_epoch_bumps():
    from repro.api.client import local_client
    cl = local_client(_pacing_cfg(), seed=0, initial_shards=3,
                      max_inflight=7)
    assert cl.max_inflight == 7
    cl.backend.join_shard()
    cl.pump()
    assert cl.max_inflight == 7


# ------------------------------------------------------- M7: autoscale

def test_autoscale_policy_joins_retires_and_holds():
    from repro.api import DiLiClient, LocalBackend
    from repro.core.balancer import AutoscalePolicy, Balancer

    cfg = small_cfg(4)
    backend = LocalBackend(cfg, seed=2, initial_shards=2)
    pol = AutoscalePolicy(
        backend, target_load=20, cooldown=0,
        balancer=Balancer(backend, split_threshold=16, merge_threshold=4,
                          rng=backend.balancer_rng))
    client = DiLiClient(backend, balance=pol, balance_every=2)
    mb = backend.membership

    keys = list(range(10, 600, 4))             # 148 keys >> 1.25*20*2
    client.insert_batch(keys)
    client.settle()
    assert len(mb.active) == 4                 # grew to capacity
    assert not mb.joining and not mb.draining

    client.remove_batch(keys[10:])             # 10 keys << 0.45*20*n
    client.settle()
    assert len(mb.active) == 1                 # shrank to min_shards
    assert sorted(backend.all_keys()) == sorted(keys[:10])

    # hysteresis: load the band between retire (9) and join (25) targets
    client.insert_batch(list(range(1000, 1010)))
    client.settle()
    before = mb.epoch
    assert pol.step()["join"] == 0
    assert pol.step()["retire"] == 0
    assert mb.epoch == before


# ------------------------------------------- M8: ShardMap backend parity

@pytest.mark.slow
def test_shardmap_backend_scales_under_nemesis():
    n_seeds = int(os.environ.get("MEMBERSHIP_SOAK_SHARDMAP_SEEDS", "1"))
    n_ops = int(os.environ.get("MEMBERSHIP_SOAK_OPS", "150"))
    seeds = [str(11 + i) for i in range(n_seeds)]
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=6"
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run(
        [sys.executable, os.path.join("tests", "membership_harness.py"),
         "shardmap", str(n_ops), "0.1"] + seeds,
        env=env, capture_output=True, text=True,
        timeout=600 * max(1, n_seeds), cwd=REPO)
    if r.returncode != 0:
        for line in r.stdout.splitlines():
            if line.startswith("FAILING-SEEDS "):
                outdir = os.path.join(REPO, "membership_failures")
                os.makedirs(outdir, exist_ok=True)
                with open(os.path.join(outdir, "shardmap_soak.json"),
                          "w") as f:
                    f.write(line[len("FAILING-SEEDS "):])
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert r.stdout.count("OK shardmap") == n_seeds


# ----------------------------------------------------------- M9: soak

@pytest.mark.slow
def test_membership_soak_many_seeds():
    """Seeds x fault levels over the 3->5->2 schedule plus a partitioned
    variant. The membership-soak CI job scales MEMBERSHIP_SOAK_SEEDS /
    MEMBERSHIP_SOAK_OPS; failing seeds are dumped under
    membership_failures/ for artifact upload."""
    per_level = int(os.environ.get("MEMBERSHIP_SOAK_SEEDS", "1"))
    n_ops = int(os.environ.get("MEMBERSHIP_SOAK_OPS", "200"))
    part = (Partition(15, 45, (1,)),)
    failures = []
    for li, (p, parts) in enumerate(((0.05, ()), (0.2, ()), (0.1, part))):
        config = NemesisConfig(drop_prob=p, dup_prob=p, reorder_prob=p,
                               delay_prob=p / 2, delay_rounds=3,
                               partitions=parts)
        for seed in range(2000 + 500 * li, 2000 + 500 * li + per_level):
            repro = config.repro(seed)
            try:
                res = run_membership_differential("local", seed, config,
                                                  n_ops=n_ops)
                check(res, repro)
            except (AssertionError, RuntimeError) as e:
                failures.append({"seed": seed, "config": config.to_dict(),
                                 "backend": "local", "error": str(e)})
    if failures:
        outdir = os.path.join(REPO, "membership_failures")
        os.makedirs(outdir, exist_ok=True)
        path = os.path.join(outdir, "local_soak.json")
        with open(path, "w") as f:
            json.dump(failures, f, indent=1)
        pytest.fail(f"{len(failures)} failing seeds written to {path}: "
                    + ", ".join(str(x["seed"]) for x in failures))
