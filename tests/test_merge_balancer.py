"""Merge (Appendix B) and the §7.1 load balancer, end to end."""
import numpy as np
import pytest

from repro.core.balancer import Balancer
from repro.core.oracle import OracleList
from repro.core.sim import Cluster
from repro.core.types import DiLiConfig, OP_FIND, OP_INSERT, OP_REMOVE


def mkcfg(**kw):
    base = dict(num_shards=2, pool_capacity=4096, max_sublists=64,
                max_ctrs=64, max_scan=4096, batch_size=32, mailbox_cap=256,
                move_batch=16)
    base.update(kw)
    return DiLiConfig(**base)


def test_merge_after_split_roundtrip():
    cfg = mkcfg(num_shards=2)
    cl = Cluster(cfg)
    oracle = OracleList()
    keys = list(range(10, 90))
    ids = cl.submit(0, [OP_INSERT] * len(keys), keys)
    oracle.apply_batch([OP_INSERT] * len(keys), keys)
    cl.run_until_quiet()

    subs = cl.sublists(0)
    mid = cl.middle_item(0, subs[0]["head_idx"])
    cl.split(0, subs[0]["keymax"], mid)
    cl.run_until_quiet()
    subs = sorted(cl.sublists(0), key=lambda e: e["keymin"])
    assert len(subs) == 2

    cl.merge(0, subs[0]["keymax"], subs[1]["keymax"])
    cl.run_until_quiet()
    for s in range(2):
        assert len(cl.sublists(s)) == 1, cl.sublists(s)
    assert cl.all_keys() == sorted(oracle.snapshot())

    # semantics intact after merge
    kinds = [OP_FIND, OP_REMOVE, OP_FIND, OP_INSERT]
    ks = [50, 50, 50, 50]
    ids = cl.submit(1, kinds, ks)
    exp = oracle.apply_batch(kinds, ks)
    cl.run_until_quiet()
    assert [bool(cl.results[i]) for i in ids] == exp
    assert cl.all_keys() == sorted(oracle.snapshot())


def test_merge_under_concurrent_ops():
    cfg = mkcfg(num_shards=1)
    cl = Cluster(cfg)
    oracle = OracleList()
    rng = np.random.default_rng(3)
    keys = list(range(0, 300, 3))[1:]
    cl.submit(0, [OP_INSERT] * len(keys), keys)
    oracle.apply_batch([OP_INSERT] * len(keys), keys)
    cl.run_until_quiet()
    subs = cl.sublists(0)
    mid = cl.middle_item(0, subs[0]["head_idx"])
    cl.split(0, subs[0]["keymax"], mid)
    cl.run_until_quiet()
    subs = sorted(cl.sublists(0), key=lambda e: e["keymin"])

    cl.merge(0, subs[0]["keymax"], subs[1]["keymax"])
    all_ids, all_exp = [], []
    for _ in range(5):
        kinds = rng.choice([OP_INSERT, OP_REMOVE, OP_FIND], 8).tolist()
        ks = rng.integers(1, 320, 8).tolist()
        all_ids += cl.submit(0, kinds, ks)
        all_exp += oracle.apply_batch(kinds, ks)
        cl.step()
    cl.run_until_quiet()
    assert [bool(cl.results[i]) for i in all_ids] == all_exp
    assert cl.all_keys() == sorted(oracle.snapshot())
    assert len(cl.sublists(0)) == 1


@pytest.mark.parametrize("nshards", [2, 4])
def test_balancer_end_to_end(nshards):
    """The paper's experiment in miniature: load keys through the balancer;
    sublists stay under the threshold and shards end up roughly even."""
    cfg = mkcfg(num_shards=nshards, split_threshold=40,
                pool_capacity=8192, max_scan=8192)
    cl = Cluster(cfg)
    bal = Balancer(cl)
    oracle = OracleList()
    rng = np.random.default_rng(11)
    keyspace = rng.permutation(np.arange(1, 2000))[:600]

    chunks = np.array_split(keyspace, 30)
    for ch in chunks:
        ks = ch.tolist()
        cl.submit(0, [OP_INSERT] * len(ks), ks)
        oracle.apply_batch([OP_INSERT] * len(ks), ks)
        cl.step()
        bal.step()
    cl.run_until_quiet(600)
    # let the balancer settle: one background op per shard per pass
    # (the paper's one-background-thread-per-machine rule), so convergence
    # takes a number of passes proportional to the final sublist count.
    for _ in range(100):
        issued = bal.step()
        cl.run_until_quiet(600)
        if not any(issued.values()):
            break

    assert cl.all_keys() == sorted(oracle.snapshot())
    # no oversized sublists (bounded hybrid-search traversal)
    for s in range(nshards):
        for e in cl.sublists(s):
            if e["owner"] == s and e["size"] is not None:
                assert e["size"] <= cfg.split_threshold + 10, e
    # load roughly balanced across shards
    loads = []
    for s in range(nshards):
        loads.append(sum(e["size"] or 0 for e in cl.sublists(s)
                         if e["owner"] == s))
    assert sum(loads) == len(oracle.snapshot())
    assert max(loads) <= 1.7 * (sum(loads) / nshards) + 50, loads
