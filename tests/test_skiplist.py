"""Skip-list baseline vs the oracle (it feeds the Fig. 3a benchmark)."""
import jax
import numpy as np
import pytest

from repro.core import skiplist as SL
from repro.core.oracle import OracleList
from repro.core.types import OP_FIND, OP_INSERT, OP_REMOVE

LEVELS = 8


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_skiplist_matches_oracle(seed):
    rng = np.random.default_rng(seed)
    sl = SL.init(capacity=2048, max_level=LEVELS)
    oracle = OracleList()
    n = 400
    kinds = rng.choice([OP_FIND, OP_INSERT, OP_REMOVE], n,
                       p=[0.2, 0.5, 0.3]).astype(np.int32)
    keys = rng.integers(1, 200, n).astype(np.int32)
    batch = jax.jit(lambda s, k, x: SL.apply_batch(s, k, x, LEVELS))
    sl, res = batch(sl, kinds, keys)
    exp = oracle.apply_batch(kinds, keys)
    assert [bool(r) for r in np.asarray(res)] == exp
    # level-0 chain equals the oracle's sorted key set
    nxt = np.asarray(sl.nxt)
    key = np.asarray(sl.key)
    out, node = [], int(nxt[0, SL.HEAD])
    while node != SL.NIL:
        out.append(int(key[node]))
        node = int(nxt[0, node])
    assert out == sorted(oracle.snapshot())


def test_skiplist_reuse_slots():
    sl = SL.init(capacity=64, max_level=LEVELS)
    batch = jax.jit(lambda s, k, x: SL.apply_batch(s, k, x, LEVELS))
    ins = [OP_INSERT] * 30
    rem = [OP_REMOVE] * 30
    ks = list(range(1, 31))
    sl, r1 = batch(sl, ins, ks)
    sl, r2 = batch(sl, rem, ks)
    sl, r3 = batch(sl, ins, ks)
    assert all(np.asarray(r1)) and all(np.asarray(r2)) and all(np.asarray(r3))
    assert int(sl.alloc_top) <= 31  # slots recycled
