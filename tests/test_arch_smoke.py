"""Per-architecture smoke tests: reduced configs, one train + serve step.

Every assigned architecture must instantiate, run a forward/backward train
step and a prefill+decode step on CPU with finite outputs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.data.synthetic import make_serve_batch, make_train_batch
from repro.models import transformer as T
from repro.models.config import ShapeCell

SMOKE_TRAIN = ShapeCell("smoke_train", "train", 128, 2)
SMOKE_SERVE = ShapeCell("smoke_serve", "decode", 128, 2)


def _finite(tree):
    return all(bool(jnp.all(jnp.isfinite(x))) for x in
               jax.tree_util.tree_leaves(tree)
               if jnp.issubdtype(x.dtype, jnp.floating))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = get_smoke_config(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    batch = make_train_batch(cfg, SMOKE_TRAIN, dtype=jnp.float32)

    @jax.jit
    def loss_and_grad(p):
        return jax.value_and_grad(
            lambda p: T.forward_train(p, cfg, batch)[0])(p)

    loss, grads = loss_and_grad(params)
    assert np.isfinite(float(loss)), arch
    # loss should be near ln(vocab) for random init
    assert 0.1 * np.log(cfg.vocab) < float(loss) < 3 * np.log(cfg.vocab) + 2
    assert _finite(grads), f"{arch}: non-finite grads"
    # gradients reach the embedding / first-layer params
    gnorm = sum(jnp.sum(jnp.square(g)) for g in
                jax.tree_util.tree_leaves(grads))
    assert float(gnorm) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_serve_prefill_decode_smoke(arch):
    cfg = get_smoke_config(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    b, s = SMOKE_SERVE.global_batch, SMOKE_SERVE.seq_len
    cache = T.init_cache(cfg, b, s, dtype=jnp.float32)
    prompt = make_serve_batch(cfg, SMOKE_SERVE, decode=False,
                              dtype=jnp.float32)
    plen = (prompt.get("tokens", prompt.get("frame_embeds"))).shape[1]
    if "patch_embeds" in prompt:
        plen += prompt["patch_embeds"].shape[1]

    serve = jax.jit(lambda p, batch, c, n, d: T.forward_serve(
        p, cfg, batch, c, n, decode=d), static_argnames=("d",))

    zero = jnp.zeros((b,), jnp.int32)
    logits, cache = serve(params, prompt, cache, zero, False)
    assert logits.shape == (b, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), arch

    # 3 decode steps
    cache_len = jnp.full((b,), plen, jnp.int32)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for _ in range(3):
        step_batch = {"tokens": tok}
        if cfg.modality == "audio_stub":
            emb = params["embed"][tok[:, 0]][:, None, :]
            step_batch = {"frame_embeds": emb}
        logits, cache = serve(params, step_batch, cache, cache_len, True)
        assert logits.shape == (b, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits)))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        cache_len = cache_len + 1


def test_decode_matches_prefill_dense():
    """Teacher-forced decode must reproduce prefill logits (KV-cache check)."""
    cfg = get_smoke_config("qwen2_0_5b")
    params = T.init_params(cfg, jax.random.PRNGKey(1), dtype=jnp.float32)
    b, s = 2, 16
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)).astype(np.int32))

    # full prefill logits of the last position
    cache = T.init_cache(cfg, b, 32, dtype=jnp.float32)
    full_logits, _ = T.forward_serve(params, cfg, {"tokens": toks}, cache,
                                     jnp.zeros((b,), jnp.int32), decode=False)

    # prefill s-1 then decode token s-1
    cache = T.init_cache(cfg, b, 32, dtype=jnp.float32)
    _, cache = T.forward_serve(params, cfg, {"tokens": toks[:, :-1]}, cache,
                               jnp.zeros((b,), jnp.int32), decode=False)
    step_logits, _ = T.forward_serve(
        params, cfg, {"tokens": toks[:, -1:]}, cache,
        jnp.full((b,), s - 1, jnp.int32), decode=True)
    np.testing.assert_allclose(np.asarray(step_logits),
                               np.asarray(full_logits), atol=2e-4, rtol=1e-3)


def test_decode_matches_prefill_ssm():
    cfg = get_smoke_config("falcon_mamba_7b")
    params = T.init_params(cfg, jax.random.PRNGKey(1), dtype=jnp.float32)
    b, s = 2, 16
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)).astype(np.int32))
    cache = T.init_cache(cfg, b, 32, dtype=jnp.float32)
    full_logits, _ = T.forward_serve(params, cfg, {"tokens": toks}, cache,
                                     jnp.zeros((b,), jnp.int32), decode=False)
    cache = T.init_cache(cfg, b, 32, dtype=jnp.float32)
    _, cache = T.forward_serve(params, cfg, {"tokens": toks[:, :-1]}, cache,
                               jnp.zeros((b,), jnp.int32), decode=False)
    step_logits, _ = T.forward_serve(
        params, cfg, {"tokens": toks[:, -1:]}, cache,
        jnp.full((b,), s - 1, jnp.int32), decode=True)
    np.testing.assert_allclose(np.asarray(step_logits),
                               np.asarray(full_logits), atol=2e-4, rtol=1e-3)
