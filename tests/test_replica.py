"""Hot-sublist read replication tests (DESIGN.md §15).

R1  Lifecycle: replicate -> delta stream -> INSTALL -> replica-served
    FINDs -> drop_replica retires the slot and routing falls back home.
R2  Staleness lease: with renewals effectively disabled, a replica stops
    serving within ``replica_staleness_rounds`` of its last commit and
    reads bounce home — still correct, just no longer replica-served.
R3  Mutation propagation: a write at the primary reaches the replica
    image within one refresh cadence (plus streaming slack).
R4  Move interaction: moving a replicated entry prunes the routing view
    and the session self-audit retires the remote slot; reads stay
    correct throughout.
R5  Replay: the journaled replicate command is part of the (seed,
    config) witness — a crash-restart of the primary recovers the
    session, and two identical executions digest-match.
R6  Differential under nemesis with replication forced on: the windowed
    referee (bounded staleness for replica-served FINDs) holds against
    the sequential oracle, and the final key set is exact.
R7  Same differential across a crash-restart schedule.
"""
import numpy as np
import pytest

from nemesis_harness import check, default_nemesis, run_differential
from repro.api import DiLiClient, LocalBackend
from repro.core.net import NemesisConfig
from repro.core.net.nemesis import CrashPlan
from repro.core.sim import Cluster
from repro.core.types import DiLiConfig, OP_FIND, OP_INSERT, SH_KEY


def rep_cfg(**over):
    base = dict(num_shards=3, pool_capacity=4096, max_sublists=32,
                max_ctrs=32, max_scan=4096, batch_size=16,
                mailbox_cap=256, move_batch=8, replication=True,
                replica_sessions=2, replica_slots=4, replica_batch=8,
                replica_refresh_rounds=4, replica_staleness_rounds=32)
    base.update(over)
    return DiLiConfig(**base)


KEYS = list(range(10, 400, 3))


def _loaded_backend(cfg):
    be = LocalBackend(cfg)
    client = DiLiClient(be)
    client.insert_batch(KEYS)
    client.drain(2000)
    return be, client


def _only_entry_kmax(be, shard=0):
    ents = [e for e in be.sublists(shard) if e["owner"] == shard]
    assert len(ents) == 1
    return ents[0]["keymax"]


def _pump_until(client, pred, rounds=200):
    for _ in range(rounds):
        if pred():
            return True
        client.pump()
    return pred()


def test_replicate_install_serve_drop_lifecycle():
    be, client = _loaded_backend(rep_cfg())
    kmax = _only_entry_kmax(be)
    assert be.replicate(0, kmax, 1)
    assert be.replicate(0, kmax, 2)
    sets = be.replica_sets()
    assert sets[kmax][1] == 0 and sets[kmax][2] == [1, 2]

    def installed():
        return all(int(np.asarray(be.cluster.states[t].rslots.ttl).max()) > 0
                   for t in (1, 2))
    assert _pump_until(client, installed), "replica images never committed"

    # replica-served reads: spread over primary+replicas, all correct
    probe = KEYS[::7] + [11, 12, 200, 399]
    futs = client.find_batch(probe)
    client.drain(2000)
    assert [bool(r) for r in futs.results()] == [k in set(KEYS)
                                                 for k in probe]
    assert be.stats["rep_hits"] > 0

    # drop: slots retire, routing falls back home, reads stay correct
    assert be.drop_replica(0, kmax)
    def retired():
        return all(int(np.asarray(be.cluster.states[t].rslots.ttl).max()) == 0
                   for t in (1, 2))
    assert _pump_until(client, retired), "replica slots never retired"
    assert be.replica_sets() == {}
    h0 = be.stats["rep_hits"]
    futs = client.find_batch(probe)
    client.drain(2000)
    assert [bool(r) for r in futs.results()] == [k in set(KEYS)
                                                 for k in probe]
    assert be.stats["rep_hits"] == h0


def test_staleness_lease_lapses_without_refresh():
    # renewals pushed past any horizon this test runs: after the first
    # INSTALL the lease only decays, so the slot must self-invalidate
    # within replica_staleness_rounds and reads bounce home
    cfg = rep_cfg(replica_refresh_rounds=10_000,
                  replica_staleness_rounds=6)
    be, client = _loaded_backend(cfg)
    kmax = _only_entry_kmax(be)
    assert be.replicate(0, kmax, 1)
    assert _pump_until(
        client,
        lambda: int(np.asarray(be.cluster.states[1].rslots.ttl).max()) > 0)
    for _ in range(cfg.replica_staleness_rounds + 2):
        client.pump()
    assert int(np.asarray(be.cluster.states[1].rslots.ttl).max()) == 0
    h0 = be.stats["rep_hits"]
    probe = KEYS[:8] + [11, 14]
    futs = client.find_batch(probe)
    client.drain(2000)
    assert [bool(r) for r in futs.results()] == [k in set(KEYS)
                                                 for k in probe]
    # lease lapsed: nothing was replica-served, yet every read answered
    assert be.stats["rep_hits"] == h0


def test_mutation_reaches_replica_within_cadence():
    cfg = rep_cfg(replica_refresh_rounds=3)
    be, client = _loaded_backend(cfg)
    kmax = _only_entry_kmax(be)
    assert be.replicate(0, kmax, 1)
    assert _pump_until(
        client,
        lambda: int(np.asarray(be.cluster.states[1].rslots.ttl).max()) > 0)
    new_key = 101   # inside the range, not in KEYS (KEYS are 10+3k)
    assert new_key not in set(KEYS)
    client.insert(new_key)
    client.drain(2000)

    def image_has_key():
        # keep FIND traffic flowing: cadence renewals require traffic
        client.find(KEYS[0])
        return new_key in np.asarray(be.cluster.states[1].rslots.keys)
    budget = cfg.replica_refresh_rounds + cfg.replica_batch + 16
    assert _pump_until(client, image_has_key, rounds=budget), \
        "mutation did not reach the replica image within one cadence"
    client.drain(2000)


def test_move_of_replicated_entry_retires_replicas():
    be, client = _loaded_backend(rep_cfg())
    kmax = _only_entry_kmax(be)
    assert be.replicate(0, kmax, 1)
    assert _pump_until(
        client,
        lambda: int(np.asarray(be.cluster.states[1].rslots.ttl).max()) > 0)
    # raw move (no balancer shed): the routing view prunes on ownership
    # loss and the primary session's self-audit drops the remote slot
    assert be.move(0, kmax, 2)
    client.drain(2000)
    assert be.replica_sets() == {}
    assert _pump_until(
        client,
        lambda: int(np.asarray(be.cluster.states[1].rslots.ttl).max()) == 0)
    # session freed on the old primary
    assert all(int(k) == SH_KEY
               for k in np.asarray(be.cluster.states[0].rep.keymax))
    probe = KEYS[::11] + [11, 398]
    futs = client.find_batch(probe)
    client.drain(2000)
    assert [bool(r) for r in futs.results()] == [k in set(KEYS)
                                                 for k in probe]


def _scripted_replicated_run(tmpdir, crashes=()):
    nem = NemesisConfig(crashes=tuple(crashes)) if crashes else None
    cl = Cluster(rep_cfg(), seed=7, nemesis=nem,
                 durability=str(tmpdir))
    cl.submit(0, [OP_INSERT] * len(KEYS), list(KEYS))
    cl.run_until_quiet(800)
    ents = [e for e in cl.sublists(0) if e["owner"] == 0]
    assert cl.replicate(0, ents[0]["keymax"], 1)
    for _ in range(50):
        cl.step()
    cl.submit(1, [OP_FIND] * 5, [10, 11, 13, 397, 399])
    cl.run_until_quiet(800)
    return cl


def test_replicate_command_replays_byte_identically(tmp_path):
    from repro.core.net.digest import state_digest
    a = _scripted_replicated_run(tmp_path / "a")
    b = _scripted_replicated_run(tmp_path / "b")
    assert state_digest(a.states, a.bgs) == state_digest(b.states, b.bgs)


def test_replicate_survives_primary_crash_restart(tmp_path):
    # crash the primary after the replicate command lands: recovery
    # replays the journaled command and the session (plus its lease
    # bookkeeping) is rebuilt into the same state
    cl = _scripted_replicated_run(
        tmp_path, crashes=[CrashPlan(shard=0, crash_round=15,
                                     restart_round=35)])
    assert cl.durability.stats["recoveries"] == 1
    assert cl.durability.stats["commands"] >= 1
    kmaxes = np.asarray(cl.states[0].rep.keymax)
    assert (kmaxes != SH_KEY).any(), \
        "recovered primary lost its replication session"
    cl.submit(2, [OP_FIND] * 3, [10, 11, 399])
    cl.run_until_quiet(800)
    assert cl.results


REP_OVERRIDES = dict(replication=True, replica_sessions=4, replica_slots=8,
                     replica_batch=8, replica_refresh_rounds=4,
                     replica_staleness_rounds=32)
# hot_rate floor of 1 op/round + no share gate: the balancer replicates
# whatever the differential workload touches, so the windowed referee and
# the REPLICA_* wire kinds are actually exercised
REP_BAL = dict(hot_rate=1.0, hot_share=0.0, cold_rate=0.0,
               replica_fanout=2)


def test_differential_nemesis_with_replication():
    nem = default_nemesis(0.10)
    res = run_differential("local", 47, nem, n_ops=400,
                           cfg_overrides=REP_OVERRIDES,
                           balancer_kwargs=REP_BAL, keep_backend=True)
    check(res, nem.repro(47))
    assert res["backend"].stats["rep_hits"] > 0, \
        "replication never engaged — the run exercised nothing new"


def test_differential_crash_restart_with_replication():
    nem = NemesisConfig(drop_prob=0.05, dup_prob=0.05, reorder_prob=0.05,
                        crashes=(CrashPlan(shard=1, crash_round=60,
                                           restart_round=110),))
    res = run_differential("local", 29, nem, n_ops=300,
                           cfg_overrides=REP_OVERRIDES,
                           balancer_kwargs=REP_BAL, keep_backend=True)
    check(res, nem.repro(29))
    dur = res["backend"].cluster.durability
    assert dur.stats["recoveries"] == 1
