"""Nemesis integration tests (DESIGN.md §11).

N1  Corpus replay: every checked-in (seed, config) schedule in
    tests/nemesis_corpus.json passes the full differential (results +
    final key set vs the sequential oracle, quiescence) — hunt-found
    failures get their repro line added there.
N2  Duplicate-delivery idempotence: re-delivering every recorded message
    kind (including a full batched MSG_MOVE_ITEMS run and stale slot
    acks after the MOVE completed) leaves the state hash unchanged —
    at-least-once delivery collapses to exactly-once effects.
N3  Single-seed reproducibility: two runs from one (seed, config) produce
    byte-identical round traces; a run killed mid-flight and restarted
    reproduces the same trace prefix.
N4  Partition heal: a multi-round partition stalls cross-cut traffic,
    retransmission delivers everything after the cut lifts.
N5  Backend parity under fire: the ShardMap backend passes the same
    differential through host-routed transport (subprocess: needs a
    multi-device XLA host platform).
N6  Soak: many-seed differentials, scaled up by NEMESIS_SOAK_* env vars
    in the nemesis-soak CI job; failing seeds are written as artifacts.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from nemesis_harness import (default_nemesis, make_backend,
                             run_differential, check, small_cfg)
from repro.core import messages as M
from repro.core.net import NemesisConfig, state_digest
from repro.core.sim import Cluster
from repro.core.types import OP_FIND, OP_INSERT, OP_REMOVE

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
CORPUS = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                      "nemesis_corpus.json")

with open(CORPUS) as f:
    _corpus = json.load(f)["entries"]


# ------------------------------------------------------------- N1: corpus

@pytest.mark.parametrize("entry", _corpus, ids=[e["name"] for e in _corpus])
def test_corpus_schedule(entry):
    config = NemesisConfig.from_dict(entry["config"])
    repro = f"corpus:{entry['name']} {config.repro(entry['seed'])}"
    res = run_differential("local", entry["seed"], config,
                           n_ops=entry["n_ops"],
                           scan_every=entry.get("scan_every", 0))
    check(res, repro)
    if entry.get("scan_every"):
        assert res["n_scans"] > 0, repro
    # the schedule must actually have exercised the wire
    assert res["net_stats"]["sent"] > 0, repro
    if config.crashes:       # and the kill -9 must actually have fired
        assert any("mb crash" in ln for ln in res["trace"]), repro


# ------------------------------------------------- N2: idempotence matrix

def _scripted_move_workload():
    """A deterministic 2-shard run (transport on, zero faults) covering
    the protocol's message kinds: split, two moves (the second's left
    neighbor lives remotely → remote SwitchST), racing ops during the
    copies (replicates), a merge on the target, and cross-shard client
    ops (delegation + results), and a shard join (epoch announcements).
    Returns (cluster, recorded frames)."""
    cfg = small_cfg(3)._replace(move_batch=2, replication=True,
                                replica_sessions=2, replica_slots=4,
                                replica_batch=4, replica_refresh_rounds=2,
                                replica_staleness_rounds=16)
    cl = Cluster(cfg, seed=1, nemesis=NemesisConfig(), initial_shards=2)
    rec = []
    orig = cl.net.nemesis.perturb

    def spy(frames, round_no):
        rec.extend((s, d, row.copy()) for s, d, row in frames)
        return orig(frames, round_no)

    cl.net.nemesis.perturb = spy

    keys = list(range(10, 210, 5))
    cl.submit(0, [OP_INSERT] * len(keys), keys)
    cl.run_until_quiet(600)

    subs = [e for e in cl.sublists(0) if e["owner"] == 0]
    mid = cl.middle_item(0, subs[0]["head_idx"])
    assert cl.split(0, subs[0]["keymax"], mid)
    cl.run_until_quiet(600)

    def move_with_races(entry_idx, racing_lo, racing_hi):
        subs = sorted((e for e in cl.sublists(0) if e["owner"] == 0),
                      key=lambda e: e["keymin"])
        assert cl.move(0, subs[entry_idx]["keymax"], 1)
        rng = np.random.default_rng(9)
        for _ in range(12):
            ks = rng.integers(racing_lo, racing_hi, 2).tolist()
            cl.submit(0, [OP_INSERT, OP_REMOVE], ks)
            cl.step()
        cl.run_until_quiet(800)

    move_with_races(0, 10, 100)      # left half; local switch
    move_with_races(0, 100, 210)     # remaining half; left now on 1 →
                                     # remote SwitchST + ack
    subs1 = sorted((e for e in cl.sublists(1) if e["owner"] == 1),
                   key=lambda e: e["keymin"])
    assert len(subs1) >= 2
    assert cl.merge(1, subs1[0]["keymax"], subs1[1]["keymax"])
    cl.run_until_quiet(600)

    # read replication (§15): replicate shard 1's merged entry onto
    # shard 0, race mutations against the delta stream, then retire it —
    # REPLICA_DELTA (image cells), REPLICA_INSTALL (version commits /
    # lease renewals) and REPLICA_DROP (teardown) all cross the recorded
    # wire
    ent = sorted((e for e in cl.sublists(1) if e["owner"] == 1),
                 key=lambda e: e["keymin"])[0]
    assert cl.replicate(1, ent["keymax"], 0)
    lo = max(ent["keymin"] + 1, 11)
    hi = min(ent["keymax"], 209)
    rng = np.random.default_rng(11)
    for _ in range(10):
        ks = rng.integers(lo, hi, 2).tolist()
        cl.submit(1, [OP_INSERT, OP_REMOVE], ks)
        cl.step()
    cl.run_until_quiet(600)
    assert cl.drop_replica(1, ent["keymax"])
    cl.run_until_quiet(600)
    # teardown complete: no live lease or session may keep ticking, or
    # the digest comparisons below would drift with every extra round
    assert all(int(np.asarray(st.rslots.ttl).max(initial=0)) == 0
               for st in cl.states)
    assert cl.replica_sets() == {}

    # cross-shard client traffic: submitted at 0, owned by 1
    cl.submit(0, [OP_FIND] * 4, [20, 60, 120, 180])
    cl.run_until_quiet(600)

    # elastic membership (DESIGN.md §13): admit the spare capacity slot
    # and hand it a sublist — the join and promote epoch announcements
    # (MSG_EPOCH) cross the recorded wire
    assert cl.join_shard() == 2
    cl.run_until_quiet(600)
    subs1 = sorted((e for e in cl.sublists(1) if e["owner"] == 1),
                   key=lambda e: e["keymin"])
    assert cl.move(1, subs1[0]["keymax"], 2)
    cl.run_until_quiet(800)
    assert cl.membership.active == (0, 1, 2)
    return cl, rec


def _digest(cl):
    """State hash modulo the BgTable's free-running per-round tick
    (``bg.round`` advances every round even at rest; with all slots idle
    it has no other effect)."""
    bgs = [b._replace(round=np.zeros_like(np.asarray(b.round)))
           for b in cl.bgs]
    return state_digest(cl.states, bgs)


def test_duplicate_delivery_idempotence_matrix():
    cl, rec = _scripted_move_workload()
    data = [f for f in rec if int(f[2][M.F_KIND]) != M.MSG_NET_ACK]
    kinds = {int(f[2][M.F_KIND]) for f in data}
    # the workload must cover the full protocol surface, incl. the
    # batched MSG_MOVE_ITEMS runs and every ack kind
    required = {M.MSG_OP, M.MSG_RESULT, M.MSG_MOVE_SH, M.MSG_MOVE_SH_ACK,
                M.MSG_MOVE_ITEMS, M.MSG_MOVE_ITEM, M.MSG_MOVE_ACK,
                M.MSG_SWITCH_ST, M.MSG_SWITCH_ST_ACK, M.MSG_SWITCH_SERVER,
                M.MSG_REG_SPLIT, M.MSG_REG_MERGED, M.MSG_EPOCH,
                M.MSG_REPLICA_DELTA, M.MSG_REPLICA_INSTALL,
                M.MSG_REPLICA_DROP}
    assert required <= kinds, f"missing kinds: {sorted(required - kinds)}"

    d0 = _digest(cl)
    for kind in sorted(kinds):
        frames = [f for f in data if int(f[2][M.F_KIND]) == kind]
        before = cl.net.stats["dup_dropped"]
        # re-deliver the kind's entire recorded traffic twice — every
        # frame is a duplicate (its seq is at or below the lane cursor)
        # and must be absorbed by the transport's dedup window
        cl.net._staged.extend(frames)
        cl.net._staged.extend(frames)
        cl.step()
        cl.run_until_quiet(200)
        assert cl.net.stats["dup_dropped"] >= before + 2 * len(frames), kind
        assert _digest(cl) == d0, \
            f"kind {kind} re-delivery changed state"


def test_stale_slot_ack_after_move_is_inert():
    """A *fresh* (new-seq) MOVE_ACK addressed at a now-idle background
    slot — the handler-level guard, beyond transport dedup: slot credits
    are phase-gated and the newLoc write is idempotent by identity."""
    cl, rec = _scripted_move_workload()
    acks = [f for f in rec
            if int(f[2][M.F_KIND]) == M.MSG_MOVE_ACK][:4]
    assert acks
    d0 = _digest(cl)
    for src, dst, row in acks:
        fresh = row.copy()
        fresh[M.F_SEQ] = 0              # never crossed a transport
        cl.backlog[dst] = np.concatenate(
            [cl.backlog[dst], fresh[None]], axis=0)
    cl.run_until_quiet(200)
    assert _digest(cl) == d0


def test_duplicate_delivery_after_recovery_is_inert():
    """Idempotence extended to WAL-replayed rounds (DESIGN.md §14):
    recovery restores the receiver cursors from the journaled lane
    image, so frames recorded before/through a crash, re-delivered
    against the just-recovered shard, are absorbed by the dedup window
    with no state change — recovery must not reopen at-least-once
    delivery into double effects."""
    from repro.core.net.nemesis import CrashPlan
    cfg = small_cfg(2)._replace(move_batch=2)
    nem = NemesisConfig(crashes=(CrashPlan(1, 30, 55),))
    cl = Cluster(cfg, seed=1, nemesis=nem)
    rec = []
    orig = cl.net.nemesis.perturb

    def spy(frames, round_no):
        rec.extend((s, d, row.copy()) for s, d, row in frames)
        return orig(frames, round_no)

    cl.net.nemesis.perturb = spy

    keys = list(range(10, 210, 5))
    cl.submit(0, [OP_INSERT] * len(keys), keys)
    cl.run_until_quiet(600)
    subs = [e for e in cl.sublists(0) if e["owner"] == 0]
    mid = cl.middle_item(0, subs[0]["head_idx"])
    assert cl.split(0, subs[0]["keymax"], mid)
    cl.run_until_quiet(600)
    subs = sorted((e for e in cl.sublists(0) if e["owner"] == 0),
                  key=lambda e: e["keymin"])
    assert cl.move(0, subs[0]["keymax"], 1)
    cl.run_until_quiet(800)
    # cross-shard FINDs through the crash window (r30 crash, r55 restart)
    while cl.round_no < 70:
        cl.submit(0, [OP_FIND] * 4, [20, 60, 120, 180])
        cl.step()
    cl.run_until_quiet(800)
    assert cl.durability.stats["recoveries"] == 1
    assert sorted(cl.all_keys()) == sorted(keys)

    d0 = _digest(cl)
    replayed = [f for f in rec
                if int(f[2][M.F_KIND]) != M.MSG_NET_ACK and f[1] == 1]
    assert len(replayed) > 10
    before = cl.net.stats["dup_dropped"]
    cl.net._staged.extend(replayed)
    cl.net._staged.extend(replayed)
    cl.step()
    cl.run_until_quiet(200)
    assert cl.net.stats["dup_dropped"] >= before + 2 * len(replayed)
    assert _digest(cl) == d0, "re-delivery against recovered shard " \
                              "changed state"


# --------------------------------------------- N3: (seed, config) replay

def _scripted_run(seed, config, rounds):
    cfg = small_cfg(2)
    cl = Cluster(cfg, seed=seed, nemesis=config)
    rng = np.random.default_rng(42)      # workload stream, fixed
    keys = list(range(5, 150, 3))
    cl.submit(0, [OP_INSERT] * len(keys), keys)
    for r in range(rounds):
        if r == 10:
            subs = [e for e in cl.sublists(0) if e["owner"] == 0]
            if subs:
                mid = cl.middle_item(0, subs[0]["head_idx"])
                if mid is not None:
                    cl.split(0, subs[0]["keymax"], mid)
        if r == 25:
            subs = [e for e in cl.sublists(0) if e["owner"] == 0]
            if subs:
                cl.move(0, subs[-1]["keymax"], 1)
        kinds = rng.choice([OP_FIND, OP_INSERT, OP_REMOVE], 4).tolist()
        cl.submit(r % 2, kinds, rng.integers(1, 200, 4).tolist())
        cl.step()
    return cl


def test_same_seed_runs_produce_identical_round_traces():
    config = default_nemesis(0.2)
    a = _scripted_run(3, config, 80)
    b = _scripted_run(3, config, 80)
    assert a.round_trace == b.round_trace
    assert state_digest(a.states, a.bgs) == state_digest(b.states, b.bgs)
    c = _scripted_run(4, config, 80)
    assert a.round_trace != c.round_trace


def test_killed_and_restarted_schedule_replays_byte_identically():
    """Kill a run mid-flight (messages in fabric, move in progress);
    a fresh run from the same (seed, config) reproduces the dead run's
    trace as an exact prefix — the repro contract for failing seeds."""
    config = default_nemesis(0.2)
    dead = _scripted_run(7, config, 30)      # killed at round 30
    assert not dead.net.idle() or any(
        b.shape[0] for b in dead.backlog)    # genuinely mid-flight
    full = _scripted_run(7, config, 80)
    assert full.round_trace[:len(dead.round_trace)] == dead.round_trace


# --------------------------------------------------- N4: partition heal

def test_partition_stalls_then_heals():
    from repro.core.net import Partition
    config = NemesisConfig(drop_prob=0.05,
                           partitions=(Partition(5, 30, (0,)),))
    res = run_differential("local", 17, config, n_ops=200,
                           num_shards=2, keep_backend=True)
    check(res, config.repro(17))
    nem = res["backend"].net.nemesis
    assert nem.stats["partitioned"] > 0      # the cut really fired
    assert res["net_stats"]["retransmits"] > 0


# ------------------------------------------- N5: ShardMap backend parity

@pytest.mark.slow
def test_shardmap_backend_survives_nemesis():
    """Scaled by NEMESIS_SOAK_SHARDMAP_SEEDS / NEMESIS_SOAK_OPS in the
    nemesis-soak CI job (the harness script prints a FAILING-SEEDS json
    line on failure, captured below as an artifact)."""
    n_seeds = int(os.environ.get("NEMESIS_SOAK_SHARDMAP_SEEDS", "2"))
    n_ops = int(os.environ.get("NEMESIS_SOAK_OPS", "200"))
    seeds = [str(11 + i) for i in range(n_seeds)]
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run(
        [sys.executable, os.path.join("tests", "nemesis_harness.py"),
         "shardmap", str(n_ops)] + seeds,
        env=env, capture_output=True, text=True,
        timeout=600 * max(1, n_seeds), cwd=REPO)
    if r.returncode != 0:
        for line in r.stdout.splitlines():
            if line.startswith("FAILING-SEEDS "):
                outdir = os.path.join(REPO, "nemesis_failures")
                os.makedirs(outdir, exist_ok=True)
                with open(os.path.join(outdir, "shardmap_soak.json"),
                          "w") as f:
                    f.write(line[len("FAILING-SEEDS "):])
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert r.stdout.count("OK shardmap") == n_seeds


# ----------------------------------------------------------- N6: soak

@pytest.mark.slow
def test_nemesis_soak_many_seeds():
    """Differential sweep over distinct seeds at drop/dup/reorder
    p in {0.05, 0.2}. The nemesis-soak CI job scales this to >=25
    distinct seeds x 10k ops (NEMESIS_SOAK_SEEDS is per fault level /
    NEMESIS_SOAK_OPS); failing seeds are dumped under nemesis_failures/
    for artifact upload and corpus check-in."""
    per_level = int(os.environ.get("NEMESIS_SOAK_SEEDS", "2"))
    n_ops = int(os.environ.get("NEMESIS_SOAK_OPS", "600"))
    failures = []
    for li, p in enumerate((0.05, 0.2)):
        config = default_nemesis(p)
        for seed in range(1000 + 500 * li, 1000 + 500 * li + per_level):
            repro = config.repro(seed)
            try:
                res = run_differential("local", seed, config, n_ops=n_ops)
                check(res, repro)
            except AssertionError as e:
                failures.append({"seed": seed, "config": config.to_dict(),
                                 "backend": "local", "error": str(e)})
    if failures:
        outdir = os.path.join(REPO, "nemesis_failures")
        os.makedirs(outdir, exist_ok=True)
        path = os.path.join(outdir, "local_soak.json")
        with open(path, "w") as f:
            json.dump(failures, f, indent=1)
        pytest.fail(f"{len(failures)} failing seeds written to {path}: "
                    + ", ".join(str(x["seed"]) for x in failures))
