"""Single-shard client-op semantics vs the sequential oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import messages as M
from repro.core import refs
from repro.core.oracle import OracleList
from repro.core.ops import apply_op
from repro.core.types import (DiLiConfig, OP_FIND, OP_INSERT, OP_REMOVE,
                              RES_FALSE, RES_TRUE, ST_KEY, SH_KEY, init_shard)

CFG = DiLiConfig(num_shards=1, pool_capacity=1024, max_sublists=16,
                 max_ctrs=16, max_scan=1024, batch_size=32, mailbox_cap=64)


def apply_batch(state, kinds, keys, me=0, cfg=CFG):
    """Sequentially apply a batch of fresh client ops on one shard."""
    outbox, count = M.empty_outbox(cfg.mailbox_cap)

    def step(carry, x):
        st, ob, ct = carry
        kind, key = x
        row = M.make_row(M.MSG_OP, me, me, a=kind, key=key,
                         ref1=M.ref2i(refs.null_ref()), sid=me, ts=0)
        out = apply_op(st, me, row, ob, ct, cfg)
        return (out.state, out.outbox, out.count), out.result

    (state, outbox, count), results = jax.lax.scan(
        step, (state, outbox, count),
        (jnp.asarray(kinds, jnp.int32), jnp.asarray(keys, jnp.int32)))
    return state, np.asarray(results), outbox, count


def snapshot_keys(state, me=0, max_steps=4096):
    """Walk the whole chain, returning live (unmarked, non-sentinel) keys."""
    nxt = np.asarray(state.pool.nxt)
    key = np.asarray(state.pool.key)
    reg = state.registry
    size = int(reg.size)
    assert size >= 1
    head = int(refs.ref_idx(reg.subhead[0]))
    out = []
    curr = int(nxt[head]) & refs.IDX_MASK
    curr_ref = int(nxt[head])
    for _ in range(max_steps):
        idx = curr_ref & refs.IDX_MASK
        if idx == refs.NULL_IDX:
            break
        k = int(key[idx])
        marked = bool(int(nxt[idx]) & refs.MARK_BIT)
        if k == ST_KEY:
            nref = int(nxt[idx]) & ~refs.MARK_BIT & 0xFFFFFFFF
            if (nref & refs.IDX_MASK) == refs.NULL_IDX:
                break
            curr_ref = int(nxt[idx])
            continue
        if k != SH_KEY and not marked:
            out.append(k)
        curr_ref = int(nxt[idx])
    return out


def test_insert_find_remove_basic():
    state = init_shard(CFG, 0, bootstrap=True)
    kinds = [OP_INSERT, OP_INSERT, OP_INSERT, OP_FIND, OP_FIND,
             OP_REMOVE, OP_FIND, OP_INSERT, OP_REMOVE, OP_REMOVE]
    keys = [10, 5, 20, 5, 7, 5, 5, 5, 5, 99]
    state, res, outbox, count = apply_batch(state, kinds, keys)
    oracle = OracleList()
    exp = oracle.apply_batch(kinds, keys)
    assert [bool(r) for r in res] == exp
    assert int(count) == 0  # single shard, no sublist moving => no messages
    assert snapshot_keys(state) == sorted(oracle.snapshot())


def test_duplicate_inserts_and_reinserts():
    state = init_shard(CFG, 0, bootstrap=True)
    kinds = [OP_INSERT] * 4 + [OP_REMOVE, OP_INSERT, OP_FIND]
    keys = [42, 42, 41, 43, 42, 42, 42]
    state, res, _, _ = apply_batch(state, kinds, keys)
    assert [bool(r) for r in res] == [True, False, True, True,
                                      True, True, True]
    assert snapshot_keys(state) == [41, 42, 43]


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_random_stream_matches_oracle(seed):
    rng = np.random.default_rng(seed)
    state = init_shard(CFG, 0, bootstrap=True)
    oracle = OracleList()
    n = 200
    kinds = rng.choice([OP_FIND, OP_INSERT, OP_REMOVE],
                       size=n, p=[0.3, 0.4, 0.3]).astype(np.int32)
    keys = rng.integers(1, 40, size=n).astype(np.int32)  # small key space
    state, res, _, _ = apply_batch(state, kinds, keys)
    exp = oracle.apply_batch(kinds, keys)
    assert [bool(r) for r in res] == exp
    assert snapshot_keys(state) == sorted(oracle.snapshot())


def test_free_list_reuse():
    state = init_shard(CFG, 0, bootstrap=True)
    # fill, delete, re-insert: pool should recycle delinked slots
    kinds = [OP_INSERT] * 8 + [OP_REMOVE] * 8 + [OP_FIND] * 8 + [OP_INSERT] * 8
    keys = list(range(1, 9)) * 4
    state, res, _, _ = apply_batch(state, kinds, keys)
    assert all(bool(r) for r in res[:16])
    assert not any(bool(r) for r in res[16:24])  # finds after removes
    assert all(bool(r) for r in res[24:])
    # alloc_top bounded: the finds delinked, the re-inserts recycled
    assert int(state.alloc_top) <= 2 + 8 + 8
    assert snapshot_keys(state) == list(range(1, 9))
