"""Property-based tests (hypothesis) for the system's invariants.

P1  Linearizability: any op stream + any Split/Move schedule + any channel
    delay pattern => results identical to the sequential oracle and the
    final key set is exact.
P2  Replay permutation invariance (paper Thm 10): the move-destination list
    is independent of replicate delivery interleaving (exercised via
    channel holds).
P3  Registry: get_by_key returns the covering entry for any sorted layout.
P4  Counters: after quiescence every live sublist has stCt - endCt ==
    offset (the Move-termination precondition is observable).
P5  Hybrid-search kernel == oracle on arbitrary registry layouts.
P6  Nemesis linearizability: any op stream x any NemesisConfig (drop/
    dup/reorder/delay) x the balancer's bg schedule => oracle parity,
    exact final key set, and quiescence; shrunk failures print a
    (seed, config) repro line for tests/nemesis_corpus.json.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, note, settings, strategies as st

import jax.numpy as jnp

from repro.core import refs
from repro.core import registry as reg_ops
from repro.core.oracle import OracleList
from repro.core.sim import Cluster
from repro.core.types import (DiLiConfig, KEY_MAX, OP_FIND, OP_INSERT,
                              OP_REMOVE, ST_KEY, init_shard)

CFG = DiLiConfig(num_shards=2, pool_capacity=4096, max_sublists=32,
                 max_ctrs=32, max_scan=4096, batch_size=16,
                 mailbox_cap=256, move_batch=8)


@settings(max_examples=12, deadline=None,
          suppress_health_check=[HealthCheck.too_slow,
                                 HealthCheck.data_too_large])
@given(
    seed=st.integers(0, 10_000),
    ops=st.lists(
        st.tuples(st.sampled_from([OP_FIND, OP_INSERT, OP_REMOVE]),
                  st.integers(1, 120)),
        min_size=10, max_size=120),
    move_at=st.integers(0, 6),
    split_at=st.integers(0, 6),
    delay=st.floats(0.0, 0.5),
)
def test_linearizable_under_background_ops(seed, ops, move_at, split_at,
                                           delay):
    """P1 + P2: random streams, random bg schedule, random channel holds."""
    cl = Cluster(CFG, seed=seed, delay_prob=delay)
    oracle = OracleList()
    # seed the list so splits/moves have substance
    base = list(range(10, 110, 7))
    ids = cl.submit(0, [OP_INSERT] * len(base), base)
    oracle.apply_batch([OP_INSERT] * len(base), base)
    cl.run_until_quiet(400)

    expected = {}
    chunks = [ops[i:i + 8] for i in range(0, len(ops), 8)]
    for i, chunk in enumerate(chunks):
        if i == split_at:
            subs = [e for e in cl.sublists(0) if e["owner"] == 0]
            if subs:
                mid = cl.middle_item(0, subs[0]["head_idx"])
                if mid is not None:
                    cl.split(0, subs[0]["keymax"], mid)
        if i == move_at:
            subs = [e for e in cl.sublists(0) if e["owner"] == 0]
            if subs:
                cl.move(0, subs[-1]["keymax"], 1)
        kinds = [k for k, _ in chunk]
        keys = [x for _, x in chunk]
        got = cl.submit(i % 2, kinds, keys)
        exp = oracle.apply_batch(kinds, keys)
        expected.update(dict(zip(got, exp)))
        cl.step()
    cl.run_until_quiet(1500)

    for op_id, exp in expected.items():
        assert bool(cl.results[op_id]) == exp
    assert cl.all_keys() == sorted(oracle.snapshot())


@settings(max_examples=6, deadline=None,
          suppress_health_check=[HealthCheck.too_slow,
                                 HealthCheck.data_too_large])
@given(
    seed=st.integers(0, 100_000),
    drop=st.floats(0.0, 0.2),
    dup=st.floats(0.0, 0.2),
    reorder=st.floats(0.0, 0.2),
    delay=st.floats(0.0, 0.15),
    delay_rounds=st.integers(1, 4),
    split_threshold=st.sampled_from([16, 24, 48]),
    n_ops=st.integers(40, 120),
)
def test_linearizable_under_nemesis(seed, drop, dup, reorder, delay,
                                    delay_rounds, split_threshold, n_ops):
    """P6: random op streams x random fault schedules x bg churn. The
    ``DiLiClient`` drives the stream (per-key FIFO admission is the
    ordering contract the sequential oracle referees); the balancer's
    split/move/merge commands ride along. Failures print the
    ``(seed, config)`` pair — replay it byte-identically, then check it
    into tests/nemesis_corpus.json."""
    from nemesis_harness import check, run_differential
    from repro.core.net import NemesisConfig

    config = NemesisConfig(drop_prob=drop, dup_prob=dup,
                           reorder_prob=reorder, delay_prob=delay,
                           delay_rounds=delay_rounds)
    repro = config.repro(seed)
    note(f"repro line: {repro}")
    res = run_differential("local", seed, config, n_ops=n_ops,
                           num_shards=2, key_space=300,
                           split_threshold=split_threshold)
    check(res, repro)


@settings(max_examples=25, deadline=None)
@given(
    bounds=st.lists(st.integers(0, 10_000), min_size=1, max_size=20,
                    unique=True),
    queries=st.lists(st.integers(-5, 10_005), min_size=1, max_size=30),
)
def test_registry_cover_matches_bisect(bounds, queries):
    """P3: get_by_key agrees with a plain python interval scan."""
    bs = sorted(bounds)
    cfg = DiLiConfig(max_sublists=32)
    state = init_shard(cfg, 0, bootstrap=True)
    reg = state.registry
    # build entries (b[i], b[i+1]] from the bootstrap (SH_KEY, KEY_MAX]
    lo = None
    spans = []
    prev = None
    for b in bs:
        if prev is not None and b > prev:
            spans.append((prev, b))
        prev = b
    reg = reg._replace(size=jnp.zeros((), jnp.int32),
                       keymin=jnp.full_like(reg.keymin, ST_KEY),
                       keymax=jnp.full_like(reg.keymax, ST_KEY))
    for a, b in spans:
        reg = reg_ops.add_entry(reg, a, b, refs.make_ref(0, 0),
                                refs.make_ref(0, 1), 0, 0)
    got = np.asarray(reg_ops.get_by_key(reg, jnp.asarray(queries)))
    for q, g in zip(queries, got):
        want = -1
        for i, (a, b) in enumerate(spans):
            if a < q <= b:
                want = i
                break
        assert g == want, (q, spans, got)


def test_counters_balanced_after_quiescence():
    """P4: stCt - endCt == offset for every live sublist at rest."""
    cl = Cluster(CFG)
    rng = np.random.default_rng(0)
    keys = rng.permutation(np.arange(1, 400))[:120]
    cl.submit(0, [OP_INSERT] * len(keys), keys.tolist())
    cl.run_until_quiet(400)
    subs = [e for e in cl.sublists(0) if e["owner"] == 0]
    mid = cl.middle_item(0, subs[0]["head_idx"])
    cl.split(0, subs[0]["keymax"], mid)
    cl.run_until_quiet(400)
    cl.move(0, sorted(cl.sublists(0), key=lambda e: e["keymin"])[0]["keymax"],
            1)
    mixed = rng.choice([OP_INSERT, OP_REMOVE], 40).tolist()
    ks = rng.integers(1, 400, 40).tolist()
    cl.submit(1, mixed, ks)
    cl.run_until_quiet(800)

    for s in range(cl.n):
        stc = np.asarray(cl.states[s].stct)
        enc = np.asarray(cl.states[s].endct)
        reg = cl.states[s].registry
        for e in range(int(reg.size)):
            sh = int(np.asarray(reg.subhead)[e])
            if (sh & refs.SID_MASK) >> refs.IDX_BITS != s:
                continue
            slot = int(np.asarray(reg.ctr)[e])
            off = int(np.asarray(reg.offset)[e])
            if stc[slot] < 0:
                continue  # switched-away
            assert stc[slot] - enc[slot] == off, (s, e, slot)


@settings(max_examples=20, deadline=None)
@given(
    m=st.sampled_from([4, 16, 64]),
    c=st.sampled_from([32, 128]),
    seed=st.integers(0, 1000),
)
def test_hybrid_search_kernel_property(m, c, seed):
    """P5: kernel == oracle for random layouts, queries hit slots exactly."""
    from repro.kernels import ops as K
    rng = np.random.default_rng(seed)
    bounds = np.sort(rng.choice(np.arange(0, 5000), m, replace=False))
    bounds[0] = -1
    keymin = jnp.asarray(bounds.astype(np.int32))
    blocks = np.full((m, c), np.iinfo(np.int32).max, np.int32)
    for i in range(m):
        lo = int(bounds[i]) + 1
        hi = int(bounds[i + 1]) if i + 1 < m else lo + 200
        fill = rng.integers(0, c)
        if hi > lo and fill:
            vals = rng.choice(np.arange(lo, hi + 200), fill, replace=False)
            vals = np.sort(vals)[:fill]
            blocks[i, :len(vals)] = vals
    blocks = jnp.asarray(blocks)
    q = jnp.asarray(rng.integers(0, 5400, 128).astype(np.int32))
    slot, found = K.hybrid_search(keymin, blocks, q, tile_q=128)
    slot_r, found_r = K.hybrid_search_ref(keymin, blocks, q)
    np.testing.assert_array_equal(np.asarray(found), np.asarray(found_r))
    np.testing.assert_array_equal(np.asarray(slot), np.asarray(slot_r))


@settings(max_examples=20, deadline=None)
@given(
    bounds=st.lists(st.integers(0, 2000), min_size=2, max_size=8,
                    unique=True),
    seed=st.integers(0, 1000),
)
def test_hybrid_search_boundary_parity(bounds, seed):
    """P7: kernel stage 1 == registry.get_by_key at interval boundaries,
    and stage 2 == a hand searchsorted oracle (independent of ref.py) on
    empty and full blocks alike.

    The shared jnp oracle can't referee these cases — it had the same
    argmax(all-False) bug — so the expectations here are computed from
    first principles: entry from a python interval scan cross-checked
    against ``get_by_key``, pos from ``np.searchsorted`` on the live keys.
    """
    from repro.kernels import ops as K
    rng = np.random.default_rng(seed)
    bs = sorted(bounds)
    spans = [(bs[i], bs[i + 1]) for i in range(len(bs) - 1)]
    m, c = len(spans), 16
    int_max = np.iinfo(np.int32).max

    cfg = DiLiConfig(max_sublists=32)
    state = init_shard(cfg, 0, bootstrap=True)
    reg = state.registry._replace(
        size=jnp.zeros((), jnp.int32),
        keymin=jnp.full_like(state.registry.keymin, ST_KEY),
        keymax=jnp.full_like(state.registry.keymax, ST_KEY))
    for a, b in spans:
        reg = reg_ops.add_entry(reg, a, b, refs.make_ref(0, 0),
                                refs.make_ref(0, 1), 0, 0)

    blocks = np.full((m, c), int_max, np.int32)
    live = []
    for i, (a, b) in enumerate(spans):
        # force the edge shapes the fuzzers rarely draw: one empty row,
        # one full row, the rest random fill (keys in (a, b])
        if i == 0:
            fill = 0
        elif i == 1 or m == 1:
            fill = c
        else:
            fill = int(rng.integers(0, c + 1))
        vals = np.sort(rng.choice(np.arange(a + 1, b + 1),
                                  min(fill, b - a), replace=False))
        blocks[i, :len(vals)] = vals
        live.append(vals)
    jblocks = jnp.asarray(blocks)
    jkeymin = jnp.asarray(np.asarray([a for a, _ in spans], np.int32))

    # boundary queries per entry: keymin, keymin+1, keymax; plus fuzz
    qs = []
    for a, b in spans:
        qs += [a, a + 1, b]
    qs += rng.integers(bs[0] - 2, bs[-1] + 3, 16).tolist()
    q = jnp.asarray(np.asarray(qs, np.int32))

    slot, found = K.hybrid_search(jkeymin, jblocks, q, tile_q=64)
    ent = np.asarray(reg_ops.get_by_key(reg, q))
    for j, qq in enumerate(qs):
        # stage-1 parity: the kernel's entry pick must match the
        # registry's covering entry wherever one exists
        want_e = -1
        for i, (a, b) in enumerate(spans):
            if a < qq <= b:
                want_e = i
                break
        assert ent[j] == want_e, (qq, spans)
        if want_e < 0:
            continue
        pos = int(np.searchsorted(live[want_e], qq))
        assert int(slot[j]) == want_e * c + pos, (qq, want_e, live[want_e])
        assert bool(found[j]) == (qq in live[want_e])
