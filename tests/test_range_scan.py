"""RANGE scan suite (DESIGN.md §16).

Three layers of evidence that RANGE(lo, hi, limit) is a linearizable
snapshot of its span:

  * a boundary matrix on a quiesced multi-shard list — empty, singleton,
    full-space and cross-shard spans, limit truncation, error surfacing;
  * differential runs against the sequential oracle while the balancer
    splits/moves/merges under nemesis delays — the client's span-conflict
    admission makes "oracle at the scan's submission index" the exact
    referee (see tests/nemesis_harness.py);
  * the serving-level regressions that motivated the op: `python -O`
    must not strip the pool/batch admission checks, and a missing page
    mapping must surface as a -1 sentinel / KeyError, never alias slot 0.
"""
from __future__ import annotations

import os
import subprocess
import sys

import numpy as np
import pytest

from nemesis_harness import default_nemesis, run_differential, check

from repro.api import DiLiClient, LocalBackend
from repro.core.types import DiLiConfig, KEY_MIN, KEY_MAX


def _cfg(num_shards=4, **kw):
    base = dict(num_shards=num_shards, pool_capacity=4096,
                max_sublists=32, max_ctrs=32, max_scan=4096,
                batch_size=16, mailbox_cap=256, move_batch=8,
                range_scan=True)
    base.update(kw)
    return DiLiConfig(**base)


def _spread_client(keys, values=None, num_shards=4):
    """A client over a list spread across shards via split + move."""
    c = DiLiClient(LocalBackend(_cfg(num_shards), seed=7))
    c.insert_batch(keys, values).results()
    for target in range(1, num_shards):
        subs = [e for e in c.backend.sublists(0) if e["size"] is not None]
        if not subs:
            break
        big = max(subs, key=lambda e: e["size"])
        mid = c.backend.middle_item(0, big["head_idx"])
        if mid is None:
            break
        assert c.backend.split(0, big["keymax"], mid)
        c.drain()
        subs = [e for e in c.backend.sublists(0) if e["size"] is not None]
        small = min(subs, key=lambda e: e["keymax"])
        assert c.backend.move(0, small["keymax"], target)
        c.drain()
    owners = {e[2] for e in c.backend.registry_entries(0)}
    assert len(owners) > 1, "list did not spread across shards"
    return c


# ------------------------------------------------------ boundary matrix

def test_range_boundary_matrix():
    keys = list(range(10, 610, 5))
    vals = [k * 7 for k in keys]
    c = _spread_client(keys, vals)
    kv = dict(zip(keys, vals))

    def scan(lo, hi, limit=10_000):
        return c.range(lo, hi, limit).items()

    # empty spans: before all keys, in a gap, after all keys, hi <= lo
    assert scan(0, 10) == []
    assert scan(11, 15) == []
    assert scan(700, 9000) == []
    assert scan(50, 50) == []
    assert scan(60, 40) == []
    # singleton spans, inclusive-lo / exclusive-hi edges
    assert scan(10, 11) == [(10, 70)]
    assert scan(605, 606) == [(605, 4235)]
    assert scan(10, 15) == [(10, 70)]
    assert scan(11, 16) == [(15, 105)]
    # full space (cross-shard) and a cross-shard interior span
    assert scan(KEY_MIN, KEY_MAX + 1) == sorted(kv.items())
    expect = [(k, kv[k]) for k in keys if 200 <= k < 400]
    assert scan(200, 400) == expect
    # limit truncation keeps the low end, in order
    assert scan(KEY_MIN, KEY_MAX + 1, limit=7) == sorted(kv.items())[:7]
    assert scan(200, 400, limit=1) == expect[:1]
    assert c.backend.stats["range_hits"] > 0


def test_range_rejects_bad_args():
    c = DiLiClient(LocalBackend(_cfg(), seed=1))
    with pytest.raises(ValueError):
        c.range(0, 10, limit=0)
    with pytest.raises(ValueError):
        c.backend.submit_range(0, KEY_MIN - 2, 10, 5)
    off = DiLiClient(LocalBackend(DiLiConfig(num_shards=2), seed=1))
    with pytest.raises(ValueError):
        off.range(0, 10)


def test_range_span_hold_orders_mutations():
    """A mutation queued after a scan into its span must not appear in
    the scan's snapshot; one queued before must."""
    keys = list(range(0, 200, 2))
    c = DiLiClient(LocalBackend(_cfg(), seed=3))
    c.insert_batch(keys).results()
    ins = c.insert(101)            # queued first: in the snapshot
    r = c.range(0, 200, limit=500)
    rm = c.remove(100)             # queued after: held until r resolves
    c.drain()
    got = r.keys(wait=False)
    assert 101 in got
    assert 100 in got
    assert ins.result(wait=False) is True
    assert rm.result(wait=False) is True
    assert c.find(100).result() is False


# ------------------------------------------- differential (churn+delays)

@pytest.mark.parametrize("seed", [11, 12])
def test_range_differential_local(seed):
    nem = default_nemesis(0.1)
    res = run_differential("local", seed, nem, n_ops=400, scan_every=2)
    check(res, f"range-diff local seed={seed}")
    assert res["n_scans"] >= 10


def test_range_differential_no_faults():
    """Clean wire, heavy churn: every batch carries a scan."""
    from repro.core.net import NemesisConfig
    res = run_differential("local", 21, NemesisConfig(), n_ops=400,
                           scan_every=1, split_threshold=16)
    check(res, "range-diff clean seed=21")
    assert res["n_scans"] >= 20


SHARDMAP_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ["RANGE_EVERY"] = "3"
import sys
sys.path.insert(0, "tests")
from nemesis_harness import main
sys.exit(main(["shardmap", "200", "31"]))
"""


@pytest.mark.slow
def test_range_differential_shardmap():
    """Scan parity on the SPMD backend (hostroute path, nemesis on) —
    subprocess because the device count must be set before jax loads."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SHARDMAP_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "OK shardmap" in r.stdout


# ------------------------------------------------- serving regressions

OPT_SCRIPT = r"""
import numpy as np
from repro.configs import get_smoke_config
from repro.serving.engine import BatchOverflow, Request, ServingEngine
from repro.serving.paged import PagedKVManager, PagePoolExhausted

if __debug__:
    raise SystemExit("must run under python -O (asserts stripped)")

cfg = get_smoke_config("qwen2_5_3b")
kv = PagedKVManager(cfg, num_pages=2, page_size=4)
kv.alloc_page(0, 0)
kv.alloc_page(0, 1)
try:
    kv.alloc_page(1, 0)
    raise SystemExit("pool exhaustion not raised")
except PagePoolExhausted:
    pass

# admission overflow must raise without building a real model: bypass
# admit()'s prefill by pre-filling the active list
eng = ServingEngine.__new__(ServingEngine)
eng.active = [None] * 2
eng.max_batch = 2
try:
    ServingEngine.admit(eng, Request(9, np.zeros(4, np.int32), 4))
    raise SystemExit("batch overflow not raised")
except BatchOverflow:
    pass
print("OK")
"""


def test_guards_survive_python_O():
    """The pool-exhaustion and batch-admission guards are exceptions,
    not asserts — they must fire under ``python -O``."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-O", "-c", OPT_SCRIPT],
                       env=env, capture_output=True, text=True,
                       timeout=600,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "OK" in r.stdout


def test_page_table_sentinel_and_never_allocated():
    """Missing-but-allocated pages read as -1 (masked downstream);
    never-allocated pages raise instead of aliasing slot 0."""
    from repro.configs import get_smoke_config
    from repro.serving.paged import PagedKVManager, page_key
    cfg = get_smoke_config("qwen2_5_3b")
    kv = PagedKVManager(cfg, num_pages=8, page_size=4)
    s00 = kv.alloc_page(0, 0)
    kv.alloc_page(0, 1)
    kv.alloc_page(1, 0)
    pt = np.asarray(kv.page_table([0, 1], [2, 1]))
    assert pt.shape == (2, 2)
    assert pt[0, 0] == s00 and (pt >= -1).all()
    assert pt[1, 1] == -1          # padding past seq 1's count
    # allocated but missing from the snapshot (simulated stale cache)
    kv._table.pop(page_key(0, 1))
    pt = np.asarray(kv.page_table([0], [2]))
    assert pt[0, 1] == -1
    # never allocated: refuse
    with pytest.raises(KeyError):
        kv.page_table([2], [1])


def test_free_seq_verifies_removes():
    """A failed remove must not recycle the slot (key resurrection)."""
    from repro.configs import get_smoke_config
    from repro.serving.paged import PagedKVManager, page_key
    cfg = get_smoke_config("qwen2_5_3b")
    kv = PagedKVManager(cfg, num_pages=8, page_size=4)
    kv.alloc_page(0, 0)
    kv.alloc_page(0, 1)
    free_before = len(kv.free_slots)
    # sabotage: remove the key out-of-band so the tracked remove bounces
    kv.client.remove(page_key(0, 1)).result()
    with pytest.raises(RuntimeError, match="still live|failed"):
        kv.free_seq(0, 2)
    # page 0's confirmed remove recycled; page 1's slot must NOT be
    # recycled by the failed path (it is leaked pending operator action)
    assert len(kv.free_slots) == free_before + 1


def test_refresh_seq_matches_rescan_after_migration():
    """refresh_seq's RANGE snapshot equals the full rescan's view of the
    same sequence after a live split+move of the page table."""
    from repro.configs import get_smoke_config
    from repro.serving.paged import PagedKVManager, page_key
    cfg = get_smoke_config("qwen2_5_3b")
    kv = PagedKVManager(cfg, num_pages=64, page_size=4, dili_shards=2)
    for sid in range(3):
        for p in range(8):
            kv.alloc_page(sid, p)
    be = kv.backend
    subs = [e for e in be.sublists(0) if e["size"] is not None]
    big = max(subs, key=lambda e: e["size"])
    mid = be.middle_item(0, big["head_idx"])
    assert be.split(0, big["keymax"], mid)
    kv.client.drain()
    subs = [e for e in be.sublists(0) if e["size"] is not None]
    small = min(subs, key=lambda e: e["keymax"])
    assert be.move(0, small["keymax"], 1)
    kv.client.drain()
    kv._table.clear()
    for sid in range(3):
        n = kv.refresh_seq(sid)
        assert n == 8, (sid, n)
    via_range = dict(kv._table)
    kv.refresh_table()
    assert via_range == {k: v for k, v in kv._table.items()}
    pt = np.asarray(kv.page_table([0, 1, 2], 8))
    assert (pt >= 0).all()
