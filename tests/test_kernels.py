"""Kernel sweeps: Pallas (interpret mode) vs pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops as K

INT_MAX = np.iinfo(np.int32).max


def make_registry(rng, m, c, coverage=0.7):
    """Random sorted registry + per-sublist sorted key blocks."""
    bounds = np.sort(rng.choice(np.arange(0, 10_000, 7), m, replace=False))
    bounds[0] = -1
    keymin = bounds.astype(np.int32)
    blocks = np.full((m, c), INT_MAX, np.int32)
    for i in range(m):
        lo = int(bounds[i]) + 1
        hi = int(bounds[i + 1]) if i + 1 < m else lo + 500
        span = np.arange(lo, max(hi, lo + 1))
        take = rng.permutation(span)[:int(c * coverage)]
        take = np.sort(take)
        blocks[i, :take.size] = take
    return jnp.asarray(keymin), jnp.asarray(blocks)


@pytest.mark.parametrize("m,c,b", [(8, 32, 128), (32, 128, 256),
                                   (128, 128, 128), (64, 256, 512)])
def test_hybrid_search_matches_ref(m, c, b):
    rng = np.random.default_rng(m * 1000 + c)
    keymin, blocks = make_registry(rng, m, c)
    # half the queries are present keys, half are misses
    present = np.asarray(blocks).ravel()
    present = present[present != INT_MAX]
    q_hit = rng.choice(present, b // 2)
    q_miss = rng.integers(0, 10_500, b // 2)
    queries = jnp.asarray(np.concatenate([q_hit, q_miss]).astype(np.int32))

    slot, found = K.hybrid_search(keymin, blocks, queries, tile_q=b // 2)
    slot_r, found_r = K.hybrid_search_ref(keymin, blocks, queries)
    np.testing.assert_array_equal(np.asarray(found), np.asarray(found_r))
    np.testing.assert_array_equal(np.asarray(slot), np.asarray(slot_r))
    # every hit's slot actually holds the queried key
    hits = np.asarray(found)
    flat = np.asarray(blocks).ravel()
    np.testing.assert_array_equal(flat[np.asarray(slot)[hits]],
                                  np.asarray(queries)[hits])


def test_hybrid_search_full_block_all_less():
    """Regression: a full block whose keys are all < q must report pos==C.

    With every comparison False, ``argmax(ge)`` used to return 0 — the
    probe would hand back slot == entry*C (the block's *first* key's
    link) as the insertion point, silently wrong by the whole block.
    The contract is slot == entry*C + C: one past the last live key.
    Hand-computed expectations — this test must fail on the unfixed
    kernel AND the unfixed oracle, so neither can vouch for the other.
    """
    c = 8
    keymin = jnp.asarray([-1, 50], jnp.int32)
    blocks = np.full((2, c), INT_MAX, np.int32)
    blocks[0] = np.arange(10, 10 + c)        # full block: 10..17
    blocks[1, :3] = [60, 70, 80]
    blocks = jnp.asarray(blocks)
    # q=49 routes to entry 0 (49 > keymin[0], <= next bound) and exceeds
    # every key in the full block; q=75 is a normal interior miss; q=60
    # pads the batch to a whole tile with an ordinary hit.
    q = jnp.asarray([49, 18, 75, 60, 60, 60, 60, 60], jnp.int32)
    for fn in (lambda: K.hybrid_search(keymin, blocks, q, tile_q=8),
               lambda: K.hybrid_search_ref(keymin, blocks, q)):
        slot, found = fn()
        np.testing.assert_array_equal(np.asarray(found)[:3],
                                      [False, False, False])
        assert bool(found[3])
        # entry 0, pos C — NOT slot 0
        assert int(slot[0]) == 0 * c + c
        assert int(slot[1]) == 0 * c + c
        assert int(slot[2]) == 1 * c + 2   # first key >= 75 is 80 at pos 2
        assert int(slot[3]) == 1 * c + 0


def test_hybrid_search_sentinel_query_never_found():
    """q == INT32_MAX equals the pad value; matching a pad slot must not
    count as membership (pads are absent keys, and ST_KEY is not a user
    key). Both public entry points must agree."""
    c = 8
    keymin = jnp.asarray([-1, 50], jnp.int32)
    blocks = np.full((2, c), INT_MAX, np.int32)
    blocks[0, :4] = [10, 20, 30, 40]
    blocks = jnp.asarray(blocks)
    q = jnp.asarray([INT_MAX, INT_MAX, 30], jnp.int32)
    slot, found = K.hybrid_search(keymin, blocks, q, tile_q=8)
    slot_r, found_r = K.hybrid_search_ref(keymin, blocks, q)
    np.testing.assert_array_equal(np.asarray(found), [False, False, True])
    np.testing.assert_array_equal(np.asarray(found_r), np.asarray(found))
    np.testing.assert_array_equal(np.asarray(slot_r), np.asarray(slot))


@pytest.mark.parametrize("b,tile_q", [(3, 8), (100, 64), (129, 128)])
def test_hybrid_search_ragged_batch(b, tile_q):
    """Batches that don't divide tile_q are padded internally and sliced
    back — callers never see the pad lanes."""
    rng = np.random.default_rng(b)
    keymin, blocks = make_registry(rng, 8, 32)
    q = jnp.asarray(rng.integers(0, 10_500, b).astype(np.int32))
    slot, found = K.hybrid_search(keymin, blocks, q, tile_q=tile_q)
    slot_r, found_r = K.hybrid_search_ref(keymin, blocks, q)
    assert slot.shape == (b,) and found.shape == (b,)
    np.testing.assert_array_equal(np.asarray(found), np.asarray(found_r))
    np.testing.assert_array_equal(np.asarray(slot), np.asarray(slot_r))


@pytest.mark.parametrize("b,h,kh,d,pages,ps", [
    (4, 8, 2, 64, 8, 16),
    (2, 16, 16, 128, 4, 32),   # MHA
    (8, 4, 1, 64, 16, 8),      # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_matches_ref(b, h, kh, d, pages, ps, dtype):
    rng = np.random.default_rng(b * 100 + h)
    pool = pages * 3
    q = jnp.asarray(rng.standard_normal((b, h, d)), dtype)
    k_pages = jnp.asarray(rng.standard_normal((pool, ps, kh, d)) * 0.3, dtype)
    v_pages = jnp.asarray(rng.standard_normal((pool, ps, kh, d)) * 0.3, dtype)
    page_table = jnp.asarray(
        rng.integers(0, pool, (b, pages)).astype(np.int32))
    seq_lens = jnp.asarray(
        rng.integers(1, pages * ps + 1, (b,)).astype(np.int32))

    out = K.paged_attention(q, k_pages, v_pages, page_table, seq_lens,
                            page_size=ps)
    ref = K.paged_attention_ref(q, k_pages, v_pages, page_table, seq_lens,
                                page_size=ps)
    atol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=atol, rtol=2e-2)


def test_paged_attention_ignores_padding_pages():
    """Slots past seq_len must not affect the output."""
    rng = np.random.default_rng(0)
    b, h, kh, d, pages, ps = 2, 4, 2, 32, 4, 8
    pool = 12
    q = jnp.asarray(rng.standard_normal((b, h, d)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((pool, ps, kh, d)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((pool, ps, kh, d)), jnp.float32)
    seq = jnp.asarray([9, 17], jnp.int32)
    pt1 = jnp.asarray(rng.integers(0, pool, (b, pages)).astype(np.int32))
    # scramble only the fully-masked tail pages
    pt2 = np.asarray(pt1).copy()
    pt2[0, 2:] = (pt2[0, 2:] + 5) % pool
    pt2[1, 3:] = (pt2[1, 3:] + 3) % pool
    o1 = K.paged_attention(q, kp, vp, pt1, seq, page_size=ps)
    o2 = K.paged_attention(q, kp, vp, jnp.asarray(pt2), seq, page_size=ps)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-6)


# ------------------------------------------- REPRO_INTERPRET env override

def test_default_interpret_env_override(monkeypatch):
    for v in ("1", "true", " ON ", "Yes"):
        monkeypatch.setenv("REPRO_INTERPRET", v)
        assert K._default_interpret() is True, v
    for v in ("0", "false", "off", " No"):
        monkeypatch.setenv("REPRO_INTERPRET", v)
        assert K._default_interpret() is False, v


def test_default_interpret_unset_follows_platform(monkeypatch):
    monkeypatch.delenv("REPRO_INTERPRET", raising=False)
    assert K._default_interpret() is (jax.default_backend() != "tpu")


def test_default_interpret_rejects_typos(monkeypatch):
    monkeypatch.setenv("REPRO_INTERPRET", "ture")
    with pytest.raises(ValueError, match="REPRO_INTERPRET"):
        K._default_interpret()


def test_hybrid_search_honors_forced_interpret(monkeypatch):
    """The override must reach the public entry point: forcing interpret
    on matches the oracle exactly (same path CI uses on TPU repros)."""
    monkeypatch.setenv("REPRO_INTERPRET", "1")
    rng = np.random.default_rng(5)
    keymin, blocks = make_registry(rng, 8, 32)
    queries = jnp.asarray(rng.integers(0, 10_500, 64).astype(np.int32))
    slot, found = K.hybrid_search(keymin, blocks, queries, tile_q=64)
    rslot, rfound = K.hybrid_search_ref(keymin, blocks, queries)
    np.testing.assert_array_equal(np.asarray(slot), np.asarray(rslot))
    np.testing.assert_array_equal(np.asarray(found), np.asarray(rfound))
