"""Kernel sweeps: Pallas (interpret mode) vs pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops as K

INT_MAX = np.iinfo(np.int32).max


def make_registry(rng, m, c, coverage=0.7):
    """Random sorted registry + per-sublist sorted key blocks."""
    bounds = np.sort(rng.choice(np.arange(0, 10_000, 7), m, replace=False))
    bounds[0] = -1
    keymin = bounds.astype(np.int32)
    blocks = np.full((m, c), INT_MAX, np.int32)
    for i in range(m):
        lo = int(bounds[i]) + 1
        hi = int(bounds[i + 1]) if i + 1 < m else lo + 500
        span = np.arange(lo, max(hi, lo + 1))
        take = rng.permutation(span)[:int(c * coverage)]
        take = np.sort(take)
        blocks[i, :take.size] = take
    return jnp.asarray(keymin), jnp.asarray(blocks)


@pytest.mark.parametrize("m,c,b", [(8, 32, 128), (32, 128, 256),
                                   (128, 128, 128), (64, 256, 512)])
def test_hybrid_search_matches_ref(m, c, b):
    rng = np.random.default_rng(m * 1000 + c)
    keymin, blocks = make_registry(rng, m, c)
    # half the queries are present keys, half are misses
    present = np.asarray(blocks).ravel()
    present = present[present != INT_MAX]
    q_hit = rng.choice(present, b // 2)
    q_miss = rng.integers(0, 10_500, b // 2)
    queries = jnp.asarray(np.concatenate([q_hit, q_miss]).astype(np.int32))

    slot, found = K.hybrid_search(keymin, blocks, queries, tile_q=b // 2)
    slot_r, found_r = K.hybrid_search_ref(keymin, blocks, queries)
    np.testing.assert_array_equal(np.asarray(found), np.asarray(found_r))
    np.testing.assert_array_equal(np.asarray(slot), np.asarray(slot_r))
    # every hit's slot actually holds the queried key
    hits = np.asarray(found)
    flat = np.asarray(blocks).ravel()
    np.testing.assert_array_equal(flat[np.asarray(slot)[hits]],
                                  np.asarray(queries)[hits])


@pytest.mark.parametrize("b,h,kh,d,pages,ps", [
    (4, 8, 2, 64, 8, 16),
    (2, 16, 16, 128, 4, 32),   # MHA
    (8, 4, 1, 64, 16, 8),      # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_matches_ref(b, h, kh, d, pages, ps, dtype):
    rng = np.random.default_rng(b * 100 + h)
    pool = pages * 3
    q = jnp.asarray(rng.standard_normal((b, h, d)), dtype)
    k_pages = jnp.asarray(rng.standard_normal((pool, ps, kh, d)) * 0.3, dtype)
    v_pages = jnp.asarray(rng.standard_normal((pool, ps, kh, d)) * 0.3, dtype)
    page_table = jnp.asarray(
        rng.integers(0, pool, (b, pages)).astype(np.int32))
    seq_lens = jnp.asarray(
        rng.integers(1, pages * ps + 1, (b,)).astype(np.int32))

    out = K.paged_attention(q, k_pages, v_pages, page_table, seq_lens,
                            page_size=ps)
    ref = K.paged_attention_ref(q, k_pages, v_pages, page_table, seq_lens,
                                page_size=ps)
    atol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=atol, rtol=2e-2)


def test_paged_attention_ignores_padding_pages():
    """Slots past seq_len must not affect the output."""
    rng = np.random.default_rng(0)
    b, h, kh, d, pages, ps = 2, 4, 2, 32, 4, 8
    pool = 12
    q = jnp.asarray(rng.standard_normal((b, h, d)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((pool, ps, kh, d)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((pool, ps, kh, d)), jnp.float32)
    seq = jnp.asarray([9, 17], jnp.int32)
    pt1 = jnp.asarray(rng.integers(0, pool, (b, pages)).astype(np.int32))
    # scramble only the fully-masked tail pages
    pt2 = np.asarray(pt1).copy()
    pt2[0, 2:] = (pt2[0, 2:] + 5) % pool
    pt2[1, 3:] = (pt2[1, 3:] + 3) % pool
    o1 = K.paged_attention(q, kp, vp, pt1, seq, page_size=ps)
    o2 = K.paged_attention(q, kp, vp, jnp.asarray(pt2), seq, page_size=ps)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-6)
