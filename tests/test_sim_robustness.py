"""Regression tests for Cluster client-API and routing robustness.

R1  ``Cluster.submit`` accepts generators/iterators for kinds/keys/values —
    the old ``len(list(keys))`` probe exhausted the iterator before the
    ``zip``, silently dropping every op (``ids == []``, no error).
R2  submit validates length mismatches loudly instead of zip-truncating.
R3  Outbox overflow raises ``OutboxOverflow`` unconditionally — it must
    not be an ``assert`` (``python -O`` would silently truncate messages,
    and a lost replicate/ack deadlocks ``run_until_quiet``).
R4  Op ids are int32 message lanes: completed ids drained through
    ``take_result`` are recycled, and exhaustion raises instead of
    silently wrapping into colliding ids.
R5  ``shard_chain`` raises on a cyclic/corrupted chain instead of
    returning a silent prefix (which made ``all_keys()``-based
    assertions pass vacuously).
"""
import numpy as np
import pytest

from repro.core import refs
from repro.core.oracle import OracleList
from repro.core.sim import Cluster, OutboxOverflow
from repro.core.types import DiLiConfig, OP_FIND, OP_INSERT

CFG = DiLiConfig(num_shards=2, pool_capacity=2048, max_sublists=16,
                 max_ctrs=16, max_scan=2048, batch_size=16,
                 mailbox_cap=128)


def test_submit_accepts_generators():
    """R1: generator inputs must land every op, not silently drop all."""
    cl = Cluster(CFG)
    keys = list(range(10, 26))
    ids = cl.submit(0,
                    (OP_INSERT for _ in keys),
                    (k for k in keys),
                    (k * 2 for k in keys))
    assert len(ids) == len(keys), "generator ops were silently dropped"
    cl.run_until_quiet(400)
    assert [bool(cl.results[j]) for j in ids] == [True] * len(keys)
    assert cl.all_keys() == sorted(keys)
    # values rode along (payload is stored in pool.keymax for items)
    chain = {k: v for k, _, v in cl.shard_chain(0, 0, include_meta=True)}
    assert chain == {k: k * 2 for k in keys}


def test_submit_generator_matches_list_submission():
    """R1: a generator submission behaves exactly like the list one."""
    keys = list(range(5, 45, 3))
    a, b = Cluster(CFG), Cluster(CFG)
    ids_a = a.submit(0, [OP_INSERT] * len(keys), list(keys))
    ids_b = b.submit(0, (OP_INSERT for _ in keys), iter(keys))
    a.run_until_quiet(400)
    b.run_until_quiet(400)
    assert ids_a == ids_b
    assert [a.results[j] for j in ids_a] == [b.results[j] for j in ids_b]
    assert a.all_keys() == b.all_keys() == sorted(set(keys))
    oracle = OracleList(keys)
    assert a.all_keys() == sorted(oracle.snapshot())


def test_submit_length_mismatch_raises():
    """R2: mismatched kinds/keys/values must fail loudly, not truncate."""
    cl = Cluster(CFG)
    with pytest.raises(ValueError):
        cl.submit(0, [OP_INSERT] * 3, [1, 2])
    with pytest.raises(ValueError):
        cl.submit(0, [OP_INSERT] * 2, [1, 2], [7])


def test_outbox_overflow_raises():
    """R3: a round emitting more messages than mailbox_cap must raise."""
    cfg = DiLiConfig(num_shards=2, pool_capacity=512, max_sublists=8,
                     max_ctrs=8, max_scan=512, batch_size=16,
                     mailbox_cap=4, find_fastpath=False, mut_fastpath=False)
    cl = Cluster(cfg)
    # every key is owned by shard 0, so each op submitted at shard 1
    # delegates: 12 outbox rows in one round > mailbox_cap = 4
    cl.submit(1, [OP_FIND] * 12, list(range(10, 22)))
    with pytest.raises(OutboxOverflow, match="mailbox_cap"):
        cl.step()


def test_outbox_at_cap_does_not_raise():
    """R3: exactly-at-cap rounds are legal — only genuine overflow raises."""
    cfg = DiLiConfig(num_shards=2, pool_capacity=512, max_sublists=8,
                     max_ctrs=8, max_scan=512, batch_size=16,
                     mailbox_cap=4, find_fastpath=False, mut_fastpath=False)
    cl = Cluster(cfg)
    cl.submit(1, [OP_FIND] * 4, list(range(10, 14)))
    cl.run_until_quiet(100)
    assert cl.stats["max_outbox"] == 4
    assert all(cl.results[j] == 0 for j in range(4))  # absent keys


def test_op_ids_recycle_via_take_result():
    """R4: drained op ids are reissued; _next_slot stays bounded."""
    cl = Cluster(CFG)
    ids = cl.submit(0, [OP_INSERT] * 4, [10, 11, 12, 13])
    cl.run_until_quiet(200)
    for j in ids:
        assert cl.take_result(j) == 1
        with pytest.raises(KeyError):
            cl.take_result(j)       # already drained
    top = cl._ids.next_id
    ids2 = cl.submit(0, [OP_FIND] * 4, [10, 11, 12, 13])
    assert sorted(ids2) == sorted(ids), "drained ids were not reissued"
    assert cl._ids.next_id == top
    cl.run_until_quiet(200)
    assert [cl.take_result(j) for j in ids2] == [1] * 4


def test_op_id_exhaustion_raises():
    """R4: id-space exhaustion must raise, not wrap into int32 aliasing."""
    cl = Cluster(CFG)
    cl._ids.next_id = np.iinfo(np.int32).max
    with pytest.raises(RuntimeError, match="op-id space exhausted"):
        cl.submit(0, [OP_FIND], [5])
    # recycled ids keep a full results dict submittable at the guard
    cl._ids.release(7)
    assert cl.submit(0, [OP_FIND], [5]) == [7]


def test_shard_chain_cycle_raises():
    """R5: a corrupted (cyclic) chain must raise, not truncate silently."""
    cl = Cluster(CFG)
    ids = cl.submit(0, [OP_INSERT] * 3, [10, 20, 30])
    cl.run_until_quiet(200)
    assert cl.all_keys() == [10, 20, 30]
    # corrupt: point the node holding key 20 back at itself
    st = cl.states[0]
    idx = {k: i for k, i, _ in cl.shard_chain(0, 0, include_meta=True)}[20]
    cl.states[0] = st._replace(pool=st.pool._replace(
        nxt=st.pool.nxt.at[idx].set(refs.make_ref(0, idx))))
    with pytest.raises(RuntimeError, match="did not terminate"):
        cl.shard_chain(0, 0)
    with pytest.raises(RuntimeError, match="did not terminate"):
        cl.all_keys()
