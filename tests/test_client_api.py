"""Tests for the futures-based client API (repro.api, DESIGN.md §9).

Covers: oracle-differential correctness through ``DiLiClient`` under
balancer churn and message delays, admission pacing (client queues instead
of surfacing ``OutboxOverflow``), registry-cache routing (fewer delegation
hops than fixed-shard submission, wrong-route learning), and
Local/ShardMap backend parity on an identical seeded workload.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.api import DiLiClient, LocalBackend, RegistryCache
from repro.core.balancer import Balancer
from repro.core.oracle import OracleList
from repro.core.sim import Cluster, OutboxOverflow
from repro.core.types import DiLiConfig, OP_FIND, OP_INSERT, OP_REMOVE


def _cfg(**kw):
    base = dict(num_shards=4, pool_capacity=2048, max_sublists=32,
                max_ctrs=32, max_scan=2048, batch_size=16,
                mailbox_cap=128, split_threshold=24, move_batch=8)
    base.update(kw)
    return DiLiConfig(**base)


def _mixed(client, oracle, rng, rounds, n_per_round, key_space):
    checks = []
    for _ in range(rounds):
        kinds = rng.choice([OP_FIND, OP_INSERT, OP_REMOVE],
                           n_per_round).tolist()
        keys = rng.integers(1, key_space, n_per_round).tolist()
        checks.append((client.submit(kinds, keys),
                       oracle.apply_batch(kinds, keys)))
        client.pump()
    client.drain()
    return checks


def _assert_checks(checks):
    wrong = [(f.key, f.result(), exp)
             for batch, exps in checks for f, exp in zip(batch, exps)
             if f.result() != exp]
    assert not wrong, f"linearizability violations: {wrong[:5]}"


# ------------------------------------------------------------- correctness

def test_client_matches_oracle_under_churn():
    """Mixed workload + balancer churn + channel delays, vs the oracle."""
    backend = LocalBackend(_cfg(), seed=7, delay_prob=0.15)
    client = DiLiClient(backend, balance=Balancer(backend))
    oracle = OracleList()
    rng = np.random.default_rng(3)

    keys = rng.permutation(np.arange(1, 800))[:200].tolist()
    load = client.insert_batch(keys)
    oracle.apply_batch([OP_INSERT] * len(keys), keys)
    client.drain(run_balance=True)
    assert load.results() == [True] * len(keys)

    checks = _mixed(client, oracle, rng, rounds=12, n_per_round=24,
                    key_space=800)
    client.settle()
    _assert_checks(checks)
    assert client.all_keys() == sorted(oracle.snapshot())
    # churn actually happened: keys spread beyond the bootstrap shard
    owners = {e["owner"] for s in range(backend.n)
              for e in backend.sublists(s)}
    assert len(owners) > 1


def test_future_protocol():
    client = DiLiClient(LocalBackend(_cfg(num_shards=1)))
    f1 = client.insert(5)
    with pytest.raises(RuntimeError, match="pending"):
        f1.result(wait=False)
    assert not f1.done
    assert f1.result()          # wait=True drives drain()
    assert f1.done and f1.src == 0
    f2, f3 = client.insert(5), client.find(5)
    batch = client.remove_batch([5, 6])
    client.drain()
    assert not f2.result()      # duplicate insert
    assert f3.result()
    assert batch.done and batch.results() == [True, False]
    assert len(batch) == 2 and [b.key for b in batch] == [5, 6]


def test_registry_cache_semantics():
    cache = RegistryCache([(0, 10, 1), (10, 20, 2)])
    assert cache.lookup(1) == 1
    assert cache.lookup(10) == 1     # half-open: (keymin, keymax]
    assert cache.lookup(11) == 2
    assert cache.lookup(0) is None
    assert cache.lookup(21) is None
    cache.load([(0, 20, 3)])
    assert cache.lookup(10) == 3 and len(cache) == 1


# ----------------------------------------------------------------- pacing

def test_pacing_queues_instead_of_overflow():
    """A burst that overflows raw submission drains cleanly via the client.

    The raw path feeds ``in_cap`` delegating ops into one round, whose
    replies exceed ``mailbox_cap``; the client's in-flight cap keeps every
    round under budget, so the same burst queues client-side.
    """
    cfg = _cfg(num_shards=2, mailbox_cap=16, batch_size=32, move_batch=4)
    n_ops = 300
    keys = list(range(1, n_ops + 1))

    # control: raw fixed-shard burst at a non-owner overflows the outbox
    raw = Cluster(cfg)
    raw.submit(1, [OP_INSERT] * n_ops, keys)
    with pytest.raises(OutboxOverflow):
        raw.run_until_quiet(400)

    # the client paces the identical burst (fixed-shard routing, worst
    # case: every op delegates) without surfacing the overflow
    backend = LocalBackend(cfg)
    client = DiLiClient(backend, route_cache=False, home_shard=1)
    batch = client.insert_batch(keys)
    client.drain(max_rounds=4000)
    assert batch.results() == [True] * n_ops
    assert client.all_keys() == keys


# ---------------------------------------------------------------- routing

def _loaded_spread_backend(route_cache, *, seed=11):
    """Load 300 keys, balance until keys live on all 4 shards, drain."""
    backend = LocalBackend(_cfg(), seed=seed)
    client = DiLiClient(backend, balance=Balancer(backend),
                        route_cache=route_cache)
    rng = np.random.default_rng(seed)
    keys = rng.permutation(np.arange(1, 1200))[:300].tolist()
    client.insert_batch(keys)
    client.settle()
    owners = {e["owner"] for s in range(backend.n)
              for e in backend.sublists(s) if e["owner"] == s}
    assert len(owners) > 1, "balancer never spread the keyspace"
    client.balance = None       # freeze topology for the measured window
    return backend, client, keys


def test_cached_routing_reduces_hops():
    """Registry-cached routing beats fixed-shard submission on hops."""
    results = {}
    for cached in (True, False):
        backend, client, keys = _loaded_spread_backend(cached)
        if cached:
            client.refresh_route_cache()
        backend.stats.update(max_hops=0, delegated=0)
        probe = client.find_batch(keys[::3])
        client.drain()
        assert all(probe.results())
        results[cached] = dict(backend.stats)
    assert results[True]["max_hops"] < results[False]["max_hops"]
    assert results[True]["delegated"] < results[False]["delegated"]
    # a fresh cache routes every probe to its owner: zero delegations
    assert results[True]["max_hops"] == 0
    assert results[False]["max_hops"] >= 1


def test_wrong_route_replies_refresh_cache():
    """A stale cache is corrected by wrong-route completions, not manual
    refreshes: after the first delegated batch the client re-learns the
    registry and later ops go direct."""
    backend, client, keys = _loaded_spread_backend(True)
    # deliberately poison the cache back to the bootstrap view
    client._cache.load([(0, 2 ** 31 - 2, 0)])
    probe1 = client.find_batch(keys[:40])
    client.drain()
    assert all(probe1.results())
    assert client.wrong_routes > 0, "expected stale-route corrections"
    # cache now refreshed from the correcting shard: a second probe of the
    # same keys is hop-free
    backend.stats.update(max_hops=0, delegated=0)
    probe2 = client.find_batch(keys[:40])
    client.drain()
    assert all(probe2.results())
    assert backend.stats["max_hops"] == 0
    assert backend.stats["delegated"] == 0


# ---------------------------------------------------------- backend parity

PARITY_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np

    from repro.api import DiLiClient, LocalBackend, ShardMapBackend
    from repro.core.oracle import OracleList
    from repro.core.types import DiLiConfig, OP_FIND, OP_INSERT, OP_REMOVE

    cfg = DiLiConfig(num_shards=4, pool_capacity=1024, max_sublists=16,
                     max_ctrs=16, max_scan=1024, batch_size=8,
                     mailbox_cap=64, move_batch=4)

    def run(backend):
        client = DiLiClient(backend)
        oracle = OracleList()
        rng = np.random.default_rng(0)
        results = []
        load = rng.permutation(np.arange(1, 120))[:60].tolist()
        batch = client.insert_batch(load)
        oracle.apply_batch([OP_INSERT] * len(load), load)
        client.drain()
        results += batch.results()

        # identical explicit background commands on both backends
        subs = [e for e in backend.sublists(0) if e["owner"] == 0]
        big = max(subs, key=lambda e: e["size"])
        mid = backend.middle_item(0, big["head_idx"])
        backend.split(0, big["keymax"], mid)
        client.drain()
        subs = [e for e in backend.sublists(0) if e["owner"] == 0]
        backend.move(0, subs[-1]["keymax"], 2)
        mixed = []
        for r in range(16):
            kinds = rng.choice([OP_FIND, OP_INSERT, OP_REMOVE], 8).tolist()
            keys = rng.integers(1, 160, 8).tolist()
            mixed.append(client.submit(kinds, keys))
            oracle.apply_batch(kinds, keys)
            client.pump()
        client.drain()
        for b in mixed:
            results += b.results()
        return results, backend.all_keys(), oracle

    res_local, keys_local, oracle_l = run(LocalBackend(cfg))
    res_smap, keys_smap, oracle_s = run(ShardMapBackend(cfg))

    assert oracle_l.snapshot() == oracle_s.snapshot()
    assert keys_local == sorted(oracle_l.snapshot()), "local diverged"
    assert keys_smap == sorted(oracle_s.snapshot()), "shard_map diverged"
    assert keys_local == keys_smap
    assert res_local == res_smap, "linearized results differ"
    print(f"OK parity over {len(res_local)} checked ops, "
          f"{len(keys_local)} final keys")
""")


@pytest.mark.slow
def test_backend_parity_local_vs_shard_map():
    """Same seeded workload + same bg commands through both backends →
    identical linearized results and final key sets."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", PARITY_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "OK parity" in r.stdout
